"""Synthetic data pipeline (offline container: no real corpora/image sets).

Deterministic, seekable streams so training is reproducible and resumable:

* `TokenStream` — a Zipf-ish Markov token source with bin-packing into fixed
  (tokens, targets) blocks; statistically non-trivial (learnable bigram
  structure) so train-loss decreases measurably in examples/.
* `latent_images` — smooth random-field latents for DiT training.
* `stub_embeds` — the modality-frontend stand-ins (audio frames / image
  patches) required by the [audio]/[vlm] carve-out.
"""

from __future__ import annotations

import numpy as np


class TokenStream:
    """Markov-chain token generator with packing. Seekable via block index."""

    def __init__(self, vocab_size: int, seq_len: int, batch: int, seed: int = 0,
                 branching: int = 32):
        self.V = vocab_size
        self.S = seq_len
        self.B = batch
        rng = np.random.default_rng(seed)
        # sparse bigram table: each token can be followed by `branching` tokens
        self.next_tokens = rng.integers(0, vocab_size,
                                        size=(vocab_size, branching))
        probs = rng.dirichlet(0.5 * np.ones(branching), size=vocab_size)
        self.cum_probs = np.cumsum(probs, axis=-1)

    def block(self, index: int):
        """Return dict(tokens (B, S), targets (B, S)) for a block index."""
        rng = np.random.default_rng(hash(("block", index)) % (2**63))
        seq = np.empty((self.B, self.S + 1), np.int64)
        seq[:, 0] = rng.integers(0, self.V, size=self.B)
        u = rng.random((self.B, self.S))
        for s in range(self.S):
            cur = seq[:, s]
            choice = (u[:, s, None] < self.cum_probs[cur]).argmax(-1)
            seq[:, s + 1] = self.next_tokens[cur, choice]
        return {"tokens": seq[:, :-1].astype(np.int32),
                "targets": seq[:, 1:].astype(np.int32)}

    def __iter__(self):
        i = 0
        while True:
            yield self.block(i)
            i += 1


def latent_images(batch: int, tokens: int, latent_dim: int, seed: int = 0):
    """Smooth random-field latents in [-1, 1] (stand-in for VAE latents)."""
    rng = np.random.default_rng(seed)
    base = rng.normal(size=(batch, tokens, latent_dim))
    # smooth along the token axis (images have local structure)
    k = np.array([0.25, 0.5, 0.25])
    sm = np.apply_along_axis(lambda a: np.convolve(a, k, mode="same"), 1, base)
    return np.tanh(1.5 * sm).astype(np.float32)


def stub_embeds(batch: int, tokens: int, d_model: int, seed: int = 0):
    """Frontend-stub embeddings (audio frames / image patches)."""
    rng = np.random.default_rng(seed)
    return (0.02 * rng.normal(size=(batch, tokens, d_model))).astype(np.float32)


def class_ids(batch: int, num_classes: int = 1000, seed: int = 0):
    return np.random.default_rng(seed).integers(
        0, num_classes, size=(batch,)).astype(np.int32)
