"""olmo-1b [dense]: 16L d_model=2048 16H (MHA kv=16) d_ff=8192 vocab=50304,
non-parametric LayerNorm, GELU MLP (OLMo uses plain SwiGLU-free MLP at 1B).
[arXiv:2402.00838]"""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="olmo-1b", family="dense", source="arXiv:2402.00838",
        num_layers=16, d_model=2048, num_heads=16, num_kv_heads=16,
        d_ff=8192, vocab_size=50304, norm="nonparam_ln", act="gelu",
        tie_embeddings=True, latent_dim=64,
    )
