"""mamba2-780m [ssm]: attention-free SSD stack, 48L d_model=1536,
ssm_state=128, vocab=50280. [arXiv:2405.21060]"""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="mamba2-780m", family="ssm", source="arXiv:2405.21060",
        num_layers=48, d_model=1536, num_heads=0, num_kv_heads=0, head_dim=0,
        d_ff=0, vocab_size=50280,
        ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
        latent_dim=64,
    )
