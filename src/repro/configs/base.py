"""Model configuration dataclass shared by every architecture family."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclass
class ModelConfig:
    arch_id: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio | dit
    source: str = ""                # citation for the exact numbers

    # transformer core
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: Optional[int] = None
    head_dim: Optional[int] = None
    d_ff: int = 1024
    vocab_size: int = 1000
    qkv_bias: bool = False
    norm: str = "rmsnorm"           # rmsnorm | layernorm | nonparam_ln
    act: str = "swiglu"             # swiglu | gelu
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None
    tie_embeddings: bool = False

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: Optional[int] = None  # per-expert hidden (granite: 512)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 128

    # hybrid (zamba2-style shared attention)
    attn_every: int = 0             # one shared attn block per this many ssm layers

    # VLM (llama-3.2-vision-style cross-attention layers)
    cross_attn_every: int = 0
    image_tokens: int = 0

    # audio enc-dec (whisper-style)
    encoder_layers: int = 0
    audio_frames: int = 0

    # diffusion
    latent_dim: int = 0             # diffusion-LM latent width (0 = AR only)
    patch_tokens: int = 0           # DiT tokens per image

    # numerics
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = False            # checkpoint each scanned block (training)

    # performance knobs (EXPERIMENTS.md §Perf hillclimbs; 0 = baseline)
    attention_chunk: int = 0       # blockwise attention over query chunks
    moe_shard_map: bool = False    # H1 iter-2: MoE block under shard_map
    moe_dispatch_groups: int = 0   # group-local MoE dispatch (H1): groups
    #                                aligned with the data shards so the
    #                                position-in-expert cumsum never crosses
    #                                shard boundaries

    # kernels (fast-eval path, DESIGN.md §11). None = platform policy
    # (Pallas on TPU, jnp oracle elsewhere); pin "pallas" | "interpret" |
    # "jnp" explicitly (CI runs the real kernels under "interpret").
    attention_backend: Optional[str] = None  # kernels/flash_attention dispatch
    adaln_backend: Optional[str] = None      # kernels/adaln_modulate dispatch
    quant_backend: Optional[str] = None      # kernels/quant_matmul dispatch

    # quantized denoiser path (DESIGN.md §14): a models.quant.QuantSpec when
    # the param tree carries quant records, None for the float path. Typed
    # loosely to keep configs free of a models import.
    quant: Optional[object] = None

    def __post_init__(self):
        if self.num_kv_heads is None:
            self.num_kv_heads = self.num_heads
        if self.head_dim is None and self.num_heads:
            self.head_dim = self.d_model // self.num_heads

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def weight_dtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def reduced(self, **overrides) -> "ModelConfig":
        """CPU-smoke-test variant of the same family (<=2 layers, small dims)."""
        base = dict(
            num_layers=2,
            d_model=min(self.d_model, 128),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else None,
            head_dim=32,
            d_ff=min(self.d_ff, 256) or 256,
            vocab_size=min(self.vocab_size, 512),
            dtype="float32",
            param_dtype="float32",
        )
        if self.num_experts:
            base.update(num_experts=min(self.num_experts, 4),
                        experts_per_token=min(self.experts_per_token, 2),
                        moe_d_ff=min(self.moe_d_ff or self.d_ff, 128))
        if self.ssm_state:
            base.update(ssm_state=min(self.ssm_state, 16), ssm_head_dim=32,
                        ssm_chunk=32)
        if self.attn_every:
            base.update(attn_every=2, num_layers=4)
        if self.cross_attn_every:
            base.update(cross_attn_every=2, num_layers=4,
                        image_tokens=min(self.image_tokens, 16) or 16)
        if self.encoder_layers:
            base.update(encoder_layers=2, audio_frames=min(self.audio_frames, 32) or 32)
        if self.latent_dim:
            base.update(latent_dim=min(self.latent_dim, 32))
        base.update(overrides)
        return dataclasses.replace(self, **base)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
