"""llama-3.2-vision-90b [vlm]: 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256; gated cross-attention layers every 5th layer read a fixed
buffer of projected image-patch embeddings (ViT encoder STUBBED).
[hf:meta-llama/Llama-3.2-11B-Vision, scaled per assignment]

long_500k runs with sliding_window=8192 on the self-attn layers; cross-attn
reads the fixed image buffer (O(1) in sequence length). DESIGN.md §7.2."""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="llama-3.2-vision-90b", family="vlm",
        source="hf:meta-llama/Llama-3.2-11B-Vision",
        num_layers=100, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=28672, vocab_size=128256,
        cross_attn_every=5, image_tokens=1600, rope_theta=5e5,
        latent_dim=64,
    )
