"""dit-cifar — paper-native unconditional CIFAR10-scale pixel diffusion
backbone (stand-in for the ScoreSDE DDPM++ checkpoint the paper samples;
DESIGN.md §6). 8 blocks, d_model=384, 64 tokens of dim 48 (= 4x4 patches of
32x32x3 pixels). [Song et al. 2021b for the setting]."""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="dit-cifar", family="dit", source="arXiv:2011.13456",
        num_layers=8, d_model=384, num_heads=6, num_kv_heads=6,
        d_ff=1536, vocab_size=0, act="gelu", norm="layernorm",
        latent_dim=48, patch_tokens=64,
    )
