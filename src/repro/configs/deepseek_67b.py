"""deepseek-67b [dense]: llama-arch, 95L d_model=8192 64H (GQA kv=8)
d_ff=22016 vocab=102400. [arXiv:2401.02954]"""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="deepseek-67b", family="dense", source="arXiv:2401.02954",
        num_layers=95, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=22016, vocab_size=102400, latent_dim=64,
    )
