"""qwen2-0.5b [dense]: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936,
QKV bias, tied embeddings. [arXiv:2407.10671]

14 heads / kv=2 do not divide a 16-way model axis -> head dims replicated on
'model' (DESIGN.md §7.3); d_ff=4864=16*304 and vocab shard fine."""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen2-0.5b", family="dense", source="arXiv:2407.10671",
        num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
        d_ff=4864, vocab_size=151936, qkv_bias=True, tie_embeddings=True,
        rope_theta=1e6, latent_dim=64,
    )
