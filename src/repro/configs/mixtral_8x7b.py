"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
8 experts top-2, native sliding-window attention (4096). [arXiv:2401.04088]"""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="mixtral-8x7b", family="moe", source="arXiv:2401.04088",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=32000,
        num_experts=8, experts_per_token=2, moe_d_ff=14336,
        sliding_window=4096, latent_dim=64,
    )
