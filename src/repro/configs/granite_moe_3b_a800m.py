"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) per-expert
d_ff=512, vocab=49155, 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base family]"""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="granite-moe-3b-a800m", family="moe",
        source="hf:ibm-granite/granite-3.0-1b-a400m-base",
        num_layers=32, d_model=1536, num_heads=24, num_kv_heads=8,
        d_ff=512, vocab_size=49155,
        num_experts=40, experts_per_token=8, moe_d_ff=512,
        latent_dim=64,
    )
