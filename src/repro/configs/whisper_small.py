"""whisper-small [audio]: enc-dec, 12 encoder + 12 decoder layers,
d_model=768 12H d_ff=3072 vocab=51865; conv/mel frontend STUBBED — the
input_specs provide 1500 precomputed frame embeddings. [arXiv:2212.04356]

long_500k is SKIPPED for this arch (enc-dec full cross-attention; DESIGN.md §7.2)."""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="whisper-small", family="audio", source="arXiv:2212.04356",
        num_layers=12, encoder_layers=12, d_model=768, num_heads=12,
        num_kv_heads=12, d_ff=3072, vocab_size=51865, act="gelu",
        norm="layernorm", audio_frames=1500, latent_dim=64,
    )
