"""zamba2-7b [hybrid]: Mamba2 backbone + one parameter-SHARED attention block
applied every 6 SSM layers. 81L d_model=3584 32H (GQA kv=32) d_ff=14336
vocab=32000, ssm_state=64. [arXiv:2411.15242]

Note: the released checkpoints add per-invocation LoRA deltas to the shared
block and concatenate the original embedding into the attention input; both
are omitted here (parameter sharing itself is the architectural feature).
long_500k uses sliding_window=8192 on the shared attention (DESIGN.md §7.2)."""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="zamba2-7b", family="hybrid", source="arXiv:2411.15242",
        num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
        d_ff=14336, vocab_size=32000,
        ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_groups=1,
        attn_every=6, latent_dim=64,
    )
