"""Architecture registry: --arch <id> lookup over the assigned pool + the
paper-native diffusion configs. Each config file cites its source."""

from __future__ import annotations

import importlib

from .base import INPUT_SHAPES, InputShape, ModelConfig

ARCH_IDS = [
    "zamba2-7b", "mixtral-8x7b", "qwen2-0.5b", "olmo-1b", "whisper-small",
    "qwen2.5-3b", "granite-moe-3b-a800m", "llama-3.2-vision-90b",
    "deepseek-67b", "mamba2-780m",
    # paper-native diffusion backbones (beyond the assigned pool)
    "dit-i256", "dit-cifar",
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.config()


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]


def all_arch_ids(include_paper_native: bool = False):
    return ARCH_IDS if include_paper_native else ARCH_IDS[:10]
