"""dit-i256 — paper-native conditional ImageNet-256 latent diffusion backbone
(TPU adaptation of the paper's ADM UNet; DESIGN.md §6). DiT-XL/2 geometry:
28 blocks, d_model=1152, 16 heads, 256 latent patch tokens of dim 32
(= 2x2 patches of a 32x32x8 latent). [Peebles & Xie 2023; Dhariwal & Nichol
2021 for the guided-sampling setting the paper evaluates]."""

from .base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        arch_id="dit-i256", family="dit", source="arXiv:2212.09748",
        num_layers=28, d_model=1152, num_heads=16, num_kv_heads=16,
        d_ff=4608, vocab_size=0, act="gelu", norm="layernorm",
        latent_dim=32, patch_tokens=256,
    )
