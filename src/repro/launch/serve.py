"""Batched serving loop. Token models: prefill a batch of prompts, then
greedy/temperature decode with the per-family cache. Diffusion models (dit
family): one request = one latent to generate, served with *continuous
batching* (DESIGN.md §9) — a request-level scheduler over `--batch` slots
drives the engine's per-slot step function, so requests admit the moment a
slot frees, carry their own seed and guidance scale, and emit without waiting
for a batch to drain. One batched (optionally 2B cond+uncond stacked) network
eval per tick; any registered solver; CPU-runnable at reduced scale.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --batch 4 --prompt-len 32 --gen 32
    PYTHONPATH=src python -m repro.launch.serve --arch dit-cifar --reduced \
        --batch 8 --nfe 10 --solver dpmpp --order 2 --cfg-scale 2.0
    PYTHONPATH=src python -m repro.launch.serve --arch dit-cifar --reduced \
        --batch 4 --nfe 10 --arrival-rate 0.4 --requests 16   # Poisson traffic
    PYTHONPATH=src python -m repro.launch.serve --arch dit-cifar --reduced \
        --batch 8 --tiers fast,balanced,quality --arrival-rate 0.5
        # quality tiers: one compiled plan-bank program (DESIGN.md §10)
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import get_config
from ..data.synthetic import TokenStream, class_ids, stub_embeds
from ..models import api


def serve(arch: str, *, reduced=True, batch=4, prompt_len=32, gen=32,
          temperature=0.0, seed=0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    rng = jax.random.PRNGKey(seed)
    params = api.init_params(cfg, rng)
    max_len = prompt_len + gen

    stream = TokenStream(cfg.vocab_size, prompt_len, batch, seed)
    batch_in = {"tokens": jnp.asarray(stream.block(0)["tokens"])}
    if cfg.family == "vlm":
        batch_in["image_embeds"] = jnp.asarray(
            stub_embeds(batch, cfg.image_tokens, cfg.d_model, seed))
    if cfg.family == "audio":
        batch_in["audio_embeds"] = jnp.asarray(
            stub_embeds(batch, cfg.audio_frames, cfg.d_model, seed))

    prefill = jax.jit(lambda p, b: api.prefill_fn(cfg)(p, b, max_len))
    decode = jax.jit(lambda p, c, t, pos: api.decode_fn(cfg)(p, c, t, pos))

    t0 = time.time()
    logits, cache = prefill(params, batch_in)
    prefill_s = time.time() - t0

    def sample_tok(lg, key):
        if temperature <= 0:
            return jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, lg[:, -1] / temperature).astype(jnp.int32)

    toks = []
    tok = sample_tok(logits, rng)
    t0 = time.time()
    for i in range(gen):
        toks.append(np.asarray(tok))
        logits, cache = decode(params, cache, tok[:, None],
                               jnp.int32(prompt_len + i))
        rng, sub = jax.random.split(rng)
        tok = sample_tok(logits, sub)
    jax.block_until_ready(logits)
    decode_s = time.time() - t0
    out = np.stack(toks, axis=1)
    print(f"prefill {prefill_s*1e3:.1f} ms; decode {gen} steps "
          f"{decode_s*1e3:.1f} ms ({decode_s/gen*1e3:.2f} ms/tok, "
          f"batch={batch})")
    return out


def serve_diffusion(arch: str, *, reduced=True, batch=4, nfe=10, order=3,
                    solver="unipc", fused_update=True, cfg_scale=0.0,
                    cfg_schedule="constant", thresholding=False, seed=0,
                    arrival_rate=None, trace=None, requests=None,
                    plan_bank=None, tiers=None, eval_dtype="float32",
                    quant="none", pipeline_depth=2, trace_out=None,
                    metrics_out=None, metrics_every=None,
                    probe_fraction=0.0, probe_ref_nfe=64,
                    resilience=None, faults=None):
    """Continuous-batching diffusion serving through the engine's per-slot
    step program (`SamplerEngine.build_step` + `serving.SlotScheduler`):
    `batch` slots, requests admitted the tick a slot frees, per-request
    seed/cfg-scale, one batched eps-net eval per tick. `cfg_scale` turns on
    fused classifier-free guidance — ONE (2B-batched, cond+uncond stacked)
    network call per tick, the per-slot guidance scale riding the step state;
    `thresholding` adds dynamic thresholding of the x0 prediction. On TPU the
    fused-update dispatch selects the single-pass Pallas combine, and the
    slot batch shards over the data axis under SERVE_RULES.

    Traffic: `trace` (a JSON arrival trace) or `arrival_rate` (Poisson,
    requests per tick) serve asynchronous traffic; with neither, `batch`
    requests all arrive at tick 0 (classic batch serving, now through the
    same scheduler). The step program is compiled ahead of time
    (`jit(...).lower(...).compile()`), so compile and steady-state serving
    are reported separately. Returns the finished latents ordered by rid.

    `pipeline_depth` (DESIGN.md §13) is how many ticks the scheduler keeps
    in flight: the default 2 overlaps host bookkeeping and admission with
    device execution (JAX async dispatch, trailing-stream readback of
    finished latents); 1 is the synchronous legacy loop. Finished latents
    and tick-denominated metrics are bit-identical across depths.

    Quality tiers (DESIGN.md §10): `plan_bank` (a JSON bank of tuned
    `SolverPlan`s from `repro.launch.tune --bank`) or `tiers` (a list of
    hand-set tier names from `engine.default_tier_specs`) compiles ONE
    `StepProgram` serving every tier — requests tagged fast/balanced/quality
    coexist in the same batch with per-slot row offsets. Untagged generated
    traffic cycles through the tiers.

    Resilience (DESIGN.md §16): `resilience` (a `serving.ResilienceConfig`)
    bounds the admission queue with a shed policy, expires queued requests
    past their TTL, re-admits requests whose latent came back non-finite
    (walking a degraded-tier fallback chain), and recovers from host/device
    desync instead of raising. `faults` (a `serving.FaultPlan`, CLI
    `--inject-faults`) deterministically injects NaN latents, meta-counter
    corruption, and admission clock skew to exercise those paths — requests
    no fault touched still finish bit-identical to a clean run.

    Observability (DESIGN.md §15): `trace_out` records per-tick / per-request
    spans into a Chrome trace_event JSON (opens in chrome://tracing);
    `metrics_out` writes the metrics artifact (registry snapshot delta +
    derived ServeMetrics + Prometheus exposition, with periodic rows every
    `metrics_every` ticks); `probe_fraction` > 0 replays that fraction of
    completions against a `probe_ref_nfe`-step fp32 UniPC reference and
    records per-tier trajectory-discrepancy gauges. All three are off by
    default — the untraced path is byte-for-byte the old serving loop.
    Render the artifacts with `python -m repro.launch.obsreport`.
    """
    from ..engine import EngineSpec, default_tier_specs
    from ..diffusion import VPLinear
    from ..obs import QualityProbe, Tracer, build_reference_fn
    from ..obs import metrics as obsm
    from ..obs.report import write_metrics_artifact
    from ..serving import Request, SlotScheduler, load_trace, poisson_requests, run_trace
    from .sample import NULL_CLASS_ID, build_engine

    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    rng = jax.random.PRNGKey(seed)
    params = api.init_params(cfg, rng)
    # a cached plan bank (DESIGN.md §12) decides the engine's cache wiring,
    # so load it before build_engine; every cached tier must agree on the one
    # static block boundary the compiled program bakes in
    plans = None
    cache_block = 0
    if plan_bank is not None:
        from ..tuning import load_bank

        plans = load_bank(plan_bank)
        blocks = sorted({p.cache_block for p in plans.values()
                         if p.cache_block})
        if len(blocks) > 1:
            raise ValueError(
                f"plan bank {plan_bank} mixes cache boundaries {blocks}; one "
                f"compiled program serves one static cache_block — retune "
                f"the bank with a single --cache-block")
        cache_block = blocks[0] if blocks else 0
        if cache_block and cfg_scale != 0.0:
            raise ValueError(
                f"plan bank {plan_bank} schedules feature reuse "
                f"(cache_block={cache_block}) but --cfg-scale={cfg_scale}; "
                f"cached programs serve unconditional sampling only")
        # a quant-tuned bank records its tier in plan meta (launch/tune.py);
        # one quantized param tree serves the whole program, so the bank
        # must be uniform and must agree with an explicit --quant
        bank_quants = sorted({p.meta.get("quant", "none")
                              for p in plans.values()})
        if len(bank_quants) > 1:
            raise ValueError(
                f"plan bank {plan_bank} mixes quant tiers {bank_quants}; "
                f"one quantized param tree serves one compiled program — "
                f"retune the bank with a single --quant")
        if bank_quants[0] != "none":
            if quant not in ("none", bank_quants[0]):
                raise ValueError(
                    f"plan bank {plan_bank} was tuned for "
                    f"quant={bank_quants[0]!r} but --quant={quant!r}; a "
                    f"plan's parity gate only holds for the tier it was "
                    f"scored against")
            quant = bank_quants[0]
    engine = build_engine(cfg, params, VPLinear(), batch, seed,
                          want_cfg=cfg_scale != 0.0, per_request_cond=True,
                          eval_dtype=eval_dtype, cache_block=cache_block,
                          quant=quant)
    spec = EngineSpec(solver=solver, nfe=nfe, order=order,
                      cfg_scale=cfg_scale, cfg_schedule=cfg_schedule,
                      thresholding=thresholding, fused_update=fused_update,
                      eval_dtype=eval_dtype, quant=quant)
    common = dict(cfg_scale=cfg_scale, cfg_schedule=cfg_schedule,
                  thresholding=thresholding, fused_update=fused_update,
                  eval_dtype=eval_dtype, cache_block=cache_block,
                  quant=quant)
    tier_names = None
    if plans is not None:
        schedule = engine.schedule
        tier_specs = {
            name: EngineSpec(solver="unipc", nfe=p.nfe,
                             order=max(p.orders), prediction=p.prediction,
                             **common)
            for name, p in plans.items()}
        tables = {name: p.compile(schedule) for name, p in plans.items()}
        program = engine.build_bank(tier_specs, tables)
        tier_names = list(plans)
    elif tiers:
        all_specs = default_tier_specs(**common)
        unknown = [t for t in tiers if t not in all_specs]
        if unknown:
            raise ValueError(f"unknown tiers {unknown}; hand-set tiers are "
                             f"{sorted(all_specs)}")
        program = engine.build_bank({t: all_specs[t] for t in tiers})
        tier_names = list(tiers)
    else:
        program = engine.build_step(spec)
    # idle slots are conditioned on the null class; every request carries its
    # own class id (drawn from its seed), so conditioning is reproducible
    # regardless of which slot the scheduler admits it into
    tracer = None
    if trace_out is not None:
        tracer = Tracer(meta={"arch": arch, "slots": batch,
                              "pipeline_depth": pipeline_depth,
                              "eval_dtype": eval_dtype, "quant": quant,
                              "cache_block": cache_block,
                              "cfg_scale": cfg_scale,
                              "tiers": tier_names})
    probe = None
    if probe_fraction > 0.0:
        # the reference engine is deliberately plain — fp32, unquantized,
        # uncached — so the probe measures what the SERVING tier's precision
        # tricks cost, against the converged solver trajectory
        ref_engine = build_engine(cfg, params, VPLinear(), batch, seed,
                                  want_cfg=cfg_scale != 0.0,
                                  per_request_cond=True)
        probe = QualityProbe(
            build_reference_fn(ref_engine, spec, ref_nfe=probe_ref_nfe),
            probe_fraction)
    sched = SlotScheduler(program, batch,
                          (cfg.patch_tokens, cfg.latent_dim),
                          extras_init={"class_ids": NULL_CLASS_ID},
                          pipeline_depth=pipeline_depth,
                          tracer=tracer, probe=probe,
                          resilience=resilience, faults=faults)
    compile_s = sched.aot_compile()
    if trace is not None:
        reqs = load_trace(trace)
    elif arrival_rate is not None:
        n_req = requests if requests is not None else 4 * batch
        reqs = poisson_requests(n_req, arrival_rate, seed=seed,
                                base_seed=seed, tiers=tier_names)
    else:
        reqs = [Request(rid=i, seed=seed + i) for i in range(batch)]
    for r in reqs:
        # single assignment point for untagged requests on a tiered program
        # (trace requests may carry their own tags)
        if tier_names is not None and r.tier is None:
            r.tier = tier_names[r.rid % len(tier_names)]
        if r.extras is None or "class_ids" not in r.extras:
            r.extras = {**(r.extras or {}),
                        "class_ids": int(class_ids(1, seed=r.seed)[0])}
    snap0 = sched.registry.snapshot()
    snapshot_log = [] if metrics_out is not None else None
    if metrics_out is not None and not metrics_every:
        metrics_every = 8
    m = run_trace(sched, reqs, snapshot_every=metrics_every,
                  snapshot_log=snapshot_log)
    if trace_out is not None:
        exported = tracer.export(trace_out)
        print(f"trace: {len(exported['traceEvents'])} events "
              f"({tracer.dropped} dropped) -> {trace_out}")
    if metrics_out is not None:
        write_metrics_artifact(
            metrics_out,
            metrics=obsm.delta(snap0, sched.registry.snapshot()),
            serve_metrics=m.row(),
            static={"mode": m.mode, "slots": m.slots, "n_rows": m.n_rows,
                    "pipeline_depth": m.pipeline_depth},
            exposition=sched.registry.exposition(),
            rows=snapshot_log,
            probe=probe.summary() if probe is not None else None)
        print(f"metrics: {len(snapshot_log)} periodic rows -> {metrics_out}")
    if probe is not None:
        for t, row in sorted(probe.summary().items()):
            print(f"  probe tier {t}: {row['count']} replayed, "
                  f"discrepancy mean {row['mean']:.3e} max {row['max']:.3e} "
                  f"(vs fp32 unipc-3 nfe={probe_ref_nfe})")
    mode = (f"bank[{','.join(tier_names)}]" if tier_names
            else f"{solver} nfe={nfe} order={order}")
    print(f"diffusion slots={batch} {mode} depth={m.pipeline_depth} "
          f"cfg={cfg_scale} fused_update={fused_update} eval={eval_dtype} "
          f"quant={quant}: "
          f"compile {compile_s:.2f}s (AOT), tick {m.tick_s*1e3:.1f} ms, "
          f"{m.completed}/{m.requests} requests, "
          f"throughput {m.throughput_rps:.2f} req/s, "
          f"latency p50/p95 {m.latency_s_p50*1e3:.0f}/"
          f"{m.latency_s_p95*1e3:.0f} ms, occupancy {m.occupancy:.2f}, "
          f"evals/latent {m.evals_per_latent:.1f}")
    if (m.rejected or m.retries or m.failed or m.recoveries
            or m.faults_injected):
        print(f"  resilience: {m.rejected} rejected "
              f"({m.expired} expired), {m.degraded} shed-degraded, "
              f"{m.retries} retries, {m.failed} failed, "
              f"{m.recoveries} desync recoveries, "
              f"{m.faults_injected} faults injected")
        for ev in sched.events:
            print(f"    event {ev}")
    if m.per_tier:
        for t, row in m.per_tier.items():
            cost = (f" ({row['eval_cost']:.2f} full-eval units)"
                    if row["eval_cost"] and row["eval_cost"] != row["evals"]
                    else "")
            print(f"  tier {t}: {row['completed']} done, "
                  f"{row['evals']} evals/request{cost}, "
                  f"p50 latency {row['latency_ticks_p50']:.0f} ticks")
    # failed completions (retry budget exhausted on a non-finite latent)
    # carry poisoned arrays; never ship those
    order_by_rid = sorted((c for c in sched.completions if c.ok),
                          key=lambda c: c.rid)
    if not order_by_rid:  # e.g. an empty trace
        return np.zeros((0, cfg.patch_tokens, cfg.latent_dim), np.float32)
    return np.stack([c.latent for c in order_by_rid], axis=0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--nfe", type=int, default=None,
                    help="diffusion serving: sampler steps (default 10; "
                         "incompatible with --plan-bank/--tiers, which "
                         "carry per-tier schedules)")
    ap.add_argument("--order", type=int, default=None,
                    help="diffusion serving: solver order (default 3; "
                         "incompatible with --plan-bank/--tiers)")
    from ..engine import SOLVERS
    ap.add_argument("--solver", default=None, choices=sorted(SOLVERS),
                    help="diffusion serving: any engine-registered solver "
                         "(default unipc; incompatible with "
                         "--plan-bank/--tiers)")
    ap.add_argument("--no-fused-update", action="store_true",
                    help="diffusion serving: pin the jnp op-chain combine")
    ap.add_argument("--cfg-scale", type=float, default=0.0,
                    help="diffusion serving: fused classifier-free guidance "
                         "scale (0 = off; one batched eval per step)")
    ap.add_argument("--cfg-schedule", default="constant",
                    choices=["constant", "linear", "cosine"])
    ap.add_argument("--thresholding", action="store_true",
                    help="diffusion serving: dynamic thresholding (off by "
                         "default)")
    ap.add_argument("--eval-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="diffusion serving: eps-network eval precision "
                         "(default fp32); bfloat16 halves the network's "
                         "serving HBM traffic — solver state and combine "
                         "weights stay fp32 (DESIGN.md §11)")
    ap.add_argument("--quant", default="none",
                    choices=["none", "w8a16", "w8a8", "fp8a16", "w4a16"],
                    help="diffusion serving: quantized denoiser tier "
                         "(DESIGN.md §14); calibrates + installs int8/fp8 "
                         "weight records before compiling the step program. "
                         "A quant-tuned plan bank pins its own tier")
    ap.add_argument("--arrival-rate", type=float, default=None,
                    help="diffusion serving: Poisson request arrivals, in "
                         "requests per tick (one tick = one batched eval); "
                         "omit for all requests at tick 0")
    ap.add_argument("--trace", default=None,
                    help="diffusion serving: JSON arrival trace "
                         "(list of {rid, seed, arrival, cfg_scale})")
    ap.add_argument("--requests", type=int, default=None,
                    help="diffusion serving: request count for "
                         "--arrival-rate traffic (default 4x batch)")
    ap.add_argument("--pipeline-depth", type=int, default=2,
                    help="diffusion serving: ticks kept in flight "
                         "(DESIGN.md §13); 1 = synchronous loop, >= 2 "
                         "overlaps host bookkeeping with device execution; "
                         "finished latents are bit-identical at any depth")
    ap.add_argument("--trace-out", default=None,
                    help="diffusion serving: write a Chrome trace_event JSON "
                         "of per-tick and per-request spans (open in "
                         "chrome://tracing; DESIGN.md §15)")
    ap.add_argument("--metrics-out", default=None,
                    help="diffusion serving: write the metrics artifact "
                         "(registry snapshot + derived ServeMetrics + "
                         "Prometheus exposition); render with "
                         "python -m repro.launch.obsreport")
    ap.add_argument("--metrics-every", type=int, default=None,
                    help="periodic snapshot row cadence in executed ticks "
                         "for --metrics-out (default 8)")
    ap.add_argument("--probe-fraction", type=float, default=0.0,
                    help="diffusion serving: replay this fraction of "
                         "completed requests against a high-NFE fp32 "
                         "reference and record per-tier trajectory-"
                         "discrepancy gauges (0 = off)")
    ap.add_argument("--probe-ref-nfe", type=int, default=64,
                    help="NFE of the probe's UniPC-3 reference run")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="diffusion serving resilience (DESIGN.md §16): "
                         "bound on queued requests; past it new submissions "
                         "are shed per --shed-policy (default unbounded)")
    ap.add_argument("--shed-policy", default="reject",
                    choices=["reject", "degrade"],
                    help="what happens to submissions past --max-queue: "
                         "'reject' returns a typed Rejection, 'degrade' "
                         "remaps them to --degrade-tier first")
    ap.add_argument("--degrade-tier", default=None,
                    help="tier shed requests are remapped to under "
                         "--shed-policy degrade (needs --plan-bank/--tiers)")
    ap.add_argument("--ttl", type=float, default=None,
                    help="diffusion serving resilience: admission deadline "
                         "in tick-clock units past arrival; queued requests "
                         "whose deadline passes before a slot frees are "
                         "expired, not served late")
    ap.add_argument("--max-retries", type=int, default=0,
                    help="diffusion serving resilience: re-admissions after "
                         "a non-finite latent (same seed) before emitting a "
                         "failed completion (default 0)")
    ap.add_argument("--retry-fallback", default=None,
                    help="comma-separated safer-tier chain walked on retry "
                         "(needs --plan-bank/--tiers); omit to retry on the "
                         "same tier")
    ap.add_argument("--recovery", default="recover",
                    choices=["recover", "raise"],
                    help="host/device desync handling: 'recover' drains the "
                         "pipeline, resyncs from device state and requeues "
                         "(the default); 'raise' keeps the legacy hard "
                         "RuntimeError")
    ap.add_argument("--inject-faults", default=None,
                    help="diffusion serving chaos (DESIGN.md §16): "
                         "semicolon-separated fault clauses, e.g. "
                         "'nan:rid=2,step=1;meta:tick=6;skew:tick=3,delta=9' "
                         "or 'seed:7,requests=8,nfe=4' for a seeded plan")
    bank = ap.add_mutually_exclusive_group()
    bank.add_argument("--plan-bank", default=None,
                      help="diffusion serving: JSON bank of tuned SolverPlans"
                           " (repro.launch.tune --bank); serves every tier "
                           "from one compiled step program")
    bank.add_argument("--tiers", default=None,
                      help="diffusion serving: comma-separated hand-set "
                           "quality tiers (fast,balanced,quality) served "
                           "from one compiled step program")
    scale = ap.add_mutually_exclusive_group()
    scale.add_argument("--reduced", action="store_true",
                       help="reduced CPU-scale config (the default)")
    scale.add_argument("--full", action="store_true")
    args = ap.parse_args()
    from .sample import require_dit_for_cfg
    family = require_dit_for_cfg(ap, args.arch, args.cfg_scale)
    if family != "dit" and (args.arrival_rate is not None or args.trace):
        ap.error(f"--arrival-rate/--trace drive the diffusion request "
                 f"scheduler; --arch {args.arch} is family '{family}' "
                 f"(token serving decodes a fixed batch)")
    if family != "dit" and (args.plan_bank or args.tiers):
        ap.error(f"--plan-bank/--tiers serve diffusion quality tiers; "
                 f"--arch {args.arch} is family '{family}'")
    if family != "dit" and args.eval_dtype != "float32":
        ap.error(f"--eval-dtype configures the diffusion engine's network "
                 f"eval; --arch {args.arch} is family '{family}'")
    if family != "dit" and args.quant != "none":
        ap.error(f"--quant configures the diffusion engine's denoiser; "
                 f"--arch {args.arch} is family '{family}'")
    if ((args.plan_bank or args.tiers)
            and (args.solver is not None or args.nfe is not None
                 or args.order is not None)):
        ap.error("--solver/--nfe/--order configure a single-plan program; "
                 "a plan bank / tier program takes its per-tier schedules "
                 "from the bank (drop those flags)")
    solver = args.solver if args.solver is not None else "unipc"
    nfe = args.nfe if args.nfe is not None else 10
    order = args.order if args.order is not None else 3
    if args.arrival_rate is not None and args.arrival_rate <= 0:
        ap.error(f"--arrival-rate must be > 0 requests per tick, "
                 f"got {args.arrival_rate}")
    if family != "dit" and args.pipeline_depth != 2:
        ap.error(f"--pipeline-depth configures the diffusion serving loop; "
                 f"--arch {args.arch} is family '{family}'")
    if args.pipeline_depth < 1:
        ap.error(f"--pipeline-depth must be >= 1, got {args.pipeline_depth}")
    if family != "dit" and (args.trace_out or args.metrics_out
                            or args.probe_fraction):
        ap.error(f"--trace-out/--metrics-out/--probe-fraction instrument the "
                 f"diffusion serving loop; --arch {args.arch} is family "
                 f"'{family}'")
    if not 0.0 <= args.probe_fraction <= 1.0:
        ap.error(f"--probe-fraction must be in [0, 1], "
                 f"got {args.probe_fraction}")
    wants_resilience = (args.max_queue is not None or args.ttl is not None
                        or args.max_retries or args.retry_fallback
                        or args.degrade_tier or args.shed_policy != "reject"
                        or args.recovery != "recover")
    if family != "dit" and (wants_resilience or args.inject_faults):
        ap.error(f"--max-queue/--ttl/--max-retries/--inject-faults and "
                 f"friends configure the diffusion serving scheduler; "
                 f"--arch {args.arch} is family '{family}'")
    resilience = None
    if wants_resilience:
        from ..serving import ResilienceConfig
        resilience = ResilienceConfig(
            max_queue=args.max_queue, shed_policy=args.shed_policy,
            degrade_tier=args.degrade_tier, default_ttl=args.ttl,
            max_retries=args.max_retries,
            fallback=(tuple(args.retry_fallback.split(","))
                      if args.retry_fallback else ()),
            recovery=args.recovery)
    faults = None
    if args.inject_faults:
        from ..serving import parse_fault_spec
        faults = parse_fault_spec(args.inject_faults)
    if family == "dit":
        serve_diffusion(args.arch, reduced=not args.full, batch=args.batch,
                        nfe=nfe, order=order, solver=solver,
                        fused_update=not args.no_fused_update,
                        cfg_scale=args.cfg_scale,
                        cfg_schedule=args.cfg_schedule,
                        thresholding=args.thresholding,
                        arrival_rate=args.arrival_rate, trace=args.trace,
                        requests=args.requests, plan_bank=args.plan_bank,
                        tiers=(args.tiers.split(",") if args.tiers else None),
                        eval_dtype=args.eval_dtype, quant=args.quant,
                        pipeline_depth=args.pipeline_depth,
                        trace_out=args.trace_out,
                        metrics_out=args.metrics_out,
                        metrics_every=args.metrics_every,
                        probe_fraction=args.probe_fraction,
                        probe_ref_nfe=args.probe_ref_nfe,
                        resilience=resilience, faults=faults)
        return
    serve(args.arch, reduced=not args.full, batch=args.batch,
          prompt_len=args.prompt_len, gen=args.gen,
          temperature=args.temperature)


if __name__ == "__main__":
    main()
