"""Batched serving loop: prefill a batch of prompts, then greedy/temperature
decode with the per-family cache. CPU-runnable at reduced scale.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --batch 4 --prompt-len 32 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import get_config
from ..data.synthetic import TokenStream, stub_embeds
from ..models import api


def serve(arch: str, *, reduced=True, batch=4, prompt_len=32, gen=32,
          temperature=0.0, seed=0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    rng = jax.random.PRNGKey(seed)
    params = api.init_params(cfg, rng)
    max_len = prompt_len + gen

    stream = TokenStream(cfg.vocab_size, prompt_len, batch, seed)
    batch_in = {"tokens": jnp.asarray(stream.block(0)["tokens"])}
    if cfg.family == "vlm":
        batch_in["image_embeds"] = jnp.asarray(
            stub_embeds(batch, cfg.image_tokens, cfg.d_model, seed))
    if cfg.family == "audio":
        batch_in["audio_embeds"] = jnp.asarray(
            stub_embeds(batch, cfg.audio_frames, cfg.d_model, seed))

    prefill = jax.jit(lambda p, b: api.prefill_fn(cfg)(p, b, max_len))
    decode = jax.jit(lambda p, c, t, pos: api.decode_fn(cfg)(p, c, t, pos))

    t0 = time.time()
    logits, cache = prefill(params, batch_in)
    prefill_s = time.time() - t0

    def sample_tok(lg, key):
        if temperature <= 0:
            return jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, lg[:, -1] / temperature).astype(jnp.int32)

    toks = []
    tok = sample_tok(logits, rng)
    t0 = time.time()
    for i in range(gen):
        toks.append(np.asarray(tok))
        logits, cache = decode(params, cache, tok[:, None],
                               jnp.int32(prompt_len + i))
        rng, sub = jax.random.split(rng)
        tok = sample_tok(logits, sub)
    jax.block_until_ready(logits)
    decode_s = time.time() - t0
    out = np.stack(toks, axis=1)
    print(f"prefill {prefill_s*1e3:.1f} ms; decode {gen} steps "
          f"{decode_s*1e3:.1f} ms ({decode_s/gen*1e3:.2f} ms/tok, "
          f"batch={batch})")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    serve(args.arch, reduced=not args.full, batch=args.batch,
          prompt_len=args.prompt_len, gen=args.gen,
          temperature=args.temperature)


if __name__ == "__main__":
    main()
