"""Batched serving loop. Token models: prefill a batch of prompts, then
greedy/temperature decode with the per-family cache. Diffusion models (dit
family): one request = one latent to generate, the whole batch rides a
single jitted scan built by the engine — any registered solver, fused state
update, and optionally fused classifier-free guidance (one 2B-batched
cond+uncond eval per step; DESIGN.md §3-§4, §8). CPU-runnable at reduced
scale.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --reduced \
        --batch 4 --prompt-len 32 --gen 32
    PYTHONPATH=src python -m repro.launch.serve --arch dit-cifar --reduced \
        --batch 8 --nfe 10 --solver dpmpp --order 2 --cfg-scale 2.0
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.registry import get_config
from ..data.synthetic import TokenStream, class_ids, stub_embeds
from ..models import api


def serve(arch: str, *, reduced=True, batch=4, prompt_len=32, gen=32,
          temperature=0.0, seed=0):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    rng = jax.random.PRNGKey(seed)
    params = api.init_params(cfg, rng)
    max_len = prompt_len + gen

    stream = TokenStream(cfg.vocab_size, prompt_len, batch, seed)
    batch_in = {"tokens": jnp.asarray(stream.block(0)["tokens"])}
    if cfg.family == "vlm":
        batch_in["image_embeds"] = jnp.asarray(
            stub_embeds(batch, cfg.image_tokens, cfg.d_model, seed))
    if cfg.family == "audio":
        batch_in["audio_embeds"] = jnp.asarray(
            stub_embeds(batch, cfg.audio_frames, cfg.d_model, seed))

    prefill = jax.jit(lambda p, b: api.prefill_fn(cfg)(p, b, max_len))
    decode = jax.jit(lambda p, c, t, pos: api.decode_fn(cfg)(p, c, t, pos))

    t0 = time.time()
    logits, cache = prefill(params, batch_in)
    prefill_s = time.time() - t0

    def sample_tok(lg, key):
        if temperature <= 0:
            return jnp.argmax(lg[:, -1], axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, lg[:, -1] / temperature).astype(jnp.int32)

    toks = []
    tok = sample_tok(logits, rng)
    t0 = time.time()
    for i in range(gen):
        toks.append(np.asarray(tok))
        logits, cache = decode(params, cache, tok[:, None],
                               jnp.int32(prompt_len + i))
        rng, sub = jax.random.split(rng)
        tok = sample_tok(logits, sub)
    jax.block_until_ready(logits)
    decode_s = time.time() - t0
    out = np.stack(toks, axis=1)
    print(f"prefill {prefill_s*1e3:.1f} ms; decode {gen} steps "
          f"{decode_s*1e3:.1f} ms ({decode_s/gen*1e3:.2f} ms/tok, "
          f"batch={batch})")
    return out


def serve_diffusion(arch: str, *, reduced=True, batch=4, nfe=10, order=3,
                    solver="unipc", fused_update=True, cfg_scale=0.0,
                    cfg_schedule="constant", thresholding=False, seed=0):
    """Diffusion batch-serving through the engine: sample `batch` latents in
    one jitted scan — any registered solver, one eps-net eval per step for
    the whole batch. `cfg_scale` turns on fused classifier-free guidance:
    still ONE (2B-batched, cond+uncond stacked) network call per step, with
    the guidance scale riding the schedule table; `thresholding` adds dynamic
    thresholding of the x0 prediction. On TPU the fused-update dispatch
    selects the single-pass Pallas combine, the hot path of the memory-bound
    state update."""
    from ..engine import EngineSpec
    from ..diffusion import VPLinear
    from .sample import build_engine

    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    rng = jax.random.PRNGKey(seed)
    params = api.init_params(cfg, rng)
    engine = build_engine(cfg, params, VPLinear(), batch, seed,
                          want_cfg=cfg_scale != 0.0)
    spec = EngineSpec(solver=solver, nfe=nfe, order=order,
                      cfg_scale=cfg_scale, cfg_schedule=cfg_schedule,
                      thresholding=thresholding, fused_update=fused_update)
    run = engine.build(spec)
    x_T = jax.random.normal(rng, (batch, cfg.patch_tokens, cfg.latent_dim),
                            jnp.float32)
    t0 = time.time()
    out = jax.block_until_ready(run(x_T))  # includes compile
    compile_s = time.time() - t0
    t0 = time.time()
    out = jax.block_until_ready(run(x_T))
    serve_s = time.time() - t0
    print(f"diffusion batch={batch} solver={solver} nfe={nfe} order={order} "
          f"cfg={cfg_scale} fused_update={fused_update}: "
          f"compile {compile_s:.2f}s, serve {serve_s*1e3:.1f} ms "
          f"({serve_s/batch*1e3:.2f} ms/latent)")
    return np.asarray(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--nfe", type=int, default=10,
                    help="diffusion serving: sampler steps")
    ap.add_argument("--order", type=int, default=3,
                    help="diffusion serving: solver order")
    from ..engine import SOLVERS
    ap.add_argument("--solver", default="unipc", choices=sorted(SOLVERS),
                    help="diffusion serving: any engine-registered solver")
    ap.add_argument("--no-fused-update", action="store_true",
                    help="diffusion serving: pin the jnp op-chain combine")
    ap.add_argument("--cfg-scale", type=float, default=0.0,
                    help="diffusion serving: fused classifier-free guidance "
                         "scale (0 = off; one batched eval per step)")
    ap.add_argument("--cfg-schedule", default="constant",
                    choices=["constant", "linear", "cosine"])
    ap.add_argument("--thresholding", action="store_true",
                    help="diffusion serving: dynamic thresholding (off by "
                         "default)")
    scale = ap.add_mutually_exclusive_group()
    scale.add_argument("--reduced", action="store_true",
                       help="reduced CPU-scale config (the default)")
    scale.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if get_config(args.arch).family == "dit":
        serve_diffusion(args.arch, reduced=not args.full, batch=args.batch,
                        nfe=args.nfe, order=args.order, solver=args.solver,
                        fused_update=not args.no_fused_update,
                        cfg_scale=args.cfg_scale,
                        cfg_schedule=args.cfg_schedule,
                        thresholding=args.thresholding)
        return
    serve(args.arch, reduced=not args.full, batch=args.batch,
          prompt_len=args.prompt_len, gen=args.gen,
          temperature=args.temperature)


if __name__ == "__main__":
    main()
