import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on the
production meshes, collect memory/cost/collective statistics, write JSON.

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
        --mesh single multi --out results/dryrun

The XLA_FLAGS line above MUST precede every other import (jax locks the
device count at first init); smoke tests and benchmarks never import this
module, so they see 1 device.
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..analysis.hlo import analyze
from ..analysis.roofline import (Roofline, active_params, model_flops_decode,
                                 model_flops_train)
from ..configs.base import INPUT_SHAPES
from ..configs.registry import all_arch_ids, get_config
from ..models import api
from ..optim import AdamW
from ..parallel.sharding import (KV_SEQ_SERVE_RULES, LONG_SERVE_RULES,
                                 SEQ_PARALLEL_TRAIN_RULES, SERVE_RULES,
                                 TRAIN_RULES, sharding_rules)
from .mesh import make_production_mesh
from .specs import (abstract_cache, abstract_params, batch_shardings,
                    cache_shardings, input_specs, param_shardings)

# (arch, shape) pairs that do not lower, with the reason (DESIGN.md §7.2)
SKIPS = {
    ("whisper-small", "long_500k"):
        "enc-dec full cross-attention; no sub-quadratic decode variant",
}

# long-context overrides: dense/moe/vlm/hybrid archs get a sliding window so
# long_500k decode is sub-quadratic with an O(window) cache (DESIGN.md §7.2)
LONG_SWA_WINDOW = 8192


def adapt_config(cfg, shape_name):
    if shape_name == "long_500k" and cfg.family in ("dense", "moe", "vlm",
                                                    "hybrid"):
        if not cfg.sliding_window:
            cfg = dataclasses.replace(cfg, sliding_window=LONG_SWA_WINDOW)
    if INPUT_SHAPES[shape_name].kind == "train":
        cfg = dataclasses.replace(cfg, remat=True)
    return cfg


# §Perf hillclimb variants (EXPERIMENTS.md): per-(arch, shape) optimization
# stages applied on top of the baseline config/rules via --opt <stage>.
# cfg = dataclasses.replace overrides; rules = alternative rule set.
OPTIMIZATIONS = {
    # H1: MoE dispatch locality (worst-MFU / most-collective-bound pair)
    ("granite-moe-3b-a800m", "train_4k"): {
        "local_dispatch": dict(cfg=dict(moe_dispatch_groups=32)),
        "shard_map": dict(cfg=dict(moe_shard_map=True)),
        "shard_map_seqp": dict(cfg=dict(moe_shard_map=True),
                               rules=SEQ_PARALLEL_TRAIN_RULES),
    },
    ("mixtral-8x7b", "train_4k"): {
        "local_dispatch": dict(cfg=dict(moe_dispatch_groups=32)),
        "shard_map": dict(cfg=dict(moe_shard_map=True)),
    },
    # H2: sequence parallelism for the biggest dense train
    ("deepseek-67b", "train_4k"): {
        "seqp": dict(rules=SEQ_PARALLEL_TRAIN_RULES),
        "seqp_chunk": dict(cfg=dict(attention_chunk=512),
                           rules=SEQ_PARALLEL_TRAIN_RULES),
        "chunk": dict(cfg=dict(attention_chunk=512)),
    },
    # H4 (bonus): KV-seq model sharding when kv-heads don't divide the axis
    ("deepseek-67b", "decode_32k"): {
        "kvseq": dict(rules=KV_SEQ_SERVE_RULES),
        "kvseq_bf16": dict(cfg=dict(param_dtype="bfloat16"),
                           rules=KV_SEQ_SERVE_RULES),
    },
    ("qwen2-0.5b", "decode_32k"): {
        "kvseq": dict(rules=KV_SEQ_SERVE_RULES),
    },
    # H3: blockwise attention for the memory-bound long prefill (the eps-net
    # forward that dominates UniPC sampling wall-clock)
    ("qwen2-0.5b", "prefill_32k"): {
        "chunk": dict(cfg=dict(attention_chunk=1024)),
        "chunk512": dict(cfg=dict(attention_chunk=512)),
        "chunk2048": dict(cfg=dict(attention_chunk=2048)),
    },
}


def rules_for(shape):
    if shape.kind == "train":
        return TRAIN_RULES
    if shape.name == "long_500k":
        return LONG_SERVE_RULES
    return SERVE_RULES


def build_workload(cfg, shape, mesh, rules, objective="ar"):
    """Returns (fn, example_args, in_shardings, donate) ready for jit."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    params_abs = abstract_params(cfg)
    p_sh = param_shardings(params_abs, mesh, rules)
    batch_abs = input_specs(cfg, shape, objective)
    b_sh = batch_shardings(batch_abs, mesh, rules)
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        opt = AdamW()
        opt_abs = jax.eval_shape(opt.init, params_abs)
        # optimizer state: (step, m, v) with m/v mirroring the param shardings
        o_sh = type(opt_abs)(repl, p_sh, p_sh)
        loss_fn = api.train_loss(cfg, objective)

        def train_step(params, opt_state, batch, rng):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch, rng)
            new_params, new_opt = opt.update(grads, opt_state, params)
            return new_params, new_opt, loss

        rng_abs = jax.ShapeDtypeStruct((2,), jnp.uint32)
        return (train_step,
                (params_abs, opt_abs, batch_abs, rng_abs),
                (p_sh, o_sh, b_sh, repl),
                (p_sh, o_sh, repl))

    if shape.kind == "prefill":
        pf = api.prefill_fn(cfg)
        S = shape.seq_len

        def prefill_step(params, batch):
            return pf(params, batch, S)

        return (prefill_step, (params_abs, batch_abs), (p_sh, b_sh), None)

    # decode
    cache_abs = abstract_cache(cfg, shape)
    c_sh = cache_shardings(cache_abs, mesh, rules)
    dec = api.decode_fn(cfg)

    def decode_step(params, cache, batch, pos):
        return dec(params, cache, batch["tokens"], pos)

    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    return (decode_step,
            (params_abs, cache_abs, batch_abs, pos_abs),
            (p_sh, c_sh, b_sh, repl),
            None)


def run_one(arch, shape_name, mesh_kind, objective="ar", out_dir=None,
            save_hlo=False, opt=None):
    shape = INPUT_SHAPES[shape_name]
    cfg = adapt_config(get_config(arch), shape_name)
    multi = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = int(np.prod(list(mesh.shape.values())))
    rules = rules_for(shape)
    if opt:
        stage = OPTIMIZATIONS[(arch, shape_name)][opt]
        if stage.get("cfg"):
            cfg = dataclasses.replace(cfg, **stage["cfg"])
        if stage.get("rules") is not None:
            rules = stage["rules"]
    t0 = time.time()
    with mesh:
        with sharding_rules(mesh, rules):
            fn, args, in_sh, out_sh = build_workload(cfg, shape, mesh, rules,
                                                     objective)
            jitted = (jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
                      if out_sh is not None else
                      jax.jit(fn, in_shardings=in_sh))
            lowered = jitted.lower(*args)
            compiled = lowered.compile()
    compile_s = time.time() - t0

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    # cost_analysis counts while bodies ONCE (scan under-count) — kept for
    # reference; the roofline uses the trip-count-scaled HLO accounting.
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))
    try:
        mem = compiled.memory_analysis()
        mem_stats = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "peak_bytes": int(getattr(mem, "peak_memory_in_bytes", 0)),
        }
    except Exception as e:  # noqa: BLE001 — backend may not implement it
        mem_stats = {"error": str(e)}
    hlo_text = compiled.as_text()
    acct = analyze(hlo_text, chips)
    coll = acct["collectives"]

    if shape.kind == "train":
        mf = model_flops_train(cfg, shape.global_batch * shape.seq_len)
    elif shape.kind == "prefill":
        mf = 2.0 * active_params(cfg) * shape.global_batch * shape.seq_len
    else:
        mf = model_flops_decode(cfg, shape.global_batch)
    roof = Roofline(flops=acct["flops"], hbm_bytes=acct["hbm_bytes"],
                    collective_bytes=coll.get("_total", 0.0),
                    chips=chips, model_flops=mf)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "chips": chips,
        "opt": opt,
        "objective": objective if shape.kind == "train" else shape.kind,
        "compile_s": round(compile_s, 2),
        "cost_xla_unscaled": {"flops": xla_flops, "hbm_bytes": xla_bytes},
        "memory": mem_stats,
        "collectives": coll,
        "roofline": roof.row(),
        "params_active": active_params(cfg),
        "hlo_lines": hlo_text.count("\n"),
    }
    print(compiled.memory_analysis() if "error" not in mem_stats else mem_stats)
    if out_dir:
        out_dir = Path(out_dir)
        out_dir.mkdir(parents=True, exist_ok=True)
        suffix = f"__{opt}" if opt else ""
        name = f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"
        (out_dir / name).write_text(json.dumps(rec, indent=1))
        if save_hlo:
            (out_dir / name.replace(".json", ".hlo.txt")).write_text(hlo_text)
    return rec


def run_sample_workload(arch="dit-i256", mesh_kind="single", batch=256,
                        nfe=10, order=3, out_dir=None, fused_update=True):
    """Beyond the assigned 40 pairs: lower the paper's production workload —
    a full UniPC sampling trajectory (one lax.scan over the static coefficient
    table, one eps-net eval per step) — on the production mesh."""
    from ..core import make_unipc_schedule, unipc_sample_scan
    from ..diffusion.schedules import VPLinear
    from ..models.api import eps_network

    cfg = get_config(arch)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    chips = int(np.prod(list(mesh.shape.values())))
    sched = make_unipc_schedule(VPLinear(), nfe, order=order, prediction="data")
    net = eps_network(cfg)
    vp = VPLinear()

    def sample_step(params, x_T, class_ids):
        def data_model(x, t):
            a, sg = vp.alpha_sigma_jax(jnp.asarray(t, jnp.float32))
            eps = net(params, x, t, {"class_ids": class_ids})
            return ((x.astype(jnp.float32) - sg * eps.astype(jnp.float32))
                    / a).astype(x.dtype)
        return unipc_sample_scan(data_model, x_T, sched,
                                 fused_update=fused_update,
                                 dtype=cfg.activation_dtype)

    rules = SERVE_RULES
    t0 = time.time()
    with mesh:
        with sharding_rules(mesh, rules):
            params_abs = abstract_params(cfg)
            p_sh = param_shardings(params_abs, mesh, rules)
            from jax.sharding import NamedSharding, PartitionSpec as P
            from ..parallel.sharding import normalize_axes
            baxes = normalize_axes(mesh, ("pod", "data"))
            x_abs = jax.ShapeDtypeStruct(
                (batch, cfg.patch_tokens, cfg.latent_dim), cfg.activation_dtype)
            c_abs = jax.ShapeDtypeStruct((batch,), jnp.int32)
            b_sh = NamedSharding(mesh, P(baxes, None, None))
            c_sh = NamedSharding(mesh, P(baxes))
            compiled = jax.jit(sample_step,
                               in_shardings=(p_sh, b_sh, c_sh)).lower(
                params_abs, x_abs, c_abs).compile()
    acct = analyze(compiled.as_text(), chips)
    mf = nfe * 2.0 * active_params(cfg) * batch * cfg.patch_tokens
    roof = Roofline(flops=acct["flops"], hbm_bytes=acct["hbm_bytes"],
                    collective_bytes=acct["collectives"].get("_total", 0.0),
                    chips=chips, model_flops=mf)
    rec = {"arch": arch, "shape": f"sample_nfe{nfe}", "mesh": mesh_kind,
           "chips": chips, "opt": None, "compile_s": round(time.time() - t0, 2),
           "collectives": acct["collectives"], "roofline": roof.row(),
           "memory": {}, "params_active": active_params(cfg)}
    r = rec["roofline"]
    print(f"[ok] {arch} x sample_nfe{nfe} x {mesh_kind}: "
          f"bottleneck={r['bottleneck']} compute={r['compute_s']:.2e}s "
          f"mem={r['memory_s']:.2e}s coll={r['collective_s']:.2e}s "
          f"mfu={r['mfu']:.4f}")
    if out_dir:
        out_dir = Path(out_dir)
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"{arch}__sample_nfe{nfe}__{mesh_kind}.json").write_text(
            json.dumps(rec, indent=1))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="+", default=["all"])
    ap.add_argument("--shape", nargs="+", default=["all"])
    ap.add_argument("--mesh", nargs="+", default=["single"],
                    choices=["single", "multi"], help="single=256, multi=512")
    ap.add_argument("--objective", default="ar", choices=["ar", "diffusion"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--opt", default=None,
                    help="optimization stage name from OPTIMIZATIONS")
    ap.add_argument("--sample", action="store_true",
                    help="lower the UniPC sampling scan workload instead")
    args = ap.parse_args()

    if args.sample:
        for arch in (args.arch if args.arch != ["all"] else ["dit-i256"]):
            for mesh_kind in args.mesh:
                run_sample_workload(arch, mesh_kind, out_dir=args.out)
        return
    archs = all_arch_ids() if args.arch == ["all"] else args.arch
    shapes = list(INPUT_SHAPES) if args.shape == ["all"] else args.shape
    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in args.mesh:
                key = (arch, shape)
                tag = f"{arch} x {shape} x {mesh_kind}"
                if key in SKIPS:
                    print(f"[SKIP] {tag}: {SKIPS[key]}")
                    continue
                suffix = f"__{args.opt}" if args.opt else ""
                out_file = Path(args.out) / f"{arch}__{shape}__{mesh_kind}{suffix}.json"
                if args.resume and out_file.exists():
                    print(f"[ok-cached] {tag}")
                    continue
                try:
                    rec = run_one(arch, shape, mesh_kind, args.objective,
                                  args.out, args.save_hlo, opt=args.opt)
                    r = rec["roofline"]
                    print(f"[ok] {tag}: compile={rec['compile_s']}s "
                          f"bottleneck={r['bottleneck']} "
                          f"compute={r['compute_s']:.2e}s "
                          f"mem={r['memory_s']:.2e}s "
                          f"coll={r['collective_s']:.2e}s")
                except Exception as e:  # noqa: BLE001
                    failures.append((tag, str(e)))
                    print(f"[FAIL] {tag}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} failures:")
        for tag, err in failures:
            print(f"  {tag}: {err[:200]}")
        raise SystemExit(1)
    print("\nall dry-runs compiled")


if __name__ == "__main__":
    main()
