"""Training launcher: any --arch, AR or diffusion objective, CPU-runnable at
reduced scale and mesh-ready at full scale.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
        --objective diffusion --steps 200 --batch 8 --seq 128
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import ckpt
from ..configs.registry import get_config
from ..data.synthetic import TokenStream, class_ids, latent_images, stub_embeds
from ..models import api
from ..optim import AdamW, warmup_cosine


def make_train_step(cfg, objective, opt):
    loss_fn = api.train_loss(cfg, objective)

    @jax.jit
    def step(params, opt_state, batch, rng):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch, rng)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, loss

    return step


def build_batch_fn(cfg, batch_size, seq_len, seed=0):
    if cfg.family == "dit":
        def fn(i):
            return {"latents": jnp.asarray(latent_images(
                        batch_size, cfg.patch_tokens, cfg.latent_dim, seed + i)),
                    "class_ids": jnp.asarray(class_ids(batch_size, seed=seed + i))}
        return fn
    stream = TokenStream(cfg.vocab_size, seq_len, batch_size, seed)

    def fn(i):
        b = {k: jnp.asarray(v) for k, v in stream.block(i).items()}
        if cfg.family == "vlm":
            b["image_embeds"] = jnp.asarray(
                stub_embeds(batch_size, cfg.image_tokens, cfg.d_model, seed + i))
        if cfg.family == "audio":
            b["audio_embeds"] = jnp.asarray(
                stub_embeds(batch_size, cfg.audio_frames, cfg.d_model, seed + i))
        return b

    return fn


def train(arch: str, *, reduced=True, objective="ar", steps=100, batch=8,
          seq=128, lr=3e-4, ckpt_dir=None, ckpt_every=0, log_every=10,
          seed=0, log_file=None):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    rng = jax.random.PRNGKey(seed)
    params = api.init_params(cfg, rng)
    opt = AdamW(lr=warmup_cosine(lr, min(20, steps // 10 + 1), steps))
    opt_state = opt.init(params)
    step_fn = make_train_step(cfg, objective, opt)
    batch_fn = build_batch_fn(cfg, batch, seq, seed)
    history = []
    t0 = time.time()
    for i in range(steps):
        rng, sub = jax.random.split(rng)
        params, opt_state, loss = step_fn(params, opt_state, batch_fn(i), sub)
        if i % log_every == 0 or i == steps - 1:
            loss_v = float(loss)
            history.append({"step": i, "loss": loss_v,
                            "elapsed_s": round(time.time() - t0, 1)})
            print(f"step {i:5d} loss {loss_v:.4f}")
        if ckpt_dir and ckpt_every and i and i % ckpt_every == 0:
            ckpt.save(ckpt_dir, {"params": params}, step=i)
    if ckpt_dir:
        ckpt.save(ckpt_dir, {"params": params}, step=steps)
    if log_file:
        Path(log_file).parent.mkdir(parents=True, exist_ok=True)
        Path(log_file).write_text(json.dumps(history, indent=1))
    return params, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--objective", default="ar", choices=["ar", "diffusion"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    scale = ap.add_mutually_exclusive_group()
    scale.add_argument("--reduced", action="store_true",
                       help="reduced CPU-scale config (the default)")
    scale.add_argument("--full", action="store_true",
                       help="full config (default: reduced CPU-scale)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-file", default=None)
    args = ap.parse_args()
    train(args.arch, reduced=not args.full, objective=args.objective,
          steps=args.steps, batch=args.batch, seq=args.seq, lr=args.lr,
          ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
          log_file=args.log_file)


if __name__ == "__main__":
    main()
