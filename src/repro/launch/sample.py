"""Diffusion sampling launcher — the paper's workload. Loads (or freshly
initializes) an eps-network for --arch, then samples with any solver in the
zoo at a given NFE budget.

    PYTHONPATH=src python -m repro.launch.sample --arch dit-cifar --reduced \
        --solver unipc --order 3 --nfe 10
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import ckpt
from ..configs.registry import get_config
from ..core import (DDIM, DEIS, DPMSolverPP, DPMSolverSinglestep, PNDM, Grid,
                    UniPC, make_unipc_schedule, unipc_sample_scan)
from ..data.synthetic import class_ids
from ..diffusion import VPLinear, wrap_model
from ..models import api


def build_model_fn(cfg, params, batch, schedule, prediction):
    net = api.eps_network(cfg)

    def eps(x, t):
        return net(params, x, jnp.asarray(t, jnp.float32), batch)

    return wrap_model(schedule, jax.jit(eps), prediction)


def latent_shape(cfg, batch):
    if cfg.family == "dit":
        return (batch, cfg.patch_tokens, cfg.latent_dim)
    return (batch, 64, cfg.latent_dim)  # diffusion-LM over a 64-token window


def sample(arch: str, *, reduced=True, solver="unipc", order=3, nfe=10,
           variant="bh2", prediction="data", batch=4, seed=0,
           params=None, use_scan=False, fused_update=True):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    rng = jax.random.PRNGKey(seed)
    if params is None:
        params = api.init_params(cfg, rng)
    schedule = VPLinear()
    extra = {}
    if cfg.family == "dit":
        extra["class_ids"] = jnp.asarray(class_ids(batch))
    model = build_model_fn(cfg, params, extra, schedule, prediction)
    x_T = jax.random.normal(rng, latent_shape(cfg, batch), jnp.float32)

    t0 = time.time()
    if use_scan and solver == "unipc":
        us = make_unipc_schedule(schedule, nfe, order=order,
                                 prediction=prediction, variant=variant)
        x0 = unipc_sample_scan(model, x_T, us, fused_update=fused_update)
        nfe_used = nfe + 1  # the scan evaluates the final step's eps too
    else:
        grid_steps = nfe if solver in ("unipc", "ddim", "dpmpp", "pndm",
                                       "deis") else max(1, nfe // order)
        grid = Grid.build(schedule, grid_steps)
        if solver == "unipc":
            s = UniPC(model, grid, order=order, prediction=prediction,
                      variant=variant)
            x0 = s.sample_pc(x_T, use_corrector=True)
        elif solver == "ddim":
            s = DDIM(model, grid, prediction=prediction)
            x0 = s.sample(x_T)
        elif solver == "dpmpp":
            s = DPMSolverPP(model, grid, order=min(order, 3))
            x0 = s.sample(x_T)
        elif solver == "dpm":
            s = DPMSolverSinglestep(model, grid, schedule, order=min(order, 3),
                                    prediction="noise")
            x0 = s.sample(x_T)
        elif solver == "pndm":
            s = PNDM(model, grid)
            x0 = s.sample(x_T)
        elif solver == "deis":
            s = DEIS(model, grid, schedule, order=min(order, 3))
            x0 = s.sample(x_T)
        else:
            raise ValueError(solver)
        nfe_used = s.model.nfe
    dt = time.time() - t0
    x0 = np.asarray(x0)
    print(f"{solver}-{order} nfe={nfe_used} wall={dt:.2f}s "
          f"out_shape={x0.shape} mean={x0.mean():+.4f} std={x0.std():.4f} "
          f"finite={np.isfinite(x0).all()}")
    return x0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dit-cifar")
    ap.add_argument("--solver", default="unipc",
                    choices=["unipc", "ddim", "dpmpp", "dpm", "pndm", "deis"])
    ap.add_argument("--order", type=int, default=3)
    ap.add_argument("--nfe", type=int, default=10)
    ap.add_argument("--variant", default="bh2", choices=["bh1", "bh2", "vary"])
    ap.add_argument("--prediction", default="data", choices=["data", "noise"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--scan", action="store_true")
    ap.add_argument("--no-fused-update", action="store_true",
                    help="pin the inline jnp op-chain combine in the scan "
                         "sampler (default: fused kernel dispatch)")
    scale = ap.add_mutually_exclusive_group()
    scale.add_argument("--reduced", action="store_true",
                       help="reduced CPU-scale config (the default)")
    scale.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    params = None
    if args.ckpt:
        tree, _ = ckpt.restore(args.ckpt)
        params = tree["params"]
    sample(args.arch, reduced=not args.full, solver=args.solver,
           order=args.order, nfe=args.nfe, variant=args.variant,
           prediction=args.prediction, batch=args.batch, params=params,
           use_scan=args.scan, fused_update=not args.no_fused_update)


if __name__ == "__main__":
    main()
