"""Diffusion sampling launcher — the paper's workload. Loads (or freshly
initializes) an eps-network for --arch, then samples with any solver in the
zoo at a given NFE budget. Every solver runs scan-compiled through the
engine (`SamplerEngine.build`: weight-table compiler -> one `lax.scan` ->
fused Pallas state update); `--loop` pins the python-loop GridSolver
reference instead. Conditional sampling (dit family): `--cfg-scale` fuses
classifier-free guidance into the scan — cond+uncond stacked into ONE
batched network call per step — and `--thresholding` adds Imagen-style
dynamic thresholding; both default off.

    PYTHONPATH=src python -m repro.launch.sample --arch dit-cifar --reduced \
        --solver dpmpp --order 2 --nfe 10 --cfg-scale 2.0
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import ckpt
from ..configs.registry import get_config
from ..data.synthetic import class_ids
from ..diffusion import VPLinear
from ..engine import EngineSpec, SamplerEngine
from ..models import api

NULL_CLASS_ID = 1000  # init_dit allocates num_classes + 1 embeddings; the
                      # extra row is the CFG null class


def build_engine(cfg, params, schedule, batch: int, seed: int = 0,
                 want_cfg: bool = False, per_request_cond: bool = False,
                 eval_dtype: str = "float32",
                 cache_block: int = 0, quant: str = "none") -> SamplerEngine:
    """Wire the arch's eps-network into a SamplerEngine: the cond branch,
    and — for dit-family conditional sampling — the stacked 2B cond+uncond
    branch that fused CFG serves from, plus the uncond branch for the
    sequential loop reference.

    per_request_cond (dit only): instead of baking a per-batch-row class-id
    array at build time (slot-positional — fine for a uniform batch, wrong
    under continuous batching where a request's slot depends on arrival
    order), the eps branches take `class_ids` as a per-call (B,) keyword
    argument, which the serving scheduler scatters per request.

    eval_dtype="bfloat16" is the fast serving eval (DESIGN.md §11): the
    network's params-at-use and activations run in bf16 (params are pre-cast
    once, so serving HBM reads are halved; the conditioning MLP keeps its
    fp32 compute). The engine side of the boundary — solver state, combine
    weights, eps↔x0 — stays fp32 via the matching `EngineSpec.eval_dtype`.

    cache_block > 0 additionally wires the feature-reuse eval (DESIGN.md
    §12, dit only): the engine gets `eps_cached` — the same network with a
    deep-feature cache split at block `cache_block` — plus the matching
    `CacheSpec`, and serves cached plans whose specs carry the same
    `cache_block`. Incompatible with guidance (see `EngineSpec.resolve`).

    quant != "none" (DESIGN.md §14, dit only) calibrates and installs the
    tier's quantized param tree (`api.calibrate_and_quantize`, deterministic
    given `seed`) before wiring, so every eps branch — stacked CFG, cached —
    routes its dense sites through kernels/quant_matmul. The engine records
    the tier and `model_fn` rejects specs that disagree, exactly like
    eval_dtype."""
    import dataclasses

    if eval_dtype not in ("float32", "bfloat16"):
        raise ValueError(f"eval_dtype must be 'float32' or 'bfloat16', "
                         f"got {eval_dtype!r}")
    if quant != "none" and cfg.family != "dit":
        raise ValueError(f"the quantized denoiser path needs the dit "
                         f"family; {cfg.arch_id!r} is family "
                         f"{cfg.family!r}")
    if cache_block:
        if cfg.family != "dit":
            raise ValueError(f"cache_block needs the dit family; "
                             f"{cfg.arch_id!r} is family {cfg.family!r}")
        if want_cfg:
            raise ValueError("feature reuse serves unconditional programs "
                             "only (EngineSpec.resolve rejects cache_block "
                             "with cfg_scale)")
        if not 1 <= cache_block < cfg.num_layers:
            raise ValueError(f"cache_block must be in "
                             f"1..{cfg.num_layers - 1}, got {cache_block}")
    if eval_dtype == "bfloat16":
        cfg = dataclasses.replace(cfg, dtype=eval_dtype)
        params = api.cast_params_for_eval(params, eval_dtype)
    if quant != "none":
        # quantize after the eval cast: records are derived from the exact
        # tree the net will otherwise read, scales stay fp32 either way
        cfg, params, _ = api.calibrate_and_quantize(
            cfg, params, quant, schedule=schedule, seed=seed)
    net = api.eps_network(cfg)

    def eps_with(extra):
        # jit so the python-loop reference path gets compiled evals too; the
        # scan path's outer jit simply inlines it
        return jax.jit(
            lambda x, t: net(params, x, jnp.asarray(t, jnp.float32), extra))

    def cache_kw(baked=None):
        """(eps_cached, cache_spec) for this wiring — None, None uncached.
        `baked` fixes the batch dict at build time (the uniform-batch mode);
        otherwise the per-call extras are the batch (per-request mode)."""
        if not cache_block:
            return {}
        from ..engine import CacheSpec
        from ..models.dit import dit_cache_shape

        cnet = api.eps_network_cached(cfg, cache_block)

        def eps_cached(x, t, cache, reuse, **extra):
            return cnet(params, x, jnp.asarray(t, jnp.float32),
                        baked if baked is not None else extra, cache, reuse)

        return {"eps_cached": eps_cached,
                "cache_spec": CacheSpec(shape=dit_cache_shape(cfg),
                                        block=cache_block,
                                        n_blocks=cfg.num_layers,
                                        dtype=eval_dtype)}

    if cfg.family != "dit":
        if want_cfg:
            raise ValueError("classifier-free guidance needs the dit family "
                             "(class-conditional eps-net)")
        return SamplerEngine(schedule, eps=eps_with({}),
                             eval_dtype=eval_dtype, quant=quant)
    null = jnp.full((batch,), NULL_CLASS_ID, jnp.int32)
    if per_request_cond:
        def eps_cond(x, t, class_ids):
            return net(params, x, jnp.asarray(t, jnp.float32),
                       {"class_ids": class_ids})

        def eps_stacked(xx, t, class_ids):
            ids2 = jnp.concatenate([jnp.asarray(class_ids, jnp.int32),
                                    jnp.full_like(class_ids, NULL_CLASS_ID,
                                                  jnp.int32)])
            return net(params, xx, jnp.asarray(t, jnp.float32),
                       {"class_ids": ids2})

        return SamplerEngine(schedule, eps=jax.jit(eps_cond),
                             eps_stacked=jax.jit(eps_stacked),
                             eps_uncond=eps_with({"class_ids": null}),
                             eval_dtype=eval_dtype, quant=quant,
                             **cache_kw())
    ids = jnp.asarray(class_ids(batch, seed=seed))
    return SamplerEngine(
        schedule,
        eps=eps_with({"class_ids": ids}),
        eps_stacked=eps_with({"class_ids": jnp.concatenate([ids, null])}),
        eps_uncond=eps_with({"class_ids": null}),
        eval_dtype=eval_dtype, quant=quant,
        **cache_kw(baked={"class_ids": ids}),
    )


def require_dit_for_cfg(ap, arch: str, cfg_scale: float) -> str:
    """Argparse-friendly guard shared by the sample/serve CLIs: guidance
    needs the class-conditional dit family. Returns the arch's family."""
    from ..configs.registry import get_config

    family = get_config(arch).family
    if cfg_scale and family != "dit":
        ap.error(f"--cfg-scale needs a class-conditional eps-net; "
                 f"--arch {arch} is family '{family}', not 'dit' "
                 f"(try dit-cifar or dit-i256)")
    return family


def latent_shape(cfg, batch):
    if cfg.family == "dit":
        return (batch, cfg.patch_tokens, cfg.latent_dim)
    return (batch, 64, cfg.latent_dim)  # diffusion-LM over a 64-token window


def sample(arch: str, *, reduced=True, solver="unipc", order=3, nfe=10,
           variant="bh2", prediction=None, batch=4, seed=0, params=None,
           loop=False, fused_update=True, cfg_scale=0.0,
           cfg_schedule="constant", thresholding=False, plan=None,
           eval_dtype="float32", quant="none"):
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    rng = jax.random.PRNGKey(seed)
    if params is None:
        params = api.init_params(cfg, rng)
    schedule = VPLinear()
    plan_tab = None
    cache_block = 0
    if plan is not None:
        # a tuned SolverPlan (path or object) replaces the registry table:
        # the spec keeps only the conditioning/runtime knobs
        from ..tuning import SolverPlan

        if loop:
            raise ValueError("a tuned plan runs the scan-compiled table; "
                             "there is no python-loop reference for "
                             "searched plans")
        if isinstance(plan, str):
            plan = SolverPlan.load(plan)
        solver, nfe, order = "unipc", plan.nfe, max(plan.orders)
        prediction = plan.prediction
        # a cached plan (nonzero cache_depth) needs the cache-wired engine
        # and a spec carrying the same static boundary
        cache_block = plan.cache_block
        plan_tab = plan.compile(schedule)
    if loop and eval_dtype != "float32":
        raise ValueError("the python-loop reference is fp32-only; "
                         "eval_dtype rides the engine paths")
    if loop and quant != "none":
        raise ValueError("the python-loop reference is fp32-only; "
                         "quantized tiers ride the engine paths")
    engine = build_engine(cfg, params, schedule, batch, seed,
                          want_cfg=cfg_scale != 0.0, eval_dtype=eval_dtype,
                          cache_block=cache_block, quant=quant)
    spec = EngineSpec(solver=solver, nfe=nfe, order=order, variant=variant,
                      prediction=prediction, cfg_scale=cfg_scale,
                      cfg_schedule=cfg_schedule, thresholding=thresholding,
                      fused_update=fused_update, eval_dtype=eval_dtype,
                      cache_block=cache_block, quant=quant)
    x_T = jax.random.normal(rng, latent_shape(cfg, batch), jnp.float32)

    t0 = time.time()
    if loop:
        run = engine.build_loop(spec)
        x0 = run(x_T)
        nfe_used = run.solver.model.nfe  # measured eval count
    else:
        tab = engine.compile(spec, table=plan_tab)
        x0 = engine.build(spec, table=tab)(x_T)
        # the scan evaluates the final step's eps too; fused CFG keeps one
        # (2B-batched) call per step
        nfe_used = len(tab.timesteps)
    dt = time.time() - t0
    x0 = np.asarray(x0)
    path = "loop" if loop else "scan"
    tag = (f"{solver}-{order}" + (" [plan]" if plan_tab is not None else "")
           + (f" [{quant}]" if quant != "none" else ""))
    cache_note = (f" evals/latent={plan.eval_cost(cfg.num_layers):.2f} "
                  f"(cache_block={cache_block})" if cache_block else "")
    print(f"{tag} [{path}] nfe={nfe_used}{cache_note} cfg={cfg_scale} "
          f"wall={dt:.2f}s out_shape={x0.shape} mean={x0.mean():+.4f} "
          f"std={x0.std():.4f} finite={np.isfinite(x0).all()}")
    return x0


def main():
    from ..engine import SOLVERS

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dit-cifar")
    ap.add_argument("--solver", default="unipc", choices=sorted(SOLVERS))
    ap.add_argument("--order", type=int, default=3)
    ap.add_argument("--nfe", type=int, default=10)
    ap.add_argument("--variant", default="bh2", choices=["bh1", "bh2", "vary"])
    ap.add_argument("--prediction", default=None, choices=["data", "noise"],
                    help="override the solver's native prediction type "
                         "(unipc/ddim/dpm support both)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--loop", action="store_true",
                    help="python-loop GridSolver reference instead of the "
                         "scan-compiled engine path")
    ap.add_argument("--no-fused-update", action="store_true",
                    help="pin the inline jnp op-chain combine in the scan "
                         "sampler (default: fused kernel dispatch)")
    ap.add_argument("--cfg-scale", type=float, default=0.0,
                    help="classifier-free guidance scale (0 = off); fused "
                         "into the scan as one batched eval per step")
    ap.add_argument("--cfg-schedule", default="constant",
                    choices=["constant", "linear", "cosine"])
    ap.add_argument("--thresholding", action="store_true",
                    help="Imagen-style dynamic thresholding of the x0 "
                         "prediction (data-prediction solvers)")
    ap.add_argument("--eval-dtype", default="float32",
                    choices=["float32", "bfloat16"],
                    help="eps-network eval precision (default fp32); "
                         "bfloat16 is the fast serving eval — solver state "
                         "and combine weights stay fp32 (DESIGN.md §11)")
    ap.add_argument("--quant", default="none",
                    choices=["none", "w8a16", "w8a8", "fp8a16", "w4a16"],
                    help="quantized denoiser tier (DESIGN.md §14): int8/fp8 "
                         "weight matmuls with calibrated scales, fp32 "
                         "accumulation; dit family only")
    ap.add_argument("--plan", default=None,
                    help="path to a tuned SolverPlan JSON (repro.launch.tune)"
                         "; overrides --solver/--order/--nfe with the plan's "
                         "searched per-step schedule")
    scale = ap.add_mutually_exclusive_group()
    scale.add_argument("--reduced", action="store_true",
                       help="reduced CPU-scale config (the default)")
    scale.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    require_dit_for_cfg(ap, args.arch, args.cfg_scale)
    if args.plan and args.loop:
        ap.error("--plan runs the scan-compiled table; --loop has no "
                 "python-loop reference for searched plans")
    if args.loop and args.eval_dtype != "float32":
        ap.error("--eval-dtype rides the engine paths; the python-loop "
                 "reference is fp32-only")
    if args.loop and args.quant != "none":
        ap.error("--quant rides the engine paths; the python-loop "
                 "reference is fp32-only")
    if args.quant != "none" and get_config(args.arch).family != "dit":
        ap.error(f"--quant needs the dit family; --arch {args.arch} is "
                 f"family {get_config(args.arch).family!r}")
    params = None
    if args.ckpt:
        tree, _ = ckpt.restore(args.ckpt)
        params = tree["params"]
    sample(args.arch, reduced=not args.full, solver=args.solver,
           order=args.order, nfe=args.nfe, variant=args.variant,
           prediction=args.prediction, batch=args.batch, params=params,
           loop=args.loop, fused_update=not args.no_fused_update,
           cfg_scale=args.cfg_scale, cfg_schedule=args.cfg_schedule,
           thresholding=args.thresholding, plan=args.plan,
           eval_dtype=args.eval_dtype, quant=args.quant)


if __name__ == "__main__":
    main()
