"""Solver-plan autotuning launcher (DESIGN.md §10).

Searches the per-step decision space (timestep knots, UniP order, UniC
on/off, B(h) variant) for one NFE budget — or a whole tier bank — against a
high-NFE reference trajectory on the arch's eps-network, and saves the
winning plan(s) as JSON for `launch/sample.py --plan` and
`launch/serve.py --plan-bank`.

    PYTHONPATH=src python -m repro.launch.tune --arch dit-cifar --nfe 8 \
        --budget 80 --out plan8.json
    PYTHONPATH=src python -m repro.launch.tune --arch dit-cifar \
        --bank fast=5,balanced=8,quality=16 --out bank.json
    PYTHONPATH=src python -m repro.launch.tune --smoke   # the CI gate

The smoke runs a tiny search and exits nonzero unless the tuned plan's
discrepancy is no worse than the hand-set UniPC-2 baseline it starts from
(the search never regresses, so a failure means the tuner itself broke).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from ..configs.registry import get_config
from ..diffusion import VPLinear
from ..engine import EngineSpec
from ..models import api
from ..tuning import (SearchConfig, SolverPlan, make_objective,
                      quant_parity_gate, reference_trajectory, save_bank,
                      tune_cached_plan, tune_plan)
from .sample import build_engine, latent_shape


def _setup(arch: str, reduced: bool, batch: int, seed: int,
           train_steps: int = 0, cache_block: int = 0, quant: str = "none"):
    """Engine + probe latents for the objective. `train_steps > 0` briefly
    trains the eps-net first (diffusion objective): at random init the
    reduced nets are nearly linear and every solver lands within fp32 noise
    of the reference, so plan rankings are meaningless; ~100 steps makes the
    trajectory curvature real (same reasoning as the tier-1 trained-model
    solver-ordering test).

    Returns (engine, x_T, fp32_engine). With `quant != "none"` the primary
    engine serves the quantized denoiser (DESIGN.md §14) and `fp32_engine`
    is a second engine over the SAME trained params at fp32 — the parity
    gate's reference and baseline anchor. Otherwise fp32_engine IS engine."""
    cfg = get_config(arch)
    if reduced:
        cfg = cfg.reduced()
    rng = jax.random.PRNGKey(seed)
    if train_steps > 0:
        from .train import train as _train

        params, _ = _train(arch, reduced=reduced, objective="diffusion",
                           steps=train_steps, batch=8, seq=32, lr=1e-3,
                           log_every=max(1, train_steps), seed=seed)
    else:
        params = api.init_params(cfg, rng)
    engine = build_engine(cfg, params, VPLinear(), batch, seed,
                          cache_block=cache_block, quant=quant)
    fp32_engine = engine
    if quant != "none":
        fp32_engine = build_engine(cfg, params, VPLinear(), batch, seed,
                                   cache_block=cache_block)
    x_T = jax.random.normal(rng, latent_shape(cfg, batch), jnp.float32)
    return engine, x_T, fp32_engine


def tune(arch: str = "dit-cifar", *, nfe: int = 8, budget: int = 80,
         beam: int = 2, rounds: int = 3, baseline_order: int = 2,
         ref_nfe: int = 48, batch: int = 4, seed: int = 0,
         reduced: bool = True, train_steps: int = 100, engine=None,
         x_T=None, x_ref=None, cache_block: int = 0,
         cache_slack: float = 1.1, quant: str = "none",
         quant_slack: float = 1.5, fp32_engine=None, verbose: bool = False):
    """Search one NFE budget; returns (plan, report). The search starts from
    the hand-set UniPC-`baseline_order` plan, so the reported baseline IS the
    paper's default table at this budget. Pass engine/x_T/x_ref (see
    `reference_trajectory`) to share setup across several budgets.

    cache_block > 0 runs the joint solver + cache-schedule search
    (`tune_cached_plan`, DESIGN.md §12): the engine must be cache-wired
    (pass cache_block to `_setup`, or an `engine` built with it), and the
    report gains the no-cache anchor, the discrepancy ratio against it
    (constrained <= `cache_slack`), and the plan's evals-per-latent.

    quant != "none" tunes against the quantized denoiser (DESIGN.md §14)
    but anchors everything to fp32: the reference trajectory AND the
    baseline anchor come from `fp32_engine` (same trained params, full
    precision), and the tuned plan is only emitted if its discrepancy stays
    within `quant_slack` x the fp32 baseline's — `quant_parity_gate` raises
    `QuantParityError` otherwise. The emitted plan's meta records the tier,
    so a serving bank pins it (`launch/serve.py --plan-bank`)."""
    if engine is None:
        engine, x_T, fp32_engine = _setup(arch, reduced, batch, seed,
                                          train_steps,
                                          cache_block=cache_block,
                                          quant=quant)
    elif quant != "none" and fp32_engine is None:
        raise ValueError("tuning a quant tier with a prebuilt engine needs "
                         "the matching fp32_engine (same params) for the "
                         "parity gate's reference and baseline anchor")
    spec = EngineSpec(solver="unipc", nfe=nfe, order=baseline_order,
                      cache_block=cache_block, quant=quant)
    fp32_anchor = None
    if quant != "none":
        from dataclasses import replace as _replace

        fp32_spec = _replace(spec, quant="none")
        if x_ref is None:
            x_ref = reference_trajectory(fp32_engine, fp32_spec, x_T,
                                         ref_nfe=ref_nfe)
        anchor_obj = make_objective(fp32_engine, fp32_spec, x_T,
                                    ref_nfe=ref_nfe, x_ref=x_ref)
        fp32_anchor = anchor_obj(SolverPlan.from_spec(fp32_spec),
                                 fp32_engine.schedule)
    objective = make_objective(engine, spec, x_T, ref_nfe=ref_nfe,
                               x_ref=x_ref)
    init = SolverPlan.from_spec(spec)
    cfg_search = SearchConfig(budget=budget, beam=beam, rounds=rounds)
    t0 = time.perf_counter()
    if cache_block:
        cres = tune_cached_plan(objective, engine.schedule, init, cfg_search,
                                cache_block=cache_block, slack=cache_slack,
                                verbose=verbose)
        wall = time.perf_counter() - t0
        n_blocks = engine.cache_spec.n_blocks
        plan = cres.plan.with_meta(arch=arch, nfe=nfe, ref_nfe=ref_nfe,
                                   baseline_order=baseline_order, seed=seed,
                                   search_wall_s=round(wall, 3))
        report = {"arch": arch, "nfe": nfe,
                  "baseline": cres.history[0][0] if cres.history else None,
                  "tuned": cres.score, "evals": cres.evals,
                  "search_wall_s": wall, "cache_block": cache_block,
                  "uncached_tuned": cres.uncached_score,
                  "cached_ratio": cres.score / max(cres.uncached_score,
                                                   1e-12),
                  "nfe_evals": nfe + 1,
                  "evals_per_latent": plan.eval_cost(n_blocks)}
        tuned = cres.score
    else:
        res = tune_plan(objective, engine.schedule, init, cfg_search,
                        verbose=verbose)
        wall = time.perf_counter() - t0
        plan = res.plan.with_meta(arch=arch, nfe=nfe, ref_nfe=ref_nfe,
                                  baseline_order=baseline_order, seed=seed,
                                  search_wall_s=round(wall, 3))
        report = {"arch": arch, "nfe": nfe, "baseline": res.baseline,
                  "tuned": res.score,
                  "improvement": res.baseline - res.score,
                  "evals": res.evals, "search_wall_s": wall}
        tuned = res.score
    if quant != "none":
        # gate BEFORE emitting: raises QuantParityError on an over-quantized
        # tier, so no plan with an unmet parity budget ever reaches disk
        ratio = quant_parity_gate(tuned, fp32_anchor, slack=quant_slack,
                                  quant=quant, context=f"{arch} nfe={nfe}")
        plan = plan.with_meta(quant=quant, quant_slack=quant_slack,
                              quant_ratio=round(ratio, 4),
                              fp32_baseline=fp32_anchor)
        report.update(quant=quant, quant_slack=quant_slack,
                      quant_ratio=ratio, fp32_baseline=fp32_anchor)
    return plan, report


def tune_bank(arch: str, tiers: dict, *, budget: int = 80, beam: int = 2,
              rounds: int = 3, baseline_order: int = 2, seed: int = 0,
              ref_nfe: int = 48, batch: int = 4, reduced: bool = True,
              train_steps: int = 100, cache_block: int = 0,
              cache_slack: float = 1.1, quant: str = "none",
              quant_slack: float = 1.5, verbose: bool = False):
    """Tune one plan per tier ({name: nfe}) over a shared engine, probe
    batch, and reference trajectory; returns ({name: plan}, [report]).
    `cache_block > 0` tunes every tier jointly with a cache schedule at that
    shared boundary (a bank serves through ONE compiled program). With
    `quant != "none"` the whole bank is tuned against one quantized param
    tree — the fp32 reference trajectory is shared, each tier runs its own
    parity gate, and every plan's meta records the tier so serving pins it."""
    engine, x_T, fp32_engine = _setup(arch, reduced, batch, seed, train_steps,
                                      cache_block=cache_block, quant=quant)
    x_ref = reference_trajectory(
        fp32_engine, EngineSpec(solver="unipc", nfe=ref_nfe,
                                cache_block=cache_block), x_T,
        ref_nfe=ref_nfe)
    plans, reports = {}, []
    for name, nfe in tiers.items():
        plan, rep = tune(arch, nfe=int(nfe), budget=budget, beam=beam,
                         rounds=rounds, baseline_order=baseline_order,
                         ref_nfe=ref_nfe, seed=seed,
                         engine=engine, x_T=x_T, x_ref=x_ref,
                         cache_block=cache_block, cache_slack=cache_slack,
                         quant=quant, quant_slack=quant_slack,
                         fp32_engine=fp32_engine, verbose=verbose)
        plans[name] = plan.with_meta(tier=name)
        rep["tier"] = name
        reports.append(rep)
    return plans, reports


def smoke(arch: str = "dit-cifar", nfe: int = 6, budget: int = 24,
          train_steps: int = 100, seed: int = 0,
          reduced: bool = True) -> dict:
    """The CI gate: tiny search budget on a briefly trained net, assert the
    tuned plan's discrepancy is <= the hand-set UniPC-2 baseline's.
    rounds=1 / ref_nfe=24 / batch=2 are pinned — they define smoke scale."""
    plan, report = tune(arch, nfe=nfe, budget=budget, rounds=1,
                        ref_nfe=24, batch=2, seed=seed, reduced=reduced,
                        train_steps=train_steps)
    assert report["tuned"] <= report["baseline"], (
        f"tuned plan regressed the baseline: {report['tuned']:.6f} > "
        f"{report['baseline']:.6f}")
    assert plan.nfe == nfe
    return report


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="dit-cifar")
    ap.add_argument("--nfe", type=int, default=8)
    ap.add_argument("--budget", type=int, default=80,
                    help="max objective evaluations for the search")
    ap.add_argument("--beam", type=int, default=2)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--baseline-order", type=int, default=2,
                    help="order of the hand-set UniPC baseline the search "
                         "starts from (and is scored against)")
    ap.add_argument("--ref-nfe", type=int, default=48,
                    help="NFE of the reference trajectory the objective "
                         "measures discrepancy against")
    ap.add_argument("--batch", type=int, default=4,
                    help="probe latent batch size")
    ap.add_argument("--train-steps", type=int, default=100,
                    help="brief diffusion-objective training of the eps-net "
                         "before tuning (0 = tune the random init, where "
                         "plan rankings drown in fp32 noise)")
    ap.add_argument("--cache-block", type=int, default=0,
                    help="jointly tune a DiT feature-reuse schedule at this "
                         "block boundary (0 = no caching); shallow steps "
                         "recompute only the first k blocks (DESIGN.md §12)")
    ap.add_argument("--cache-slack", type=float, default=1.1,
                    help="max tuned-discrepancy ratio vs the no-cache anchor "
                         "the cached search may spend on reuse steps")
    ap.add_argument("--quant", default="none",
                    choices=["none", "w8a16", "w8a8", "fp8a16", "w4a16"],
                    help="tune against the quantized denoiser tier "
                         "(DESIGN.md §14); the plan is only emitted if its "
                         "discrepancy vs the fp32 reference passes the "
                         "parity gate (exits nonzero otherwise)")
    ap.add_argument("--quant-slack", type=float, default=1.5,
                    help="parity budget: max tuned-discrepancy ratio vs the "
                         "fp32 baseline a quantized tier may cost")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="write the tuned plan (or bank) JSON here")
    ap.add_argument("--bank", default=None,
                    help="tune a tier bank instead: name=nfe pairs, e.g. "
                         "fast=5,balanced=8,quality=16")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: tiny search on dit-cifar, exit nonzero "
                         "if the tuned plan is worse than the UniPC-2 "
                         "baseline")
    ap.add_argument("--verbose", action="store_true")
    scale = ap.add_mutually_exclusive_group()
    scale.add_argument("--reduced", action="store_true",
                       help="reduced CPU-scale config (the default)")
    scale.add_argument("--full", action="store_true")
    args = ap.parse_args()
    if args.smoke:
        report = smoke(args.arch, nfe=args.nfe, budget=args.budget,
                       train_steps=args.train_steps, seed=args.seed,
                       reduced=not args.full)
        print(json.dumps(report, indent=1))
        print(f"tuning smoke ok: baseline {report['baseline']:.5f} -> "
              f"tuned {report['tuned']:.5f} in {report['evals']} evals")
        return
    if args.bank:
        tiers = dict(kv.split("=") for kv in args.bank.split(","))
        plans, reports = tune_bank(
            args.arch, tiers, budget=args.budget, beam=args.beam,
            rounds=args.rounds, baseline_order=args.baseline_order,
            seed=args.seed, ref_nfe=args.ref_nfe,
            batch=args.batch, reduced=not args.full,
            train_steps=args.train_steps, cache_block=args.cache_block,
            cache_slack=args.cache_slack, quant=args.quant,
            quant_slack=args.quant_slack, verbose=args.verbose)
        for rep in reports:
            print(f"tier {rep['tier']} (nfe={rep['nfe']}): baseline "
                  f"{rep['baseline']:.5f} -> tuned {rep['tuned']:.5f} "
                  f"({rep['evals']} evals, {rep['search_wall_s']:.1f}s)")
            if args.quant != "none":
                print(f"    quant {args.quant}: {rep['quant_ratio']:.3f}x "
                      f"the fp32 baseline {rep['fp32_baseline']:.5f} "
                      f"(budget {args.quant_slack}x) — parity gate passed")
        if args.out:
            save_bank(args.out, plans)
            print(f"wrote bank ({', '.join(plans)}) to {args.out}")
        return
    plan, report = tune(args.arch, nfe=args.nfe, budget=args.budget,
                        beam=args.beam, rounds=args.rounds,
                        baseline_order=args.baseline_order,
                        ref_nfe=args.ref_nfe, batch=args.batch,
                        seed=args.seed, reduced=not args.full,
                        train_steps=args.train_steps,
                        cache_block=args.cache_block,
                        cache_slack=args.cache_slack, quant=args.quant,
                        quant_slack=args.quant_slack, verbose=args.verbose)
    print(f"{args.arch} nfe={args.nfe}: baseline {report['baseline']:.5f} "
          f"-> tuned {report['tuned']:.5f} ({report['evals']} evals, "
          f"{report['search_wall_s']:.1f}s)")
    if args.quant != "none":
        print(f"  quant {args.quant}: {report['quant_ratio']:.3f}x the fp32 "
              f"baseline {report['fp32_baseline']:.5f} "
              f"(budget {args.quant_slack}x) — parity gate passed")
    if args.cache_block:
        print(f"  cached @ block {args.cache_block}: "
              f"{report['evals_per_latent']:.2f} evals/latent vs "
              f"{report['nfe_evals']} uncached, ratio "
              f"{report['cached_ratio']:.3f} (slack {args.cache_slack})")
    if args.out:
        plan.save(args.out)
        print(f"wrote plan to {args.out}")


if __name__ == "__main__":
    main()
