"""Abstract input/param/cache specs + sharding inference for the dry-run.

`input_specs(cfg, shape)` returns ShapeDtypeStruct stand-ins for every model
input (weak-type-correct, shardable, no allocation). Param shardings are
inferred from leaf *path names* (the weight naming convention is uniform
across families) with divisibility guards; cache shardings likewise. Logical
axes ('fsdp' / 'model' / 'batch' / 'kv_seq') resolve through the active rule
set, so train uses 2D FSDPxTP weight sharding while serve replicates over
data (parallel/sharding.py).
"""

from __future__ import annotations

import re
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import InputShape, ModelConfig
from ..models import api

F = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# inputs
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: InputShape, objective: str = "ar"):
    """Batch dict of ShapeDtypeStructs for the given workload shape."""
    B, S = shape.global_batch, shape.seq_len
    act = cfg.activation_dtype
    if shape.kind == "train":
        batch = {"tokens": F((B, S), jnp.int32), "targets": F((B, S), jnp.int32)}
        if objective == "diffusion" and cfg.family == "dit":
            batch = {"latents": F((B, cfg.patch_tokens, cfg.latent_dim), act),
                     "class_ids": F((B,), jnp.int32)}
    elif shape.kind == "prefill":
        batch = {"tokens": F((B, S), jnp.int32)}
    else:  # decode: ONE token against a seq_len-deep cache
        batch = {"tokens": F((B, 1), jnp.int32)}
    if cfg.family == "vlm":
        batch["image_embeds"] = F((B, cfg.image_tokens, cfg.d_model), act)
    if cfg.family == "audio" and shape.kind != "decode":
        batch["audio_embeds"] = F((B, cfg.audio_frames, cfg.d_model), act)
    return batch


def abstract_params(cfg: ModelConfig, rng=None):
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    return jax.eval_shape(lambda r: api.init_params(cfg, r), rng)


def abstract_cache(cfg: ModelConfig, shape: InputShape):
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "audio":
        # audio cache structure comes from prefill (cross-KV included)
        batch = {"tokens": F((B, min(S, 8)), jnp.int32),
                 "audio_embeds": F((B, cfg.audio_frames, cfg.d_model),
                                   cfg.activation_dtype)}
        _, cache = jax.eval_shape(
            lambda p, b: api.prefill_fn(cfg)(p, b, S),
            abstract_params(cfg), batch)
        return cache
    return jax.eval_shape(lambda: api.init_cache(cfg, B, S))


# ---------------------------------------------------------------------------
# sharding inference
# ---------------------------------------------------------------------------

# leaf-name -> logical spec for the trailing dims (earlier dims: None/stack)
_PARAM_RULES = [
    (r"(w_down|wo|out_proj)$", ("model", "fsdp")),
    (r"(w_gate|w_up|wq|wk|wv|in_proj|lm_head|w1|w2|ada|img_proj|t_mlp\d)$",
     ("fsdp", "model")),
    (r"(embed|token_latents|class_embed)$", ("model", "fsdp")),
    (r"router$", ("fsdp", None)),
    (r"conv_w$", (None, "model")),
]

_CACHE_KV_KEYS = {"k", "v", "attn_k", "attn_v", "img_k", "img_v", "xk", "xv"}


from ..parallel.sharding import _axis_len, normalize_axes


def _guard(spec_entries, shape, mesh, rules):
    """Map logical names -> mesh axes, dropping any that don't divide evenly,
    are absent from this mesh, or were already claimed by an earlier dim."""
    out = []
    used = set()
    for dim, logical in zip(shape, spec_entries):
        axes = normalize_axes(
            mesh, rules.get(logical) if logical is not None else None)
        if axes is not None:
            axes = tuple(a for a in axes if a not in used) or None
        if axes is not None and dim % _axis_len(mesh, axes) != 0:
            axes = None
        if axes is not None:
            used.update(axes)
        out.append(axes)
    return P(*out)


def param_shardings(params_abstract, mesh: Mesh, rules: dict):
    paths_leaves = jax.tree_util.tree_flatten_with_path(params_abstract)
    flat, treedef = paths_leaves
    out = []
    for path, leaf in flat:
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        spec = None
        for pat, trailing in _PARAM_RULES:
            if re.search(pat, name):
                nd = leaf.ndim
                t = list(trailing)[-nd:] if nd < len(trailing) else list(trailing)
                entries = [None] * (nd - len(t)) + t
                spec = _guard(entries, leaf.shape, mesh, rules)
                break
        if spec is None:
            if leaf.ndim >= 2:
                entries = [None] * (leaf.ndim - 2) + ["fsdp", "model"]
                spec = _guard(entries, leaf.shape, mesh, rules)
            else:
                spec = P()
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def cache_shardings(cache_abstract, mesh: Mesh, rules: dict):
    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_abstract)
    out = []
    for path, leaf in flat:
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        nd = leaf.ndim
        if name in _CACHE_KV_KEYS:
            # (..., B, W, Hkv, D)
            entries = [None] * (nd - 4) + ["batch", "kv_seq", "kv_heads", None]
        elif name == "ssm":
            # (..., B, H, P, N)
            entries = [None] * (nd - 4) + ["batch", "heads", None, None]
        elif name == "conv":
            # (..., B, K, C)
            entries = [None] * (nd - 3) + ["batch", None, "d_ff"]
        else:
            entries = [None] * nd
        out.append(NamedSharding(mesh, _guard(entries, leaf.shape, mesh, rules)))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_shardings(batch_abstract, mesh: Mesh, rules: dict):
    def f(leaf):
        entries = ["batch"] + [None] * (leaf.ndim - 1)
        return NamedSharding(mesh, _guard(entries, leaf.shape, mesh, rules))

    return jax.tree.map(f, batch_abstract)
