"""Render serving observability artifacts (DESIGN.md §15).

    PYTHONPATH=src python -m repro.launch.obsreport \
        --trace trace.json --metrics metrics.json --check

Takes the artifacts a serve run exported (`repro.launch.serve --trace-out /
--metrics-out`), validates both against their schemas, and renders the
human-readable breakdown: the "where a tick goes" per-phase table (DESIGN
§11, produced from measured data), per-tier serving rows, quality-probe
drift, and aggregated span statistics from the Chrome trace. `--check`
additionally re-derives `ServeMetrics` from the artifact's raw registry
snapshot via `serving.server.serve_metrics_from_snapshot` and requires it to
EXACTLY equal the artifact's embedded aggregate — the no-drift contract
between live metrics and the end-of-run report, checkable offline.
"""

from __future__ import annotations

import argparse
import json
import sys

from ..obs.metrics import validate_metrics
from ..obs.report import render_report
from ..obs.trace import validate_trace


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def check_metrics_roundtrip(obj: dict) -> list:
    """Re-derive ServeMetrics from the artifact's snapshot delta and diff it
    against the embedded aggregate; returns [(field, embedded, derived)]
    mismatches (empty = the artifact is self-consistent)."""
    from ..serving.server import serve_metrics_from_snapshot

    static = obj["run"]["static"]
    derived = serve_metrics_from_snapshot(
        obj["run"]["metrics"], mode=static["mode"], slots=static["slots"],
        n_rows=static["n_rows"],
        pipeline_depth=static.get("pipeline_depth", 1)).row()
    embedded = obj["serve_metrics"]
    keys = sorted(set(embedded) | set(derived))
    return [(k, embedded.get(k), derived.get(k)) for k in keys
            if embedded.get(k) != derived.get(k)]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--trace", default=None,
                    help="Chrome trace_event JSON from serve --trace-out")
    ap.add_argument("--metrics", default=None,
                    help="metrics artifact from serve --metrics-out")
    ap.add_argument("--check", action="store_true",
                    help="re-derive ServeMetrics from the metrics artifact's "
                         "raw snapshot and require exact equality with the "
                         "embedded aggregate")
    args = ap.parse_args()
    if args.trace is None and args.metrics is None:
        ap.error("give --trace and/or --metrics (artifacts from "
                 "repro.launch.serve --trace-out/--metrics-out)")

    failed = False
    trace_obj = metrics_obj = None
    if args.trace is not None:
        trace_obj = _load(args.trace)
        errs = validate_trace(trace_obj)
        for e in errs:
            print(f"TRACE INVALID: {e}", file=sys.stderr)
        failed |= bool(errs)
    if args.metrics is not None:
        metrics_obj = _load(args.metrics)
        errs = validate_metrics(metrics_obj)
        for e in errs:
            print(f"METRICS INVALID: {e}", file=sys.stderr)
        failed |= bool(errs)
        if args.check and not errs:
            mismatches = check_metrics_roundtrip(metrics_obj)
            for k, emb, der in mismatches:
                print(f"CHECK FAIL: serve_metrics.{k}: artifact has {emb!r}, "
                      f"re-derivation gives {der!r}", file=sys.stderr)
            if not mismatches:
                print("check ok: embedded ServeMetrics == re-derivation "
                      "from the raw snapshot")
            failed |= bool(mismatches)

    print(render_report(trace=trace_obj, metrics=metrics_obj))
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
