"""UniPC-JAX: unified predictor-corrector diffusion framework (see README)."""
