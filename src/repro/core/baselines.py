"""Baseline solvers the paper compares against (all on the GridSolver driver,
so the method-agnostic UniC can be bolted onto each of them — Table 2).

* DDIM (Song et al., 2021a)               — order 1; identical to UniP-1.
* DPM-Solver 2S/3S (Lu et al., 2022a)     — singlestep, noise prediction.
* DPM-Solver++ 1M/2M/3M (Lu et al., 2022b)— multistep, data prediction.
* DPM-Solver++ 3S                          — singlestep, data prediction.
* PNDM / PLMS (Liu et al., 2022)          — pseudo linear multistep, noise pred.
* DEIS tAB-k (Zhang & Chen, 2022)         — time-domain exponential integrator,
  polynomial extrapolation with numerically exact integral weights.
"""

from __future__ import annotations

import math
from typing import Optional

import numpy as np

from .solver import Grid, GridSolver, History, semilinear_base, unified_step


class DDIM(GridSolver):
    """First-order exponential-integrator step == UniP-1 (Section 3.3)."""

    order = 1

    def __init__(self, model_fn, grid: Grid, prediction: str = "noise"):
        super().__init__(model_fn, grid)
        self.prediction = prediction

    def predict(self, i, x, hist: History):
        g = self.grid
        m0 = hist.at_lam(g.lam[i - 1])
        return unified_step(
            x, m0, [],
            lam_s=g.lam[i - 1], lam_t=g.lam[i],
            alpha_s=g.alpha[i - 1], alpha_t=g.alpha[i],
            sigma_s=g.sigma[i - 1], sigma_t=g.sigma[i],
            prediction=self.prediction,
        )


class DPMSolverPP(GridSolver):
    """DPM-Solver++ multistep (1M/2M/3M), data prediction, exactly the update
    formulas of Lu et al. 2022b; lower-order warm-up and lower-order-final."""

    prediction = "data"

    def __init__(self, model_fn, grid: Grid, order: int = 2,
                 lower_order_final: bool = True):
        assert order in (1, 2, 3)
        super().__init__(model_fn, grid)
        self.order = order
        self.lower_order_final = lower_order_final

    def predict(self, i, x, hist: History):
        g = self.grid
        M = len(g)
        p = min(self.order, i)
        if self.lower_order_final:
            p = min(p, M - i + 1)
        lam = g.lam
        m0 = hist.at_lam(lam[i - 1])
        h = lam[i] - lam[i - 1]
        sig_r = g.sigma[i] / g.sigma[i - 1]
        a_t = g.alpha[i]
        phi_1 = math.expm1(-h)
        if p == 1:
            return sig_r * x - a_t * phi_1 * m0
        m1 = hist.at_lam(lam[i - 2])
        h_0 = lam[i - 1] - lam[i - 2]
        r0 = h_0 / h
        D1_0 = (m0 - m1) / r0
        if p == 2:
            return sig_r * x - a_t * phi_1 * m0 - 0.5 * a_t * phi_1 * D1_0
        m2 = hist.at_lam(lam[i - 3])
        h_1 = lam[i - 2] - lam[i - 3]
        r1 = h_1 / h
        D1_1 = (m1 - m2) / r1
        D1 = D1_0 + (r0 / (r0 + r1)) * (D1_0 - D1_1)
        D2 = (D1_0 - D1_1) / (r0 + r1)
        phi_2 = phi_1 / h + 1.0
        phi_3 = phi_2 / h - 0.5
        return (sig_r * x - a_t * phi_1 * m0 + a_t * phi_2 * D1 - a_t * phi_3 * D2)


class DPMSolverSinglestep(GridSolver):
    """DPM-Solver-2/-3 (noise prediction, singlestep; Lu et al. 2022a) and
    DPM-Solver++(3S) via prediction='data'."""

    def __init__(self, model_fn, grid: Grid, noise_schedule, order: int = 3,
                 prediction: str = "noise"):
        assert order in (2, 3)
        super().__init__(model_fn, grid)
        self.order = order
        self.prediction = prediction
        self.noise_schedule = noise_schedule
        self.r_inner = [0.5] if order == 2 else [1.0 / 3.0, 2.0 / 3.0]

    def _point(self, lam_m):
        t_m = float(self.noise_schedule.t_of_lam(lam_m))
        return t_m, float(self.noise_schedule.alpha(t_m)), float(self.noise_schedule.sigma(t_m))

    def predict(self, i, x, hist: History):
        g = self.grid
        lam_s, lam_t = float(g.lam[i - 1]), float(g.lam[i])
        h = lam_t - lam_s
        a_s, s_s = g.alpha[i - 1], g.sigma[i - 1]
        a_t, s_t = g.alpha[i], g.sigma[i]
        m_s = hist.at_lam(g.lam[i - 1])
        noise = self.prediction == "noise"
        if self.order == 2:
            r1 = self.r_inner[0]
            lam_1 = lam_s + r1 * h
            t1, a1, s1 = self._point(lam_1)
            if noise:
                x1 = (a1 / a_s) * x - s1 * math.expm1(r1 * h) * m_s
            else:
                x1 = (s1 / s_s) * x - a1 * math.expm1(-r1 * h) * m_s
            m1 = self.model(x1, t1)
            hist.push(lam_1, t1, m1)
            if noise:
                return ((a_t / a_s) * x - s_t * math.expm1(h) * m_s
                        - s_t / (2 * r1) * math.expm1(h) * (m1 - m_s))
            return ((s_t / s_s) * x - a_t * math.expm1(-h) * m_s
                    - a_t / (2 * r1) * math.expm1(-h) * (m1 - m_s))
        # order 3
        r1, r2 = self.r_inner
        lam_1, lam_2 = lam_s + r1 * h, lam_s + r2 * h
        t1, a1, s1 = self._point(lam_1)
        t2, a2, s2 = self._point(lam_2)
        if noise:
            phi_11 = math.expm1(r1 * h)
            phi_12 = math.expm1(r2 * h)
            phi_1 = math.expm1(h)
            phi_22 = math.expm1(r2 * h) / (r2 * h) - 1.0
            phi_2 = phi_1 / h - 1.0
            x1 = (a1 / a_s) * x - s1 * phi_11 * m_s
            m1 = self.model(x1, t1)
            hist.push(lam_1, t1, m1)
            x2 = ((a2 / a_s) * x - s2 * phi_12 * m_s
                  - (r2 / r1) * s2 * phi_22 * (m1 - m_s))
            m2 = self.model(x2, t2)
            hist.push(lam_2, t2, m2)
            return ((a_t / a_s) * x - s_t * phi_1 * m_s
                    - (1.0 / r2) * s_t * phi_2 * (m2 - m_s))
        phi_11 = math.expm1(-r1 * h)
        phi_12 = math.expm1(-r2 * h)
        phi_1 = math.expm1(-h)
        phi_22 = math.expm1(-r2 * h) / (r2 * h) + 1.0
        phi_2 = phi_1 / h + 1.0
        x1 = (s1 / s_s) * x - a1 * phi_11 * m_s
        m1 = self.model(x1, t1)
        hist.push(lam_1, t1, m1)
        x2 = ((s2 / s_s) * x - a2 * phi_12 * m_s
              + (r2 / r1) * a2 * phi_22 * (m1 - m_s))
        m2 = self.model(x2, t2)
        hist.push(lam_2, t2, m2)
        return ((s_t / s_s) * x - a_t * phi_1 * m_s
                + (1.0 / r2) * a_t * phi_2 * (m2 - m_s))


# Adams-Bashforth coefficients on newest-first evals (PLMS warm-up ladder).
# Shared with the engine compiler: a PLMS step is the DDIM transfer map of
# e_AB = sum_j AB[n][j] * E[j], and sum_j AB[n][j] == 1 for every n.
PLMS_AB = {
    1: np.array([1.0]),
    2: np.array([3.0, -1.0]) / 2.0,
    3: np.array([23.0, -16.0, 5.0]) / 12.0,
    4: np.array([55.0, -59.0, 37.0, -9.0]) / 24.0,
}


class PNDM(GridSolver):
    """PLMS variant of PNDM: Adams-Bashforth extrapolation of the noise
    prediction fed through the DDIM transfer map; lower-order AB warm-up."""

    prediction = "noise"
    order = 4

    def predict(self, i, x, hist: History):
        g = self.grid
        es = [e for _, _, e in hist.last(4)]  # newest first
        n = min(len(es), i)
        ab = PLMS_AB[min(n, 4)]
        e = sum(c * e_j for c, e_j in zip(ab, es))
        return semilinear_base(
            x, e, alpha_s=g.alpha[i - 1], alpha_t=g.alpha[i],
            sigma_s=g.sigma[i - 1], sigma_t=g.sigma[i],
            h=float(g.lam[i] - g.lam[i - 1]), prediction="noise",
        )


class DEIS(GridSolver):
    """DEIS tAB-k: exponential integrator in the *time* domain with Lagrange
    extrapolation of eps over previous timesteps. The integral

        x_t = (alpha_t/alpha_s) x_s - alpha_t * int e^{-lambda(tau)} lambda'(tau) L_j(tau) dtau

    has no closed form, so the per-step weights are computed with Gauss-Legendre
    quadrature in float64 at construction (faithful to the method: DEIS's
    integrals are also evaluated numerically)."""

    prediction = "noise"

    def __init__(self, model_fn, grid: Grid, noise_schedule, order: int = 3,
                 quad_points: int = 64):
        super().__init__(model_fn, grid)
        self.order = order
        self.noise_schedule = noise_schedule
        self.quad_points = quad_points

    def predict(self, i, x, hist: History):
        g = self.grid
        k = min(self.order, i)
        pts = hist.last(k)  # newest first: t_{i-1}, t_{i-2}, ...
        ts_prev = [t for _, t, _ in pts]
        es = [e for _, _, e in pts]
        ws = deis_quad_weights(self.noise_schedule, float(g.t[i - 1]),
                               float(g.t[i]), float(g.alpha[i]), ts_prev,
                               self.quad_points)
        acc = 0.0
        for w, e in zip(ws, es):
            acc = acc + w * e
        return (g.alpha[i] / g.alpha[i - 1]) * x + acc


def deis_quad_weights(noise_schedule, t_lo, t_hi, alpha_t, ts_prev,
                      quad_points: int = 64):
    """DEIS per-eval weights w_j = -alpha_t * int_{t_lo}^{t_hi} e^{-lam(tau)}
    lam'(tau) L_j(tau) dtau, with L_j the Lagrange basis over `ts_prev`.

    Module-level (shared by the python-loop `DEIS` and the engine's weight-
    table compiler): Gauss-Legendre quadrature in float64 — faithful to the
    method, whose integrals are also evaluated numerically."""
    nodes, gl_w = np.polynomial.legendre.leggauss(quad_points)
    tau = 0.5 * (t_hi - t_lo) * nodes + 0.5 * (t_hi + t_lo)
    jac = 0.5 * (t_hi - t_lo)
    eps = 1e-5
    dlam = (noise_schedule.lam(tau + eps) - noise_schedule.lam(tau - eps)) / (2 * eps)
    kern = np.exp(-noise_schedule.lam(tau)) * dlam
    ws = []
    for j in range(len(ts_prev)):
        L = np.ones_like(tau)
        for k in range(len(ts_prev)):
            if k != j:
                L *= (tau - ts_prev[k]) / (ts_prev[j] - ts_prev[k])
        ws.append(-float(alpha_t) * float(np.sum(gl_w * kern * L)) * jac)
    return ws
