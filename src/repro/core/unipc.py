"""UniPC: unified predictor-corrector solvers (the paper's contribution).

Three implementations, all sharing the coefficient machinery in `coeffs.py`:

* `UniPC` — python-loop multistep solver on the GridSolver driver. Reference
  semantics, supports arbitrary order, custom order schedules (Table 4),
  UniC-oracle (Table 3), both prediction types and all B(h) variants.
* `UniPCSinglestep` — singlestep variant (Section 3.4): intermediate points at
  r in (0,1), lower-order estimates for the inner points.
* `unipc_sample_scan` — the production path: all coefficients are a static
  per-step table, the whole sampler is one `lax.scan` that jits, shards, and
  routes the state update through the fused Pallas kernel by default
  (`fused_update=True`; the dispatch policy lives in `kernels.unipc_update.ops`).
  Since the continuous-batching refactor it is a thin scan over
  `unipc_step_fn`, the per-row step function that also powers the serving
  scheduler (`repro.serving`): with a per-slot index vector, every batch
  element executes its *own* row of the table (DESIGN.md §9).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .coeffs import (UniPCSchedule, augment_step_rows, build_unipc_schedule,
                     default_order_schedule)
from .solver import CorrectorConfig, Grid, GridSolver, History, unified_step


class UniPC(GridSolver):
    """Multistep UniPC-p (Alg. 5-8). Predictor order = `order`; with the
    corrector enabled the order of accuracy is order+1 (Thm 3.1)."""

    def __init__(
        self,
        model_fn,
        grid: Grid,
        *,
        order: int = 3,
        prediction: str = "data",
        variant: str = "bh2",
        order_schedule: Optional[Sequence[int]] = None,
        lower_order_final: bool = True,
    ):
        super().__init__(model_fn, grid)
        self.order = order
        self.prediction = prediction
        self.variant = variant
        M = len(grid)
        self.order_schedule = (
            list(order_schedule)
            if order_schedule is not None
            else default_order_schedule(M, order, lower_order_final)
        )

    def predict(self, i, x, hist: History):
        g = self.grid
        p_i = min(self.order_schedule[i - 1], i)
        m0 = hist.at_lam(g.lam[i - 1])
        pts = hist.last(p_i - 1, before_lam=float(g.lam[i - 1]))
        points = [(lam, e) for lam, _, e in reversed(pts)]
        return unified_step(
            x, m0, points,
            lam_s=g.lam[i - 1], lam_t=g.lam[i],
            alpha_s=g.alpha[i - 1], alpha_t=g.alpha[i],
            sigma_s=g.sigma[i - 1], sigma_t=g.sigma[i],
            prediction=self.prediction, variant=self.variant,
        )

    def corrector_config(self, **kw) -> CorrectorConfig:
        """UniC matched to this predictor's order/variant."""
        return CorrectorConfig(order=self.order, variant=self.variant, **kw)

    def sample_pc(self, x_T, *, oracle: bool = False, use_corrector: bool = True):
        """Full UniPC = UniP + UniC with per-step order from the schedule."""
        if not use_corrector:
            return self.sample(x_T, corrector=None)
        return self.sample(x_T, corrector=_ScheduledCorrector(self, oracle))


class _ScheduledCorrector(CorrectorConfig):
    """Corrector whose order follows the predictor's per-step order schedule
    (UniC-p_i after UniP-p_i, Alg. 5). GridSolver._correct consults order_at()."""

    def __init__(self, solver: UniPC, oracle: bool):
        super().__init__(order=solver.order, variant=solver.variant, oracle=oracle)
        self._solver = solver

    def order_at(self, i: int) -> int:
        return min(self._solver.order_schedule[i - 1], i)


class UniPCSinglestep(GridSolver):
    """Singlestep UniPC-p (p = 2 or 3): intermediate points at r in (0,1),
    estimated with lower-order unified steps; costs p NFE per grid step."""

    def __init__(self, model_fn, grid: Grid, noise_schedule, *, order: int = 2,
                 prediction: str = "data", variant: str = "bh2"):
        assert order in (2, 3)
        super().__init__(model_fn, grid)
        self.order = order
        self.prediction = prediction
        self.variant = variant
        self.noise_schedule = noise_schedule
        self.r_inner = [0.5] if order == 2 else [1.0 / 3.0, 2.0 / 3.0]

    def predict(self, i, x, hist: History):
        g = self.grid
        lam_s, lam_t = float(g.lam[i - 1]), float(g.lam[i])
        h = lam_t - lam_s
        m0 = hist.at_lam(g.lam[i - 1])
        # walk the intermediate points, each estimated with all points so far
        points = []
        sched = self.noise_schedule
        for r in self.r_inner:
            lam_m = lam_s + r * h
            t_m = float(sched.t_of_lam(lam_m))
            a_m, s_m = float(sched.alpha(t_m)), float(sched.sigma(t_m))
            x_m = unified_step(
                x, m0, points,
                lam_s=lam_s, lam_t=lam_m,
                alpha_s=g.alpha[i - 1], alpha_t=a_m,
                sigma_s=g.sigma[i - 1], sigma_t=s_m,
                prediction=self.prediction, variant=self.variant,
            )
            e_m = self.model(x_m, t_m)
            hist.push(lam_m, t_m, e_m)
            points.append((lam_m, e_m))
        return unified_step(
            x, m0, points,
            lam_s=lam_s, lam_t=lam_t,
            alpha_s=g.alpha[i - 1], alpha_t=g.alpha[i],
            sigma_s=g.sigma[i - 1], sigma_t=g.sigma[i],
            prediction=self.prediction, variant=self.variant,
        )


# ---------------------------------------------------------------------------
# Production path: static-coefficient lax.scan sampler
# ---------------------------------------------------------------------------


def make_unipc_schedule(schedule, num_steps, *, order=3, prediction="data",
                        variant="bh2", spacing="logsnr", use_corrector=True,
                        corrector_at_last=False, order_schedule=None,
                        lower_order_final=True) -> UniPCSchedule:
    from ..diffusion.schedules import timestep_grid

    t, lam, alpha, sigma = timestep_grid(schedule, num_steps, spacing)
    return build_unipc_schedule(
        lambdas=lam, alphas=alpha, sigmas=sigma, timesteps=t,
        order=order, prediction=prediction, variant=variant,
        use_corrector=use_corrector, corrector_at_last=corrector_at_last,
        order_schedule=order_schedule, lower_order_final=lower_order_final,
    )


def unipc_step_fn(
    model_fn: Callable,
    sched: UniPCSchedule,
    *,
    fused_update: bool = True,
    dtype=jnp.float32,
    cached: bool = False,
):
    """The per-row step function: (step, n_rows) over the augmented table.

    `step((x, E), idx, model_kwargs=None) -> (x, E)` executes one table row
    per sample, where the table is `coeffs.augment_step_rows(sched)` — the
    init row (identity transfer, eval at timesteps[0]) followed by the M body
    rows. Two index shapes, one body:

    * idx scalar — one uniform row for the whole state: exactly one iteration
      of the classic scan (weights enter the combine as scalars). This is what
      `unipc_sample_scan` folds over.
    * idx (B,) — *per-slot* rows: every batch element gathers its own row
      (weights, timestep, model columns), so a heterogeneous slot batch can
      sit at different trajectory positions — the continuous-batching step
      (DESIGN.md §9). Weights enter the combine as per-slot (K+2, B) columns
      and the model sees per-sample timesteps/columns. A fresh slot needs its
      ring zeroed and idx = 0; idle slots park on the init row (clipped), an
      identity update.

    E is the (K+1, ...) eval ring, newest first; warm-up is data (zero-padded
    weight rows over a zeroed ring), never shape. `model_kwargs` entries are
    forwarded to the model on top of the gathered per-eval columns — the hook
    per-request guidance scales ride in on.
    """
    rows_np = augment_step_rows(sched)
    n_rows = len(rows_np["t"])
    tab = {k: jnp.asarray(v, dtype) for k, v in rows_np.items()}
    step = step_fn_over_rows(model_fn, tab, sign=sched.sign,
                             fused_update=fused_update, dtype=dtype,
                             cached=cached)
    return step, n_rows


def step_fn_over_rows(
    model_fn: Callable,
    tab: dict,
    *,
    sign: float,
    fused_update: bool = True,
    dtype=jnp.float32,
    cached: bool = False,
):
    """Build the per-row step over an explicit row table.

    `tab` is an augmented row dict (`coeffs.augment_step_rows`, or several
    tables stacked by `coeffs.stack_step_rows` into a plan bank) whose arrays
    may be *traced* values: the solver-plan tuner jits one runner with the
    rows as an argument, so scoring a candidate plan re-executes the compiled
    program with new weights instead of recompiling per candidate. `sign` is
    the table's prediction sign (static). Semantics are exactly
    `unipc_step_fn`'s — that function is now this one over the concrete rows.

    `cached=True` switches to the feature-reuse contract (DESIGN.md §12):
    the carry grows a third element C (the per-slot deep-feature cache) and
    `model_fn(x, t, cache=C, **extras) -> (pred, C')`. The table's
    `mc_cache_reuse` column (gathered per row like every model column)
    reaches the model as the `cache_reuse` kwarg, so *which* rows reuse the
    cache is data while the cache boundary stays static in the model.
    """
    K = tab["w_pred"].shape[-1]
    col_keys = sorted(k for k in tab if k.startswith("mc_"))
    n_rows = tab["t"].shape[0]
    sign = jnp.asarray(sign, dtype)

    if fused_update:
        from ..kernels.unipc_update import ops as fused_ops
        combine = fused_ops.weighted_combine
    else:
        def combine(terms, weights):
            # terms: (K+2, *x); weights: (K+2,) or per-slot (K+2, B)
            if weights.ndim == 2:
                w = weights.reshape(weights.shape + (1,) * (terms.ndim - 2))
                return jnp.sum(w * terms, axis=0)
            return jnp.tensordot(weights, terms, axes=1)

    def step(carry, idx, model_kwargs=None):
        if cached:
            x, E, C = carry
        else:
            x, E = carry
        idx = jnp.clip(jnp.asarray(idx), 0, n_rows - 1)
        per_slot = idx.ndim == 1
        row = {k: v[idx] for k, v in tab.items()}

        def wstack(base_x, base_m0, w_prev, w_new=None):
            # scalar rows: (K,) weights; per-slot rows: (B, K) -> (K, B)
            scale = row["out_scale"][..., None] if per_slot else row["out_scale"]
            parts = [base_x[None], base_m0[None],
                     jnp.moveaxis(sign * scale * w_prev, -1, 0)]
            if w_new is not None:
                parts.append((sign * row["out_scale"] * w_new)[None])
            return jnp.concatenate(parts, axis=0)

        m0 = E[0]
        diffs = E[1:] - m0[None] if K > 0 else jnp.zeros((0,) + x.shape, x.dtype)
        extras = {k[3:]: row[k] for k in col_keys}
        if model_kwargs:
            extras = {**extras, **model_kwargs}
        # predictor
        terms = jnp.concatenate([x[None], m0[None], diffs], axis=0)
        x_pred = combine(terms, wstack(row["base_x"], row["base_m0"],
                                       row["w_pred"]))
        if cached:
            e_new, C = model_fn(x_pred, row["t"], cache=C, **extras)
        else:
            e_new = model_fn(x_pred, row["t"], **extras)
        # corrector (re-uses e_new; no extra NFE)
        d_new = e_new - m0
        terms_c = jnp.concatenate([terms, d_new[None]], axis=0)
        x_corr = combine(terms_c, wstack(row["base_x_c"], row["base_m0_c"],
                                         row["w_corr_prev"], row["w_corr_new"]))
        use_c = (row["use_c"].reshape((-1,) + (1,) * (x.ndim - 1))
                 if per_slot else row["use_c"])
        x_next = x_pred + use_c * (x_corr - x_pred)
        E_next = jnp.concatenate([e_new[None], E[:-1]], axis=0)
        if cached:
            return (x_next, E_next, C)
        return (x_next, E_next)

    return step


def unipc_sample_scan(
    model_fn: Callable,
    x_T: jnp.ndarray,
    sched: UniPCSchedule,
    *,
    fused_update: bool = True,
    dtype=jnp.float32,
    cache0=None,
):
    """Multistep UniPC as a single lax.scan over the step function: rows
    0..M of the augmented table with a uniform index (row 0 is the init eval
    at timesteps[0] over a zeroed ring — see `coeffs.augment_step_rows`).

    model_fn(x, t) -> prediction of `sched.prediction` type. The eval buffer is a
    ring of `order` slots; warm-up and order schedules are realized purely through
    zero-padded weight rows, so the scan body is shape-static and jit/pjit-able.
    One model eval per step (the corrector re-uses it). NFE = M - 1 + (1 if the
    schedule keeps the last eval, see coeffs.build_unipc_schedule).

    fused_update=True (the default) routes the K-term state combine through
    `kernels.unipc_update`: the single-pass Pallas kernel on TPU, an
    XLA-fused fp32 axpy chain elsewhere — equivalent to fused_update=False
    on CPU to <=1e-5 at fp32 (DESIGN.md §4-§5). fused_update=False pins the
    inline jnp tensordot form, kept as the reference for equivalence tests.

    The scan is solver-agnostic: it executes whatever weight rows the table
    carries, so any solver `repro.engine` compiles to a `SolverTable` (DDIM,
    DPM-Solver++, PLMS, DEIS, expanded-grid singlestep) runs through this one
    function. `sched.model_cols` entries ((M+1,) per-eval arrays, e.g. a
    guidance-scale schedule) are passed to `model_fn` as keyword arguments.

    `cache0` opts into the feature-reuse contract (DESIGN.md §12): pass the
    zeroed (B, *cache_shape) deep-feature cache and a cached `model_fn`
    ((x, t, cache=..., **cols) -> (pred, cache)); the cache rides the scan
    carry alongside (x, E). Zero-init is safe because the table's init row
    is always a full eval.
    """
    cached = cache0 is not None
    step, n_rows = unipc_step_fn(model_fn, sched, fused_update=fused_update,
                                 dtype=dtype, cached=cached)
    K = sched.w_pred.shape[1]
    x0 = x_T.astype(dtype)
    E0 = jnp.zeros((K + 1,) + x_T.shape, dtype)
    carry0 = (x0, E0, cache0) if cached else (x0, E0)
    carry, _ = jax.lax.scan(lambda c, j: (step(c, j), None), carry0,
                            jnp.arange(n_rows))
    return carry[0]


def sample_step_fn(sched: UniPCSchedule, fused_update: bool = True):
    """Return a closure suitable for jit/lower in the dry-run: one full UniPC
    sampling trajectory given (params -> model_fn factory) handled by caller."""
    return partial(unipc_sample_scan, sched=sched, fused_update=fused_update)
