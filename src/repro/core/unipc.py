"""UniPC: unified predictor-corrector solvers (the paper's contribution).

Three implementations, all sharing the coefficient machinery in `coeffs.py`:

* `UniPC` — python-loop multistep solver on the GridSolver driver. Reference
  semantics, supports arbitrary order, custom order schedules (Table 4),
  UniC-oracle (Table 3), both prediction types and all B(h) variants.
* `UniPCSinglestep` — singlestep variant (Section 3.4): intermediate points at
  r in (0,1), lower-order estimates for the inner points.
* `unipc_sample_scan` — the production path: all coefficients are a static
  per-step table, the whole sampler is one `lax.scan` that jits, shards, and
  routes the state update through the fused Pallas kernel by default
  (`fused_update=True`; the dispatch policy lives in `kernels.unipc_update.ops`).
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .coeffs import UniPCSchedule, build_unipc_schedule, default_order_schedule
from .solver import CorrectorConfig, Grid, GridSolver, History, unified_step


class UniPC(GridSolver):
    """Multistep UniPC-p (Alg. 5-8). Predictor order = `order`; with the
    corrector enabled the order of accuracy is order+1 (Thm 3.1)."""

    def __init__(
        self,
        model_fn,
        grid: Grid,
        *,
        order: int = 3,
        prediction: str = "data",
        variant: str = "bh2",
        order_schedule: Optional[Sequence[int]] = None,
        lower_order_final: bool = True,
    ):
        super().__init__(model_fn, grid)
        self.order = order
        self.prediction = prediction
        self.variant = variant
        M = len(grid)
        self.order_schedule = (
            list(order_schedule)
            if order_schedule is not None
            else default_order_schedule(M, order, lower_order_final)
        )

    def predict(self, i, x, hist: History):
        g = self.grid
        p_i = min(self.order_schedule[i - 1], i)
        m0 = hist.at_lam(g.lam[i - 1])
        pts = hist.last(p_i - 1, before_lam=float(g.lam[i - 1]))
        points = [(lam, e) for lam, _, e in reversed(pts)]
        return unified_step(
            x, m0, points,
            lam_s=g.lam[i - 1], lam_t=g.lam[i],
            alpha_s=g.alpha[i - 1], alpha_t=g.alpha[i],
            sigma_s=g.sigma[i - 1], sigma_t=g.sigma[i],
            prediction=self.prediction, variant=self.variant,
        )

    def corrector_config(self, **kw) -> CorrectorConfig:
        """UniC matched to this predictor's order/variant."""
        return CorrectorConfig(order=self.order, variant=self.variant, **kw)

    def sample_pc(self, x_T, *, oracle: bool = False, use_corrector: bool = True):
        """Full UniPC = UniP + UniC with per-step order from the schedule."""
        if not use_corrector:
            return self.sample(x_T, corrector=None)
        return self.sample(x_T, corrector=_ScheduledCorrector(self, oracle))


class _ScheduledCorrector(CorrectorConfig):
    """Corrector whose order follows the predictor's per-step order schedule
    (UniC-p_i after UniP-p_i, Alg. 5). GridSolver._correct consults order_at()."""

    def __init__(self, solver: UniPC, oracle: bool):
        super().__init__(order=solver.order, variant=solver.variant, oracle=oracle)
        self._solver = solver

    def order_at(self, i: int) -> int:
        return min(self._solver.order_schedule[i - 1], i)


class UniPCSinglestep(GridSolver):
    """Singlestep UniPC-p (p = 2 or 3): intermediate points at r in (0,1),
    estimated with lower-order unified steps; costs p NFE per grid step."""

    def __init__(self, model_fn, grid: Grid, noise_schedule, *, order: int = 2,
                 prediction: str = "data", variant: str = "bh2"):
        assert order in (2, 3)
        super().__init__(model_fn, grid)
        self.order = order
        self.prediction = prediction
        self.variant = variant
        self.noise_schedule = noise_schedule
        self.r_inner = [0.5] if order == 2 else [1.0 / 3.0, 2.0 / 3.0]

    def predict(self, i, x, hist: History):
        g = self.grid
        lam_s, lam_t = float(g.lam[i - 1]), float(g.lam[i])
        h = lam_t - lam_s
        m0 = hist.at_lam(g.lam[i - 1])
        # walk the intermediate points, each estimated with all points so far
        points = []
        sched = self.noise_schedule
        for r in self.r_inner:
            lam_m = lam_s + r * h
            t_m = float(sched.t_of_lam(lam_m))
            a_m, s_m = float(sched.alpha(t_m)), float(sched.sigma(t_m))
            x_m = unified_step(
                x, m0, points,
                lam_s=lam_s, lam_t=lam_m,
                alpha_s=g.alpha[i - 1], alpha_t=a_m,
                sigma_s=g.sigma[i - 1], sigma_t=s_m,
                prediction=self.prediction, variant=self.variant,
            )
            e_m = self.model(x_m, t_m)
            hist.push(lam_m, t_m, e_m)
            points.append((lam_m, e_m))
        return unified_step(
            x, m0, points,
            lam_s=lam_s, lam_t=lam_t,
            alpha_s=g.alpha[i - 1], alpha_t=g.alpha[i],
            sigma_s=g.sigma[i - 1], sigma_t=g.sigma[i],
            prediction=self.prediction, variant=self.variant,
        )


# ---------------------------------------------------------------------------
# Production path: static-coefficient lax.scan sampler
# ---------------------------------------------------------------------------


def make_unipc_schedule(schedule, num_steps, *, order=3, prediction="data",
                        variant="bh2", spacing="logsnr", use_corrector=True,
                        corrector_at_last=False, order_schedule=None,
                        lower_order_final=True) -> UniPCSchedule:
    from ..diffusion.schedules import timestep_grid

    t, lam, alpha, sigma = timestep_grid(schedule, num_steps, spacing)
    return build_unipc_schedule(
        lambdas=lam, alphas=alpha, sigmas=sigma, timesteps=t,
        order=order, prediction=prediction, variant=variant,
        use_corrector=use_corrector, corrector_at_last=corrector_at_last,
        order_schedule=order_schedule, lower_order_final=lower_order_final,
    )


def unipc_sample_scan(
    model_fn: Callable,
    x_T: jnp.ndarray,
    sched: UniPCSchedule,
    *,
    fused_update: bool = True,
    dtype=jnp.float32,
):
    """Multistep UniPC as a single lax.scan over a static coefficient table.

    model_fn(x, t) -> prediction of `sched.prediction` type. The eval buffer is a
    ring of `order` slots; warm-up and order schedules are realized purely through
    zero-padded weight rows, so the scan body is shape-static and jit/pjit-able.
    One model eval per step (the corrector re-uses it). NFE = M - 1 + (1 if the
    schedule keeps the last eval, see coeffs.build_unipc_schedule).

    fused_update=True (the default) routes the K-term state combine through
    `kernels.unipc_update`: the single-pass Pallas kernel on TPU, an
    XLA-fused fp32 axpy chain elsewhere — equivalent to fused_update=False
    on CPU to <=1e-5 at fp32 (DESIGN.md §4-§5). fused_update=False pins the
    inline jnp tensordot form, kept as the reference for equivalence tests.

    The scan is solver-agnostic: it executes whatever weight rows the table
    carries, so any solver `repro.engine` compiles to a `SolverTable` (DDIM,
    DPM-Solver++, PLMS, DEIS, expanded-grid singlestep) runs through this one
    function. `sched.model_cols` entries ((M+1,) per-eval arrays, e.g. a
    guidance-scale schedule) are passed to `model_fn` as keyword arguments.
    """
    K = sched.w_pred.shape[1]
    f = lambda a: jnp.asarray(a, dtype=dtype)
    base_x_c = sched.base_x_corr if sched.base_x_corr is not None else sched.base_x
    base_m0_c = sched.base_m0_corr if sched.base_m0_corr is not None else sched.base_m0
    cols = sched.model_cols or {}
    tab = dict(
        base_x=f(sched.base_x), base_m0=f(sched.base_m0),
        base_x_c=f(base_x_c), base_m0_c=f(base_m0_c),
        w_pred=f(sched.w_pred), w_corr_prev=f(sched.w_corr_prev),
        w_corr_new=f(sched.w_corr_new), use_c=f(sched.use_corrector),
        out_scale=f(sched.out_scale), t=f(sched.timesteps[1:]),
        **{f"mc_{k}": f(np.asarray(v)[1:]) for k, v in cols.items()},
    )
    sign = jnp.asarray(sched.sign, dtype)

    if fused_update:
        from ..kernels.unipc_update import ops as fused_ops
        combine = fused_ops.weighted_combine
    else:
        def combine(terms, weights):
            # terms: (K+2, *x), weights: (K+2,)
            return jnp.tensordot(weights, terms, axes=1)

    def body(carry, step):
        x, E = carry
        m0 = E[0]
        diffs = E[1:] - m0[None] if K > 0 else jnp.zeros((0,) + x.shape, x.dtype)
        extras = {k: step[f"mc_{k}"] for k in cols}
        # predictor
        terms = jnp.concatenate([x[None], m0[None], diffs], axis=0)
        wts_p = jnp.concatenate(
            [step["base_x"][None], step["base_m0"][None],
             sign * step["out_scale"] * step["w_pred"]], axis=0)
        x_pred = combine(terms, wts_p)
        e_new = model_fn(x_pred, step["t"], **extras)
        # corrector (re-uses e_new; no extra NFE)
        d_new = e_new - m0
        terms_c = jnp.concatenate([terms, d_new[None]], axis=0)
        wts_c = jnp.concatenate(
            [step["base_x_c"][None], step["base_m0_c"][None],
             sign * step["out_scale"] * step["w_corr_prev"],
             (sign * step["out_scale"] * step["w_corr_new"])[None]], axis=0)
        x_corr = combine(terms_c, wts_c)
        x_next = x_pred + step["use_c"] * (x_corr - x_pred)
        E_next = jnp.concatenate([e_new[None], E[:-1]], axis=0)
        return (x_next, E_next), None

    # the initial timestep rides the schedule table explicitly — the first
    # model eval is at sched.timesteps[0], with row 0 of every model column
    t0 = jnp.asarray(sched.timesteps[0], dtype)
    e0 = model_fn(x_T, t0, **{k: f(np.asarray(v)[0]) for k, v in cols.items()})
    E = jnp.concatenate([e0[None], jnp.zeros((K,) + x_T.shape, x_T.dtype)], axis=0)
    (x, _), _ = jax.lax.scan(body, (x_T.astype(dtype), E.astype(dtype)), tab)
    return x


def sample_step_fn(sched: UniPCSchedule, fused_update: bool = True):
    """Return a closure suitable for jit/lower in the dry-run: one full UniPC
    sampling trajectory given (params -> model_fn factory) handled by caller."""
    return partial(unipc_sample_scan, sched=sched, fused_update=fused_update)
