"""UniPC coefficient computation (host-side, float64).

Everything here depends only on the timestep grid (through lambda = log(alpha/sigma))
and the solver hyper-parameters — never on data. We therefore compute all
coefficients in numpy float64 at schedule-build time and feed the sampling
`lax.scan` a static per-step coefficient table. This is both numerically safer
(the phi/psi recursions cancel catastrophically in float32) and faster on TPU
(no per-step host sync, no tiny traced linear solves).

Unified weight convention
-------------------------
Every solver update in this repo is expressed as

    noise pred: x_t = (a_t/a_s) x_s - s_t (e^h - 1) m0 - s_t * sum_m w_m D_m
    data  pred: x_t = (s_t/s_s) x_s + a_t (1 - e^{-h}) m0 + a_t * sum_m w_m D_m

with D_m = model(point_m) - m0.  For UniPC, w_m = B(h) * a_m / r_m where
a = R^{-1} phi / B (Thm 3.1); for UniPC_v, w_m = (sum_n h varphi_{n+1}(h) A[n,m]) / r_m
with A = C_p^{-1} (App. C). Both reduce to a single per-difference weight vector,
which is what `unipc_weights` returns.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from .phi import varphi, psi

BH_VARIANTS = ("bh1", "bh2", "vary")
PREDICTION_TYPES = ("noise", "data")


def semilinear_coeffs(h: float, alpha_s: float, alpha_t: float,
                      sigma_s: float, sigma_t: float, prediction: str):
    """(base_x, base_m0) of the order-1 semilinear (DDIM) transfer — the base
    every unified update (and UniC corrector row) is built on."""
    if prediction == "noise":
        return alpha_t / alpha_s, -sigma_t * math.expm1(h)
    return sigma_t / sigma_s, alpha_t * (-math.expm1(-h))


def bh_value(h: float, variant: str, prediction: str) -> float:
    """B(h), sign-normalized so B(h) = h + O(h^2) for BOTH prediction types.

    The official implementation works in hh = -h for data prediction with a
    matching sign flip in its rhs vector; our rhs (`_rhs_vector`, psi on +h)
    keeps the +h convention, so B must too — for exact solves the sign cancels
    anyway, but the degenerate a_1 = 0.5 shortcut (App. F) depends on it.
    B1(h) = h; B2(h) = e^h - 1 (noise) / 1 - e^{-h} (data)."""
    if variant == "bh1":
        return h
    if variant == "bh2":
        return math.expm1(h) if prediction == "noise" else -math.expm1(-h)
    raise ValueError(f"no explicit B(h) for variant {variant!r}")


def _rhs_vector(q: int, h: float, prediction: str) -> np.ndarray:
    """b_n = h * n! * varphi_{n+1}(h)  (noise)  or  h * n! * psi_{n+1}(h)  (data),
    i.e. phi_n / h^{n-1}: we divide row n of R_p(h) by h^{n-1} so the Vandermonde
    system is in powers of r alone (better conditioned, h-free matrix)."""
    fn = varphi if prediction == "noise" else psi
    return np.array(
        [h * math.factorial(n) * float(fn(n + 1, h)) for n in range(1, q + 1)],
        dtype=np.float64,
    )


def unipc_weights(r: np.ndarray, h: float, variant: str, prediction: str,
                  degenerate_a1: bool = True) -> np.ndarray:
    """Per-difference weights w_m (length len(r)) for the unified update.

    r: the relative log-SNR offsets r_m = (lambda_{s_m} - lambda_{t_{i-1}})/h_i,
       all distinct and nonzero (negative for previous points, 1 for the
       corrector's current point).

    degenerate_a1: for the single-point systems (UniP-2 / UniC-1) the paper
    (App. F) and the official implementation use the fixed solution a_1 = 0.5
    instead of the exact solve. This is what makes B_1(h) and B_2(h)
    *empirically distinguishable* (Table 1): with exact solves, B(h) cancels —
    w = B * R^{-1}(phi/B) = R^{-1} phi — and all variants coincide.
    """
    r = np.asarray(r, dtype=np.float64)
    q = len(r)
    if q == 0:
        return np.zeros((0,), dtype=np.float64)
    if q == 1 and degenerate_a1 and variant != "vary":
        return np.array([0.5 * bh_value(h, variant, prediction)]) / r
    R = np.vander(r, N=q, increasing=True).T  # R[n-1, m] = r_m^{n-1}
    if variant == "vary":
        # UniPC_v (App. C): per-point weights w solve C_p w = h*varphi_{n+1}(h)
        # with C[n-1, m] = r_m^{n-1} / n!  (A_p = C_p^{-1} is h-independent).
        fn = varphi if prediction == "noise" else psi
        C = R / np.array([[math.factorial(n)] for n in range(1, q + 1)])
        hphi = np.array([h * float(fn(n + 1, h)) for n in range(1, q + 1)])
        w = np.linalg.solve(C, hphi)
    else:
        b = _rhs_vector(q, h, prediction)
        B = bh_value(h, variant, prediction)
        a = np.linalg.solve(R, b / B)
        w = B * a
    return w / r


def default_order_schedule(num_steps: int, order: int, lower_order_final: bool = True):
    """Predictor order p_i per step (1-indexed steps i=1..M), as in Alg. 5/7
    (warm-up p_i = min(p, i)) with the DPM-Solver++ style lower-order-final."""
    orders = []
    for i in range(1, num_steps + 1):
        p_i = min(order, i)
        if lower_order_final:
            p_i = min(p_i, num_steps - i + 1)
        orders.append(max(1, p_i))
    return orders


@dataclass
class UniPCSchedule:
    """Static per-step weight table consumed by the scan-based sampler.

    Despite the name this is the *solver-agnostic* table format: every
    multistep solver in the zoo (and the singlestep ones, on an expanded grid)
    compiles to rows of this table — see `repro.engine`. UniPC is simply the
    solver whose rows `build_unipc_schedule` emits.

    All arrays are float64 numpy; the sampler casts once. M = number of steps.
    The difference-weight width K = w_pred.shape[1] (order-1 for UniPC; the
    sampler derives its eval-ring size from it, not from `order`).
    """

    lambdas: np.ndarray           # (M+1,) half log-SNR at t_0..t_M
    alphas: np.ndarray            # (M+1,)
    sigmas: np.ndarray            # (M+1,)
    order: int
    prediction: str
    variant: str
    # per-step (M,) / (M, K) / (M,) tables:
    base_x: np.ndarray = field(default=None)       # coeff on x_{i-1}
    base_m0: np.ndarray = field(default=None)      # coeff on m0
    w_pred: np.ndarray = field(default=None)       # (M, K) predictor diff weights (0-padded)
    w_corr_prev: np.ndarray = field(default=None)  # (M, K) corrector prev-diff weights
    w_corr_new: np.ndarray = field(default=None)   # (M,) corrector current-diff weight
    use_corrector: np.ndarray = field(default=None)  # (M,) 0/1
    out_scale: np.ndarray = field(default=None)    # sigma_t (noise) / alpha_t (data) per step
    sign: float = field(default=None)              # -1 noise, +1 data
    timesteps: np.ndarray = field(default=None)    # (M+1,) t grid (for the model)
    orders: list = field(default=None)
    # corrector base coefficients: UniC is always the *semilinear* base plus
    # difference terms, which coincides with the predictor's base for UniPC /
    # DDIM / DPM-Solver++ but not for e.g. DEIS (whose predictor folds the
    # quadrature weights into base_m0). None -> same as base_x / base_m0.
    base_x_corr: np.ndarray = field(default=None)  # (M,)
    base_m0_corr: np.ndarray = field(default=None)  # (M,)
    # per-eval model columns: {name: (M+1,) array} fed to model_fn as keyword
    # arguments (row 0 at the initial eval, row i at step i's eval). Used by
    # the engine for guidance-scale schedules and thresholding percentiles.
    model_cols: dict = field(default=None)


# The engine refers to the table by its role, not by the solver that named it.
SolverTable = UniPCSchedule


def augment_step_rows(sched: UniPCSchedule) -> dict:
    """The row-gatherable step table: one numpy float64 array per column, each
    with M+1 rows indexable by a per-slot step index.

    Row 0 is the *init row* — an identity transfer (base_x = 1, every other
    weight 0, corrector off) whose model eval lands at timesteps[0]. A slot
    whose ring buffer has been zeroed and which executes rows 0, 1, ..., M on
    consecutive ticks reproduces the uniform scan exactly: the init row pushes
    e_0 into the ring, and the zero-padded weight rows of the early body rows
    null the still-empty ring slots, so a freshly admitted slot warms up at
    low effective order as data, never as shape (DESIGN.md §2, §9).

    Model columns (guidance scale, thresholding percentile) keep their native
    (M+1,) per-eval layout — row i is the column value at eval i.
    """
    base_x_c = sched.base_x_corr if sched.base_x_corr is not None else sched.base_x
    base_m0_c = sched.base_m0_corr if sched.base_m0_corr is not None else sched.base_m0

    def aug(v, head):
        v = np.asarray(v, np.float64)
        head_row = np.full((1,) + v.shape[1:], head, np.float64)
        return np.concatenate([head_row, v], axis=0)

    rows = dict(
        base_x=aug(sched.base_x, 1.0), base_m0=aug(sched.base_m0, 0.0),
        base_x_c=aug(base_x_c, 1.0), base_m0_c=aug(base_m0_c, 0.0),
        w_pred=aug(sched.w_pred, 0.0), w_corr_prev=aug(sched.w_corr_prev, 0.0),
        w_corr_new=aug(sched.w_corr_new, 0.0),
        use_c=aug(sched.use_corrector, 0.0), out_scale=aug(sched.out_scale, 0.0),
        t=np.asarray(sched.timesteps, np.float64),
    )
    for k, v in (sched.model_cols or {}).items():
        rows[f"mc_{k}"] = np.asarray(v, np.float64)
    return rows


def eval_cost_rows(rows: dict, *, cache_block: int = 0,
                   n_blocks: int = 0) -> np.ndarray:
    """Per-row model-eval cost as a fraction of one full denoiser eval.

    `rows` is an augmented (or stacked) step-row dict. Without feature reuse
    every row costs 1.0 — the NFE floor. With a cache boundary, rows whose
    `mc_cache_reuse` column is set run only the first `cache_block` of
    `n_blocks` DiT blocks, so they cost cache_block / n_blocks. (The patch
    embed, conditioning MLP, and final layer run on every eval and are
    excluded from the fraction — the accounting is per-block, documented in
    DESIGN.md §12.) Summing a request's row span gives its evals-per-latent,
    the quantity the tuning benchmarks and `guard.py` gate on.
    """
    n = len(rows["t"])
    cost = np.ones(n, np.float64)
    if cache_block and n_blocks and "mc_cache_reuse" in rows:
        reuse = np.asarray(rows["mc_cache_reuse"], np.float64)
        cost = np.where(reuse > 0.5, cache_block / n_blocks, 1.0)
    return cost


def stack_step_rows(tables: dict) -> tuple:
    """Concatenate several tables' augmented step rows into one plan bank.

    tables: {tier_name: UniPCSchedule}, iterated in insertion order. Returns
    (rows, tiers) where `rows` is one row-gatherable dict exactly like
    `augment_step_rows` emits — every tier's init row + body rows stacked
    along axis 0, difference-weight columns zero-padded to the widest tier —
    and `tiers` maps tier name to its (row_offset, n_rows) span. A slot that
    executes rows offset..offset+n_rows-1 runs that tier's trajectory; row 0
    (the first tier's init row) stays the identity parking row for idle
    slots.

    Every table must share prediction type, sign, and model-column keys (the
    step function closes over one sign and gathers one column set); mixed
    banks of that kind fail loudly here rather than miscompute.
    """
    if not tables:
        raise ValueError("plan bank needs at least one tier table")
    items = list(tables.items())
    _, first = items[0]
    cols0 = sorted((first.model_cols or {}).keys())
    for name, t in items[1:]:
        if t.prediction != first.prediction or t.sign != first.sign:
            raise ValueError(
                f"plan-bank tiers must share prediction type; tier {name!r} "
                f"is {t.prediction}-prediction, expected {first.prediction}")
        if sorted((t.model_cols or {}).keys()) != cols0:
            raise ValueError(
                f"plan-bank tiers must share model columns; tier {name!r} "
                f"has {sorted((t.model_cols or {}).keys())}, expected {cols0}")
    K = max(t.w_pred.shape[1] for _, t in items)
    tiers, stacked, offset = {}, [], 0
    for name, t in items:
        rows = augment_step_rows(t)
        for key in ("w_pred", "w_corr_prev"):
            pad = K - rows[key].shape[1]
            if pad:
                rows[key] = np.pad(rows[key], ((0, 0), (0, pad)))
        n = len(rows["t"])
        tiers[name] = (offset, n)
        offset += n
        stacked.append(rows)
    keys = stacked[0].keys()
    return ({k: np.concatenate([r[k] for r in stacked], axis=0) for k in keys},
            tiers)


def build_unipc_schedule(
    *,
    lambdas: np.ndarray,
    alphas: np.ndarray,
    sigmas: np.ndarray,
    timesteps: np.ndarray,
    order: int = 3,
    prediction: str = "data",
    variant: str = "bh2",
    use_corrector: bool = True,
    corrector_at_last: bool = False,
    order_schedule=None,
    lower_order_final: bool = True,
    variant_schedule=None,
    corrector_schedule=None,
) -> UniPCSchedule:
    """Precompute every scalar/vector the multistep UniPC scan needs.

    Buffer convention inside the sampler: E[k] holds the model output at point
    t_{i-1-k}; predictor differences at step i use r_m = (lam[i-1-m] - lam[i-1])/h
    for m = 1..p_i-1 and D_m = E[m] - E[0]; the corrector appends r = 1 with
    D = model(x_pred, t_i) - E[0]. (Alg. 5-8.)

    The schedules generalize the paper's hand-set policy into a searchable
    per-step decision vector (`repro.tuning`): `order_schedule` the UniP order
    per step, `variant_schedule` the B(h) variant per step, and
    `corrector_schedule` a per-step 0/1 UniC mask overriding the
    `use_corrector`/`corrector_at_last` policy. All default to the paper's
    fixed choices, under which the emitted table is unchanged.
    """
    assert prediction in PREDICTION_TYPES and variant in BH_VARIANTS
    lambdas = np.asarray(lambdas, dtype=np.float64)
    M = len(lambdas) - 1
    if order_schedule is None:
        order_schedule = default_order_schedule(M, order, lower_order_final)
    assert len(order_schedule) == M
    if variant_schedule is None:
        variant_schedule = [variant] * M
    assert len(variant_schedule) == M
    assert all(v in BH_VARIANTS for v in variant_schedule)
    if corrector_schedule is not None:
        assert len(corrector_schedule) == M
    max_prev = max(1, order - 1) if order > 1 else 1
    # allocate with at least one column so jnp shapes stay static even for order 1
    w_pred = np.zeros((M, max(1, order - 1)))
    w_corr_prev = np.zeros((M, max(1, order - 1)))
    w_corr_new = np.zeros((M,))
    base_x = np.zeros((M,))
    base_m0 = np.zeros((M,))
    out_scale = np.zeros((M,))
    use_c = np.zeros((M,))
    for i in range(1, M + 1):
        h = float(lambdas[i] - lambdas[i - 1])
        p_i = min(order_schedule[i - 1], i)
        v_i = variant_schedule[i - 1]
        # previous-point offsets r_m, m=1..p_i-1  (points t_{i-1-m})
        r_prev = np.array(
            [(lambdas[i - 1 - m] - lambdas[i - 1]) / h for m in range(1, p_i)],
            dtype=np.float64,
        )
        wp = unipc_weights(r_prev, h, v_i, prediction)
        w_pred[i - 1, : len(wp)] = wp
        # corrector: previous offsets + r=1 for the current point
        r_corr = np.concatenate([r_prev, [1.0]])
        wc = unipc_weights(r_corr, h, v_i, prediction)
        w_corr_prev[i - 1, : len(wc) - 1] = wc[:-1]
        w_corr_new[i - 1] = wc[-1]
        if corrector_schedule is not None:
            corr_here = bool(corrector_schedule[i - 1])
        else:
            corr_here = use_corrector and (corrector_at_last or i < M)
        use_c[i - 1] = 1.0 if corr_here else 0.0
        base_x[i - 1], base_m0[i - 1] = semilinear_coeffs(
            h, alphas[i - 1], alphas[i], sigmas[i - 1], sigmas[i], prediction)
        out_scale[i - 1] = sigmas[i] if prediction == "noise" else alphas[i]
    return UniPCSchedule(
        lambdas=lambdas,
        alphas=np.asarray(alphas, dtype=np.float64),
        sigmas=np.asarray(sigmas, dtype=np.float64),
        order=order,
        prediction=prediction,
        variant=variant,
        base_x=base_x,
        base_m0=base_m0,
        w_pred=w_pred,
        w_corr_prev=w_corr_prev,
        w_corr_new=w_corr_new,
        use_corrector=use_c,
        out_scale=out_scale,
        sign=-1.0 if prediction == "noise" else 1.0,
        timesteps=np.asarray(timesteps, dtype=np.float64),
        orders=list(order_schedule),
    )
