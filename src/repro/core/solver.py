"""Solver substrate: grids, eval history, and the *unified* UniPC step.

The paper's central observation is that predictor and corrector share one
analytical form (Eq. 3 / Eq. 8-9): a semilinear base plus a weighted sum of
model-output differences at points with relative log-SNR offsets r_m. UniP uses
only previous points (r_m < 0 in multistep); UniC appends the current point
(r = 1). `unified_step` below *is* that form; everything else — multistep UniPC
of any order, UniC bolted onto any off-the-shelf solver (Table 2), singlestep
variants — is a choice of which (lambda, eval) points to feed it.

This module is the reference/python-loop path (research, baselines, Table 2).
The production scan-based sampler lives in `core/unipc.py`.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable, Deque, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from .coeffs import unipc_weights
from ..diffusion.schedules import NoiseSchedule, timestep_grid

Array = jnp.ndarray
ModelFn = Callable[[Array, float], Array]  # (x, t) -> prediction (noise or data)


@dataclass
class Grid:
    """Sampling grid from T down to t_eps, with host-precision schedule values."""

    t: np.ndarray
    lam: np.ndarray
    alpha: np.ndarray
    sigma: np.ndarray

    @classmethod
    def build(cls, schedule: NoiseSchedule, num_steps: int, spacing: str = "logsnr"):
        return cls(*timestep_grid(schedule, num_steps, spacing))

    def __len__(self):
        return len(self.t) - 1


class History:
    """Recent model evaluations as (lambda, t, output) in evaluation order.

    Backed by a bounded deque: push is O(1) (the old list form paid an O(n)
    `pop(0)` on every eviction), and all consumers iterate (newest-first via
    `reversed` / `last`) rather than slice."""

    def __init__(self, maxlen: int = 16):
        self.maxlen = maxlen
        self.items: Deque[Tuple[float, float, Array]] = deque(maxlen=maxlen)

    def push(self, lam: float, t: float, out: Array):
        self.items.append((float(lam), float(t), out))

    def last(self, k: int, before_lam: Optional[float] = None, exclude_lam=()):
        """Most recent k entries (newest first), optionally excluding lambdas."""
        out = []
        if k <= 0:
            return out
        for lam, t, e in reversed(self.items):
            if any(abs(lam - ex) < 1e-12 for ex in exclude_lam):
                continue
            if before_lam is not None and lam >= before_lam - 1e-12:
                continue
            out.append((lam, t, e))
            if len(out) == k:
                break
        return out

    def at_lam(self, lam: float):
        for l, t, e in reversed(self.items):
            if abs(l - lam) < 1e-12:
                return e
        raise KeyError(f"no eval at lambda={lam}")


class EvalCounter:
    """Wraps a model fn, counting NFE."""

    def __init__(self, fn: ModelFn):
        self.fn = fn
        self.nfe = 0

    def __call__(self, x, t):
        self.nfe += 1
        return self.fn(x, t)


def semilinear_base(x, m0, *, alpha_s, alpha_t, sigma_s, sigma_t, h, prediction):
    """The order-1 (DDIM) part of the unified update."""
    if prediction == "noise":
        return (alpha_t / alpha_s) * x - sigma_t * np.expm1(h) * m0
    return (sigma_t / sigma_s) * x + alpha_t * (-np.expm1(-h)) * m0


def unified_step(
    x,
    m0,
    points: Sequence[Tuple[float, Array]],
    *,
    lam_s: float,
    lam_t: float,
    alpha_s: float,
    alpha_t: float,
    sigma_s: float,
    sigma_t: float,
    prediction: str,
    variant: str = "bh2",
    current: Optional[Array] = None,
):
    """One unified UniP/UniC update (Eq. 3 / 8 / 9).

    x:       state at the anchor point s (already corrected, if applicable)
    m0:      model output at the anchor (evaluated at the *uncorrected* sample)
    points:  [(lambda_m, model_out_m)] extra points (previous in multistep,
             intermediate in singlestep); may be empty -> DDIM / UniP-1.
    current: model output at lam_t (appends r = 1) -> corrector form.
    """
    h = float(lam_t - lam_s)
    rs = [(lam_m - lam_s) / h for lam_m, _ in points]
    outs = [e for _, e in points]
    if current is not None:
        rs.append(1.0)
        outs.append(current)
    base = semilinear_base(
        x, m0, alpha_s=alpha_s, alpha_t=alpha_t, sigma_s=sigma_s, sigma_t=sigma_t,
        h=h, prediction=prediction,
    )
    if not rs:
        return base
    w = unipc_weights(np.array(rs), h, variant, prediction)
    acc = 0.0
    for w_m, e_m in zip(w, outs):
        acc = acc + float(w_m) * (e_m - m0)
    scale = sigma_t if prediction == "noise" else alpha_t
    sign = -1.0 if prediction == "noise" else 1.0
    return base + sign * scale * acc


@dataclass
class CorrectorConfig:
    """UniC-p applied after any solver (Alg. 1 / 3)."""

    order: int  # p: number of difference points incl. the current one
    variant: str = "bh2"
    oracle: bool = False          # re-evaluate at the corrected sample (Table 3)
    at_last_step: bool = False    # costs one extra NFE if True
    free_oracle: float = 0.0      # beyond-paper (§4.2 future work): estimate
    # eps(x_c) ~ eps(x_pred) + gamma * J_hat (x_c - x_pred) with a FREE secant
    # Jacobian-diagonal estimate from the last two stored evals — pushes the
    # buffer entry toward the oracle's without any extra NFE. gamma in (0, 1].


class GridSolver:
    """Python-loop driver shared by UniPC and every baseline.

    Subclasses implement `predict(i, x, hist) -> x_pred` and may evaluate the
    model at intermediate points (pushing them to `hist`). The driver maintains
    the grid-point evals, applies the optional method-agnostic UniC, and counts
    NFE faithfully (corrector re-uses the next step's eval; no extra NFE except
    oracle / at_last_step).
    """

    prediction: str = "data"
    order: int = 1  # order of accuracy of the predictor (for UniC-p default)

    def __init__(self, model_fn: ModelFn, grid: Grid):
        self.model = EvalCounter(model_fn)
        self.grid = grid

    # -- subclass hook -------------------------------------------------------
    def predict(self, i: int, x, hist: History):
        raise NotImplementedError

    # -- driver --------------------------------------------------------------
    def sample(self, x_T, corrector: Optional[CorrectorConfig] = None):
        g = self.grid
        M = len(g)
        hist = History()            # every eval (incl. singlestep intermediates)
        self._grid_hist = History()  # grid-point evals only — the corrector
        # anchors on these: intermediate evals sit at low-order-accurate
        # estimates and would degrade UniC's order (cf. Thm 3.1 regularity).
        x = x_T
        e0 = self.model(x_T, float(g.t[0]))
        hist.push(g.lam[0], g.t[0], e0)
        self._grid_hist.push(g.lam[0], g.t[0], e0)
        prev_pair = (x_T, e0)
        for i in range(1, M + 1):
            x_pred = self.predict(i, x, hist)
            last = i == M
            do_corr = corrector is not None and (not last or corrector.at_last_step)
            need_eval = (i < M) or do_corr
            e_new = self.model(x_pred, float(g.t[i])) if need_eval else None
            if do_corr:
                x = self._correct(i, x, x_pred, e_new, corrector)
                if corrector.oracle:
                    e_new = self.model(x, float(g.t[i]))
                elif corrector.free_oracle and e_new is not None:
                    # beyond-paper (paper §4.2 future work): push a FREE
                    # estimate of eps(x_c) instead of eps(x_pred): secant
                    # diagonal-Jacobian from the previous (sample, eval) pair.
                    xp, ep = prev_pair
                    denom = jnp.asarray(x_pred) - jnp.asarray(xp)
                    ok = jnp.abs(denom) > 1e-8
                    jhat = jnp.where(
                        ok,
                        (jnp.asarray(e_new) - jnp.asarray(ep))
                        / jnp.where(ok, denom, 1.0),
                        0.0)
                    jhat = jnp.clip(jhat, -5.0, 5.0)
                    e_new = jnp.asarray(e_new) + corrector.free_oracle * jhat * (
                        jnp.asarray(x) - jnp.asarray(x_pred))
            else:
                x = x_pred
            if e_new is not None:
                hist.push(g.lam[i], g.t[i], e_new)
                self._grid_hist.push(g.lam[i], g.t[i], e_new)
                prev_pair = (x_pred, e_new)
        return x

    def _correct(self, i, x_prev, x_pred, e_new, cfg: CorrectorConfig):
        g = self.grid
        hist = self._grid_hist
        order = cfg.order_at(i) if hasattr(cfg, "order_at") else cfg.order
        m0 = hist.at_lam(g.lam[i - 1])
        pts = hist.last(order - 1, before_lam=float(g.lam[i - 1]))
        points = [(lam, e) for lam, _, e in reversed(pts)]
        return unified_step(
            x_prev, m0, points,
            lam_s=g.lam[i - 1], lam_t=g.lam[i],
            alpha_s=g.alpha[i - 1], alpha_t=g.alpha[i],
            sigma_s=g.sigma[i - 1], sigma_t=g.sigma[i],
            prediction=self.prediction, variant=cfg.variant, current=e_new,
        )
