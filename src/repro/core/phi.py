"""Exponential-integrator functions for UniPC (Hochbruck & Ostermann, 2005).

Noise-prediction side uses

    varphi_0(h) = e^h,   varphi_{k+1}(h) = (varphi_k(h) - 1/k!) / h
    phi_n(h)    = h^n * n! * varphi_{n+1}(h)                      (Thm 3.1)

Data-prediction side uses

    psi_0(h) = e^{-h},   psi_{k+1}(h) = (1/k! - psi_k(h)) / h
    g_n(h)   = h^n * n! * psi_{n+1}(h)                            (Eq. 10)

The recursions suffer catastrophic cancellation for small |h| (each step divides
an O(h) difference by h), so below a threshold we switch to the absolutely
convergent series

    varphi_k(h) = sum_{j>=0} h^j / (j + k)!
    psi_k(h)    = sum_{j>=0} (-h)^j / (j + k)!        [psi_k(h) = varphi_k(-h)]

All coefficient computation happens host-side in float64 (the quantities depend
only on the timestep grid, never on data), so numpy is the primary implementation;
jnp variants exist for the fully-traced research path.
"""

from __future__ import annotations

import math

import numpy as np

_SERIES_THRESHOLD = 0.5
_SERIES_TERMS = 24  # |h| <= 0.5 -> term j ~ 0.5^j / (j+k)! ; 24 terms is far below eps


def varphi(k: int, h) -> np.ndarray:
    """varphi_k(h), elementwise over h (float64)."""
    h = np.asarray(h, dtype=np.float64)
    small = np.abs(h) < _SERIES_THRESHOLD
    return np.where(small, _varphi_series(k, h), _varphi_recursive(k, h))


def psi(k: int, h) -> np.ndarray:
    """psi_k(h) = varphi_k(-h)."""
    return varphi(k, -np.asarray(h, dtype=np.float64))


def _varphi_series(k: int, h: np.ndarray) -> np.ndarray:
    acc = np.zeros_like(h)
    # Horner-style from the tail: sum_j h^j / (j+k)!
    for j in reversed(range(_SERIES_TERMS)):
        acc = acc * h + 1.0 / math.factorial(j + k)
    return acc


def _varphi_recursive(k: int, h: np.ndarray) -> np.ndarray:
    with np.errstate(divide="ignore", invalid="ignore"):
        v = np.exp(h)
        for j in range(k):
            v = (v - 1.0 / math.factorial(j)) / h
    return v


def phi_vec(p: int, h) -> np.ndarray:
    """phi_p(h) = (phi_1..phi_p), phi_n = h^n n! varphi_{n+1}(h). Shape (p,) + h.shape."""
    h = np.asarray(h, dtype=np.float64)
    return np.stack([h**n * math.factorial(n) * varphi(n + 1, h) for n in range(1, p + 1)])


def g_vec(p: int, h) -> np.ndarray:
    """g_p(h) = (g_1..g_p), g_n = h^n n! psi_{n+1}(h). Shape (p,) + h.shape."""
    h = np.asarray(h, dtype=np.float64)
    return np.stack([h**n * math.factorial(n) * psi(n + 1, h) for n in range(1, p + 1)])


# Closed forms used only by tests (App. E.1 / E.4):
def varphi1_closed(h):
    return np.expm1(h) / h


def varphi2_closed(h):
    return (np.exp(h) - h - 1.0) / h**2


def varphi3_closed(h):
    return (np.exp(h) - h**2 / 2 - h - 1.0) / h**3


def psi1_closed(h):
    return -np.expm1(-h) / h


def psi2_closed(h):
    return (h - 1.0 + np.exp(-h)) / h**2


def psi3_closed(h):
    return (h**2 / 2 - h + 1.0 - np.exp(-h)) / h**3
