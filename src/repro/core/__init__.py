"""UniPC core: unified predictor-corrector solvers + every compared baseline."""

from .coeffs import (
    UniPCSchedule,
    augment_step_rows,
    bh_value,
    build_unipc_schedule,
    default_order_schedule,
    stack_step_rows,
    unipc_weights,
)
from .solver import CorrectorConfig, Grid, GridSolver, History, unified_step
from .unipc import (UniPC, UniPCSinglestep, make_unipc_schedule,
                    step_fn_over_rows, unipc_sample_scan, unipc_step_fn)
from .baselines import DDIM, DEIS, DPMSolverPP, DPMSolverSinglestep, PNDM

__all__ = [
    "UniPC", "UniPCSinglestep", "UniPCSchedule", "unipc_sample_scan",
    "unipc_step_fn", "step_fn_over_rows", "augment_step_rows",
    "stack_step_rows",
    "make_unipc_schedule", "build_unipc_schedule", "default_order_schedule",
    "unipc_weights", "bh_value", "unified_step",
    "Grid", "GridSolver", "History", "CorrectorConfig",
    "DDIM", "DPMSolverPP", "DPMSolverSinglestep", "PNDM", "DEIS",
]
