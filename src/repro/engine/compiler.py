"""Per-step weight-table compilers: the solver zoo as data.

Every solver here emits rows of the same `SolverTable` that
`core.coeffs.build_unipc_schedule` emits for UniPC — `(base_x, base_m0,
w_pred, base_*_corr, w_corr_*, out_scale)` per step, host-side float64 — so
`unipc_sample_scan` (one `lax.scan`, fused Pallas combine) executes all of
them unchanged. The translations:

* **DDIM** — the semilinear base alone (UniP-1); zero difference weights.
* **DPM-Solver++ 1M/2M/3M** — Lu et al. 2022b's D1/D2 combinations re-based
  onto our newest-first differences D_m = E[m] − E[0] (linear, exact).
* **PLMS / Adams-Bashforth (PNDM)** — e_AB = Σ c_j E[j] with Σ c_j = 1, so
  e_AB = m0 + Σ_{j≥1} c_j D_j and the AB ladder folds into the weight rows.
* **DEIS tAB-k** — quadrature weights w_j on raw evals e_j become
  base_m0 = Σ w_j plus difference weights (e_j = m0 + D_j).
* **DPM-Solver 2S/3S (singlestep)** — compiled onto an *expanded grid*: each
  grid step becomes `order` scan rows (one per intermediate point), with the
  carry re-based from the previous intermediate state. The scan's eval ring
  then holds exactly the intermediates the singlestep formulas need.
* **UniC bolt-on** (Table 2) — for any multistep table: corrector rows from
  `unipc_weights` on [r_prev..., 1] over the *semilinear* base (which is why
  the table carries separate `base_*_corr` columns — DEIS's predictor base
  absorbs its quadrature weights and differs from the semilinear one).

Warm-up is data, not shape: rows beyond a step's true order are zero-padded,
exactly as in DESIGN.md §2.
"""

from __future__ import annotations

import math

import numpy as np

from ..core import (DDIM, DEIS, DPMSolverPP, DPMSolverSinglestep, Grid, PNDM,
                    UniPC)
from ..core.baselines import PLMS_AB, deis_quad_weights
from ..core.coeffs import (SolverTable, build_unipc_schedule,
                           semilinear_coeffs, unipc_weights)
from ..core.solver import CorrectorConfig
from ..diffusion.schedules import timestep_grid
from .specs import EngineSpec, SolverDef, register, solver_def


def compile_table(spec: EngineSpec, noise_schedule) -> SolverTable:
    """Resolve the spec against the registry and compile its weight table."""
    spec = spec.resolve()
    return solver_def(spec.solver).compile(spec, noise_schedule)


def build_loop(spec: EngineSpec, noise_schedule, model_fn):
    """The python-loop GridSolver reference for the same spec (same grid,
    same corrector policy) — what the engine's scan path is tested against."""
    spec = spec.resolve()
    return solver_def(spec.solver).loop(spec, noise_schedule, model_fn)


def apply_model_cols(tab: SolverTable, spec: EngineSpec) -> SolverTable:
    """Return `tab` with the spec's per-eval model columns attached: the
    guidance-scale schedule (`g`) and the dynamic-thresholding percentile
    (`tq`). Shared by the registry path (`SamplerEngine.compile`) and
    plan-compiled tables (`repro.tuning`), so a tuned plan serves with the
    same conditioning knobs as a hand-set table. The input table is NOT
    mutated — callers may compile one base table under several specs."""
    from dataclasses import replace as dc_replace

    from ..diffusion.guidance import guidance_schedule

    spec = spec.resolve()
    n_evals = len(tab.timesteps)
    cols = dict(tab.model_cols or {})
    if spec.cfg_scale:
        cols["g"] = guidance_schedule(spec.cfg_scale, n_evals,
                                      spec.cfg_schedule, spec.cfg_scale_end)
    if spec.thresholding:
        if tab.prediction != "data":
            raise ValueError("dynamic thresholding clips the x0 "
                             "prediction; use a data-prediction solver")
        cols["tq"] = guidance_schedule(spec.threshold_percentile, n_evals)
    return dc_replace(tab, model_cols=cols)


def step_guidance_profile(tab: SolverTable, spec: EngineSpec) -> np.ndarray:
    """(M+1,) guidance profile for the per-slot step path, host-side float64.

    The step function carries the guidance scale as *per-slot state* (every
    request its own scale) instead of the scan's absolute per-eval column, so
    the table contributes only the schedule *shape*: the compiled `g` column
    normalized by the spec's nominal scale. Effective per-slot scale at row i
    is then `g_slot * profile[i]` — identically `g_slot` for the constant
    schedule (profile == 1), and a proportional ramp for linear/cosine
    schedules. Requires a compiled table with cfg on (a `g` model column).
    """
    cols = tab.model_cols or {}
    if "g" not in cols or not spec.cfg_scale:
        raise ValueError("guidance profile needs a table compiled with "
                         "cfg_scale != 0")
    return np.asarray(cols["g"], np.float64) / float(spec.cfg_scale)


# ---------------------------------------------------------------------------
# shared machinery
# ---------------------------------------------------------------------------


def _empty_table(spec: EngineSpec, noise_schedule, steps: int, K: int,
                 prediction: str) -> SolverTable:
    t, lam, alpha, sigma = timestep_grid(noise_schedule, steps, spec.spacing)
    M = steps
    Kc = max(K, spec.corrector_order - 1 if spec.use_corrector else 0, 1)
    return SolverTable(
        lambdas=lam, alphas=alpha, sigmas=sigma, order=spec.order,
        prediction=prediction, variant=spec.variant,
        base_x=np.zeros(M), base_m0=np.zeros(M),
        w_pred=np.zeros((M, Kc)), w_corr_prev=np.zeros((M, Kc)),
        w_corr_new=np.zeros(M), use_corrector=np.zeros(M),
        out_scale=(sigma[1:] if prediction == "noise" else alpha[1:]).copy(),
        sign=-1.0 if prediction == "noise" else 1.0,
        timesteps=t, orders=[],
        base_x_corr=np.zeros(M), base_m0_corr=np.zeros(M),
    )


def _apply_unic(tab: SolverTable, spec: EngineSpec) -> SolverTable:
    """Fill the corrector columns: UniC-p over the solver's own grid, anchored
    on the semilinear base (method-agnostic, Alg. 1/3 / Table 2)."""
    lam, alpha, sigma = tab.lambdas, tab.alphas, tab.sigmas
    M = len(tab.base_x)
    p = spec.corrector_order
    for i in range(1, M + 1):
        h = float(lam[i] - lam[i - 1])
        tab.base_x_corr[i - 1], tab.base_m0_corr[i - 1] = semilinear_coeffs(
            h, alpha[i - 1], alpha[i], sigma[i - 1], sigma[i], tab.prediction)
        p_i = min(p, i)
        r_prev = np.array(
            [(lam[i - 1 - m] - lam[i - 1]) / h for m in range(1, p_i)])
        wc = unipc_weights(np.concatenate([r_prev, [1.0]]), h, spec.variant,
                           tab.prediction)
        tab.w_corr_prev[i - 1, : len(wc) - 1] = wc[:-1]
        tab.w_corr_new[i - 1] = wc[-1]
        last = i == M
        tab.use_corrector[i - 1] = 1.0 if (not last or spec.corrector_at_last) else 0.0
    return tab


def _loop_corrector(spec: EngineSpec):
    if not spec.use_corrector:
        return None
    return CorrectorConfig(order=spec.corrector_order, variant=spec.variant,
                           at_last_step=spec.corrector_at_last)


def _grid(spec: EngineSpec, noise_schedule, steps: int) -> Grid:
    return Grid.build(noise_schedule, steps, spec.spacing)


def _with_solver(s, sample_fn):
    """Expose the GridSolver on the loop closure so callers can read the
    measured NFE (`fn.solver.model.nfe`) after a run."""
    sample_fn.solver = s
    return sample_fn


# ---------------------------------------------------------------------------
# UniPC — the native table (delegates to core.coeffs)
# ---------------------------------------------------------------------------


def _compile_unipc(spec: EngineSpec, noise_schedule) -> SolverTable:
    t, lam, alpha, sigma = timestep_grid(noise_schedule, spec.nfe, spec.spacing)
    return build_unipc_schedule(
        lambdas=lam, alphas=alpha, sigmas=sigma, timesteps=t,
        order=spec.order, prediction=spec.prediction, variant=spec.variant,
        use_corrector=spec.use_corrector,
        corrector_at_last=spec.corrector_at_last,
        lower_order_final=spec.lower_order_final,
    )


def _loop_unipc(spec: EngineSpec, noise_schedule, model_fn):
    s = UniPC(model_fn, _grid(spec, noise_schedule, spec.nfe),
              order=spec.order, prediction=spec.prediction,
              variant=spec.variant, lower_order_final=spec.lower_order_final)
    return _with_solver(
        s, lambda x_T: s.sample_pc(x_T, use_corrector=spec.use_corrector))


register(SolverDef(
    name="unipc", prediction="data", fixed_prediction=False,
    compile=_compile_unipc, loop=_loop_unipc, corrector_default=True))


# ---------------------------------------------------------------------------
# DDIM — the semilinear base alone (== UniP-1)
# ---------------------------------------------------------------------------


def _compile_ddim(spec: EngineSpec, noise_schedule) -> SolverTable:
    tab = _empty_table(spec, noise_schedule, spec.nfe, 1, spec.prediction)
    lam, alpha, sigma = tab.lambdas, tab.alphas, tab.sigmas
    for i in range(1, spec.nfe + 1):
        h = float(lam[i] - lam[i - 1])
        tab.base_x[i - 1], tab.base_m0[i - 1] = semilinear_coeffs(
            h, alpha[i - 1], alpha[i], sigma[i - 1], sigma[i], spec.prediction)
        tab.orders.append(1)
    if spec.use_corrector:
        _apply_unic(tab, spec)
    return tab


def _loop_ddim(spec: EngineSpec, noise_schedule, model_fn):
    s = DDIM(model_fn, _grid(spec, noise_schedule, spec.nfe),
             prediction=spec.prediction)
    return _with_solver(
        s, lambda x_T: s.sample(x_T, corrector=_loop_corrector(spec)))


register(SolverDef(
    name="ddim", prediction="noise", fixed_prediction=False,
    compile=_compile_ddim, loop=_loop_ddim,
    default_corrector_order=lambda spec: 1))


# ---------------------------------------------------------------------------
# DPM-Solver++ 1M/2M/3M — D1/D2 combinations re-based onto D_m = E[m] − E[0]
# ---------------------------------------------------------------------------


def _compile_dpmpp(spec: EngineSpec, noise_schedule) -> SolverTable:
    order = spec.order
    if order not in (1, 2, 3):
        raise ValueError("DPM-Solver++ multistep supports orders 1-3, got "
                         f"order={order}")
    M = spec.nfe
    tab = _empty_table(spec, noise_schedule, M, max(1, order - 1), "data")
    lam, alpha, sigma = tab.lambdas, tab.alphas, tab.sigmas
    for i in range(1, M + 1):
        p = min(order, i)
        if spec.lower_order_final:
            p = min(p, M - i + 1)
        h = float(lam[i] - lam[i - 1])
        a_t = alpha[i]
        phi_1 = math.expm1(-h)
        tab.base_x[i - 1] = sigma[i] / sigma[i - 1]
        tab.base_m0[i - 1] = -a_t * phi_1
        tab.orders.append(max(1, p))
        if p >= 2:
            r0 = float(lam[i - 1] - lam[i - 2]) / h
            if p == 2:
                # −0.5·a_t·φ1·D1_0 with D1_0 = (m0−m1)/r0 = −D_1/r0
                tab.w_pred[i - 1, 0] = 0.5 * phi_1 / r0
            else:
                r1 = float(lam[i - 2] - lam[i - 3]) / h
                c0 = r0 / (r0 + r1)
                # D1 = (1+c0)·D1_0 − c0·D1_1; D2 = (D1_0 − D1_1)/(r0+r1),
                # with D1_0 = −D_1/r0 and D1_1 = (D_1 − D_2)/r1
                d1 = np.array([-(1.0 + c0) / r0 - c0 / r1, c0 / r1])
                d2 = np.array([(-1.0 / r0 - 1.0 / r1) / (r0 + r1),
                               1.0 / (r1 * (r0 + r1))])
                phi_2 = phi_1 / h + 1.0
                phi_3 = phi_2 / h - 0.5
                tab.w_pred[i - 1, :2] = phi_2 * d1 - phi_3 * d2
    if spec.use_corrector:
        _apply_unic(tab, spec)
    return tab


def _loop_dpmpp(spec: EngineSpec, noise_schedule, model_fn):
    s = DPMSolverPP(model_fn, _grid(spec, noise_schedule, spec.nfe),
                    order=spec.order,
                    lower_order_final=spec.lower_order_final)
    return _with_solver(
        s, lambda x_T: s.sample(x_T, corrector=_loop_corrector(spec)))


register(SolverDef(
    name="dpmpp", prediction="data",
    compile=_compile_dpmpp, loop=_loop_dpmpp))


# ---------------------------------------------------------------------------
# PLMS / Adams-Bashforth (PNDM) — AB ladder folded into the weight rows
# ---------------------------------------------------------------------------


def _compile_plms(spec: EngineSpec, noise_schedule) -> SolverTable:
    M = spec.nfe
    tab = _empty_table(spec, noise_schedule, M, 3, "noise")
    lam, alpha, sigma = tab.lambdas, tab.alphas, tab.sigmas
    for i in range(1, M + 1):
        h = float(lam[i] - lam[i - 1])
        n = min(i, 4)
        ab = PLMS_AB[n]
        tab.base_x[i - 1] = alpha[i] / alpha[i - 1]
        tab.base_m0[i - 1] = -sigma[i] * math.expm1(h)
        # e_AB = m0 + Σ_{j≥1} ab[j]·D_j  (Σ ab = 1), through the DDIM map:
        # −σ_t·expm1(h)·ab[j] on D_j, i.e. w_j = expm1(h)·ab[j] under sign=−1
        tab.w_pred[i - 1, : n - 1] = math.expm1(h) * ab[1:]
        tab.orders.append(n)
    if spec.use_corrector:
        _apply_unic(tab, spec)
    return tab


def _loop_plms(spec: EngineSpec, noise_schedule, model_fn):
    s = PNDM(model_fn, _grid(spec, noise_schedule, spec.nfe))
    return _with_solver(
        s, lambda x_T: s.sample(x_T, corrector=_loop_corrector(spec)))


register(SolverDef(
    name="pndm", prediction="noise",
    compile=_compile_plms, loop=_loop_plms,
    default_corrector_order=lambda spec: PNDM.order))


# ---------------------------------------------------------------------------
# DEIS tAB-k — quadrature weights on raw evals become base_m0 + diff weights
# ---------------------------------------------------------------------------


def _compile_deis(spec: EngineSpec, noise_schedule,
                  quad_points: int = 64) -> SolverTable:
    order = spec.order
    M = spec.nfe
    tab = _empty_table(spec, noise_schedule, M, max(1, order - 1), "noise")
    t, alpha, sigma = tab.timesteps, tab.alphas, tab.sigmas
    for i in range(1, M + 1):
        k = min(order, i)
        ts_prev = [float(t[i - 1 - m]) for m in range(k)]  # newest first
        ws = deis_quad_weights(noise_schedule, float(t[i - 1]), float(t[i]),
                               float(alpha[i]), ts_prev, quad_points)
        tab.base_x[i - 1] = alpha[i] / alpha[i - 1]
        # Σ_j w_j e_j = (Σ w_j)·m0 + Σ_{j≥1} w_j·D_j; scan adds −σ_t·w on D_j
        tab.base_m0[i - 1] = float(np.sum(ws))
        tab.w_pred[i - 1, : k - 1] = -np.asarray(ws[1:]) / sigma[i]
        tab.orders.append(k)
    if spec.use_corrector:
        _apply_unic(tab, spec)
    return tab


def _loop_deis(spec: EngineSpec, noise_schedule, model_fn):
    s = DEIS(model_fn, _grid(spec, noise_schedule, spec.nfe), noise_schedule,
             order=spec.order)
    return _with_solver(
        s, lambda x_T: s.sample(x_T, corrector=_loop_corrector(spec)))


register(SolverDef(
    name="deis", prediction="noise",
    compile=_compile_deis, loop=_loop_deis))


# ---------------------------------------------------------------------------
# DPM-Solver 2S/3S — singlestep, compiled onto an expanded grid
# ---------------------------------------------------------------------------
#
# Each grid step [s → t] becomes `order` scan rows, one per intermediate
# point. The scan carries the *latest intermediate state*, so each row's
# update is re-based: substitute x = inverse-transfer(carry) into the
# original formula (exact, linear). At row k the eval ring holds exactly
# [m_{k-1}, ..., m_s]: the intermediates the singlestep formulas combine.


def _dpm_singlestep_rows(h, r_inner, aa, ss, prediction):
    """Per-substep (base_x, base_m0, w[]) for one grid step.

    aa/ss: [a_s, a_1, (a_2), a_t] / [s_s, s_1, (s_2), s_t] at the anchor,
    intermediate(s), and target. Mirrors `DPMSolverSinglestep.predict`.
    """
    noise = prediction == "noise"
    sgn = 1.0 if noise else -1.0      # expm1 argument sign: +h noise, −h data
    # role swap: noise scales differences by σ (sign −1), data by α (sign +1)
    A = aa if noise else ss           # semilinear ratio numerators
    S = ss if noise else aa           # difference/output scales
    rows = []
    if len(r_inner) == 1:             # order 2
        r1 = r_inner[0]
        phi_11 = math.expm1(sgn * r1 * h)
        phi_1 = math.expm1(sgn * h)
        a_s, a_1, a_t = A
        s_s, s_1, s_t = S
        rows.append((a_1 / a_s, -s_1 * phi_11, []))
        c_m1 = -(s_t / (2 * r1)) * phi_1
        c_ms = (a_t / a_1) * s_1 * phi_11 - s_t * phi_1 + (s_t / (2 * r1)) * phi_1
        rows.append((a_t / a_1, c_m1 + c_ms, [c_ms]))
        return rows
    r1, r2 = r_inner                  # order 3
    phi_11 = math.expm1(sgn * r1 * h)
    phi_12 = math.expm1(sgn * r2 * h)
    phi_1 = math.expm1(sgn * h)
    phi_22 = math.expm1(sgn * r2 * h) / (r2 * h) - sgn
    phi_2 = phi_1 / h - sgn
    a_s, a_1, a_2, a_t = A
    s_s, s_1, s_2, s_t = S
    rows.append((a_1 / a_s, -s_1 * phi_11, []))
    # x2 = (a2/a_s)x − s2·φ12·m_s − sgn·(r2/r1)·s2·φ22·(m1 − m_s), re-based on x1
    g22 = sgn * (r2 / r1) * s_2 * phi_22
    c_m1 = -g22
    c_ms = (a_2 / a_1) * s_1 * phi_11 - s_2 * phi_12 + g22
    rows.append((a_2 / a_1, c_m1 + c_ms, [c_ms]))
    # x_t = (a_t/a_s)x − s_t·φ1·m_s − sgn·(1/r2)·s_t·φ2·(m2 − m_s), re-based on x2
    g2 = sgn * (1.0 / r2) * s_t * phi_2
    c_m2 = -g2
    c_m1 = (a_t / a_2) * g22
    c_ms = (a_t / a_2) * (s_2 * phi_12 - g22) - s_t * phi_1 + g2
    rows.append((a_t / a_2, c_m2 + c_m1 + c_ms, [c_m1, c_ms]))
    return rows


def _compile_dpm_singlestep(spec: EngineSpec, noise_schedule) -> SolverTable:
    order = spec.order
    if order not in (2, 3):
        raise ValueError("DPM-Solver singlestep supports orders 2 and 3, "
                         f"got order={order}")
    prediction = spec.prediction
    G = max(1, spec.nfe // order)
    t, lam, alpha, sigma = timestep_grid(noise_schedule, G, spec.spacing)
    r_inner = [0.5] if order == 2 else [1.0 / 3.0, 2.0 / 3.0]
    # expanded point sequence: anchor, then every intermediate + grid target
    ts, lams, alphas, sigmas = [t[0]], [lam[0]], [alpha[0]], [sigma[0]]
    S = G * order
    K = order - 1
    tab_rows = []
    for i in range(1, G + 1):
        h = float(lam[i] - lam[i - 1])
        pts_a, pts_s, pts_t, pts_l = [alpha[i - 1]], [sigma[i - 1]], [], []
        for r in r_inner:
            lam_m = float(lam[i - 1] + r * h)
            t_m = float(noise_schedule.t_of_lam(lam_m))
            pts_t.append(t_m)
            pts_l.append(lam_m)
            pts_a.append(float(noise_schedule.alpha(t_m)))
            pts_s.append(float(noise_schedule.sigma(t_m)))
        pts_a.append(alpha[i])
        pts_s.append(sigma[i])
        pts_t.append(float(t[i]))
        pts_l.append(float(lam[i]))
        ts.extend(pts_t)
        lams.extend(pts_l)
        alphas.extend(pts_a[1:])
        sigmas.extend(pts_s[1:])
        rows = _dpm_singlestep_rows(h, r_inner, pts_a, pts_s, prediction)
        # difference weights carry out_scale at each row's own target point
        scales = pts_s[1:] if prediction == "noise" else pts_a[1:]
        sign = -1.0 if prediction == "noise" else 1.0
        for (bx, bm, cs), sc in zip(rows, scales):
            w = np.zeros(max(1, K))
            w[: len(cs)] = sign * np.asarray(cs) / sc if cs else []
            tab_rows.append((bx, bm, w, sc))
    base_x = np.array([r[0] for r in tab_rows])
    base_m0 = np.array([r[1] for r in tab_rows])
    w_pred = np.stack([r[2] for r in tab_rows])
    out_scale = np.array([r[3] for r in tab_rows])
    return SolverTable(
        lambdas=np.asarray(lams), alphas=np.asarray(alphas),
        sigmas=np.asarray(sigmas), order=order, prediction=prediction,
        variant=spec.variant,
        base_x=base_x, base_m0=base_m0, w_pred=w_pred,
        w_corr_prev=np.zeros_like(w_pred), w_corr_new=np.zeros(S),
        use_corrector=np.zeros(S), out_scale=out_scale,
        sign=-1.0 if prediction == "noise" else 1.0,
        timesteps=np.asarray(ts), orders=[order] * G,
    )


def _loop_dpm_singlestep(spec: EngineSpec, noise_schedule, model_fn):
    G = max(1, spec.nfe // spec.order)
    s = DPMSolverSinglestep(model_fn, _grid(spec, noise_schedule, G),
                            noise_schedule, order=spec.order,
                            prediction=spec.prediction)
    return _with_solver(s, lambda x_T: s.sample(x_T))


register(SolverDef(
    name="dpm", prediction="noise", fixed_prediction=False, singlestep=True,
    compile=_compile_dpm_singlestep, loop=_loop_dpm_singlestep))


# --------------------------------------------------------------------------
# Flight done-mask contract (DESIGN.md §16).
#
# `StepProgram.step_flight` reports per-slot completion as an int32 *code*,
# not a boolean: the extra state distinguishes a slot that finished with a
# usable latent from one whose latent went non-finite somewhere in the
# stacked approximation layers (bf16 eval, quantized matmuls, cache reuse,
# aggressive low-NFE plans). The finiteness reduction runs on device inside
# the compiled step — one elementwise pass fused by XLA, negligible next to
# the denoiser eval — so validation costs the host nothing and survives
# `python -O` (it is program output, not an assert). The serving scheduler
# treats any nonzero code as "done" and routes DONE_NONFINITE completions
# into the degraded-tier retry path (serving/resilience.py).

DONE_IDLE = 0        # slot not finishing this tick (idle or mid-flight)
DONE_OK = 1          # slot finished; latent is finite
DONE_NONFINITE = 2   # slot finished; latent contains NaN/Inf


def finite_slots(x):
    """Per-slot finiteness mask for a (B, ...) latent batch: True where
    every element of slot b is finite. Traced inside `step_flight`."""
    import jax.numpy as jnp

    return jnp.isfinite(x).reshape(x.shape[0], -1).all(axis=1)


def flag_done(done, x):
    """Fold the per-slot finite check into a boolean done mask, producing
    the coded int32 mask `step_flight` returns (DONE_* above)."""
    import jax.numpy as jnp

    ok = finite_slots(x)
    return jnp.where(done, jnp.where(ok, DONE_OK, DONE_NONFINITE),
                     DONE_IDLE).astype(jnp.int32)
