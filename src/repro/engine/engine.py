"""SamplerEngine: spec → weight table → jitted scan, with fused CFG serving.

The single entry point every launcher and benchmark builds on:

    engine = SamplerEngine(schedule, eps=eps_fn,
                           eps_stacked=stacked_fn,    # for cfg_scale != 0
                           eps_uncond=uncond_fn)      # for the loop reference
    run = engine.build(EngineSpec(solver="dpmpp", order=2, nfe=10,
                                  cfg_scale=2.0, thresholding=True))
    x0 = run(x_T)

`build` compiles the solver's weight table (registry-driven — see
`compiler.py`), wraps the eps-network into the table's prediction type, and
jits one `unipc_sample_scan` over the result. Conditional sampling (the
paper's Table 9 setting) is fused into that same scan:

* **CFG** runs as ONE batched network call per step — cond and uncond stacked
  along the batch (`cfg_model_fused`) instead of `cfg_model`'s two sequential
  evals — with the guidance scale (possibly a schedule) riding the table as
  a per-eval column.
* **Dynamic thresholding** percentiles are likewise a per-eval table column,
  applied to the x0-prediction inside the model wrapper.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from ..core.coeffs import SolverTable
from ..core.unipc import unipc_sample_scan
from ..diffusion.guidance import (cfg_model, cfg_model_fused,
                                  dynamic_threshold, guidance_schedule)
from ..diffusion.process import eps_to_x0
from ..diffusion.schedules import NoiseSchedule
from .compiler import build_loop, compile_table
from .specs import EngineSpec, SOLVERS


@dataclass
class SamplerEngine:
    """Solver-agnostic sampling engine over one eps-network.

    eps:         (x, t) -> eps-hat, conditioning captured (the cond branch).
    eps_stacked: (xx, t) -> eps-hat on a 2B batch whose conditioning is
                 [cond; null] — required for cfg_scale != 0 (fused CFG).
    eps_uncond:  (x, t) -> eps-hat with null conditioning — only needed for
                 `build_loop`'s reference path (sequential, two evals/step).
    """

    schedule: NoiseSchedule
    eps: Callable
    eps_stacked: Optional[Callable] = None
    eps_uncond: Optional[Callable] = None

    # -- table ---------------------------------------------------------------
    def compile(self, spec: EngineSpec) -> SolverTable:
        spec = spec.resolve()
        tab = compile_table(spec, self.schedule)
        n_evals = len(tab.timesteps)
        cols = dict(tab.model_cols or {})
        if spec.cfg_scale:
            cols["g"] = guidance_schedule(spec.cfg_scale, n_evals,
                                          spec.cfg_schedule,
                                          spec.cfg_scale_end)
        if spec.thresholding:
            if tab.prediction != "data":
                raise ValueError("dynamic thresholding clips the x0 "
                                 "prediction; use a data-prediction solver")
            cols["tq"] = guidance_schedule(spec.threshold_percentile, n_evals)
        tab.model_cols = cols
        return tab

    # -- model ---------------------------------------------------------------
    def model_fn(self, spec: EngineSpec, tab: SolverTable) -> Callable:
        """Wrap the eps-net into the table's prediction type, consuming the
        per-eval model columns the table carries (g, tq)."""
        spec = spec.resolve()
        if spec.cfg_scale:
            if self.eps_stacked is None:
                raise ValueError("cfg_scale != 0 needs eps_stacked (a 2B "
                                 "cond+uncond batched eps-net)")
            eps = cfg_model_fused(self.eps_stacked)   # (x, t, g)
        else:
            eps = lambda x, t, g=None: self.eps(x, t)

        schedule = self.schedule

        def model(x, t, g=None, tq=None):
            e = eps(x, t, g)
            if tab.prediction == "noise":
                return e
            x0 = eps_to_x0(schedule, x, t, e)
            if tq is not None:
                x0 = dynamic_threshold(x0, tq)
            return x0

        return model

    # -- run functions -------------------------------------------------------
    def build(self, spec: EngineSpec, jit: bool = True,
              table: Optional[SolverTable] = None) -> Callable:
        """spec -> run_fn(x_T) -> x0: the scan-compiled production path.
        Pass `table` (from a prior `compile`) to skip recompiling it."""
        spec = spec.resolve()
        tab = table if table is not None else self.compile(spec)
        model = self.model_fn(spec, tab)
        run = lambda x_T: unipc_sample_scan(model, x_T, tab,
                                            fused_update=spec.fused_update)
        return jax.jit(run) if jit else run

    def build_loop(self, spec: EngineSpec) -> Callable:
        """The python-loop GridSolver reference for the same spec — identical
        math on the same grid, sequential CFG (two evals per step)."""
        spec = spec.resolve()
        if spec.cfg_scale and spec.cfg_schedule != "constant":
            raise ValueError("loop reference supports constant cfg only")
        eps = self.eps
        if spec.cfg_scale:
            if self.eps_uncond is None:
                raise ValueError("loop reference with cfg needs eps_uncond")
            eps = cfg_model(self.eps, self.eps_uncond, spec.cfg_scale)
        schedule = self.schedule
        if spec.prediction == "noise":
            if spec.thresholding:
                raise ValueError("thresholding needs a data-prediction solver")
            model = eps
        else:
            def model(x, t):
                x0 = eps_to_x0(schedule, x, t, eps(x, t))
                if spec.thresholding:
                    x0 = dynamic_threshold(x0, spec.threshold_percentile)
                return x0
        return build_loop(spec, self.schedule, model)

    @staticmethod
    def solvers():
        """Registered solver names (the --solver choices everywhere)."""
        return sorted(SOLVERS)
