"""SamplerEngine: spec → weight table → jitted scan, with fused CFG serving.

The single entry point every launcher and benchmark builds on:

    engine = SamplerEngine(schedule, eps=eps_fn,
                           eps_stacked=stacked_fn,    # for cfg_scale != 0
                           eps_uncond=uncond_fn)      # for the loop reference
    run = engine.build(EngineSpec(solver="dpmpp", order=2, nfe=10,
                                  cfg_scale=2.0, thresholding=True))
    x0 = run(x_T)

`build` is the whole-trajectory path (one uniform batch, one scan);
`build_step` compiles the same table into a per-slot `StepProgram` — the
continuous-batching step function `repro.serving`'s scheduler drives, where
every slot gathers its own table row and guidance scale (DESIGN.md §9).

`build` compiles the solver's weight table (registry-driven — see
`compiler.py`), wraps the eps-network into the table's prediction type, and
jits one `unipc_sample_scan` over the result. Conditional sampling (the
paper's Table 9 setting) is fused into that same scan:

* **CFG** runs as ONE batched network call per step — cond and uncond stacked
  along the batch (`cfg_model_fused`) instead of `cfg_model`'s two sequential
  evals — with the guidance scale (possibly a schedule) riding the table as
  a per-eval column.
* **Dynamic thresholding** percentiles are likewise a per-eval table column,
  applied to the x0-prediction inside the model wrapper.
"""

from __future__ import annotations

from dataclasses import dataclass, replace as dc_replace
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.coeffs import SolverTable, eval_cost_rows, stack_step_rows
from ..core.unipc import step_fn_over_rows, unipc_sample_scan
from ..diffusion.guidance import cfg_model, cfg_model_fused, dynamic_threshold
from ..diffusion.process import eps_to_x0
from ..diffusion.schedules import NoiseSchedule
from ..parallel.sharding import shard
from .compiler import (apply_model_cols, build_loop, compile_table,
                       flag_done, step_guidance_profile)
from .specs import EngineSpec, SOLVERS


@dataclass(frozen=True)
class CacheSpec:
    """Shape contract for the feature-reuse cache (DESIGN.md §12).

    `shape` is the per-sample cache layout ((patch_tokens, d_model) for the
    DiT's deep-feature delta), `block` the static boundary the wired cached
    eps-net was built with (first `block` of `n_blocks` blocks recompute on
    shallow evals). The engine validates every spec's `cache_block` against
    `block` the same way `eval_dtype` is handshaken — the net-side closure
    and the engine-side state cannot silently disagree.
    """

    shape: Tuple[int, ...]
    block: int
    n_blocks: int
    dtype: str = "float32"

    def zeros(self, slots: int):
        return jnp.zeros((slots,) + tuple(self.shape), jnp.dtype(self.dtype))


@dataclass
class StepProgram:
    """A compiled per-slot step program — what the serving scheduler drives.

    step(state, idx[, g]) -> state advances every slot by one table row:
    `state = (x, E)` with x (B, *sample) and E the (K+1, B, *sample) eval
    ring — or `(x, E, C)` for feature-reuse programs, C the (B, *cache)
    deep-feature cache that must live (and be donated) with the rest of the
    slot state (DESIGN.md §12) — `idx` (B,) int32 the per-slot row index
    (0 = init row; idle slots park there), and `g` (B,) float32 the per-slot
    guidance scale (only for cfg-enabled programs). Slot batches are sharded
    over the data axis via the active `parallel.sharding` rules (SERVE_RULES
    on the mesh; a no-op single-device), so the same tick loop runs
    everywhere. One batched model eval per call — a request admitted at tick
    tau and stepped through rows 0..n_rows-1 reproduces the uniform
    `build()` scan for its own (solver, order, nfe, seed, cfg-scale)
    exactly.

    step_flight(state, meta[, g, extras]) -> (state, meta, done) is the
    async-serving variant (DESIGN.md §13): the per-slot bookkeeping lives
    on device as `meta`, a (4, B) int32 array of [row, offset, budget, busy]
    rows (`init_meta`). The program derives each slot's table index from its
    own counters (`offset + row` while busy, the parked init row otherwise),
    advances them, and emits the per-slot `done` mask — the tick a busy slot
    executes its last budgeted row. The mask is a coded int32 per slot
    (`compiler.DONE_IDLE` / `DONE_OK` / `DONE_NONFINITE`): completion folds
    an on-device finite-check of the slot's latent, so the serving layer
    learns at emission — not from a host-side scan — whether the request's
    output is usable (DESIGN.md §16). The host never rebuilds `idx`: it only
    scatters admissions into `meta` and reads the tiny done mask back, which
    is what lets the serving scheduler keep several ticks in flight.
    """

    step: Callable
    n_rows: int          # total table rows (single plan: ticks per request)
    table: SolverTable   # single-plan programs; first tier's table for banks
    spec: EngineSpec
    uses_cfg: bool
    ring: int            # eval-ring slots carried per sample, K + 1
    # plan banks (`SamplerEngine.build_bank`): tier name -> (row_offset,
    # n_rows) span in the stacked table. None for single-plan programs.
    tiers: Optional[Dict[str, Tuple[int, int]]] = None
    # feature reuse: the cache contract (None for uncached programs) and the
    # per-row eval cost (n_rows,) in fractions of a full denoiser eval —
    # 1.0 everywhere without caching, cache_block/n_blocks on reuse rows.
    cache: Optional[CacheSpec] = None
    row_cost: Optional[np.ndarray] = None
    # the on-device-bookkeeping step (same compiled math as `step`, plus the
    # meta counters and done mask); always built by `_step_program`
    step_flight: Optional[Callable] = None

    def resolve_tier(self, tier: Optional[str]) -> Tuple[int, int]:
        """(row_offset, rows_to_run) for a request's tier tag. Single-plan
        programs take untagged requests only; bank programs require a tag."""
        if self.tiers is None:
            if tier is not None:
                raise ValueError(
                    f"request tagged tier={tier!r} but the step program was "
                    f"compiled from a single plan; build it with "
                    f"SamplerEngine.build_bank")
            return 0, self.n_rows
        if tier is None:
            raise ValueError(f"this program is a plan bank; tag requests "
                             f"with tier= one of {sorted(self.tiers)}")
        if tier not in self.tiers:
            raise ValueError(f"unknown tier {tier!r}; this plan bank serves "
                             f"{sorted(self.tiers)}")
        return self.tiers[tier]

    def init_state(self, slots: int, sample_shape: Tuple[int, ...],
                   dtype=jnp.float32):
        """Zeroed slot state: every slot idle on the init row. Cached
        programs carry the feature cache as a third state array."""
        shape = tuple(sample_shape)
        state = (jnp.zeros((slots,) + shape, dtype),
                 jnp.zeros((self.ring, slots) + shape, dtype))
        if self.cache is not None:
            state = state + (self.cache.zeros(slots),)
        return state

    def span_cost(self, offset: int, n: int) -> float:
        """Total eval cost (full-eval units) of rows offset..offset+n-1 —
        a request's evals-per-latent when (offset, n) is its tier span."""
        if self.row_cost is None:
            return float(n)
        return float(np.sum(self.row_cost[offset:offset + n]))

    def tier_eval_cost(self, tier: Optional[str]) -> float:
        """Evals-per-latent for a tier tag (or the whole single-plan span)."""
        return self.span_cost(*self.resolve_tier(tier))

    def init_g(self, slots: int):
        """Per-slot guidance scales, seeded with the spec's nominal scale."""
        return jnp.full((slots,), float(self.spec.cfg_scale or 0.0),
                        jnp.float32)

    def init_meta(self, slots: int):
        """Zeroed on-device slot counters for `step_flight`: a (4, slots)
        int32 array of [row, offset, budget, busy] rows. Every slot starts
        idle (busy = 0, parked on the init row); budget is seeded with the
        full table so an un-admitted slot can never trip the done mask."""
        meta = np.zeros((4, slots), np.int32)
        meta[2] = self.n_rows
        return jnp.asarray(meta)


@dataclass
class SamplerEngine:
    """Solver-agnostic sampling engine over one eps-network.

    eps:         (x, t) -> eps-hat, conditioning captured (the cond branch).
    eps_stacked: (xx, t) -> eps-hat on a 2B batch whose conditioning is
                 [cond; null] — required for cfg_scale != 0 (fused CFG).
    eps_uncond:  (x, t) -> eps-hat with null conditioning — only needed for
                 `build_loop`'s reference path (sequential, two evals/step).
    eps_cached:  (x, t, cache, reuse) -> (eps-hat, cache') — the feature-reuse
                 eval (DESIGN.md §12), wired by
                 `launch.sample.build_engine(cache_block=...)` together with
                 `cache_spec`; only dit-family models support it.
    eval_dtype:  the precision the wired eps-net actually computes in —
                 `launch.sample.build_engine(eval_dtype=...)` sets it when
                 it casts the net; `model_fn` rejects specs that disagree,
                 so the net-side cast and the engine-side fp32 boundary
                 (DESIGN.md §11.3) cannot silently desynchronize.
    cache_spec:  the cache-state contract matching `eps_cached`; its `block`
                 is handshaken against every spec's `cache_block` exactly
                 like `eval_dtype`.
    """

    schedule: NoiseSchedule
    eps: Callable
    eps_stacked: Optional[Callable] = None
    eps_uncond: Optional[Callable] = None
    eval_dtype: str = "float32"
    eps_cached: Optional[Callable] = None
    cache_spec: Optional["CacheSpec"] = None
    # quantized-tier contract (DESIGN.md §14), handshaken like eval_dtype:
    # "none" or the models.quant tier the wired eps-net's params were
    # quantized for (`launch.sample.build_engine(quant=...)` sets it)
    quant: str = "none"

    # -- table ---------------------------------------------------------------
    def compile(self, spec: EngineSpec,
                table: Optional[SolverTable] = None) -> SolverTable:
        """Compile the spec's weight table and attach its per-eval model
        columns (guidance schedule, thresholding percentile). Pass `table`
        to skip the registry compiler and use an externally lowered table —
        a tuned `SolverPlan` — with the same conditioning knobs applied."""
        spec = spec.resolve()
        tab = table if table is not None else compile_table(spec, self.schedule)
        return apply_model_cols(tab, spec)

    # -- model ---------------------------------------------------------------
    def model_fn(self, spec: EngineSpec, tab: SolverTable) -> Callable:
        """Wrap the eps-net into the table's prediction type, consuming the
        per-eval model columns the table carries (g, tq). Any further keyword
        arguments (per-slot conditioning from a StepProgram's extras, e.g.
        class ids) pass through to the eps-net.

        `spec.eval_dtype` is the network-eval precision boundary (DESIGN.md
        §11): the state is cast down on the way into the eps-net and the
        prediction cast back up, so solver state, combine weights, and the
        eps↔x0 conversion stay fp32 whatever the network runs in. (For the
        network itself to *compute* in bf16 the model config's activation
        dtype must match — `launch.sample.build_engine(eval_dtype=...)`
        wires both ends.)"""
        spec = spec.resolve()
        if spec.eval_dtype != self.eval_dtype:
            raise ValueError(
                f"spec.eval_dtype={spec.eval_dtype!r} but this engine's "
                f"eps-net was wired for {self.eval_dtype!r}; pass the same "
                f"eval_dtype to build_engine and the EngineSpec")
        if spec.quant != self.quant:
            raise ValueError(
                f"spec.quant={spec.quant!r} but this engine's eps-net was "
                f"wired for {self.quant!r}; the quantized param tree is "
                f"baked into the net — pass the same quant to build_engine "
                f"and the EngineSpec")
        if spec.cache_block:
            return self._cached_model_fn(spec, tab)
        if "cache_reuse" in (tab.model_cols or {}):
            raise ValueError(
                "this table carries a cache_reuse column (a cached plan) but "
                "spec.cache_block=0; build the engine and spec with the "
                "plan's cache_block so its shallow steps actually reuse the "
                "feature cache instead of silently paying full evals")
        if spec.cfg_scale:
            if self.eps_stacked is None:
                raise ValueError("cfg_scale != 0 needs eps_stacked (a 2B "
                                 "cond+uncond batched eps-net)")
            eps = cfg_model_fused(self.eps_stacked)   # (x, t, g, **extra)
        else:
            eps = lambda x, t, g=None, **extra: self.eps(x, t, **extra)

        schedule = self.schedule
        if spec.eval_dtype != "float32":
            # the precision boundary: state down-cast into the net, the
            # prediction back up to fp32 — only wrapped for reduced-precision
            # eval so the fp32 default (and the fp64 exactness tests) keep
            # the eps-net's native dtypes end to end
            eval_dtype = jnp.dtype(spec.eval_dtype)
            inner = eps
            eps = lambda x, t, g=None, **extra: inner(
                x.astype(eval_dtype), t, g, **extra).astype(jnp.float32)

        def model(x, t, g=None, tq=None, **extra):
            e = eps(x, t, g, **extra)
            if tab.prediction == "noise":
                return e
            x0 = eps_to_x0(schedule, x, t, e)
            if tq is not None:
                x0 = dynamic_threshold(x0, tq)
            return x0

        return model

    def _cached_model_fn(self, spec: EngineSpec, tab: SolverTable) -> Callable:
        """The feature-reuse model wrapper: (x, t, cache=..., cache_reuse=...,
        tq=..., **extra) -> (prediction, cache'). `cache_reuse` arrives from
        the table's `cache_reuse` model column when the plan schedules
        shallow steps; a plain registry table has no such column and every
        eval runs full (reuse = 0) — the bit-identity parity path."""
        if self.eps_cached is None or self.cache_spec is None:
            raise ValueError(
                f"spec.cache_block={spec.cache_block} but this engine has no "
                f"cached eps-net; wire one with "
                f"build_engine(cache_block={spec.cache_block})")
        if spec.cache_block != self.cache_spec.block:
            raise ValueError(
                f"spec.cache_block={spec.cache_block} but the engine's "
                f"cached eps-net was wired for cache boundary "
                f"{self.cache_spec.block}; the boundary is baked into the "
                f"compiled program — pass the same cache_block to "
                f"build_engine and the EngineSpec")
        eps_cached = self.eps_cached
        schedule = self.schedule
        if spec.eval_dtype != "float32":
            eval_dtype = jnp.dtype(spec.eval_dtype)
            inner = eps_cached

            def eps_cached(x, t, cache, reuse, **extra):
                e, c = inner(x.astype(eval_dtype), t, cache, reuse, **extra)
                return e.astype(jnp.float32), c

        def model(x, t, cache, cache_reuse=None, tq=None, **extra):
            reuse = jnp.asarray(0.0 if cache_reuse is None else cache_reuse,
                                jnp.float32)
            e, cache = eps_cached(x, t, cache, reuse, **extra)
            if tab.prediction == "noise":
                return e, cache
            x0 = eps_to_x0(schedule, x, t, e)
            if tq is not None:
                x0 = dynamic_threshold(x0, tq)
            return x0, cache

        return model

    # -- run functions -------------------------------------------------------
    def build(self, spec: EngineSpec, jit: bool = True,
              table: Optional[SolverTable] = None) -> Callable:
        """spec -> run_fn(x_T) -> x0: the scan-compiled production path.
        Pass `table` (from a prior `compile`) to skip recompiling it."""
        spec = spec.resolve()
        tab = table if table is not None else self.compile(spec)
        model = self.model_fn(spec, tab)
        if spec.cache_block:
            cache_spec = self.cache_spec

            def run(x_T):
                return unipc_sample_scan(
                    model, x_T, tab, fused_update=spec.fused_update,
                    cache0=cache_spec.zeros(x_T.shape[0]))
        else:
            run = lambda x_T: unipc_sample_scan(
                model, x_T, tab, fused_update=spec.fused_update)
        return jax.jit(run) if jit else run

    def build_step(self, spec: EngineSpec, jit: bool = True,
                   table: Optional[SolverTable] = None,
                   donate: bool = True) -> StepProgram:
        """spec -> StepProgram: the per-slot step function for continuous
        batching (DESIGN.md §9). The same table rows `build` scans uniformly,
        gathered per slot; the guidance scale becomes per-slot state
        (multiplied by the table's schedule profile) so every request can
        carry its own cfg scale through one compiled program.

        `donate` (default on) donates the slot-state buffers (x, E) to the
        jitted step, so each tick's state update reuses the previous tick's
        HBM allocation instead of round-tripping a fresh one — the state is
        the whole slot batch plus the eval ring, the largest serving-resident
        tensors after the params. Callers must treat the passed-in state as
        consumed (the scheduler always does); `donate=False` keeps the
        allocating behavior for aliasing callers and the parity test."""
        spec = spec.resolve()
        tab = table if table is not None else self.compile(spec)
        return self._step_program({"_": (spec, tab)}, tiers=None, jit=jit,
                                  donate=donate)

    def build_bank(self, tier_specs: Dict[str, EngineSpec],
                   tables: Optional[Dict[str, SolverTable]] = None,
                   jit: bool = True, donate: bool = True) -> StepProgram:
        """Compile several plans into ONE servable step program (§10).

        tier_specs: {tier_name: EngineSpec} in serving-priority order; every
        tier may differ in solver / order / NFE budget (and tuned `tables`
        entries may replace the registry compile per tier), but all tiers
        must share prediction type and guidance configuration — the bank is
        one compiled program, one model wrapper, one eval ring. The stacked
        row table (`core.stack_step_rows`) gives each tier a contiguous row
        span; `StepProgram.tiers` maps tier -> (offset, n_rows) and the
        scheduler admits `Request(tier=...)` onto per-slot row offsets, so
        fast/balanced/quality requests coexist in one batch.
        """
        if not tier_specs:
            raise ValueError("build_bank needs at least one tier spec")
        stray = set(tables or {}) - set(tier_specs)
        if stray:
            raise ValueError(f"tables carry tiers {sorted(stray)} not in "
                             f"tier_specs {sorted(tier_specs)}; a typo'd "
                             f"key would silently serve the untuned "
                             f"registry table")
        items = {}
        for name, tspec in tier_specs.items():
            tspec = tspec.resolve()
            tab = (tables or {}).get(name)
            items[name] = (tspec, self.compile(tspec, table=tab))
        return self._step_program(items, tiers=True, jit=jit, donate=donate)

    def _step_program(self, items, tiers, jit, donate=True) -> StepProgram:
        """Shared lowering for build_step (single plan) and build_bank."""
        names = list(items)
        spec0, tab0 = items[names[0]]
        uses_cfg = bool(spec0.cfg_scale)
        cached = bool(spec0.cache_block)
        for name, (s, t) in items.items():
            if bool(s.cfg_scale) != uses_cfg or (
                    uses_cfg and float(s.cfg_scale) != float(spec0.cfg_scale)):
                raise ValueError(
                    f"bank tiers must share the nominal guidance scale; tier "
                    f"{name!r} has cfg_scale={s.cfg_scale}, expected "
                    f"{spec0.cfg_scale} (per-request scales stay free)")
            if s.fused_update != spec0.fused_update:
                raise ValueError("bank tiers must agree on fused_update")
            if s.eval_dtype != spec0.eval_dtype:
                raise ValueError("bank tiers must agree on eval_dtype (one "
                                 "compiled program, one model wrapper)")
            if s.quant != spec0.quant:
                raise ValueError(
                    f"bank tiers must agree on quant (one quantized param "
                    f"tree serves the whole program); tier {name!r} has "
                    f"quant={s.quant!r}, expected {spec0.quant!r}")
            if s.cache_block != spec0.cache_block:
                raise ValueError(
                    f"bank tiers must agree on cache_block (the boundary is "
                    f"static in the compiled eps-net); tier {name!r} has "
                    f"cache_block={s.cache_block}, expected "
                    f"{spec0.cache_block}")
            if not cached and "cache_reuse" in (t.model_cols or {}):
                raise ValueError(
                    f"tier {name!r} carries a cached plan (cache_reuse "
                    f"column) but the bank specs have cache_block=0; set "
                    f"cache_block on every tier spec (and the engine) to "
                    f"serve it")
        model = self.model_fn(spec0, tab0)
        profs, step_tabs = [], {}
        for name, (s, t) in items.items():
            if uses_cfg:
                # the scan's absolute g column is replaced by per-slot state
                # x schedule profile; the core step must not gather it
                profs.append(np.asarray(step_guidance_profile(t, s),
                                        np.float64))
                cols = {k: v for k, v in (t.model_cols or {}).items()
                        if k != "g"}
                t = dc_replace(t, model_cols=cols)
            if cached and "cache_reuse" not in (t.model_cols or {}):
                # a bank may mix cached plans with plain tiers: a tier
                # without a reuse schedule runs every eval full (all-zero
                # column), keeping the stacked tables' column sets equal
                cols = dict(t.model_cols or {})
                cols["cache_reuse"] = np.zeros(len(t.timesteps))
                t = dc_replace(t, model_cols=cols)
            step_tabs[name] = t
        rows_np, spans = stack_step_rows(step_tabs)
        n_rows = len(rows_np["t"])
        rows = {k: jnp.asarray(v, jnp.float32) for k, v in rows_np.items()}
        core_step = step_fn_over_rows(model, rows, sign=tab0.sign,
                                      fused_update=spec0.fused_update,
                                      cached=cached)
        prof = (jnp.asarray(np.concatenate(profs), jnp.float32)
                if uses_cfg else None)
        row_cost = (eval_cost_rows(rows_np, cache_block=spec0.cache_block,
                                   n_blocks=self.cache_spec.n_blocks)
                    if cached else None)

        def _shard_state(*state):
            x, E = state[:2]
            x = shard(x, "batch", *([None] * (x.ndim - 1)))
            E = shard(E, None, "batch", *([None] * (E.ndim - 2)))
            if len(state) == 2:
                return x, E
            C = state[2]
            return x, E, shard(C, "batch", *([None] * (C.ndim - 1)))

        def _apply(state, idx, g, extras):
            state = _shard_state(*state)
            kw = dict(extras) if extras else {}
            if uses_cfg:
                gs = (jnp.full(idx.shape, float(spec0.cfg_scale), jnp.float32)
                      if g is None else jnp.asarray(g, jnp.float32))
                kw["g"] = gs * prof[jnp.clip(idx, 0, n_rows - 1)]
            state = core_step(state, idx, model_kwargs=kw or None)
            return _shard_state(*state)

        def step(state, idx, g=None, extras=None):
            return _apply(state, idx, g, extras)

        def step_flight(state, meta, g=None, extras=None):
            # on-device bookkeeping (DESIGN.md §13): the slot's table index
            # is derived from its own counters, never shipped from the host
            row, off, budget, busy = meta
            live = busy > 0
            idx = jnp.where(live, off + row, 0).astype(jnp.int32)
            state = _apply(state, idx, g, extras)
            row = row + 1
            done = live & (row >= budget)
            live = live & ~done
            # finished / idle slots park back on the init row (idx 0, an
            # identity update) so the next tick leaves their latent intact
            # until the trailing readback collects it
            meta = jnp.stack([jnp.where(live, row, 0),
                              jnp.where(live, off, 0),
                              budget, live.astype(jnp.int32)])
            # the done mask carries the on-device output validation: a coded
            # int32 per slot (DONE_IDLE / DONE_OK / DONE_NONFINITE, see
            # compiler.flag_done) so a non-finite latent is flagged the tick
            # it finishes, inside the compiled step, at no host cost
            return state, meta, flag_done(done, state[0])

        if jit:
            # donate the slot state (arg 0): the tick's (x, E) update writes
            # into the previous tick's buffers instead of fresh HBM — safe
            # because every caller replaces its state reference with the
            # step's return value (bit-identity pinned in tests/test_serving).
            # For cached programs the feature cache C rides in the same
            # donated tuple: it is per-slot trajectory state exactly like the
            # eval ring, so it must live (and be recycled) with it. The
            # flight variant additionally donates the (tiny) meta counters,
            # which live and recycle with the state across in-flight ticks.
            if donate:
                step = jax.jit(step, donate_argnums=(0,))
                step_flight = jax.jit(step_flight, donate_argnums=(0, 1))
            else:
                step = jax.jit(step)
                step_flight = jax.jit(step_flight)
        return StepProgram(step=step, step_flight=step_flight, n_rows=n_rows,
                           table=tab0, spec=spec0, uses_cfg=uses_cfg,
                           ring=rows_np["w_pred"].shape[-1] + 1,
                           tiers=dict(spans) if tiers else None,
                           cache=self.cache_spec if cached else None,
                           row_cost=row_cost)

    def build_loop(self, spec: EngineSpec) -> Callable:
        """The python-loop GridSolver reference for the same spec — identical
        math on the same grid, sequential CFG (two evals per step)."""
        spec = spec.resolve()
        if spec.cfg_scale and spec.cfg_schedule != "constant":
            raise ValueError("loop reference supports constant cfg only")
        eps = self.eps
        if spec.cfg_scale:
            if self.eps_uncond is None:
                raise ValueError("loop reference with cfg needs eps_uncond")
            eps = cfg_model(self.eps, self.eps_uncond, spec.cfg_scale)
        schedule = self.schedule
        if spec.prediction == "noise":
            if spec.thresholding:
                raise ValueError("thresholding needs a data-prediction solver")
            model = eps
        else:
            def model(x, t):
                x0 = eps_to_x0(schedule, x, t, eps(x, t))
                if spec.thresholding:
                    x0 = dynamic_threshold(x0, spec.threshold_percentile)
                return x0
        return build_loop(spec, self.schedule, model)

    @staticmethod
    def solvers():
        """Registered solver names (the --solver choices everywhere)."""
        return sorted(SOLVERS)
