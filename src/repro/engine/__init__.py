"""Solver-agnostic sampling engine: compile the zoo to the fused scan path.

Importing this package populates the solver registry (`SOLVERS`) — each
entry pairs a per-step weight-table compiler with its python-loop reference.
"""

from .specs import (SOLVERS, EngineSpec, SolverDef, default_tier_specs,
                    solver_def)
from .compiler import (apply_model_cols, build_loop, compile_table,
                       step_guidance_profile)
from .engine import CacheSpec, SamplerEngine, StepProgram

__all__ = [
    "SOLVERS", "EngineSpec", "SolverDef", "solver_def", "default_tier_specs",
    "SamplerEngine", "StepProgram", "CacheSpec", "compile_table",
    "build_loop", "step_guidance_profile", "apply_model_cols",
]
