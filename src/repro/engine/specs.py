"""Engine specs and the solver registry.

The engine's view (after Liu et al. 2023's unified-framework reading of the
paper): every multistep solver is a per-step *weight table* over one shared
state update — a semilinear base plus weighted model-output differences —
so the whole zoo compiles to the same `lax.scan` + fused-kernel path that
`unipc_sample_scan` runs. A `SolverDef` is the pairing of that compiler with
its python-loop reference (the `GridSolver` subclass the tests and benches
compare against); `EngineSpec` is the user-facing configuration every entry
point (`launch/sample.py`, `launch/serve.py`, `benchmarks/`) passes to
`SamplerEngine.build`.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Dict, Optional

SOLVERS: Dict[str, "SolverDef"] = {}


@dataclass(frozen=True)
class EngineSpec:
    """Everything `SamplerEngine.build` needs to produce a jitted run_fn."""

    solver: str = "unipc"
    nfe: int = 10
    order: int = 3
    prediction: Optional[str] = None   # None -> the solver's default
    variant: str = "bh2"               # B(h) variant (UniPC / UniC rows)
    spacing: str = "logsnr"
    lower_order_final: bool = True
    # corrector: UniPC's own, or the method-agnostic UniC bolt-on (Table 2)
    # for any other multistep solver. None -> solver default (on for unipc).
    use_corrector: Optional[bool] = None
    corrector_order: Optional[int] = None  # None -> solver-matched UniC-p
    corrector_at_last: bool = False
    # classifier-free guidance + thresholding (fused into the scan)
    cfg_scale: float = 0.0
    cfg_schedule: str = "constant"     # constant | linear | cosine
    cfg_scale_end: Optional[float] = None
    thresholding: bool = False
    threshold_percentile: float = 0.995
    # execution
    fused_update: bool = True
    # feature reuse (DESIGN.md §12): static DiT cache boundary — shallow
    # steps recompute only the first `cache_block` blocks and reuse the
    # cached deep-feature delta. 0 = no caching. Which steps are shallow is
    # per-step table data (a tuned plan's `cache_depth` column), not spec
    # state; the engine must be wired with a matching cached eps-net
    # (`build_engine(cache_block=...)`).
    cache_block: int = 0
    # serving eval precision (DESIGN.md §11): the eps-network evaluates in
    # this dtype; solver state, combine weights, and the x0/eps conversion
    # stay fp32 regardless. "bfloat16" is the opt-in fast serving mode —
    # parity bounds documented in DESIGN.md §11 and pinned by tests.
    eval_dtype: str = "float32"
    # quantized denoiser tier (DESIGN.md §14): "none" or a
    # models.quant.QUANT_MODES name ("w8a16", "w8a8", "fp8a16", "w4a16").
    # Like eval_dtype this is a contract, not a switch: the engine must be
    # wired with a matching quantized param tree
    # (`build_engine(quant=...)`), and `model_fn` rejects a mismatch.
    quant: str = "none"

    def resolve(self) -> "EngineSpec":
        """Fill solver-dependent defaults; validate against the registry."""
        sd = solver_def(self.solver)
        out = self
        if out.eval_dtype not in ("float32", "bfloat16"):
            raise ValueError(f"eval_dtype must be 'float32' or 'bfloat16', "
                             f"got {out.eval_dtype!r}")
        if out.quant != "none":
            # import here: specs stays importable without the models package
            from ..models.quant import quant_spec
            quant_spec(out.quant)  # raises on unknown tier names
        if out.cache_block < 0:
            raise ValueError(f"cache_block must be >= 0, got "
                             f"{out.cache_block}")
        if out.cache_block and out.cfg_scale:
            raise ValueError(
                "feature reuse (cache_block > 0) serves unconditional "
                "programs only: the fused-CFG eval stacks cond+uncond into "
                "one 2B batch, which would need a 2B cache ring — tune and "
                "serve cached plans with cfg_scale=0")
        if out.prediction is None:
            out = replace(out, prediction=sd.prediction)
        elif sd.fixed_prediction and out.prediction != sd.prediction:
            raise ValueError(
                f"solver {sd.name!r} is {sd.prediction}-prediction only, "
                f"got prediction={out.prediction!r}")
        if out.use_corrector is None:
            out = replace(out, use_corrector=sd.corrector_default)
        if out.use_corrector and sd.singlestep:
            raise ValueError(
                f"UniC bolt-on is grid-anchored; singlestep solver "
                f"{sd.name!r} compiles with use_corrector=False")
        if out.corrector_order is None:
            out = replace(out, corrector_order=sd.unic_order(out))
        return out


@dataclass(frozen=True)
class SolverDef:
    """One registry entry: a weight-table compiler plus its loop reference.

    compile(spec, noise_schedule) -> SolverTable  (host-side float64 rows)
    loop(spec, noise_schedule, model_fn) -> sample_fn(x_T)  (GridSolver path)
    """

    name: str
    prediction: str                    # default prediction type
    compile: Callable
    loop: Callable
    fixed_prediction: bool = True
    singlestep: bool = False
    corrector_default: bool = False
    # UniC-p order matched to the solver (Table 2), as a function of the spec
    default_corrector_order: Optional[Callable] = None

    def unic_order(self, spec: EngineSpec) -> int:
        if self.default_corrector_order is None:
            return spec.order
        return self.default_corrector_order(spec)


def register(sd: SolverDef) -> SolverDef:
    SOLVERS[sd.name] = sd
    return sd


def solver_def(name: str) -> SolverDef:
    if name not in SOLVERS:
        raise KeyError(f"unknown solver {name!r}; registered: "
                       f"{sorted(SOLVERS)}")
    return SOLVERS[name]


def default_tier_specs(**common) -> Dict[str, EngineSpec]:
    """Hand-set quality-tier specs for plan-bank serving: one deployment,
    three NFE budgets. `common` overrides shared knobs (cfg_scale, ...) on
    every tier. Tuned plans (`repro.tuning`) replace these tables tier by
    tier; the specs still carry the conditioning/runtime configuration."""
    tiers = {
        "fast": EngineSpec(solver="unipc", nfe=5, order=2),
        "balanced": EngineSpec(solver="unipc", nfe=8, order=3),
        "quality": EngineSpec(solver="unipc", nfe=16, order=3),
    }
    return {k: replace(v, **common) for k, v in tiers.items()}
