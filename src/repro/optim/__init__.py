from .adamw import AdamW, AdamWState
from .schedule import constant, warmup_cosine
