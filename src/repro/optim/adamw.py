"""AdamW with decoupled weight decay + global-norm gradient clipping (pure JAX)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: dict
    v: dict


@dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0

    def init(self, params) -> AdamWState:
        z = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return AdamWState(jnp.zeros((), jnp.int32), z,
                          jax.tree.map(jnp.copy, z))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.clip_norm:
            gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                              for g in jax.tree.leaves(grads)))
            scale = jnp.minimum(1.0, self.clip_norm / (gn + 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        m = jax.tree.map(lambda mm, g: self.b1 * mm + (1 - self.b1)
                         * g.astype(jnp.float32), state.m, grads)
        v = jax.tree.map(lambda vv, g: self.b2 * vv + (1 - self.b2)
                         * jnp.square(g.astype(jnp.float32)), state.v, grads)
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, mm, vv):
            u = (mm / bc1) / (jnp.sqrt(vv / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamWState(step, m, v)
