"""Three-term roofline model from compiled dry-run artifacts (EXPERIMENTS.md
§Roofline).

Hardware model: TPU v5e — 197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s
per ICI link (DESIGN/system prompt constants).

IMPORTANT unit note: the dry-run parses the *post-SPMD-partitioning* HLO, whose
tensor shapes are already per-device shards. So `flops` / `hbm_bytes` /
`collective_bytes` here are PER-CHIP quantities and the terms are simply

    compute_s    = flops_per_chip / PEAK_FLOPS
    memory_s     = hbm_bytes_per_chip / HBM_BW
    collective_s = wire_bytes_per_chip / LINK_BW

(equivalently HLO_FLOPs_total / (chips * peak) — the same number, since the
partitioned module is what each chip executes). MODEL_FLOPS is whole-model,
so the useful-compute ratio compares it against flops * chips.
"""

from __future__ import annotations

from dataclasses import dataclass

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link (ICI)


@dataclass
class Roofline:
    flops: float             # per chip (post-SPMD HLO)
    hbm_bytes: float          # per chip
    collective_bytes: float   # per chip wire traffic
    chips: int
    model_flops: float = 0.0  # whole model (6ND / 2ND)

    @property
    def compute_s(self):
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self):
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self):
        return self.collective_bytes / LINK_BW

    @property
    def bottleneck(self):
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self):
        """Optimistic (fully-overlapped) step time = max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self):
        total = self.flops * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def mfu(self):
        """Model-FLOPs utilization at the optimistic step time."""
        denom = self.step_time_s * self.chips * PEAK_FLOPS
        return self.model_flops / denom if denom else 0.0

    def row(self):
        return dict(
            compute_s=self.compute_s, memory_s=self.memory_s,
            collective_s=self.collective_s, bottleneck=self.bottleneck,
            flops_per_chip=self.flops, hbm_bytes_per_chip=self.hbm_bytes,
            collective_bytes_per_chip=self.collective_bytes,
            model_flops=self.model_flops,
            useful_ratio=self.useful_flops_ratio,
            mfu=self.mfu,
        )


def model_flops_train(cfg, tokens: int) -> float:
    """6 * N_active * D (dense) with MoE using active params only."""
    return 6.0 * active_params(cfg) * tokens


def model_flops_decode(cfg, tokens: int) -> float:
    return 2.0 * active_params(cfg) * tokens


def active_params(cfg) -> float:
    """Parameter count with only the routed-active experts counted."""
    d, f, V, L = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.num_layers
    emb = V * d
    # ssm/hybrid/vlm/audio implementations reuse the embedding as the output
    # head (no separate lm_head); dense/moe honor cfg.tie_embeddings
    tied = cfg.tie_embeddings or cfg.family in ("ssm", "hybrid", "vlm", "audio")
    n = emb if tied else 2 * emb
    if cfg.family in ("dense", "moe", "vlm"):
        hd = cfg.head_dim * (cfg.num_heads + 2 * cfg.num_kv_heads) * d \
            + cfg.num_heads * cfg.head_dim * d
        if cfg.num_experts:
            fe = cfg.moe_d_ff or f
            mlp = 3 * d * fe * cfg.experts_per_token + d * cfg.num_experts
        else:
            mlp = 3 * d * f if cfg.act == "swiglu" else 2 * d * f
        per_layer = hd + mlp
        n += L * per_layer
        if cfg.family == "vlm":
            n += d * d  # projector
    elif cfg.family == "audio":
        attn = 4 * d * d
        mlp = 2 * d * f
        n += cfg.encoder_layers * (attn + mlp) + L * (2 * attn + mlp)
    elif cfg.family in ("ssm", "hybrid"):
        di = cfg.ssm_d_inner
        G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
        per = d * (2 * di + 2 * G * N + H) + di * d
        n += L * per
        if cfg.family == "hybrid":
            n += 4 * d * d + 3 * d * f  # one shared attention block
    elif cfg.family == "dit":
        n += L * (4 * d * d + 2 * d * cfg.d_ff + 6 * d * d)
    return float(n)
