"""HLO-text analysis: FLOP / HBM-byte / collective-byte accounting with
while-loop trip-count scaling.

Why not cost_analysis()? XLA's cost analysis counts a while-loop body ONCE —
with scan-over-layers (this repo's standard structure) that under-counts a
95-layer model by 95x. We therefore parse the optimized HLO ourselves:

1. two-pass per-computation symbol table (instruction name -> result shape),
2. call-graph walk (while body=..., fusion calls=..., call to=...) propagating
   execution multipliers from `backend_config={"known_trip_count":{"n":N}}`
   (scan always emits known trip counts),
3. totals:
   - flops: dot ops (2 * prod(result) * contracted size), anywhere incl.
     inside fused computations,
   - hbm bytes: per top-level (non-fused) instruction, result bytes +
     operand bytes — a standard post-fusion traffic proxy (each fusion reads
     its operands from HBM and writes its result once),
   - collective wire bytes per device with op-appropriate (n-1)/n factors.

All numbers are whole-program; divide by device count for per-chip terms
(collectives are already per-device wire traffic).
"""

from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->.*\{")
_INSTR_RE = re.compile(
    r"^\s+(?:ROOT\s+)?%([\w\.\-]+)\s+=\s+"
    r"((?:\([^)]*\))|(?:\w+\[[^\]]*\](?:\{[^}]*\})?))\s+"
    r"([\w\-]+)\((.*)$")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?(\d+)')
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_TO_RE = re.compile(r"to_apply=%?([\w\.\-]+)")
_GROUPS_ITER_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")

_BOOKKEEPING = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "after-all", "partition-id", "replica-id", "iota", "opt-barrier",
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_elems_bytes(type_str: str):
    elems, nbytes = 0, 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dtype]
    return elems, nbytes


@dataclass
class Instr:
    name: str
    type_str: str
    op: str
    rest: str
    elems: int
    nbytes: int


@dataclass
class Computation:
    name: str
    instrs: list = field(default_factory=list)
    symbols: dict = field(default_factory=dict)


def parse_computations(hlo_text: str):
    comps = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line)
        if m:
            cur = Computation(m.group(2))
            comps[cur.name] = cur
            if m.group(1):
                entry = cur.name
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if mi:
            name, type_str, op, rest = mi.groups()
            elems, nbytes = _shape_elems_bytes(type_str)
            ins = Instr(name, type_str, op, rest, elems, nbytes)
            cur.instrs.append(ins)
            cur.symbols[name] = ins
    return comps, entry


def _multipliers(comps: dict, entry=None):
    """Execution multiplier + fused flag per computation via the call graph."""
    mult = defaultdict(float)
    fused = {}
    if entry is None:
        roots = [n for n in comps if n.startswith("main")] or list(comps)[:1]
        entry = roots[0]
    mult[entry] = 1.0
    fused[entry] = False
    changed = True
    it = 0
    while changed and it < 50:
        changed, it = False, it + 1
        for cname, comp in comps.items():
            if cname not in mult:
                continue
            base = mult[cname]
            for ins in comp.instrs:
                targets = []
                if ins.op == "while":
                    trips = 1
                    mt = _TRIP_RE.search(ins.rest)
                    if mt:
                        trips = int(mt.group(1))
                    for pat in (_BODY_RE, _COND_RE):
                        mm = pat.search(ins.rest)
                        if mm:
                            targets.append((mm.group(1), trips, False))
                elif ins.op == "fusion":
                    mm = _CALLS_RE.search(ins.rest)
                    if mm:
                        targets.append((mm.group(1), 1, True))
                else:
                    for pat in (_CALLS_RE, _TO_RE):
                        mm = pat.search(ins.rest)
                        if mm:
                            targets.append((mm.group(1), 1, fused.get(cname, False)))
                for tgt, k, is_fused in targets:
                    if tgt not in comps:
                        continue
                    newm = base * k
                    if mult.get(tgt, 0.0) < newm:
                        mult[tgt] = newm
                        fused[tgt] = is_fused
                        changed = True
                    elif tgt not in fused:
                        fused[tgt] = is_fused
    return mult, fused


def _dot_flops(comp: Computation, ins: Instr) -> float:
    ops = _OPERAND_RE.findall(ins.rest)
    mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    contract = 1
    if ops and mc:
        lhs = comp.symbols.get(ops[0])
        if lhs is not None:
            dims_m = _SHAPE_RE.search(lhs.type_str)
            if dims_m:
                dims = [int(d) for d in dims_m.group(2).split(",") if d]
                for ci in mc.group(1).split(","):
                    if ci:
                        contract *= dims[int(ci)]
    return 2.0 * ins.elems * contract


def _collective_wire(ins: Instr, default_n: int) -> float:
    n = default_n
    m = _GROUPS_ITER_RE.search(ins.rest)
    if m:
        n = max(2, int(m.group(2)))
    else:
        m = _GROUPS_LIST_RE.search(ins.rest)
        if m:
            n = max(2, len([x for x in m.group(1).split(",") if x.strip()]))
    b = ins.nbytes
    op = ins.op.replace("-start", "")
    if op == "all-reduce":
        return 2.0 * (n - 1) / n * b
    if op == "all-gather":
        return (n - 1) / n * b
    if op == "reduce-scatter":
        return (n - 1) * b
    if op == "all-to-all":
        return (n - 1) / n * b
    return float(b)  # collective-permute


def analyze(hlo_text: str, num_devices: int) -> dict:
    comps, entry = parse_computations(hlo_text)
    mult, fused = _multipliers(comps, entry)
    flops = 0.0
    hbm = 0.0
    coll = defaultdict(float)
    for cname, comp in comps.items():
        m = mult.get(cname, 1.0)
        is_fused = fused.get(cname, False)
        for ins in comp.instrs:
            op = ins.op.replace("-start", "").replace("-done", "")
            if ins.op == "dot" or ins.op == "convolution":
                flops += _dot_flops(comp, ins) * m
            if is_fused:
                continue
            if op in _COLLECTIVES and not ins.op.endswith("-done"):
                coll[op] += _collective_wire(ins, num_devices) * m
            if op in _BOOKKEEPING or op in ("while", "call", "conditional"):
                continue
            # traffic proxy: write the result once, read operands once
            operand_bytes = 0
            for oname in _OPERAND_RE.findall(ins.rest):
                src = comp.symbols.get(oname)
                if src is not None:
                    operand_bytes += src.nbytes
            hbm += (ins.nbytes + operand_bytes) * m
    coll["_total"] = sum(v for k, v in coll.items() if not k.startswith("_"))
    return {"flops": flops, "hbm_bytes": hbm, "collectives": dict(coll)}


def collective_bytes(hlo_text: str, num_devices: int) -> dict:
    return analyze(hlo_text, num_devices)["collectives"]
