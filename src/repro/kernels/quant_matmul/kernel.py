"""Quantized blocked matmul — Pallas TPU kernel.

The denoiser's serving tick is dominated by its dense matmuls (BENCH_model:
dit-i256 eval ~12.3 ms vs ~0.34 ms for the whole solver combine), and every
one of them is memory-bound at slot-batch shapes: the weight matrix is read
from HBM once per eval, so halving (int8) or quartering (int4 container) the
bytes per weight is the direct lever. The kernel keeps the MXU contraction
in fp32 regardless of storage width: each (bk, bn) weight tile is widened
in-register after the VMEM load — HBM sees quantized bytes, the accumulator
never does.

One kernel serves W8A16 and W8A8: the x operand is either float activations
or int8 pre-quantized upstream (ops.py folds the static activation scale
into the per-channel weight scale), and the weight tile is int8 or fp8 e4m3.
Grid is (M tiles, N tiles, K tiles) with K innermost: the fp32 output block
stays resident in VMEM across the K sweep (zeroed at k == 0, scaled by the
per-output-channel row once at the last K step). Arbitrary (M, N, K) is
handled by ops.py zero-padding every operand to the tile lattice — zero
rows/columns contribute nothing to the fp32 accumulation and the padded
output rows/cols are sliced off — matching the pad-and-mask contract of the
other kernel packages (DESIGN.md §5).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-shaped tiles; 128 lanes also satisfies the int8 (32, 128) minimum
DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_K = 128


def _qmm_kernel(x_ref, w_ref, s_ref, o_ref, *, nk):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # widen the quantized tile in-register; fp32 MXU accumulation
    o_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                          w_ref[...].astype(jnp.float32),
                          preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _scale():
        o_ref[...] *= s_ref[...].astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("blk_m", "blk_n", "blk_k",
                                             "interpret"))
def quant_matmul(x, qw, scale, *, blk_m=DEFAULT_BLOCK_M, blk_n=DEFAULT_BLOCK_N,
                 blk_k=DEFAULT_BLOCK_K, interpret=True):
    """x: (M, K) float or int8; qw: (K, N) int8/fp8; scale: (1, N) fp32.
    M/N/K must be tile multiples (pad upstream in ops.py; zero padding is
    exact under the fp32 accumulation). Returns fp32 (M, N) =
    (x @ qw) * scale."""
    M, K = x.shape
    N = qw.shape[1]
    nk = K // blk_k
    kernel = functools.partial(_qmm_kernel, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=(M // blk_m, N // blk_n, nk),
        in_specs=[
            pl.BlockSpec((blk_m, blk_k), lambda m, n, k: (m, k)),
            pl.BlockSpec((blk_k, blk_n), lambda m, n, k: (k, n)),
            pl.BlockSpec((1, blk_n), lambda m, n, k: (0, n)),
        ],
        out_specs=pl.BlockSpec((blk_m, blk_n), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x, qw, scale)
