"""Public wrapper for the quantized matmul: backend dispatch + padding.

The same explicit three-backend policy as the other kernel packages
(DESIGN.md §5):

* ``"pallas"``    — the compiled blocked kernel; the production path on TPU,
  where the int8/fp8 weight tiles halve/quarter the HBM bytes per eval.
* ``"interpret"`` — the same kernel under the Pallas interpreter (CI).
* ``"jnp"``       — the fp32-accumulation oracle in `ref.py`; the right
  default off-TPU. XLA still reads int8 weight buffers and widens at use, so
  the HBM-bytes win is real on CPU too even where wall-clock is not.

`quant_matmul` takes a float activation tensor of any leading shape against
an int8/fp8 weight matrix with per-output-channel fp32 scales. With
``sa=None`` activations stay floating (W8A16); with a static calibrated
activation scale the activations are quantized here and `sa` is folded into
the weight scale, so every backend runs the identical
``(x_q @ qw) * (sa * ws)`` contraction (W8A8). Arbitrary (M, N, K) is
zero-padded to the tile lattice and sliced back — exact under fp32
accumulation.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import ref
from ..dispatch import (BACKENDS, resolve_backend,  # noqa: F401 (re-export)
                        platform_select as select_backend)
from .kernel import (DEFAULT_BLOCK_K, DEFAULT_BLOCK_M, DEFAULT_BLOCK_N,
                     quant_matmul as _qmm_kernel)
from .ref import dequantize, quantize, quantize_act  # noqa: F401 (re-export)


def quant_matmul(x, qw, ws, *, sa=None, backend=None, force_pallas=False,
                 blk_m=DEFAULT_BLOCK_M, blk_n=DEFAULT_BLOCK_N,
                 blk_k=DEFAULT_BLOCK_K):
    """x: (..., K) float; qw: (K, N) int8/fp8; ws: (N,) fp32 per-output-
    channel weight scales; sa: optional static activation scale (W8A8).
    Returns (..., N) in x.dtype. `backend` pins one of BACKENDS;
    `force_pallas` means "run the kernel even off-TPU" (compiled on TPU,
    interpreted elsewhere)."""
    lead, K = x.shape[:-1], x.shape[-1]
    N = qw.shape[-1]
    x2 = x.reshape(-1, K)
    scale = ws.astype(jnp.float32)
    if sa is not None:
        x2 = ref.quantize_act(x2, sa)
        scale = scale * sa
    backend = resolve_backend(backend, force_pallas, select_backend)
    if backend == "jnp":
        out = ref.matmul(x2, qw, scale)
    else:
        M = x2.shape[0]
        # don't tile past tiny slot batches; int8 rows keep the (32, 128)
        # minimum tile, float rows the fp32 (8, 128) one
        bm = min(blk_m, max(32 if x2.dtype == jnp.int8 else 8, M))
        pm, pn, pk = (-M) % bm, (-N) % blk_n, (-K) % blk_k
        out = _qmm_kernel(
            jnp.pad(x2, ((0, pm), (0, pk))),
            jnp.pad(qw, ((0, pk), (0, pn))),
            jnp.pad(scale.reshape(1, N), ((0, 0), (0, pn))),
            blk_m=bm, blk_n=blk_n, blk_k=blk_k,
            interpret=backend == "interpret")[:M, :N]
    return out.astype(x.dtype).reshape(*lead, N)
