"""Pure-jnp oracle + quantization helpers for the quantized matmul package.

The quantization scheme (DESIGN.md §14) is symmetric absmax:

* weights — per-output-channel (one fp32 scale per output column, absmax
  over the contraction axis) or per-tensor (one scale per weight matrix,
  broadcast to the channel shape so every record looks the same downstream).
  Stored as an int8 container (int4 tiers clip to +/-7 inside the same
  container) or fp8 e4m3 when the ``fmt="fp8"`` tier is selected.
* activations — optional static per-tensor scale calibrated from reference
  trajectories (models/quant.py); ``sa=None`` leaves activations in floating
  point (W8A16).

`matmul` is the dequantize-free core every backend agrees on:
``(x @ qw) * scale`` with both operands widened to fp32 before the dot, so
the accumulation is fp32 on every path and the Pallas kernel's blocked
result differs from this oracle only by fp32 summation order.
"""

from __future__ import annotations

import jax.numpy as jnp

GRANULARITIES = ("channel", "tensor")

# symmetric integer ranges; fp8 e4m3 saturates at +/-448
_QMAX = {8: 127.0, 4: 7.0}
FP8_MAX = 448.0
ACT_QMAX = 127.0
_TINY = 1e-12  # floor for absmax-derived scales (all-zero columns)


def quantize(w, *, bits: int = 8, granularity: str = "channel",
             fmt: str = "int"):
    """w: (..., K, N) float -> (qw, scale) with scale (..., N) fp32.

    channel: absmax over K, one scale per output column; tensor: absmax over
    (K, N) per leading batch index, broadcast to (..., N) so records carry a
    uniform scale shape either way. ``fmt="fp8"`` stores e4m3 weights (bits
    is ignored); otherwise an int8 container holding ``bits``-bit values.
    """
    if granularity not in GRANULARITIES:
        raise ValueError(f"granularity must be one of {GRANULARITIES}, "
                         f"got {granularity!r}")
    wf = w.astype(jnp.float32)
    amax = jnp.max(jnp.abs(wf), axis=-2)                      # (..., N)
    if granularity == "tensor":
        amax = jnp.broadcast_to(
            jnp.max(amax, axis=-1, keepdims=True), amax.shape)
    if fmt == "fp8":
        if not hasattr(jnp, "float8_e4m3fn"):
            raise ValueError("fp8 weights need a jax build with "
                             "jnp.float8_e4m3fn")
        scale = jnp.maximum(amax, _TINY) / FP8_MAX
        qw = (wf / scale[..., None, :]).astype(jnp.float8_e4m3fn)
        return qw, scale
    if bits not in _QMAX:
        raise ValueError(f"bits must be one of {sorted(_QMAX)}, got {bits}")
    qmax = _QMAX[bits]
    scale = jnp.maximum(amax, _TINY) / qmax
    q = jnp.round(wf / scale[..., None, :])
    qw = jnp.clip(q, -qmax, qmax).astype(jnp.int8)
    return qw, scale


def dequantize(qw, scale):
    """(qw (..., K, N), scale (..., N)) -> fp32 weights."""
    return qw.astype(jnp.float32) * scale[..., None, :].astype(jnp.float32)


def quantize_act(x, sa):
    """Static-scale symmetric activation quantization: x float -> int8."""
    q = jnp.round(x.astype(jnp.float32) / sa)
    return jnp.clip(q, -ACT_QMAX, ACT_QMAX).astype(jnp.int8)


def matmul(x, qw, scale):
    """The fp32-accumulation core: (x (M, K) @ qw (K, N)) * scale (N,).

    x is float (W8A16) or int8 (W8A8, pre-quantized upstream with the static
    activation scale already folded into `scale`); qw is int8 or fp8.
    Returns fp32 (M, N).
    """
    acc = jnp.dot(x.astype(jnp.float32), qw.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return acc * scale.astype(jnp.float32)[None, :]


def quant_matmul(x, qw, ws, *, sa=None):
    """Convenience full oracle over a weight record: quantizes activations
    when `sa` is given, then runs the fp32 core. x: (..., K) -> (..., N)."""
    lead, K = x.shape[:-1], x.shape[-1]
    x2 = x.reshape(-1, K)
    scale = ws
    if sa is not None:
        x2 = quantize_act(x2, sa)
        scale = ws * sa
    out = matmul(x2, qw, scale)
    return out.astype(x.dtype).reshape(*lead, qw.shape[-1])
