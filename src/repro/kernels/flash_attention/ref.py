"""Pure-jnp oracle for blockwise attention (GQA, causal, sliding window)."""

import math

import jax
import jax.numpy as jnp


def attention(q, k, v, *, causal=True, window=None):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D). fp32 softmax."""
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    qg = q.reshape(B, Hkv, group, Sq, D)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Skv)[None, :]
    ok = jnp.ones((Sq, Skv), bool)
    if causal:
        ok &= kpos <= qpos
    if window is not None:
        ok &= kpos > qpos - window
    s = jnp.where(ok[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", w, v.astype(jnp.float32))
    return o.reshape(B, Hq, Sq, D).astype(q.dtype)
