"""Public wrapper: padding to block multiples + backend dispatch."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .kernel import DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q, flash_attention


def attention(q, k, v, *, causal=True, window=None, force_pallas=False,
              blk_q=DEFAULT_BLOCK_Q, blk_k=DEFAULT_BLOCK_K):
    """(B, Hq, Sq, D) x (B, Hkv, Skv, D) -> (B, Hq, Sq, D).

    Pallas on TPU (or interpret when forced); jnp oracle elsewhere. Pads
    sequence lengths up to block multiples; key padding is masked inside the
    kernel via kv_len, query padding is sliced off."""
    on_tpu = jax.default_backend() == "tpu"
    if not (on_tpu or force_pallas):
        return ref.attention(q, k, v, causal=causal, window=window)
    B, Hq, Sq, D = q.shape
    Skv = k.shape[2]
    pq = (-Sq) % blk_q
    pk = (-Skv) % blk_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          blk_q=blk_q, blk_k=blk_k, interpret=not on_tpu,
                          kv_len=Skv)
    return out[:, :, :Sq]
