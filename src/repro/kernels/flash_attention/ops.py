"""Public wrapper for blockwise attention: backend dispatch + padding.

Three backends, the same explicit policy as ``kernels/unipc_update/ops.py``
(DESIGN.md §5):

* ``"pallas"``    — the compiled Pallas kernel; the production path on TPU.
* ``"interpret"`` — the same kernel under the Pallas interpreter; correct on
  any platform, slow; what CI exercises so the real kernel code runs on CPU.
* ``"jnp"``       — the pure-jnp head-major oracle (`ref.attention`); the
  right default off-TPU. (Head-major (B, H, S, D) batched matmuls make the
  attention-dominated DiT eval ~1.5x faster on CPU than the model's
  seq-major einsum at dit-i256 serving shapes — BENCH_model.json, DESIGN.md
  §11 — so the fallback is a real path, not just a test oracle.)

`select_backend` encodes the policy; `attention` applies it. Callers can pin
a backend explicitly (tests, CI, the `cfg.attention_backend` model knob) or
let the dispatcher choose by platform. Sequence lengths are padded up to
block multiples for the kernel backends: key padding is masked inside the
kernel via kv_len, query padding is sliced off.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import ref
from ..dispatch import (BACKENDS, resolve_backend,  # noqa: F401 (re-export)
                        platform_select as select_backend)
from .kernel import DEFAULT_BLOCK_K, DEFAULT_BLOCK_Q, flash_attention


def attention(q, k, v, *, causal=True, window=None, backend=None,
              force_pallas=False, blk_q=DEFAULT_BLOCK_Q, blk_k=DEFAULT_BLOCK_K):
    """(B, Hq, Sq, D) x (B, Hkv, Skv, D) -> (B, Hq, Sq, D).

    `backend` pins one of BACKENDS; `force_pallas` (kept for tests and
    benchmarks) means "run the kernel even off-TPU", i.e. compiled on TPU,
    interpreted elsewhere. With neither, `select_backend` chooses by
    platform.
    """
    backend = resolve_backend(backend, force_pallas, select_backend)
    if backend == "jnp":
        return ref.attention(q, k, v, causal=causal, window=window)
    B, Hq, Sq, D = q.shape
    Skv = k.shape[2]
    pq = (-Sq) % blk_q
    pk = (-Skv) % blk_k
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    out = flash_attention(q, k, v, causal=causal, window=window,
                          blk_q=blk_q, blk_k=blk_k,
                          interpret=backend == "interpret", kv_len=Skv)
    return out[:, :, :Sq]
