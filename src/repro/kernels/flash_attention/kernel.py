"""Blockwise (flash) causal attention — Pallas TPU kernel.

Online-softmax attention with VMEM tiling for the prefill hot path:
grid = (batch * q_heads, num_q_blocks, num_kv_blocks); the kv axis is the
innermost sequential grid dimension, carrying running (max, denom, acc)
in VMEM scratch. GQA is expressed in the BlockSpec index maps (the kv block
for query head h is head h // group of the K/V operands) — no materialized
head repetition. Causal and sliding-window masks are applied per tile;
fully-masked tiles are skipped with pl.when.

Block sizes default to (128, 128): MXU-aligned, and the working set
(q 128xD + k/v 128xD + fp32 scratch) stays well under the ~16 MB VMEM for
D <= 256.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                 scale, causal, window, blk_q, blk_k, kv_len):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[:] = jnp.full_like(m_scr, NEG_INF)
        l_scr[:] = jnp.zeros_like(l_scr)
        acc_scr[:] = jnp.zeros_like(acc_scr)

    q_start = qi * blk_q
    k_start = ki * blk_k
    # skip tiles entirely above the causal diagonal / outside the window
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + blk_q - 1)
    if window is not None:
        run = jnp.logical_and(run, k_start + blk_k - 1 > q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)            # (blk_q, d)
        k = k_ref[0].astype(jnp.float32)            # (blk_k, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        ok = kpos < kv_len
        if causal:
            ok = jnp.logical_and(ok, kpos <= qpos)
        if window is not None:
            ok = jnp.logical_and(ok, kpos > qpos - window)
        s = jnp.where(ok, s, NEG_INF)
        m_prev = m_scr[:]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_cur[:, None])
        alpha = jnp.exp(m_prev - m_cur)
        l_scr[:] = l_scr[:] * alpha + jnp.sum(p, axis=1)
        acc_scr[:] = acc_scr[:] * alpha[:, None] + jax.lax.dot(p, v)
        m_scr[:] = m_cur

    @pl.when(ki == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[:], 1e-30)
        o_ref[0] = (acc_scr[:] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "blk_q", "blk_k", "interpret",
                     "kv_len"))
def flash_attention(q, k, v, *, causal=True, window=None,
                    blk_q=DEFAULT_BLOCK_Q, blk_k=DEFAULT_BLOCK_K,
                    interpret=True, kv_len=None):
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Skv, D); Hq % Hkv == 0.

    Sq must be a multiple of blk_q and Skv of blk_k (pad upstream in ops.py;
    kv_len = the unpadded key length, padded keys are masked).
    """
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    kv_len = kv_len if kv_len is not None else Skv
    group = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    grid = (B * Hq, Sq // blk_q, Skv // blk_k)

    def q_map(bh, qi, ki):
        return (bh, qi, 0)

    def kv_map(bh, qi, ki):
        b, h = bh // Hq, bh % Hq
        return (b * Hkv + h // group, ki, 0)

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, window=window,
        blk_q=blk_q, blk_k=blk_k, kv_len=kv_len)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_q, D), q_map),
            pl.BlockSpec((1, blk_k, D), kv_map),
            pl.BlockSpec((1, blk_k, D), kv_map),
        ],
        out_specs=pl.BlockSpec((1, blk_q, D), q_map),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q,), jnp.float32),
            pltpu.VMEM((blk_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q.reshape(B * Hq, Sq, D), k.reshape(B * Hkv, Skv, D),
      v.reshape(B * Hkv, Skv, D))
    return out.reshape(B, Hq, Sq, D)
