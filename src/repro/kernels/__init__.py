"""Pallas TPU kernels for the perf-critical compute layers, each a package
of kernel.py (pl.pallas_call + BlockSpec VMEM tiling), ops.py (jit'd public
wrapper with backend dispatch + padding) and ref.py (pure-jnp oracle):

* unipc_update    — fused multi-term solver state update (one HBM pass)
* flash_attention — blockwise online-softmax causal GQA attention
                    (sliding-window capable), (128, 128) MXU-aligned tiles

Validated against the oracles in interpret mode (tests/test_kernels.py);
selected on TPU backends by the ops wrappers.
"""
