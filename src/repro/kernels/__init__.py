"""Pallas TPU kernels for the perf-critical compute layers, each a package
of kernel.py (pl.pallas_call + BlockSpec VMEM tiling), ops.py (jit'd public
wrapper with the explicit pallas|interpret|jnp backend dispatch + padding)
and ref.py (pure-jnp oracle):

* unipc_update    — fused multi-term solver state update (one HBM pass);
                    the scan sampler's default combine (DESIGN.md §4-§5)
* flash_attention — blockwise online-softmax GQA attention (causal,
                    non-causal, sliding-window), (128, 128) MXU-aligned
                    tiles; the model-side attention in
                    `models.layers.attention_apply` routes through its ops
                    wrapper (the fast-eval path, DESIGN.md §11)
* adaln_modulate  — fused layernorm + adaLN-zero scale/shift and the gated
                    residual re-entry; `models.dit` runs every block's
                    modulation through it (DESIGN.md §11)
* quant_matmul    — blocked matmul over quantized weights (int8, int4-in-
                    int8, fp8 e4m3; per-output-channel or per-tensor absmax
                    scales; optional static-scale int8 activations) with
                    fp32 MXU accumulation; `models.layers.dense_apply`
                    routes structural quant records through its ops wrapper
                    (the quantized serving path, DESIGN.md §14)

Validated against the oracles in interpret mode (tests/test_kernels.py,
tests/test_fast_eval.py, tests/test_quant.py); selected on TPU backends by
the ops wrappers, with the jnp oracles as the compiled-XLA path everywhere
else.
"""
