"""Fused adaLN-zero modulation — Pallas TPU kernel.

A DiT block conditions every sub-block as `LN(h) * (1 + scale) + shift` and
re-enters the residual stream as `h + gate * branch(h_mod)` (adaLN-zero,
Peebles & Xie 2023). Executed as separate ops that is ~5 elementwise passes
over the (B, T, D) activation per sub-block: the LN reduction, the
normalize, the scale multiply, the shift add, and the gate/residual pair —
each a full HBM round trip when the dispatch boundary pins the schedule
(eager frameworks) and still reduction+elementwise kernel splits under XLA.
At serving time the activation is the whole slot batch, so the modulation is
purely memory-bound, exactly like the solver update (DESIGN.md §4).

Two kernels, each one pass over the activation:

* `adaln_modulate(x, shift, scale)` — LN (no learnable affine, matching
  `models.layers.layernorm({}, x)`) fused with the scale/shift modulation:
  read x once, write the modulated output once. Mean/variance are computed
  in fp32 inside the tile with padded lanes masked, so arbitrary D is
  handled without host-side masking.
* `gate_residual(resid, gate, y)` — `resid + gate * y`, the adaLN-zero gated
  residual re-entry: three reads, one write, no intermediate.

Layout: x/resid/y (B, T, D); shift/scale/gate (B, D) broadcast over tokens.
Grid is (B, T tiles); D lives fully inside the block (DiT widths are <= a
few K lanes, far under VMEM). D is padded to the 128-lane boundary by ops.py
(masked in the LN reduction, garbage lanes sliced off), T to the token-tile
boundary (rows sliced off).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_T = 128  # token rows per tile; fp32/bf16 sublane-aligned


def _modulate_kernel(x_ref, sh_ref, sc_ref, o_ref, *, d_true, eps):
    x = x_ref[0].astype(jnp.float32)                       # (blk_t, Dp)
    dp = x.shape[-1]
    if dp != d_true:  # masked reduction over the real lanes only
        lane = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
        mask = lane < d_true
        x = jnp.where(mask, x, 0.0)
    mu = jnp.sum(x, axis=-1, keepdims=True) / d_true
    cen = x - mu
    if dp != d_true:
        cen = jnp.where(mask, cen, 0.0)
    var = jnp.sum(cen * cen, axis=-1, keepdims=True) / d_true
    y = cen * jax.lax.rsqrt(var + eps)
    sc = sc_ref[0].astype(jnp.float32)                     # (Dp,)
    sh = sh_ref[0].astype(jnp.float32)
    o_ref[0] = (y * (1.0 + sc)[None, :] + sh[None, :]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("d_true", "eps", "blk_t",
                                             "interpret"))
def adaln_modulate(x, shift, scale, *, d_true, eps=1e-5,
                   blk_t=DEFAULT_BLOCK_T, interpret=True):
    """x: (B, T, Dp); shift/scale: (B, Dp). T % blk_t == 0 and Dp % 128 == 0
    (pad upstream in ops.py; `d_true` = the unpadded width, the LN reduction
    masks the padding and padded output lanes are garbage to slice off)."""
    B, T, Dp = x.shape
    kernel = functools.partial(_modulate_kernel, d_true=d_true, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(B, T // blk_t),
        in_specs=[
            pl.BlockSpec((1, blk_t, Dp), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, Dp), lambda b, i: (b, 0)),
            pl.BlockSpec((1, Dp), lambda b, i: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_t, Dp), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, Dp), x.dtype),
        interpret=interpret,
    )(x, shift, scale)


def _gate_res_kernel(r_ref, g_ref, y_ref, o_ref):
    r = r_ref[0].astype(jnp.float32)
    y = y_ref[0].astype(jnp.float32)
    g = g_ref[0].astype(jnp.float32)
    o_ref[0] = (r + g[None, :] * y).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("blk_t", "interpret"))
def gate_residual(resid, gate, y, *, blk_t=DEFAULT_BLOCK_T, interpret=True):
    """resid/y: (B, T, Dp); gate: (B, Dp). resid + gate * y in one pass.
    Same padding contract as `adaln_modulate` (no reduction, so padded lanes
    need no masking — their outputs are sliced off upstream)."""
    B, T, Dp = resid.shape
    return pl.pallas_call(
        _gate_res_kernel,
        grid=(B, T // blk_t),
        in_specs=[
            pl.BlockSpec((1, blk_t, Dp), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, Dp), lambda b, i: (b, 0)),
            pl.BlockSpec((1, blk_t, Dp), lambda b, i: (b, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk_t, Dp), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T, Dp), resid.dtype),
        interpret=interpret,
    )(resid, gate, y)
