"""Public wrapper for the fused adaLN modulation: backend dispatch + padding.

The same explicit three-backend policy as ``kernels/unipc_update/ops.py``
and ``kernels/flash_attention/ops.py`` (DESIGN.md §5):

* ``"pallas"``    — the compiled Pallas kernels; the production path on TPU.
* ``"interpret"`` — the same kernels under the Pallas interpreter (CI).
* ``"jnp"``       — the fp32 oracle in `ref.py`; the right default off-TPU —
  under jit XLA fuses it to the same elementwise schedule the kernel pins,
  so CPU serving loses nothing while TPU serving drops the multi-pass HBM
  round trips (DESIGN.md §11).

`modulate` is `LN(x) * (1 + scale) + shift`; `gate_residual` is
`resid + gate * y`. The kernel backends pad D up to the 128-lane boundary
(masked inside the LN reduction) and T up to the token-tile boundary, then
slice both off.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import ref
from ..dispatch import (BACKENDS, resolve_backend,  # noqa: F401 (re-export)
                        platform_select as select_backend)
from .kernel import (DEFAULT_BLOCK_T, adaln_modulate,  # noqa: F401
                     gate_residual as _gate_residual_kernel)


def _pad(x, pt, pd):
    if pt or pd:
        x = jnp.pad(x, ((0, 0), (0, pt), (0, pd)))
    return x


def modulate(x, shift, scale, *, eps=1e-5, backend=None, force_pallas=False,
             blk_t=DEFAULT_BLOCK_T):
    """LN(x) * (1 + scale) + shift in one pass. x: (B, T, D); shift/scale:
    (B, D). `backend` pins one of BACKENDS; `force_pallas` means "run the
    kernel even off-TPU" (compiled on TPU, interpreted elsewhere)."""
    backend = resolve_backend(backend, force_pallas, select_backend)
    if backend == "jnp":
        return ref.modulate(x, shift, scale, eps=eps)
    B, T, D = x.shape
    bt = min(blk_t, max(8, T))      # don't tile past tiny T
    pt, pd = (-T) % bt, (-D) % 128
    pad1 = ((0, 0), (0, pd))
    out = adaln_modulate(
        _pad(x, pt, pd), jnp.pad(shift, pad1), jnp.pad(scale, pad1),
        d_true=D, eps=eps, blk_t=bt, interpret=backend == "interpret")
    return out[:, :T, :D]


def gate_residual(resid, gate, y, *, backend=None, force_pallas=False,
                  blk_t=DEFAULT_BLOCK_T):
    """resid + gate * y in one pass. resid/y: (B, T, D); gate: (B, D)."""
    backend = resolve_backend(backend, force_pallas, select_backend)
    if backend == "jnp":
        return ref.gate_residual(resid, gate, y)
    B, T, D = resid.shape
    bt = min(blk_t, max(8, T))
    pt, pd = (-T) % bt, (-D) % 128
    out = _gate_residual_kernel(
        _pad(resid, pt, pd), jnp.pad(gate, ((0, 0), (0, pd))),
        _pad(y, pt, pd), blk_t=bt, interpret=backend == "interpret")
    return out[:, :T, :D]
