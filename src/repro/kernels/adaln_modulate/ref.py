"""Pure-jnp oracle for the fused adaLN-zero modulation (fp32 math)."""

import jax
import jax.numpy as jnp


def modulate(x, shift, scale, eps=1e-5):
    """LN(x) * (1 + scale) + shift. x: (B, T, D); shift/scale: (B, D).

    Layernorm without learnable affine (the DiT convention — the affine is
    the conditioning itself), computed in fp32 like `models.layers.layernorm`
    and cast back to x.dtype at the end."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = (y * (1.0 + scale.astype(jnp.float32))[:, None]
           + shift.astype(jnp.float32)[:, None])
    return out.astype(x.dtype)


def gate_residual(resid, gate, y):
    """resid + gate * y — the adaLN-zero gated residual re-entry.
    resid/y: (B, T, D); gate: (B, D)."""
    out = (resid.astype(jnp.float32)
           + gate.astype(jnp.float32)[:, None] * y.astype(jnp.float32))
    return out.astype(resid.dtype)
