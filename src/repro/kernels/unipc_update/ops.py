"""Jit'd public wrapper: shape plumbing + TPU/interpret dispatch + fallback."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .kernel import TILE, fused_combine_flat


def weighted_combine(terms, weights, force_pallas: bool = False):
    """terms: (K, *shape); weights: (K,). Fused on TPU (or in interpret mode
    when forced); falls back to the jnp oracle elsewhere — XLA fuses that path
    reasonably, the Pallas kernel guarantees the single-pass schedule."""
    on_tpu = jax.default_backend() == "tpu"
    if not (on_tpu or force_pallas):
        return ref.weighted_combine(terms, weights)
    K = terms.shape[0]
    shape = terms.shape[1:]
    n = 1
    for s in shape:
        n *= s
    pad = (-n) % TILE
    flat = terms.reshape(K, n)
    if pad:
        flat = jnp.pad(flat, ((0, 0), (0, pad)))
    out = fused_combine_flat(flat, weights, interpret=not on_tpu)
    if pad:
        out = out[:n]
    return out.reshape(shape)
