"""Public wrapper for the fused UniPC update: backend dispatch + shape plumbing.

Three backends (DESIGN.md §5):

* ``"pallas"``    — the compiled Pallas kernel; the production path on TPU.
* ``"interpret"`` — the same kernel under the Pallas interpreter; correct on
  any platform, slow; used for cross-platform kernel testing.
* ``"jnp"``       — a pure-jnp fp32 axpy chain that XLA fuses into a single
  pass; the right default off-TPU. (Not a ``tensordot``: that lowers to a
  gemm, measured ~2.8x slower on CPU at serving shapes — DESIGN.md §5. The
  tensordot form survives as the test oracle in `ref.py`.)

`select_backend` encodes the policy; `weighted_combine` applies it. Callers
can pin a backend explicitly (tests, benchmarks) or let the dispatcher choose
by platform and shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from ..dispatch import BACKENDS, resolve_backend  # noqa: F401 (re-export)
from .kernel import TILE, fused_combine_batched, fused_combine_flat  # noqa: F401


def _jnp_combine(terms, weights):
    """Unrolled fp32 axpy chain (K is static and small: order+2 for UniPC,
    up to 6 across the engine-compiled zoo, e.g. PLMS-4 + UniC). XLA fuses
    this into one pass over the state — the same schedule the Pallas kernel
    encodes. Per-slot (K, B) weights broadcast each batch row's own scalar
    over that row's trailing dims."""
    w = weights.astype(jnp.float32)
    if w.ndim == 2:  # (K, B) per-slot columns over (K, B, ...) terms
        w = w.reshape(w.shape + (1,) * (terms.ndim - w.ndim))
    acc = w[0] * terms[0].astype(jnp.float32)
    for k in range(1, terms.shape[0]):
        acc = acc + w[k] * terms[k].astype(jnp.float32)
    return acc.astype(terms.dtype)


def select_backend(n: int, platform: str | None = None) -> str:
    """Pick the backend for a per-sample flat size `n` on `platform`.

    TPU gets the compiled kernel unless the state is smaller than one tile —
    sub-tile launches waste the masked remainder lanes and the op is cheaper
    to leave to XLA. Everything else gets the jnp oracle: without Mosaic there
    is no compiled Pallas, and the interpreter is strictly for testing.
    """
    platform = platform or jax.default_backend()
    if platform == "tpu" and n >= TILE:
        return "pallas"
    return "jnp"


def weighted_combine(terms, weights, backend: str | None = None,
                     force_pallas: bool = False):
    """terms: (K, *shape); weights: (K,) or (K, B). Returns sum_k w_k * terms[k].

    shape may be anything; for batched states (B, ...) the kernel runs on a
    (B, N-tiles) grid over the (K, B, N) view — a reshape of contiguous
    trailing dims, never a flat copy of the whole batch. Per-slot (K, B)
    weights give every batch row its own weight column (the continuous-batching
    step, DESIGN.md §9) and require terms with a leading batch dim of B.
    `backend` pins one of BACKENDS; `force_pallas` (kept for tests/benchmarks)
    means "run the kernel even off-TPU", i.e. compiled on TPU, interpreted
    elsewhere.
    """
    shape = terms.shape[1:]
    K = terms.shape[0]
    per_slot = weights.ndim == 2
    if per_slot and (len(shape) < 2 or shape[0] != weights.shape[1]):
        raise ValueError(
            f"per-slot weights (K, B)={weights.shape} need terms shaped "
            f"(K, B, ...); got terms {terms.shape}")
    def auto():
        n = 1
        for s in (shape[1:] if len(shape) >= 2 else shape):
            n *= s
        return select_backend(n)

    backend = resolve_backend(backend, force_pallas, auto)
    if backend == "jnp":
        return _jnp_combine(terms, weights)
    interpret = backend == "interpret"
    if len(shape) >= 2:
        B = shape[0]
        out = fused_combine_batched(
            terms.reshape(K, B, -1), weights, interpret=interpret)
    else:
        out = fused_combine_flat(
            terms.reshape(K, -1), weights, interpret=interpret)
    return out.reshape(shape)
