"""Pure-jnp oracle for the fused UniPC update."""

import jax.numpy as jnp


def weighted_combine(terms, weights):
    """terms: (K, *shape); weights: (K,) or per-slot (K, B). Returns the
    weighted sum over K (per batch row for per-slot weights)."""
    wf = weights.astype(jnp.float32)
    tf = terms.astype(jnp.float32)
    if wf.ndim == 2:
        wf = wf.reshape(wf.shape + (1,) * (tf.ndim - wf.ndim))
        acc = jnp.sum(wf * tf, axis=0)
    else:
        acc = jnp.tensordot(wf, tf, axes=1)
    return acc.astype(terms.dtype)
