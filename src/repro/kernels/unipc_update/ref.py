"""Pure-jnp oracle for the fused UniPC update."""

import jax.numpy as jnp


def weighted_combine(terms, weights):
    """terms: (K, *shape); weights: (K,). Returns sum_k w_k * terms[k]."""
    wf = weights.astype(jnp.float32)
    acc = jnp.tensordot(wf, terms.astype(jnp.float32), axes=1)
    return acc.astype(terms.dtype)
