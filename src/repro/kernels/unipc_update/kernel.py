"""Fused UniPC state update — Pallas TPU kernel.

The UniPC step is x_next = sum_k w_k * term_k over K = order+2 tensors (the
previous state, the anchor model output, and the difference buffer). The
reference implementations execute this as a chain of K pointwise ops, each a
separate kernel launch streaming the full state through HBM: 3K-1 full-state
arrays of traffic (K term reads, K-1 accumulator re-reads, K writes). At
sampling time the state is the entire image/latent batch, so the update is
purely memory-bound. This kernel streams each VMEM tile of all K terms once
and writes the result once — K+1 arrays, a (3K-1)/(K+1)x traffic reduction
(2.3x at the default order-3 K=5, approaching 3x with order; measured in
benchmarks/bench_kernels.py, argument in DESIGN.md §4).

Layout: terms (K, B, N) fp32/bf16 with N = flattened per-sample size, weights
(K,) fp32 broadcast from a small VMEM block; 2D grid (B, N tiles) so batched
states tile directly, no flat copy. TILE is a multiple of 128 lanes; arbitrary
N is handled by the boundary tile — Pallas pads the load and masks the store
for blocks that overrun the array, so no host-side padding of the state is
needed. Accumulation is always fp32, also for bf16 terms (DESIGN.md §4.2).

Per-slot weights (continuous batching, DESIGN.md §9): weights may instead be
(K, B) — every batch row combines with its *own* column of weights, which is
what lets a heterogeneous slot batch sit at different rows of the solver
table. Same kernel body: the weight block index just follows the batch grid
coordinate instead of broadcasting column 0.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 16 * 128  # (sublane, lane)-aligned flat tile, valid for fp32 and bf16


def _kernel(w_ref, t_ref, o_ref):
    # t_ref: (K, 1, TILE); w_ref: (K, 1); o_ref: (1, TILE)
    acc = jnp.zeros((1, t_ref.shape[2]), jnp.float32)
    for k in range(t_ref.shape[0]):  # K is static and small (order + 2)
        acc = acc + w_ref[k, 0] * t_ref[k, :, :].astype(jnp.float32)
    o_ref[:, :] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_combine_batched(terms, weights, interpret: bool = False):
    """terms: (K, B, N) with arbitrary N; weights: (K,) or (K, B). Returns (B, N).

    Grid is (B, ceil(N / TILE)); the last column of the grid is a padded
    remainder tile whose out-of-bounds lanes Pallas masks on store. (K,)
    weights broadcast over the batch; (K, B) weights are per-slot — grid row b
    reads its own (K, 1) weight column.
    """
    K, B, N = terms.shape
    grid = (B, pl.cdiv(N, TILE))
    per_slot = weights.ndim == 2
    w = (weights if per_slot else weights.reshape(K, 1)).astype(jnp.float32)
    w_map = (lambda b, i: (0, b)) if per_slot else (lambda b, i: (0, 0))
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, 1), w_map),
            pl.BlockSpec((K, 1, TILE), lambda b, i: (0, b, i)),
        ],
        out_specs=pl.BlockSpec((1, TILE), lambda b, i: (b, i)),
        out_shape=jax.ShapeDtypeStruct((B, N), terms.dtype),
        interpret=interpret,
    )(w, terms)


def fused_combine_flat(terms, weights, interpret: bool = False):
    """terms: (K, N), arbitrary N; weights: (K,). Returns (N,)."""
    K, N = terms.shape
    return fused_combine_batched(
        terms.reshape(K, 1, N), weights, interpret=interpret
    ).reshape(N)
