"""Fused UniPC state update — Pallas TPU kernel.

The UniPC step is x_next = sum_k w_k * term_k over K = order+2 tensors (the
previous state, the anchor model output, and the difference buffer). The
reference implementations execute this as a chain of ~K pointwise ops, i.e.
K+1 HBM round-trips of the full state; at sampling time the state is the
entire image/latent batch, so the update is purely memory-bound. This kernel
streams each VMEM tile of all K terms once and writes the result once:
(K+1)/2x less HBM traffic than the op-chain (DESIGN.md §4).

Layout: terms (K, N) fp32/bf16, weights (K,) fp32 broadcast from SMEM-like
small VMEM block; grid over N tiles; TILE is a multiple of 128 lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 16 * 128  # (sublane, lane)-aligned flat tile


def _kernel(w_ref, t_ref, o_ref):
    # t_ref: (K, TILE); w_ref: (K, 1); o_ref: (TILE,)
    acc = jnp.zeros((t_ref.shape[1],), jnp.float32)
    for k in range(t_ref.shape[0]):  # K is static and small (order + 2)
        acc = acc + w_ref[k, 0] * t_ref[k, :].astype(jnp.float32)
    o_ref[:] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fused_combine_flat(terms, weights, interpret: bool = True):
    """terms: (K, N) with N % TILE == 0; weights: (K,). Returns (N,)."""
    K, N = terms.shape
    grid = (N // TILE,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((K, 1), lambda i: (0, 0)),
            pl.BlockSpec((K, TILE), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((TILE,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), terms.dtype),
        interpret=interpret,
    )(weights.reshape(K, 1).astype(jnp.float32), terms)
