"""Shared backend-dispatch policy for the kernel ops wrappers (DESIGN.md §5).

Every kernel package exposes the same three backends:

* ``"pallas"``    — the compiled Pallas kernel; the production path on TPU.
* ``"interpret"`` — the same kernel under the Pallas interpreter; correct on
  any platform, slow; what CI pins to exercise the real kernel code on CPU.
* ``"jnp"``       — the package's pure-jnp oracle; the right default
  off-TPU, where there is no Mosaic to compile against.

`resolve_backend` is the one implementation of the pin/force/auto
resolution all ops wrappers share; each package keeps its own `auto` choice
(platform-only for attention/adaLN, size-aware for the solver update).
"""

from __future__ import annotations

from typing import Callable

import jax

BACKENDS = ("pallas", "interpret", "jnp")


def platform_select(platform: str | None = None) -> str:
    """The platform-only auto policy: TPU gets the compiled kernel;
    everything else the jnp oracle — without Mosaic there is no compiled
    Pallas, and the interpreter is strictly for testing. Packages with a
    shape-aware policy (unipc_update's sub-tile cutoff) wrap this."""
    platform = platform or jax.default_backend()
    return "pallas" if platform == "tpu" else "jnp"


def resolve_backend(backend: str | None, force_pallas: bool,
                    auto: Callable[[], str]) -> str:
    """Resolve the backend for one ops call.

    `backend` pins one of BACKENDS (unknown values rejected); `force_pallas`
    (kept for tests and benchmarks) means "run the kernel even off-TPU",
    i.e. compiled on TPU, interpreted elsewhere; with neither, `auto()`
    supplies the package's platform/shape policy.
    """
    if backend is None:
        if force_pallas:
            return "pallas" if jax.default_backend() == "tpu" else "interpret"
        return auto()
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, got {backend!r}")
    return backend
