"""Noise schedules for diffusion ODEs in the (alpha_t, sigma_t, lambda_t) parametrization.

lambda_t = log(alpha_t / sigma_t) is the half log-SNR (Lu et al., 2022a); it is
strictly decreasing in t, so t_lambda is well defined. Host-side schedule math is
float64 numpy (feeds the UniPC coefficient tables); the few quantities needed
inside traced training code have jnp twins.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

__all__ = ["NoiseSchedule", "VPLinear", "VPCosine", "EDMSchedule", "timestep_grid"]


class NoiseSchedule:
    """Continuous-time schedule on t in [t_eps, T]."""

    T: float = 1.0
    t_eps: float = 1e-3

    # ---- host (numpy, float64) ----
    def log_alpha(self, t):
        raise NotImplementedError

    def alpha(self, t):
        return np.exp(self.log_alpha(np.asarray(t, np.float64)))

    def sigma(self, t):
        a = self.alpha(t)
        return np.sqrt(np.clip(1.0 - a * a, 1e-30, None))

    def lam(self, t):
        t = np.asarray(t, np.float64)
        la = self.log_alpha(t)
        return la - 0.5 * np.log(np.clip(1.0 - np.exp(2 * la), 1e-30, None))

    def t_of_lam(self, lam):
        raise NotImplementedError

    # ---- traced (jnp) ----
    def log_alpha_jax(self, t):
        raise NotImplementedError

    def alpha_sigma_jax(self, t):
        la = self.log_alpha_jax(t)
        a = jnp.exp(la)
        return a, jnp.sqrt(jnp.clip(1.0 - a * a, 1e-20, None))


@dataclass
class VPLinear(NoiseSchedule):
    """Variance-preserving linear-beta schedule (ScoreSDE / DDPM continuous)."""

    beta_0: float = 0.1
    beta_1: float = 20.0
    T: float = 1.0
    t_eps: float = 1e-3

    def log_alpha(self, t):
        t = np.asarray(t, np.float64)
        return -0.25 * t**2 * (self.beta_1 - self.beta_0) - 0.5 * t * self.beta_0

    def t_of_lam(self, lam):
        lam = np.asarray(lam, np.float64)
        # alpha^2 = sigmoid(2 lam)  ->  log alpha^2 = -softplus(-2 lam)
        log_a2 = -np.logaddexp(0.0, -2.0 * lam)
        d = self.beta_1 - self.beta_0
        return (-self.beta_0 + np.sqrt(self.beta_0**2 - 2.0 * d * log_a2)) / d

    def log_alpha_jax(self, t):
        return -0.25 * t**2 * (self.beta_1 - self.beta_0) - 0.5 * t * self.beta_0


@dataclass
class VPCosine(NoiseSchedule):
    """Cosine schedule (Nichol & Dhariwal, 2021), continuous form."""

    s: float = 0.008
    T: float = 0.9946  # keep beta bounded as in the iDDPM implementation
    t_eps: float = 1e-3

    def log_alpha(self, t):
        t = np.asarray(t, np.float64)
        f = np.cos((t + self.s) / (1 + self.s) * math.pi / 2)
        f0 = math.cos(self.s / (1 + self.s) * math.pi / 2)
        return np.log(np.clip(f / f0, 1e-30, None))

    def t_of_lam(self, lam):
        lam = np.asarray(lam, np.float64)
        log_a2 = -np.logaddexp(0.0, -2.0 * lam)
        f0 = math.cos(self.s / (1 + self.s) * math.pi / 2)
        f = np.exp(0.5 * log_a2) * f0
        return np.arccos(np.clip(f, -1.0, 1.0)) * 2 * (1 + self.s) / math.pi - self.s

    def log_alpha_jax(self, t):
        f = jnp.cos((t + self.s) / (1 + self.s) * math.pi / 2)
        f0 = math.cos(self.s / (1 + self.s) * math.pi / 2)
        return jnp.log(jnp.clip(f / f0, 1e-20, None))


@dataclass
class EDMSchedule(NoiseSchedule):
    """alpha = 1, sigma = t (Karras et al. style; lambda = -log t)."""

    T: float = 80.0
    t_eps: float = 0.002

    def log_alpha(self, t):
        return np.zeros_like(np.asarray(t, np.float64))

    def sigma(self, t):
        return np.asarray(t, np.float64)

    def lam(self, t):
        return -np.log(np.asarray(t, np.float64))

    def t_of_lam(self, lam):
        return np.exp(-np.asarray(lam, np.float64))

    def log_alpha_jax(self, t):
        return jnp.zeros_like(t)

    def alpha_sigma_jax(self, t):
        return jnp.ones_like(t), t


def timestep_grid(schedule: NoiseSchedule, num_steps: int, spacing: str = "logsnr"):
    """Return (t, lam, alpha, sigma) arrays of length num_steps+1 from T to t_eps.

    spacing: 'logsnr' (uniform in lambda — the DPM-Solver/UniPC default),
    'time_uniform', or 'time_quadratic'.
    """
    if spacing == "logsnr":
        lam_T = float(schedule.lam(schedule.T))
        lam_0 = float(schedule.lam(schedule.t_eps))
        lams = np.linspace(lam_T, lam_0, num_steps + 1)
        ts = schedule.t_of_lam(lams)
    elif spacing == "time_uniform":
        ts = np.linspace(schedule.T, schedule.t_eps, num_steps + 1)
    elif spacing == "time_quadratic":
        ts = np.linspace(schedule.T**0.5, schedule.t_eps**0.5, num_steps + 1) ** 2
    else:
        raise ValueError(spacing)
    ts = np.asarray(ts, np.float64)
    lams = schedule.lam(ts)
    return ts, lams, schedule.alpha(ts), schedule.sigma(ts)
