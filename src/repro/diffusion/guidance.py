"""Guided sampling: classifier-free guidance and dynamic thresholding (Sec. 3.4).

Two CFG forms:

* `cfg_model` — two sequential network evals per step (cond, then uncond);
  the reference semantics, used by the python-loop solvers.
* `cfg_model_fused` — ONE batched network eval per step: the caller provides
  an eps-net whose conditioning is already stacked `[cond; uncond]` along the
  batch, the guided eps is recombined from the two halves. This is what the
  engine compiles into the sampling scan (`repro.engine`), with the guidance
  scale riding the schedule table as a per-eval column (`guidance_schedule`).
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .process import eps_to_x0, x0_to_eps
from .schedules import NoiseSchedule

GUIDANCE_SCHEDULES = ("constant", "linear", "cosine")


def cfg_model(eps_cond: Callable, eps_uncond: Callable, scale: float):
    """epsilon_tilde = (1 + s) * eps_cond - s * eps_uncond (Ho & Salimans)."""

    def fn(x, t):
        return (1.0 + scale) * eps_cond(x, t) - scale * eps_uncond(x, t)

    return fn


def cfg_model_fused(eps_stacked: Callable):
    """Fused CFG: one batched eval per step instead of `cfg_model`'s two.

    eps_stacked(xx, t) must run the eps-net on a 2B batch whose conditioning
    is [cond_0..cond_{B-1}, null_0..null_{B-1}] (e.g. a DiT called with
    class_ids = concat([ids, null_ids])). The returned fn takes the guidance
    scale `g` as an argument so a per-step scale schedule can ride the scan's
    static table. Both `t` and `g` may be per-sample (B,) — the per-slot
    serving path, where each slot carries its own timestep and request-level
    guidance scale; t is then tiled to the 2B stacked batch and g broadcast
    over the sample dims. Extra keyword arguments (per-slot conditioning,
    e.g. class ids) pass through to eps_stacked untouched.
    """

    def fn(x, t, g, **extra):
        t = jnp.asarray(t)
        tt = jnp.concatenate([t, t], axis=0) if t.ndim == 1 else t
        ee = eps_stacked(jnp.concatenate([x, x], axis=0), tt, **extra)
        e_cond, e_uncond = jnp.split(ee, 2, axis=0)
        g = jnp.asarray(g)
        if g.ndim == 1:
            g = g.reshape(g.shape + (1,) * (e_cond.ndim - 1))
        return (1.0 + g) * e_cond - g * e_uncond

    return fn


def guidance_schedule(scale: float, n_evals: int, kind: str = "constant",
                      scale_end: Optional[float] = None) -> np.ndarray:
    """(n_evals,) per-eval guidance scales, host-side float64.

    'constant' holds `scale`; 'linear' / 'cosine' ramp from `scale` at the
    first eval to `scale_end` (default 0) at the last — low guidance late in
    the trajectory is the usual fidelity/diversity knob.
    """
    if kind not in GUIDANCE_SCHEDULES:
        raise ValueError(f"kind must be one of {GUIDANCE_SCHEDULES}, got {kind!r}")
    end = 0.0 if scale_end is None else float(scale_end)
    u = np.linspace(0.0, 1.0, n_evals)
    if kind == "constant":
        return np.full(n_evals, float(scale))
    if kind == "linear":
        return scale + (end - scale) * u
    return scale + (end - scale) * 0.5 * (1.0 - np.cos(np.pi * u))


def dynamic_threshold(x0, percentile: float = 0.995, floor: float = 1.0):
    """Imagen-style dynamic thresholding (Saharia et al., 2022): clip x0 to the
    per-sample `percentile` absolute value and rescale into [-floor, floor].
    `percentile` may be a (B,) array — per-slot percentiles in the
    continuous-batching step, each sample quantiled at its own level."""
    flat = jnp.abs(x0.reshape(x0.shape[0], -1))
    percentile = jnp.asarray(percentile)
    if percentile.ndim == 1:
        s = jax.vmap(lambda row, q: jnp.quantile(row, q))(flat, percentile)
    else:
        s = jnp.quantile(flat, percentile, axis=-1)
    s = jnp.maximum(s, floor).reshape((-1,) + (1,) * (x0.ndim - 1))
    return jnp.clip(x0, -s, s) / s * floor


def guided_data_model(
    schedule: NoiseSchedule,
    eps_cond: Callable,
    eps_uncond: Optional[Callable] = None,
    guidance_scale: float = 0.0,
    thresholding: bool = False,
    threshold_percentile: float = 0.995,
):
    """Data-prediction model with CFG + optional dynamic thresholding — the
    configuration the paper uses for conditional sampling (UniPC-B2, Table 9)."""
    eps = (
        cfg_model(eps_cond, eps_uncond, guidance_scale)
        if eps_uncond is not None and guidance_scale != 0.0
        else eps_cond
    )

    def fn(x, t):
        x0 = eps_to_x0(schedule, x, t, eps(x, t))
        if thresholding:
            x0 = dynamic_threshold(x0, threshold_percentile)
        return x0

    return fn
