"""Guided sampling: classifier-free guidance and dynamic thresholding (Sec. 3.4)."""

from __future__ import annotations

from typing import Callable, Optional

import jax.numpy as jnp

from .process import eps_to_x0, x0_to_eps
from .schedules import NoiseSchedule


def cfg_model(eps_cond: Callable, eps_uncond: Callable, scale: float):
    """epsilon_tilde = (1 + s) * eps_cond - s * eps_uncond (Ho & Salimans)."""

    def fn(x, t):
        return (1.0 + scale) * eps_cond(x, t) - scale * eps_uncond(x, t)

    return fn


def dynamic_threshold(x0, percentile: float = 0.995, floor: float = 1.0):
    """Imagen-style dynamic thresholding (Saharia et al., 2022): clip x0 to the
    per-sample `percentile` absolute value and rescale into [-floor, floor]."""
    flat = jnp.abs(x0.reshape(x0.shape[0], -1))
    s = jnp.quantile(flat, percentile, axis=-1)
    s = jnp.maximum(s, floor).reshape((-1,) + (1,) * (x0.ndim - 1))
    return jnp.clip(x0, -s, s) / s * floor


def guided_data_model(
    schedule: NoiseSchedule,
    eps_cond: Callable,
    eps_uncond: Optional[Callable] = None,
    guidance_scale: float = 0.0,
    thresholding: bool = False,
    threshold_percentile: float = 0.995,
):
    """Data-prediction model with CFG + optional dynamic thresholding — the
    configuration the paper uses for conditional sampling (UniPC-B2, Table 9)."""
    eps = (
        cfg_model(eps_cond, eps_uncond, guidance_scale)
        if eps_uncond is not None and guidance_scale != 0.0
        else eps_cond
    )

    def fn(x, t):
        x0 = eps_to_x0(schedule, x, t, eps(x, t))
        if thresholding:
            x0 = dynamic_threshold(x0, threshold_percentile)
        return x0

    return fn
