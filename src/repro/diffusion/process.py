"""Forward diffusion process, training losses, and prediction-type conversion."""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .schedules import NoiseSchedule


def q_sample(schedule: NoiseSchedule, x0, t, noise):
    """x_t = alpha_t x0 + sigma_t eps, with t broadcast over the batch."""
    a, s = schedule.alpha_sigma_jax(t)
    bshape = (-1,) + (1,) * (x0.ndim - 1)
    return a.reshape(bshape) * x0 + s.reshape(bshape) * noise


def diffusion_loss(schedule: NoiseSchedule, eps_model: Callable, x0, rng,
                   weighting: str = "uniform"):
    """E ||eps_theta(x_t, t) - eps||^2 with t ~ U[t_eps, T]."""
    rng_t, rng_e = jax.random.split(rng)
    bsz = x0.shape[0]
    t = jax.random.uniform(rng_t, (bsz,), minval=schedule.t_eps, maxval=schedule.T)
    noise = jax.random.normal(rng_e, x0.shape, x0.dtype)
    x_t = q_sample(schedule, x0, t, noise)
    pred = eps_model(x_t, t)
    err = (pred - noise) ** 2
    if weighting == "snr_trunc":  # min(SNR, 5) weighting
        a, s = schedule.alpha_sigma_jax(t)
        w = jnp.minimum((a / s) ** 2, 5.0).reshape((-1,) + (1,) * (x0.ndim - 1))
        err = err * w
    return jnp.mean(err)


def _bcast_t(coef, t, x):
    """Align a t-shaped coefficient with x: scalar t broadcasts as before; a
    (B,) per-sample t (the continuous-batching step, where every slot sits at
    its own timestep) gains trailing singleton dims to scale (B, ...) states."""
    if jnp.ndim(t) == 0:
        return coef
    return coef.reshape(coef.shape + (1,) * (jnp.ndim(x) - jnp.ndim(t)))


def eps_to_x0(schedule: NoiseSchedule, x_t, t, eps):
    """x0 = (x_t - sigma_t eps) / alpha_t (App. A.1). t: scalar or (B,)."""
    t = jnp.asarray(t)
    a, s = schedule.alpha_sigma_jax(t)
    return (x_t - _bcast_t(s, t, x_t) * eps) / _bcast_t(a, t, x_t)


def x0_to_eps(schedule: NoiseSchedule, x_t, t, x0):
    t = jnp.asarray(t)
    a, s = schedule.alpha_sigma_jax(t)
    return (x_t - _bcast_t(a, t, x_t) * x0) / _bcast_t(s, t, x_t)


def wrap_model(schedule: NoiseSchedule, eps_model: Callable, prediction: str):
    """Adapt a noise-prediction network to the solver's prediction type."""
    if prediction == "noise":
        return eps_model

    def data_model(x, t):
        return eps_to_x0(schedule, x, t, eps_model(x, t))

    return data_model
