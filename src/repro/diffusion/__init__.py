from .schedules import EDMSchedule, NoiseSchedule, VPCosine, VPLinear, timestep_grid
from .process import diffusion_loss, eps_to_x0, q_sample, wrap_model, x0_to_eps
from .guidance import (cfg_model, cfg_model_fused, dynamic_threshold,
                       guidance_schedule, guided_data_model)
from .gaussian import GaussianDPM, MixtureDPM, empirical_order
