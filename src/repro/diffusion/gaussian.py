"""Analytic Gaussian DPM — the order-of-accuracy instrument.

For x0 ~ N(mu, s^2 I) the marginal at time t is
q_t = N(alpha_t mu, (alpha_t^2 s^2 + sigma_t^2) I), so the exact noise
prediction (score * -sigma) is

    eps*(x, t) = sigma_t (x - alpha_t mu) / (alpha_t^2 s^2 + sigma_t^2).

The diffusion ODE becomes *linear* with a known solution: writing
v_t = alpha_t^2 s^2 + sigma_t^2, the exact ODE trajectory from (x_T, T) to t is

    x_t = alpha_t mu + sqrt(v_t / v_T) * (x_T - alpha_T mu)

(the probability-flow map of a Gaussian marginal family is affine and matches
the marginals' means/variances along the flow). This gives machine-precision
ground truth for measuring a solver's empirical order of convergence
(paper Thm 3.1 / Cor 3.2) without any pretrained network.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from .schedules import NoiseSchedule


@dataclass
class GaussianDPM:
    schedule: NoiseSchedule
    mu: float = 0.7
    s: float = 0.35

    def _v(self, t):
        a = self.schedule.alpha(t)
        sig = self.schedule.sigma(t)
        return a * a * self.s**2 + sig * sig

    def eps_model(self, x, t):
        """Exact noise prediction (host floats ok: t scalar)."""
        t = float(np.asarray(t))
        a = float(self.schedule.alpha(t))
        sig = float(self.schedule.sigma(t))
        return sig * (x - a * self.mu) / (a * a * self.s**2 + sig * sig)

    def exact_solution(self, x_T, t):
        """Exact probability-flow ODE solution at time t from x_T at T."""
        t_T = self.schedule.T
        a_t = float(self.schedule.alpha(t))
        a_T = float(self.schedule.alpha(t_T))
        ratio = np.sqrt(float(self._v(t)) / float(self._v(t_T)))
        return a_t * self.mu + ratio * (x_T - a_T * self.mu)


@dataclass
class MixtureDPM:
    """Gaussian-mixture data distribution — exact eps via the closed-form
    mixture score. No closed ODE solution; the reference trajectory is a
    999-step DDIM exactly as in the paper's Fig. 4c protocol. Component 0
    doubles as the 'conditional' model for classifier-free guidance benches."""

    schedule: NoiseSchedule
    mus: tuple = (-1.0, 1.2)
    ss: tuple = (0.3, 0.5)
    ws: tuple = (0.35, 0.65)

    def eps_model(self, x, t):
        t = float(np.asarray(t))
        a = float(self.schedule.alpha(t))
        sig = float(self.schedule.sigma(t))
        x = np.asarray(x, np.float64)
        # responsibilities and per-component eps
        log_rho = []
        comp_eps = []
        for mu, s, w in zip(self.mus, self.ss, self.ws):
            v = a * a * s * s + sig * sig
            log_rho.append(np.log(w) - 0.5 * np.log(v)
                           - 0.5 * (x - a * mu) ** 2 / v)
            comp_eps.append(sig * (x - a * mu) / v)
        log_rho = np.stack(log_rho)
        log_rho -= log_rho.max(axis=0, keepdims=True)
        rho = np.exp(log_rho)
        rho /= rho.sum(axis=0, keepdims=True)
        return (rho * np.stack(comp_eps)).sum(axis=0)

    def component_eps_model(self, idx: int):
        comp = GaussianDPM(self.schedule, mu=self.mus[idx], s=self.ss[idx])
        return comp.eps_model


def empirical_order(errors, step_counts):
    """Fit slope of log(err) vs log(1/M): the measured order of convergence."""
    x = np.log(1.0 / np.asarray(step_counts, dtype=np.float64))
    y = np.log(np.asarray(errors, dtype=np.float64))
    return float(np.polyfit(x, y, 1)[0])
