"""Structured event tracer -> Chrome/Perfetto ``trace_event`` JSON.

Zero-dependency (stdlib only): the tracer is a bounded ring buffer of event
records the serving hot path appends tuples into; all formatting happens at
export time, so an *enabled* tracer costs one `deque.append` per event plus
whatever timestamps the caller already took (the scheduler reuses the
`perf_counter_ns` reads it takes for host-overhead accounting — tracing adds
no extra clock calls on the tick path). A *disabled* tracer is simply absent:
every call site is guarded by ``if tracer is not None``, so the off path is
bit-identical to pre-instrumentation code (pinned in `tests/test_obs.py`).

Event model (DESIGN.md §15):

* **Tick spans** — complete ("ph": "X") events on the scheduler thread
  track: ``tick`` encloses the per-phase children ``admission`` /
  ``dispatch`` / ``readback`` / ``emit``. Nesting is by timestamp
  containment, exactly how chrome://tracing renders stacks.
* **Request lifecycle spans** — async events keyed by rid: "b" at submit,
  "n" instants at admit / segment boundaries, "e" at emission, carrying the
  request's tier, eval_cost, evals, and latency in the args.
* **Counter tracks** — "C" events (queue depth, busy slots) render as the
  stacked area charts above the tick track.

Export is the Chrome `trace_event` JSON object format
(`{"traceEvents": [...], "displayTimeUnit": "ms", "otherData": {...}}`),
which chrome://tracing and ui.perfetto.dev open directly. `validate_trace`
checks the schema (used by `launch/obsreport.py --check` and the CI
obs-smoke job).
"""

from __future__ import annotations

import json
import time
from collections import deque
from typing import Dict, List, Optional

# record layouts appended into the ring (tuples keep the hot-path append
# cheap; export expands them into trace_event dicts):
#   ("X", name, cat, t0_ns, t1_ns, args)
#   ("I", name, cat, ts_ns, args)
#   ("C", name, ts_ns, values)
#   ("b"|"n"|"e", name, cat, id, ts_ns, args)
_ASYNC_PHASES = ("b", "n", "e")


class Tracer:
    """Bounded ring buffer of structured serving events.

    `capacity` bounds memory: when full, the OLDEST events are dropped (the
    tail of a long run is usually what you are debugging) and the drop count
    is reported in the export's `otherData.dropped_events` so a truncated
    trace is never mistaken for a complete one.

    Timestamps are `time.perf_counter_ns` values; callers that already take
    them (the scheduler's host-overhead accounting) pass them in, everything
    else defaults to now. Export normalizes to microseconds since the
    tracer's construction (the `ts`/`dur` unit chrome://tracing expects).
    """

    def __init__(self, capacity: int = 1 << 16,
                 meta: Optional[dict] = None):
        if capacity < 1:
            raise ValueError(f"tracer capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._ring: deque = deque(maxlen=self.capacity)
        self._appended = 0
        self._t0 = time.perf_counter_ns()
        self.meta = dict(meta or {})

    # -- recording (hot path) ------------------------------------------------
    def _push(self, rec) -> None:
        self._ring.append(rec)
        self._appended += 1

    def complete(self, name: str, t0_ns: int, t1_ns: int, cat: str = "tick",
                 args: Optional[dict] = None) -> None:
        """One complete ("X") span from explicit perf_counter_ns stamps."""
        self._push(("X", name, cat, t0_ns, t1_ns, args))

    def instant(self, name: str, cat: str = "tick",
                args: Optional[dict] = None,
                ts_ns: Optional[int] = None) -> None:
        self._push(("I", name, cat,
                    time.perf_counter_ns() if ts_ns is None else ts_ns, args))

    def counter(self, name: str, values: Dict[str, float],
                ts_ns: Optional[int] = None) -> None:
        """A counter ("C") sample: {series: value} rendered as stacked areas."""
        self._push(("C", name,
                    time.perf_counter_ns() if ts_ns is None else ts_ns,
                    dict(values)))

    def async_begin(self, name: str, id: int, cat: str = "request",
                    args: Optional[dict] = None,
                    ts_ns: Optional[int] = None) -> None:
        self._push(("b", name, cat, id,
                    time.perf_counter_ns() if ts_ns is None else ts_ns, args))

    def async_instant(self, name: str, id: int, cat: str = "request",
                      args: Optional[dict] = None,
                      ts_ns: Optional[int] = None) -> None:
        self._push(("n", name, cat, id,
                    time.perf_counter_ns() if ts_ns is None else ts_ns, args))

    def async_end(self, name: str, id: int, cat: str = "request",
                  args: Optional[dict] = None,
                  ts_ns: Optional[int] = None) -> None:
        self._push(("e", name, cat, id,
                    time.perf_counter_ns() if ts_ns is None else ts_ns, args))

    # -- export --------------------------------------------------------------
    @property
    def dropped(self) -> int:
        """Events evicted by the ring (0 for a complete trace)."""
        return self._appended - len(self._ring)

    def _us(self, ts_ns: int) -> float:
        return (ts_ns - self._t0) / 1e3

    def events(self) -> List[dict]:
        """Ring contents as chrome trace_event dicts (ts/dur in us)."""
        out: List[dict] = []
        for rec in self._ring:
            ph = rec[0]
            if ph == "X":
                _, name, cat, t0, t1, args = rec
                ev = {"name": name, "cat": cat, "ph": "X",
                      "ts": self._us(t0), "dur": max((t1 - t0) / 1e3, 0.0),
                      "pid": 0, "tid": 0}
            elif ph == "I":
                _, name, cat, ts, args = rec
                ev = {"name": name, "cat": cat, "ph": "i", "s": "t",
                      "ts": self._us(ts), "pid": 0, "tid": 0}
            elif ph == "C":
                _, name, ts, values = rec
                ev = {"name": name, "cat": "counter", "ph": "C",
                      "ts": self._us(ts), "pid": 0, "tid": 0, "args": values}
                out.append(ev)
                continue
            else:  # async b / n / e
                _, name, cat, id_, ts, args = rec
                ev = {"name": name, "cat": cat, "ph": ph,
                      "id": int(id_), "ts": self._us(ts), "pid": 0, "tid": 0}
            if args:
                ev["args"] = dict(args)
            out.append(ev)
        return out

    def to_json(self) -> dict:
        return {
            "traceEvents": self.events(),
            "displayTimeUnit": "ms",
            "otherData": {**self.meta,
                          "schema": TRACE_SCHEMA,
                          "dropped_events": self.dropped},
        }

    def export(self, path: str) -> dict:
        """Write the Chrome trace_event JSON artifact; returns the object."""
        obj = self.to_json()
        with open(path, "w") as f:
            json.dump(obj, f)
        return obj


TRACE_SCHEMA = "repro.obs.trace/v1"
_VALID_PH = {"X", "i", "C", "b", "n", "e"}


def validate_trace(obj: dict) -> List[str]:
    """Schema-check a trace artifact; returns a list of violations (empty =
    valid). Checked: top-level shape, per-event required keys, non-negative
    X durations, and — when no events were dropped from the ring — balanced
    async begin/end pairs per (cat, name, id)."""
    errs: List[str] = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["not a trace_event object: missing 'traceEvents'"]
    events = obj["traceEvents"]
    if not isinstance(events, list):
        return ["'traceEvents' is not a list"]
    dropped = (obj.get("otherData") or {}).get("dropped_events", 0)
    balance: Dict[tuple, int] = {}
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            errs.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _VALID_PH:
            errs.append(f"event {i}: bad ph {ph!r}")
            continue
        if not isinstance(ev.get("name"), str):
            errs.append(f"event {i}: missing name")
        if not isinstance(ev.get("ts"), (int, float)):
            errs.append(f"event {i}: missing ts")
        if ph == "X":
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                errs.append(f"event {i} ({ev.get('name')}): X span needs "
                            f"dur >= 0, got {ev.get('dur')!r}")
        if ph in _ASYNC_PHASES:
            if "id" not in ev:
                errs.append(f"event {i} ({ev.get('name')}): async event "
                            f"needs an id")
            else:
                key = (ev.get("cat"), ev["id"])
                balance[key] = balance.get(key, 0) + {"b": 1, "e": -1,
                                                      "n": 0}[ph]
    if not dropped:
        for key, n in sorted(balance.items()):
            if n != 0:
                errs.append(f"async events {key}: {abs(n)} unbalanced "
                            f"{'begin' if n > 0 else 'end'}(s)")
    return errs
