"""Streaming metrics registry: counters, gauges, fixed-bucket histograms.

No new dependencies (stdlib + numpy, no jax): the registry is the one
accounting substrate of the serving stack — the scheduler feeds it every
tick and `serving.server.run_trace` derives its final `ServeMetrics` FROM a
registry snapshot delta, so the live numbers and the end-of-run report are
one code path by construction (`tests/test_obs.py` pins registry-derived ==
legacy arithmetic).

Three metric kinds:

* `Counter` — monotone accumulator (ticks, evals, completions, per-phase
  host nanoseconds).
* `Gauge` — last-value (makespan clock, probe discrepancy per tier).
* `Histogram` — fixed upper-bound buckets (+inf tail) for the streaming /
  Prometheus view, PLUS the exact observation list, because the serving
  report quotes exact percentiles (`np.percentile` over the samples) and the
  determinism tests demand bit-identical state across pipeline depths.
  `sample_cap` bounds the list for long-lived registries; once capped,
  exact percentiles degrade to bucket state (`samples_truncated` is set so
  a report can say so).

Every metric is created with ``wall=True`` or ``False`` (default): wall
metrics measure host time and are excluded from
``snapshot(deterministic_only=True)`` — the slice that must be bit-identical
across `--pipeline-depth` 1/2/3 on the same admission schedule.

`snapshot()` returns a plain JSON-able dict; `delta(before, after)` subtracts
two snapshots (counters and histogram state subtract; gauges keep the later
value), which is how a reused scheduler reports one run's numbers.
`exposition()` renders the Prometheus text format.
"""

from __future__ import annotations

from bisect import bisect_left
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

METRICS_SCHEMA = "repro.obs.metrics/v1"

_Labels = Tuple[Tuple[str, str], ...]


def _labels_key(labels: Optional[dict]) -> _Labels:
    return tuple(sorted((str(k), str(v))
                        for k, v in (labels or {}).items()))


def _fullname(name: str, labels: _Labels) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n=1) -> None:
        self.value += n


class Gauge:
    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v) -> None:
        self.value = v


class Histogram:
    __slots__ = ("buckets", "counts", "sum", "count", "samples",
                 "sample_cap", "samples_truncated")

    def __init__(self, buckets: Sequence[float],
                 sample_cap: Optional[int] = None):
        b = tuple(float(x) for x in buckets)
        if not b or any(b[i] >= b[i + 1] for i in range(len(b) - 1)):
            raise ValueError(f"histogram buckets must be strictly "
                             f"ascending upper bounds, got {buckets}")
        self.buckets = b
        self.counts = [0] * (len(b) + 1)  # last bucket = +inf
        self.sum = 0.0
        self.count = 0
        self.samples: List[float] = []
        self.sample_cap = sample_cap
        self.samples_truncated = False

    def observe(self, v) -> None:
        v = float(v)
        self.counts[bisect_left(self.buckets, v)] += 1
        self.sum += v
        self.count += 1
        if self.sample_cap is None or len(self.samples) < self.sample_cap:
            self.samples.append(v)
        else:
            self.samples_truncated = True

    def percentile(self, q: float) -> float:
        """Exact percentile over the retained samples; 0.0 when empty (the
        zero-completion guard — never an IndexError from np.percentile)."""
        if not self.samples:
            return 0.0
        return float(np.percentile(self.samples, q))


class MetricsRegistry:
    """Get-or-create registry keyed by (name, sorted labels)."""

    def __init__(self):
        self._metrics: Dict[Tuple[str, _Labels], object] = {}
        self._meta: Dict[Tuple[str, _Labels], dict] = {}

    def _get(self, kind, name, labels, wall, help, **kw):
        key = (name, _labels_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = kind(**kw)
            self._metrics[key] = m
            self._meta[key] = {"type": kind.__name__.lower(),
                               "wall": bool(wall), "help": help or ""}
        elif not isinstance(m, kind):
            raise ValueError(f"metric {_fullname(name, key[1])} already "
                             f"registered as {type(m).__name__}")
        return m

    def counter(self, name: str, labels: Optional[dict] = None, *,
                wall: bool = False, help: str = "") -> Counter:
        return self._get(Counter, name, labels, wall, help)

    def gauge(self, name: str, labels: Optional[dict] = None, *,
              wall: bool = False, help: str = "") -> Gauge:
        return self._get(Gauge, name, labels, wall, help)

    def histogram(self, name: str, buckets: Sequence[float],
                  labels: Optional[dict] = None, *, wall: bool = False,
                  help: str = "",
                  sample_cap: Optional[int] = None) -> Histogram:
        return self._get(Histogram, name, labels, wall, help,
                         buckets=buckets, sample_cap=sample_cap)

    # -- snapshots -----------------------------------------------------------
    def snapshot(self, deterministic_only: bool = False,
                 include_samples: bool = True) -> Dict[str, dict]:
        """JSON-able state of every metric, keyed by the Prometheus-style
        full name. `deterministic_only` drops wall-clock metrics (the
        cross-pipeline-depth equality slice); `include_samples=False` drops
        the exact observation lists (the compact periodic-row form)."""
        out: Dict[str, dict] = {}
        for key in sorted(self._metrics):
            meta = self._meta[key]
            if deterministic_only and meta["wall"]:
                continue
            m = self._metrics[key]
            row = {"type": meta["type"], "wall": meta["wall"]}
            if isinstance(m, Histogram):
                row.update(buckets=list(m.buckets), counts=list(m.counts),
                           sum=m.sum, count=m.count,
                           samples_truncated=m.samples_truncated)
                if include_samples:
                    row["samples"] = list(m.samples)
            else:
                row["value"] = m.value
            out[_fullname(*key)] = row
        return out

    def exposition(self) -> str:
        """Prometheus text exposition (counters get the `_total`-as-named
        convention left to the caller's metric names; histograms render
        cumulative `_bucket{le=...}` series plus `_sum`/`_count`)."""
        lines: List[str] = []
        seen_type: Dict[str, str] = {}
        for key in sorted(self._metrics):
            name, labels = key
            m = self._metrics[key]
            meta = self._meta[key]
            if name not in seen_type:
                if meta["help"]:
                    lines.append(f"# HELP {name} {meta['help']}")
                lines.append(f"# TYPE {name} {meta['type']}")
                seen_type[name] = meta["type"]
            if isinstance(m, Histogram):
                cum = 0
                for ub, c in zip(m.buckets + (float("inf"),), m.counts):
                    cum += c
                    le = "+Inf" if ub == float("inf") else f"{ub:g}"
                    lbl = labels + (("le", le),)
                    lines.append(f"{_fullname(name + '_bucket', lbl)} {cum}")
                lines.append(f"{_fullname(name + '_sum', labels)} {m.sum:g}")
                lines.append(f"{_fullname(name + '_count', labels)} "
                             f"{m.count}")
            else:
                v = m.value
                lines.append(f"{_fullname(name, labels)} "
                             f"{v:g}" if isinstance(v, float)
                             else f"{_fullname(name, labels)} {v}")
        return "\n".join(lines) + "\n"


def delta(before: Dict[str, dict], after: Dict[str, dict]) -> Dict[str, dict]:
    """Subtract two snapshots: counter values and histogram counts/sums
    subtract, histogram samples keep the tail appended since `before`, and
    gauges keep the `after` value (last-write-wins semantics). Metrics absent
    from `before` pass through unchanged — they were created during the run."""
    out: Dict[str, dict] = {}
    for full, row in after.items():
        prev = before.get(full)
        if prev is None or row["type"] == "gauge":
            out[full] = dict(row)
            continue
        d = dict(row)
        if row["type"] == "counter":
            d["value"] = row["value"] - prev["value"]
        else:  # histogram
            d["counts"] = [a - b for a, b in zip(row["counts"],
                                                 prev["counts"])]
            d["sum"] = row["sum"] - prev["sum"]
            d["count"] = row["count"] - prev["count"]
            if "samples" in row:
                d["samples"] = row["samples"][len(prev.get("samples", [])):]
        out[full] = d
    return out


def parse_fullname(full: str) -> Tuple[str, Dict[str, str]]:
    """Invert `_fullname`: 'name{k="v",...}' -> (name, {k: v}). Label values
    are the simple identifiers this stack uses (tier names, phase names) —
    no escaping grammar."""
    if "{" not in full:
        return full, {}
    name, rest = full.split("{", 1)
    labels: Dict[str, str] = {}
    for part in rest.rstrip("}").split(","):
        k, v = part.split("=", 1)
        labels[k] = v.strip('"')
    return name, labels


def snapshot_percentile(row: dict, q: float) -> float:
    """Exact percentile from a snapshot histogram row (0.0 when empty)."""
    samples = row.get("samples") or []
    if not samples:
        return 0.0
    return float(np.percentile(samples, q))


def validate_metrics(obj: dict) -> List[str]:
    """Schema-check a metrics artifact written by
    `obs.report.write_metrics_artifact`; returns violations (empty = valid)."""
    errs: List[str] = []
    if not isinstance(obj, dict):
        return ["metrics artifact is not an object"]
    if obj.get("schema") != METRICS_SCHEMA:
        errs.append(f"schema is {obj.get('schema')!r}, "
                    f"expected {METRICS_SCHEMA!r}")
    run = obj.get("run")
    if not isinstance(run, dict) or "metrics" not in run:
        errs.append("missing 'run.metrics' (the end-of-run snapshot delta)")
        return errs
    for full, row in run["metrics"].items():
        t = row.get("type")
        if t not in ("counter", "gauge", "histogram"):
            errs.append(f"{full}: bad type {t!r}")
        elif t == "histogram":
            if len(row.get("counts", [])) != len(row.get("buckets", [])) + 1:
                errs.append(f"{full}: counts/buckets length mismatch")
            if row.get("count") != sum(row.get("counts", [])):
                errs.append(f"{full}: count != sum(counts)")
        elif "value" not in row:
            errs.append(f"{full}: missing value")
    for name in ("serve_metrics", "exposition"):
        if name not in obj:
            errs.append(f"missing '{name}'")
    if not isinstance(obj.get("rows", []), list):
        errs.append("'rows' is not a list")
    return errs
