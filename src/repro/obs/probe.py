"""Solver-quality telemetry: serving-time trajectory-discrepancy probe.

The tuning stack scores plans offline against a high-NFE reference run
(`tuning/objective.py`); this module moves the same measurement into
serving. A `QualityProbe` deterministically samples a fraction of COMPLETED
requests, replays each one's initial latent through a high-NFE UniPC
reference runner (fp32, unquantized, uncached — the converged trajectory),
and records the served latent's relative discrepancy

    d = || x0_served - x0_ref ||_2 / max(|| x0_ref ||_2, 1e-12)

as per-tier gauges/histograms in the metrics registry. An over-quantized or
over-cached tier that passed its tune-time parity gate but drifts in
production is then visible in the serving metrics, not only at tune time.

Cost model: each probed request pays one `ref_nfe`-eval batch-1 reference
run on the host thread, which is why the probe is opt-in
(`--probe-fraction 0`, the default, never builds it) and why selection is a
deterministic hash of the rid — the same trace probes the same requests at
every pipeline depth, keeping probe metrics inside the deterministic
snapshot slice.
"""

from __future__ import annotations

from dataclasses import replace as dc_replace
from typing import Callable, List, Optional

import numpy as np

PROBE_BUCKETS = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1, 1.0)


def build_reference_fn(engine, spec, *, ref_nfe: int = 64,
                       ref_order: int = 3) -> Callable:
    """A jitted high-NFE reference runner with per-request conditioning.

    `tuning.objective.reference_trajectory` serves the unconditional tuning
    path (`engine.build` on the reference spec); serving requests also carry
    per-request guidance scales and conditioning extras (class ids), so this
    runner threads them through `step_fn_over_rows`'s `model_kwargs` — the
    same mechanism the serving step program uses — instead of the scan's
    baked table columns.

    `engine` must be wired fp32 / quant="none" / cache_block=0 (the
    reference measures the solver+schedule, not the serving engine's
    precision tricks); the spec handshake in `model_fn` enforces it.
    Returns `reference(x_T, g=None, extras=None) -> np.ndarray` over a
    (B, *sample) batch; `g` is one scalar guidance scale for the batch
    (None -> the spec's nominal), `extras` maps conditioning keys to scalars
    or (B,) arrays.
    """
    import jax
    import jax.numpy as jnp

    from ..core.coeffs import augment_step_rows
    from ..core.unipc import step_fn_over_rows
    from ..engine.compiler import step_guidance_profile

    ref_spec = dc_replace(spec.resolve(), solver="unipc", nfe=ref_nfe,
                          order=ref_order, prediction=None,
                          eval_dtype="float32", quant="none",
                          cache_block=0).resolve()
    tab = engine.compile(ref_spec)
    model = engine.model_fn(ref_spec, tab)
    rows_np = augment_step_rows(tab)
    uses_cfg = bool(ref_spec.cfg_scale)
    if uses_cfg:
        # per-request scale x the schedule's shape, exactly like the serving
        # step program (engine._step_program): drop the absolute g column
        prof = jnp.asarray(step_guidance_profile(tab, ref_spec), jnp.float32)
        rows_np = {k: v for k, v in rows_np.items() if k != "mc_g"}
    rows = {k: jnp.asarray(v, jnp.float32) for k, v in rows_np.items()}
    step = step_fn_over_rows(model, rows, sign=float(tab.sign),
                             fused_update=ref_spec.fused_update)
    n_rows = int(rows["t"].shape[0])
    K = int(rows["w_pred"].shape[-1])
    nominal = float(ref_spec.cfg_scale or 0.0)

    @jax.jit
    def run(x_T, g, extras):
        E0 = jnp.zeros((K + 1,) + x_T.shape, x_T.dtype)

        def body(carry, j):
            kw = dict(extras)
            if uses_cfg:
                kw["g"] = g * prof[j]
            return step(carry, j, model_kwargs=kw or None), None

        carry, _ = jax.lax.scan(body, (x_T, E0), jnp.arange(n_rows))
        return carry[0]

    def reference(x_T, g=None, extras=None):
        x_T = jnp.asarray(x_T, jnp.float32)
        B = x_T.shape[0]
        gv = jnp.full((B,), nominal if g is None else float(g), jnp.float32)
        ex = {}
        for k, v in (extras or {}).items():
            a = np.asarray(v)
            dt = jnp.int32 if np.issubdtype(a.dtype, np.integer) \
                else jnp.float32
            ex[k] = jnp.full((B,), v, dt) if a.ndim == 0 \
                else jnp.asarray(a, dt)
        return np.asarray(run(x_T, gv, ex))

    return reference


def probe_selected(rid: int, fraction: float, salt: int = 0) -> bool:
    """Deterministic rid -> [0, 1) hash against the probe fraction: the same
    requests are probed on every run / pipeline depth of the same trace
    (Knuth multiplicative hash; no RNG state, no draw-order dependence)."""
    if fraction <= 0.0:
        return False
    if fraction >= 1.0:
        return True
    u = ((int(rid) * 2654435761 + int(salt) * 40503) % (1 << 32)) / (1 << 32)
    return u < fraction


class QualityProbe:
    """Replay sampled completions against the reference runner.

    reference_fn: `build_reference_fn`'s closure (or any
        (x_T, g, extras) -> x0_ref batch callable).
    fraction: probability a completed rid is probed (deterministic in rid).
    registry: optional `obs.metrics.MetricsRegistry` receiving, per tier
        label: `probe_requests` (counter), `probe_discrepancy` (last-value
        gauge), `probe_discrepancy_hist` (histogram over PROBE_BUCKETS).
    tracer: optional `obs.trace.Tracer`; each probe emits an instant event
        carrying rid / tier / discrepancy.
    max_probes: hard cap on replays per run (the probe is a sampled
        diagnostic, not a second serving workload).
    """

    def __init__(self, reference_fn: Callable, fraction: float,
                 registry=None, tracer=None, salt: int = 0,
                 max_probes: Optional[int] = None):
        if not (0.0 <= fraction <= 1.0):
            raise ValueError(f"probe fraction must be in [0, 1], "
                             f"got {fraction}")
        self.reference_fn = reference_fn
        self.fraction = float(fraction)
        self.registry = registry
        self.tracer = tracer
        self.salt = int(salt)
        self.max_probes = max_probes
        self.results: List[dict] = []

    def selected(self, rid: int) -> bool:
        if self.max_probes is not None and len(self.results) >= self.max_probes:
            return False
        return probe_selected(rid, self.fraction, self.salt)

    def observe(self, req, completion, x_T) -> Optional[float]:
        """Measure one completion's discrepancy (caller pre-filters with
        `selected`); returns d, or None if the rid was not sampled."""
        if not self.selected(completion.rid):
            return None
        x_T = np.asarray(x_T)[None]
        x_ref = np.asarray(self.reference_fn(
            x_T, g=req.cfg_scale, extras=req.extras))[0]
        served = np.asarray(completion.latent, np.float32)
        d = float(np.linalg.norm(served - x_ref)
                  / max(float(np.linalg.norm(x_ref)), 1e-12))
        tier = completion.tier or "default"
        self.results.append({"rid": completion.rid, "tier": tier,
                             "discrepancy": d,
                             "eval_cost": completion.eval_cost})
        if self.registry is not None:
            lbl = {"tier": tier}
            self.registry.counter(
                "probe_requests", lbl,
                help="completed requests replayed by the quality probe").inc()
            self.registry.gauge(
                "probe_discrepancy", lbl,
                help="latest trajectory discrepancy vs the high-NFE "
                     "reference").set(d)
            self.registry.histogram(
                "probe_discrepancy_hist", PROBE_BUCKETS, lbl,
                help="trajectory discrepancy distribution").observe(d)
        if self.tracer is not None:
            self.tracer.instant("probe", cat="quality",
                                args={"rid": completion.rid, "tier": tier,
                                      "discrepancy": d})
        return d

    def summary(self) -> dict:
        """{tier: {count, mean, max}} over everything probed so far."""
        by_tier: dict = {}
        for r in self.results:
            by_tier.setdefault(r["tier"], []).append(r["discrepancy"])
        return {t: {"count": len(ds),
                    "mean": float(np.mean(ds)),
                    "max": float(np.max(ds))}
                for t, ds in sorted(by_tier.items())}
