"""Render observability artifacts into human-readable reports.

`launch/obsreport.py` drives this module: given the trace and/or metrics
artifacts a serve run exported (`--trace-out` / `--metrics-out`), it renders
the DESIGN §11 "where a tick goes" breakdown from *measured* per-phase data
instead of by hand, plus per-tier serving rows, quality-probe drift, and
aggregated span statistics from the Chrome trace. Everything here is pure
text over JSON-able dicts — no jax, no scheduler imports — so a saved
artifact from any run renders anywhere.
"""

from __future__ import annotations

import json
from typing import Dict, List, Optional

from .metrics import METRICS_SCHEMA, parse_fullname


def write_metrics_artifact(path: str, *, metrics: Dict[str, dict],
                           serve_metrics: dict, static: dict,
                           exposition: str,
                           rows: Optional[List[dict]] = None,
                           probe: Optional[dict] = None) -> dict:
    """Write the metrics artifact (`obs.metrics.validate_metrics` schema).

    metrics: the run's registry snapshot delta (with samples — exact
        percentile reproduction is part of the artifact's contract).
    serve_metrics: the derived `ServeMetrics.row()` dict.
    static: the derivation's non-registry inputs ({mode, slots, n_rows,
        pipeline_depth}), so `serve_metrics_from_snapshot` can be re-run on
        the artifact alone (`obsreport --check`).
    exposition: the Prometheus text dump of the registry.
    rows: optional periodic snapshot rows (`run_trace(snapshot_every=...)`).
    probe: optional quality-probe summary ({tier: {count, mean, max}}).
    """
    obj = {
        "schema": METRICS_SCHEMA,
        "run": {"static": dict(static), "metrics": metrics},
        "serve_metrics": serve_metrics,
        "rows": list(rows or []),
        "exposition": exposition,
    }
    if probe is not None:
        obj["probe"] = probe
    with open(path, "w") as f:
        json.dump(obj, f, indent=1)
    return obj


def _fmt_us(ns_or_us: float) -> str:
    return f"{ns_or_us:10.1f}"


def render_tick_table(serve_metrics: dict) -> str:
    """The "where a tick goes" table (DESIGN §11 / §15), from measured
    per-phase host counters: µs per executed tick and the share of the
    fenced tick wall each phase accounts for."""
    phases = serve_metrics.get("host_phase_us_per_tick") or {}
    tick_us = float(serve_metrics.get("tick_s") or 0.0) * 1e6
    lines = ["where a tick goes (measured, per executed tick):",
             f"  {'phase':<14} {'us/tick':>10}   share of tick"]
    known = sum(phases.values())
    for name in ("admission", "dispatch", "readback", "bookkeeping"):
        us = float(phases.get(name, 0.0))
        share = f"{us / tick_us * 100:5.1f}%" if tick_us > 0 else "    --"
        lines.append(f"  {name:<14} {_fmt_us(us)}   {share}")
    if tick_us > 0:
        # at depth 1 the fenced tick wall also covers device execution the
        # dispatch call handed off asynchronously — report the remainder
        other = max(tick_us - known, 0.0)
        lines.append(f"  {'(device/other)':<14} {_fmt_us(other)}   "
                     f"{other / tick_us * 100:5.1f}%")
        lines.append(f"  {'tick wall':<14} {_fmt_us(tick_us)}   100.0%")
    host = serve_metrics.get("host_us_per_tick")
    if host is not None:
        lines.append(f"  host bookkeeping (admission + bookkeeping): "
                     f"{float(host):.1f} us/tick")
    return "\n".join(lines)


def render_serve_summary(serve_metrics: dict) -> str:
    m = serve_metrics
    lines = [
        f"serve run: mode={m.get('mode')} slots={m.get('slots')} "
        f"depth={m.get('pipeline_depth')} n_rows={m.get('n_rows')}",
        f"  requests {m.get('requests')}  completed {m.get('completed')}  "
        f"ticks {m.get('ticks')}  evals {m.get('evals')}",
        f"  occupancy {float(m.get('occupancy') or 0.0):.3f}  "
        f"evals/latent {float(m.get('evals_per_latent') or 0.0):.2f}  "
        f"makespan {float(m.get('makespan_ticks') or 0.0):.1f} ticks",
        f"  latency p50/p95 {float(m.get('latency_ticks_p50') or 0.0):.1f}/"
        f"{float(m.get('latency_ticks_p95') or 0.0):.1f} ticks  "
        f"throughput {float(m.get('throughput_rps') or 0.0):.2f} req/s",
    ]
    per_tier = m.get("per_tier")
    if per_tier:
        lines.append(f"  {'tier':<10} {'done':>5} {'evals':>6} "
                     f"{'cost':>7} {'lat p50':>8}")
        for t, row in sorted(per_tier.items()):
            lines.append(f"  {t:<10} {row.get('completed', 0):>5} "
                         f"{row.get('evals', 0):>6} "
                         f"{float(row.get('eval_cost') or 0.0):>7.2f} "
                         f"{float(row.get('latency_ticks_p50') or 0.0):>8.1f}")
    return "\n".join(lines)


def render_resilience(serve_metrics: dict,
                      metrics: Optional[Dict[str, dict]] = None) -> str:
    """The fault/resilience ledger (DESIGN §16): what was shed, retried,
    failed and recovered, with the per-label breakdown (rejection reasons,
    injected fault kinds) read back out of the registry delta. Returns ""
    when the run saw no resilience event at all — fault-free reports are
    unchanged."""
    m = serve_metrics
    keys = ("rejected", "expired", "degraded", "retries", "failed",
            "recoveries", "faults_injected")
    if not any(int(m.get(k) or 0) for k in keys):
        return ""
    lines = ["resilience ledger (DESIGN §16):",
             f"  rejected {int(m.get('rejected') or 0)} "
             f"(expired {int(m.get('expired') or 0)})  "
             f"shed-degraded {int(m.get('degraded') or 0)}  "
             f"retries {int(m.get('retries') or 0)}  "
             f"failed {int(m.get('failed') or 0)}",
             f"  desync recoveries {int(m.get('recoveries') or 0)}  "
             f"faults injected {int(m.get('faults_injected') or 0)}"]
    if metrics:
        breakdown = []
        for fullname, row in sorted(metrics.items()):
            name, labels = parse_fullname(fullname)
            if (name in ("serve_rejected", "fault_injected", "serve_retries",
                         "serve_requeued") and row.get("type") == "counter"):
                tag = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
                breakdown.append(f"  {name}{{{tag}}} = "
                                 f"{int(row.get('value') or 0)}")
        lines.extend(breakdown)
    return "\n".join(lines)


def render_probe_summary(probe: Dict[str, dict]) -> str:
    lines = ["quality probe (trajectory discrepancy vs high-NFE reference):",
             f"  {'tier':<10} {'probed':>6} {'mean':>12} {'max':>12}"]
    for t, row in sorted(probe.items()):
        lines.append(f"  {t:<10} {row.get('count', 0):>6} "
                     f"{float(row.get('mean') or 0.0):>12.3e} "
                     f"{float(row.get('max') or 0.0):>12.3e}")
    return "\n".join(lines)


def span_stats(trace: dict) -> Dict[str, dict]:
    """Aggregate the trace's complete ("X") spans by name:
    {name: {count, total_us, mean_us, max_us}}."""
    out: Dict[str, dict] = {}
    for ev in trace.get("traceEvents", []):
        if ev.get("ph") != "X":
            continue
        row = out.setdefault(ev["name"], {"count": 0, "total_us": 0.0,
                                          "max_us": 0.0})
        dur = float(ev.get("dur", 0.0))
        row["count"] += 1
        row["total_us"] += dur
        row["max_us"] = max(row["max_us"], dur)
    for row in out.values():
        row["mean_us"] = row["total_us"] / row["count"]
    return out


def render_trace_summary(trace: dict) -> str:
    other = trace.get("otherData") or {}
    n = len(trace.get("traceEvents", []))
    lines = [f"trace: {n} events, {other.get('dropped_events', 0)} dropped "
             f"(schema {other.get('schema')})"]
    meta = {k: v for k, v in other.items()
            if k not in ("schema", "dropped_events")}
    if meta:
        lines.append(f"  meta: {json.dumps(meta, sort_keys=True)}")
    stats = span_stats(trace)
    if stats:
        lines.append(f"  {'span':<14} {'count':>6} {'mean us':>10} "
                     f"{'max us':>10} {'total us':>11}")
        for name, row in sorted(stats.items(),
                                key=lambda kv: -kv[1]["total_us"]):
            lines.append(f"  {name:<14} {row['count']:>6} "
                         f"{row['mean_us']:>10.1f} {row['max_us']:>10.1f} "
                         f"{row['total_us']:>11.1f}")
    # request lifecycle: how many began / ended
    begins = sum(1 for e in trace.get("traceEvents", [])
                 if e.get("ph") == "b")
    ends = sum(1 for e in trace.get("traceEvents", []) if e.get("ph") == "e")
    lines.append(f"  request spans: {begins} submitted, {ends} completed")
    return "\n".join(lines)


def render_report(trace: Optional[dict] = None,
                  metrics: Optional[dict] = None) -> str:
    """The full obsreport text over whichever artifacts were given."""
    parts: List[str] = []
    if metrics is not None:
        sm = metrics.get("serve_metrics") or {}
        parts.append(render_serve_summary(sm))
        parts.append(render_tick_table(sm))
        resil = render_resilience(
            sm, (metrics.get("run") or {}).get("metrics"))
        if resil:
            parts.append(resil)
        if metrics.get("probe"):
            parts.append(render_probe_summary(metrics["probe"]))
        if metrics.get("rows"):
            parts.append(f"periodic snapshots: {len(metrics['rows'])} rows "
                         f"(sample-free registry deltas)")
    if trace is not None:
        parts.append(render_trace_summary(trace))
    return "\n\n".join(parts) if parts else "(no artifacts given)"
