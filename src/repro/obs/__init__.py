"""Serving observability (DESIGN.md §15): tracing, metrics, quality probe.

Layering: `trace` is stdlib-only, `metrics` adds numpy, `report` renders
both; `probe` touches jax only inside `build_reference_fn` (building the
reference runner), so importing the package never drags in the engine. The
serving scheduler depends on this package — never the reverse.
"""

from .metrics import (METRICS_SCHEMA, MetricsRegistry, delta, parse_fullname,
                      snapshot_percentile, validate_metrics)
from .probe import QualityProbe, build_reference_fn, probe_selected
from .report import render_report, span_stats, write_metrics_artifact
from .trace import TRACE_SCHEMA, Tracer, validate_trace

__all__ = [
    "METRICS_SCHEMA", "MetricsRegistry", "delta", "parse_fullname",
    "snapshot_percentile", "validate_metrics",
    "QualityProbe", "build_reference_fn", "probe_selected",
    "render_report", "span_stats", "write_metrics_artifact",
    "TRACE_SCHEMA", "Tracer", "validate_trace",
]
