"""Logical-axis sharding: models annotate tensors with *logical* axis names;
a rules table maps those to mesh axes. Outside a mesh context everything is a
no-op, so the same model code runs in single-device smoke tests and in the
512-chip dry-run.

Two standard rule sets:

* TRAIN_RULES — batch over (pod, data); FSDP: one weight dim over data;
  tensor-parallel dims (d_ff / vocab / experts / heads) over model.
* SERVE_RULES — batch over (pod, data); weights sharded over model only
  (replicated over data), KV-cache batch over data, long-context KV sequence
  over data when batch is too small to occupy the axis.

Archs whose head counts don't divide the model axis simply don't annotate the
head dim (see DESIGN.md §7.3); GSPMD keeps those dims replicated.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


TRAIN_RULES = {
    "model": "model",
    "batch": ("pod", "data"),
    "seq": None,
    "d_model": None,
    "heads": "model",
    "kv_heads": "model",
    "d_ff": "model",
    "vocab": "model",
    "experts": None,          # expert weights: d_ff dim is TP; experts stacked
    "expert_cap": ("pod", "data"),
    "fsdp": "data",           # second weight dim (ZeRO-3 style)
    "kv_seq": None,
    "state": None,
}

SERVE_RULES = {
    "model": "model",
    "batch": ("pod", "data"),
    "seq": None,
    "d_model": None,
    "heads": "model",
    "kv_heads": "model",
    "d_ff": "model",
    "vocab": "model",
    "experts": None,
    "expert_cap": ("pod", "data"),
    "fsdp": None,             # weights replicated over data at serve time
    "kv_seq": None,
    "state": None,
}

LONG_SERVE_RULES = dict(SERVE_RULES, batch=None, kv_seq=("pod", "data"))

# §Perf H2: sequence parallelism — residual activations sharded over the model
# axis along *sequence* instead of resharding d_model/d_ff per projection.
# Weight 2D sharding (fsdp x model) stays; per-layer collectives become weight
# all-gathers (small) instead of activation all-gathers (huge). When 'seq' and
# a tensor dim would claim the same mesh axis in one annotation, shard() keeps
# the first occurrence (sequence wins on the residual stream).
SEQ_PARALLEL_TRAIN_RULES = dict(TRAIN_RULES, seq="model")

# §Perf H4 (beyond the required three): decode caches for archs whose kv-head
# count does not divide the model axis (deepseek kv=8, qwen kv=2 on 16-way TP)
# are otherwise only batch-sharded — 119 GB/chip for deepseek decode_32k, far
# over a v5e's 16 GB. Shard the cache *sequence* over the model axis instead
# (kv_heads keeps precedence where it divides; _guard dedupes).
KV_SEQ_SERVE_RULES = dict(SERVE_RULES, kv_seq="model")


@contextlib.contextmanager
def sharding_rules(mesh: Optional[Mesh], rules: Optional[dict], drop_axes=()):
    """Activate (mesh, rules) for `shard()` calls inside model code.

    drop_axes: logical axes to force-replicate for this context (e.g. 'heads'
    for archs whose head count doesn't divide the model axis).
    """
    eff = None
    if rules is not None:
        eff = dict(rules)
        for ax in drop_axes:
            eff[ax] = None
    prev = getattr(_state, "ctx", None)
    _state.ctx = (mesh, eff)
    try:
        yield
    finally:
        _state.ctx = prev


def current_mesh() -> Optional[Mesh]:
    ctx = getattr(_state, "ctx", None)
    return ctx[0] if ctx else None


def logical_spec(*logical_axes) -> Optional[P]:
    ctx = getattr(_state, "ctx", None)
    if not ctx or ctx[1] is None:
        return None
    _, rules = ctx
    return P(*[rules.get(a) if a is not None else None for a in logical_axes])


def normalize_axes(mesh, axes):
    """Keep only axes present in this mesh (single-pod meshes have no 'pod')."""
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    kept = tuple(a for a in axes if a in mesh.shape)
    return kept or None


def _axis_len(mesh, axes) -> int:
    axes = normalize_axes(mesh, axes)
    if axes is None:
        return 1
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def shard(x, *logical_axes):
    """with_sharding_constraint by logical axis names; no-op without a context.

    Axes whose mesh extent does not divide the tensor dim are dropped
    (replicated) — this is what lets archs with awkward head counts (qwen2:
    14 heads on a 16-way model axis) lower cleanly; see DESIGN.md §7.3."""
    ctx = getattr(_state, "ctx", None)
    if not ctx or ctx[0] is None or ctx[1] is None:
        return x
    mesh, rules = ctx
    assert len(logical_axes) == x.ndim, (logical_axes, x.shape)
    entries = []
    used = set()
    for dim, name in zip(x.shape, logical_axes):
        axes = normalize_axes(mesh, rules.get(name) if name is not None else None)
        if axes is not None:
            axes = tuple(a for a in axes if a not in used) or None
        if axes is not None and dim % _axis_len(mesh, axes) != 0:
            axes = None
        if axes is not None:
            used.update(axes)
        entries.append(axes)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))


def named_sharding(mesh: Mesh, *logical_axes, rules: dict) -> NamedSharding:
    return NamedSharding(
        mesh, P(*[rules.get(a) if a is not None else None for a in logical_axes])
    )
