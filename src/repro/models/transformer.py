"""Decoder-only transformer LM (dense or MoE blocks), scan-over-layers.

Serves qwen2-0.5b / qwen2.5-3b / olmo-1b / deepseek-67b (dense) and, with
`cfg.num_experts > 0`, mixtral-8x7b / granite-moe (MoE). Three entry points:

  forward(params, cfg, tokens)                -> hidden (B, S, d)   [train]
  prefill(params, cfg, tokens)                -> (logits_last, cache)
  decode_step(params, cfg, cache, tok, pos)   -> (logits, cache)

KV caches are (L, B, W, Hkv, D) stacked over the layer/scan axis, where W is
`max_len` (full cache) or `cfg.sliding_window` (rolling cache, sub-quadratic
long-context decode). Keys are stored rope'd at their true positions.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..parallel.sharding import shard
from .layers import (
    NORMS, apply_rope, attention_apply, attention_init, dense_init, maybe_remat,
    mlp_apply, mlp_init, sdpa,
)
from .moe import moe_apply, moe_apply_shard_map, moe_decode_apply, moe_init


def _norm(cfg):
    init, apply = NORMS[cfg.norm]
    return init, apply


def layer_init(rng, cfg):
    ninit, _ = _norm(cfg)
    ks = jax.random.split(rng, 4)
    p = {
        "ln1": ninit(cfg.d_model, cfg.weight_dtype),
        "attn": attention_init(ks[0], cfg),
        "ln2": ninit(cfg.d_model, cfg.weight_dtype),
    }
    if cfg.num_experts:
        p["moe"] = moe_init(ks[1], cfg)
    else:
        p["mlp"] = mlp_init(ks[1], cfg)
    return p


def init_lm(cfg, rng):
    ks = jax.random.split(rng, cfg.num_layers + 3)
    layers = [layer_init(k, cfg) for k in ks[: cfg.num_layers]]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    ninit, _ = _norm(cfg)
    p = {
        "embed": dense_init(ks[-1], cfg.vocab_size, cfg.d_model,
                            cfg.weight_dtype, scale=0.02),
        "layers": stacked,
        "final_ln": ninit(cfg.d_model, cfg.weight_dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(ks[-2], cfg.d_model, cfg.vocab_size,
                                  cfg.weight_dtype)
    return p


def _block(lp, x, cfg, *, sliding_window, causal=True):
    _, napply = _norm(cfg)
    h = attention_apply(lp["attn"], napply(lp["ln1"], x), cfg,
                        causal=causal, sliding_window=sliding_window)
    x = x + h
    y = napply(lp["ln2"], x)
    if cfg.num_experts:
        from ..parallel.sharding import current_mesh
        mesh = current_mesh()
        if cfg.moe_shard_map and mesh is not None:
            y, aux = moe_apply_shard_map(lp["moe"], y, cfg, mesh)
        else:
            y, aux = moe_apply(lp["moe"], y, cfg)
    else:
        y, aux = mlp_apply(lp["mlp"], y, cfg), jnp.zeros((), jnp.float32)
    return x + y, aux


def forward(params, cfg, tokens, *, causal: bool = True,
            inputs_embeds: Optional[jnp.ndarray] = None):
    """Full-sequence forward; returns (hidden, aux_loss)."""
    x = (inputs_embeds if inputs_embeds is not None
         else params["embed"].astype(cfg.activation_dtype)[tokens])
    x = shard(x, "batch", "seq", "d_model")

    def body(h, lp):
        h, aux = _block(lp, h, cfg, sliding_window=cfg.sliding_window,
                        causal=causal)
        return h, aux

    x, auxs = jax.lax.scan(maybe_remat(body, cfg), x, params["layers"])
    _, napply = _norm(cfg)
    return napply(params["final_ln"], x), jnp.sum(auxs)


def logits_from_hidden(params, cfg, hidden):
    w = (params["embed"].T if cfg.tie_embeddings or "lm_head" not in params
         else params["lm_head"])
    out = jnp.einsum("bsd,dv->bsv", hidden, w.astype(hidden.dtype))
    return shard(out, "batch", "seq", "vocab")


def lm_loss(params, cfg, tokens, targets):
    hidden, aux = forward(params, cfg, tokens)
    logits = logits_from_hidden(params, cfg, hidden).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1).mean()
    return nll + cfg.router_aux_weight * aux


# ---------------------------------------------------------------------------
# serving: prefill + single-token decode with stacked KV caches
# ---------------------------------------------------------------------------

def cache_window(cfg, max_len: int) -> int:
    return min(cfg.sliding_window, max_len) if cfg.sliding_window else max_len


def init_cache(cfg, batch: int, max_len: int):
    W = cache_window(cfg, max_len)
    shape = (cfg.num_layers, batch, W, cfg.num_kv_heads, cfg.head_dim)
    z = jnp.zeros(shape, cfg.activation_dtype)
    return {"k": z, "v": z}


def _attn_with_cache(lp, x_tok, k_cache, v_cache, pos, cfg, W):
    """x_tok: (B, 1, d); cache slices (B, W, Hkv, D); pos: scalar int."""
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    B = x_tok.shape[0]
    q = jnp.einsum("bsd,de->bse", x_tok, lp["attn"]["wq"].astype(x_tok.dtype))
    k = jnp.einsum("bsd,de->bse", x_tok, lp["attn"]["wk"].astype(x_tok.dtype))
    v = jnp.einsum("bsd,de->bse", x_tok, lp["attn"]["wv"].astype(x_tok.dtype))
    if "bq" in lp["attn"]:
        q = q + lp["attn"]["bq"].astype(x_tok.dtype)
        k = k + lp["attn"]["bk"].astype(x_tok.dtype)
        v = v + lp["attn"]["bv"].astype(x_tok.dtype)
    q = q.reshape(B, 1, hq, hd)
    k = k.reshape(B, 1, hkv, hd)
    v = v.reshape(B, 1, hkv, hd)
    posb = jnp.full((B, 1), pos)
    q = apply_rope(q, posb, cfg.rope_theta)
    k = apply_rope(k, posb, cfg.rope_theta)
    slot = pos % W
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, slot, axis=1)
    # slot j holds position pos - ((pos - j) mod W); valid if <= pos (always,
    # once written) and > pos - W (rolling window) — mask unwritten slots early.
    j = jnp.arange(W)
    key_pos = pos - jnp.mod(pos - j, W)
    valid = key_pos >= jnp.maximum(0, pos - W + 1)
    if cfg.sliding_window:
        valid &= key_pos > pos - cfg.sliding_window
    logits = jnp.einsum(
        "bqhgd,bkhd->bhgqk",
        q.reshape(B, 1, hkv, hq // hkv, hd), k_cache).astype(jnp.float32)
    logits = logits / math.sqrt(hd)
    logits = jnp.where(valid[None, None, None, None, :], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v_cache).reshape(B, 1, hq * hd)
    out = jnp.einsum("bse,ed->bsd", out, lp["attn"]["wo"].astype(x_tok.dtype))
    return out, k_cache, v_cache


def decode_step(params, cfg, cache, token, pos):
    """token: (B, 1) int32; pos: scalar int32. Returns (logits (B, 1, V), cache)."""
    _, napply = _norm(cfg)
    x = params["embed"].astype(cfg.activation_dtype)[token]
    x = shard(x, "batch", "seq", "d_model")
    W = cache["k"].shape[2]

    def body(h, lc):
        lp, kc, vc = lc
        a, kc, vc = _attn_with_cache(lp, napply(lp["ln1"], h), kc, vc, pos, cfg, W)
        h = h + a
        y = napply(lp["ln2"], h)
        if cfg.num_experts:
            y = moe_decode_apply(lp["moe"], y, cfg)
        else:
            y = mlp_apply(lp["mlp"], y, cfg)
        return h + y, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
    hidden = napply(params["final_ln"], x)
    return logits_from_hidden(params, cfg, hidden), {"k": k_new, "v": v_new}


def prefill(params, cfg, tokens, max_len: int):
    """Process a full prompt, build the cache, return last-position logits.

    The cache is built by re-projecting K/V from the hidden states (one fused
    pass; equivalent to the decode path's incremental writes)."""
    _, napply = _norm(cfg)
    B, S = tokens.shape
    W = cache_window(cfg, max_len)
    x = params["embed"].astype(cfg.activation_dtype)[tokens]
    x = shard(x, "batch", "seq", "d_model")
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(h, lp):
        xn = napply(lp["ln1"], h)
        a = attention_apply(lp["attn"], xn, cfg, causal=True,
                            sliding_window=cfg.sliding_window)
        h2 = h + a
        y = napply(lp["ln2"], h2)
        if cfg.num_experts:
            y, _ = moe_apply(lp["moe"], y, cfg)
        else:
            y = mlp_apply(lp["mlp"], y, cfg)
        h_out = h2 + y
        # rebuild this layer's K/V for the cache (last W positions)
        k = jnp.einsum("bsd,de->bse", xn, lp["attn"]["wk"].astype(h.dtype))
        v = jnp.einsum("bsd,de->bse", xn, lp["attn"]["wv"].astype(h.dtype))
        if "bk" in lp["attn"]:
            k = k + lp["attn"]["bk"].astype(h.dtype)
            v = v + lp["attn"]["bv"].astype(h.dtype)
        k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
        v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
        k = apply_rope(k, pos, cfg.rope_theta)
        if S >= W:
            # keep positions S-W..S-1, placed at slot = position mod W
            tail_pos = jnp.arange(S - W, S)
            slots = jnp.mod(tail_pos, W)
            kc = jnp.zeros((B, W) + k.shape[2:], k.dtype).at[:, slots].set(
                k[:, S - W:])
            vc = jnp.zeros((B, W) + v.shape[2:], v.dtype).at[:, slots].set(
                v[:, S - W:])
        else:
            pad = ((0, 0), (0, W - S), (0, 0), (0, 0))
            kc, vc = jnp.pad(k, pad), jnp.pad(v, pad)
        return h_out, (kc, vc)

    x, (kc, vc) = jax.lax.scan(body, x, params["layers"])
    hidden = napply(params["final_ln"], x[:, -1:])
    return logits_from_hidden(params, cfg, hidden), {"k": kc, "v": vc}
