"""Shared neural building blocks (pure-JAX, dict-pytree params).

Conventions:
  params are nested dicts of jnp arrays; init fns take an `rng` and a config;
  apply fns are pure. Weights use einsum contractions so GSPMD propagates the
  logical-axis shardings annotated via parallel.sharding.shard().
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from ..parallel.sharding import shard


def _normal(rng, shape, scale, dtype):
    return (scale * jax.random.normal(rng, shape, jnp.float32)).astype(dtype)


def dense_init(rng, d_in, d_out, dtype, scale=None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return _normal(rng, (d_in, d_out), scale, dtype)


def dense_apply(x, w, cfg=None):
    """x (..., K) @ w — the one dense contraction every weight site routes
    through. `w` is either a raw (K, N) array (the unchanged float path) or
    a quant record ``{"qw", "ws"[, "sa"]}`` installed by
    `models.quant.quantize_params`, which routes through the
    kernels/quant_matmul package (DESIGN.md §14). The check is structural
    and static per trace, so unquantized models pay nothing."""
    if isinstance(w, dict):
        from ..kernels.quant_matmul import ops as qmm_ops

        return qmm_ops.quant_matmul(
            x, w["qw"], w["ws"], sa=w.get("sa"),
            backend=getattr(cfg, "quant_backend", None))
    return jnp.einsum("...k,kn->...n", x, w.astype(x.dtype))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d, dtype):
    return {"w": jnp.ones((d,), dtype)}


def rmsnorm(params, x, eps=1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps).astype(x.dtype)
    return y * params["w"].astype(x.dtype)


def layernorm_init(d, dtype):
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def layernorm(params, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)
    if params:
        y = y * params["w"].astype(y.dtype) + params["b"].astype(y.dtype)
    return y


def nonparam_ln(params, x, eps=1e-5):
    """OLMo-style non-parametric LayerNorm (no learnable affine)."""
    return layernorm({}, x, eps)


NORMS = {
    "rmsnorm": (rmsnorm_init, rmsnorm),
    "layernorm": (layernorm_init, layernorm),
    "nonparam_ln": (lambda d, dt: {}, nonparam_ln),
}


def make_norm(cfg):
    init, apply = NORMS[cfg.norm]
    return (lambda rng=None: init(cfg.d_model, cfg.weight_dtype)), apply


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------

def mlp_init(rng, cfg, d_ff=None):
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = cfg.weight_dtype
    ks = jax.random.split(rng, 3)
    if cfg.act == "swiglu":
        return {
            "w_gate": dense_init(ks[0], d, f, dt),
            "w_up": dense_init(ks[1], d, f, dt),
            "w_down": dense_init(ks[2], f, d, dt, scale=1.0 / math.sqrt(f)),
        }
    return {
        "w_up": dense_init(ks[1], d, f, dt),
        "w_down": dense_init(ks[2], f, d, dt, scale=1.0 / math.sqrt(f)),
    }


def mlp_apply(params, x, cfg):
    if cfg.act == "swiglu":
        g = dense_apply(x, params["w_gate"], cfg)
        u = dense_apply(x, params["w_up"], cfg)
        h = jax.nn.silu(g) * u
    else:
        h = jax.nn.gelu(dense_apply(x, params["w_up"], cfg))
    h = shard(h, "batch", "seq", "d_ff")
    return dense_apply(h, params["w_down"], cfg)


# ---------------------------------------------------------------------------
# attention (GQA, causal / bidirectional / sliding-window / cross)
# ---------------------------------------------------------------------------

def attention_init(rng, cfg, d_kv_src: Optional[int] = None):
    d = cfg.d_model
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = cfg.weight_dtype
    d_src = d_kv_src or d
    ks = jax.random.split(rng, 4)
    p = {
        "wq": dense_init(ks[0], d, hq * hd, dt),
        "wk": dense_init(ks[1], d_src, hkv * hd, dt),
        "wv": dense_init(ks[2], d_src, hkv * hd, dt),
        "wo": dense_init(ks[3], hq * hd, d, dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dt)
        p["bk"] = jnp.zeros((hkv * hd,), dt)
        p["bv"] = jnp.zeros((hkv * hd,), dt)
    return p


def _proj_qkv(params, x, kv_src, cfg, tap=None):
    hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    if tap is not None:  # calibration hook (models/quant.py); None in serving
        tap("qkv", x)
    q = dense_apply(x, params["wq"], cfg)
    k = dense_apply(kv_src, params["wk"], cfg)
    v = dense_apply(kv_src, params["wv"], cfg)
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    B, S = x.shape[:2]
    Skv = kv_src.shape[1]
    return (q.reshape(B, S, hq, hd), k.reshape(B, Skv, hkv, hd),
            v.reshape(B, Skv, hkv, hd))


def sdpa(q, k, v, *, causal, q_positions=None, kv_positions=None,
         sliding_window=None):
    """Grouped-query scaled dot-product attention, pure-jnp path.

    q: (B, Sq, Hq, D); k, v: (B, Skv, Hkv, D) with Hq % Hkv == 0.
    Masks are built from positions so the same code serves prefill (Sq == Skv),
    decode (Sq == 1 against a cache), and cross-attention (causal=False).
    """
    B, Sq, Hq, D = q.shape
    Hkv = k.shape[2]
    group = Hq // Hkv
    qg = q.reshape(B, Sq, Hkv, group, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k).astype(jnp.float32)
    logits = logits / math.sqrt(D)
    if causal or sliding_window is not None:
        qp = (q_positions if q_positions is not None
              else jnp.arange(Sq))[:, None]           # (Sq, 1)
        kp = (kv_positions if kv_positions is not None
              else jnp.arange(k.shape[1]))[None, :]   # (1, Skv)
        ok = jnp.ones((Sq, k.shape[1]), bool)
        if causal:
            ok &= kp <= qp
        if sliding_window is not None:
            ok &= kp > qp - sliding_window
        logits = jnp.where(ok[None, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", w, v)
    return out.reshape(B, Sq, Hq, D)


def chunked_sdpa(q, k, v, *, causal, sliding_window=None, chunk=1024):
    """Flash-style attention expressed in XLA: lax.scan over query chunks, so
    the logits footprint is O(chunk * Skv) instead of O(Sq * Skv). Exact (same
    softmax), blockwise — the §Perf memory-bound hillclimb for long prefill
    (EXPERIMENTS.md H3). The Pallas kernel (kernels/flash_attention) is the
    TPU-native version; this path is what the XLA dry-run lowers.

    Arbitrary Sq: a non-multiple tail is handled by padding the queries up to
    the chunk boundary — query rows are independent, the padded rows carry
    real past-the-end positions (a causal pad row attends to everything, its
    softmax stays finite) and their outputs are sliced off."""
    B, Sq, Hq, D = q.shape
    Skv = k.shape[1]
    pad = (-Sq) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = (Sq + pad) // chunk
    qc = jnp.moveaxis(q.reshape(B, nq, chunk, Hq, D), 1, 0)
    kp = jnp.arange(Skv)

    def body(_, inp):
        qi, idx = inp
        qpos = idx * chunk + jnp.arange(chunk)
        out = sdpa(qi, k, v, causal=causal, q_positions=qpos,
                   kv_positions=kp, sliding_window=sliding_window)
        return None, out

    _, outs = jax.lax.scan(body, None, (qc, jnp.arange(nq)))
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq + pad, Hq, D)[:, :Sq]


def attention_apply(params, x, cfg, *, kv_src=None, causal=True, positions=None,
                    kv_positions=None, sliding_window=None, rope=True,
                    tap=None):
    """Full-sequence attention (training / prefill without cache)."""
    kv_src = x if kv_src is None else kv_src
    q, k, v = _proj_qkv(params, x, kv_src, cfg, tap=tap)
    if rope:
        B, S = x.shape[:2]
        pos = positions if positions is not None else jnp.broadcast_to(
            jnp.arange(S), (B, S))
        q = apply_rope(q, pos, cfg.rope_theta)
        kv_pos = kv_positions if kv_positions is not None else jnp.broadcast_to(
            jnp.arange(k.shape[1]), (B, k.shape[1]))
        k = apply_rope(k, kv_pos, cfg.rope_theta)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv_heads", None)
    chunk = getattr(cfg, "attention_chunk", 0)
    if chunk and q.shape[1] > chunk:
        # the XLA long-prefill hillclimb; remainder chunks handled by padding
        out = chunked_sdpa(q, k, v, causal=causal,
                           sliding_window=sliding_window, chunk=chunk)
    else:
        # the fast-eval path (DESIGN.md §11): kernels/flash_attention with
        # the explicit pallas|interpret|jnp policy — the Pallas kernel on
        # TPU, the head-major jnp oracle elsewhere (measured faster on CPU
        # than the seq-major sdpa einsum at DiT serving shapes). sdpa stays
        # the decode-path / positions-aware reference.
        from ..kernels.flash_attention import ops as fa_ops

        out = fa_ops.attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=causal, window=sliding_window,
            backend=getattr(cfg, "attention_backend", None),
        ).transpose(0, 2, 1, 3)
    B, S = x.shape[:2]
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
    if tap is not None:
        tap("wo", out)
    return dense_apply(out, params["wo"], cfg)


def maybe_remat(body, cfg):
    """Wrap a scan body with activation checkpointing when cfg.remat is set."""
    if getattr(cfg, "remat", False):
        return jax.checkpoint(body, prevent_cse=False)
    return body
