"""Unified per-family model API.

Every architecture family exposes the same five entry points, so the launcher,
dry-run, tests, and benchmarks are family-agnostic:

    init_params(cfg, rng)                    -> params
    train_loss(cfg)(params, batch, rng)      -> scalar   (objective: 'ar' | 'diffusion')
    prefill(cfg)(params, batch, max_len)     -> (logits, cache)
    decode_step(cfg)(params, cache, tok, pos)-> (logits, cache)
    init_cache(cfg, batch, max_len)          -> cache pytree

`batch` is a dict: tokens/targets always; image_embeds (vlm), audio_embeds
(audio), latents (dit). The diffusion objective implements embedding-space
diffusion-LM (Li et al., 2022-style: learned token latents + eps-loss +
rounding CE) — the vehicle for UniPC on every backbone (DESIGN.md §7.1).
"""

from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..diffusion.process import q_sample
from ..diffusion.schedules import VPLinear
from .diffusion_lm import diffusion_lm_apply, init_diffusion_head
from .dit import dit_apply, init_dit
from .layers import dense_init
from . import encdec, hybrid, transformer, vlm


def _backbone_forward(cfg):
    """(params, inputs_embeds, extra) -> (hidden, aux) for diffusion mode."""
    fam = cfg.family
    if fam in ("dense", "moe"):
        return lambda p, e, b: transformer.forward(
            p["backbone"], cfg, None, causal=False, inputs_embeds=e)
    if fam == "ssm":
        return lambda p, e, b: hybrid.mamba_forward(
            p["backbone"], cfg, None, inputs_embeds=e)
    if fam == "hybrid":
        return lambda p, e, b: hybrid.zamba_forward(
            p["backbone"], cfg, None, inputs_embeds=e)
    if fam == "vlm":
        # image conditioning flows through the cross-attn layers as usual
        def f(p, e, b):
            return _vlm_embeds_forward(p["backbone"], cfg, e, b["image_embeds"])
        return f
    if fam == "audio":
        def f(p, e, b):
            return _audio_embeds_forward(p["backbone"], cfg, e, b["audio_embeds"])
        return f
    raise ValueError(fam)


def _vlm_embeds_forward(params, cfg, embeds, image_embeds):
    return vlm._forward_embeds(params, cfg, embeds, image_embeds)


def _audio_embeds_forward(params, cfg, embeds, audio_embeds):
    return encdec._forward_embeds(params, cfg, embeds, audio_embeds)


# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, rng):
    fam = cfg.family
    k1, k2, k3 = jax.random.split(rng, 3)
    if fam == "dit":
        return {"backbone": init_dit(cfg, k1, num_classes=1000)}
    if fam in ("dense", "moe"):
        p = {"backbone": transformer.init_lm(cfg, k1)}
    elif fam == "ssm":
        p = {"backbone": hybrid.init_mamba_lm(cfg, k1)}
    elif fam == "hybrid":
        p = {"backbone": hybrid.init_zamba_lm(cfg, k1)}
    elif fam == "vlm":
        p = {"backbone": vlm.init_vlm(cfg, k1)}
    elif fam == "audio":
        p = {"backbone": encdec.init_encdec(cfg, k1)}
    else:
        raise ValueError(fam)
    if cfg.latent_dim:
        p["diffusion_head"] = init_diffusion_head(cfg, k2)
        p["token_latents"] = dense_init(k3, cfg.vocab_size, cfg.latent_dim,
                                        cfg.weight_dtype, scale=1.0)
    return p


def ar_loss(cfg: ModelConfig) -> Callable:
    fam = cfg.family

    def loss(params, batch, rng):
        bk = params["backbone"]
        if fam in ("dense", "moe"):
            return transformer.lm_loss(bk, cfg, batch["tokens"], batch["targets"])
        if fam == "ssm":
            return hybrid.mamba_lm_loss(bk, cfg, batch["tokens"], batch["targets"])
        if fam == "hybrid":
            return hybrid.zamba_lm_loss(bk, cfg, batch["tokens"], batch["targets"])
        if fam == "vlm":
            return vlm.vlm_loss(bk, cfg, batch["tokens"], batch["targets"],
                                batch["image_embeds"])
        if fam == "audio":
            return encdec.encdec_loss(bk, cfg, batch["tokens"], batch["targets"],
                                      batch["audio_embeds"])
        raise ValueError(fam)

    return loss


def cast_params_for_eval(params, eval_dtype: str):
    """Pre-cast every float param leaf to the serving eval dtype (DESIGN.md
    §11.3) — once, so reduced-precision serving halves the params' HBM reads
    instead of casting at use. Non-float leaves (e.g. int tables) pass
    through. The single definition serves both `launch.sample.build_engine`
    and the model benchmarks, so the benchmarked bf16 mode is exactly the
    shipped one."""
    dt = jnp.dtype(eval_dtype)
    return jax.tree.map(
        lambda a: (a.astype(dt)
                   if jnp.issubdtype(a.dtype, jnp.floating) else a),
        params)


def calibrate_and_quantize(cfg: ModelConfig, params, quant, *, schedule=None,
                           nfe: int = 6, calib_batch: int = 2, seed: int = 0):
    """Quantized serving path (DESIGN.md §14): calibrate + install records.

    `quant` is a tier name from models.quant.QUANT_MODES ("w8a16", "w8a8",
    ...) or a QuantSpec. Weight scales come from per-output-channel absmax
    of the weights themselves; a8 tiers additionally record per-site
    activation absmax over `calib_batch` deterministic reference
    trajectories (same seed -> bit-identical scales). Returns
    (cfg', params', info): cfg' carries the spec (and is what eps_network
    should be built from), params' the quantized tree.
    """
    import dataclasses

    from .quant import calibrate_act_stats, quant_spec, quantize_params

    spec = quant_spec(quant) if isinstance(quant, str) else quant
    stats = None
    if spec.act_bits == 8:
        stats = calibrate_act_stats(cfg, params, schedule=schedule, nfe=nfe,
                                    batch=calib_batch, seed=seed)
    qparams = quantize_params(cfg, params, spec, act_stats=stats)
    cfg = dataclasses.replace(cfg, quant=spec)
    return cfg, qparams, {"spec": spec, "act_stats": stats}


def eps_network(cfg: ModelConfig) -> Callable:
    """(params, x_t (B,S,L), t, batch) -> eps-hat — what UniPC samples from."""
    if cfg.family == "dit":
        return lambda p, x_t, t, batch: dit_apply(
            p["backbone"], cfg, x_t, t, batch.get("class_ids"))
    fwd = _backbone_forward(cfg)

    def f(params, x_t, t, batch):
        return diffusion_lm_apply(
            params["diffusion_head"],
            lambda e: fwd(params, e, batch), cfg, x_t, t)

    return f


def eps_network_cached(cfg: ModelConfig, cache_block: int) -> Callable:
    """Feature-reuse eps-net (DESIGN.md §12), dit family only:

        (params, x_t, t, batch, cache, reuse) -> (eps-hat, new_cache)

    `cache` is the (B, T, d_model) deep-feature delta state (see
    `dit.dit_apply_cached`); `reuse` the per-sample shallow-eval flag. The
    `cache_block` boundary is static — it is baked into the compiled step
    program, while *which* steps reuse the cache is data (a searched
    per-step table column, `repro.tuning`)."""
    if cfg.family != "dit":
        raise ValueError(f"feature-reuse eval needs the dit family (residual "
                         f"block stack); arch {cfg.arch_id!r} is family "
                         f"{cfg.family!r}")
    from .dit import dit_apply_cached

    def f(params, x_t, t, batch, cache, reuse):
        return dit_apply_cached(params["backbone"], cfg, x_t, t,
                                batch.get("class_ids"), cache=cache,
                                reuse=reuse, cache_block=cache_block)

    return f


def diffusion_loss_fn(cfg: ModelConfig, schedule=None) -> Callable:
    schedule = schedule or VPLinear()
    net = eps_network(cfg)

    def loss(params, batch, rng):
        rng_t, rng_e = jax.random.split(rng)
        if cfg.family == "dit":
            x0 = batch["latents"]
        else:
            x0 = params["token_latents"].astype(cfg.activation_dtype)[batch["tokens"]]
        B = x0.shape[0]
        t = jax.random.uniform(rng_t, (B,), minval=schedule.t_eps,
                               maxval=schedule.T)
        noise = jax.random.normal(rng_e, x0.shape, jnp.float32).astype(x0.dtype)
        x_t = q_sample(schedule, x0, t, noise)
        eps_hat = net(params, x_t, t, batch)
        mse = jnp.mean((eps_hat.astype(jnp.float32)
                        - noise.astype(jnp.float32)) ** 2)
        if cfg.family == "dit":
            return mse
        # rounding loss anchors the latent space (Diffusion-LM), weighted by
        # alpha_t^2: at high noise x0_hat = (x_t - sigma eps)/alpha amplifies
        # the residual by 1/alpha and the unweighted CE is pure variance
        a, s = schedule.alpha_sigma_jax(t)
        bshape = (-1,) + (1,) * (x0.ndim - 1)
        x0_hat = (x_t - s.reshape(bshape) * eps_hat) / a.reshape(bshape)
        logits = jnp.einsum("bsl,vl->bsv", x0_hat.astype(jnp.float32),
                            params["token_latents"].astype(jnp.float32))
        logp = jax.nn.log_softmax(logits, axis=-1)
        ce = -jnp.take_along_axis(logp, batch["tokens"][..., None], -1)
        w = (a * a).reshape((-1,) + (1,) * (ce.ndim - 1))
        ce = jnp.mean(w * ce) / jnp.mean(w)
        return mse + ce

    return loss


def train_loss(cfg: ModelConfig, objective: str = "ar") -> Callable:
    return ar_loss(cfg) if objective == "ar" else diffusion_loss_fn(cfg)


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    fam = cfg.family
    if fam in ("dense", "moe"):
        return transformer.init_cache(cfg, batch, max_len)
    if fam == "ssm":
        return hybrid.init_mamba_cache(cfg, batch, max_len)
    if fam == "hybrid":
        return hybrid.init_zamba_cache(cfg, batch, max_len)
    if fam == "vlm":
        return vlm.init_vlm_cache(cfg, batch, max_len)
    if fam == "audio":
        cache = None  # built by prefill; specs via prefill lowering
        raise ValueError("audio cache comes from encdec_prefill")
    raise ValueError(fam)


def prefill_fn(cfg: ModelConfig) -> Callable:
    fam = cfg.family

    def f(params, batch, max_len):
        bk = params["backbone"]
        if fam in ("dense", "moe"):
            return transformer.prefill(bk, cfg, batch["tokens"], max_len)
        if fam == "ssm":
            return hybrid.mamba_prefill(bk, cfg, batch["tokens"], max_len)
        if fam == "hybrid":
            return hybrid.zamba_prefill(bk, cfg, batch["tokens"], max_len)
        if fam == "vlm":
            return vlm.vlm_prefill(bk, cfg, batch["tokens"],
                                   batch["image_embeds"], max_len)
        if fam == "audio":
            return encdec.encdec_prefill(bk, cfg, batch["tokens"],
                                         batch["audio_embeds"], max_len)
        raise ValueError(fam)

    return f


def decode_fn(cfg: ModelConfig) -> Callable:
    fam = cfg.family

    def f(params, cache, token, pos):
        bk = params["backbone"]
        if fam in ("dense", "moe"):
            return transformer.decode_step(bk, cfg, cache, token, pos)
        if fam == "ssm":
            return hybrid.mamba_decode_step(bk, cfg, cache, token, pos)
        if fam == "hybrid":
            return hybrid.zamba_decode_step(bk, cfg, cache, token, pos)
        if fam == "vlm":
            return vlm.vlm_decode_step(bk, cfg, cache, token, pos)
        if fam == "audio":
            return encdec.encdec_decode_step(bk, cfg, cache, token, pos)
        raise ValueError(fam)

    return f
