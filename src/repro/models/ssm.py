"""Mamba2 (state-space duality / SSD) block — chunked parallel form for
training/prefill and O(1) recurrent form for decode. (Dao & Gu, 2024,
arXiv:2405.21060; zamba2's Mamba2 blocks use the same core.)

Shapes: d_inner = expand * d_model, H = d_inner / head_dim heads, state N,
G groups for B/C (GVA-style). Chunked scan: within-chunk quadratic form +
inter-chunk recurrence on (H, P, N) states — TPU-friendly (all einsums, one
small sequential scan over chunks).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..parallel.sharding import shard
from .layers import dense_init, rmsnorm, rmsnorm_init


def mamba2_init(rng, cfg):
    d = cfg.d_model
    di = cfg.ssm_d_inner
    H = cfg.ssm_heads
    N = cfg.ssm_state
    G = cfg.ssm_groups
    dt = cfg.weight_dtype
    conv_dim = di + 2 * G * N
    ks = jax.random.split(rng, 5)
    return {
        # order: [z (di), x (di), B (G*N), C (G*N), dt (H)]
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * G * N + H, dt),
        "conv_w": (0.1 * jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim))).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(dt),
        "D": jnp.ones((H,), dt),
        "dt_bias": jnp.log(jnp.expm1(0.01 * jnp.ones((H,)))).astype(dt),
        "out_norm": rmsnorm_init(di, dt),
        "out_proj": dense_init(ks[2], di, d, dt),
    }


def _split_proj(params, u, cfg):
    di, G, N, H = cfg.ssm_d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = jnp.einsum("bsd,de->bse", u, params["in_proj"].astype(u.dtype))
    z, xBC, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * G * N], axis=-1)
    return z, xBC, dt


def _causal_conv(params, xBC, cfg):
    """Depthwise causal conv1d, window ssm_conv, then SiLU."""
    K = cfg.ssm_conv
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    w = params["conv_w"].astype(xBC.dtype)
    out = sum(pad[:, k: k + xBC.shape[1]] * w[k] for k in range(K))
    return jax.nn.silu(out + params["conv_b"].astype(xBC.dtype))


def _segsum(a):
    """a: (..., q) -> (..., q, q) lower-triangular cumulative sums
    L[i, j] = sum_{j < k <= i} a_k (and -inf above the diagonal)."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_scan(x, dt, A, B, C, D, chunk):
    """SSD chunked algorithm.

    x: (b, l, h, p); dt: (b, l, h) (post-softplus); A: (h,) negative;
    B, C: (b, l, g, n); D: (h,). Returns y: (b, l, h, p) and the final
    state (b, h, p, n).
    """
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    orig_l = l
    pad = (-l) % chunk
    if pad:
        # zero-pad: dt=0 rows have decay exp(0)=1 and contribute nothing
        zpad = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        x, dt, B, C = zpad(x), zpad(dt), zpad(B), zpad(C)
        l = l + pad
    c = l // chunk
    rep = h // g
    ch = lambda t: t.reshape((b, c, chunk) + t.shape[2:])
    xc, dtc, Bc, Cc = ch(x), ch(dt), ch(B), ch(C)
    a = (dtc * A[None, None, None, :]).astype(jnp.float32)        # (b,c,q,h)
    a = jnp.moveaxis(a, -1, 2)                                    # (b,c,h,q)
    xdt = xc * dtc[..., None]                                     # (b,c,q,h,p)

    # 1) within-chunk (quadratic) term
    L = jnp.exp(_segsum(a))                                       # (b,c,h,q,q)
    Bh = jnp.repeat(Bc, rep, axis=3)                              # (b,c,q,h,n)
    Ch = jnp.repeat(Cc, rep, axis=3)
    Ydiag = jnp.einsum("bcihn,bcjhn,bchij,bcjhp->bcihp",
                       Ch.astype(jnp.float32), Bh.astype(jnp.float32),
                       L, xdt.astype(jnp.float32))

    # 2) chunk states
    a_cum = jnp.cumsum(a, axis=-1)                                # (b,c,h,q)
    a_tot = a_cum[..., -1]                                        # (b,c,h)
    decay_states = jnp.exp(a_tot[..., None] - a_cum)              # (b,c,h,q)
    states = jnp.einsum("bcqhn,bchq,bcqhp->bchpn",
                        Bh.astype(jnp.float32),
                        decay_states, xdt.astype(jnp.float32))    # (b,c,h,p,n)

    # 3) inter-chunk recurrence  S_c = exp(a_tot_c) * S_{c-1} + states_c
    def step(S, inp):
        st, dk = inp
        S = S * jnp.exp(dk)[..., None, None] + st
        return S, S

    S0 = jnp.zeros((b, h, p, n), jnp.float32)
    final, S_after = jax.lax.scan(
        step, S0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(a_tot, 1, 0)))
    # state *entering* chunk c is S_after[c-1]; chunk 0 enters with zeros
    S_in = jnp.concatenate([S0[None], S_after[:-1]], axis=0)
    S_in = jnp.moveaxis(S_in, 0, 1)                               # (b,c,h,p,n)

    # 4) state -> output within each chunk
    Yoff = jnp.einsum("bcqhn,bchpn,bchq->bcqhp",
                      Ch.astype(jnp.float32), S_in, jnp.exp(a_cum))
    y = (Ydiag + Yoff).reshape(b, l, h, p).astype(x.dtype)
    y = y + x * D[None, None, :, None].astype(x.dtype)
    if pad:
        y = y[:, :orig_l]
    return y, final.astype(x.dtype)


def mamba2_apply(params, u, cfg, *, return_state: bool = False):
    """Full-sequence Mamba2 block. u: (B, S, d_model)."""
    di, G, N, H = cfg.ssm_d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim
    z, xBC, dt = _split_proj(params, u, cfg)
    xBC = _causal_conv(params, xBC, cfg)
    x, B, C = jnp.split(xBC, [di, di + G * N], axis=-1)
    b, l = u.shape[:2]
    x = x.reshape(b, l, H, P)
    x = shard(x, "batch", "seq", "heads", None)
    B = B.reshape(b, l, G, N)
    C = C.reshape(b, l, G, N)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    y, state = ssd_scan(x, dt, A, B, C, params["D"], cfg.ssm_chunk)
    y = y.reshape(b, l, di)
    y = rmsnorm(params["out_norm"], y * jax.nn.silu(z))
    out = jnp.einsum("bsd,de->bse", y, params["out_proj"].astype(u.dtype))
    if return_state:
        conv_tail = jnp.concatenate(
            [jnp.zeros((b, max(0, cfg.ssm_conv - 1 - l), xBC.shape[-1]), u.dtype),
             _conv_input_tail(params, u, cfg)], axis=1)
        return out, {"ssm": state, "conv": conv_tail}
    return out


def _conv_input_tail(params, u, cfg):
    """Last (ssm_conv - 1) *pre-conv* channel rows, for decode continuation."""
    _, xBC_raw, _ = _split_proj(params, u, cfg)
    return xBC_raw[:, -(cfg.ssm_conv - 1):]


def init_mamba_state(cfg, batch):
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_dim = cfg.ssm_d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "ssm": jnp.zeros((batch, H, P, N), cfg.activation_dtype),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim),
                          cfg.activation_dtype),
    }


def mamba2_decode(params, state, u_tok, cfg):
    """One-token recurrent update. u_tok: (B, 1, d). Returns (y, state)."""
    di, G, N, H = cfg.ssm_d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    P = cfg.ssm_head_dim
    b = u_tok.shape[0]
    z, xBC_raw, dt = _split_proj(params, u_tok, cfg)
    window = jnp.concatenate([state["conv"], xBC_raw], axis=1)  # (B, K, conv_dim)
    w = params["conv_w"].astype(u_tok.dtype)
    xBC = jax.nn.silu(jnp.einsum("bkc,kc->bc", window, w)
                      + params["conv_b"].astype(u_tok.dtype))[:, None]
    x, B, C = jnp.split(xBC, [di, di + G * N], axis=-1)
    x = x.reshape(b, H, P)
    B = jnp.repeat(B.reshape(b, G, N), H // G, axis=1)
    C = jnp.repeat(C.reshape(b, G, N), H // G, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))[:, 0]  # (b,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    da = jnp.exp(dt * A[None])                                   # (b,H)
    S = state["ssm"].astype(jnp.float32)
    S = S * da[..., None, None] + jnp.einsum(
        "bhp,bhn->bhpn", (x * dt[..., None]).astype(jnp.float32),
        B.astype(jnp.float32))
    y = jnp.einsum("bhn,bhpn->bhp", C.astype(jnp.float32), S)
    y = y.astype(u_tok.dtype) + x * params["D"].astype(u_tok.dtype)[None, :, None]
    y = y.reshape(b, 1, di)
    y = rmsnorm(params["out_norm"], y * jax.nn.silu(z))
    out = jnp.einsum("bsd,de->bse", y, params["out_proj"].astype(u_tok.dtype))
    new_state = {"ssm": S.astype(state["ssm"].dtype), "conv": window[:, 1:]}
    return out, new_state
