"""DiT — the paper-native epsilon-network, TPU-adapted (DESIGN.md §7.1: the
paper's UNet checkpoints are CNNs; on TPU the standard diffusion backbone is a
patch transformer with adaLN-zero time conditioning, Peebles & Xie 2023).

Operates on pre-patchified latents (B, patch_tokens, latent_dim); class
conditioning optional (classifier-free guidance drops the class embedding).

Feature reuse (DESIGN.md §12): `dit_apply_cached` splits the block stack at a
static boundary `cache_block` and carries the deep segment's *residual delta*
as explicit cache state. On a full eval the deep blocks run and the delta is
recorded; on a shallow eval only the first `cache_block` blocks (plus the
final layer) recompute and the cached delta stands in for the deep segment —
the DeepCache observation that deep features drift slowly across adjacent
solver steps, applied to a residual transformer.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..kernels.adaln_modulate import ops as adaln_ops
from ..parallel.sharding import shard
from .layers import attention_apply, attention_init, dense_apply, dense_init


def timestep_embedding(t, dim: int, max_period=10000.0):
    """t: (B,) float in [0, 1]-ish; sinusoidal features then MLP outside."""
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period) * jnp.arange(half) / half)
    ang = t[:, None].astype(jnp.float32) * freqs[None] * 1000.0
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], axis=-1)


def _dit_block_init(rng, cfg):
    ks = jax.random.split(rng, 4)
    d = cfg.d_model
    return {
        "attn": attention_init(ks[0], cfg),
        "w1": dense_init(ks[1], d, cfg.d_ff, cfg.weight_dtype),
        "w2": dense_init(ks[2], cfg.d_ff, d, cfg.weight_dtype,
                         scale=1.0 / math.sqrt(cfg.d_ff)),
        # adaLN-zero: 6 modulation vectors, zero-init
        "ada": jnp.zeros((d, 6 * d), cfg.weight_dtype),
        "ada_b": jnp.zeros((6 * d,), cfg.weight_dtype),
    }


def init_dit(cfg, rng, num_classes: int = 0):
    ks = jax.random.split(rng, cfg.num_layers + 5)
    blocks = [_dit_block_init(k, cfg) for k in ks[: cfg.num_layers]]
    d = cfg.d_model
    p = {
        "in_proj": dense_init(ks[-1], cfg.latent_dim, d, cfg.weight_dtype),
        "t_mlp1": dense_init(ks[-2], 256, d, cfg.weight_dtype),
        "t_mlp2": dense_init(ks[-3], d, d, cfg.weight_dtype),
        "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
        "final_ada": jnp.zeros((d, 2 * d), cfg.weight_dtype),
        "final_ada_b": jnp.zeros((2 * d,), cfg.weight_dtype),
        "out_proj": jnp.zeros((d, cfg.latent_dim), cfg.weight_dtype),
    }
    if num_classes:
        p["class_embed"] = (0.02 * jax.random.normal(
            ks[-4], (num_classes + 1, d))).astype(cfg.weight_dtype)
    return p


def _embed(params, cfg, x_t, t, class_ids):
    """Shared front end: patch projection + adaLN conditioning vector."""
    B = x_t.shape[0]
    t = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (B,))
    x = jnp.einsum("btl,ld->btd", x_t.astype(cfg.activation_dtype),
                   params["in_proj"].astype(cfg.activation_dtype))
    x = shard(x, "batch", "seq", "d_model")
    c = jax.nn.silu(jnp.einsum(
        "bf,fd->bd", timestep_embedding(t, 256),
        params["t_mlp1"].astype(jnp.float32)))
    c = jnp.einsum("bd,de->be", c, params["t_mlp2"].astype(jnp.float32))
    if class_ids is not None and "class_embed" in params:
        c = c + params["class_embed"].astype(jnp.float32)[class_ids]
    c = jax.nn.silu(c).astype(x.dtype)
    return x, c


def _block_body(cfg, c, adaln, tap=None):
    """Scan body over the stacked block params (fused adaLN, DESIGN.md §11).
    Every dense site goes through `dense_apply`, so a quantized param tree
    (models/quant.py records) routes through kernels/quant_matmul with no
    change here. `tap` is the calibration hook — None (the default) in every
    serving/training path, a per-site absmax recorder when models/quant.py
    replays the forward unrolled."""

    def body(h, bp):
        if tap is not None:
            tap("ada", c)
        mod = dense_apply(c, bp["ada"], cfg) + bp["ada_b"].astype(h.dtype)
        sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6, axis=-1)
        hn = adaln_ops.modulate(h, sh1, sc1, backend=adaln)
        a = attention_apply(bp["attn"], hn, cfg, causal=False, rope=False,
                            tap=tap)
        h = adaln_ops.gate_residual(h, g1, a, backend=adaln)
        hn = adaln_ops.modulate(h, sh2, sc2, backend=adaln)
        if tap is not None:
            tap("mlp_in", hn)
        y = jax.nn.gelu(dense_apply(hn, bp["w1"], cfg))
        if tap is not None:
            tap("mlp_mid", y)
        y = dense_apply(y, bp["w2"], cfg)
        return adaln_ops.gate_residual(h, g2, y, backend=adaln), None

    return body


def _head(params, cfg, x, c, adaln, tap=None):
    """Final adaLN + output projection back to latent width."""
    if tap is not None:
        tap("final_ada", c)
    mod = (dense_apply(c, params["final_ada"], cfg)
           + params["final_ada_b"].astype(x.dtype))
    sh, sc = jnp.split(mod, 2, axis=-1)
    x = adaln_ops.modulate(x, sh, sc, backend=adaln)
    return jnp.einsum("btd,dl->btl", x, params["out_proj"].astype(x.dtype))


def dit_apply(params, cfg, x_t, t, class_ids=None):
    """x_t: (B, T, latent_dim); t: scalar or (B,). Returns eps-hat, same shape."""
    adaln = getattr(cfg, "adaln_backend", None)
    x, c = _embed(params, cfg, x_t, t, class_ids)
    x, _ = jax.lax.scan(_block_body(cfg, c, adaln), x, params["blocks"])
    return _head(params, cfg, x, c, adaln)


def dit_cache_shape(cfg):
    """Per-sample shape of the deep-feature cache (the residual delta of the
    blocks past the cache boundary): one (T, d_model) array per slot."""
    return (cfg.patch_tokens, cfg.d_model)


def dit_apply_cached(params, cfg, x_t, t, class_ids=None, *, cache,
                     reuse=None, cache_block: int):
    """DiT eval with a deep-feature cache at a static block boundary.

    cache: (B, T, d_model) — the deep segment's residual delta
        (x_after_all_blocks − x_after_cache_block) recorded at each sample's
        last full eval. Zero-init is safe: the first eval of a trajectory
        must be a full one (the table's init row always is).
    reuse: scalar or (B,) flag, 1 = shallow eval (reuse the cached delta and
        recompute only the first `cache_block` blocks + the final layer),
        0 = full eval (recompute everything, refresh the cache). None = 0.
    cache_block: static split index k, 1 <= k < num_layers.

    Returns (eps_hat, new_cache). With reuse = 0 everywhere the deep scan
    runs and the output is BIT-IDENTICAL to `dit_apply` at fp32 (the shallow
    and deep block scans chain the same body over the same stacked params).
    The deep segment only executes when some sample in the batch needs a
    full eval (`lax.cond` on the batch-reduced flag), so an all-shallow tick
    pays k blocks instead of num_layers.
    """
    L = int(cfg.num_layers)
    k = int(cache_block)
    if not 1 <= k < L:
        raise ValueError(f"cache_block must be in 1..{L - 1} "
                         f"(num_layers={L}), got {k}")
    adaln = getattr(cfg, "adaln_backend", None)
    x, c = _embed(params, cfg, x_t, t, class_ids)
    body = _block_body(cfg, c, adaln)
    shallow = jax.tree.map(lambda a: a[:k], params["blocks"])
    deep = jax.tree.map(lambda a: a[k:], params["blocks"])
    x_k, _ = jax.lax.scan(body, x, shallow)

    B = x_t.shape[0]
    reuse = (jnp.zeros((B,), jnp.float32) if reuse is None
             else jnp.broadcast_to(jnp.asarray(reuse, jnp.float32), (B,)))
    need_deep = jnp.any(reuse < 0.5)
    x_deep = jax.lax.cond(
        need_deep,
        lambda xk: jax.lax.scan(body, xk, deep)[0],
        lambda xk: xk,  # all-shallow tick: deep blocks skipped entirely
        x_k)
    cache = cache.astype(x_k.dtype)
    r = (reuse > 0.5).reshape((B,) + (1,) * (x_k.ndim - 1))
    # full slots take the freshly computed deep output (exact — never
    # reconstructed through the delta) and refresh their cache; shallow
    # slots approximate it as x_k + cached delta and keep their cache
    x_out = jnp.where(r, x_k + cache, x_deep)
    new_cache = jnp.where(r, cache, x_deep - x_k)
    return _head(params, cfg, x_out, c, adaln), new_cache
