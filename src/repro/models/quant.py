"""Quantized denoiser path: QuantSpec, calibration, param-tree quantization.

DESIGN.md §14. The flow is

    QuantSpec (a serving tier's precision contract)
      -> calibrate_act_stats (per-site activation absmax over a few
         deterministic reference trajectories; only the a8 tiers need it)
      -> quantize_params (replace each selected weight leaf with a quant
         record {"qw", "ws"[, "sa"]})
      -> layers.dense_apply routes records through kernels/quant_matmul

Routing is purely structural: a dense site sees either a raw weight array
(unchanged fp path) or a record installed here, so the cached feature-reuse
forward, CFG stacking, and every other eval path quantize for free. Static
metadata (bits, granularity, families) lives on the spec — the param tree
carries only arrays, which keeps the stacked block leaves scannable.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..diffusion.schedules import VPLinear
from ..kernels.quant_matmul import ref as qref
from . import dit

FAMILIES = ("attn", "mlp", "adaln")

# dense-site -> (family, activation-stat name); per-block sites live inside
# params["backbone"]["blocks"], final_ada at the backbone top level. wq/wk/wv
# share one stat: DiT attention is self-attention, all three read the same
# normed activation.
_BLOCK_SITES = {
    "wq": ("attn", "qkv"), "wk": ("attn", "qkv"), "wv": ("attn", "qkv"),
    "wo": ("attn", "wo"),
    "w1": ("mlp", "mlp_in"), "w2": ("mlp", "mlp_mid"),
    "ada": ("adaln", "ada"),
}
PER_BLOCK_STATS = ("qkv", "wo", "mlp_in", "mlp_mid", "ada")


@dataclass(frozen=True)
class QuantSpec:
    """A quality tier's precision contract (immutable, hashable — lives on
    the model config and inside EngineSpec validation)."""
    bits: int = 8               # weight bits: 8 | 4 (int8 container)
    act_bits: int = 16          # 16 = float activations, 8 = static int8
    granularity: str = "channel"  # per-output-"channel" | per-"tensor"
    fmt: str = "int"            # "int" | "fp8" (e4m3 weights)
    families: Tuple[str, ...] = FAMILIES


# the serving-facing tier names (EngineSpec.quant / --quant). "w4a16" is the
# deliberately harsh tier: per-tensor int4 exists to prove the tuner's
# parity gate rejects an over-quantized spec, not to ship.
QUANT_MODES = {
    "w8a16": QuantSpec(),
    "w8a8": QuantSpec(act_bits=8),
    "fp8a16": QuantSpec(fmt="fp8"),
    "w4a16": QuantSpec(bits=4, granularity="tensor"),
}


def quant_spec(mode: str) -> QuantSpec:
    if mode not in QUANT_MODES:
        raise ValueError(f"quant mode must be one of "
                         f"{('none',) + tuple(QUANT_MODES)}, got {mode!r}")
    return QUANT_MODES[mode]


def _require_dit(cfg):
    if cfg.family != "dit":
        raise ValueError(f"the quantized denoiser path needs the dit family "
                         f"(adaLN block stack); arch {cfg.arch_id!r} is "
                         f"family {cfg.family!r}")


# ---------------------------------------------------------------------------
# calibration: per-site activation absmax over reference trajectories
# ---------------------------------------------------------------------------

def calibrate_act_stats(cfg, params, *, schedule=None, nfe: int = 6,
                        batch: int = 2, seed: int = 0, class_ids=None):
    """Record per-dense-site activation absmax along `batch` deterministic
    DDIM reference trajectories (probe latents from PRNGKey(seed)).

    Runs eagerly with the block scan unrolled in python — inside `lax.scan`
    the per-block activations are tracers, so the unrolled replay is what
    makes per-block stats observable. The replay chains the *same*
    `dit._block_body` the shipped forward scans (tap hooks default to None
    there), so the recorded activations are exactly the serving ones; a
    tier-1 test pins replay == `dit_apply` to catch drift.

    Returns {stat_name: np.float32 array}, (num_layers,) per block site and
    scalar for final_ada. Pure deterministic fp given (params, seed, nfe,
    batch) — same trajectories, bit-identical stats.
    """
    _require_dit(cfg)
    schedule = schedule or VPLinear()
    L = int(cfg.num_layers)
    stats = {name: np.zeros((L,), np.float32) for name in PER_BLOCK_STATS}
    stats["final_ada"] = np.zeros((), np.float32)

    key = jax.random.PRNGKey(seed)
    k_x, k_c = jax.random.split(key)
    x = jax.random.normal(
        k_x, (batch, cfg.patch_tokens, cfg.latent_dim),
        jnp.float32).astype(cfg.activation_dtype)
    bk = params["backbone"]
    if class_ids is None and "class_embed" in bk:
        n_cls = bk["class_embed"].shape[0] - 1
        class_ids = jax.random.randint(k_c, (batch,), 0, n_cls)

    cur = {"i": 0}

    def tap(site, v):
        m = np.float32(jnp.max(jnp.abs(v.astype(jnp.float32))))
        if stats[site].ndim:
            i = cur["i"]
            stats[site][i] = max(stats[site][i], m)
        else:
            stats[site] = np.maximum(stats[site], m)

    def tapped_eps(x_t, t):
        adaln = getattr(cfg, "adaln_backend", None)
        h, c = dit._embed(bk, cfg, x_t, t, class_ids)
        body = dit._block_body(cfg, c, adaln, tap=tap)
        for i in range(L):
            cur["i"] = i
            bp = jax.tree.map(lambda a: a[i], bk["blocks"])
            h, _ = body(h, bp)
        return dit._head(bk, cfg, h, c, adaln, tap=tap)

    # coarse DDIM trajectory, T -> t_eps: the probe visits the same noise
    # levels a served request does, so the absmax covers the serving range
    ts = np.linspace(schedule.T, schedule.t_eps, nfe + 1)
    for t, t_next in zip(ts[:-1], ts[1:]):
        eps = tapped_eps(x, t)
        a, s = float(schedule.alpha(t)), float(schedule.sigma(t))
        a_n, s_n = float(schedule.alpha(t_next)), float(schedule.sigma(t_next))
        x0 = (x - s * eps) / a
        x = a_n * x0 + s_n * eps
    tapped_eps(x, ts[-1])  # stats at the final (lowest-noise) state too
    return stats


# ---------------------------------------------------------------------------
# param-tree quantization
# ---------------------------------------------------------------------------

def _record(w, spec: QuantSpec, amax=None):
    qw, ws = qref.quantize(w, bits=spec.bits, granularity=spec.granularity,
                           fmt=spec.fmt)
    rec = {"qw": qw, "ws": ws}
    if spec.act_bits == 8:
        rec["sa"] = jnp.maximum(
            jnp.asarray(amax, jnp.float32), 1e-12) / qref.ACT_QMAX
    return rec


def quantize_params(cfg, params, spec: QuantSpec, act_stats=None):
    """Replace the selected dense weight leaves with quant records.

    Per-block leaves are stacked (L, K, N); quantization reduces over the K
    axis only, so each block keeps independent per-channel scales and the
    records stay scannable. `act_stats` (from `calibrate_act_stats`) is
    required for a8 tiers: the (L,) per-site absmax becomes a stacked static
    activation scale, unstacked per block by the scan.
    """
    _require_dit(cfg)
    if spec.act_bits == 8 and act_stats is None:
        raise ValueError("act_bits=8 needs calibrated activation stats — "
                         "run models.quant.calibrate_act_stats (or go "
                         "through api.calibrate_and_quantize)")
    out = jax.tree.map(lambda a: a, params)  # shallow-ish copy of the dicts
    bk = dict(out["backbone"])
    blocks = dict(bk["blocks"])
    for name, (family, stat) in _BLOCK_SITES.items():
        if family not in spec.families:
            continue
        amax = act_stats[stat] if spec.act_bits == 8 else None
        if name in ("wq", "wk", "wv", "wo"):
            attn = dict(blocks["attn"])
            attn[name] = _record(attn[name], spec, amax)
            blocks["attn"] = attn
        else:
            blocks[name] = _record(blocks[name], spec, amax)
    bk["blocks"] = blocks
    if "adaln" in spec.families:
        amax = act_stats["final_ada"] if spec.act_bits == 8 else None
        bk["final_ada"] = _record(bk["final_ada"], spec, amax)
    out["backbone"] = bk
    return out


def quant_param_bytes(params) -> dict:
    """Quantized vs fp32 weight-byte accounting over the installed records
    (benchmarks): {"quant": bytes actually stored, "fp32": the bytes the
    same sites would cost unquantized}."""
    n = {"quant": 0, "fp32": 0}

    def visit(sub):
        if isinstance(sub, dict) and "qw" in sub:
            n["quant"] += sum(int(np.prod(v.shape)) * v.dtype.itemsize
                              for v in sub.values())
            n["fp32"] += int(np.prod(sub["qw"].shape)) * 4
            return
        if isinstance(sub, dict):
            for v in sub.values():
                visit(v)

    visit(params)
    return n
