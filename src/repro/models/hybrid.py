"""SSM and hybrid LMs.

* MambaLM — pure Mamba2 stack (mamba2-780m): scan over stacked SSD blocks.
* Zamba2LM — Mamba2 backbone with ONE *shared* attention block applied every
  `cfg.attn_every` SSM layers (zamba2's parameter-shared attention; we omit the
  per-invocation LoRA deltas of the released checkpoints — noted in the config).

Both support full-sequence forward (train/prefill) and O(1)-state decode.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..parallel.sharding import shard
from .layers import (NORMS, attention_apply, attention_init, dense_init,
                     maybe_remat, mlp_apply, mlp_init)
from .ssm import (init_mamba_state, mamba2_apply, mamba2_decode, mamba2_init)
from .transformer import (_attn_with_cache, cache_window, logits_from_hidden)


def _norm(cfg):
    init, apply = NORMS[cfg.norm]
    return init, apply


def _ssm_layer_init(rng, cfg):
    ninit, _ = _norm(cfg)
    return {"ln": ninit(cfg.d_model, cfg.weight_dtype),
            "mamba": mamba2_init(rng, cfg)}


def init_mamba_lm(cfg, rng):
    ks = jax.random.split(rng, cfg.num_layers + 2)
    layers = [_ssm_layer_init(k, cfg) for k in ks[: cfg.num_layers]]
    ninit, _ = _norm(cfg)
    return {
        "embed": dense_init(ks[-1], cfg.vocab_size, cfg.d_model,
                            cfg.weight_dtype, scale=0.02),
        "layers": jax.tree.map(lambda *xs: jnp.stack(xs), *layers),
        "final_ln": ninit(cfg.d_model, cfg.weight_dtype),
    }


def mamba_forward(params, cfg, tokens, *, inputs_embeds=None):
    _, napply = _norm(cfg)
    x = (inputs_embeds if inputs_embeds is not None
         else params["embed"].astype(cfg.activation_dtype)[tokens])
    x = shard(x, "batch", "seq", "d_model")

    def body(h, lp):
        return h + mamba2_apply(lp["mamba"], napply(lp["ln"], h), cfg), None

    x, _ = jax.lax.scan(maybe_remat(body, cfg), x, params["layers"])
    return napply(params["final_ln"], x), jnp.zeros((), jnp.float32)


def mamba_lm_loss(params, cfg, tokens, targets):
    hidden, _ = mamba_forward(params, cfg, tokens)
    logits = logits_from_hidden(params, cfg, hidden).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, targets[..., None], axis=-1).mean()


def init_mamba_cache(cfg, batch, max_len=None):
    one = init_mamba_state(cfg, batch)
    return jax.tree.map(
        lambda z: jnp.zeros((cfg.num_layers,) + z.shape, z.dtype), one)


def mamba_prefill(params, cfg, tokens, max_len=None):
    """Full-sequence pass that also returns the decode state per layer."""
    _, napply = _norm(cfg)
    x = params["embed"].astype(cfg.activation_dtype)[tokens]

    def body(h, lp):
        y, st = mamba2_apply(lp["mamba"], napply(lp["ln"], h), cfg,
                             return_state=True)
        return h + y, st

    x, states = jax.lax.scan(body, x, params["layers"])
    hidden = napply(params["final_ln"], x[:, -1:])
    return logits_from_hidden(params, cfg, hidden), states


def mamba_decode_step(params, cfg, cache, token, pos):
    _, napply = _norm(cfg)
    x = params["embed"].astype(cfg.activation_dtype)[token]

    def body(h, lc):
        lp, st = lc
        y, st = mamba2_decode(lp["mamba"], st, napply(lp["ln"], h), cfg)
        return h + y, st

    x, states = jax.lax.scan(body, x, (params["layers"], cache))
    hidden = napply(params["final_ln"], x)
    return logits_from_hidden(params, cfg, hidden), states


# ---------------------------------------------------------------------------
# zamba2: groups of `attn_every` mamba layers + one shared attention block
# ---------------------------------------------------------------------------

def _zamba_groups(cfg):
    n_groups = cfg.num_layers // cfg.attn_every
    tail = cfg.num_layers - n_groups * cfg.attn_every
    return n_groups, tail


def init_zamba_lm(cfg, rng):
    n_groups, tail = _zamba_groups(cfg)
    ninit, _ = _norm(cfg)
    n_ssm = n_groups * cfg.attn_every + tail
    ks = jax.random.split(rng, n_ssm + 4)
    layers = [_ssm_layer_init(k, cfg) for k in ks[:n_ssm]]
    grouped = layers[: n_groups * cfg.attn_every]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *grouped)
    # reshape leading axis (n_groups * attn_every) -> (n_groups, attn_every)
    stacked = jax.tree.map(
        lambda a: a.reshape((n_groups, cfg.attn_every) + a.shape[1:]), stacked)
    p = {
        "embed": dense_init(ks[-1], cfg.vocab_size, cfg.d_model,
                            cfg.weight_dtype, scale=0.02),
        "groups": stacked,
        "shared_attn": {
            "ln1": ninit(cfg.d_model, cfg.weight_dtype),
            "attn": attention_init(ks[-2], cfg),
            "ln2": ninit(cfg.d_model, cfg.weight_dtype),
            "mlp": mlp_init(ks[-3], cfg),
        },
        "final_ln": ninit(cfg.d_model, cfg.weight_dtype),
    }
    if tail:
        tail_layers = layers[n_groups * cfg.attn_every:]
        p["tail"] = jax.tree.map(lambda *xs: jnp.stack(xs), *tail_layers)
    return p


def _shared_attn_block(sp, x, cfg, napply):
    a = attention_apply(sp["attn"], napply(sp["ln1"], x), cfg, causal=True,
                        sliding_window=cfg.sliding_window)
    x = x + a
    return x + mlp_apply(sp["mlp"], napply(sp["ln2"], x), cfg)


def zamba_forward(params, cfg, tokens, *, inputs_embeds=None):
    _, napply = _norm(cfg)
    x = (inputs_embeds if inputs_embeds is not None
         else params["embed"].astype(cfg.activation_dtype)[tokens])
    x = shard(x, "batch", "seq", "d_model")
    sp = params["shared_attn"]

    def ssm_body(h, lp):
        return h + mamba2_apply(lp["mamba"], napply(lp["ln"], h), cfg), None

    def group_body(h, gp):
        h, _ = jax.lax.scan(maybe_remat(ssm_body, cfg), h, gp)
        return _shared_attn_block(sp, h, cfg, napply), None

    x, _ = jax.lax.scan(maybe_remat(group_body, cfg), x, params["groups"])
    if "tail" in params:
        x, _ = jax.lax.scan(ssm_body, x, params["tail"])
    return napply(params["final_ln"], x), jnp.zeros((), jnp.float32)


def zamba_lm_loss(params, cfg, tokens, targets):
    hidden, _ = zamba_forward(params, cfg, tokens)
    logits = logits_from_hidden(params, cfg, hidden).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, targets[..., None], axis=-1).mean()


def init_zamba_cache(cfg, batch, max_len):
    n_groups, tail = _zamba_groups(cfg)
    one = init_mamba_state(cfg, batch)
    W = cache_window(cfg, max_len)
    kv = jnp.zeros((n_groups, batch, W, cfg.num_kv_heads, cfg.head_dim),
                   cfg.activation_dtype)
    cache = {
        "groups": jax.tree.map(
            lambda z: jnp.zeros((n_groups, cfg.attn_every) + z.shape, z.dtype),
            one),
        "attn_k": kv, "attn_v": kv,
    }
    if tail:
        cache["tail"] = jax.tree.map(
            lambda z: jnp.zeros((tail,) + z.shape, z.dtype), one)
    return cache


def zamba_decode_step(params, cfg, cache, token, pos):
    _, napply = _norm(cfg)
    x = params["embed"].astype(cfg.activation_dtype)[token]
    sp = params["shared_attn"]
    W = cache["attn_k"].shape[2]

    def ssm_body(h, lc):
        lp, st = lc
        y, st = mamba2_decode(lp["mamba"], st, napply(lp["ln"], h), cfg)
        return h + y, st

    def group_body(h, gc):
        gp, gst, kc, vc = gc
        h, gst = jax.lax.scan(ssm_body, h, (gp, gst))
        a, kc, vc = _attn_with_cache(sp, napply(sp["ln1"], h), kc, vc, pos, cfg, W)
        h = h + a
        h = h + mlp_apply(sp["mlp"], napply(sp["ln2"], h), cfg)
        return h, (gst, kc, vc)

    x, (gst, kc, vc) = jax.lax.scan(
        group_body, x,
        (params["groups"], cache["groups"], cache["attn_k"], cache["attn_v"]))
    new_cache = dict(cache, groups=gst, attn_k=kc, attn_v=vc)
    if "tail" in params:
        x, tst = jax.lax.scan(ssm_body, x, (params["tail"], cache["tail"]))
        new_cache["tail"] = tst
    hidden = napply(params["final_ln"], x)
    return logits_from_hidden(params, cfg, hidden), new_cache


def zamba_prefill(params, cfg, tokens, max_len):
    """Prefill by running decode positions via full-sequence mamba + attention
    with cache rebuild (attention K/V recomputed from the shared block inputs)."""
    _, napply = _norm(cfg)
    from .layers import apply_rope
    B, S = tokens.shape
    W = cache_window(cfg, max_len)
    x = params["embed"].astype(cfg.activation_dtype)[tokens]
    sp = params["shared_attn"]
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))

    def ssm_body(h, lp):
        y, st = mamba2_apply(lp["mamba"], napply(lp["ln"], h), cfg,
                             return_state=True)
        return h + y, st

    def group_body(h, gp):
        h, gst = jax.lax.scan(ssm_body, h, gp)
        xn = napply(sp["ln1"], h)
        a = attention_apply(sp["attn"], xn, cfg, causal=True,
                            sliding_window=cfg.sliding_window)
        h2 = h + a
        h_out = h2 + mlp_apply(sp["mlp"], napply(sp["ln2"], h2), cfg)
        k = jnp.einsum("bsd,de->bse", xn, sp["attn"]["wk"].astype(h.dtype))
        v = jnp.einsum("bsd,de->bse", xn, sp["attn"]["wv"].astype(h.dtype))
        k = apply_rope(k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim), pos,
                       cfg.rope_theta)
        v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
        if S >= W:
            tail_pos = jnp.arange(S - W, S)
            slots = jnp.mod(tail_pos, W)
            kc = jnp.zeros((B, W) + k.shape[2:], k.dtype).at[:, slots].set(k[:, S - W:])
            vc = jnp.zeros((B, W) + v.shape[2:], v.dtype).at[:, slots].set(v[:, S - W:])
        else:
            padw = ((0, 0), (0, W - S), (0, 0), (0, 0))
            kc, vc = jnp.pad(k, padw), jnp.pad(v, padw)
        return h_out, (gst, kc, vc)

    x, (gst, kc, vc) = jax.lax.scan(group_body, x, params["groups"])
    cache = {"groups": gst, "attn_k": kc, "attn_v": vc}
    if "tail" in params:
        x, tst = jax.lax.scan(ssm_body, x, params["tail"])
        cache["tail"] = tst
    hidden = napply(params["final_ln"], x[:, -1:])
    return logits_from_hidden(params, cfg, hidden), cache
