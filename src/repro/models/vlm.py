"""Llama-3.2-Vision-style VLM decoder: groups of (cross_attn_every - 1)
self-attention layers followed by one gated cross-attention layer reading a
fixed buffer of projected image-patch embeddings.

The vision encoder is a STUB per the assignment carve-out: `image_embeds`
(B, image_tokens, d_model) arrive precomputed; only the projector + language
decoder are real. Cross-attention K/V are position-independent and precomputed
once at prefill — decode cost is O(1) in sequence length for those layers.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..parallel.sharding import shard
from .layers import (NORMS, attention_apply, attention_init, dense_init,
                     maybe_remat, mlp_apply, mlp_init, sdpa)
from .transformer import _attn_with_cache, cache_window, layer_init, logits_from_hidden


def _norm(cfg):
    init, apply = NORMS[cfg.norm]
    return init, apply


def _xattn_layer_init(rng, cfg):
    ninit, _ = _norm(cfg)
    ks = jax.random.split(rng, 2)
    return {
        "ln1": ninit(cfg.d_model, cfg.weight_dtype),
        "xattn": attention_init(ks[0], cfg),
        "gate_attn": jnp.zeros((), cfg.weight_dtype),
        "ln2": ninit(cfg.d_model, cfg.weight_dtype),
        "mlp": mlp_init(ks[1], cfg),
        "gate_mlp": jnp.zeros((), cfg.weight_dtype),
    }


def _vlm_groups(cfg):
    assert cfg.num_layers % cfg.cross_attn_every == 0
    return cfg.num_layers // cfg.cross_attn_every


def init_vlm(cfg, rng):
    n_groups = _vlm_groups(cfg)
    n_self = cfg.cross_attn_every - 1
    ks = jax.random.split(rng, n_groups * (n_self + 1) + 3)
    self_layers, x_layers = [], []
    idx = 0
    for _ in range(n_groups):
        self_layers.append([layer_init(ks[idx + i], cfg) for i in range(n_self)])
        idx += n_self
        x_layers.append(_xattn_layer_init(ks[idx], cfg))
        idx += 1
    stack2 = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[jax.tree.map(lambda *ys: jnp.stack(ys), *g) for g in self_layers])
    ninit, _ = _norm(cfg)
    return {
        "embed": dense_init(ks[-1], cfg.vocab_size, cfg.d_model,
                            cfg.weight_dtype, scale=0.02),
        "img_proj": dense_init(ks[-2], cfg.d_model, cfg.d_model,
                               cfg.weight_dtype),
        "self_groups": stack2,                      # (G, n_self, ...)
        "xattn_layers": jax.tree.map(lambda *xs: jnp.stack(xs), *x_layers),
        "final_ln": ninit(cfg.d_model, cfg.weight_dtype),
    }


def _xattn_block(xp, h, img, cfg, napply):
    a = attention_apply(xp["xattn"], napply(xp["ln1"], h), cfg, kv_src=img,
                        causal=False, rope=False)
    h = h + jnp.tanh(xp["gate_attn"]).astype(h.dtype) * a
    y = mlp_apply(xp["mlp"], napply(xp["ln2"], h), cfg)
    return h + jnp.tanh(xp["gate_mlp"]).astype(h.dtype) * y


def vlm_forward(params, cfg, tokens, image_embeds, *, inputs_embeds=None,
                causal=True):
    from .transformer import _block
    _, napply = _norm(cfg)
    x = (inputs_embeds if inputs_embeds is not None
         else params["embed"].astype(cfg.activation_dtype)[tokens])
    x = shard(x, "batch", "seq", "d_model")
    img = jnp.einsum("bnd,de->bne", image_embeds.astype(x.dtype),
                     params["img_proj"].astype(x.dtype))

    def self_body(h, lp):
        h, aux = _block(lp, h, cfg, sliding_window=cfg.sliding_window,
                        causal=causal)
        return h, aux

    def group_body(h, gp):
        sp, xp = gp
        h, _ = jax.lax.scan(maybe_remat(self_body, cfg), h, sp)
        return _xattn_block(xp, h, img, cfg, napply), None

    x, _ = jax.lax.scan(maybe_remat(group_body, cfg), x,
                        (params["self_groups"], params["xattn_layers"]))
    return napply(params["final_ln"], x), jnp.zeros((), jnp.float32)


def _forward_embeds(params, cfg, inputs_embeds, image_embeds):
    """Diffusion-mode entry: bidirectional, continuous inputs."""
    return vlm_forward(params, cfg, None, image_embeds,
                       inputs_embeds=inputs_embeds, causal=False)


def vlm_loss(params, cfg, tokens, targets, image_embeds):
    hidden, _ = vlm_forward(params, cfg, tokens, image_embeds)
    logits = logits_from_hidden(params, cfg, hidden).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, targets[..., None], axis=-1).mean()


def init_vlm_cache(cfg, batch, max_len):
    G = _vlm_groups(cfg)
    n_self = cfg.cross_attn_every - 1
    W = cache_window(cfg, max_len)
    kv = jnp.zeros((G, n_self, batch, W, cfg.num_kv_heads, cfg.head_dim),
                   cfg.activation_dtype)
    xkv = jnp.zeros((G, batch, cfg.image_tokens, cfg.num_kv_heads, cfg.head_dim),
                    cfg.activation_dtype)
    return {"k": kv, "v": kv, "img_k": xkv, "img_v": xkv}


def _img_kv(xp, img, cfg):
    B, T = img.shape[:2]
    k = jnp.einsum("bnd,de->bne", img, xp["xattn"]["wk"].astype(img.dtype))
    v = jnp.einsum("bnd,de->bne", img, xp["xattn"]["wv"].astype(img.dtype))
    if "bk" in xp["xattn"]:
        k = k + xp["xattn"]["bk"].astype(img.dtype)
        v = v + xp["xattn"]["bv"].astype(img.dtype)
    return (k.reshape(B, T, cfg.num_kv_heads, cfg.head_dim),
            v.reshape(B, T, cfg.num_kv_heads, cfg.head_dim))


def vlm_prefill(params, cfg, tokens, image_embeds, max_len):
    """Build self-attn KV caches + precompute per-layer image K/V."""
    from .layers import apply_rope
    _, napply = _norm(cfg)
    B, S = tokens.shape
    W = cache_window(cfg, max_len)
    x = params["embed"].astype(cfg.activation_dtype)[tokens]
    img = jnp.einsum("bnd,de->bne", image_embeds.astype(x.dtype),
                     params["img_proj"].astype(x.dtype))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))

    def self_body(h, lp):
        xn = napply(lp["ln1"], h)
        a = attention_apply(lp["attn"], xn, cfg, causal=True,
                            sliding_window=cfg.sliding_window)
        h2 = h + a
        h_out = h2 + mlp_apply(lp["mlp"], napply(lp["ln2"], h2), cfg)
        k = jnp.einsum("bsd,de->bse", xn, lp["attn"]["wk"].astype(h.dtype))
        v = jnp.einsum("bsd,de->bse", xn, lp["attn"]["wv"].astype(h.dtype))
        k = apply_rope(k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim), pos,
                       cfg.rope_theta)
        v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
        if S >= W:
            slots = jnp.mod(jnp.arange(S - W, S), W)
            kc = jnp.zeros((B, W) + k.shape[2:], k.dtype).at[:, slots].set(k[:, S - W:])
            vc = jnp.zeros((B, W) + v.shape[2:], v.dtype).at[:, slots].set(v[:, S - W:])
        else:
            padw = ((0, 0), (0, W - S), (0, 0), (0, 0))
            kc, vc = jnp.pad(k, padw), jnp.pad(v, padw)
        return h_out, (kc, vc)

    def group_body(h, gp):
        sp, xp = gp
        h, kv = jax.lax.scan(self_body, h, sp)
        h = _xattn_block(xp, h, img, cfg, napply)
        ik, iv = _img_kv(xp, img, cfg)
        return h, (kv[0], kv[1], ik, iv)

    x, (kc, vc, ik, iv) = jax.lax.scan(
        group_body, x, (params["self_groups"], params["xattn_layers"]))
    hidden = napply(params["final_ln"], x[:, -1:])
    cache = {"k": kc, "v": vc, "img_k": ik, "img_v": iv}
    return logits_from_hidden(params, cfg, hidden), cache


def vlm_decode_step(params, cfg, cache, token, pos):
    _, napply = _norm(cfg)
    x = params["embed"].astype(cfg.activation_dtype)[token]
    W = cache["k"].shape[3]
    B = x.shape[0]

    def self_body(h, lc):
        lp, kc, vc = lc
        a, kc, vc = _attn_with_cache(lp, napply(lp["ln1"], h), kc, vc, pos, cfg, W)
        h = h + a
        return h + mlp_apply(lp["mlp"], napply(lp["ln2"], h), cfg), (kc, vc)

    def group_body(h, gc):
        sp, xp, kc, vc, ik, iv = gc
        h, (kc, vc) = jax.lax.scan(self_body, h, (sp, kc, vc))
        # cross-attention against the fixed image K/V
        hq, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        xn = napply(xp["ln1"], h)
        q = jnp.einsum("bsd,de->bse", xn, xp["xattn"]["wq"].astype(h.dtype))
        if "bq" in xp["xattn"]:
            q = q + xp["xattn"]["bq"].astype(h.dtype)
        q = q.reshape(B, 1, hq, hd)
        o = sdpa(q, ik, iv, causal=False)
        o = jnp.einsum("bse,ed->bsd", o.reshape(B, 1, hq * hd),
                       xp["xattn"]["wo"].astype(h.dtype))
        h = h + jnp.tanh(xp["gate_attn"]).astype(h.dtype) * o
        y = mlp_apply(xp["mlp"], napply(xp["ln2"], h), cfg)
        h = h + jnp.tanh(xp["gate_mlp"]).astype(h.dtype) * y
        return h, (kc, vc)

    x, (kc, vc) = jax.lax.scan(
        group_body, x,
        (params["self_groups"], params["xattn_layers"],
         cache["k"], cache["v"], cache["img_k"], cache["img_v"]))
    hidden = napply(params["final_ln"], x)
    new_cache = dict(cache, k=kc, v=vc)
    return logits_from_hidden(params, cfg, hidden), new_cache
