"""Whisper-style encoder-decoder (audio family).

The mel-spectrogram + conv feature extractor is a STUB per the assignment
carve-out: `audio_embeds` (B, audio_frames, d_model) arrive precomputed.
Encoder: bidirectional attention with sinusoidal positions. Decoder: causal
self-attention + cross-attention to the encoder output; at serve time the
cross K/V are precomputed once at prefill.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..parallel.sharding import shard
from .layers import (NORMS, attention_apply, attention_init, dense_init,
                     maybe_remat, mlp_apply, mlp_init, sdpa)
from .transformer import _attn_with_cache, cache_window, logits_from_hidden


def _norm(cfg):
    init, apply = NORMS[cfg.norm]
    return init, apply


def sinusoids(length: int, d: int):
    half = d // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / (half - 1))
    ang = jnp.arange(length)[:, None] * freqs[None]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def _enc_layer_init(rng, cfg):
    ninit, _ = _norm(cfg)
    ks = jax.random.split(rng, 2)
    return {"ln1": ninit(cfg.d_model, cfg.weight_dtype),
            "attn": attention_init(ks[0], cfg),
            "ln2": ninit(cfg.d_model, cfg.weight_dtype),
            "mlp": mlp_init(ks[1], cfg)}


def _dec_layer_init(rng, cfg):
    ninit, _ = _norm(cfg)
    ks = jax.random.split(rng, 3)
    return {"ln1": ninit(cfg.d_model, cfg.weight_dtype),
            "attn": attention_init(ks[0], cfg),
            "lnx": ninit(cfg.d_model, cfg.weight_dtype),
            "xattn": attention_init(ks[1], cfg),
            "ln2": ninit(cfg.d_model, cfg.weight_dtype),
            "mlp": mlp_init(ks[2], cfg)}


def init_encdec(cfg, rng):
    ninit, _ = _norm(cfg)
    ks = jax.random.split(rng, cfg.encoder_layers + cfg.num_layers + 3)
    enc = [_enc_layer_init(k, cfg) for k in ks[: cfg.encoder_layers]]
    dec = [_dec_layer_init(k, cfg)
           for k in ks[cfg.encoder_layers: cfg.encoder_layers + cfg.num_layers]]
    return {
        "embed": dense_init(ks[-1], cfg.vocab_size, cfg.d_model,
                            cfg.weight_dtype, scale=0.02),
        "enc_layers": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "enc_ln": ninit(cfg.d_model, cfg.weight_dtype),
        "dec_layers": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "final_ln": ninit(cfg.d_model, cfg.weight_dtype),
    }


def encode(params, cfg, audio_embeds):
    _, napply = _norm(cfg)
    x = audio_embeds.astype(cfg.activation_dtype)
    x = x + sinusoids(x.shape[1], cfg.d_model).astype(x.dtype)[None]
    x = shard(x, "batch", "seq", "d_model")

    def body(h, lp):
        a = attention_apply(lp["attn"], napply(lp["ln1"], h), cfg,
                            causal=False, rope=False)
        h = h + a
        return h + mlp_apply(lp["mlp"], napply(lp["ln2"], h), cfg), None

    x, _ = jax.lax.scan(maybe_remat(body, cfg), x, params["enc_layers"])
    return napply(params["enc_ln"], x)


def _dec_block(lp, h, enc_out, cfg, napply, *, causal=True):
    a = attention_apply(lp["attn"], napply(lp["ln1"], h), cfg, causal=causal)
    h = h + a
    xa = attention_apply(lp["xattn"], napply(lp["lnx"], h), cfg,
                         kv_src=enc_out, causal=False, rope=False)
    h = h + xa
    return h + mlp_apply(lp["mlp"], napply(lp["ln2"], h), cfg)


def encdec_forward(params, cfg, tokens, audio_embeds, *, inputs_embeds=None,
                   causal=True):
    _, napply = _norm(cfg)
    enc_out = encode(params, cfg, audio_embeds)
    x = (inputs_embeds if inputs_embeds is not None
         else params["embed"].astype(cfg.activation_dtype)[tokens])
    x = shard(x, "batch", "seq", "d_model")

    def body(h, lp):
        return _dec_block(lp, h, enc_out, cfg, napply, causal=causal), None

    x, _ = jax.lax.scan(maybe_remat(body, cfg), x, params["dec_layers"])
    return napply(params["final_ln"], x), jnp.zeros((), jnp.float32)


def _forward_embeds(params, cfg, inputs_embeds, audio_embeds):
    """Diffusion-mode entry: bidirectional decoder over continuous inputs."""
    return encdec_forward(params, cfg, None, audio_embeds,
                          inputs_embeds=inputs_embeds, causal=False)


def encdec_loss(params, cfg, tokens, targets, audio_embeds):
    hidden, _ = encdec_forward(params, cfg, tokens, audio_embeds)
    logits = logits_from_hidden(params, cfg, hidden).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, targets[..., None], axis=-1).mean()


def _xattn_kv(lp, enc_out, cfg):
    B, T = enc_out.shape[:2]
    k = jnp.einsum("bnd,de->bne", enc_out, lp["xattn"]["wk"].astype(enc_out.dtype))
    v = jnp.einsum("bnd,de->bne", enc_out, lp["xattn"]["wv"].astype(enc_out.dtype))
    if "bk" in lp["xattn"]:
        k = k + lp["xattn"]["bk"].astype(enc_out.dtype)
        v = v + lp["xattn"]["bv"].astype(enc_out.dtype)
    return (k.reshape(B, T, cfg.num_kv_heads, cfg.head_dim),
            v.reshape(B, T, cfg.num_kv_heads, cfg.head_dim))


def encdec_prefill(params, cfg, tokens, audio_embeds, max_len):
    from .layers import apply_rope
    _, napply = _norm(cfg)
    enc_out = encode(params, cfg, audio_embeds)
    B, S = tokens.shape
    W = cache_window(cfg, max_len)
    x = params["embed"].astype(cfg.activation_dtype)[tokens]
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))

    def body(h, lp):
        xn = napply(lp["ln1"], h)
        h_out = _dec_block(lp, h, enc_out, cfg, napply)
        k = jnp.einsum("bsd,de->bse", xn, lp["attn"]["wk"].astype(h.dtype))
        v = jnp.einsum("bsd,de->bse", xn, lp["attn"]["wv"].astype(h.dtype))
        k = apply_rope(k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim), pos,
                       cfg.rope_theta)
        v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
        if S >= W:
            slots = jnp.mod(jnp.arange(S - W, S), W)
            kc = jnp.zeros((B, W) + k.shape[2:], k.dtype).at[:, slots].set(k[:, S - W:])
            vc = jnp.zeros((B, W) + v.shape[2:], v.dtype).at[:, slots].set(v[:, S - W:])
        else:
            padw = ((0, 0), (0, W - S), (0, 0), (0, 0))
            kc, vc = jnp.pad(k, padw), jnp.pad(v, padw)
        xk, xv = _xattn_kv(lp, enc_out, cfg)
        return h_out, (kc, vc, xk, xv)

    x, (kc, vc, xk, xv) = jax.lax.scan(body, x, params["dec_layers"])
    hidden = napply(params["final_ln"], x[:, -1:])
    return (logits_from_hidden(params, cfg, hidden),
            {"k": kc, "v": vc, "xk": xk, "xv": xv})


def encdec_decode_step(params, cfg, cache, token, pos):
    _, napply = _norm(cfg)
    x = params["embed"].astype(cfg.activation_dtype)[token]
    W = cache["k"].shape[2]
    B = x.shape[0]
    hq, hd = cfg.num_heads, cfg.head_dim

    def body(h, lc):
        lp, kc, vc, xk, xv = lc
        a, kc, vc = _attn_with_cache(lp, napply(lp["ln1"], h), kc, vc, pos, cfg, W)
        h = h + a
        xn = napply(lp["lnx"], h)
        q = jnp.einsum("bsd,de->bse", xn, lp["xattn"]["wq"].astype(h.dtype))
        if "bq" in lp["xattn"]:
            q = q + lp["xattn"]["bq"].astype(h.dtype)
        o = sdpa(q.reshape(B, 1, hq, hd), xk, xv, causal=False)
        o = jnp.einsum("bse,ed->bsd", o.reshape(B, 1, hq * hd),
                       lp["xattn"]["wo"].astype(h.dtype))
        h = h + o
        return h + mlp_apply(lp["mlp"], napply(lp["ln2"], h), cfg), (kc, vc)

    x, (kc, vc) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    hidden = napply(params["final_ln"], x)
    return logits_from_hidden(params, cfg, hidden), dict(cache, k=kc, v=vc)
