"""Diffusion-LM head: turns any backbone into the eps-network of a continuous
diffusion process over a latent sequence (B, S, latent_dim) — the vehicle for
applying UniPC to every assigned architecture family (DESIGN.md §7.1).

The backbone runs WITHOUT a causal mask where the family permits (attention
archs denoise bidirectionally); SSM/hybrid backbones stay causal by
construction (noted in DESIGN.md §7.1). Conditioning: sinusoidal lambda(t) features
added to the input projection (FiLM-light — sufficient for an eps-net; the
heavy adaLN variant lives in dit.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .dit import timestep_embedding
from .layers import dense_init


def init_diffusion_head(cfg, rng):
    d, L = cfg.d_model, cfg.latent_dim
    ks = jax.random.split(rng, 4)
    return {
        "in_proj": dense_init(ks[0], L, d, cfg.weight_dtype),
        "t_mlp1": dense_init(ks[1], 256, d, cfg.weight_dtype),
        "t_mlp2": dense_init(ks[2], d, d, cfg.weight_dtype),
        "out_proj": jnp.zeros((d, L), cfg.weight_dtype),
    }


def diffusion_lm_apply(head, backbone_forward, cfg, x_t, t):
    """x_t: (B, S, latent_dim); t scalar or (B,). backbone_forward:
    (inputs_embeds) -> (hidden, aux). Returns eps-hat (B, S, latent_dim)."""
    B = x_t.shape[0]
    t = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (B,))
    h = jnp.einsum("bsl,ld->bsd", x_t.astype(cfg.activation_dtype),
                   head["in_proj"].astype(cfg.activation_dtype))
    c = jax.nn.silu(jnp.einsum("bf,fd->bd", timestep_embedding(t, 256),
                               head["t_mlp1"].astype(jnp.float32)))
    c = jnp.einsum("bd,de->be", c, head["t_mlp2"].astype(jnp.float32))
    h = h + c.astype(h.dtype)[:, None]
    hidden, _aux = backbone_forward(h)
    return jnp.einsum("bsd,dl->bsl", hidden, head["out_proj"].astype(hidden.dtype))
