"""Mixture-of-Experts block: top-k routing with capacity-bucketed dispatch.

Dispatch is scatter-based (position-in-expert via cumsum) into per-expert
buffers (E, C, d_model) with C = ceil(k * N / E * capacity_factor); dropped
tokens fall through the residual connection. Expert FFNs run as one einsum
over stacked expert weights — tensor-parallel over the per-expert hidden on
the 'model' mesh axis, expert capacity sharded over 'data' (see DESIGN.md §7.3:
this sidesteps expert-count divisibility — mixtral has 8 experts, granite 40,
neither divides a 16-way model axis).

Aux losses: Switch-style load-balance loss + router z-loss.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from ..parallel.sharding import shard
from .layers import dense_init


def moe_init(rng, cfg):
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    E = cfg.num_experts
    dt = cfg.weight_dtype
    ks = jax.random.split(rng, 4)
    scale = 1.0 / math.sqrt(d)
    return {
        "router": dense_init(ks[0], d, E, dt, scale=0.02),
        "w_gate": (scale * jax.random.normal(ks[1], (E, d, f))).astype(dt),
        "w_up": (scale * jax.random.normal(ks[2], (E, d, f))).astype(dt),
        "w_down": ((1.0 / math.sqrt(f))
                   * jax.random.normal(ks[3], (E, f, d))).astype(dt),
    }


def _route(params, xf, cfg):
    """xf: (N, d) -> (probs (N, k), idx (N, k), aux_loss)."""
    logits = jnp.einsum("nd,de->ne", xf, params["router"].astype(xf.dtype))
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.experts_per_token)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # Switch load-balance loss + z-loss
    E = cfg.num_experts
    me = jnp.mean(probs, axis=0)                                  # mean prob
    ce = jnp.mean(jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32), axis=0)
    lb = E * jnp.sum(me * ce)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return top_p, top_i, lb + 1e-3 * z


def moe_apply(params, x, cfg):
    """x: (B, S, d). Returns (y, aux_loss).

    Baseline (moe_dispatch_groups == 0): one global position-in-expert cumsum
    over all N*k dispatch slots and a single (E, C, d) buffer. Under a sharded
    token axis this makes the scatter *global* — every slot may land in any
    shard of the buffer, so GSPMD lowers it to heavy cross-shard traffic (the
    §Perf H1 bottleneck).

    Optimized (moe_dispatch_groups == G, G aligned with the batch shards):
    tokens are split into G groups; positions are computed *within* each
    group into per-group buffers (G, E, C/G, d) whose leading axis shares the
    batch sharding — dispatch never crosses a shard boundary; only the expert
    FFN's tensor-parallel collectives remain."""
    B, S, d = x.shape
    N = B * S
    k = cfg.experts_per_token
    E = cfg.num_experts
    G = cfg.moe_dispatch_groups or 1
    assert N % G == 0, (N, G)
    n = N // G
    C = max(1, int(math.ceil(k * n / E * cfg.capacity_factor)))
    xf = x.reshape(N, d)
    top_p, top_i, aux = _route(params, xf, cfg)

    # position-in-expert within each dispatch group (G=1 -> global, baseline)
    flat_e = top_i.reshape(G, n * k)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)               # (G, n*k, E)
    pos = (jnp.cumsum(oh, axis=1) * oh).sum(-1) - 1               # (G, n*k)
    keep = pos < C
    slot = jnp.where(keep, pos, C)                                 # C = trash slot
    x_rep = jnp.repeat(xf.reshape(G, n, d), k, axis=1)             # (G, n*k, d)

    gi = jnp.arange(G)[:, None]
    buf = jnp.zeros((G, E, C + 1, d), x.dtype).at[gi, flat_e, slot].add(
        jnp.where(keep[..., None], x_rep, 0))
    buf = shard(buf, "expert_cap", "experts", None, "d_model")

    h_g = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"].astype(x.dtype))
    h_u = jnp.einsum("gecd,edf->gecf", buf, params["w_up"].astype(x.dtype))
    h = jax.nn.silu(h_g) * h_u
    h = shard(h, "expert_cap", "experts", None, "d_ff")
    out_buf = jnp.einsum("gecf,efd->gecd", h, params["w_down"].astype(x.dtype))

    y_rep = out_buf[gi, flat_e, slot] * keep[..., None]
    y = (y_rep.reshape(N, k, d)
         * top_p.astype(x.dtype).reshape(N, k, 1)).sum(axis=1)
    return y.reshape(B, S, d), aux


def moe_apply_shard_map(params, x, cfg, mesh, data_axes=("pod", "data"),
                        model_axis="model"):
    """§Perf H1 iteration 2: the whole MoE block under shard_map.

    GSPMD cannot prove that the grouped scatter/gather of `moe_apply` stays
    within a data shard (arbitrary-index scatter on a sharded operand), so it
    all-gathers the expert buffers — the dominant collective in the baseline.
    Under shard_map the dispatch is *structurally* local: tokens, positions,
    and buffers live per data shard; the only collectives are the router's
    aux-loss psum and the row-parallel w_down psum over the model axis.

    Expert weights arrive model-sharded on the hidden dim (f/|model| per
    chip), tokens batch-sharded; returns the same (y, aux) contract."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    data_axes = tuple(a for a in data_axes if a in mesh.shape)
    B, S, d = x.shape

    def local(w_router, w_gate, w_up, w_down, xs):
        y, aux = _moe_local(w_router, w_gate, w_up, w_down, xs, cfg)
        y = jax.lax.psum(y, model_axis)
        aux = jax.lax.pmean(aux, data_axes + (model_axis,))
        return y, aux

    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(None, None, model_axis), P(None, None, model_axis),
                  P(None, model_axis, None), P(data_axes)),
        out_specs=(P(data_axes), P()),
        check_rep=False,
    )
    return fn(params["router"], params["w_gate"], params["w_up"],
              params["w_down"], x)


def _moe_local(w_router, w_gate, w_up, w_down, x, cfg):
    """Per-shard dispatch + expert FFN (partial sums over the sharded f dim)."""
    B, S, d = x.shape
    N = B * S
    k, E = cfg.experts_per_token, cfg.num_experts
    C = max(1, int(math.ceil(k * N / E * cfg.capacity_factor)))
    xf = x.reshape(N, d)
    logits = jnp.einsum("nd,de->ne", xf, w_router.astype(xf.dtype))
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32), axis=0)
    aux = E * jnp.sum(me * ce) + 1e-3 * jnp.mean(
        jax.nn.logsumexp(logits, axis=-1) ** 2)

    flat_e = top_i.reshape(N * k)
    oh = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = (jnp.cumsum(oh, axis=0) * oh).sum(-1) - 1
    keep = pos < C
    slot = jnp.where(keep, pos, C)
    x_rep = jnp.repeat(xf, k, axis=0)
    buf = jnp.zeros((E, C + 1, d), x.dtype).at[flat_e, slot].add(
        jnp.where(keep[:, None], x_rep, 0))
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, w_gate.astype(x.dtype))) \
        * jnp.einsum("ecd,edf->ecf", buf, w_up.astype(x.dtype))
    out_buf = jnp.einsum("ecf,efd->ecd", h, w_down.astype(x.dtype))
    y_rep = out_buf[flat_e, slot] * keep[:, None]
    y = (y_rep.reshape(N, k, d)
         * top_p.astype(x.dtype).reshape(N, k, 1)).sum(axis=1)
    return y.reshape(B, S, d), aux


def moe_decode_apply(params, x, cfg):
    """Decode-time MoE (B, 1, d): tiny token count — dense-gather per expert
    via einsum over one-hot combine (k active experts per token)."""
    B, S, d = x.shape
    xf = x.reshape(B * S, d)
    top_p, top_i, _ = _route(params, xf, cfg)
    comb = jnp.einsum("nk,nke->ne", top_p,
                      jax.nn.one_hot(top_i, cfg.num_experts)).astype(x.dtype)
    h_g = jnp.einsum("nd,edf->nef", xf, params["w_gate"].astype(x.dtype))
    h_u = jnp.einsum("nd,edf->nef", xf, params["w_up"].astype(x.dtype))
    h = jax.nn.silu(h_g) * h_u
    out = jnp.einsum("nef,efd->ned", h, params["w_down"].astype(x.dtype))
    y = jnp.einsum("ned,ne->nd", out, comb)
    return y.reshape(B, S, d)
