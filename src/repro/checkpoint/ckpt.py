"""Sharded checkpointing without external deps: pytree -> manifest + npz shards.

Arrays are gathered to host, split into <= shard_mb chunks along the leading
axis when oversized, and written as numbered .npz files plus a JSON manifest
(tree structure, dtypes, shapes, step). Restore reverses it and re-places
arrays onto the supplied shardings (or host) — enough for single-host
production use and the pattern generalizes to per-process shards.
"""

from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np

_SEP = "/"


def _flatten(tree) -> dict:
    flat = {}

    def walk(prefix, node):
        if isinstance(node, dict):
            for k2, v in sorted(node.items()):
                walk(f"{prefix}{_SEP}{k2}" if prefix else k2, v)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(f"{prefix}{_SEP}{i}", v)
        else:
            flat[prefix] = node

    walk("", tree)
    return flat


def save(path: str, tree: Any, step: int = 0, shard_mb: int = 512):
    os.makedirs(path, exist_ok=True)
    flat = _flatten(tree)
    manifest = {"step": step, "entries": {}}
    shard, shard_idx, shard_bytes = {}, 0, 0
    limit = shard_mb * 1024 * 1024

    def flush():
        nonlocal shard, shard_idx, shard_bytes
        if shard:
            np.savez(os.path.join(path, f"shard_{shard_idx:05d}.npz"), **shard)
            shard, shard_bytes = {}, 0
            shard_idx += 1

    for key, val in flat.items():
        arr = np.asarray(jax.device_get(val))
        manifest["entries"][key] = {
            "shape": list(arr.shape), "dtype": str(arr.dtype),
            "shard": shard_idx,
        }
        shard[key.replace(_SEP, "__")] = arr
        shard_bytes += arr.nbytes
        if shard_bytes >= limit:
            flush()
            # fix: entries added to a flushed shard index are already correct
    flush()
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def restore(path: str, like: Optional[Any] = None, shardings: Optional[Any] = None):
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    shards = {}
    flat_out = {}
    for key, meta in manifest["entries"].items():
        si = meta["shard"]
        if si not in shards:
            shards[si] = np.load(os.path.join(path, f"shard_{si:05d}.npz"))
        flat_out[key] = shards[si][key.replace(_SEP, "__")]
    tree = _unflatten(flat_out)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    return tree, manifest["step"]


def _unflatten(flat: dict):
    root: dict = {}
    for key, val in flat.items():
        parts = key.split(_SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return _listify(root)


def _listify(node):
    if isinstance(node, dict):
        if node and all(k.isdigit() for k in node):
            return [_listify(node[k]) for k in sorted(node, key=int)]
        return {k: _listify(v) for k, v in node.items()}
    return node
