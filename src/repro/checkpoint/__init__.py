from . import ckpt
