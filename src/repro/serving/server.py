"""Request generators, the trace driver, and serving metrics.

Traffic is simulated in *tick time*: one tick = one batched model eval (the
scheduler's unit of work), so a trace is deterministic and hardware-free —
the same arrival stream replays identically on CPU and on the mesh. Wall-clock
figures come from measuring the ticks that actually ran: `run_trace` times
every step call and reports both tick-denominated metrics (latency in evals,
evals-per-latent) and wall-denominated ones (throughput in requests/s, p50/p95
latency seconds).

    PYTHONPATH=src python -m repro.serving.server --smoke

runs the CI smoke: a short Poisson trace against the reduced dit-cifar
backbone, asserting every request completes and that the scheduler performed
exactly one batched eval per tick.
"""

from __future__ import annotations

import argparse
import json
import time
from dataclasses import asdict, dataclass
from typing import List, Optional, Sequence

import jax
import numpy as np

from ..obs import metrics as obsm
from .faults import FaultPlan, MetaFault, NanFault
from .resilience import ResilienceConfig
from .scheduler import Completion, Request, SlotScheduler

TICK_WALL_BUCKETS = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1, 1.0, 3.0)


def poisson_requests(n: int, rate: float, seed: int = 0,
                     cfg_scales: Optional[Sequence[float]] = None,
                     base_seed: int = 0,
                     tiers: Optional[Sequence[str]] = None) -> List[Request]:
    """n requests with Exp(1/rate) inter-arrival gaps (arrival in tick units).

    `rate` is requests per tick. `cfg_scales`, if given, is cycled through the
    requests — the per-request guidance knob (UniPC Table 9 settings vary it).
    `tiers`, if given, is likewise cycled — the quality-tier tag plan-bank
    programs route on (`Request.tier`).
    """
    if rate <= 0:
        raise ValueError(f"arrival rate must be > 0 requests per tick, "
                         f"got {rate}")
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    return [Request(rid=i, seed=base_seed + i, arrival=float(arrivals[i]),
                    cfg_scale=(None if cfg_scales is None
                               else float(cfg_scales[i % len(cfg_scales)])),
                    tier=(None if tiers is None
                          else str(tiers[i % len(tiers)])))
            for i in range(n)]


def save_trace(path: str, requests: Sequence[Request]) -> None:
    rows = [{"rid": r.rid, "seed": r.seed, "arrival": r.arrival,
             "cfg_scale": r.cfg_scale, "extras": r.extras, "tier": r.tier,
             "ttl": r.ttl}
            for r in requests]
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)


def load_trace(path: str) -> List[Request]:
    """JSON trace: a list of {rid, seed, arrival, cfg_scale, extras, tier}
    objects; `extras` (optional) carries per-request model conditioning,
    e.g. {"class_ids": 7}; `tier` (optional) tags the request's quality tier
    for plan-bank serving."""
    with open(path) as f:
        rows = json.load(f)
    return [Request(rid=int(r["rid"]), seed=int(r.get("seed", 0)),
                    arrival=float(r.get("arrival", 0.0)),
                    cfg_scale=(None if r.get("cfg_scale") is None
                               else float(r["cfg_scale"])),
                    extras=r.get("extras"),
                    tier=(None if r.get("tier") is None
                          else str(r["tier"])),
                    ttl=(None if r.get("ttl") is None
                         else float(r["ttl"])))
            for r in rows]


@dataclass
class ServeMetrics:
    """What one trace run measured. Tick-denominated fields are deterministic
    (the simulation), *_s fields are measured wall-clock."""

    mode: str                 # continuous | gang
    requests: int
    completed: int
    slots: int
    n_rows: int               # evals per request (the per-request NFE
                              # budget); for plan-bank programs, the MAX
                              # across tiers — per_tier carries each tier's
                              # exact budget
    ticks: int                # batched step calls
    evals: int                # always == ticks
    makespan_ticks: float     # clock when the last request finished
    throughput_per_tick: float
    latency_ticks_p50: float
    latency_ticks_p95: float
    occupancy: float          # busy-slot fraction over ticks that ran
    evals_per_latent: float   # slot-evals spent per finished latent
    tick_s: float             # wall seconds per tick: the per-tick median at
                              # pipeline depth 1, wall_s / ticks otherwise
                              # (per-tick walls are meaningless mid-pipeline)
    throughput_rps: float     # completed / wall_s
    latency_s_p50: float
    latency_s_p95: float
    # plan-bank runs: {tier: {completed, evals, latency_ticks_p50}} — how
    # each quality tier fared inside the shared batch. None for single-plan.
    per_tier: Optional[dict] = None
    pipeline_depth: int = 1   # ticks kept in flight (DESIGN.md §13)
    wall_s: float = 0.0       # measured wall seconds for the whole trace
    host_us_per_tick: float = 0.0  # host bookkeeping µs per tick, excluding
                                   # time blocked on device readbacks
    # the host_us_per_tick split by tick phase (DESIGN.md §15):
    # {admission, dispatch, readback, bookkeeping} µs per executed tick —
    # admission + bookkeeping == host_us_per_tick; dispatch and readback are
    # device-facing time, reported for the "where a tick goes" breakdown
    host_phase_us_per_tick: Optional[dict] = None
    # resilience accounting (DESIGN.md §16). Completions and rejections
    # partition every submission: requests == completed + rejected, the
    # invariant run_trace metrics hold under overload and faults.
    rejected: int = 0         # shed before admission (queue_full + expired)
    expired: int = 0          # the TTL/deadline subset of `rejected`
    degraded: int = 0         # submissions remapped to the shed tier
    retries: int = 0          # non-finite re-admissions (validation retry)
    failed: int = 0           # completions with ok=False (retry exhausted)
    recoveries: int = 0       # host/device desync recoveries
    faults_injected: int = 0  # chaos-harness faults that fired (faults.py)

    def row(self) -> dict:
        return asdict(self)


def _counter_val(delta: dict, name: str, default=0):
    row = delta.get(name)
    return row["value"] if row else default


def serve_metrics_from_snapshot(delta: dict, *, mode: str, slots: int,
                                n_rows: int,
                                pipeline_depth: int = 1) -> ServeMetrics:
    """Re-derive `ServeMetrics` from a metrics-registry snapshot delta.

    `delta` is `obs.metrics.delta(before, after)` over the scheduler's
    registry around one run (`MetricsRegistry.snapshot` with samples). This
    is THE code path `run_trace` reports through — the live registry and the
    end-of-run aggregate cannot drift — and it is a pure function of
    JSON-able data, so `launch/obsreport.py --check` re-runs it on a saved
    metrics artifact and compares against the artifact's embedded metrics.

    Percentiles come from the histograms' exact retained samples; an empty
    histogram (zero-completion run) reports 0.0 — the np.percentile
    empty-list crash cannot happen by construction. `occupancy` likewise
    guards ticks == 0."""
    ticks = _counter_val(delta, "serve_ticks")
    n_done = _counter_val(delta, "serve_completed")
    makespan = float(_counter_val(delta, "serve_makespan_ticks", 0.0))
    wall_s = float(_counter_val(delta, "serve_wall_s", 0.0))
    lat_row = delta.get("latency_ticks") or {}
    lat_p50 = obsm.snapshot_percentile(lat_row, 50)
    lat_p95 = obsm.snapshot_percentile(lat_row, 95)
    tw_row = delta.get("tick_wall_s") or {}
    tick_s = (obsm.snapshot_percentile(tw_row, 50) if tw_row.get("count")
              else (wall_s / ticks if ticks else 0.0))
    phases = {}
    rejected = expired = faults = 0
    for full, row in delta.items():
        name, labels = obsm.parse_fullname(full)
        if name == "host_phase_ns" and "phase" in labels:
            phases[labels["phase"]] = row["value"]
        elif name == "serve_rejected":
            rejected += int(row["value"])
            if labels.get("reason") == "expired":
                expired += int(row["value"])
        elif name == "fault_injected":
            faults += int(row["value"])
    host_ns = phases.get("admission", 0) + phases.get("bookkeeping", 0)
    tiers = sorted({obsm.parse_fullname(full)[1].get("tier")
                    for full in delta
                    if obsm.parse_fullname(full)[0] == "tier_completed"})
    per_tier = None
    if tiers:
        per_tier = {}
        for t in tiers:
            lbl = f'{{tier="{t}"}}'
            per_tier[t] = {
                "completed": _counter_val(delta, f"tier_completed{lbl}"),
                "evals": int(_counter_val(delta, f"tier_evals{lbl}", 0)),
                # full-eval units: < evals when the tier's plan schedules
                # shallow feature-reuse steps (DESIGN.md §12)
                "eval_cost": float(_counter_val(delta,
                                                f"tier_eval_cost{lbl}", 0.0)),
                "latency_ticks_p50": obsm.snapshot_percentile(
                    delta.get(f"tier_latency_ticks{lbl}") or {}, 50),
            }
    return ServeMetrics(
        mode=mode,
        requests=_counter_val(delta, "serve_submitted"),
        completed=n_done, slots=slots, n_rows=n_rows,
        ticks=ticks, evals=_counter_val(delta, "serve_evals"),
        makespan_ticks=makespan,
        throughput_per_tick=n_done / max(makespan, 1.0),
        latency_ticks_p50=lat_p50,
        latency_ticks_p95=lat_p95,
        occupancy=(_counter_val(delta, "serve_active_slot_ticks")
                   / (ticks * slots) if ticks else 0.0),
        evals_per_latent=ticks * slots / max(n_done, 1),
        tick_s=tick_s,
        throughput_rps=n_done / max(wall_s, 1e-12),
        latency_s_p50=lat_p50 * tick_s,
        latency_s_p95=lat_p95 * tick_s,
        per_tier=per_tier,
        pipeline_depth=pipeline_depth,
        wall_s=wall_s,
        host_us_per_tick=host_ns / ticks / 1e3 if ticks else 0.0,
        host_phase_us_per_tick={p: (phases.get(p, 0) / ticks / 1e3
                                    if ticks else 0.0)
                                for p in ("admission", "dispatch",
                                          "readback", "bookkeeping")},
        rejected=rejected, expired=expired,
        degraded=int(_counter_val(delta, "serve_shed_degraded")),
        retries=int(_counter_val(delta, "serve_retries")),
        failed=int(_counter_val(delta, "serve_failed")),
        recoveries=int(_counter_val(delta, "serve_desync_recoveries")),
        faults_injected=faults,
    )


def run_trace(sched: SlotScheduler, requests: Sequence[Request],
              mode: Optional[str] = None,
              snapshot_every: Optional[int] = None,
              snapshot_log: Optional[list] = None) -> ServeMetrics:
    """Drive a scheduler through an arrival trace to completion.

    The clock advances one tick per step call; when nothing is queued or
    in-flight the clock fast-forwards to the next arrival without burning an
    eval (so `evals == ticks` holds by construction).

    At pipeline depth 1 every tick is individually fenced (dispatch + block),
    so `tick_s` is a clean per-tick median. At depth >= 2 the loop never
    blocks mid-trace — completions surface from the trailing readback stream
    as their flights land, and the final `flush()` consumes the stragglers —
    so only the whole-trace `wall_s` is meaningful and `tick_s` is reported
    as its per-tick mean. Completion clocks are stamped at dispatch time, so
    tick-denominated latency metrics are identical at every depth.

    Metrics are derived from the scheduler's registry: the run brackets a
    registry snapshot (so a reused scheduler reports THIS run's numbers) and
    `serve_metrics_from_snapshot` turns the delta into the ServeMetrics
    aggregate — one code path for live and final numbers (DESIGN.md §15).
    `snapshot_every`, with a `snapshot_log` list, additionally appends a
    compact (sample-free) registry snapshot row every N executed ticks —
    the periodic streaming view the metrics artifact records.

    Submissions need not all complete (DESIGN.md §16): a bounded-queue
    scheduler sheds under overload, TTLs expire queued requests, and the
    resilience layer can requeue in-flight work (validation retry, desync
    recovery). The driver keeps serving until queue, slots, AND the
    readback pipeline are empty, and the derived metrics partition every
    submission: `requests == completed + rejected`.
    """
    pending = sorted(requests, key=lambda r: r.arrival)
    sync = sched.pipeline_depth == 1
    reg = sched.registry
    snap0 = reg.snapshot()
    ticks0 = sched.ticks
    # wall-clock metrics ride the registry too, flagged wall=True so the
    # deterministic snapshot slice (the cross-depth equality) excludes them
    h_tick_wall = reg.histogram("tick_wall_s", TICK_WALL_BUCKETS, wall=True,
                                help="fenced per-tick wall seconds (pipeline "
                                     "depth 1 runs only)")
    g_wall = reg.gauge("serve_wall_s", wall=True,
                       help="whole-trace wall seconds of the last run")
    # counters, not gauges: the snapshot delta of a reused scheduler must
    # isolate this run's value, and gauges don't subtract
    c_makespan = reg.counter("serve_makespan_ticks",
                             help="clock when the run's last request "
                                  "finished (per-run delta)")
    i = 0
    now = 0.0
    wall0 = time.perf_counter()
    try:
        while True:
            while i < len(pending) and pending[i].arrival <= now:
                sched.submit(pending[i])
                i += 1
            if not sched.queue and not sched.active:
                if sched.in_flight:
                    # drain the trailing readbacks before declaring idle: a
                    # consumed flight can REQUEUE work (validation retry,
                    # desync recovery), in which case serving resumes
                    sched.flush()
                    continue
                if i < len(pending):
                    now = pending[i].arrival  # idle: jump to the next arrival
                    continue
                break
            sched.clock = now + 1.0  # this tick's completions land at now+1
            t0 = time.perf_counter()
            sched.tick()
            if sync:
                # block per tick: JAX dispatch is async, and ticks without a
                # completion fetch would otherwise clock only dispatch cost
                jax.block_until_ready(sched.state)
                h_tick_wall.observe(time.perf_counter() - t0)
            now += 1.0
            if (snapshot_every and snapshot_log is not None
                    and (sched.ticks - ticks0) % snapshot_every == 0):
                snapshot_log.append({
                    "tick": sched.ticks - ticks0, "clock": now,
                    "metrics": obsm.delta(
                        snap0, reg.snapshot(include_samples=False))})
        jax.block_until_ready(sched.state)
    finally:
        sched.clock = None  # later direct tick()s fall back to the tick clock
    wall_s = time.perf_counter() - wall0
    g_wall.set(wall_s)
    c_makespan.inc(now)
    prog = sched.program
    budget = (max(n for _, n in prog.tiers.values()) if prog.tiers
              else prog.n_rows)
    return serve_metrics_from_snapshot(
        obsm.delta(snap0, reg.snapshot()),
        mode=mode or ("gang" if sched.gang else "continuous"),
        slots=sched.slots, n_rows=budget,
        pipeline_depth=sched.pipeline_depth)


# ---------------------------------------------------------------------------
# CI smokes: short Poisson traces on CPU against the reduced dit backbone
# ---------------------------------------------------------------------------


def _require(cond: bool, msg: str) -> None:
    """Always-on invariant check for the CI smokes: unlike `assert`, it
    survives `python -O` — an invariant violation must fail loudly no
    matter how the interpreter was invoked."""
    if not cond:
        raise RuntimeError(f"serving invariant violated: {msg}")


def _build_smoke_sched(arch: str, slots: int, nfe: int, cfg_scale: float,
                       seed: int, pipeline_depth: int, **sched_kw):
    """One reduced-backbone scheduler for the smoke/chaos drivers."""
    import jax

    from ..configs.registry import get_config
    from ..diffusion import VPLinear
    from ..engine import EngineSpec
    from ..launch.sample import build_engine
    from ..models import api

    cfg = get_config(arch).reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(seed))
    engine = build_engine(cfg, params, VPLinear(), slots, seed,
                          want_cfg=cfg_scale != 0.0)
    spec = EngineSpec(solver="unipc", nfe=nfe, cfg_scale=cfg_scale)
    program = engine.build_step(spec)
    sched = SlotScheduler(program, slots,
                          (cfg.patch_tokens, cfg.latent_dim),
                          pipeline_depth=pipeline_depth, **sched_kw)
    return sched, program


def smoke(arch: str = "dit-cifar", slots: int = 2, nfe: int = 4,
          n_requests: int = 5, rate: float = 0.5, cfg_scale: float = 2.0,
          seed: int = 0, pipeline_depth: int = 1) -> ServeMetrics:
    """Serve a short Poisson trace end to end and check the scheduler
    invariants: every request completes with a validated-finite latent
    (the on-device done-mask check, surfaced as `Completion.ok`), one
    batched eval per tick, per-request eval bookkeeping adds up, the
    completion clock is monotonic (dispatch-stamped even when readbacks
    trail the pipeline), and completions + rejections partition the
    submissions."""
    sched, program = _build_smoke_sched(arch, slots, nfe, cfg_scale, seed,
                                        pipeline_depth)
    reqs = poisson_requests(n_requests, rate, seed=seed,
                            cfg_scales=[1.5, cfg_scale, 4.0])
    m = run_trace(sched, reqs)
    _require(m.completed == n_requests,
             f"{m.completed} of {n_requests} requests completed")
    _require(m.evals == m.ticks, f"{m.evals} evals != {m.ticks} ticks")
    _require(sched.in_flight == 0,
             f"{sched.in_flight} readbacks left in flight")
    _require(all(c.evals == program.n_rows for c in sched.completions),
             "per-request eval bookkeeping does not add up")
    # the always-on output validation path: ok mirrors the on-device
    # finite check folded into the step program's done mask
    _require(all(c.ok for c in sched.completions),
             "a completion failed the on-device finite check")
    _require(m.requests == m.completed + m.rejected,
             f"submissions not partitioned: {m.requests} != "
             f"{m.completed} + {m.rejected}")
    clocks = [c.finish_clock for c in sched.completions]
    _require(clocks == sorted(clocks),
             f"completion clock not monotonic: {clocks}")
    _require(all(c.finish_clock > c.arrival for c in sched.completions),
             "a completion finished before it arrived")
    return m


def chaos(arch: str = "dit-cifar", slots: int = 2, nfe: int = 4,
          n_requests: int = 8, rate: float = 1.0, cfg_scale: float = 2.0,
          seed: int = 0, depths: Sequence[int] = (1, 2, 3)) -> None:
    """The chaos smoke (DESIGN.md §16): serve the same seeded Poisson trace
    clean and fault-injected, at pipeline depths 1/2/3, and check the
    resilience acceptance properties end to end:

    * NaN fault + forced desync (scenario A): the scheduler never raises,
      every request still completes ok, and every latent — including the
      retried and requeued ones, whose seeds are preserved — is
      bit-identical to the clean run's.
    * Queue-bound shed under ~2x overload (scenario B): submissions are
      partitioned into completions + typed rejections, FIFO order is
      preserved among the accepted, the shed set is identical across
      depths, and every accepted latent is bit-identical to the clean run.
    * Determinism: a repeated run of the same seeded FaultPlan produces an
      identical event ledger and identical completion bookkeeping.
    """
    def requests():
        return poisson_requests(n_requests, rate, seed=seed,
                                cfg_scales=[1.5, cfg_scale, 4.0])

    def run(depth, resilience=None, faults=None):
        sched, _ = _build_smoke_sched(arch, slots, nfe, cfg_scale, seed,
                                      depth, resilience=resilience,
                                      faults=faults)
        m = run_trace(sched, requests())
        return sched, m

    # the clean reference: fault-free, resilience at inert defaults
    sched0, m0 = run(1)
    _require(m0.completed == n_requests and all(c.ok for c in
                                                sched0.completions),
             "clean reference run did not complete cleanly")
    clean = {c.rid: np.asarray(c.latent) for c in sched0.completions}

    # scenario A: poisoned eval + corrupted device counter, every depth
    plan = FaultPlan(nans=(NanFault(rid=2, step=1),),
                     metas=(MetaFault(tick=2 * nfe),))
    armed = ResilienceConfig(max_retries=2)
    ledgers = {}
    for depth in depths:
        sched, m = run(depth, resilience=armed, faults=plan)
        _require(m.completed == n_requests,
                 f"[chaos A depth {depth}] {m.completed}/{n_requests} "
                 f"completed under faults")
        _require(all(c.ok for c in sched.completions),
                 f"[chaos A depth {depth}] a failed completion leaked")
        _require(m.faults_injected >= 2 and m.recoveries >= 1,
                 f"[chaos A depth {depth}] faults did not fire "
                 f"(injected={m.faults_injected}, "
                 f"recoveries={m.recoveries})")
        _require(m.requests == m.completed + m.rejected,
                 f"[chaos A depth {depth}] partition broken")
        for c in sched.completions:
            np.testing.assert_array_equal(
                np.asarray(c.latent), clean[c.rid],
                err_msg=f"[chaos A depth {depth}] rid {c.rid} latent "
                        f"differs from the clean run")
        ledgers[depth] = list(sched.events)
    # determinism: same plan, same trace -> identical ledger + bookkeeping
    sched_r, _ = run(depths[0], resilience=armed, faults=plan)
    _require(sched_r.events == ledgers[depths[0]],
             "[chaos A] seeded fault ledger not deterministic across runs")

    # scenario B: bounded queue under ~2x overload, every depth
    bound = ResilienceConfig(max_queue=2)

    def over_requests():
        return poisson_requests(2 * n_requests, 2 * rate, seed=seed + 1,
                                cfg_scales=[1.5, cfg_scale, 4.0])

    sched_c, _ = _build_smoke_sched(arch, slots, nfe, cfg_scale, seed, 1)
    run_trace(sched_c, over_requests())
    clean_b = {c.rid: np.asarray(c.latent) for c in sched_c.completions}
    shed_sets = []
    for depth in depths:
        sched, _ = _build_smoke_sched(arch, slots, nfe, cfg_scale, seed,
                                      depth, resilience=bound)
        m = run_trace(sched, over_requests())
        _require(m.rejected > 0,
                 f"[chaos B depth {depth}] 2x overload shed nothing")
        _require(m.requests == m.completed + m.rejected,
                 f"[chaos B depth {depth}] partition broken: "
                 f"{m.requests} != {m.completed} + {m.rejected}")
        admits = [c.admit_tick for c in sched.completions]
        _require(admits == sorted(admits),
                 f"[chaos B depth {depth}] FIFO admission order broken")
        for c in sched.completions:
            np.testing.assert_array_equal(
                np.asarray(c.latent), clean_b[c.rid],
                err_msg=f"[chaos B depth {depth}] rid {c.rid} latent "
                        f"differs from the unbounded run")
        shed_sets.append(frozenset(r.rid for r in sched.rejections))
    _require(len(set(shed_sets)) == 1,
             f"[chaos B] shed set differs across depths: {shed_sets}")
    print(f"chaos ok: {len(depths)} depths, "
          f"A: {n_requests} requests bit-identical under NaN+desync, "
          f"B: {len(shed_sets[0])} shed of {2 * n_requests} under "
          f"2x overload, ledgers deterministic")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="run the CI scheduler smoke and exit nonzero on "
                         "any invariant violation")
    ap.add_argument("--chaos", action="store_true",
                    help="run the CI chaos smoke (DESIGN.md §16): the same "
                         "trace clean and fault-injected at pipeline depths "
                         "1/2/3, checking recovery, shed determinism, and "
                         "bit-identical untouched latents")
    ap.add_argument("--arch", default="dit-cifar")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--nfe", type=int, default=4)
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--arrival-rate", type=float, default=0.5,
                    help="requests per tick (one tick = one batched eval)")
    ap.add_argument("--cfg-scale", type=float, default=2.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="ticks kept in flight; 1 = synchronous loop, "
                         ">= 2 overlaps host bookkeeping with device "
                         "execution (DESIGN.md §13)")
    args = ap.parse_args()
    if not (args.smoke or args.chaos):
        ap.error("this entry point runs the CI scheduler smokes; pass "
                 "--smoke or --chaos (real serving lives in "
                 "repro.launch.serve)")
    if args.chaos:
        chaos(args.arch, slots=args.slots, nfe=args.nfe,
              n_requests=args.requests, rate=args.arrival_rate,
              cfg_scale=args.cfg_scale, seed=args.seed)
        return
    m = smoke(args.arch, slots=args.slots, nfe=args.nfe,
              n_requests=args.requests, rate=args.arrival_rate,
              cfg_scale=args.cfg_scale, seed=args.seed,
              pipeline_depth=args.pipeline_depth)
    print(json.dumps(m.row(), indent=1))
    print(f"smoke ok: {m.completed}/{m.requests} requests, "
          f"{m.evals} evals == {m.ticks} ticks, "
          f"depth {m.pipeline_depth}")


if __name__ == "__main__":
    main()
