"""Request generators, the trace driver, and serving metrics.

Traffic is simulated in *tick time*: one tick = one batched model eval (the
scheduler's unit of work), so a trace is deterministic and hardware-free —
the same arrival stream replays identically on CPU and on the mesh. Wall-clock
figures come from measuring the ticks that actually ran: `run_trace` times
every step call and reports both tick-denominated metrics (latency in evals,
evals-per-latent) and wall-denominated ones (throughput in requests/s, p50/p95
latency seconds).

    PYTHONPATH=src python -m repro.serving.server --smoke

runs the CI smoke: a short Poisson trace against the reduced dit-cifar
backbone, asserting every request completes and that the scheduler performed
exactly one batched eval per tick.
"""

from __future__ import annotations

import argparse
import json
import time
from dataclasses import asdict, dataclass
from typing import List, Optional, Sequence

import jax
import numpy as np

from ..obs import metrics as obsm
from .scheduler import Completion, Request, SlotScheduler

TICK_WALL_BUCKETS = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1, 3e-1, 1.0, 3.0)


def poisson_requests(n: int, rate: float, seed: int = 0,
                     cfg_scales: Optional[Sequence[float]] = None,
                     base_seed: int = 0,
                     tiers: Optional[Sequence[str]] = None) -> List[Request]:
    """n requests with Exp(1/rate) inter-arrival gaps (arrival in tick units).

    `rate` is requests per tick. `cfg_scales`, if given, is cycled through the
    requests — the per-request guidance knob (UniPC Table 9 settings vary it).
    `tiers`, if given, is likewise cycled — the quality-tier tag plan-bank
    programs route on (`Request.tier`).
    """
    if rate <= 0:
        raise ValueError(f"arrival rate must be > 0 requests per tick, "
                         f"got {rate}")
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    return [Request(rid=i, seed=base_seed + i, arrival=float(arrivals[i]),
                    cfg_scale=(None if cfg_scales is None
                               else float(cfg_scales[i % len(cfg_scales)])),
                    tier=(None if tiers is None
                          else str(tiers[i % len(tiers)])))
            for i in range(n)]


def save_trace(path: str, requests: Sequence[Request]) -> None:
    rows = [{"rid": r.rid, "seed": r.seed, "arrival": r.arrival,
             "cfg_scale": r.cfg_scale, "extras": r.extras, "tier": r.tier}
            for r in requests]
    with open(path, "w") as f:
        json.dump(rows, f, indent=1)


def load_trace(path: str) -> List[Request]:
    """JSON trace: a list of {rid, seed, arrival, cfg_scale, extras, tier}
    objects; `extras` (optional) carries per-request model conditioning,
    e.g. {"class_ids": 7}; `tier` (optional) tags the request's quality tier
    for plan-bank serving."""
    with open(path) as f:
        rows = json.load(f)
    return [Request(rid=int(r["rid"]), seed=int(r.get("seed", 0)),
                    arrival=float(r.get("arrival", 0.0)),
                    cfg_scale=(None if r.get("cfg_scale") is None
                               else float(r["cfg_scale"])),
                    extras=r.get("extras"),
                    tier=(None if r.get("tier") is None
                          else str(r["tier"])))
            for r in rows]


@dataclass
class ServeMetrics:
    """What one trace run measured. Tick-denominated fields are deterministic
    (the simulation), *_s fields are measured wall-clock."""

    mode: str                 # continuous | gang
    requests: int
    completed: int
    slots: int
    n_rows: int               # evals per request (the per-request NFE
                              # budget); for plan-bank programs, the MAX
                              # across tiers — per_tier carries each tier's
                              # exact budget
    ticks: int                # batched step calls
    evals: int                # always == ticks
    makespan_ticks: float     # clock when the last request finished
    throughput_per_tick: float
    latency_ticks_p50: float
    latency_ticks_p95: float
    occupancy: float          # busy-slot fraction over ticks that ran
    evals_per_latent: float   # slot-evals spent per finished latent
    tick_s: float             # wall seconds per tick: the per-tick median at
                              # pipeline depth 1, wall_s / ticks otherwise
                              # (per-tick walls are meaningless mid-pipeline)
    throughput_rps: float     # completed / wall_s
    latency_s_p50: float
    latency_s_p95: float
    # plan-bank runs: {tier: {completed, evals, latency_ticks_p50}} — how
    # each quality tier fared inside the shared batch. None for single-plan.
    per_tier: Optional[dict] = None
    pipeline_depth: int = 1   # ticks kept in flight (DESIGN.md §13)
    wall_s: float = 0.0       # measured wall seconds for the whole trace
    host_us_per_tick: float = 0.0  # host bookkeeping µs per tick, excluding
                                   # time blocked on device readbacks
    # the host_us_per_tick split by tick phase (DESIGN.md §15):
    # {admission, dispatch, readback, bookkeeping} µs per executed tick —
    # admission + bookkeeping == host_us_per_tick; dispatch and readback are
    # device-facing time, reported for the "where a tick goes" breakdown
    host_phase_us_per_tick: Optional[dict] = None

    def row(self) -> dict:
        return asdict(self)


def _counter_val(delta: dict, name: str, default=0):
    row = delta.get(name)
    return row["value"] if row else default


def serve_metrics_from_snapshot(delta: dict, *, mode: str, slots: int,
                                n_rows: int,
                                pipeline_depth: int = 1) -> ServeMetrics:
    """Re-derive `ServeMetrics` from a metrics-registry snapshot delta.

    `delta` is `obs.metrics.delta(before, after)` over the scheduler's
    registry around one run (`MetricsRegistry.snapshot` with samples). This
    is THE code path `run_trace` reports through — the live registry and the
    end-of-run aggregate cannot drift — and it is a pure function of
    JSON-able data, so `launch/obsreport.py --check` re-runs it on a saved
    metrics artifact and compares against the artifact's embedded metrics.

    Percentiles come from the histograms' exact retained samples; an empty
    histogram (zero-completion run) reports 0.0 — the np.percentile
    empty-list crash cannot happen by construction. `occupancy` likewise
    guards ticks == 0."""
    ticks = _counter_val(delta, "serve_ticks")
    n_done = _counter_val(delta, "serve_completed")
    makespan = float(_counter_val(delta, "serve_makespan_ticks", 0.0))
    wall_s = float(_counter_val(delta, "serve_wall_s", 0.0))
    lat_row = delta.get("latency_ticks") or {}
    lat_p50 = obsm.snapshot_percentile(lat_row, 50)
    lat_p95 = obsm.snapshot_percentile(lat_row, 95)
    tw_row = delta.get("tick_wall_s") or {}
    tick_s = (obsm.snapshot_percentile(tw_row, 50) if tw_row.get("count")
              else (wall_s / ticks if ticks else 0.0))
    phases = {}
    for full, row in delta.items():
        name, labels = obsm.parse_fullname(full)
        if name == "host_phase_ns" and "phase" in labels:
            phases[labels["phase"]] = row["value"]
    host_ns = phases.get("admission", 0) + phases.get("bookkeeping", 0)
    tiers = sorted({obsm.parse_fullname(full)[1].get("tier")
                    for full in delta
                    if obsm.parse_fullname(full)[0] == "tier_completed"})
    per_tier = None
    if tiers:
        per_tier = {}
        for t in tiers:
            lbl = f'{{tier="{t}"}}'
            per_tier[t] = {
                "completed": _counter_val(delta, f"tier_completed{lbl}"),
                "evals": int(_counter_val(delta, f"tier_evals{lbl}", 0)),
                # full-eval units: < evals when the tier's plan schedules
                # shallow feature-reuse steps (DESIGN.md §12)
                "eval_cost": float(_counter_val(delta,
                                                f"tier_eval_cost{lbl}", 0.0)),
                "latency_ticks_p50": obsm.snapshot_percentile(
                    delta.get(f"tier_latency_ticks{lbl}") or {}, 50),
            }
    return ServeMetrics(
        mode=mode,
        requests=_counter_val(delta, "serve_submitted"),
        completed=n_done, slots=slots, n_rows=n_rows,
        ticks=ticks, evals=_counter_val(delta, "serve_evals"),
        makespan_ticks=makespan,
        throughput_per_tick=n_done / max(makespan, 1.0),
        latency_ticks_p50=lat_p50,
        latency_ticks_p95=lat_p95,
        occupancy=(_counter_val(delta, "serve_active_slot_ticks")
                   / (ticks * slots) if ticks else 0.0),
        evals_per_latent=ticks * slots / max(n_done, 1),
        tick_s=tick_s,
        throughput_rps=n_done / max(wall_s, 1e-12),
        latency_s_p50=lat_p50 * tick_s,
        latency_s_p95=lat_p95 * tick_s,
        per_tier=per_tier,
        pipeline_depth=pipeline_depth,
        wall_s=wall_s,
        host_us_per_tick=host_ns / ticks / 1e3 if ticks else 0.0,
        host_phase_us_per_tick={p: (phases.get(p, 0) / ticks / 1e3
                                    if ticks else 0.0)
                                for p in ("admission", "dispatch",
                                          "readback", "bookkeeping")},
    )


def run_trace(sched: SlotScheduler, requests: Sequence[Request],
              mode: Optional[str] = None,
              snapshot_every: Optional[int] = None,
              snapshot_log: Optional[list] = None) -> ServeMetrics:
    """Drive a scheduler through an arrival trace to completion.

    The clock advances one tick per step call; when nothing is queued or
    in-flight the clock fast-forwards to the next arrival without burning an
    eval (so `evals == ticks` holds by construction).

    At pipeline depth 1 every tick is individually fenced (dispatch + block),
    so `tick_s` is a clean per-tick median. At depth >= 2 the loop never
    blocks mid-trace — completions surface from the trailing readback stream
    as their flights land, and the final `flush()` consumes the stragglers —
    so only the whole-trace `wall_s` is meaningful and `tick_s` is reported
    as its per-tick mean. Completion clocks are stamped at dispatch time, so
    tick-denominated latency metrics are identical at every depth.

    Metrics are derived from the scheduler's registry: the run brackets a
    registry snapshot (so a reused scheduler reports THIS run's numbers) and
    `serve_metrics_from_snapshot` turns the delta into the ServeMetrics
    aggregate — one code path for live and final numbers (DESIGN.md §15).
    `snapshot_every`, with a `snapshot_log` list, additionally appends a
    compact (sample-free) registry snapshot row every N executed ticks —
    the periodic streaming view the metrics artifact records.
    """
    pending = sorted(requests, key=lambda r: r.arrival)
    sync = sched.pipeline_depth == 1
    reg = sched.registry
    snap0 = reg.snapshot()
    ticks0 = sched.ticks
    # wall-clock metrics ride the registry too, flagged wall=True so the
    # deterministic snapshot slice (the cross-depth equality) excludes them
    h_tick_wall = reg.histogram("tick_wall_s", TICK_WALL_BUCKETS, wall=True,
                                help="fenced per-tick wall seconds (pipeline "
                                     "depth 1 runs only)")
    g_wall = reg.gauge("serve_wall_s", wall=True,
                       help="whole-trace wall seconds of the last run")
    # counters, not gauges: the snapshot delta of a reused scheduler must
    # isolate this run's value, and gauges don't subtract
    c_makespan = reg.counter("serve_makespan_ticks",
                             help="clock when the run's last request "
                                  "finished (per-run delta)")
    i = 0
    now = 0.0
    wall0 = time.perf_counter()
    try:
        while i < len(pending) or sched.queue or sched.active:
            while i < len(pending) and pending[i].arrival <= now:
                sched.submit(pending[i])
                i += 1
            if not sched.queue and not sched.active:
                now = pending[i].arrival  # idle: jump to the next arrival
                continue
            sched.clock = now + 1.0  # this tick's completions land at now+1
            t0 = time.perf_counter()
            sched.tick()
            if sync:
                # block per tick: JAX dispatch is async, and ticks without a
                # completion fetch would otherwise clock only dispatch cost
                jax.block_until_ready(sched.state)
                h_tick_wall.observe(time.perf_counter() - t0)
            now += 1.0
            if (snapshot_every and snapshot_log is not None
                    and (sched.ticks - ticks0) % snapshot_every == 0):
                snapshot_log.append({
                    "tick": sched.ticks - ticks0, "clock": now,
                    "metrics": obsm.delta(
                        snap0, reg.snapshot(include_samples=False))})
        sched.flush()  # consume the trailing readbacks still in flight
        jax.block_until_ready(sched.state)
    finally:
        sched.clock = None  # later direct tick()s fall back to the tick clock
    wall_s = time.perf_counter() - wall0
    g_wall.set(wall_s)
    c_makespan.inc(now)
    prog = sched.program
    budget = (max(n for _, n in prog.tiers.values()) if prog.tiers
              else prog.n_rows)
    return serve_metrics_from_snapshot(
        obsm.delta(snap0, reg.snapshot()),
        mode=mode or ("gang" if sched.gang else "continuous"),
        slots=sched.slots, n_rows=budget,
        pipeline_depth=sched.pipeline_depth)


# ---------------------------------------------------------------------------
# CI smoke: short Poisson trace on CPU against the reduced dit backbone
# ---------------------------------------------------------------------------


def smoke(arch: str = "dit-cifar", slots: int = 2, nfe: int = 4,
          n_requests: int = 5, rate: float = 0.5, cfg_scale: float = 2.0,
          seed: int = 0, pipeline_depth: int = 1) -> ServeMetrics:
    """Serve a short Poisson trace end to end and assert the scheduler
    invariants: every request completes, one batched eval per tick,
    per-request eval bookkeeping adds up, and the completion clock is
    monotonic (dispatch-stamped even when readbacks trail the pipeline)."""
    import jax

    from ..configs.registry import get_config
    from ..diffusion import VPLinear
    from ..engine import EngineSpec
    from ..launch.sample import build_engine
    from ..models import api

    cfg = get_config(arch).reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(seed))
    engine = build_engine(cfg, params, VPLinear(), slots, seed,
                          want_cfg=cfg_scale != 0.0)
    spec = EngineSpec(solver="unipc", nfe=nfe, cfg_scale=cfg_scale)
    program = engine.build_step(spec)
    sched = SlotScheduler(program, slots,
                          (cfg.patch_tokens, cfg.latent_dim),
                          pipeline_depth=pipeline_depth)
    reqs = poisson_requests(n_requests, rate, seed=seed,
                            cfg_scales=[1.5, cfg_scale, 4.0])
    m = run_trace(sched, reqs)
    assert m.completed == n_requests, (m.completed, n_requests)
    assert m.evals == m.ticks, (m.evals, m.ticks)
    assert sched.in_flight == 0, sched.in_flight
    assert all(c.evals == program.n_rows for c in sched.completions)
    assert all(np.isfinite(c.latent).all() for c in sched.completions)
    clocks = [c.finish_clock for c in sched.completions]
    assert clocks == sorted(clocks), clocks
    assert all(c.finish_clock > c.arrival for c in sched.completions)
    return m


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="run the CI scheduler smoke and exit nonzero on "
                         "any invariant violation")
    ap.add_argument("--arch", default="dit-cifar")
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--nfe", type=int, default=4)
    ap.add_argument("--requests", type=int, default=5)
    ap.add_argument("--arrival-rate", type=float, default=0.5,
                    help="requests per tick (one tick = one batched eval)")
    ap.add_argument("--cfg-scale", type=float, default=2.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--pipeline-depth", type=int, default=1,
                    help="ticks kept in flight; 1 = synchronous loop, "
                         ">= 2 overlaps host bookkeeping with device "
                         "execution (DESIGN.md §13)")
    args = ap.parse_args()
    if not args.smoke:
        ap.error("this entry point runs the CI scheduler smoke; pass "
                 "--smoke (real serving lives in repro.launch.serve)")
    m = smoke(args.arch, slots=args.slots, nfe=args.nfe,
              n_requests=args.requests, rate=args.arrival_rate,
              cfg_scale=args.cfg_scale, seed=args.seed,
              pipeline_depth=args.pipeline_depth)
    print(json.dumps(m.row(), indent=1))
    print(f"smoke ok: {m.completed}/{m.requests} requests, "
          f"{m.evals} evals == {m.ticks} ticks, "
          f"depth {m.pipeline_depth}")


if __name__ == "__main__":
    main()
