"""Serving resilience policy: overload control, validation retry, recovery.

This module is pure policy — small frozen dataclasses the `SlotScheduler`
consults on its hot path (DESIGN.md §16). The mechanisms live in the
scheduler itself; everything here is declarative so a config can be built
once, validated against the compiled `StepProgram`, logged, and reproduced.

Failure taxonomy the config covers:

* **Bad output** — a finished latent containing NaN/Inf, flagged on device
  by the coded `step_flight` done mask (`engine.compiler.DONE_NONFINITE`).
  Policy: re-admit the request (same seed, same x_T) up to `max_retries`
  times, walking the `fallback` chain toward safer tiers; exhaustion emits
  a failed `Completion` (ok=False) instead of shipping NaNs.
* **Overload** — more arrivals than the fleet drains. Policy: bound the
  admission queue at `max_queue` and shed past it, either rejecting new
  submissions outright (a typed `Rejection` back to the traffic source) or
  first remapping them to a cheaper tier once the queue passes
  `degrade_watermark`. Queued requests can additionally carry a TTL
  (per-request or `default_ttl`): a request whose deadline passed before a
  slot freed up is expired at admission time rather than served late.
* **Desync** — the host's predicted completion schedule disagreeing with
  the authoritative on-device `meta` counters (a lying step override, a
  corrupted counter, a driver bug). Policy `recovery='recover'`: drain the
  pipeline, re-derive the host mirrors from device state, requeue affected
  requests, keep serving; `recovery='raise'` keeps the pre-resilience hard
  RuntimeError as the escape hatch for tests and debugging.

The defaults are deliberately inert: an unbounded queue, no TTL, no
retries, recovery enabled. A scheduler built with `ResilienceConfig()`
is bit-identical to one built before this layer existed as long as no
fault fires.
"""

from __future__ import annotations

from dataclasses import dataclass, replace as dc_replace
from typing import Optional, Tuple

REJECT_QUEUE_FULL = "queue_full"
REJECT_EXPIRED = "expired"
FAIL_NONFINITE = "nonfinite"


@dataclass(frozen=True)
class Rejection:
    """A request the scheduler refused to serve, returned to the traffic
    source by `SlotScheduler.submit` (queue_full) or recorded at admission
    (expired). Together with `Completion`s, rejections partition every
    submitted request: submitted == completed + rejected, the invariant
    `server.run_trace` metrics are derived under."""

    rid: int
    reason: str              # REJECT_QUEUE_FULL | REJECT_EXPIRED
    arrival: float
    clock: float             # when the decision was made (tick-clock units)
    tier: Optional[str] = None


@dataclass(frozen=True)
class ResilienceConfig:
    """Scheduler resilience policy. All defaults are inert (pre-resilience
    behavior); see the module docstring for the taxonomy each knob covers."""

    # -- overload control --
    max_queue: Optional[int] = None      # bound on queued requests; None =
                                         # unbounded (the legacy deque)
    shed_policy: str = "reject"          # 'reject' | 'degrade' past the bound
    degrade_watermark: Optional[int] = None  # queue depth at which 'degrade'
                                             # starts remapping tiers; None =
                                             # max_queue (only when full)
    degrade_tier: Optional[str] = None   # tier shed requests are remapped to
    default_ttl: Optional[float] = None  # admission deadline (tick-clock
                                         # units past arrival) for requests
                                         # without their own Request.ttl
    # -- output validation / retry --
    max_retries: int = 0                 # re-admissions after a non-finite
                                         # latent before emitting ok=False
    fallback: Tuple[str, ...] = ()       # safer-tier chain walked on retry;
                                         # () = retry on the same tier
    # -- desync recovery --
    recovery: str = "recover"            # 'recover' | 'raise'
    max_recoveries: int = 8              # recoveries before giving up: a
                                         # persistently lying step program
                                         # must not recover forever


DEFAULT_RESILIENCE = ResilienceConfig()


def validate_resilience(cfg: ResilienceConfig, program) -> ResilienceConfig:
    """Check a config against the compiled program it will police and
    return it normalized (degrade_watermark defaulted). Raises ValueError
    on contradictions — bad tier names, watermark past the queue bound —
    at construction time, never mid-serve."""
    if cfg.shed_policy not in ("reject", "degrade"):
        raise ValueError(f"shed_policy must be 'reject' or 'degrade', "
                         f"got {cfg.shed_policy!r}")
    if cfg.recovery not in ("recover", "raise"):
        raise ValueError(f"recovery must be 'recover' or 'raise', "
                         f"got {cfg.recovery!r}")
    if cfg.max_queue is not None and cfg.max_queue < 1:
        raise ValueError(f"max_queue must be >= 1, got {cfg.max_queue}")
    if cfg.max_retries < 0:
        raise ValueError(f"max_retries must be >= 0, got {cfg.max_retries}")
    if cfg.max_recoveries < 1:
        raise ValueError(f"max_recoveries must be >= 1, "
                         f"got {cfg.max_recoveries}")
    if cfg.default_ttl is not None and cfg.default_ttl <= 0:
        raise ValueError(f"default_ttl must be > 0, got {cfg.default_ttl}")
    # tier names must resolve against the program's bank — resolve_tier
    # raises the precise error (unknown tier / single-plan program)
    for t in cfg.fallback:
        program.resolve_tier(t)
    if cfg.shed_policy == "degrade":
        if cfg.degrade_tier is None:
            raise ValueError("shed_policy='degrade' needs degrade_tier")
        program.resolve_tier(cfg.degrade_tier)
    if cfg.degrade_watermark is None and cfg.shed_policy == "degrade":
        cfg = dc_replace(cfg, degrade_watermark=(
            cfg.max_queue if cfg.max_queue is not None else 0))
    if (cfg.degrade_watermark is not None and cfg.max_queue is not None
            and cfg.degrade_watermark > cfg.max_queue):
        raise ValueError(
            f"degrade_watermark ({cfg.degrade_watermark}) past max_queue "
            f"({cfg.max_queue}): shedding would reject before it degrades")
    return cfg


def fallback_tier(cfg: ResilienceConfig, tier: Optional[str]) -> Optional[str]:
    """The tier a failed request retries on: the next entry of the fallback
    chain after its current tier (entering at the head if the tier is not on
    the chain, parking at the tail once reached). An empty chain retries on
    the same tier — the right default for transient faults."""
    chain = cfg.fallback
    if not chain:
        return tier
    if tier not in chain:
        return chain[0]
    i = chain.index(tier)
    return chain[min(i + 1, len(chain) - 1)]
