"""Continuous-batching diffusion serving (DESIGN.md §9).

`scheduler.SlotScheduler` drives a compiled `StepProgram` over a fixed set of
batch slots: requests queue, admit on any free slot, step per-slot through the
solver table, and emit their latent the tick they finish — no request ever
waits for a whole batch to drain. `server` adds synthetic Poisson / trace
request generators and the serving metrics (throughput, p50/p95 latency, slot
occupancy, evals-per-latent).

`resilience` + `faults` make the loop survivable (DESIGN.md §16): bounded
admission with typed rejections and TTL expiry, on-device output validation
with degraded-tier retry, host/device desync recovery, and a deterministic
fault-injection harness that proves all of it under chaos.
"""

from .faults import (FaultInjector, FaultPlan, MetaFault, NanFault,
                     SkewFault, parse_fault_spec)
from .resilience import (DEFAULT_RESILIENCE, Rejection, ResilienceConfig,
                         fallback_tier, validate_resilience)
from .scheduler import Completion, Request, SlotScheduler
from .server import (ServeMetrics, load_trace, poisson_requests, run_trace,
                     save_trace)

__all__ = [
    "Request", "Completion", "SlotScheduler",
    "ServeMetrics", "poisson_requests", "load_trace", "save_trace",
    "run_trace",
    "ResilienceConfig", "DEFAULT_RESILIENCE", "Rejection",
    "fallback_tier", "validate_resilience",
    "FaultPlan", "FaultInjector", "NanFault", "MetaFault", "SkewFault",
    "parse_fault_spec",
]
