"""Continuous-batching diffusion serving (DESIGN.md §9).

`scheduler.SlotScheduler` drives a compiled `StepProgram` over a fixed set of
batch slots: requests queue, admit on any free slot, step per-slot through the
solver table, and emit their latent the tick they finish — no request ever
waits for a whole batch to drain. `server` adds synthetic Poisson / trace
request generators and the serving metrics (throughput, p50/p95 latency, slot
occupancy, evals-per-latent).
"""

from .scheduler import Completion, Request, SlotScheduler
from .server import (ServeMetrics, load_trace, poisson_requests, run_trace,
                     save_trace)

__all__ = [
    "Request", "Completion", "SlotScheduler",
    "ServeMetrics", "poisson_requests", "load_trace", "save_trace",
    "run_trace",
]
