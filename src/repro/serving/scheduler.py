"""Request-level slot scheduler for continuous-batching diffusion serving.

The engine compiles a `StepProgram` (per-slot step function over the solver
table, `SamplerEngine.build_step`); this module owns everything request-shaped
around it: a fixed set of B slots, a FIFO admission queue, per-request
seed / cfg-scale / NFE-budget bookkeeping, and finished-latent emission.

One `tick()` = one batched model eval: admit queued requests into free slots
(write the request's initial latent, zero the slot's eval ring, set its
guidance scale), dispatch the step program once for the whole batch, then
emit every slot that just executed its last row. Because admission resets the
ring and the zero-padded warm-up rows null empty ring slots, a request
admitted mid-flight reproduces the uniform `build()` scan for its own
(solver, order, nfe, seed, cfg-scale) exactly — the parity property
`tests/test_serving.py` pins across solvers.

The dispatched program is `StepProgram.step_flight` (DESIGN.md §13): the
per-slot row / budget / busy counters live on device, so the host never
ships a rebuilt `idx` vector — it only scatters admissions in and reads the
per-slot done mask back. Completion readback is a *trailing stream*: each
tick with predicted completions issues ONE batched gather of the finished
slots' latents plus an async host copy, and the concrete values are consumed
`pipeline_depth - 1` ticks later. `pipeline_depth=1` (the default) is the
synchronous loop — dispatch, then consume the same tick's readback before
returning — while depth >= 2 keeps that many ticks in flight, overlapping
host bookkeeping and admission with device execution (JAX async dispatch).
Both depths run the identical compiled program over the identical admission
schedule, so finished latents, completion order, and tick-clock metrics are
bit-identical across depths (`tests/test_async_serving.py`).

Idle slots park on row 0 (an identity update), so the batch shape — and the
compiled program — never changes. `gang=True` degrades admission to
sequential full-batch serving (admit only when *every* slot is free): the
baseline the benchmarks compare continuous batching against.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field, replace as dc_replace
from functools import partial
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..engine.compiler import DONE_NONFINITE
from ..engine.engine import StepProgram
from ..obs.metrics import MetricsRegistry
from .faults import FaultInjector, FaultPlan
from .resilience import (DEFAULT_RESILIENCE, FAIL_NONFINITE,
                         REJECT_EXPIRED, REJECT_QUEUE_FULL, Rejection,
                         ResilienceConfig, fallback_tier,
                         validate_resilience)

# fixed upper-bound buckets for the scheduler's streaming histograms
# (DESIGN.md §15): tick-denominated and depth-invariant, so the bucket
# counts are part of the deterministic metrics slice
QUEUE_DEPTH_BUCKETS = (0, 1, 2, 4, 8, 16, 32, 64, 128)
BUSY_SLOT_BUCKETS = (0, 1, 2, 4, 8, 16, 32, 64)
OCCUPANCY_BUCKETS = (0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0)
LATENCY_TICK_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512)
EVAL_COST_BUCKETS = (1, 2, 4, 8, 12, 16, 24, 32, 48, 64)
HOST_PHASES = ("admission", "dispatch", "readback", "bookkeeping")

# resilience / fault-injection event counters (DESIGN.md §16). Registered
# lazily — on the first event of each kind — so a fault-free run's metrics
# snapshot is exactly the pre-resilience snapshot.
EVENT_COUNTER_HELP = {
    "serve_rejected": "requests shed before admission (by reason)",
    "serve_shed_degraded": "requests remapped to the shed tier at submit",
    "serve_retries": "non-finite completions re-admitted on a fallback tier",
    "serve_failed": "failed completions emitted (retry budget exhausted)",
    "serve_desync_recoveries": "host/device desync recoveries",
    "serve_requeued": "in-flight requests requeued by desync recovery",
    "fault_injected": "injected faults that fired (by kind)",
}


@partial(jax.jit, static_argnames=("has_cache", "uses_cfg"))
def _apply_admission(state, meta, g, extras,
                     mask, x_new, meta_new, g_new, ex_new,
                     *, has_cache, uses_cfg):
    """Fold one tick's admissions into the device state in ONE fixed-shape
    dispatch: the host builds full-width (B-wide) masked update buffers in
    numpy and this compiled apply selects them in. Shapes never depend on
    how many slots admit, so the executable compiles once per (B, sample
    shape) — eager per-count scatters would recompile for every distinct
    admission count. Module-level so the compile cache is shared across
    scheduler instances."""
    x, E = state[0], state[1]
    mx = mask.reshape(mask.shape + (1,) * (x.ndim - 1))
    x = jnp.where(mx, x_new, x)
    mE = mask.reshape((1,) + mask.shape + (1,) * (E.ndim - 2))
    E = jnp.where(mE, 0.0, E)  # fresh rings -> warm-up from order 1
    if has_cache:
        # a reused slot must not inherit the previous request's deep
        # features; zeroed cache + the span's full init row reproduce the
        # uniform cached scan exactly (DESIGN.md §12)
        C = state[2]
        mC = mask.reshape(mask.shape + (1,) * (C.ndim - 1))
        state = (x, E, jnp.where(mC, 0.0, C))
    else:
        state = (x, E)
    meta = jnp.where(mask[None, :], meta_new, meta)
    if uses_cfg:
        g = jnp.where(mask, g_new, g)
    extras = {k: jnp.where(mask, ex_new[k], v) for k, v in extras.items()}
    return state, meta, g, extras


@jax.jit
def _gather_rows(x, idx):
    """Fixed-width readback gather: `idx` is padded to B so the compiled
    shape is count-independent (one compile per (B, sample shape), ever)."""
    return x[idx]


@jax.jit
def _poison_slot(x, slot):
    """Overwrite one slot's latent with NaN — fault injection only
    (serving/faults.py); never on the clean path."""
    return x.at[slot].set(jnp.nan)


@jax.jit
def _bump_row(meta, slot, delta):
    """Corrupt one slot's on-device row counter — fault injection only."""
    return meta.at[0, slot].add(delta)


@dataclass
class Request:
    """One sampling request: a latent to generate under per-request knobs.

    seed draws the initial latent (or pass `x_T` explicitly); `cfg_scale`
    overrides the program's nominal guidance scale for this request only
    (cfg-enabled programs); `extras` are per-request model conditioning
    scalars (e.g. {"class_ids": 7}) scattered into the scheduler's per-slot
    extras state at admission — the scheduler must be constructed with a
    matching `extras_init`; `arrival` is the request's arrival time in tick
    units — the trace driver (`server.run_trace`) submits it once the clock
    reaches it. The NFE budget is the compiled grid's (n_rows evals, one per
    tick); per-request consumption is bookkept on the `Completion`.
    """

    rid: int
    seed: int = 0
    cfg_scale: Optional[float] = None
    arrival: float = 0.0
    x_T: Optional[object] = None
    extras: Optional[dict] = None
    # quality tier for plan-bank programs (`SamplerEngine.build_bank`):
    # selects which tuned plan's row span this request steps through. Must
    # name a tier of the program's bank; None on single-plan programs.
    tier: Optional[str] = None
    # admission deadline in tick-clock units past `arrival`: a request still
    # queued when its deadline passes is expired at admission time instead
    # of served late (None = the scheduler's ResilienceConfig.default_ttl,
    # itself None = no deadline). Already-admitted requests always run to
    # completion — the deadline bounds queue wait, not service.
    ttl: Optional[float] = None


@dataclass
class Completion:
    """A finished request with its latent and bookkeeping."""

    rid: int
    latent: np.ndarray
    arrival: float
    admit_tick: int
    finish_tick: int     # executed-step counter when this request finished
    finish_clock: float  # simulated clock time (== finish_tick unless the
                         # trace driver fast-forwarded over idle gaps)
    evals: int           # rows executed = model evals this request consumed
    tier: Optional[str] = None  # the plan-bank tier served (None: single plan)
    # evals-per-latent in FULL-eval units: == evals for uncached programs;
    # below it when the request's row span scheduled shallow feature-reuse
    # evals (StepProgram.span_cost, DESIGN.md §12)
    eval_cost: float = 0.0
    # resilience provenance (DESIGN.md §16): ok=False marks a latent that
    # failed the on-device finite check with the retry budget exhausted
    # (fail_reason says why); retries counts non-finite re-admissions,
    # requeues counts desync-recovery re-admissions; first_tier is the
    # originally requested tier when retry fallback or shed-degrade moved
    # the request off it (None when it was served as requested).
    ok: bool = True
    retries: int = 0
    requeues: int = 0
    first_tier: Optional[str] = None
    fail_reason: Optional[str] = None

    @property
    def latency_ticks(self) -> float:
        """Queue wait + service, in tick units (one tick = one batched eval),
        on the same clock `arrival` is on."""
        return self.finish_clock - self.arrival


@dataclass
class _Flight:
    """One dispatched-but-not-yet-consumed tick: the trailing-readback
    record. `mask` is the device done mask, `lat` the one batched gather of
    the finished slots' latents (both with async host copies already in
    flight); everything else is host metadata stamped at dispatch time, so
    latency metrics are correct no matter how late the flight is consumed."""

    tick: int
    clock: float
    mask: object = None                 # device (B,) bool done mask
    lat: object = None                  # device (B, *sample) gather, padded
                                        # to full width — rows [0, n_done)
                                        # are the finished slots in order
    slots: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    reqs: List[Request] = field(default_factory=list)
    admits: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    budgets: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    offs: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))


class SlotScheduler:
    """Fixed-B continuous batching over a compiled `StepProgram`.

    `pipeline_depth` is the number of ticks kept in flight (DESIGN.md §13):
    1 = the synchronous loop (every tick's readback is consumed before
    `tick()` returns), N >= 2 dispatches up to N ticks ahead and consumes
    readbacks N-1 ticks late. Admission bookkeeping is host-predicted (the
    solver grid is deterministic), so the admission schedule — and therefore
    every latent — is identical at every depth; the device done mask is
    verified against the prediction at consumption time.
    """

    def __init__(self, program: StepProgram, slots: int,
                 sample_shape: Tuple[int, ...], dtype=jnp.float32,
                 gang: bool = False, step_override=None,
                 extras_init: Optional[dict] = None,
                 pipeline_depth: int = 1,
                 registry: Optional[MetricsRegistry] = None,
                 tracer=None, probe=None,
                 resilience: Optional[ResilienceConfig] = None,
                 faults: Optional[FaultPlan] = None):
        if pipeline_depth < 1:
            raise ValueError(f"pipeline_depth must be >= 1, "
                             f"got {pipeline_depth}")
        self.program = program
        self.slots = slots
        self.sample_shape = tuple(sample_shape)
        self.dtype = dtype
        self.gang = gang
        self.pipeline_depth = int(pipeline_depth)
        self.state = program.init_state(slots, self.sample_shape, dtype)
        self.meta = program.init_meta(slots)
        self.g = program.init_g(slots)
        # per-slot model conditioning (e.g. class ids): one (slots,) array
        # per key, seeded from extras_init and overwritten at admission from
        # Request.extras — conditioning is per-REQUEST, never slot-positional.
        # Explicit dtypes: AOT-compiled signatures must not drift weak->strong
        def _col(v):
            dt = (jnp.int32 if np.issubdtype(np.asarray(v).dtype, np.integer)
                  else jnp.float32)
            return jnp.full((slots,), v, dt)

        self.extras = {k: _col(v) for k, v in (extras_init or {}).items()}
        self._extras_init = dict(extras_init or {})
        self.queue: Deque[Request] = deque()
        self.slot_req: List[Optional[Request]] = [None] * slots
        # host mirror of the on-device meta counters, all vectorized numpy:
        # needed for admission (which slots are free), completion prediction
        # (which flight a request's latent rides home on), and the Completion
        # metadata. The device counters stay authoritative for the compiled
        # program's idx; the done mask is cross-checked at consumption.
        self._busy = np.zeros(slots, bool)
        self.slot_row = np.zeros(slots, np.int64)    # next row (tier-relative)
        self.slot_admit = np.zeros(slots, np.int64)
        # plan-bank bookkeeping: each slot's row span in the stacked table.
        # Single-plan programs keep offset 0 / budget n_rows for every slot.
        self.slot_off = np.zeros(slots, np.int64)
        self.slot_budget = np.full(slots, program.n_rows, np.int64)
        self.ticks = 0           # batched step calls = batched model evals
        self.evals = 0           # always == ticks (the CI smoke invariant)
        self.active_slot_ticks = 0
        self.clock: Optional[float] = None  # trace driver's simulated time;
                                            # None -> clock follows ticks
        self.completions: List[Completion] = []
        self._inflight: Deque[_Flight] = deque()
        # resilience policy (DESIGN.md §16): the default config is inert —
        # unbounded queue, no TTL, no retries — so a scheduler built without
        # one behaves bit-identically to the pre-resilience loop until a
        # fault actually fires. `rejections` partitions submissions together
        # with `completions`; `events` is the deterministic resilience /
        # fault ledger (plain tuples, compared across chaos runs).
        self.resilience = validate_resilience(
            resilience if resilience is not None else DEFAULT_RESILIENCE,
            program)
        self.rejections: List[Rejection] = []
        self.events: List[tuple] = []
        self._injector = (FaultInjector(faults, ledger=self.events)
                          if faults else None)
        self._rstate: Dict[int, dict] = {}  # rid -> retry/requeue provenance
        self._recoveries = 0
        # host-overhead accounting (benchmarks/bench_serve.py), split by tick
        # phase (DESIGN.md §15): admission = the _admit() call, dispatch = the
        # step call itself (inline device execution on runtimes without async
        # dispatch — device time, not bookkeeping), readback = time blocked
        # on device readbacks in _consume, bookkeeping = everything else in
        # tick(). The legacy `host_ns` (what the bench guard's host-fraction
        # cap is defined over) is admission + bookkeeping — tick wall minus
        # the dispatch call minus blocked readback, exactly as before.
        self._admission_ns = 0
        self._blocked_ns = 0
        self._dispatch_ns = 0
        self._bookkeeping_ns = 0
        self._probe_ns = 0  # quality-probe replays (excluded from phases)
        # observability (DESIGN.md §15): the registry is always on — it is
        # the one accounting substrate ServeMetrics is derived from — while
        # the tracer and quality probe are opt-in (None = zero work: every
        # call site is `if self.tracer is not None`-guarded).
        self.registry = registry if registry is not None else MetricsRegistry()
        self.tracer = tracer
        self.probe = probe
        if probe is not None:
            if probe.registry is None:
                probe.registry = self.registry
            if probe.tracer is None:
                probe.tracer = tracer
        r = self.registry
        self._m_ticks = r.counter(
            "serve_ticks", help="executed batched step calls")
        self._m_evals = r.counter(
            "serve_evals", help="batched model evals (== serve_ticks)")
        self._m_active = r.counter(
            "serve_active_slot_ticks", help="busy-slot ticks")
        self._m_submitted = r.counter(
            "serve_submitted", help="requests submitted")
        self._m_admitted = r.counter(
            "serve_admitted", help="requests admitted into slots")
        self._m_completed = r.counter(
            "serve_completed", help="requests completed")
        self._m_queue = r.histogram(
            "queue_depth", QUEUE_DEPTH_BUCKETS,
            help="queued requests per executed tick (post-admission)")
        self._m_busy = r.histogram(
            "busy_slots", BUSY_SLOT_BUCKETS,
            help="busy slots per executed tick")
        self._m_occ = r.histogram(
            "occupancy_frac", OCCUPANCY_BUCKETS,
            help="busy-slot fraction per executed tick")
        self._m_latency = r.histogram(
            "latency_ticks", LATENCY_TICK_BUCKETS,
            help="request latency (queue wait + service) in ticks")
        self._m_cost = r.histogram(
            "request_eval_cost", EVAL_COST_BUCKETS,
            help="evals-per-latent (full-eval units) per completion")
        self._m_phase = {p: r.counter("host_phase_ns", {"phase": p},
                                      wall=True,
                                      help="host ns per tick phase")
                         for p in HOST_PHASES}
        # step_override replaces the dispatched flight step — signature
        # step(state, meta, g, extras) -> (state, meta, done), and the done
        # mask must be consistent with the meta counters (it is verified
        # against the host prediction whenever a completion is consumed)
        self._flight = (step_override if step_override is not None
                        else program.step_flight)
        self._np_dtype = np.dtype(dtype)
        self._extras_np = {k: np.asarray(v).dtype
                           for k, v in self.extras.items()}

    # -- queue / slots -------------------------------------------------------
    def _count_event(self, name: str, labels: Optional[dict] = None,
                     n: int = 1) -> None:
        """Bump a lazily-registered resilience/fault counter."""
        self.registry.counter(name, labels,
                              help=EVENT_COUNTER_HELP[name]).inc(n)

    def submit(self, req: Request) -> Optional[Rejection]:
        """Queue a request, or shed it under overload control.

        Returns None when the request was accepted, or the typed
        `Rejection` handed back to the traffic source when the bounded
        queue shed it (also appended to `self.rejections`). Malformed
        requests — bad tier tag, unknown extras, guidance on an unguided
        program — still raise: those are programmer errors, not load."""
        if (req.cfg_scale is not None and float(req.cfg_scale) != 0.0
                and not self.program.uses_cfg):
            raise ValueError(
                f"request rid={req.rid} carries cfg_scale={req.cfg_scale} "
                f"but the step program was compiled without guidance; "
                f"build the engine spec with cfg_scale != 0")
        unknown = set(req.extras or {}) - set(self.extras)
        if unknown:
            raise ValueError(
                f"request rid={req.rid} carries extras {sorted(unknown)} the "
                f"scheduler was not constructed for; pass extras_init with "
                f"matching keys")
        self.program.resolve_tier(req.tier)  # reject bad tier tags at submit
        self._m_submitted.inc()
        cfg = self.resilience
        if (cfg.max_queue is not None
                and len(self.queue) >= cfg.max_queue):
            return self._reject(req, REJECT_QUEUE_FULL)
        if (cfg.shed_policy == "degrade"
                and cfg.degrade_watermark is not None
                and len(self.queue) >= cfg.degrade_watermark
                and req.tier != cfg.degrade_tier):
            # shed by degrading instead of dropping: past the watermark new
            # requests are remapped to the cheap tier, recording provenance
            self._rprov(req.rid)["first_tier"] = req.tier
            req = dc_replace(req, tier=cfg.degrade_tier)
            self.events.append(("shed_degrade", req.arrival, req.rid))
            self._count_event("serve_shed_degraded")
        self.queue.append(req)
        if self.tracer is not None:
            self.tracer.async_begin("request", req.rid,
                                    args={"tier": req.tier,
                                          "arrival": req.arrival})
        return None

    def _rprov(self, rid: int) -> dict:
        """This rid's resilience provenance record (created on first use;
        stamped onto its Completion and dropped at emission)."""
        return self._rstate.setdefault(
            rid, {"retries": 0, "requeues": 0, "first_tier": None})

    def _reject(self, req: Request, reason: str,
                clock: Optional[float] = None) -> Rejection:
        rej = Rejection(rid=req.rid, reason=reason, arrival=req.arrival,
                        clock=req.arrival if clock is None else clock,
                        tier=req.tier)
        self.rejections.append(rej)
        self.events.append(("reject", rej.clock, req.rid, reason))
        self._rstate.pop(req.rid, None)
        self._count_event("serve_rejected", {"reason": reason})
        if self.tracer is not None:
            if reason == REJECT_EXPIRED:
                # the lifecycle span opened at submit: close it as expired
                self.tracer.async_end("request", req.rid,
                                      args={"rejected": reason,
                                            "tier": req.tier})
            else:
                # queue_full sheds before the span opens: a lone instant
                self.tracer.instant("reject", cat="request",
                                    args={"rid": req.rid, "reason": reason})
        return rej

    @property
    def active(self) -> int:
        return int(self._busy.sum())

    @property
    def in_flight(self) -> int:
        """Dispatched ticks whose readback has not been consumed yet."""
        return len(self._inflight)

    @property
    def host_ns(self) -> int:
        """Accumulated host-side bookkeeping time across tick() calls,
        excluding time spent blocked on device readbacks and the step
        dispatch call itself (== the admission + bookkeeping phases)."""
        return self._admission_ns + self._bookkeeping_ns

    @property
    def phase_ns(self) -> dict:
        """Per-phase host time (DESIGN.md §15): {phase: ns} over the
        HOST_PHASES split. admission + bookkeeping == `host_ns`."""
        return {"admission": self._admission_ns,
                "dispatch": self._dispatch_ns,
                "readback": self._blocked_ns,
                "bookkeeping": self._bookkeeping_ns}

    @property
    def occupancy(self) -> float:
        """Mean fraction of slots doing useful work per tick."""
        return (self.active_slot_ticks / (self.ticks * self.slots)
                if self.ticks else 0.0)

    def _draw(self, req: Request) -> np.ndarray:
        """The request's initial latent, as host numpy (it is written into
        the full-width admission buffer, not shipped per-request)."""
        if req.x_T is not None:
            return np.asarray(req.x_T, self._np_dtype)
        key = jax.random.PRNGKey(req.seed)
        return np.asarray(jax.random.normal(key, self.sample_shape,
                                            self.dtype))

    def _expired(self, req: Request, admit_now: float) -> bool:
        """Deadline check at admission time (DESIGN.md §16): a queued
        request whose TTL elapsed before a slot freed is expired, never
        served late. Admitted requests are exempt by construction — this
        is only consulted on the queue->slot edge."""
        ttl = req.ttl if req.ttl is not None else self.resilience.default_ttl
        return ttl is not None and admit_now - req.arrival > ttl

    def _admit(self) -> None:
        if self.gang and self._busy.any():
            return  # sequential full-batch baseline: drain before refilling
        if not self.queue:
            return
        free = np.flatnonzero(~self._busy)
        if free.size == 0:
            return
        # the admission clock: the simulated time this tick's admissions
        # happen at (the trace driver advances `clock` to now+1 pre-tick).
        # A skew fault shifts it — the chaos stand-in for a stalled host.
        admit_now = (float(self.ticks) if self.clock is None
                     else self.clock - 1.0)
        if self._injector is not None:
            skew = self._injector.take_skew(self.ticks + 1)
            if skew:
                admit_now += skew
                self.events.append(("fault_skew", self.ticks + 1, skew))
                self._count_event("fault_injected", {"kind": "skew"})
        reqs: List[Request] = []
        while self.queue and len(reqs) < free.size:
            r = self.queue.popleft()
            if self._expired(r, admit_now):
                self._reject(r, REJECT_EXPIRED, clock=admit_now)
                continue
            reqs.append(r)
        n = len(reqs)
        if n == 0:
            return
        taken = free[:n]
        offs = np.empty(n, np.int64)
        budgets = np.empty(n, np.int64)
        for j, r in enumerate(reqs):
            offs[j], budgets[j] = self.program.resolve_tier(r.tier)
            self.slot_req[int(taken[j])] = r
        # vectorized host bookkeeping: one fancy-indexed write per array
        self._busy[taken] = True
        self.slot_row[taken] = 0
        self.slot_off[taken] = offs
        self.slot_budget[taken] = budgets
        self.slot_admit[taken] = self.ticks
        self._m_admitted.inc(n)
        if self.tracer is not None:
            # the admit instant opens the request's step segment: rows
            # [offset, offset + budget) execute over the next `budget` ticks
            for j, r in enumerate(reqs):
                self.tracer.async_instant(
                    "admit", r.rid,
                    args={"slot": int(taken[j]), "tick": self.ticks,
                          "offset": int(offs[j]), "budget": int(budgets[j]),
                          "tier": r.tier})
        # full-width masked update buffers, built host-side in numpy; the
        # jitted apply folds latents + meta counters + guidance + extras into
        # the device state in ONE fixed-shape dispatch per tick
        B = self.slots
        mask = np.zeros(B, bool)
        mask[taken] = True
        x_new = np.zeros((B,) + self.sample_shape, self._np_dtype)
        for j, r in enumerate(reqs):
            x_new[taken[j]] = self._draw(r)
        # on-device counters: row 0, the tier's span, busy
        meta_new = np.zeros((4, B), np.int32)
        meta_new[1, taken] = offs
        meta_new[2, taken] = budgets
        meta_new[3, taken] = 1
        g_new = np.zeros(B, np.float32)
        if self.program.uses_cfg:
            g_new[taken] = [float(r.cfg_scale) if r.cfg_scale is not None
                            else float(self.program.spec.cfg_scale or 0.0)
                            for r in reqs]
        ex_new = {k: np.zeros(B, self._extras_np[k]) for k in self.extras}
        for k in ex_new:
            ex_new[k][taken] = [(r.extras or {}).get(k, self._extras_init[k])
                                for r in reqs]
        self.state, self.meta, self.g, self.extras = _apply_admission(
            tuple(self.state), self.meta, self.g, self.extras,
            mask, x_new, meta_new, g_new, ex_new,
            has_cache=self.program.cache is not None,
            uses_cfg=self.program.uses_cfg)

    # -- the serving step ----------------------------------------------------
    def tick(self) -> List[Completion]:
        """Admit, dispatch ONE batched step, consume due readbacks.

        At pipeline_depth=1 the returned completions are this tick's; at
        depth N they are the completions of the tick dispatched N-1 ticks
        ago (its readback has had N-1 device ticks to land)."""
        t0 = time.perf_counter_ns()
        b0 = self._blocked_ns
        p0 = self._probe_ns
        self._admit()
        a1 = time.perf_counter_ns()
        adm_ns = a1 - t0
        self._admission_ns += adm_ns
        busy = self._busy
        if not busy.any():
            book_ns = time.perf_counter_ns() - a1
            self._bookkeeping_ns += book_ns
            self._m_phase["admission"].inc(adm_ns)
            self._m_phase["bookkeeping"].inc(book_ns)
            return []
        self.ticks += 1
        self.evals += 1
        n_busy = int(busy.sum())
        self.active_slot_ticks += n_busy
        self._m_ticks.inc()
        self._m_evals.inc()
        self._m_active.inc(n_busy)
        self._m_queue.observe(len(self.queue))
        self._m_busy.observe(n_busy)
        self._m_occ.observe(n_busy / self.slots)
        if self._injector is not None:
            self._inject()
        # dispatch: idx construction and row advance happen on device
        # (StepProgram.step_flight); nothing tick-varying crosses the host
        # boundary here. Timed separately — the call is device time (inline
        # execution on runtimes without async dispatch), not bookkeeping.
        d0 = time.perf_counter_ns()
        self.state, self.meta, mask = self._flight(self.state, self.meta,
                                                   *self._step_tail())
        d1 = time.perf_counter_ns()
        flight = _Flight(
            tick=self.ticks,
            clock=(float(self.ticks) if self.clock is None else self.clock))
        # host prediction of this tick's completions (the grid is
        # deterministic): vectorized row advance + budget compare
        self.slot_row[busy] += 1
        done_mask = busy & (self.slot_row >= self.slot_budget)
        if done_mask.any():
            slots_done = np.flatnonzero(done_mask)
            flight.mask = mask
            flight.slots = slots_done
            flight.reqs = [self.slot_req[int(s)] for s in slots_done]
            flight.admits = self.slot_admit[slots_done].copy()
            flight.budgets = self.slot_budget[slots_done].copy()
            flight.offs = self.slot_off[slots_done].copy()
            # the trailing readback stream: ONE batched gather of the
            # finished slots' latents, host copy started immediately; the
            # concrete values are consumed up to depth-1 ticks later. The
            # gather is dispatched before the next tick's donated step, so
            # it reads this tick's output before the buffers are recycled.
            # Indices are padded to full width so the compiled gather shape
            # is count-independent; rows past n_done are discarded.
            idx = np.full(self.slots, slots_done[-1], np.int32)
            idx[:slots_done.size] = slots_done
            lat = _gather_rows(self.state[0], idx)
            lat.copy_to_host_async()
            mask.copy_to_host_async()
            flight.lat = lat
            # free the slots now (host prediction): the next dispatch may
            # re-admit into them without draining the pipeline
            for s in slots_done:
                self.slot_req[int(s)] = None
            self._busy[done_mask] = False
            self.slot_row[done_mask] = 0
            self.slot_off[done_mask] = 0
        self._inflight.append(flight)
        done: List[Completion] = []
        while len(self._inflight) > self.pipeline_depth - 1:
            done.extend(self._consume(self._inflight.popleft()))
        t1 = time.perf_counter_ns()
        book_ns = (t1 - t0 - adm_ns - (d1 - d0)
                   - (self._blocked_ns - b0) - (self._probe_ns - p0))
        self._dispatch_ns += d1 - d0
        self._bookkeeping_ns += book_ns
        self._m_phase["admission"].inc(adm_ns)
        self._m_phase["dispatch"].inc(d1 - d0)
        self._m_phase["readback"].inc(self._blocked_ns - b0)
        self._m_phase["bookkeeping"].inc(book_ns)
        if self.tracer is not None:
            tr = self.tracer
            tr.complete("admission", t0, a1)
            tr.complete("dispatch", d0, d1)
            tr.complete("tick", t0, t1,
                        args={"tick": self.ticks, "busy": n_busy,
                              "queue": len(self.queue),
                              "emitted": len(done)})
            tr.counter("slots", {"busy": n_busy, "queue": len(self.queue)},
                       ts_ns=t0)
        return done

    def _inject(self) -> None:
        """Fire the armed faults due this tick (serving/faults.py), after
        admission and before dispatch, directly on device state — the
        compiled step program itself is never altered, so chaos tests
        exercise the real serving path. `self.ticks` already names the tick
        about to dispatch; `slot_row` still holds the row about to run."""
        inj = self._injector
        for s in np.flatnonzero(self._busy):
            req = self.slot_req[int(s)]
            fault = inj.take_nan(req.rid, int(self.slot_row[s]))
            if fault is not None:
                x = _poison_slot(self.state[0], jnp.int32(int(s)))
                self.state = (x,) + tuple(self.state[1:])
                self.events.append(("fault_nan", self.ticks, req.rid,
                                    int(self.slot_row[s])))
                self._count_event("fault_injected", {"kind": "nan"})
                if self.tracer is not None:
                    self.tracer.async_instant(
                        "fault_nan", req.rid,
                        args={"tick": self.ticks,
                              "step": int(self.slot_row[s])})
        mf = inj.take_meta(self.ticks)
        if mf is not None:
            slot = mf.slot
            if slot is None:
                busy = np.flatnonzero(self._busy)
                slot = int(busy[0]) if busy.size else None
            if slot is not None:
                self.meta = _bump_row(self.meta, jnp.int32(slot),
                                      jnp.int32(mf.delta))
                self.events.append(("fault_meta", self.ticks, slot,
                                    mf.delta))
                self._count_event("fault_injected", {"kind": "meta"})
                if self.tracer is not None:
                    self.tracer.instant("fault_meta", cat="tick",
                                        args={"tick": self.ticks,
                                              "slot": slot,
                                              "delta": mf.delta})

    def _consume(self, f: _Flight) -> List[Completion]:
        """Materialize one flight's readback: verify the on-device done mask
        against the host prediction and emit the finished latents."""
        if not f.slots.size:
            return []
        tb = time.perf_counter_ns()
        mask_np = np.asarray(f.mask)       # blocks until the tick executed
        lat_np = np.asarray(f.lat)         # ONE batched device_get per tick
        te = time.perf_counter_ns()
        self._blocked_ns += te - tb
        got = np.flatnonzero(mask_np)
        if not np.array_equal(got, f.slots):
            if self.resilience.recovery == "raise":
                raise RuntimeError(
                    f"on-device done mask {got.tolist()} disagrees with the "
                    f"host completion prediction {f.slots.tolist()} at tick "
                    f"{f.tick} — scheduler bookkeeping desynchronized from "
                    f"the compiled step program")
            return self._recover(f, got)
        # on-device output validation (DESIGN.md §16): the done mask is
        # coded, and DONE_NONFINITE marks a finished slot whose latent
        # failed the finite check inside the compiled step. Those requests
        # re-admit on the fallback chain while retry budget remains; only
        # exhaustion emits a (marked-failed) completion.
        bad = mask_np[f.slots] == DONE_NONFINITE
        cfg = self.resilience
        emitted: List[Tuple[Request, Completion]] = []
        for j, req in enumerate(f.reqs):
            if bad[j]:
                prov = self._rprov(req.rid)
                if prov["retries"] < cfg.max_retries:
                    self._retry(req, f, prov)
                    continue
            prov = self._rstate.pop(req.rid, None) or {}
            c = Completion(
                rid=req.rid, latent=lat_np[j], arrival=req.arrival,
                admit_tick=int(f.admits[j]), finish_tick=f.tick,
                finish_clock=f.clock, evals=int(f.budgets[j]),
                tier=req.tier,
                eval_cost=self.program.span_cost(int(f.offs[j]),
                                                 int(f.budgets[j])),
                ok=not bool(bad[j]),
                retries=int(prov.get("retries", 0)),
                requeues=int(prov.get("requeues", 0)),
                first_tier=prov.get("first_tier"),
                fail_reason=FAIL_NONFINITE if bad[j] else None)
            if not c.ok:
                self.events.append(("failed", f.tick, c.rid))
                self._count_event("serve_failed")
            emitted.append((req, c))
        done = [c for _, c in emitted]
        self.completions.extend(done)
        reg = self.registry
        for c in done:
            self._m_completed.inc()
            self._m_latency.observe(c.latency_ticks)
            self._m_cost.observe(c.eval_cost)
            if c.tier is not None:
                lbl = {"tier": c.tier}
                reg.counter("tier_completed", lbl,
                            help="completions per quality tier").inc()
                reg.gauge("tier_evals", lbl,
                          help="evals per request of this tier").set(c.evals)
                reg.gauge("tier_eval_cost", lbl,
                          help="evals-per-latent (full-eval units) of this "
                               "tier").set(c.eval_cost)
                reg.histogram("tier_latency_ticks", LATENCY_TICK_BUCKETS,
                              lbl, help="per-tier request latency in "
                                        "ticks").observe(c.latency_ticks)
        if self.tracer is not None:
            for c in done:
                args = {"tier": c.tier, "evals": c.evals,
                        "eval_cost": c.eval_cost,
                        "latency_ticks": c.latency_ticks,
                        "admit_tick": c.admit_tick,
                        "finish_tick": c.finish_tick}
                if not c.ok or c.retries or c.requeues:
                    args.update(ok=c.ok, retries=c.retries,
                                requeues=c.requeues,
                                fail_reason=c.fail_reason)
                self.tracer.async_end("request", c.rid, args=args)
            self.tracer.complete("readback", tb, te)
            self.tracer.complete("emit", te, time.perf_counter_ns())
        if self.probe is not None:
            # replay a sampled fraction against the high-NFE reference; the
            # replay is device work, not scheduler bookkeeping — timed apart
            # so it never pollutes the per-phase host accounting. Failed
            # completions are never probed (their latent is non-finite).
            pp0 = time.perf_counter_ns()
            for req, c in emitted:
                if c.ok and self.probe.selected(c.rid):
                    self.probe.observe(req, c, self._draw(req))
            self._probe_ns += time.perf_counter_ns() - pp0
        return done

    def _retry(self, req: Request, f: _Flight, prov: dict) -> None:
        """Re-admit a request whose finished latent failed validation:
        seed and x_T preserved (the retry re-draws the identical initial
        latent), tier advanced along the fallback chain, and the request
        put at the queue FRONT — it has waited longest. Bookkept as a
        re-admission, not a new submission."""
        nxt = fallback_tier(self.resilience, req.tier)
        if nxt != req.tier and prov["first_tier"] is None:
            prov["first_tier"] = req.tier
        prov["retries"] += 1
        self.events.append(("retry", f.tick, req.rid, req.tier, nxt))
        self._count_event("serve_retries")
        if self.tracer is not None:
            self.tracer.async_instant(
                "retry", req.rid,
                args={"tick": f.tick, "from": req.tier, "to": nxt,
                      "attempt": prov["retries"]})
        self.queue.appendleft(req if nxt == req.tier
                              else dc_replace(req, tier=nxt))

    def _recover(self, f: _Flight, got: np.ndarray) -> List[Completion]:
        """Desync recovery (DESIGN.md §16): the device done mask disagreed
        with the host's predicted completion schedule. Drain the pipeline
        (every in-flight readback is suspect), re-derive the host slot
        mirrors from the authoritative device `meta` counters — slots whose
        host and device bookkeeping still agree keep running untouched —
        and requeue every affected request to re-serve from scratch (seed
        preserved, so a recovered request's latent still reproduces the
        clean run). Returns no completions; the requeued work re-emits
        through the normal path."""
        self._recoveries += 1
        if self._recoveries > self.resilience.max_recoveries:
            raise RuntimeError(
                f"desync recovery limit ({self.resilience.max_recoveries}) "
                f"exhausted: on-device done mask {got.tolist()} still "
                f"disagrees with the host completion prediction "
                f"{f.slots.tolist()} at tick {f.tick} — the step program "
                f"and scheduler bookkeeping cannot re-synchronize")
        affected: List[Request] = list(f.reqs)
        while self._inflight:
            affected.extend(self._inflight.popleft().reqs)
        meta_dev = np.asarray(self.meta)  # authoritative device counters
        nr = self.program.n_rows
        for s in range(self.slots):
            host_busy = bool(self._busy[s])
            dev_busy = bool(meta_dev[3, s])
            if not host_busy and not dev_busy:
                continue
            if (host_busy and dev_busy
                    and int(meta_dev[0, s]) == int(self.slot_row[s])
                    and int(meta_dev[1, s]) == int(self.slot_off[s])
                    and int(meta_dev[2, s]) == int(self.slot_budget[s])):
                continue  # mirrors agree: the slot keeps running
            req = self.slot_req[s]
            if req is not None:
                affected.append(req)
            self.slot_req[s] = None
            self._busy[s] = False
            self.slot_row[s] = 0
            self.slot_off[s] = 0
            self.slot_budget[s] = nr
            meta_dev[:, s] = (0, 0, nr, 0)
        self.meta = jnp.asarray(meta_dev)
        # requeue at the queue front in original arrival order: recovered
        # requests were in service before anything still queued
        affected.sort(key=lambda r: (r.arrival, r.rid))
        for r in reversed(affected):
            self._rprov(r.rid)["requeues"] += 1
            self.queue.appendleft(r)
        self.events.append(("desync", f.tick,
                            tuple(r.rid for r in affected)))
        self._count_event("serve_desync_recoveries")
        if affected:
            self._count_event("serve_requeued", n=len(affected))
        if self.tracer is not None:
            self.tracer.instant(
                "desync_recover", cat="tick",
                args={"tick": f.tick, "got": got.tolist(),
                      "predicted": f.slots.tolist(),
                      "requeued": [r.rid for r in affected]})
            for r in affected:
                self.tracer.async_instant("requeue", r.rid,
                                          args={"tick": f.tick})
        return []

    def flush(self) -> List[Completion]:
        """Consume every in-flight readback (blocking). A no-op at
        pipeline_depth=1; the async trace driver calls it once the arrival
        stream is exhausted. May leave work REQUEUED (a consumed readback
        can trigger a retry or a desync recovery) — drivers must re-check
        `queue`/`active` after flushing, as `drain` and `run_trace` do."""
        done: List[Completion] = []
        while self._inflight:
            done.extend(self._consume(self._inflight.popleft()))
        return done

    def drain(self) -> List[Completion]:
        """Tick until every queued and in-flight request has finished —
        including requests the resilience layer requeued mid-drain."""
        out: List[Completion] = []
        while True:
            while self.queue or self.active:
                out.extend(self.tick())
            out.extend(self.flush())
            if not (self.queue or self.active):
                return out

    def _step_tail(self):
        """Trailing step args after (state, meta) — identical for every tick
        and for the AOT lowering, so compiled signatures always match."""
        return (self.g if self.program.uses_cfg else None,
                self.extras if self.extras else None)

    # -- AOT compile (DESIGN.md §9; the serve-timing fix) --------------------
    def aot_compile(self) -> float:
        """Lower + compile the flight step ahead of time and swap the
        compiled executable in; returns the compile seconds. Keeps the first
        tick's timing honest — compile is no longer folded into execution."""
        t0 = time.perf_counter()
        compiled = self._flight.lower(self.state, self.meta,
                                      *self._step_tail()).compile()
        dt = time.perf_counter() - t0
        self._flight = compiled
        return dt
