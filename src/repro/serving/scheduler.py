"""Request-level slot scheduler for continuous-batching diffusion serving.

The engine compiles a `StepProgram` (per-slot step function over the solver
table, `SamplerEngine.build_step`); this module owns everything request-shaped
around it: a fixed set of B slots, a FIFO admission queue, per-request
seed / cfg-scale / NFE-budget bookkeeping, and finished-latent emission.

One `tick()` = one batched model eval: admit queued requests into free slots
(write the request's initial latent, zero the slot's eval ring, set its
guidance scale), gather the per-slot row indices, call the step function once
for the whole batch, then emit every slot that just executed its last row.
Because admission resets the ring and the zero-padded warm-up rows null empty
ring slots, a request admitted mid-flight reproduces the uniform `build()`
scan for its own (solver, order, nfe, seed, cfg-scale) exactly — the parity
property `tests/test_serving.py` pins across solvers.

Idle slots park on row 0 (an identity update), so the batch shape — and the
compiled program — never changes. `gang=True` degrades admission to
sequential full-batch serving (admit only when *every* slot is free): the
baseline the benchmarks compare continuous batching against.
"""

from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..engine.engine import StepProgram


@dataclass
class Request:
    """One sampling request: a latent to generate under per-request knobs.

    seed draws the initial latent (or pass `x_T` explicitly); `cfg_scale`
    overrides the program's nominal guidance scale for this request only
    (cfg-enabled programs); `extras` are per-request model conditioning
    scalars (e.g. {"class_ids": 7}) scattered into the scheduler's per-slot
    extras state at admission — the scheduler must be constructed with a
    matching `extras_init`; `arrival` is the request's arrival time in tick
    units — the trace driver (`server.run_trace`) submits it once the clock
    reaches it. The NFE budget is the compiled grid's (n_rows evals, one per
    tick); per-request consumption is bookkept on the `Completion`.
    """

    rid: int
    seed: int = 0
    cfg_scale: Optional[float] = None
    arrival: float = 0.0
    x_T: Optional[object] = None
    extras: Optional[dict] = None
    # quality tier for plan-bank programs (`SamplerEngine.build_bank`):
    # selects which tuned plan's row span this request steps through. Must
    # name a tier of the program's bank; None on single-plan programs.
    tier: Optional[str] = None


@dataclass
class Completion:
    """A finished request with its latent and bookkeeping."""

    rid: int
    latent: np.ndarray
    arrival: float
    admit_tick: int
    finish_tick: int     # executed-step counter when this request finished
    finish_clock: float  # simulated clock time (== finish_tick unless the
                         # trace driver fast-forwarded over idle gaps)
    evals: int           # rows executed = model evals this request consumed
    tier: Optional[str] = None  # the plan-bank tier served (None: single plan)
    # evals-per-latent in FULL-eval units: == evals for uncached programs;
    # below it when the request's row span scheduled shallow feature-reuse
    # evals (StepProgram.span_cost, DESIGN.md §12)
    eval_cost: float = 0.0

    @property
    def latency_ticks(self) -> float:
        """Queue wait + service, in tick units (one tick = one batched eval),
        on the same clock `arrival` is on."""
        return self.finish_clock - self.arrival


class SlotScheduler:
    """Fixed-B continuous batching over a compiled `StepProgram`."""

    def __init__(self, program: StepProgram, slots: int,
                 sample_shape: Tuple[int, ...], dtype=jnp.float32,
                 gang: bool = False, step_override=None,
                 extras_init: Optional[dict] = None):
        self.program = program
        self.slots = slots
        self.sample_shape = tuple(sample_shape)
        self.dtype = dtype
        self.gang = gang
        self.state = program.init_state(slots, self.sample_shape, dtype)
        self.g = program.init_g(slots)
        # per-slot model conditioning (e.g. class ids): one (slots,) array
        # per key, seeded from extras_init and overwritten at admission from
        # Request.extras — conditioning is per-REQUEST, never slot-positional.
        # Explicit dtypes: AOT-compiled signatures must not drift weak->strong
        def _col(v):
            dt = (jnp.int32 if np.issubdtype(np.asarray(v).dtype, np.integer)
                  else jnp.float32)
            return jnp.full((slots,), v, dt)

        self.extras = {k: _col(v) for k, v in (extras_init or {}).items()}
        self._extras_init = dict(extras_init or {})
        self.queue: Deque[Request] = deque()
        self.slot_req: List[Optional[Request]] = [None] * slots
        self.slot_row = np.zeros(slots, np.int64)    # next row (tier-relative)
        self.slot_admit = np.zeros(slots, np.int64)
        # plan-bank bookkeeping: each slot's row span in the stacked table.
        # Single-plan programs keep offset 0 / budget n_rows for every slot.
        self.slot_off = np.zeros(slots, np.int64)
        self.slot_budget = np.full(slots, program.n_rows, np.int64)
        self.ticks = 0           # batched step calls = batched model evals
        self.evals = 0           # always == ticks (the CI smoke invariant)
        self.active_slot_ticks = 0
        self.clock: Optional[float] = None  # trace driver's simulated time;
                                            # None -> clock follows ticks
        self.completions: List[Completion] = []
        self._step = step_override if step_override is not None else program.step

    # -- queue / slots -------------------------------------------------------
    def submit(self, req: Request) -> None:
        if (req.cfg_scale is not None and float(req.cfg_scale) != 0.0
                and not self.program.uses_cfg):
            raise ValueError(
                f"request rid={req.rid} carries cfg_scale={req.cfg_scale} "
                f"but the step program was compiled without guidance; "
                f"build the engine spec with cfg_scale != 0")
        unknown = set(req.extras or {}) - set(self.extras)
        if unknown:
            raise ValueError(
                f"request rid={req.rid} carries extras {sorted(unknown)} the "
                f"scheduler was not constructed for; pass extras_init with "
                f"matching keys")
        self.program.resolve_tier(req.tier)  # reject bad tier tags at submit
        self.queue.append(req)

    @property
    def active(self) -> int:
        return sum(r is not None for r in self.slot_req)

    @property
    def occupancy(self) -> float:
        """Mean fraction of slots doing useful work per tick."""
        return (self.active_slot_ticks / (self.ticks * self.slots)
                if self.ticks else 0.0)

    def _draw(self, req: Request):
        if req.x_T is not None:
            return jnp.asarray(req.x_T, self.dtype)
        key = jax.random.PRNGKey(req.seed)
        return jax.random.normal(key, self.sample_shape, self.dtype)

    def _admit(self) -> None:
        if self.gang and self.active:
            return  # sequential full-batch baseline: drain before refilling
        taken, draws, scales = [], [], []
        extra_vals = {k: [] for k in self.extras}
        for s in range(self.slots):
            if not self.queue:
                break
            if self.slot_req[s] is not None:
                continue
            req = self.queue.popleft()
            taken.append(s)
            draws.append(self._draw(req))
            scales.append(float(req.cfg_scale)
                          if req.cfg_scale is not None
                          else float(self.program.spec.cfg_scale or 0.0))
            for k in extra_vals:
                extra_vals[k].append((req.extras or {}).get(
                    k, self._extras_init[k]))
            self.slot_req[s] = req
            self.slot_row[s] = 0
            off, budget = self.program.resolve_tier(req.tier)
            self.slot_off[s] = off
            self.slot_budget[s] = budget
            self.slot_admit[s] = self.ticks
        if not taken:
            return
        # one scatter per tick, not one full-state copy per admitted request
        x, E = self.state[:2]
        sl = jnp.asarray(taken, jnp.int32)
        x = x.at[sl].set(jnp.stack(draws))
        E = E.at[:, sl].set(0.0)  # fresh rings -> warm-up from order 1
        if self.program.cache is not None:
            # a reused slot must not inherit the previous request's deep
            # features; zeroed cache + the span's full init row reproduce the
            # uniform cached scan exactly (DESIGN.md §12)
            C = self.state[2].at[sl].set(0.0)
            self.state = (x, E, C)
        else:
            self.state = (x, E)
        if self.program.uses_cfg:
            self.g = self.g.at[sl].set(jnp.asarray(scales, jnp.float32))
        for k, vals in extra_vals.items():
            self.extras[k] = self.extras[k].at[sl].set(
                jnp.asarray(vals, self.extras[k].dtype))

    # -- the serving step ----------------------------------------------------
    def tick(self) -> List[Completion]:
        """Admit, run ONE batched step, emit finished latents."""
        self._admit()
        if self.active == 0:
            return []
        busy = np.array([r is not None for r in self.slot_req])
        # idle slots park on row 0 — the (first tier's) init row, an identity
        # update; busy slots gather their tier offset + trajectory position
        idx = jnp.asarray(np.where(busy, self.slot_off + self.slot_row, 0),
                          jnp.int32)
        self.state = self._step(self.state, idx, *self._step_tail())
        self.ticks += 1
        self.evals += 1
        self.active_slot_ticks += int(busy.sum())
        done: List[Completion] = []
        for s in range(self.slots):
            req = self.slot_req[s]
            if req is None:
                continue
            self.slot_row[s] += 1
            if self.slot_row[s] >= self.slot_budget[s]:
                done.append(Completion(
                    rid=req.rid, latent=np.asarray(self.state[0][s]),
                    arrival=req.arrival, admit_tick=int(self.slot_admit[s]),
                    finish_tick=self.ticks,
                    finish_clock=(float(self.ticks) if self.clock is None
                                  else self.clock),
                    evals=int(self.slot_budget[s]), tier=req.tier,
                    eval_cost=self.program.span_cost(
                        int(self.slot_off[s]), int(self.slot_budget[s]))))
                self.slot_req[s] = None
                self.slot_row[s] = 0
                self.slot_off[s] = 0
        self.completions.extend(done)
        return done

    def drain(self) -> List[Completion]:
        """Tick until every queued and in-flight request has finished."""
        out: List[Completion] = []
        while self.queue or self.active:
            out.extend(self.tick())
        return out

    def _step_tail(self):
        """Trailing step args after (state, idx) — identical for every tick
        and for the AOT lowering, so compiled signatures always match."""
        return (self.g if self.program.uses_cfg else None,
                self.extras if self.extras else None)

    # -- AOT compile (DESIGN.md §9; the serve-timing fix) --------------------
    def aot_compile(self) -> float:
        """Lower + compile the step function ahead of time and swap the
        compiled executable in; returns the compile seconds. Keeps the first
        tick's timing honest — compile is no longer folded into execution."""
        import time

        idx = jnp.zeros((self.slots,), jnp.int32)
        t0 = time.perf_counter()
        compiled = self._step.lower(self.state, idx,
                                    *self._step_tail()).compile()
        dt = time.perf_counter() - t0
        self._step = compiled
        return dt
