"""Deterministic fault injection for the serving loop (DESIGN.md §16).

Chaos testing only proves something when the chaos is reproducible: a
`FaultPlan` is a frozen, seedable description of exactly which faults fire
where, so two runs of the same plan over the same trace produce the same
event ledger, the same sheds and retries, and — for every request a fault
never touched — bit-identical latents to the clean run. The scheduler
threads the plan through a `FaultInjector`, which arms each fault once
(unless sticky) and records what actually fired.

Three fault kinds, one per failure class the resilience layer handles:

* `NanFault` — poison request `rid`'s slot latent with NaN just before the
  eval of step `step`, exercising the on-device finite-check + the
  degraded-tier retry path. Because the DiT's attention and normalization
  are per-sample, a poisoned slot never contaminates its batch-mates: the
  clean requests in the same batch still finish bit-identical to a
  fault-free run.
* `MetaFault` — corrupt the on-device row counter of a busy slot at tick
  `tick`, desynchronizing the authoritative device bookkeeping from the
  host's predicted completion schedule, exercising desync recovery.
* `SkewFault` — shift the admission clock by `delta` at tick `tick`,
  exercising TTL/deadline expiry without a real slow consumer.

Faults are injected by the scheduler between admission and dispatch, on
device state, through two tiny jitted updates — the compiled step program
itself is never altered, so what the chaos tests exercise is the real
serving path under the real compiled program.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class NanFault:
    """Poison request `rid`'s latent before its step `step` eval."""

    rid: int
    step: int = 0
    sticky: bool = False   # re-fire on every retry attempt (exhaustion tests)


@dataclass(frozen=True)
class MetaFault:
    """Bump the device row counter of slot `slot` (lowest busy slot when
    None) by `delta` at tick `tick`, forcing a host/device desync."""

    tick: int
    slot: Optional[int] = None
    delta: int = 1


@dataclass(frozen=True)
class SkewFault:
    """Shift the admission clock by `delta` tick-units at tick `tick`."""

    tick: int
    delta: float = 0.0


@dataclass(frozen=True)
class FaultPlan:
    """An immutable set of faults to inject into one serving run."""

    nans: Tuple[NanFault, ...] = ()
    metas: Tuple[MetaFault, ...] = ()
    skews: Tuple[SkewFault, ...] = ()

    def __bool__(self) -> bool:
        return bool(self.nans or self.metas or self.skews)

    def describe(self) -> str:
        parts = ([f"nan:rid={f.rid},step={f.step}"
                  + (",sticky=1" if f.sticky else "") for f in self.nans]
                 + [f"meta:tick={f.tick}"
                    + (f",slot={f.slot}" if f.slot is not None else "")
                    + (f",delta={f.delta}" if f.delta != 1 else "")
                    for f in self.metas]
                 + [f"skew:tick={f.tick},delta={f.delta:g}"
                    for f in self.skews])
        return ";".join(parts) if parts else "none"

    @classmethod
    def seeded(cls, seed: int, *, n_requests: int, nfe: int,
               n_nan: int = 1, n_meta: int = 0, n_skew: int = 0,
               horizon: Optional[int] = None) -> "FaultPlan":
        """Draw a reproducible plan: `n_nan` poisoned (rid, step) pairs,
        `n_meta` desyncs and `n_skew` clock skews over the first `horizon`
        ticks (default: n_requests * nfe, the serial-service bound)."""
        rng = np.random.default_rng(seed)
        horizon = int(horizon if horizon is not None
                      else max(1, n_requests * nfe))
        nans = tuple(NanFault(rid=int(rng.integers(n_requests)),
                              step=int(rng.integers(nfe)))
                     for _ in range(n_nan))
        metas = tuple(MetaFault(tick=int(rng.integers(1, horizon + 1)))
                      for _ in range(n_meta))
        skews = tuple(SkewFault(tick=int(rng.integers(1, horizon + 1)),
                                delta=float(rng.integers(1, nfe + 1)))
                      for _ in range(n_skew))
        return cls(nans=nans, metas=metas, skews=skews)


def parse_fault_spec(spec: str) -> FaultPlan:
    """Parse the `--inject-faults` CLI string: semicolon-separated clauses
    `kind:key=val,key=val`, e.g.

        nan:rid=2,step=1;meta:tick=6;skew:tick=3,delta=9

    `seed:value[,n_nan=..,n_meta=..,n_skew=..,requests=..,nfe=..]` draws a
    `FaultPlan.seeded` plan instead (requests/nfe required)."""
    spec = (spec or "").strip()
    if not spec or spec == "none":
        return FaultPlan()
    nans: List[NanFault] = []
    metas: List[MetaFault] = []
    skews: List[SkewFault] = []
    for clause in spec.split(";"):
        clause = clause.strip()
        if not clause:
            continue
        kind, _, body = clause.partition(":")
        kind = kind.strip()
        kv = {}
        first = None
        for part in body.split(","):
            part = part.strip()
            if not part:
                continue
            if "=" in part:
                k, v = part.split("=", 1)
                kv[k.strip()] = v.strip()
            elif first is None:
                first = part
        try:
            if kind == "nan":
                nans.append(NanFault(rid=int(kv["rid"]),
                                     step=int(kv.get("step", 0)),
                                     sticky=bool(int(kv.get("sticky", 0)))))
            elif kind == "meta":
                slot = kv.get("slot")
                metas.append(MetaFault(tick=int(kv["tick"]),
                                       slot=None if slot is None
                                       else int(slot),
                                       delta=int(kv.get("delta", 1))))
            elif kind == "skew":
                skews.append(SkewFault(tick=int(kv["tick"]),
                                       delta=float(kv["delta"])))
            elif kind == "seed":
                plan = FaultPlan.seeded(
                    int(first if first is not None else kv["value"]),
                    n_requests=int(kv["requests"]), nfe=int(kv["nfe"]),
                    n_nan=int(kv.get("n_nan", 1)),
                    n_meta=int(kv.get("n_meta", 0)),
                    n_skew=int(kv.get("n_skew", 0)))
                nans.extend(plan.nans)
                metas.extend(plan.metas)
                skews.extend(plan.skews)
            else:
                raise KeyError(kind)
        except (KeyError, ValueError) as e:
            raise ValueError(
                f"bad fault clause {clause!r} (expected e.g. "
                f"'nan:rid=2,step=1', 'meta:tick=6', 'skew:tick=3,delta=9', "
                f"'seed:7,requests=8,nfe=4'): {e}") from None
    return FaultPlan(nans=tuple(nans), metas=tuple(metas),
                     skews=tuple(skews))


@dataclass
class FaultInjector:
    """Arms a `FaultPlan` for one run: each fault fires at most once (NaN
    faults marked sticky re-fire on every attempt), and everything that
    fired is appended to `ledger` in firing order — the deterministic
    record the chaos tests compare across runs."""

    plan: FaultPlan
    ledger: List[tuple] = field(default_factory=list)

    def __post_init__(self):
        self._nan_fired: set = set()
        self._meta_fired: set = set()
        self._skew_fired: set = set()

    def take_nan(self, rid: int, step: int) -> Optional[NanFault]:
        """The NaN fault due for (rid, step) right now, or None."""
        for f in self.plan.nans:
            if f.rid != rid or f.step != step:
                continue
            key = (f.rid, f.step)
            if not f.sticky and key in self._nan_fired:
                continue
            self._nan_fired.add(key)
            return f
        return None

    def take_meta(self, tick: int) -> Optional[MetaFault]:
        """The meta-corruption fault due at `tick` (first executed tick
        at-or-after its scheduled tick), or None."""
        for i, f in enumerate(self.plan.metas):
            if tick >= f.tick and i not in self._meta_fired:
                self._meta_fired.add(i)
                return f
        return None

    def take_skew(self, tick: int) -> float:
        """Total admission-clock shift due by `tick` (0.0 when none). Skews
        fire at the first admission at-or-after their tick — admission does
        not happen every tick, and a skew must not be lost to that."""
        delta = 0.0
        for i, f in enumerate(self.plan.skews):
            if tick >= f.tick and i not in self._skew_fired:
                self._skew_fired.add(i)
                delta += f.delta
        return delta
