"""Solver-plan autotuner (DESIGN.md §10).

UniPC's accuracy at extreme few-step budgets hinges on per-step choices the
paper fixes by hand: timestep placement, UniP order, UniC on/off, B(h)
variant. This package makes those choices *data*:

* `plans`     — `SolverPlan`, the per-step decision vector; lowers through
                the same `build_unipc_schedule` path as every hand-set
                table; JSON (de)serialization; tier-keyed plan banks.
* `objective` — scores a plan by trajectory discrepancy against a high-NFE
                reference run (no FID model needed); one jitted runner with
                the weight table as a traced argument, so candidate scoring
                never recompiles.
* `search`    — deterministic coordinate descent with a beam over the mixed
                discrete/continuous space.

Serving integration lives in `engine.SamplerEngine.build_bank`: tuned plans
stack into one row-gatherable table (`core.stack_step_rows`) that a single
compiled `StepProgram` serves as fast/balanced/quality tiers.
"""

from .objective import (PlanObjective, QuantParityError, make_objective,
                        quant_parity_gate, reference_trajectory)
from .plans import SolverPlan, load_bank, save_bank
from .search import (CachedSearchResult, SearchConfig, SearchResult,
                     tune_cached_plan, tune_plan)

__all__ = [
    "SolverPlan", "save_bank", "load_bank",
    "PlanObjective", "make_objective", "reference_trajectory",
    "QuantParityError", "quant_parity_gate",
    "SearchConfig", "SearchResult", "tune_plan",
    "CachedSearchResult", "tune_cached_plan",
]
