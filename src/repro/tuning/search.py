"""Plan search: coordinate descent with a beam over the decision space.

The space is small but mixed (continuous knots x discrete orders /
corrector mask / B(h) variants) and the objective is cheap-but-not-free (one
compiled trajectory per candidate), which is exactly the regime where
gradient-free coordinate moves win: sweep the per-step coordinates in a
fixed deterministic order, propose every alternative value for discrete
coordinates and a few relative shifts for knots, score candidates, and keep
the top-`beam` plans as the frontier for the next coordinate. Rounds repeat
the sweep from the improved frontier; the search stops on budget exhaustion
or a sweep with no accepted improvement.

Everything is deterministic given the config — no RNG — so a tuned plan is
reproducible from (model, probe seed, SearchConfig) alone.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import List, Optional, Tuple

import numpy as np

from .objective import PlanObjective
from .plans import MAX_ORDER, SEARCH_VARIANTS, SolverPlan


@dataclass
class SearchConfig:
    budget: int = 80          # max objective evaluations (incl. the init)
    beam: int = 2             # frontier width
    rounds: int = 3           # max coordinate sweeps
    knot_fracs: Tuple[float, ...] = (0.25, 0.5)   # +- fraction of the
                              # neighbor gap proposed per knot move
    search_orders: bool = True
    search_corrector: bool = True
    search_variants: bool = True
    search_knots: bool = True
    knot_margin: float = 0.05  # keep u_i at least this fraction of the gap
                               # away from its neighbors (monotonicity)


@dataclass
class SearchResult:
    plan: SolverPlan          # the winner (meta carries the scores)
    score: float
    baseline: float           # score of the initial plan
    evals: int
    history: List[Tuple[float, str]] = field(default_factory=list)
    # (score, move) per accepted improvement, in order


def _knot_moves(plan: SolverPlan, i: int, cfg: SearchConfig):
    """Candidate positions for interior knot i (0-based into plan.knots)."""
    u = np.concatenate([[0.0], np.asarray(plan.knots, np.float64), [1.0]])
    j = i + 1                           # index into the padded grid
    lo, hi = u[j - 1], u[j + 1]
    out = []
    for frac in cfg.knot_fracs:
        for sgn in (-1.0, 1.0):
            cand = u[j] + sgn * frac * (hi - lo) / 2.0
            lo_m = lo + cfg.knot_margin * (hi - lo)
            hi_m = hi - cfg.knot_margin * (hi - lo)
            cand = float(np.clip(cand, lo_m, hi_m))
            if abs(cand - u[j]) > 1e-12:
                out.append(cand)
    return sorted(set(out))


def _canonical_key(plan: SolverPlan) -> str:
    """Dedup key on the plan's *lowered* decision content: orders are
    clamped by the warm-up rule min(p_i, i) exactly as at table build, so
    decision vectors that compile to the same table share one beam slot."""
    d = plan.to_dict()
    d["orders"] = [min(o, i + 1) for i, o in enumerate(d["orders"])]
    d.pop("meta", None)
    return repr(d)


def _mutations(plan: SolverPlan, coord: Tuple[str, int], cfg: SearchConfig):
    """All candidate plans differing from `plan` at one coordinate. Order
    candidates that the warm-up clamp maps onto the current effective order
    are skipped — they'd lower to a bit-identical table and waste evals."""
    kind, i = coord
    out = []
    if kind == "order":
        eff = min(plan.orders[i], i + 1)
        for o in range(1, MAX_ORDER + 1):
            if o != plan.orders[i] and min(o, i + 1) != eff:
                orders = list(plan.orders)
                orders[i] = o
                out.append((replace(plan, orders=orders),
                            f"order[{i}]={o}"))
    elif kind == "corr":
        corr = list(plan.corrector)
        corr[i] = not corr[i]
        out.append((replace(plan, corrector=corr),
                    f"corr[{i}]={int(corr[i])}"))
    elif kind == "variant":
        for v in SEARCH_VARIANTS:
            if v != plan.variants[i]:
                var = list(plan.variants)
                var[i] = v
                out.append((replace(plan, variants=var),
                            f"variant[{i}]={v}"))
    elif kind == "knot":
        for cand in _knot_moves(plan, i, cfg):
            knots = list(plan.knots)
            knots[i] = cand
            out.append((replace(plan, knots=knots),
                        f"knot[{i}]={cand:.4f}"))
    return out


def _coordinates(plan: SolverPlan, cfg: SearchConfig):
    """Deterministic sweep order: decisions with the coarsest effect first
    (orders), then corrector mask, knots, variants — per step, early steps
    first (where few-step error is born)."""
    M = plan.nfe
    coords = []
    if cfg.search_orders:
        coords += [("order", i) for i in range(M)]
    if cfg.search_corrector:
        coords += [("corr", i) for i in range(M)]
    if cfg.search_knots:
        coords += [("knot", i) for i in range(M - 1)]
    if cfg.search_variants:
        coords += [("variant", i) for i in range(M)]
    return coords


def tune_plan(objective: PlanObjective, noise_schedule,
              init: SolverPlan, config: Optional[SearchConfig] = None,
              verbose: bool = False) -> SearchResult:
    """Coordinate-descent + beam search from `init` (usually the hand-set
    UniPC baseline via `SolverPlan.from_spec`). Scores never regress: the
    returned plan is the best scored candidate, which is `init` itself if no
    mutation improved on it."""
    cfg = config or SearchConfig()
    evals_left = cfg.budget
    # the objective is deterministic, so already-scored candidates (same
    # lowered table — the beam-dedup key) are memo hits costing no budget
    memo = {}

    def score(p: SolverPlan) -> float:
        nonlocal evals_left
        k = _canonical_key(p)
        if k not in memo:
            evals_left -= 1
            memo[k] = objective(p, noise_schedule)
        return memo[k]

    d0 = score(init)
    beam: List[Tuple[float, SolverPlan]] = [(d0, init)]
    history: List[Tuple[float, str]] = [(d0, "init")]
    for rnd in range(cfg.rounds):
        improved = False
        for coord in _coordinates(init, cfg):
            pool = list(beam)
            for base_score, base in beam:
                for cand, move in _mutations(base, coord, cfg):
                    if evals_left <= 0:
                        break
                    d = score(cand)
                    pool.append((d, cand))
                    if d < beam[0][0]:
                        improved = True
                        history.append((d, move))
                        if verbose:
                            print(f"  round {rnd} {move}: "
                                  f"{beam[0][0]:.5f} -> {d:.5f}")
                if evals_left <= 0:
                    break
            # keep the top-`beam` distinct plans (stable under score ties;
            # distinct = distinct lowered tables, not decision vectors)
            pool.sort(key=lambda sp: sp[0])
            seen, kept = set(), []
            for d, p in pool:
                k = _canonical_key(p)
                if k not in seen:
                    seen.add(k)
                    kept.append((d, p))
                if len(kept) == cfg.beam:
                    break
            beam = kept
            if evals_left <= 0:
                break
        if evals_left <= 0 or not improved:
            break
    best_score, best = beam[0]
    best = best.with_meta(objective=best_score, baseline=d0,
                          evals=cfg.budget - evals_left,
                          beam=cfg.beam, rounds=cfg.rounds)
    return SearchResult(plan=best, score=best_score, baseline=d0,
                        evals=cfg.budget - evals_left, history=history)


@dataclass
class CachedSearchResult:
    """A jointly tuned (solver schedule, cache schedule) plan plus the
    no-cache anchor it is constrained against."""

    plan: SolverPlan            # the cached winner (cache_depth set)
    score: float                # its trajectory discrepancy
    uncached_plan: SolverPlan   # the phase-1 winner with every eval full
    uncached_score: float       # the no-cache tuned discrepancy (the anchor)
    evals: int
    history: List[Tuple[float, str]] = field(default_factory=list)


def tune_cached_plan(objective: PlanObjective, noise_schedule,
                     init: SolverPlan, config: Optional[SearchConfig] = None,
                     *, cache_block: int, slack: float = 1.1,
                     verbose: bool = False) -> CachedSearchResult:
    """Joint solver + cache-schedule search (DESIGN.md §12).

    The cache axis cannot ride the plain score-descent acceptance rule:
    a shallow eval never *improves* trajectory discrepancy, it buys eval
    cost — so pure descent would keep (or revert to) the all-full schedule.
    The search therefore runs the cache coordinate under a constrained
    acceptance: flips to shallow are kept while the score stays within
    `slack` x the no-cache tuned anchor, and each round keeps the flip that
    degrades the score least (greedy coordinate descent on the cache mask).

    Phases, all through the one jitted cached runner in `objective`:
      1. `tune_plan` over the solver axes with an all-full cache column —
         the no-cache anchor the acceptance constraint (and `guard.py`'s
         1.1x gate) measures against.
      2. Greedy shallow flips at boundary `cache_block` under the slack
         constraint, until no step can be flipped without breaching it.
      3. A final solver-axis sweep from the cached plan (`rounds=1`): the
         solver schedule re-adapts to the cheaper eval trace. Scores never
         regress in `tune_plan`, so the constraint survives phase 3.

    `objective` must wrap a cache-wired engine (`make_objective` over a
    `build_engine(cache_block=...)` engine); `init` is the usual hand-set
    baseline plan.
    """
    if cache_block < 1:
        raise ValueError(f"tune_cached_plan needs cache_block >= 1, "
                         f"got {cache_block}")
    if not objective.cached:
        raise ValueError("objective is not cache-wired; build it from an "
                         "engine constructed with build_engine(cache_block=...)")
    cfg = config or SearchConfig()
    M = init.nfe
    # phase 1 — solver axes, all evals full. The zero cache column keeps
    # every candidate on the cached runner's jit signature.
    base = tune_plan(objective, noise_schedule,
                     replace(init, cache_depth=[0] * M), cfg, verbose=verbose)
    anchor_plan, anchor = base.plan, base.score
    plan, score, evals = anchor_plan, anchor, base.evals
    history = list(base.history)
    # phase 2 — greedy constrained flips on the cache mask
    while True:
        best_flip = None
        for i in range(M):
            if plan.cache_depth[i]:
                continue
            cd = list(plan.cache_depth)
            cd[i] = cache_block
            d = objective(replace(plan, cache_depth=cd), noise_schedule)
            evals += 1
            if d <= slack * anchor and (best_flip is None
                                        or d < best_flip[0]):
                best_flip = (d, i, cd)
        if best_flip is None:
            break
        score, i, cd = best_flip
        plan = replace(plan, cache_depth=cd)
        history.append((score, f"cache[{i}]={cache_block}"))
        if verbose:
            print(f"  cache[{i}]={cache_block}: {score:.5f} "
                  f"(anchor {anchor:.5f}, slack {slack})")
    # phase 3 — let the solver schedule re-adapt to the cache schedule
    if any(plan.cache_depth):
        polish = tune_plan(objective, noise_schedule, plan,
                           replace(cfg, rounds=1), verbose=verbose)
        plan, score = polish.plan, polish.score
        evals += polish.evals
        history += polish.history[1:]
    plan = plan.with_meta(objective=score, cache_anchor=anchor,
                          cache_block=cache_block, cache_slack=slack,
                          evals=evals)
    return CachedSearchResult(plan=plan, score=score,
                              uncached_plan=anchor_plan,
                              uncached_score=anchor, evals=evals,
                              history=history)
