"""Solver plans: the searchable per-step decision vector.

A `SolverPlan` pins every choice the paper fixes by hand at a given NFE
budget — where each timestep lands, the UniP order used at each step,
whether the UniC corrector runs, and which B(h) variant builds the weights —
as plain data. Lowering a plan reuses the exact machinery hand-set UniPC
tables lower through (`core.coeffs.build_unipc_schedule` with per-step
order / variant / corrector schedules), so a tuned plan is *just a better
weight table*: the fused scan, the per-slot step function, and the serving
scheduler all execute it unchanged.

Timestep placement is parametrized in normalized log-SNR coordinates:
`knots` are the M-1 interior grid positions u_i in (0, 1), strictly
increasing, with lambda_i = lam_T + u_i (lam_eps - lam_T). Uniform knots
reproduce the 'logsnr' spacing exactly, so the default plan for an
`EngineSpec` compiles bit-identically to the registry's UniPC table — the
search starts from the paper's baseline, not beside it.

Plans (and tier-keyed *banks* of plans) serialize to JSON. Floats round-trip
exactly through `json` (repr-based), so load(save(plan)) compiles to a
bit-identical table — pinned by `tests/test_tuning.py`.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional

import numpy as np

from ..core.coeffs import (BH_VARIANTS, PREDICTION_TYPES, SolverTable,
                           build_unipc_schedule, default_order_schedule)

PLAN_KIND = "solver-plan"
BANK_KIND = "plan-bank"
SEARCH_VARIANTS = ("bh1", "bh2")   # the searchable B(h) choices (Table 1)
MAX_ORDER = 3


@dataclass
class SolverPlan:
    """Per-step decision vector for one NFE budget.

    nfe: M steps (M+1 grid points, M+1 model evals through the scan).
    knots: (M-1,) interior grid positions in (0,1), strictly increasing.
    orders: (M,) UniP order per step (warm-up clamp min(p_i, i) applies at
        lowering, as everywhere else).
    corrector: (M,) UniC on/off per step.
    variants: (M,) B(h) variant per step.
    cache_depth: optional (M,) feature-reuse depth per step (DESIGN.md §12):
        0 = full eval, k > 0 = shallow eval recomputing only the first k DiT
        blocks and reusing the cached deep features. The cache boundary is
        static in the compiled program, so every nonzero entry must be the
        same k (`cache_block`). None = the plan has no cache axis at all and
        serves on uncached engines unchanged.
    meta: provenance — search budget, objective values, arch, reference NFE.
    """

    nfe: int
    knots: List[float]
    orders: List[int]
    corrector: List[bool]
    variants: List[str]
    prediction: str = "data"
    cache_depth: Optional[List[int]] = None
    meta: dict = field(default_factory=dict)

    def __post_init__(self):
        self.validate()

    def validate(self) -> "SolverPlan":
        M = self.nfe
        if M < 1:
            raise ValueError(f"plan needs nfe >= 1, got {M}")
        if self.prediction not in PREDICTION_TYPES:
            raise ValueError(f"unknown prediction {self.prediction!r}")
        if len(self.knots) != M - 1:
            raise ValueError(f"plan nfe={M} needs {M - 1} knots, "
                             f"got {len(self.knots)}")
        u = np.asarray(self.knots, np.float64)
        if len(u) and not (np.all(np.diff(np.concatenate([[0.0], u, [1.0]]))
                                  > 0)):
            raise ValueError("knots must be strictly increasing in (0, 1)")
        for name, seq in (("orders", self.orders),
                          ("corrector", self.corrector),
                          ("variants", self.variants)):
            if len(seq) != M:
                raise ValueError(f"plan nfe={M} needs {M} {name}, "
                                 f"got {len(seq)}")
        if not all(1 <= o <= MAX_ORDER for o in self.orders):
            raise ValueError(f"orders must be in 1..{MAX_ORDER}, "
                             f"got {self.orders}")
        if not all(v in BH_VARIANTS for v in self.variants):
            raise ValueError(f"variants must be in {BH_VARIANTS}, "
                             f"got {self.variants}")
        if self.cache_depth is not None:
            if len(self.cache_depth) != M:
                raise ValueError(f"plan nfe={M} needs {M} cache_depth "
                                 f"entries, got {len(self.cache_depth)}")
            if not all(int(d) >= 0 for d in self.cache_depth):
                raise ValueError(f"cache_depth entries must be >= 0, "
                                 f"got {self.cache_depth}")
            ks = {int(d) for d in self.cache_depth if d}
            if len(ks) > 1:
                raise ValueError(
                    f"the cache boundary is static in the compiled program: "
                    f"all nonzero cache_depth entries must share one k, "
                    f"got {sorted(ks)}")
        return self

    @property
    def cache_block(self) -> int:
        """The plan's static cache boundary (0 = no shallow steps)."""
        if not self.cache_depth:
            return 0
        return max(int(d) for d in self.cache_depth)

    # -- lowering ------------------------------------------------------------
    def grid(self, noise_schedule):
        """(t, lam, alpha, sigma) arrays for this plan's knot placement."""
        lam_T = float(noise_schedule.lam(noise_schedule.T))
        lam_0 = float(noise_schedule.lam(noise_schedule.t_eps))
        u = np.concatenate([[0.0], np.asarray(self.knots, np.float64), [1.0]])
        lams = lam_T + u * (lam_0 - lam_T)
        ts = noise_schedule.t_of_lam(lams)
        ts = np.asarray(ts, np.float64)
        # recompute lambda from t so the table's grid is self-consistent with
        # the schedule's own lam(t) (exactly as timestep_grid does)
        lams = noise_schedule.lam(ts)
        return ts, lams, noise_schedule.alpha(ts), noise_schedule.sigma(ts)

    def compile(self, noise_schedule) -> SolverTable:
        """Lower the plan to the solver-agnostic weight table.

        The table width is padded to MAX_ORDER-1 difference columns no matter
        the plan's own max order, so every candidate a search proposes shares
        one shape signature — the tuner's jitted runner never recompiles, and
        stacked plan banks need no per-tier padding.
        """
        t, lam, alpha, sigma = self.grid(noise_schedule)
        tab = build_unipc_schedule(
            lambdas=lam, alphas=alpha, sigmas=sigma, timesteps=t,
            order=MAX_ORDER, prediction=self.prediction,
            variant=self.variants[0],
            order_schedule=[min(o, MAX_ORDER) for o in self.orders],
            variant_schedule=list(self.variants),
            corrector_schedule=[bool(c) for c in self.corrector],
        )
        if self.cache_depth is not None:
            # the per-eval reuse flag as a model column: row 0 (the init
            # eval) is always full — it seeds the cache — followed by one
            # 0/1 per body step. Attached even when every step is full so a
            # candidate's jit signature is stable across a cache search.
            tab.model_cols = dict(tab.model_cols or {})
            tab.model_cols["cache_reuse"] = np.asarray(
                [0.0] + [1.0 if d else 0.0 for d in self.cache_depth],
                np.float64)
        return tab

    def eval_cost(self, n_blocks: int) -> float:
        """Evals-per-latent: total model-eval cost of the plan's M+1 evals in
        full-eval units, counting each shallow step as cache_block/n_blocks
        (`core.coeffs.eval_cost_rows` over the lowered table agrees)."""
        full = self.nfe + 1
        if not self.cache_depth or not n_blocks:
            return float(full)
        shallow = sum(1 for d in self.cache_depth if d)
        return float(full - shallow * (1.0 - self.cache_block / n_blocks))

    # -- construction --------------------------------------------------------
    @staticmethod
    def default(nfe: int, *, order: int = 3, prediction: str = "data",
                variant: str = "bh2", use_corrector: bool = True,
                corrector_at_last: bool = False,
                lower_order_final: bool = True) -> "SolverPlan":
        """The hand-set UniPC-`order` policy as a plan: uniform log-SNR
        knots, the paper's warm-up order schedule, corrector on every step
        but the last. Compiles to the same table `EngineSpec(solver="unipc")`
        does (modulo the fixed MAX_ORDER column padding)."""
        M = nfe
        u = (np.arange(1, M, dtype=np.float64) / M).tolist()
        orders = default_order_schedule(M, order, lower_order_final)
        corr = [use_corrector and (corrector_at_last or i < M)
                for i in range(1, M + 1)]
        return SolverPlan(nfe=M, knots=u, orders=list(orders), corrector=corr,
                          variants=[variant] * M, prediction=prediction)

    @staticmethod
    def from_spec(spec) -> "SolverPlan":
        """Default plan matching a resolved unipc `EngineSpec`."""
        spec = spec.resolve()
        if spec.solver != "unipc":
            raise ValueError(f"plans parametrize the unipc decision space; "
                             f"got solver={spec.solver!r}")
        return SolverPlan.default(
            spec.nfe, order=spec.order, prediction=spec.prediction,
            variant=spec.variant, use_corrector=spec.use_corrector,
            corrector_at_last=spec.corrector_at_last,
            lower_order_final=spec.lower_order_final)

    # -- (de)serialization ---------------------------------------------------
    def to_dict(self) -> dict:
        d = {"kind": PLAN_KIND, "version": 1, "nfe": self.nfe,
             "prediction": self.prediction,
             "knots": [float(u) for u in self.knots],
             "orders": [int(o) for o in self.orders],
             "corrector": [bool(c) for c in self.corrector],
             "variants": list(self.variants), "meta": dict(self.meta)}
        if self.cache_depth is not None:
            d["cache_depth"] = [int(c) for c in self.cache_depth]
        return d

    @staticmethod
    def from_dict(d: dict) -> "SolverPlan":
        if d.get("kind") != PLAN_KIND:
            raise ValueError(f"not a solver plan: kind={d.get('kind')!r}")
        cd = d.get("cache_depth")
        return SolverPlan(nfe=int(d["nfe"]), knots=list(d["knots"]),
                          orders=list(d["orders"]),
                          corrector=list(d["corrector"]),
                          variants=list(d["variants"]),
                          prediction=d.get("prediction", "data"),
                          cache_depth=None if cd is None else list(cd),
                          meta=dict(d.get("meta", {})))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1)

    @staticmethod
    def load(path: str) -> "SolverPlan":
        with open(path) as f:
            return SolverPlan.from_dict(json.load(f))

    def with_meta(self, **kw) -> "SolverPlan":
        return replace(self, meta={**self.meta, **kw})


# -- plan banks --------------------------------------------------------------


def save_bank(path: str, plans: Dict[str, SolverPlan]) -> None:
    """Serialize a tier-keyed bank of plans ({'fast': plan, ...})."""
    with open(path, "w") as f:
        json.dump({"kind": BANK_KIND, "version": 1,
                   "tiers": {k: p.to_dict() for k, p in plans.items()}},
                  f, indent=1)


def load_bank(path: str) -> Dict[str, SolverPlan]:
    with open(path) as f:
        d = json.load(f)
    if d.get("kind") != BANK_KIND:
        raise ValueError(f"not a plan bank: kind={d.get('kind')!r} "
                         f"(expected {BANK_KIND!r})")
    return {k: SolverPlan.from_dict(v) for k, v in d["tiers"].items()}
