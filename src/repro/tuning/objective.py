"""Plan scoring: trajectory discrepancy against a high-NFE reference run.

No FID model fits in this container (and none is needed to *rank* plans):
following the paper's own Fig. 4c protocol — and the solver-search line of
work (Liu et al. 2023; DC-Solver) — a candidate plan is scored by how close
its terminal state lands to a fine-grid reference trajectory started from
the same probe latents, through the same network:

    d(plan) = || x0_plan - x0_ref ||_2 / || x0_ref ||_2

over a fixed probe batch. Lower is better; orderings track the paper's FID
orderings at matched NFE.

The scorer is built for search throughput: candidate tables share one shape
signature (plans pad their weight columns to MAX_ORDER-1), so the whole
trajectory run jits ONCE with the row table as a *traced argument*
(`core.step_fn_over_rows`) — scoring a new candidate is a re-execution of
the compiled program with new weights, never a recompile.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..core.coeffs import SolverTable, augment_step_rows
from ..core.unipc import step_fn_over_rows
from .plans import SolverPlan


@dataclass
class PlanObjective:
    """Callable plan -> discrepancy, over one model and probe batch.

    model_fn: the engine-wrapped model ((x, t, **cols) -> prediction of the
        plan's type) — `SamplerEngine.model_fn(spec, tab)` or any (x, t)
        callable for analytic DPMs.
    x_T: (B, *sample) probe latents (fixed across candidates).
    x_ref: (B, *sample) reference terminal states for the same latents.
    sign/prediction: the plan family's table convention (data-pred unipc by
        default).
    """

    model_fn: Callable
    x_T: jnp.ndarray
    x_ref: np.ndarray
    sign: float = 1.0
    prediction: str = "data"
    fused_update: bool = True
    # feature reuse: a cached engine's model_fn returns (pred, cache) and the
    # runner's carry grows the (B, *cache_shape) cache state — candidate
    # plans may then schedule shallow steps via their cache_reuse column
    cached: bool = False
    cache_shape: Optional[tuple] = None
    # ONE jitted runner serves every candidate: the row table is a traced
    # argument, so jit's own cache keys on row *shapes* (one entry per NFE,
    # since plans pad their weight columns to a fixed width)
    _runner: Optional[Callable] = None
    evals: int = 0

    def score_table(self, tab: SolverTable) -> float:
        rows = {k: jnp.asarray(v, jnp.float32)
                for k, v in augment_step_rows(tab).items()}
        if self._runner is None:
            self._runner = self._make_runner()
        x0 = np.asarray(self._runner(self.x_T, rows))
        self.evals += 1
        return float(np.linalg.norm(x0 - self.x_ref)
                     / max(np.linalg.norm(self.x_ref), 1e-12))

    def __call__(self, plan: SolverPlan, noise_schedule) -> float:
        if plan.prediction != self.prediction:
            raise ValueError(
                f"objective wraps a {self.prediction}-prediction model; "
                f"plan is {plan.prediction}-prediction")
        return self.score_table(plan.compile(noise_schedule))

    def _make_runner(self) -> Callable:
        model_fn, sign, fused = self.model_fn, self.sign, self.fused_update
        cached, cache_shape = self.cached, self.cache_shape

        def run(x_T, rows):
            step = step_fn_over_rows(model_fn, rows, sign=sign,
                                     fused_update=fused, cached=cached)
            K = rows["w_pred"].shape[-1]
            n_rows = rows["t"].shape[0]
            E0 = jnp.zeros((K + 1,) + x_T.shape, x_T.dtype)
            carry0 = (x_T, E0)
            if cached:
                carry0 = carry0 + (jnp.zeros(
                    (x_T.shape[0],) + tuple(cache_shape), x_T.dtype),)
            carry, _ = jax.lax.scan(lambda c, j: (step(c, j), None),
                                    carry0, jnp.arange(n_rows))
            return carry[0]

        return jax.jit(run)


class QuantParityError(RuntimeError):
    """A tuned quantized plan failed its parity budget (DESIGN.md §14).

    Raised by `quant_parity_gate` when the tuned plan's trajectory
    discrepancy — measured against the *fp32* reference trajectory — exceeds
    `slack` times what the fp32 hand-set baseline achieves at the same NFE
    budget. The tier is over-quantized for this arch/budget; the plan must
    not be emitted."""


def quant_parity_gate(tuned: float, fp32_anchor: float, *, slack: float,
                      quant: str, context: str = "") -> float:
    """Enforce the quantized tier's parity budget; returns the ratio.

    `tuned` is the tuned quantized plan's discrepancy vs the fp32
    reference; `fp32_anchor` is the fp32 baseline plan's discrepancy vs the
    same reference (same probe latents, same budget). Both are measured
    against the SAME x_ref, so the ratio isolates what quantization costs
    on top of the solver's own truncation error."""
    where = f" ({context})" if context else ""
    ratio = tuned / max(fp32_anchor, 1e-12)
    if ratio > slack:
        raise QuantParityError(
            f"quant tier {quant!r} failed its parity gate{where}: tuned "
            f"discrepancy {tuned:.6f} is {ratio:.2f}x the fp32 baseline "
            f"{fp32_anchor:.6f} (budget {slack}x) — the tier is "
            f"over-quantized for this arch/budget; not emitting the plan")
    return ratio


def reference_trajectory(engine, spec, x_T, *, ref_nfe: int = 64,
                         ref_order: int = 3) -> np.ndarray:
    """Terminal states of the high-NFE UniPC-`ref_order` reference run from
    `x_T` — the converged trajectory candidates are measured against. It
    depends only on (engine, x_T, ref_nfe, ref_order), so callers tuning
    several NFE budgets compute it once and pass it to `make_objective`."""
    from dataclasses import replace

    ref_spec = replace(spec.resolve(), solver="unipc", nfe=ref_nfe,
                       order=ref_order, prediction=None).resolve()
    return np.asarray(engine.build(ref_spec)(jnp.asarray(x_T, jnp.float32)))


def make_objective(engine, spec, x_T, *, ref_nfe: int = 64,
                   ref_order: int = 3,
                   x_ref: Optional[np.ndarray] = None) -> PlanObjective:
    """Build a PlanObjective over a `SamplerEngine`.

    The reference is the engine's own scan path at `ref_nfe` UniPC-`ref_order`
    steps (same network, same conditioning knobs as `spec`), computed here
    unless a precomputed `x_ref` (see `reference_trajectory`) is supplied.
    `spec` supplies the prediction type and model wrapping; its nfe/order are
    irrelevant here.
    """
    spec = spec.resolve()
    if spec.cfg_scale or spec.thresholding:
        # candidate plan tables carry no per-eval model columns; guided /
        # thresholded tuning would score a different program than it serves
        raise ValueError("plan tuning scores unconditional trajectories; "
                         "tune with cfg_scale=0 and thresholding off")
    x_T = jnp.asarray(x_T, jnp.float32)
    if x_ref is None:
        x_ref = reference_trajectory(engine, spec, x_T, ref_nfe=ref_nfe,
                                     ref_order=ref_order)
    tab = engine.compile(spec)
    model = engine.model_fn(spec, tab)
    cached = bool(spec.cache_block)
    return PlanObjective(model_fn=model, x_T=x_T, x_ref=np.asarray(x_ref),
                         sign=float(tab.sign), prediction=tab.prediction,
                         fused_update=spec.fused_update, cached=cached,
                         cache_shape=(tuple(engine.cache_spec.shape)
                                      if cached else None))
