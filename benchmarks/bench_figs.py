"""Figure benchmarks: Fig. 3 (unconditional, three settings, NFE 5-10) and
Fig. 4 (guided sampling with classifier-free guidance at s = 1.5/4/8, using
the paper's own convergence-error-to-999-step-DDIM metric)."""

from __future__ import annotations

import numpy as np

from .common import (SETTINGS, conv_err, emit, reference_x0, setting_model,
                     timed, x_T_for)
from repro.core import DDIM, DPMSolverPP, Grid, UniPC
from repro.diffusion import MixtureDPM


def _data_model(schedule, eps):
    def f(x, t):
        a, s = float(schedule.alpha(t)), float(schedule.sigma(t))
        return (np.asarray(x, np.float64) - s * eps(x, t)) / a
    return f


def fig3_unconditional():
    for setting in SETTINGS:
        sched, eps = setting_model(setting)
        x_T = x_T_for(30)
        ref = reference_x0(eps, sched, x_T)
        dm = _data_model(sched, eps)
        for nfe in range(5, 11):
            for name, run in {
                "ddim": lambda g: DDIM(eps, g, prediction="noise").sample(x_T),
                "dpmpp3m": lambda g: DPMSolverPP(dm, g, order=3).sample(x_T),
                "unipc3": lambda g: UniPC(dm, g, order=3, prediction="data")
                    .sample_pc(x_T, use_corrector=True),
            }.items():
                g = Grid.build(sched, nfe)
                x0, us = timed(lambda run=run, g=g: run(g))
                emit(f"fig3/{setting}/{name}/nfe{nfe}", us,
                     f"{conv_err(x0, ref)*1e3:.3f}")


def fig4_guided():
    """CFG: eps_guided = (1+s) eps_cond - s eps_uncond; conditional model =
    mixture component 0; unconditional = full mixture."""
    sched, _ = setting_model("cifar10")
    mix = SETTINGS["cifar10"][1]
    eps_c = mix.component_eps_model(0)
    eps_u = mix.eps_model
    for scale in (1.5, 4.0, 8.0):
        def eps_g(x, t, s=scale):
            return (1 + s) * eps_c(x, t) - s * eps_u(x, t)

        x_T = x_T_for(40)
        ref = reference_x0(eps_g, sched, x_T)
        dm = _data_model(sched, eps_g)
        for nfe in range(5, 11):
            for name, run in {
                "ddim": lambda g: DDIM(eps_g, g, prediction="noise").sample(x_T),
                "dpmpp2m": lambda g: DPMSolverPP(dm, g, order=2).sample(x_T),
                "unipc2-bh2": lambda g: UniPC(dm, g, order=2,
                                              prediction="data", variant="bh2")
                    .sample_pc(x_T, use_corrector=True),
                "unipc2-bh1": lambda g: UniPC(dm, g, order=2,
                                              prediction="data", variant="bh1")
                    .sample_pc(x_T, use_corrector=True),
            }.items():
                g = Grid.build(sched, nfe)
                x0, us = timed(lambda run=run, g=g: run(g))
                emit(f"fig4/s{scale}/{name}/nfe{nfe}", us,
                     f"{conv_err(x0, ref)*1e3:.3f}")


def free_oracle_study():
    """Beyond-paper (paper §4.2 future work): free secant-based estimate of
    eps(x_c) vs plain UniC vs the (extra-NFE) oracle."""
    from repro.core import DPMSolverPP
    from repro.core.solver import CorrectorConfig
    from repro.core.solver import Grid as _G

    sched, eps = setting_model("cifar10")
    x_T = x_T_for(50)
    ref = reference_x0(eps, sched, x_T)
    dm = _data_model(sched, eps)
    for nfe in (8, 10, 16):
        for mode, kw in {"plain": {}, "free-g0.5": dict(free_oracle=0.5),
                         "free-g1.0": dict(free_oracle=1.0),
                         "oracle": dict(oracle=True)}.items():
            s = DPMSolverPP(dm, Grid.build(sched, nfe), order=3)
            x0, us = timed(lambda s=s, kw=kw: s.sample(
                x_T, corrector=CorrectorConfig(order=3, variant="bh2", **kw)))
            emit(f"free_oracle/{mode}/nfe{nfe}", us,
                 f"{conv_err(x0, ref)*1e3:.3f}")
