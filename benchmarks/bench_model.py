"""Denoiser fast-eval benchmark -> BENCH_model.json (DESIGN.md §11).

PRs 1-4 made the solver side of a serving tick microseconds; what remains is
NFE x denoiser-eval cost. This bench measures the per-eval wall clock and
the trip-scaled HLO HBM bytes of the DiT eps-network at the serving shapes
(SLOTS latents of the §6 workloads), across the eval paths:

* ``eager``       — the pre-fast-eval path, preserved here as the baseline:
                    seq-major einsum sdpa (materializing the S^2 logits
                    tensor) + the inline unfused adaLN chain. Whole eval
                    jitted, like it shipped.
* ``flash``       — kernels/flash_attention wired into the attention
                    (platform dispatch: Pallas on TPU, the head-major jnp
                    oracle elsewhere), adaLN still inline.
* ``flash_fused`` — flash + kernels/adaln_modulate: the shipped fast-eval
                    path (`models.dit.dit_apply` as of this PR).
* ``flash_fused_bf16`` — the same with the opt-in bf16 serving eval
                    (params-at-use + activations bf16, fp32 boundary).

Plus one ``unipc_combine`` row per arch: the fused solver update at the same
slot shapes — the "where does a tick go" denominator §11 quotes. The guard
(`benchmarks/guard.py`) enforces flash_fused < eager wall-clock at dit-i256.

``--smoke`` (CI) swaps the kernel backends to interpret mode at tiny shapes
and asserts parity against the eager path instead of timing — the real
kernel code runs on the CPU runner, fast.
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from .common import bench_header, emit

ARCHS = ("dit-cifar", "dit-i256")
SLOTS = 4
COMBINE_K = 5  # order-3 UniC combine width, the widest default
# quantized tiers benched against the shipped fp32 fast path (DESIGN.md §14)
QUANT_BENCH_MODES = ("w8a16", "w8a8")


def _setup(arch: str, seed: int = 0, **cfg_overrides):
    from repro.configs.registry import get_config
    from repro.models import api

    cfg = get_config(arch).reduced(**cfg_overrides)
    params = api.init_params(cfg, jax.random.PRNGKey(seed))
    B, T, L = SLOTS, cfg.patch_tokens, cfg.latent_dim
    x = jax.random.normal(jax.random.PRNGKey(1), (B, T, L), jnp.float32)
    t = jnp.full((B,), 0.5, jnp.float32)
    ids = jnp.zeros((B,), jnp.int32)
    return cfg, params, x, t, ids


def _eval_variant(cfg, params, attention: str, adaln: str):
    """(x, t, ids) -> eps-hat for one eval-path variant.

    attention: 'sdpa' pins the pre-PR seq-major einsum path; anything else is
    a kernels/flash_attention backend (None = platform dispatch).
    adaln: 'inline' pins the pre-PR unfused chain; anything else is a
    kernels/adaln_modulate backend. The non-inline variants just run the
    shipped `dit_apply` with the config's backend knobs — this function
    re-creates the *old* code path only where a baseline needs pinning.
    """
    from repro.models.api import eps_network
    from repro.models.dit import timestep_embedding
    from repro.models.layers import layernorm, sdpa, _proj_qkv

    if attention != "sdpa" and adaln != "inline":
        cfg = dataclasses.replace(cfg, attention_backend=attention,
                                  adaln_backend=adaln)
        net = eps_network(cfg)
        return lambda x, t, ids: net(params, x, t, {"class_ids": ids})

    from repro.kernels.flash_attention import ops as fa_ops

    bk = params["backbone"]

    def attn(bp, hn):
        q, k, v = _proj_qkv(bp["attn"], hn, hn, cfg)
        if attention == "sdpa":
            out = sdpa(q, k, v, causal=False)
        else:
            out = fa_ops.attention(
                q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                v.transpose(0, 2, 1, 3), causal=False,
                backend=attention).transpose(0, 2, 1, 3)
        B, S = hn.shape[:2]
        out = out.reshape(B, S, cfg.num_heads * cfg.head_dim)
        return jnp.einsum("bse,ed->bsd", out,
                          bp["attn"]["wo"].astype(hn.dtype))

    def f(x_t, t, class_ids):
        # the pre-fast-eval dit_apply body, inline adaLN chain and all
        B = x_t.shape[0]
        t = jnp.broadcast_to(jnp.asarray(t, jnp.float32), (B,))
        x = jnp.einsum("btl,ld->btd", x_t.astype(cfg.activation_dtype),
                       bk["in_proj"].astype(cfg.activation_dtype))
        c = jax.nn.silu(jnp.einsum("bf,fd->bd", timestep_embedding(t, 256),
                                   bk["t_mlp1"].astype(jnp.float32)))
        c = jnp.einsum("bd,de->be", c, bk["t_mlp2"].astype(jnp.float32))
        if "class_embed" in bk:
            c = c + bk["class_embed"].astype(jnp.float32)[class_ids]
        c = jax.nn.silu(c).astype(x.dtype)

        def body(h, bp):
            mod = (jnp.einsum("bd,de->be", c, bp["ada"].astype(h.dtype))
                   + bp["ada_b"].astype(h.dtype))
            sh1, sc1, g1, sh2, sc2, g2 = jnp.split(mod, 6, axis=-1)
            hn = layernorm({}, h) * (1 + sc1[:, None]) + sh1[:, None]
            h = h + g1[:, None] * attn(bp, hn)
            hn = layernorm({}, h) * (1 + sc2[:, None]) + sh2[:, None]
            y = jnp.einsum("btd,df->btf", hn, bp["w1"].astype(h.dtype))
            y = jnp.einsum("btf,fd->btd", jax.nn.gelu(y),
                           bp["w2"].astype(h.dtype))
            return h + g2[:, None] * y, None

        x, _ = jax.lax.scan(body, x, bk["blocks"])
        mod = (jnp.einsum("bd,de->be", c, bk["final_ada"].astype(x.dtype))
               + bk["final_ada_b"].astype(x.dtype))
        sh, sc = jnp.split(mod, 2, axis=-1)
        x = layernorm({}, x) * (1 + sc[:, None]) + sh[:, None]
        return jnp.einsum("btd,dl->btl", x, bk["out_proj"].astype(x.dtype))

    return f


MODES = {
    # mode -> (attention, adaln) pins for _eval_variant
    "eager": ("sdpa", "inline"),
    "flash": (None, "inline"),
    "flash_fused": (None, None),
}


def _median_us(fn, repeat=30):
    """Median wall per call — medians, not best-of-N: eval times at these
    shapes sit in the ms range where best-of-few is all scheduler noise."""
    import time

    fn()  # warm
    walls = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        walls.append(time.perf_counter() - t0)
    return float(np.median(walls)) * 1e6


def _interleaved_us(fns: dict, repeat=40):
    """{name: fn} -> {name: median us}, with the repetitions *interleaved*
    round-robin across the variants: background load on a shared machine
    comes in bursts longer than one call, so timing modes consecutively
    biases whichever mode drew the noisy window — interleaving spreads a
    burst over every mode and keeps the ratios honest."""
    import time

    for fn in fns.values():
        fn()  # warm everything first
    walls = {k: [] for k in fns}
    for _ in range(repeat):
        for k, fn in fns.items():
            t0 = time.perf_counter()
            fn()
            walls[k].append(time.perf_counter() - t0)
    return {k: float(np.median(v)) * 1e6 for k, v in walls.items()}


def _hbm_bytes(fn, x, t, ids):
    from repro.analysis.hlo import analyze

    comp = jax.jit(fn).lower(x, t, ids).compile()
    return analyze(comp.as_text(), 1)["hbm_bytes"]


def _combine_us(sample_shape):
    from repro.kernels.unipc_update import ops as uops

    terms = jax.random.normal(jax.random.PRNGKey(2),
                              (COMBINE_K, SLOTS) + sample_shape, jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(3), (COMBINE_K,), jnp.float32)
    f = jax.jit(uops.weighted_combine)
    return _median_us(lambda: jax.block_until_ready(f(terms, w)))


def _attn_traffic(cfg):
    """Structural HBM row: the measured bytes of one seq-major sdpa call at
    the arch's attention shape (S^2 logits materialized — what the jnp
    fallback also does, so the whole-eval HLO rows above can't show the
    difference) vs the flash kernel's blockwise single-pass model (read
    q/k/v once, write o once). The TPU-side win the Pallas path pins."""
    from repro.analysis.hlo import analyze
    from repro.models.layers import sdpa

    B, S = SLOTS, cfg.patch_tokens
    H, D = cfg.num_heads, cfg.head_dim
    q = jax.ShapeDtypeStruct((B, S, H, D), jnp.float32)
    comp = jax.jit(lambda q: sdpa(q, q, q, causal=False)).lower(q).compile()
    naive = analyze(comp.as_text(), 1)["hbm_bytes"]
    flash = 4 * B * S * H * D * 4
    return naive, flash


def _quant_variant(cfg, params, mode: str):
    """(eval_fn, param_bytes) for one calibrated quant tier (DESIGN.md §14)."""
    from repro.models import api
    from repro.models.quant import quant_param_bytes

    qcfg, qparams, _ = api.calibrate_and_quantize(cfg, params, mode)
    net = api.eps_network(qcfg)
    fn = lambda x, t, ids: net(qparams, x, t, {"class_ids": ids})  # noqa: E731
    return fn, quant_param_bytes(qparams)


def bench_model(out_path: str = "BENCH_model.json"):
    """Eval-path wall clock + HBM bytes at both dit serving shapes."""
    rows, qrows = [], []
    for arch in ARCHS:
        cfg, params, x, t, ids = _setup(arch)
        variants, hbm = {}, {}
        for mode, (attention, adaln) in MODES.items():
            fn = _eval_variant(cfg, params, attention, adaln)
            variants[mode] = fn
            hbm[mode] = _hbm_bytes(fn, x, t, ids)
        # opt-in bf16 serving eval: params-at-use + activations bf16,
        # cast by the same helper build_engine ships with
        from repro.models.api import cast_params_for_eval

        bcfg = dataclasses.replace(cfg, dtype="bfloat16")
        bparams = cast_params_for_eval(params, "bfloat16")
        bfn = _eval_variant(bcfg, bparams, None, None)
        variants["flash_fused_bf16"] = (
            lambda x, t, ids, f=bfn: f(x, t, ids).astype(jnp.float32))
        hbm["flash_fused_bf16"] = _hbm_bytes(variants["flash_fused_bf16"],
                                             x, t, ids)
        jitted = {m: jax.jit(f) for m, f in variants.items()}
        us = _interleaved_us(
            {m: (lambda f=f: jax.block_until_ready(f(x, t, ids)))
             for m, f in jitted.items()})
        for mode in variants:
            row = dict(arch=arch, mode=mode, eval_us=us[mode],
                       hbm_bytes=hbm[mode],
                       speedup_vs_eager=us["eager"] / us[mode])
            if mode == "flash_fused_bf16" and row["speedup_vs_eager"] < 1.0:
                # measured 0.67x at dit-cifar on the cpu runner: XLA
                # rematerializes the bf16 casts in fp32 arithmetic, so the
                # halved HBM reads never pay off. The hbm_bytes column is
                # what the mode buys on a bandwidth-bound accelerator; the
                # guard enforces the wall-clock win on tpu/gpu only.
                row["note"] = ("loses wall-clock on this backend (cast "
                               "remat); hbm_bytes is the accelerator story")
            rows.append(row)
            emit(f"model/{arch}/{mode}", us[mode],
                 f"hbm_bytes={hbm[mode]:.3e};"
                 f"speedup={us['eager']/us[mode]:.2f}")
        # quantized denoiser tiers (DESIGN.md §14), timed interleaved with
        # the shipped fp32 fast path so the speedup_vs_fp32 ratios are honest
        qfns = {"fp32": variants["flash_fused"]}
        qmeta = {}
        for qmode in QUANT_BENCH_MODES:
            qfns[qmode], qmeta[qmode] = _quant_variant(cfg, params, qmode)
        qjit = {m: jax.jit(f) for m, f in qfns.items()}
        qus = _interleaved_us(
            {m: (lambda f=f: jax.block_until_ready(f(x, t, ids)))
             for m, f in qjit.items()})
        for qmode in QUANT_BENCH_MODES:
            qhbm = _hbm_bytes(qfns[qmode], x, t, ids)
            qrows.append(dict(arch=arch, mode=qmode, eval_us=qus[qmode],
                              fp32_eval_us=qus["fp32"], hbm_bytes=qhbm,
                              speedup_vs_fp32=qus["fp32"] / qus[qmode],
                              quant_param_bytes=qmeta[qmode]["quant"],
                              fp32_param_bytes=qmeta[qmode]["fp32"]))
            emit(f"model/{arch}/quant_{qmode}", qus[qmode],
                 f"hbm_bytes={qhbm:.3e};"
                 f"speedup_vs_fp32={qus['fp32']/qus[qmode]:.2f};"
                 f"param_bytes={qmeta[qmode]['quant']}/"
                 f"{qmeta[qmode]['fp32']}")
        # the solver side of the same tick, for the §11 breakdown
        us = _combine_us((cfg.patch_tokens, cfg.latent_dim))
        rows.append(dict(arch=arch, mode="unipc_combine", eval_us=us,
                         hbm_bytes=(COMBINE_K + 1) * SLOTS * cfg.patch_tokens
                         * cfg.latent_dim * 4))
        emit(f"model/{arch}/unipc_combine", us, "solver_side_of_tick")
        naive, flash = _attn_traffic(cfg)
        rows.append(dict(arch=arch, mode="attn_traffic",
                         naive_bytes=naive, flash_model_bytes=flash))
        emit(f"model/{arch}/attn_traffic", 0.0,
             f"naive_bytes={naive:.3e};flash_model={flash:.3e};"
             f"ratio={naive/flash:.1f}")
    with open(out_path, "w") as f:
        json.dump({"slots": SLOTS, "env": bench_header(), "runs": rows,
                   "quant_runs": qrows}, f, indent=1)
    return rows


def _perturb(params, seed: int = 9, scale: float = 0.05):
    """Perturb every float leaf — the adaLN-zero init makes an untrained
    DiT output exactly zero, which would make any parity check vacuous."""
    leaves, treedef = jax.tree.flatten(params)
    ks = jax.random.split(jax.random.PRNGKey(seed), len(leaves))
    return jax.tree.unflatten(treedef, [
        a + scale * jax.random.normal(k, a.shape, a.dtype)
        if jnp.issubdtype(a.dtype, jnp.floating) else a
        for a, k in zip(leaves, ks)])


def smoke():
    """CI: run the real kernels (interpret mode) at tiny shapes and assert
    the fast-eval path matches the eager baseline; no timing."""
    cfg, params, x, t, ids = _setup("dit-cifar", num_layers=2)
    params = _perturb(params)
    eager = jax.jit(_eval_variant(cfg, params, "sdpa", "inline"))
    fast = jax.jit(_eval_variant(cfg, params, "interpret", "interpret"))
    a, b = np.asarray(eager(x, t, ids)), np.asarray(fast(x, t, ids))
    assert np.abs(a).max() > 0, "degenerate eval — parity check is vacuous"
    np.testing.assert_allclose(b, a, rtol=1e-4, atol=1e-4)
    print(f"model smoke ok: interpret-kernel eval matches eager, "
          f"max|diff|={np.abs(a - b).max():.2e}")


def smoke_quant():
    """CI: calibrated W8 eval through the interpret-mode quant_matmul kernel
    on perturbed dit-cifar params (DESIGN.md §14). Asserts (a) the quantized
    eval tracks the fp32 eval within the tier's tolerance, (b) w8a8's
    calibrated activation scales hold too, and (c) quant composes with
    feature reuse: the cache-wired eval with reuse=0 is BITWISE the plain
    quantized eval, and a cached shallow re-eval runs the quantized records
    and stays finite."""
    from repro.models import api

    cfg, params, x, t, ids = _setup("dit-cifar", num_layers=2)
    params = _perturb(params)
    net = api.eps_network(cfg)
    ref = np.asarray(jax.jit(
        lambda x, t: net(params, x, t, {"class_ids": ids}))(x, t))
    assert np.abs(ref).max() > 0, "degenerate eval — parity is vacuous"
    for qmode, tol in (("w8a16", 1e-2), ("w8a8", 3e-2)):
        qcfg, qparams, _ = api.calibrate_and_quantize(cfg, params, qmode)
        qcfg = dataclasses.replace(qcfg, quant_backend="interpret")
        qnet = api.eps_network(qcfg)
        q = np.asarray(jax.jit(
            lambda x, t: qnet(qparams, x, t, {"class_ids": ids}))(x, t))
        rel = float(np.linalg.norm(q - ref) / np.linalg.norm(ref))
        assert rel < tol, (f"{qmode} interpret-kernel eval drifted: "
                           f"rel err {rel:.2e} >= {tol}")
        print(f"quant smoke {qmode}: rel err vs fp32 {rel:.2e} < {tol}")
        if qmode != "w8a16":
            continue
        # cache_block/quant composition: one quantized tree serves both the
        # full and the cached (shallow) eval paths
        cached = api.eps_network_cached(qcfg, cache_block=1)
        B, T = x.shape[:2]
        cache0 = jnp.zeros((B, T, qcfg.d_model), x.dtype)
        full, cache = jax.jit(lambda x, t, c: cached(
            qparams, x, t, {"class_ids": ids}, c,
            jnp.zeros((B,), jnp.bool_)))(x, t, cache0)
        qf = np.asarray(jax.jit(
            lambda x, t: qnet(qparams, x, t, {"class_ids": ids}))(x, t))
        np.testing.assert_array_equal(np.asarray(full), qf)
        shallow, _ = jax.jit(lambda x, t, c: cached(
            qparams, x, t, {"class_ids": ids}, c,
            jnp.ones((B,), jnp.bool_)))(x, t, cache)
        assert np.isfinite(np.asarray(shallow)).all()
        print("quant smoke w8a16: cached full eval bitwise == quantized "
              "eval; shallow reuse eval finite")
    print("quant smoke ok")


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI parity smoke (interpret-mode kernels, tiny "
                         "shapes); exits nonzero on mismatch")
    ap.add_argument("--smoke-quant", action="store_true",
                    help="CI quantized-eval smoke (interpret-mode "
                         "quant_matmul, calibrated W8 tiers, cache "
                         "composition); exits nonzero on drift")
    args = ap.parse_args()
    if args.smoke:
        smoke()
    elif args.smoke_quant:
        smoke_quant()
    else:
        print("name,us_per_call,derived")
        bench_model()
