"""Roofline table from the dry-run artifacts (results/dryrun/*.json):
one row per (arch x shape x mesh) with the three terms, the bottleneck, and
the useful-compute ratio. `derived` = the dominant term in seconds."""

from __future__ import annotations

import json
from pathlib import Path

from .common import emit

DRYRUN_DIR = Path("results/dryrun")


def roofline_table():
    if not DRYRUN_DIR.exists():
        emit("roofline/missing", 0.0,
             "run: PYTHONPATH=src python -m repro.launch.dryrun --arch all "
             "--shape all --mesh single multi --out results/dryrun")
        return
    for f in sorted(DRYRUN_DIR.glob("*.json")):
        rec = json.loads(f.read_text())
        r = rec["roofline"]
        emit(
            f"roofline/{rec['arch']}/{rec['shape']}/{rec['mesh']}",
            r["compute_s"] * 1e6,
            f"bottleneck={r['bottleneck']};compute_s={r['compute_s']:.3e};"
            f"memory_s={r['memory_s']:.3e};"
            f"collective_s={r['collective_s']:.3e};"
            f"useful_ratio={r['useful_ratio']:.3f};mfu={r.get('mfu', 0):.4f}",
        )
