"""One benchmark per paper table (Tables 1-5) — solver-quality comparisons at
fixed NFE budgets, quality = convergence error to the 999-step DDIM reference
(paper Fig. 4c metric; see common.py for why not FID offline)."""

from __future__ import annotations

import numpy as np

from .common import (conv_err, emit, reference_x0, setting_model, timed,
                     x_T_for)
from repro.core import (DDIM, DEIS, DPMSolverPP, DPMSolverSinglestep, PNDM,
                        Grid, UniPC)
from repro.core.solver import CorrectorConfig

NFES = (5, 6, 8, 10)


def _data_model(schedule, eps):
    def f(x, t):
        a, s = float(schedule.alpha(t)), float(schedule.sigma(t))
        return (np.asarray(x, np.float64) - s * eps(x, t)) / a
    return f


def table1_bh_ablation():
    """Table 1: B1(h) vs B2(h) vs DPM-Solver++(3M) on three settings."""
    for setting in ("cifar10", "lsun_bedroom", "ffhq"):
        sched, eps = setting_model(setting)
        x_T = x_T_for(1)
        ref = reference_x0(eps, sched, x_T)
        dm = _data_model(sched, eps)
        for nfe in NFES:
            g = Grid.build(sched, nfe)
            s = DPMSolverPP(dm, g, order=3)
            x0, us = timed(lambda s=s: s.sample(x_T))
            emit(f"table1/{setting}/dpmpp3m/nfe{nfe}", us,
                 f"{conv_err(x0, ref)*1e3:.3f}")
            for variant in ("bh1", "bh2"):
                u = UniPC(dm, Grid.build(sched, nfe), order=3,
                          prediction="data", variant=variant)
                x0, us = timed(lambda u=u: u.sample_pc(x_T, use_corrector=True))
                emit(f"table1/{setting}/unipc-{variant}/nfe{nfe}", us,
                     f"{conv_err(x0, ref)*1e3:.3f}")


def table2_unic_any_solver():
    """Table 2: UniC bolted onto DDIM / DPM-Solver++(2M/3S/3M)."""
    sched, eps = setting_model("cifar10")
    x_T = x_T_for(2)
    ref = reference_x0(eps, sched, x_T)
    dm = _data_model(sched, eps)
    solvers = {
        "ddim": (lambda g: DDIM(eps, g, prediction="noise"), 1),
        "dpmpp2m": (lambda g: DPMSolverPP(dm, g, order=2), 2),
        "dpmpp3s": (lambda g: DPMSolverSinglestep(dm, g, sched, order=3,
                                                  prediction="data"), 3),
        "dpmpp3m": (lambda g: DPMSolverPP(dm, g, order=3), 3),
    }
    for name, (mk, order) in solvers.items():
        for nfe in NFES:
            steps = nfe if name != "dpmpp3s" else max(2, nfe // 3)
            for unic in (False, True):
                s = mk(Grid.build(sched, steps))
                corr = CorrectorConfig(order=order, variant="bh2") if unic else None
                x0, us = timed(lambda s=s, c=corr: s.sample(x_T, corrector=c))
                tag = "+unic" if unic else ""
                emit(f"table2/{name}{tag}/nfe{nfe}", us,
                     f"{conv_err(x0, ref)*1e3:.3f}")


def table3_oracle():
    """Table 3: UniC vs UniC-oracle on DPM-Solver++ (lsun/ffhq settings)."""
    for setting in ("lsun_bedroom", "ffhq"):
        sched, eps = setting_model(setting)
        x_T = x_T_for(3)
        ref = reference_x0(eps, sched, x_T)
        dm = _data_model(sched, eps)
        for nfe in NFES:
            for mode in ("plain", "unic", "unic-oracle"):
                s = DPMSolverPP(dm, Grid.build(sched, nfe), order=3)
                corr = None if mode == "plain" else CorrectorConfig(
                    order=3, variant="bh2", oracle=(mode == "unic-oracle"))
                x0, us = timed(lambda s=s, c=corr: s.sample(x_T, corrector=c))
                emit(f"table3/{setting}/{mode}/steps{nfe}", us,
                     f"{conv_err(x0, ref)*1e3:.3f}")


def table4_order_schedules():
    """Table 4: customized order schedules at NFE 6 and 7."""
    sched, eps = setting_model("cifar10")
    x_T = x_T_for(4)
    ref = reference_x0(eps, sched, x_T)
    dm = _data_model(sched, eps)
    plans = {
        6: ([1, 2, 3, 3, 2, 1], [1, 2, 3, 4, 3, 2], [1, 2, 3, 4, 4, 3],
            [1, 2, 3, 4, 5, 6]),
        7: ([1, 2, 3, 3, 3, 2, 1], [1, 2, 2, 3, 3, 3, 4],
            [1, 2, 3, 4, 3, 2, 1], [1, 2, 3, 4, 5, 6, 7]),
    }
    for nfe, schedules in plans.items():
        for plan in schedules:
            u = UniPC(dm, Grid.build(sched, nfe), order=max(plan),
                      prediction="data", order_schedule=list(plan))
            x0, us = timed(lambda u=u: u.sample_pc(x_T, use_corrector=True))
            tag = "".join(map(str, plan))
            emit(f"table4/nfe{nfe}/sched{tag}", us,
                 f"{conv_err(x0, ref)*1e3:.3f}")


def table5_more_nfe():
    """Table 5: every baseline vs UniPC at NFE 10-25 (guided setting proxy)."""
    sched, eps = setting_model("cifar10")
    x_T = x_T_for(5)
    ref = reference_x0(eps, sched, x_T)
    dm = _data_model(sched, eps)
    for nfe in (10, 15, 20, 25):
        runs = {
            "ddim": lambda g: DDIM(eps, g, prediction="noise").sample(x_T),
            "dpm-solver3s": lambda g: DPMSolverSinglestep(
                eps, Grid.build(sched, max(2, nfe // 3)), sched, order=3,
                prediction="noise").sample(x_T),
            "pndm": lambda g: PNDM(eps, g).sample(x_T),
            "deis": lambda g: DEIS(eps, g, sched, order=3).sample(x_T),
            "dpmpp3m": lambda g: DPMSolverPP(dm, g, order=3).sample(x_T),
            "unipc3": lambda g: UniPC(dm, g, order=3, prediction="data")
                .sample_pc(x_T, use_corrector=True),
        }
        for name, fn in runs.items():
            g = Grid.build(sched, nfe)
            x0, us = timed(lambda fn=fn, g=g: fn(g))
            emit(f"table5/{name}/nfe{nfe}", us,
                 f"{conv_err(x0, ref)*1e3:.3f}")
