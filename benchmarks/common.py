"""Shared benchmark harness utilities.

Every bench prints CSV rows `name,us_per_call,derived` where `derived` is the
bench's quality metric (convergence error, FID-surrogate, ratio, ...).
Offline container => no CIFAR/ImageNet checkpoints; quality metrics follow the
paper's own Fig. 4c protocol: l2 distance to a 999-step DDIM reference
trajectory, reported as 'err*1e3' (lower = better, ordering comparable to the
paper's FID orderings).
"""

from __future__ import annotations

import sys
import time

import numpy as np

sys.path.insert(0, "src")

from repro.core import DDIM, Grid  # noqa: E402
from repro.diffusion import MixtureDPM, VPCosine, VPLinear  # noqa: E402


def bench_header() -> dict:
    """Environment stamp for every BENCH_*.json artifact.

    Which accelerator produced the numbers decides which guard rules apply
    (benchmarks/guard.py): low-precision eval paths (bf16, quantized) must
    WIN wall-clock on tpu/gpu — where they halve/quarter the HBM traffic the
    eval is bound by — but may legitimately lose on cpu, where XLA
    rematerializes casts in fp32 arithmetic. A committed artifact without
    this stamp is treated as cpu-produced."""
    import platform

    import jax

    cpu = platform.processor() or ""
    try:
        with open("/proc/cpuinfo") as f:
            for line in f:
                if line.startswith("model name"):
                    cpu = line.split(":", 1)[1].strip()
                    break
    except OSError:
        pass
    return {"backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "cpu": cpu}


def timed(fn, repeat=3):
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return out, best * 1e6


def emit(name, us, derived):
    print(f"{name},{us:.1f},{derived}")


def reference_x0(model, schedule, x_T, steps=999):
    """The paper's ground-truth protocol: a fine-grid DDIM trajectory."""
    g = Grid.build(schedule, steps)
    return np.asarray(DDIM(model, g, prediction="noise").sample(x_T))


def conv_err(x0, ref):
    """l2 distance / sqrt(D) — the paper's convergence-error metric."""
    x0 = np.asarray(x0)
    return float(np.linalg.norm(x0 - ref) / np.sqrt(ref.size))


# three 'dataset' stand-ins = three schedule/data settings (CIFAR/LSUN/FFHQ
# analogues for Fig. 3): different noise schedules + data spreads
SETTINGS = {
    "cifar10": (VPLinear(), MixtureDPM(VPLinear())),
    "lsun_bedroom": (VPLinear(beta_0=0.05, beta_1=14.0),
                     MixtureDPM(VPLinear(beta_0=0.05, beta_1=14.0),
                                mus=(-0.8, 0.5, 1.5), ss=(0.25, 0.4, 0.3),
                                ws=(0.3, 0.4, 0.3))),
    "ffhq": (VPCosine(), MixtureDPM(VPCosine(), mus=(-1.2, 0.9),
                                    ss=(0.45, 0.35), ws=(0.5, 0.5))),
}


def setting_model(name):
    sched, dpm = SETTINGS[name]
    return sched, dpm.eps_model


def x_T_for(seed=0, n=256):
    return np.random.default_rng(seed).normal(size=(n,))
