"""Continuous-batching serving benchmark -> BENCH_serve.json.

For each dit workload shape (DESIGN.md §6), serve the same Poisson arrival
trace — at 2x the slot-capacity rate, the acceptance setting — through two
admission policies over the *same* AOT-compiled per-slot step program:

* ``continuous`` — admit-on-free-slot (the `serving.SlotScheduler` default);
* ``gang``       — sequential full-batch: admit only into an empty batch,
                   i.e. what `launch/serve.py` did before the scheduler.

Emits the CSV row per run (us = measured wall per tick) and writes the full
metric rows (throughput, p50/p95 latency in ticks and seconds, slot
occupancy, evals-per-latent, AOT compile seconds, host µs/tick) to
BENCH_serve.json at the repo root so the perf trajectory is tracked across
PRs. The derived ratio is continuous-over-gang throughput — the number that
must stay > 1.

A second section, ``async_runs``, benchmarks the pipelined serving loop
(DESIGN.md §13): the same trace at a *saturating* arrival rate (4x slot
capacity, so the scheduler never idles and throughput is device-bound) served
synchronously (pipeline depth 1) and pipelined (depth 2). The async/sync
throughput ratio and the host-overhead fraction of tick time are the numbers
`guard.py` enforces. Each async row also carries the host_us_per_tick split
by tick phase (``host_phase_us_per_tick``: admission / dispatch / readback /
bookkeeping, DESIGN.md §15) — the measured "where a tick goes" table.

A third section, ``obs_runs``, measures observability overhead: the same
saturating depth-2 trace served untraced and with a `repro.obs.Tracer`
attached, compared on the scheduler's own host-nanosecond counters. The
committed ``obs_overhead_frac`` (extra host µs per tick over the untraced
baseline, as a fraction of tick wall) is guard-capped at 5%.

A fourth section, ``fault_runs``, prices the resilience layer (DESIGN.md
§16) on the same saturating depth-2 trace: ``plain`` (no resilience config)
vs ``armed`` (queue bound + retry budget configured, nothing fires) gives
the committed ``fault_free_overhead_frac`` — extra host µs per tick as a
fraction of tick wall, guard-capped at 2% because an idle policy layer must
be nearly free — and ``faulted`` (a NaN poisoning + a forced meta desync
under the armed config) must still complete EVERY request, with the extra
ticks recovery spent committed as ``recovery_overhead_frac``.
"""

from __future__ import annotations

import json

import jax

from .common import bench_header, emit, timed  # noqa: F401

ARCHS = ("dit-cifar", "dit-i256")
SLOTS = 4
NFE = 8
REQUESTS = 16


def _program(arch: str, cfg_scale: float, seed: int = 0):
    from repro.configs.registry import get_config
    from repro.diffusion import VPLinear
    from repro.engine import EngineSpec
    from repro.launch.sample import build_engine
    from repro.models import api

    cfg = get_config(arch).reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(seed))
    engine = build_engine(cfg, params, VPLinear(), SLOTS, seed,
                          want_cfg=cfg_scale != 0.0)
    spec = EngineSpec(solver="unipc", order=3, nfe=NFE, cfg_scale=cfg_scale)
    return (engine.build_step(spec), (cfg.patch_tokens, cfg.latent_dim))


def _serve(arch: str, cfg_scale: float, gang: bool,
           pipeline_depth: int = 1, rate_x: float = 2.0, prebuilt=None,
           warmup: bool = False, n_requests: int = 0, traced: bool = False,
           resilience=None, faults=None):
    from repro.obs import Tracer
    from repro.serving import SlotScheduler, poisson_requests, run_trace

    program, sample_shape = prebuilt or _program(arch, cfg_scale)
    sched = SlotScheduler(program, SLOTS, sample_shape, gang=gang,
                          pipeline_depth=pipeline_depth,
                          tracer=Tracer() if traced else None,
                          resilience=resilience, faults=faults)
    compile_s = sched.aot_compile()
    if warmup:
        # a short throwaway trace so first-call dispatch paths (random-draw
        # jits, scatter/gather compiles) don't land in the measured run
        run_trace(sched, poisson_requests(2 * SLOTS, 1.0, seed=7))
    # rate_x * capacity: 2x is the continuous-vs-gang acceptance point,
    # 4x saturates the slots for the async-vs-sync comparison
    rate = rate_x * SLOTS / program.n_rows
    cfg_scales = [1.5, 2.0, 3.0] if cfg_scale else None
    reqs = poisson_requests(n_requests or REQUESTS, rate, seed=11,
                            cfg_scales=cfg_scales)
    m = run_trace(sched, reqs)
    row = m.row()
    row.update(arch=arch, cfg_scale=cfg_scale, aot_compile_s=compile_s,
               arrival_rate_per_tick=rate)
    return row


def bench_serve(out_path: str = "BENCH_serve.json"):
    """Continuous vs gang serving at both dit shapes; writes BENCH_serve.json."""
    rows = []
    for arch in ARCHS:
        for cfg_scale in ((0.0, 2.0) if arch == "dit-cifar" else (0.0,)):
            cont = _serve(arch, cfg_scale, gang=False, warmup=True)
            gang = _serve(arch, cfg_scale, gang=True, warmup=True)
            rows += [cont, gang]
            ratio = cont["throughput_per_tick"] / gang["throughput_per_tick"]
            tag = f"{arch}_cfg{cfg_scale:g}"
            emit(f"serve/{tag}/continuous", cont["tick_s"] * 1e6,
                 f"rps={cont['throughput_rps']:.2f};"
                 f"p95_ms={cont['latency_s_p95']*1e3:.1f};"
                 f"evals_per_latent={cont['evals_per_latent']:.2f};"
                 f"host_us_per_tick={cont['host_us_per_tick']:.0f}")
            emit(f"serve/{tag}/gang", gang["tick_s"] * 1e6,
                 f"rps={gang['throughput_rps']:.2f};"
                 f"p95_ms={gang['latency_s_p95']*1e3:.1f};"
                 f"evals_per_latent={gang['evals_per_latent']:.2f}")
            emit(f"serve/{tag}/continuous_over_gang", 0.0,
                 f"throughput_ratio={ratio:.2f}")
            assert ratio > 1.0, (
                f"continuous batching must beat sequential full-batch "
                f"serving at 2x arrival rate; got ratio {ratio:.3f} ({tag})")
    async_rows = []
    for arch in ARCHS:
        # saturating arrival (4x capacity): the slots never idle, so
        # throughput is bounded by tick execution + whatever host overhead
        # sits on the critical path — exactly what pipelining removes. ONE
        # program serves both depths (the same compiled executable; scheduler
        # state is per-scheduler), runs alternate sync/async and the median
        # rep is committed, so the comparison is not noised by a rebuild or
        # a transient load spike. On runtimes without async dispatch (CPU:
        # the DiT step executes inline in the dispatch call) the expectation
        # is parity, not a win — the overlap shows up on TPU.
        prebuilt = _program(arch, 0.0)
        reps = {1: [], 2: []}
        for rep in range(3):
            for depth in (1, 2):
                reps[depth].append(_serve(
                    arch, 0.0, gang=False, pipeline_depth=depth, rate_x=4.0,
                    prebuilt=prebuilt, warmup=rep == 0,
                    n_requests=2 * REQUESTS))
        def _median_rep(rows):
            return sorted(rows, key=lambda r: r["throughput_rps"])[1]
        sync, asyn = _median_rep(reps[1]), _median_rep(reps[2])
        async_rows += [sync, asyn]
        ratio = asyn["throughput_rps"] / sync["throughput_rps"]
        host_frac = sync["host_us_per_tick"] / max(sync["tick_s"] * 1e6, 1e-9)
        emit(f"serve/{arch}/sync_depth1", sync["tick_s"] * 1e6,
             f"rps={sync['throughput_rps']:.2f};"
             f"host_us_per_tick={sync['host_us_per_tick']:.0f};"
             f"host_frac={host_frac:.3f}")
        emit(f"serve/{arch}/async_depth2", asyn["tick_s"] * 1e6,
             f"rps={asyn['throughput_rps']:.2f};"
             f"host_us_per_tick={asyn['host_us_per_tick']:.0f}")
        emit(f"serve/{arch}/async_over_sync", 0.0,
             f"throughput_ratio={ratio:.3f}")
    # observability overhead (DESIGN.md §15): the same saturating depth-2
    # trace untraced vs with a Tracer attached. dit-cifar only — the
    # smallest tick, so tracing overhead is proportionally at its worst.
    # The comparison uses the scheduler's own host_us_per_tick counters
    # (the host_ns methodology), not the wall clock: on CPU the device step
    # executes inline in the dispatch call, so total tick wall is dominated
    # by the model eval and would hide any host-side regression.
    obs_rows = []
    prebuilt = _program("dit-cifar", 0.0)
    obs_reps = {False: [], True: []}
    for rep in range(3):
        for traced in (False, True):
            obs_reps[traced].append(_serve(
                "dit-cifar", 0.0, gang=False, pipeline_depth=2, rate_x=4.0,
                prebuilt=prebuilt, warmup=rep == 0,
                n_requests=2 * REQUESTS, traced=traced))
    def _median_host(rows):
        return sorted(rows, key=lambda r: r["host_us_per_tick"])[1]
    base, traced = _median_host(obs_reps[False]), _median_host(obs_reps[True])
    base["traced"], traced["traced"] = False, True
    tick_us = base["tick_s"] * 1e6
    overhead_frac = ((traced["host_us_per_tick"] - base["host_us_per_tick"])
                     / max(tick_us, 1e-9))
    traced["obs_overhead_frac"] = overhead_frac
    obs_rows += [base, traced]
    emit("serve/dit-cifar/obs_untraced_depth2", base["tick_s"] * 1e6,
         f"host_us_per_tick={base['host_us_per_tick']:.0f}")
    emit("serve/dit-cifar/obs_traced_depth2", traced["tick_s"] * 1e6,
         f"host_us_per_tick={traced['host_us_per_tick']:.0f};"
         f"overhead_frac={overhead_frac:.4f}")
    # resilience pricing (DESIGN.md §16): plain vs armed-but-idle vs faulted
    # on the saturating depth-2 dit-cifar trace. Armed-vs-plain is compared
    # on the host nanosecond counters (same methodology as obs_runs: on CPU
    # the tick wall is eval-dominated and would hide a host-path regression);
    # the faulted run must complete every request despite a NaN poisoning
    # and a forced desync, and commits the extra ticks recovery cost.
    from repro.serving import FaultPlan, MetaFault, NanFault, ResilienceConfig

    armed_cfg = ResilienceConfig(max_queue=256, max_retries=2)
    # the NaN fires in the first wave, the meta corruption several waves
    # later — decoupled so the desync recovery can't requeue the poisoned
    # request before its non-finite completion is consumed (which would
    # repair it without spending a retry, leaving the retry path untested)
    fault_plan = FaultPlan(nans=(NanFault(rid=1, step=1),),
                           metas=(MetaFault(tick=3 * NFE),))
    fault_rows = []
    prebuilt = _program("dit-cifar", 0.0)
    fault_reps = {"plain": [], "armed": []}
    for rep in range(3):
        for kind in ("plain", "armed"):
            fault_reps[kind].append(_serve(
                "dit-cifar", 0.0, gang=False, pipeline_depth=2, rate_x=4.0,
                prebuilt=prebuilt, warmup=rep == 0,
                n_requests=2 * REQUESTS,
                resilience=armed_cfg if kind == "armed" else None))
    plain, armed = (_median_host(fault_reps["plain"]),
                    _median_host(fault_reps["armed"]))
    faulted = _serve("dit-cifar", 0.0, gang=False, pipeline_depth=2,
                     rate_x=4.0, prebuilt=prebuilt,
                     n_requests=2 * REQUESTS,
                     resilience=armed_cfg, faults=fault_plan)
    plain["resilience"], armed["resilience"], faulted["resilience"] = \
        "plain", "armed", "faulted"
    tick_us = plain["tick_s"] * 1e6
    ff_frac = ((armed["host_us_per_tick"] - plain["host_us_per_tick"])
               / max(tick_us, 1e-9))
    armed["fault_free_overhead_frac"] = ff_frac
    faulted["recovery_overhead_frac"] = (
        (faulted["ticks"] - plain["ticks"]) / max(plain["ticks"], 1))
    fault_rows += [plain, armed, faulted]
    emit("serve/dit-cifar/resilience_plain_depth2", plain["tick_s"] * 1e6,
         f"host_us_per_tick={plain['host_us_per_tick']:.0f}")
    emit("serve/dit-cifar/resilience_armed_depth2", armed["tick_s"] * 1e6,
         f"host_us_per_tick={armed['host_us_per_tick']:.0f};"
         f"fault_free_overhead_frac={ff_frac:.4f}")
    emit("serve/dit-cifar/resilience_faulted_depth2",
         faulted["tick_s"] * 1e6,
         f"completed={faulted['completed']}/{faulted['requests']};"
         f"retries={faulted['retries']};"
         f"recoveries={faulted['recoveries']};"
         f"recovery_overhead_frac={faulted['recovery_overhead_frac']:.4f}")
    assert faulted["completed"] == faulted["requests"], (
        f"the faulted run must recover every request; completed "
        f"{faulted['completed']}/{faulted['requests']}")
    with open(out_path, "w") as f:
        json.dump({"slots": SLOTS, "nfe": NFE, "requests": REQUESTS,
                   "env": bench_header(), "runs": rows,
                   "async_runs": async_rows, "obs_runs": obs_rows,
                   "fault_runs": fault_rows},
                  f, indent=1)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    bench_serve()
