"""Solver-plan autotuner benchmark -> BENCH_tuning.json.

Tuned vs default (hand-set UniPC-2) plans on the briefly trained reduced
dit-cifar backbone at NFE in {5, 6, 8, 10}: reference-trajectory
discrepancy for both tables, the relative improvement, search wall-clock,
and the per-sample scan wall-clock of the tuned table (a searched plan must
not change the serving cost — same rows, same fused scan).

The derived CSV field carries the discrepancy pair; the acceptance gate
(tuned <= baseline, strictly better at NFE <= 8) is asserted here so a
regressing tuner fails the bench run loudly.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit

ARCH = "dit-cifar"
NFES = (5, 6, 8, 10)
BUDGET = 40
TRAIN_STEPS = 100


def bench_tuning(out_path: str = "BENCH_tuning.json"):
    """Tuned vs default plans across NFE budgets; writes BENCH_tuning.json."""
    from repro.engine import EngineSpec
    from repro.launch.tune import _setup, tune
    from repro.tuning import reference_trajectory

    engine, x_T = _setup(ARCH, reduced=True, batch=4, seed=0,
                         train_steps=TRAIN_STEPS)
    # one reference trajectory serves every NFE budget below
    x_ref = reference_trajectory(engine, EngineSpec(solver="unipc"), x_T,
                                 ref_nfe=48)
    rows = []
    for nfe in NFES:
        plan, report = tune(ARCH, nfe=nfe, budget=BUDGET, ref_nfe=48,
                            engine=engine, x_T=x_T, x_ref=x_ref)
        # serving cost of the tuned table: same scan, same per-step cost
        spec = EngineSpec(solver="unipc", nfe=nfe,
                          order=max(plan.orders))
        tab = engine.compile(spec, table=plan.compile(engine.schedule))
        run = engine.build(spec, table=tab)
        run(x_T).block_until_ready()          # compile outside the timing
        t0 = time.perf_counter()
        run(x_T).block_until_ready()
        sample_s = time.perf_counter() - t0
        row = dict(arch=ARCH, nfe=nfe, budget=BUDGET,
                   baseline_discrepancy=report["baseline"],
                   tuned_discrepancy=report["tuned"],
                   improvement=report["improvement"],
                   rel_improvement=(report["improvement"]
                                    / max(report["baseline"], 1e-12)),
                   search_wall_s=report["search_wall_s"],
                   evals=report["evals"], sample_wall_s=sample_s,
                   train_steps=TRAIN_STEPS)
        rows.append(row)
        emit(f"tuning/{ARCH}/nfe{nfe}", report["search_wall_s"] * 1e6,
             f"baseline={report['baseline']:.5f};"
             f"tuned={report['tuned']:.5f};"
             f"rel_improvement={row['rel_improvement']:.3f};"
             f"sample_ms={sample_s*1e3:.1f}")
        assert report["tuned"] <= report["baseline"], (
            f"tuner regressed at nfe={nfe}")
        if nfe <= 8:
            # the acceptance criterion: strictly beats UniPC-2 at few steps
            assert report["tuned"] < report["baseline"], (
                f"tuned plan failed to strictly beat the UniPC-2 baseline "
                f"at nfe={nfe}")
    with open(out_path, "w") as f:
        json.dump({"arch": ARCH, "budget": BUDGET,
                   "train_steps": TRAIN_STEPS, "runs": rows}, f, indent=1)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    bench_tuning()
