"""Solver-plan autotuner benchmark -> BENCH_tuning.json.

Tuned vs default (hand-set UniPC-2) plans on the briefly trained reduced
dit-cifar backbone at NFE in {5, 6, 8, 10}: reference-trajectory
discrepancy for both tables, the relative improvement, search wall-clock,
and the per-sample scan wall-clock of the tuned table (a searched plan must
not change the serving cost — same rows, same fused scan).

The derived CSV field carries the discrepancy pair; the acceptance gate
(tuned <= baseline, strictly better at NFE <= 8) is asserted here so a
regressing tuner fails the bench run loudly.

A second section benches the joint solver + feature-reuse search
(DESIGN.md §12) on a cache-wired engine at the same NFE budgets: shallow
steps recompute only the first `cache_block` DiT blocks and reuse the cached
deep features, so the tuned plan's evals-per-latent drops strictly below the
NFE floor while the discrepancy stays within `CACHE_SLACK` of the no-cache
tuned anchor — both asserted here and re-checked from the committed artifact
by `benchmarks/guard.py`.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from .common import bench_header, emit

ARCH = "dit-cifar"
NFES = (5, 6, 8, 10)
BUDGET = 40
TRAIN_STEPS = 100
# joint solver + cache-schedule runs: the reduced dit-cifar has 2 blocks, so
# boundary 1 halves a shallow step's eval cost
CACHE_NFES = (5, 8)
CACHE_BLOCK = 1
CACHE_SLACK = 1.1


def bench_tuning(out_path: str = "BENCH_tuning.json"):
    """Tuned vs default plans across NFE budgets; writes BENCH_tuning.json."""
    from repro.engine import EngineSpec
    from repro.launch.tune import _setup, tune
    from repro.tuning import reference_trajectory

    engine, x_T, _ = _setup(ARCH, reduced=True, batch=4, seed=0,
                            train_steps=TRAIN_STEPS)
    # one reference trajectory serves every NFE budget below
    x_ref = reference_trajectory(engine, EngineSpec(solver="unipc"), x_T,
                                 ref_nfe=48)
    rows = []
    for nfe in NFES:
        plan, report = tune(ARCH, nfe=nfe, budget=BUDGET, ref_nfe=48,
                            engine=engine, x_T=x_T, x_ref=x_ref)
        # serving cost of the tuned table: same scan, same per-step cost
        spec = EngineSpec(solver="unipc", nfe=nfe,
                          order=max(plan.orders))
        tab = engine.compile(spec, table=plan.compile(engine.schedule))
        run = engine.build(spec, table=tab)
        run(x_T).block_until_ready()          # compile outside the timing
        t0 = time.perf_counter()
        run(x_T).block_until_ready()
        sample_s = time.perf_counter() - t0
        row = dict(arch=ARCH, nfe=nfe, budget=BUDGET,
                   baseline_discrepancy=report["baseline"],
                   tuned_discrepancy=report["tuned"],
                   improvement=report["improvement"],
                   rel_improvement=(report["improvement"]
                                    / max(report["baseline"], 1e-12)),
                   search_wall_s=report["search_wall_s"],
                   evals=report["evals"], sample_wall_s=sample_s,
                   train_steps=TRAIN_STEPS)
        rows.append(row)
        emit(f"tuning/{ARCH}/nfe{nfe}", report["search_wall_s"] * 1e6,
             f"baseline={report['baseline']:.5f};"
             f"tuned={report['tuned']:.5f};"
             f"rel_improvement={row['rel_improvement']:.3f};"
             f"sample_ms={sample_s*1e3:.1f}")
        assert report["tuned"] <= report["baseline"], (
            f"tuner regressed at nfe={nfe}")
        if nfe <= 8:
            # the acceptance criterion: strictly beats UniPC-2 at few steps
            assert report["tuned"] < report["baseline"], (
                f"tuned plan failed to strictly beat the UniPC-2 baseline "
                f"at nfe={nfe}")
    # -- cached runs: joint solver + feature-reuse schedules ----------------
    # same seed/train_steps -> bit-identical backbone params, so cached
    # discrepancies are comparable with the uncached rows above
    cengine, cx_T, _ = _setup(ARCH, reduced=True, batch=4, seed=0,
                              train_steps=TRAIN_STEPS,
                              cache_block=CACHE_BLOCK)
    cx_ref = reference_trajectory(
        cengine, EngineSpec(solver="unipc", cache_block=CACHE_BLOCK), cx_T,
        ref_nfe=48)
    cached_rows = []
    for nfe in CACHE_NFES:
        plan, rep = tune(ARCH, nfe=nfe, budget=BUDGET, ref_nfe=48,
                         engine=cengine, x_T=cx_T, x_ref=cx_ref,
                         cache_block=CACHE_BLOCK, cache_slack=CACHE_SLACK)
        row = dict(arch=ARCH, nfe=nfe, cache_block=CACHE_BLOCK,
                   cache_slack=CACHE_SLACK, nfe_evals=rep["nfe_evals"],
                   evals_per_latent=rep["evals_per_latent"],
                   cached_discrepancy=rep["tuned"],
                   uncached_discrepancy=rep["uncached_tuned"],
                   cached_ratio=rep["cached_ratio"],
                   search_wall_s=rep["search_wall_s"], evals=rep["evals"],
                   shallow_steps=sum(1 for d in (plan.cache_depth or [])
                                     if d))
        cached_rows.append(row)
        emit(f"tuning-cached/{ARCH}/nfe{nfe}", rep["search_wall_s"] * 1e6,
             f"evals_per_latent={row['evals_per_latent']:.2f};"
             f"nfe_evals={row['nfe_evals']};"
             f"cached_ratio={row['cached_ratio']:.3f};"
             f"shallow={row['shallow_steps']}")
        assert rep["cached_ratio"] <= CACHE_SLACK, (
            f"cached plan at nfe={nfe} overspent the discrepancy slack: "
            f"ratio {rep['cached_ratio']:.3f} > {CACHE_SLACK}")
    assert any(r["evals_per_latent"] < r["nfe_evals"] for r in cached_rows), (
        f"no cached plan landed evals-per-latent below its NFE floor "
        f"(acceptance criterion): {cached_rows}")
    with open(out_path, "w") as f:
        json.dump({"arch": ARCH, "budget": BUDGET,
                   "train_steps": TRAIN_STEPS, "env": bench_header(),
                   "runs": rows, "cached_runs": cached_rows}, f, indent=1)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    bench_tuning()
