"""Kernel-level benchmarks — dry-run style (no TPU): compare the HBM-traffic
schedule of the fused Pallas path vs the naive op-chain by lowering both and
counting bytes with the trip-scaled HLO accounting. `derived` = traffic ratio
(chain / fused target model): the structural win the kernel encodes."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import emit, timed
from repro.analysis.hlo import analyze


def _chain_update(terms, weights):
    """Reference-implementation style: K sequential axpy ops."""
    out = weights[0] * terms[0]
    for k in range(1, terms.shape[0]):
        out = out + weights[k] * terms[k]
    return out


def kernel_unipc_update():
    for K, n in ((4, 1 << 20), (5, 1 << 22), (7, 1 << 22)):
        terms = jax.ShapeDtypeStruct((K, n), jnp.bfloat16)
        weights = jax.ShapeDtypeStruct((K,), jnp.float32)
        chain = jax.jit(_chain_update).lower(terms, weights).compile()
        chain_bytes = analyze(chain.as_text(), 1)["hbm_bytes"]
        # fused single-pass model: read K terms once, write once
        ideal = (K + 1) * n * 2
        _, us = timed(lambda: None)
        emit(f"kernels/unipc_update/K{K}_n{n}", 0.0,
             f"chain_bytes={chain_bytes:.3e};single_pass={ideal:.3e};"
             f"ratio={chain_bytes/ideal:.2f}")


def kernel_flash_attention():
    B, H, S, D = 1, 8, 2048, 64
    q = jax.ShapeDtypeStruct((B, S, H, D), jnp.bfloat16)

    def naive(q):
        from repro.models.layers import sdpa
        return sdpa(q, q, q, causal=True)

    comp = jax.jit(naive).lower(q).compile()
    naive_bytes = analyze(comp.as_text(), 1)["hbm_bytes"]
    # flash model: read q,k,v once + write o once (blockwise, no S^2 tensor)
    flash = 4 * B * S * H * D * 2
    emit(f"kernels/flash_attention/S{S}", 0.0,
         f"naive_bytes={naive_bytes:.3e};flash_model={flash:.3e};"
         f"ratio={naive_bytes/flash:.1f}")


def kernel_correctness_timing():
    """Wall-clock of the interpret-mode kernels vs oracles (correctness-path
    cost only; TPU timings require hardware)."""
    from repro.kernels.unipc_update import ops as uops, ref as uref
    t = jax.random.normal(jax.random.PRNGKey(0), (5, 4096))
    w = jax.random.normal(jax.random.PRNGKey(1), (5,))
    _, us_ref = timed(lambda: jax.block_until_ready(
        uref.weighted_combine(t, w)))
    _, us_pal = timed(lambda: jax.block_until_ready(
        uops.weighted_combine(t, w, force_pallas=True)))
    emit("kernels/unipc_update/interpret_vs_ref", us_pal,
         f"ref_us={us_ref:.1f}")
