"""Kernel-level benchmarks — dry-run style (no TPU): compare the HBM-traffic
schedule of the fused Pallas path vs the naive op-chain by lowering both and
counting bytes with the trip-scaled HLO accounting. `derived` = traffic ratio
(chain / fused target model): the structural win the kernel encodes."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import emit, timed
from repro.analysis.hlo import analyze


def _chain_update(terms, weights):
    """Reference-implementation style: K sequential axpy ops."""
    out = weights[0] * terms[0]
    for k in range(1, terms.shape[0]):
        out = out + weights[k] * terms[k]
    return out


# The eager op-chain schedule, one jitted kernel per op — how reference
# implementations (and any eager framework) execute the update. Jitting the
# whole chain at once would let XLA fuse it into a single pass (CPU XLA even
# strips optimization_barrier), so the multi-pass schedule has to be pinned
# at the dispatch boundary, exactly where eager frameworks pin it.
_opchain_mul = jax.jit(lambda t0, w0: w0 * t0)
_opchain_axpy = jax.jit(lambda acc, tk, wk: acc + wk * tk)


def _opchain_run(ts, w):
    """ts: list of K per-term arrays; returns sum_k w[k] * ts[k] eagerly."""
    out = _opchain_mul(ts[0], w[0])
    for k in range(1, len(ts)):
        out = _opchain_axpy(out, ts[k], w[k])
    return out


def _opchain_bytes(shape, dtype):
    """Measured HBM bytes of the eager schedule: sum of the per-op HLO
    accounting over the K dispatches ((3K-1) full-state arrays). The weight
    scalar carries the term dtype — a strong-typed f32 scalar would promote
    the whole chain to f32 (eager frameworks keep the tensor dtype)."""
    K = shape[0]
    a_t = jax.ShapeDtypeStruct(shape[1:], dtype)
    a_w = jax.ShapeDtypeStruct((), dtype)
    total = analyze(_opchain_mul.lower(a_t, a_w).compile().as_text(), 1)[
        "hbm_bytes"]
    axpy = analyze(_opchain_axpy.lower(a_t, a_t, a_w).compile().as_text(), 1)[
        "hbm_bytes"]
    return total + (K - 1) * axpy


def kernel_unipc_update():
    for K, n in ((4, 1 << 20), (5, 1 << 22), (7, 1 << 22)):
        terms = jax.ShapeDtypeStruct((K, n), jnp.bfloat16)
        weights = jax.ShapeDtypeStruct((K,), jnp.float32)
        chain = jax.jit(_chain_update).lower(terms, weights).compile()
        chain_bytes = analyze(chain.as_text(), 1)["hbm_bytes"]
        # fused single-pass model: read K terms once, write once
        ideal = (K + 1) * n * 2
        _, us = timed(lambda: None)
        emit(f"kernels/unipc_update/K{K}_n{n}", 0.0,
             f"chain_bytes={chain_bytes:.3e};single_pass={ideal:.3e};"
             f"ratio={chain_bytes/ideal:.2f}")


# Production sampling-state shapes (batch, tokens, latent_dim) of the two
# paper workloads — see src/repro/configs/{dit_cifar,dit_i256}.py.
LATENT_SHAPES = (
    ("dit-cifar", (64, 64, 48)),
    ("dit-i256", (32, 256, 32)),
)


def kernel_unipc_update_latents():
    """Fused-vs-opchain at the paper's sampling shapes: HBM bytes of the
    lowered op-chain (trip-scaled HLO accounting) vs the kernel's single-pass
    schedule, plus wall-clock of both dispatched paths. K = order + 2 = 5 is
    the UniC-3 combine, the widest update on the default settings. The byte
    ratio is the measured form of the (3K-1)/(K+1)x claim in DESIGN.md §4."""
    from repro.kernels.unipc_update import ops as uops

    K = 5
    for name, (B, T, C) in LATENT_SHAPES:
        for dtype, isize in ((jnp.float32, 4), (jnp.bfloat16, 2)):
            shape = (K, B, T, C)
            chain_bytes = _opchain_bytes(shape, dtype)
            fused_bytes = (K + 1) * B * T * C * isize
            t = jax.random.normal(jax.random.PRNGKey(0), shape,
                                  jnp.float32).astype(dtype)
            w = jax.random.normal(jax.random.PRNGKey(1), (K,), jnp.float32)
            ts = [t[k] for k in range(K)]
            ws = [w[k].astype(dtype) for k in range(K)]  # keep the chain in dtype
            fused_fn = jax.jit(uops.weighted_combine)
            jax.block_until_ready(_opchain_run(ts, ws))
            jax.block_until_ready(fused_fn(t, w))
            _, us_chain = timed(
                lambda: jax.block_until_ready(_opchain_run(ts, ws)))
            _, us_fused = timed(lambda: jax.block_until_ready(fused_fn(t, w)))
            dt = "f32" if dtype == jnp.float32 else "bf16"
            emit(f"kernels/unipc_update/{name}_{dt}", us_fused,
                 f"opchain_bytes={chain_bytes:.3e};fused_bytes={fused_bytes:.3e};"
                 f"traffic_ratio={chain_bytes/fused_bytes:.2f};"
                 f"opchain_us={us_chain:.1f};fused_us={us_fused:.1f}")


def kernel_flash_attention():
    B, H, S, D = 1, 8, 2048, 64
    q = jax.ShapeDtypeStruct((B, S, H, D), jnp.bfloat16)

    def naive(q):
        from repro.models.layers import sdpa
        return sdpa(q, q, q, causal=True)

    comp = jax.jit(naive).lower(q).compile()
    naive_bytes = analyze(comp.as_text(), 1)["hbm_bytes"]
    # flash model: read q,k,v once + write o once (blockwise, no S^2 tensor)
    flash = 4 * B * S * H * D * 2
    emit(f"kernels/flash_attention/S{S}", 0.0,
         f"naive_bytes={naive_bytes:.3e};flash_model={flash:.3e};"
         f"ratio={naive_bytes/flash:.1f}")


def kernel_correctness_timing():
    """Wall-clock of the interpret-mode kernels vs oracles (correctness-path
    cost only; TPU timings require hardware)."""
    from repro.kernels.unipc_update import ops as uops, ref as uref
    t = jax.random.normal(jax.random.PRNGKey(0), (5, 4096))
    w = jax.random.normal(jax.random.PRNGKey(1), (5,))
    _, us_ref = timed(lambda: jax.block_until_ready(
        uref.weighted_combine(t, w)))
    _, us_pal = timed(lambda: jax.block_until_ready(
        uops.weighted_combine(t, w, force_pallas=True)))
    emit("kernels/unipc_update/interpret_vs_ref", us_pal,
         f"ref_us={us_ref:.1f}")
