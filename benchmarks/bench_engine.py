"""Engine benchmark: scan-compiled vs python-loop wall-clock, per solver, at
the dit-cifar serving shapes. `derived` = loop_us / scan_us (the speedup the
engine's scan compilation buys that solver), plus a fused-vs-sequential CFG
row (the serving win of one 2B-batched eval per step).

The eps-net is the reduced dit-cifar backbone — the same geometry
`launch/serve.py` serves — so the ratio reflects real dispatch overheads,
not toy-model noise. On CPU the eval dominates and scan ~= loop; the scan's
structural wins (one jitted program, no per-step python dispatch, the fused
Pallas combine, shardability) show on TPU — this bench records the numbers
wherever it runs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit, timed

SOLVER_ORDERS = [("unipc", 3), ("ddim", 1), ("dpmpp", 2), ("dpmpp", 3),
                 ("pndm", 4), ("deis", 3), ("dpm", 2)]


def _dit_engine(batch=8, cfg_scale=0.0, seed=0):
    from repro.configs.registry import get_config
    from repro.diffusion import VPLinear
    from repro.launch.sample import build_engine
    from repro.models import api

    cfg = get_config("dit-cifar").reduced()
    rng = jax.random.PRNGKey(seed)
    params = api.init_params(cfg, rng)
    engine = build_engine(cfg, params, VPLinear(), batch, seed,
                          want_cfg=cfg_scale != 0.0)
    x_T = jax.random.normal(rng, (batch, cfg.patch_tokens, cfg.latent_dim),
                            jnp.float32)
    return engine, x_T


def bench_engine(nfe=10, batch=8):
    """Per-solver scan vs loop wall-clock at dit-cifar serving shapes."""
    from repro.engine import EngineSpec

    engine, x_T = _dit_engine(batch=batch)
    for solver, order in SOLVER_ORDERS:
        spec = EngineSpec(solver=solver, order=order, nfe=nfe)
        run = engine.build(spec)
        jax.block_until_ready(run(x_T))  # compile outside the timing
        _, scan_us = timed(lambda: jax.block_until_ready(run(x_T)))
        loop = engine.build_loop(spec)
        _, loop_us = timed(lambda: jax.block_until_ready(loop(x_T)))
        emit(f"engine/{solver}{order}/scan_b{batch}_nfe{nfe}", scan_us,
             f"loop_us={loop_us:.0f};speedup={loop_us / scan_us:.2f}")

    # fused CFG vs the sequential two-eval loop reference (UniPC-3)
    engine, x_T = _dit_engine(batch=batch, cfg_scale=2.0)
    spec = EngineSpec(solver="unipc", order=3, nfe=nfe, cfg_scale=2.0)
    run = engine.build(spec)
    jax.block_until_ready(run(x_T))
    _, fused_us = timed(lambda: jax.block_until_ready(run(x_T)))
    loop = engine.build_loop(spec)
    _, seq_us = timed(lambda: jax.block_until_ready(loop(x_T)))
    emit(f"engine/cfg_fused_b{batch}_nfe{nfe}", fused_us,
         f"seq_loop_us={seq_us:.0f};speedup={seq_us / fused_us:.2f}")
