"""Bench-regression guard over the committed BENCH_*.json artifacts.

Stdlib-only (no jax import): CI runs it on every push before the heavy
jobs, so a perf-regressing change to the serving stack fails fast even when
the bench itself wasn't rerun.

Checks:
* BENCH_serve.json — for every (arch, cfg_scale) pair, continuous-over-gang
  throughput ratio must stay >= --min-serve-ratio (default 1.1; the
  committed trace sits at ~1.18, so the guard allows drift but not a
  collapse of the continuous-batching win). The async_runs section
  (DESIGN.md §13) must be present, with pipelined (depth-2) throughput
  >= --min-async-ratio x the synchronous depth-1 throughput per arch, and
  the synchronous host bookkeeping overhead <= --max-host-frac of the
  measured tick wall (the pipelined-serving acceptance criteria). Every
  async run must also carry the per-phase host split
  (`host_phase_us_per_tick`: admission/dispatch/readback/bookkeeping,
  DESIGN.md §15) with admission + bookkeeping matching the aggregate
  host_us_per_tick. The obs_runs section must commit the tracing-overhead
  comparison, with `obs_overhead_frac` <= --max-obs-overhead (default
  0.05: tracing is built to stay off the hot path). The
  async floor defaults to 0.95: on runtimes without async dispatch (CPU,
  where the step executes inline in the dispatch call) the expectation is
  parity within noise, and a real pipelining regression (a sync added to
  the hot loop) lands far below it. The host-frac cap defaults to 0.5,
  sized for the reduced-scale CPU tick (~2 ms at dit-cifar, where fixed
  bookkeeping is proportionally largest; dit-i256 sits under 0.1). The
  fault_runs section (DESIGN.md §16) must commit the resilience pricing:
  an armed-but-idle policy layer must cost <= --max-fault-overhead of tick
  wall in extra host time (default 0.02 — checks that never fire must be
  nearly free), and the faulted run — a NaN poisoning plus a forced desync
  — must still have completed EVERY request, with at least one recovery
  and one retry on the ledger.
* BENCH_tuning.json — must be present (the tuning acceptance trajectory is
  committed alongside the serving one); every tuned plan must score <= its
  baseline, and NFE <= 8 rows must improve strictly.
* BENCH_model.json — for every arch, the fast-eval denoiser path
  (flash + fused adaLN) must beat the eager eval wall-clock at dit-i256
  serving shapes (the acceptance criterion of the fast-eval PR); both rows
  must be present and positive. Low-precision rows (flash_fused_bf16 and
  the quant_runs tiers) are judged by the artifact's `env` stamp
  (benchmarks/common.bench_header): on tpu/gpu they must WIN wall-clock —
  they exist to cut the HBM traffic the eval is bound by, so losing there
  is a regression — while on cpu (where XLA rematerializes the casts in
  fp32 arithmetic) the wall-clock is informational and only presence,
  positivity, and the HBM-bytes win are enforced. quant_runs must carry a
  w8 tier for every arch.

    python benchmarks/guard.py [--min-serve-ratio 1.1]
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def fail(msg: str) -> None:
    print(f"GUARD FAIL: {msg}", file=sys.stderr)
    sys.exit(1)


def check_serve(path: str = "BENCH_serve.json",
                min_ratio: float = 1.1,
                min_async_ratio: float = 0.95,
                max_host_frac: float = 0.5,
                max_obs_overhead: float = 0.05,
                max_fault_overhead: float = 0.02) -> int:
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        fail(f"{path} is missing — the serving perf trajectory must stay "
             f"committed (run `python -m benchmarks.run --only serve`)")
    except json.JSONDecodeError as e:
        fail(f"{path} is corrupt: {e}")
    by_key = {}
    for run in data.get("runs", []):
        key = (run.get("arch"), run.get("cfg_scale"))
        by_key.setdefault(key, {})[run.get("mode")] = run
    if not by_key:
        fail(f"{path} carries no runs")
    checked = 0
    for (arch, cfg), modes in sorted(by_key.items()):
        if "continuous" not in modes or "gang" not in modes:
            fail(f"{path} {arch}/cfg{cfg}: needs both continuous and gang "
                 f"runs, has {sorted(modes)}")
        tputs = {m: modes[m].get("throughput_per_tick")
                 for m in ("continuous", "gang")}
        if any(not isinstance(v, (int, float)) or v <= 0
               for v in tputs.values()):
            fail(f"{path} {arch}/cfg{cfg}: throughput_per_tick missing or "
                 f"non-positive ({tputs}) — artifact schema drift?")
        ratio = tputs["continuous"] / tputs["gang"]
        status = "ok" if ratio >= min_ratio else "FAIL"
        print(f"serve {arch}/cfg{cfg}: continuous/gang throughput ratio "
              f"{ratio:.3f} (floor {min_ratio}) {status}")
        if ratio < min_ratio:
            fail(f"continuous-batching throughput ratio dropped to "
                 f"{ratio:.3f} < {min_ratio} for {arch}/cfg{cfg}")
        checked += 1
    # pipelined serving acceptance (DESIGN.md §13): async (depth >= 2) must
    # not lose throughput vs the synchronous loop at saturating arrival, and
    # synchronous host bookkeeping must stay a bounded fraction of tick time
    async_runs = data.get("async_runs")
    if not async_runs:
        fail(f"{path} carries no async_runs — the pipelined-serving "
             f"trajectory must stay committed (run `python -m benchmarks."
             f"run --only serve`)")
    by_arch = {}
    for run in async_runs:
        by_arch.setdefault(run.get("arch"), {})[run.get("pipeline_depth")] = run
    for arch, depths in sorted(by_arch.items()):
        sync = depths.get(1)
        asyn = next((r for d, r in sorted(depths.items()) if d and d >= 2),
                    None)
        if sync is None or asyn is None:
            fail(f"{path} async_runs {arch}: needs a depth-1 and a "
                 f"depth>=2 run, has depths {sorted(depths)}")
        tputs = (sync.get("throughput_rps"), asyn.get("throughput_rps"))
        if any(not isinstance(v, (int, float)) or v <= 0 for v in tputs):
            fail(f"{path} async_runs {arch}: throughput_rps missing or "
                 f"non-positive ({tputs}) — artifact schema drift?")
        ratio = tputs[1] / tputs[0]
        status = "ok" if ratio >= min_async_ratio else "FAIL"
        print(f"serve {arch}: async(depth {asyn['pipeline_depth']})/sync "
              f"throughput ratio {ratio:.3f} (floor {min_async_ratio}) "
              f"{status}")
        if ratio < min_async_ratio:
            fail(f"pipelined serving lost throughput vs the synchronous "
                 f"loop at {arch}: ratio {ratio:.3f} < {min_async_ratio}")
        host_us, tick_s = (sync.get("host_us_per_tick"), sync.get("tick_s"))
        if not all(isinstance(v, (int, float)) and v > 0
                   for v in (host_us, tick_s)):
            fail(f"{path} async_runs {arch}: host_us_per_tick/tick_s "
                 f"missing or non-positive (host_us={host_us}, "
                 f"tick_s={tick_s}) — artifact schema drift?")
        frac = host_us / (tick_s * 1e6)
        status = "ok" if frac <= max_host_frac else "FAIL"
        print(f"serve {arch}: host overhead {host_us:.0f}us/tick = "
              f"{frac:.3f} of tick wall (cap {max_host_frac}) {status}")
        if frac > max_host_frac:
            fail(f"host bookkeeping overhead at {arch} is {frac:.3f} of "
                 f"tick time > {max_host_frac} — the scheduler's host path "
                 f"regressed")
        # per-phase host split (DESIGN.md §15): every async row must carry
        # the measured "where a tick goes" columns, with admission +
        # bookkeeping matching the aggregate host_us_per_tick (the two are
        # derived from the same nanosecond counters — any gap is drift)
        for run in (sync, asyn):
            phases = run.get("host_phase_us_per_tick")
            if not isinstance(phases, dict):
                fail(f"{path} async_runs {arch} depth "
                     f"{run.get('pipeline_depth')}: missing "
                     f"host_phase_us_per_tick — the per-phase host split "
                     f"must stay committed")
            missing = ({"admission", "dispatch", "readback", "bookkeeping"}
                       - set(phases))
            if missing:
                fail(f"{path} async_runs {arch}: host_phase_us_per_tick "
                     f"missing phases {sorted(missing)}")
            if any(not isinstance(v, (int, float)) or v < 0
                   for v in phases.values()):
                fail(f"{path} async_runs {arch}: non-numeric or negative "
                     f"phase times ({phases})")
            split = phases["admission"] + phases["bookkeeping"]
            agg = run.get("host_us_per_tick", 0.0)
            if abs(split - agg) > max(1e-6 * max(agg, 1.0), 1e-9):
                fail(f"{path} async_runs {arch}: admission + bookkeeping "
                     f"({split:.3f}us) != host_us_per_tick ({agg:.3f}us) — "
                     f"the phase split drifted from the aggregate")
        checked += 1
    # observability overhead (DESIGN.md §15): tracing a depth-2 run must add
    # under max_obs_overhead of tick wall in host time vs untraced
    obs_runs = data.get("obs_runs")
    if not obs_runs:
        fail(f"{path} carries no obs_runs — the tracing-overhead trajectory "
             f"must stay committed (run `python -m benchmarks.run --only "
             f"serve`)")
    traced = next((r for r in obs_runs if r.get("traced")), None)
    untraced = next((r for r in obs_runs if r.get("traced") is False), None)
    if traced is None or untraced is None:
        fail(f"{path} obs_runs: needs a traced and an untraced run, has "
             f"traced={[r.get('traced') for r in obs_runs]}")
    frac = traced.get("obs_overhead_frac")
    if not isinstance(frac, (int, float)):
        fail(f"{path} obs_runs: traced run carries no obs_overhead_frac — "
             f"artifact schema drift?")
    status = "ok" if frac <= max_obs_overhead else "FAIL"
    print(f"serve obs: tracing overhead {frac:.4f} of tick wall "
          f"(cap {max_obs_overhead}) {status}")
    if frac > max_obs_overhead:
        fail(f"tracing overhead is {frac:.4f} of tick wall > "
             f"{max_obs_overhead} — the tracer left the cheap path")
    checked += 1
    # resilience pricing (DESIGN.md §16): an armed-but-idle policy layer
    # must be nearly free, and the chaos run must have recovered everything
    fault_runs = data.get("fault_runs")
    if not fault_runs:
        fail(f"{path} carries no fault_runs — the resilience pricing "
             f"trajectory must stay committed (run `python -m benchmarks."
             f"run --only serve`)")
    by_kind = {r.get("resilience"): r for r in fault_runs}
    missing = {"plain", "armed", "faulted"} - set(by_kind)
    if missing:
        fail(f"{path} fault_runs: missing rows {sorted(missing)} — needs "
             f"plain, armed and faulted")
    ff = by_kind["armed"].get("fault_free_overhead_frac")
    if not isinstance(ff, (int, float)):
        fail(f"{path} fault_runs: armed run carries no "
             f"fault_free_overhead_frac — artifact schema drift?")
    status = "ok" if ff <= max_fault_overhead else "FAIL"
    print(f"serve resilience: fault-free overhead {ff:.4f} of tick wall "
          f"(cap {max_fault_overhead}) {status}")
    if ff > max_fault_overhead:
        fail(f"the armed-but-idle resilience layer costs {ff:.4f} of tick "
             f"wall > {max_fault_overhead} — policy checks that never fire "
             f"left the cheap path")
    faulted = by_kind["faulted"]
    comp, reqs_n = faulted.get("completed"), faulted.get("requests")
    if (not isinstance(comp, int) or not isinstance(reqs_n, int)
            or reqs_n <= 0 or comp != reqs_n):
        fail(f"{path} fault_runs: the faulted run must complete every "
             f"request (completed={comp}, requests={reqs_n}) — recovery "
             f"stopped recovering")
    recov, retr = faulted.get("recoveries"), faulted.get("retries")
    rof = faulted.get("recovery_overhead_frac")
    if not all(isinstance(v, (int, float)) for v in (recov, retr, rof)):
        fail(f"{path} fault_runs: faulted run missing recoveries/retries/"
             f"recovery_overhead_frac — artifact schema drift?")
    if recov < 1 or retr < 1:
        fail(f"{path} fault_runs: the faulted run fired no "
             f"recovery/retry (recoveries={recov}, retries={retr}) — the "
             f"injected faults stopped exercising the paths they exist for")
    print(f"serve resilience: faulted run {comp}/{reqs_n} completed, "
          f"{recov} recoveries, {retr} retries, recovery overhead "
          f"{rof:.4f} of ticks ok")
    checked += 1
    return checked


def check_tuning(path: str = "BENCH_tuning.json") -> int:
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        fail(f"{path} is missing — the tuning acceptance trajectory must "
             f"stay committed (run `python -m benchmarks.run --only "
             f"tuning`)")
    except json.JSONDecodeError as e:
        fail(f"{path} is corrupt: {e}")
    checked = 0
    for run in data.get("runs", []):
        nfe = run.get("nfe")
        base, tuned = (run.get("baseline_discrepancy"),
                       run.get("tuned_discrepancy"))
        if not all(isinstance(v, (int, float))
                   for v in (nfe, base, tuned)):
            fail(f"{path} run {run!r}: nfe/baseline_discrepancy/"
                 f"tuned_discrepancy missing — artifact schema drift?")
        ok = tuned <= base and (nfe > 8 or tuned < base)
        print(f"tuning nfe={nfe}: {base:.5f} -> {tuned:.5f} "
              f"{'ok' if ok else 'FAIL'}")
        if tuned > base:
            fail(f"tuned plan regressed the baseline at nfe={nfe}")
        if nfe <= 8 and not tuned < base:
            fail(f"tuned plan must strictly beat the UniPC-2 baseline at "
                 f"nfe={nfe} (acceptance criterion)")
        checked += 1
    # feature-reuse acceptance (DESIGN.md §12): at least one jointly tuned
    # plan must spend strictly fewer full-eval units than its NFE floor,
    # and every cached run must hold discrepancy within its slack of the
    # no-cache tuned anchor
    cached = data.get("cached_runs", [])
    if not cached:
        fail(f"{path} carries no cached_runs — the feature-reuse acceptance "
             f"trajectory must stay committed (run `python -m benchmarks."
             f"run --only tuning`)")
    below_floor = 0
    for run in cached:
        nfe = run.get("nfe")
        nfe_evals, epl, ratio = (run.get("nfe_evals"),
                                 run.get("evals_per_latent"),
                                 run.get("cached_ratio"))
        slack = run.get("cache_slack", 1.1)
        if not all(isinstance(v, (int, float))
                   for v in (nfe, nfe_evals, epl, ratio)):
            fail(f"{path} cached run {run!r}: nfe/nfe_evals/"
                 f"evals_per_latent/cached_ratio missing — artifact schema "
                 f"drift?")
        if ratio > slack:
            fail(f"cached plan at nfe={nfe} overspent the discrepancy "
                 f"slack: ratio {ratio:.3f} > {slack}")
        below = epl < nfe_evals
        below_floor += below
        print(f"tuning cached nfe={nfe}: {epl:.2f} evals/latent vs "
              f"{nfe_evals} uncached (ratio {ratio:.3f} <= {slack}) "
              f"{'ok' if below else '(at floor)'}")
        checked += 1
    if not below_floor:
        fail(f"no cached run holds evals-per-latent strictly below its NFE "
             f"floor (acceptance criterion) — the feature-reuse schedule "
             f"stopped paying for itself")
    return checked


def check_model(path: str = "BENCH_model.json") -> int:
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        fail(f"{path} is missing — the denoiser fast-eval trajectory must "
             f"stay committed (run `python -m benchmarks.run --only model`)")
    except json.JSONDecodeError as e:
        fail(f"{path} is corrupt: {e}")
    env = data.get("env") or {}
    backend = env.get("backend")
    if backend is None:
        print(f"model: {path} has no env stamp — treating as cpu-produced "
              f"(rerun `python -m benchmarks.run --only model` to stamp it)")
        backend = "cpu"
    lowp_enforced = backend in ("tpu", "gpu", "cuda", "rocm")
    lowp_tag = "enforced" if lowp_enforced else f"informational on {backend}"
    by_arch = {}
    for run in data.get("runs", []):
        by_arch.setdefault(run.get("arch"), {})[run.get("mode")] = run
    if not by_arch:
        fail(f"{path} carries no runs")
    checked = 0
    for arch, modes in sorted(by_arch.items()):
        missing = {"eager", "flash_fused", "flash_fused_bf16"} - set(modes)
        if missing:
            fail(f"{path} {arch}: missing eval modes {sorted(missing)} — "
                 f"artifact schema drift?")
        eager, fast = (modes["eager"].get("eval_us"),
                       modes["flash_fused"].get("eval_us"))
        if any(not isinstance(v, (int, float)) or v <= 0
               for v in (eager, fast)):
            fail(f"{path} {arch}: eval_us missing or non-positive "
                 f"(eager={eager}, flash_fused={fast})")
        # the acceptance bar: the fast-eval path must beat eager at the
        # big serving shape; dit-cifar's eval is too small to separate from
        # dispatch noise, so it only has to stay within 15%
        bar = 1.0 if arch == "dit-i256" else 1.15
        ratio = fast / eager
        status = "ok" if ratio < bar else "FAIL"
        print(f"model {arch}: flash_fused/eager eval wall ratio "
              f"{ratio:.3f} (bar {bar}) {status}")
        if ratio >= bar:
            fail(f"fast-eval path no longer beats the eager eval at {arch} "
                 f"({fast:.0f}us vs {eager:.0f}us)")
        # low-precision rule, platform-conditional (env stamp): the bf16
        # eval halves params-side HBM traffic, so on an accelerator it must
        # beat the fp32 fast path; on cpu the measured loss (0.67x at
        # dit-cifar) is the documented cast-remat artifact — informational
        bf16 = modes["flash_fused_bf16"]
        b_us, b_hbm = bf16.get("eval_us"), bf16.get("hbm_bytes")
        f_hbm = modes["flash_fused"].get("hbm_bytes")
        if any(not isinstance(v, (int, float)) or v <= 0
               for v in (b_us, b_hbm, f_hbm)):
            fail(f"{path} {arch}: flash_fused_bf16 eval_us/hbm_bytes "
                 f"missing or non-positive")
        if lowp_enforced and b_hbm >= f_hbm:
            # on cpu the HLO analyzer sees the rematerialized casts as
            # extra traffic, so the bytes win only shows on an accelerator
            fail(f"bf16 eval at {arch} no longer reduces HBM bytes "
                 f"({b_hbm:.3e} >= {f_hbm:.3e}) — the mode lost its reason "
                 f"to exist")
        bratio = fast / b_us
        if lowp_enforced and bratio < 1.0:
            fail(f"bf16 eval loses wall-clock on {backend} at {arch} "
                 f"(x{bratio:.2f} vs fp32 fast path) — low precision must "
                 f"win where it cuts the bound resource")
        print(f"model {arch}: bf16/fp32 speedup x{bratio:.2f}, hbm "
              f"{b_hbm/f_hbm:.2f}x ({lowp_tag})")
        checked += 1
    # quantized denoiser tiers (DESIGN.md §14): a w8 row per arch, HBM +
    # param bytes strictly below fp32; wall-clock enforced on tpu/gpu only
    quant_runs = data.get("quant_runs")
    if not quant_runs:
        fail(f"{path} carries no quant_runs — the quantized-eval trajectory "
             f"must stay committed (run `python -m benchmarks.run --only "
             f"model`)")
    q_by_arch = {}
    for run in quant_runs:
        q_by_arch.setdefault(run.get("arch"), {})[run.get("mode")] = run
    for arch in sorted(by_arch):
        qmodes = q_by_arch.get(arch, {})
        w8 = [m for m in qmodes if m.startswith("w8")]
        if not w8:
            fail(f"{path} quant_runs: no w8 tier for {arch} — artifact "
                 f"schema drift?")
        for m in sorted(qmodes):
            run = qmodes[m]
            q_us, f_us = run.get("eval_us"), run.get("fp32_eval_us")
            qpb, fpb = (run.get("quant_param_bytes"),
                        run.get("fp32_param_bytes"))
            if any(not isinstance(v, (int, float)) or v <= 0
                   for v in (q_us, f_us, qpb, fpb)):
                fail(f"{path} quant_runs {arch}/{m}: eval_us/param_bytes "
                     f"missing or non-positive")
            if qpb >= fpb:
                fail(f"quant tier {m} at {arch} no longer shrinks param "
                     f"bytes ({qpb} >= {fpb})")
            speed = f_us / q_us
            if lowp_enforced and speed < 1.0:
                fail(f"quant tier {m} loses wall-clock on {backend} at "
                     f"{arch} (x{speed:.2f} vs fp32) — low precision must "
                     f"win where it cuts the bound resource")
            print(f"model {arch}: quant {m} x{speed:.2f} vs fp32, params "
                  f"{qpb/fpb:.2f}x ({lowp_tag})")
            checked += 1
    return checked


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--min-serve-ratio", type=float, default=1.1)
    ap.add_argument("--min-async-ratio", type=float, default=0.95,
                    help="floor on pipelined/synchronous throughput at "
                         "saturating arrival (async must not lose)")
    ap.add_argument("--max-host-frac", type=float, default=0.5,
                    help="cap on synchronous host bookkeeping as a fraction "
                         "of measured tick wall time")
    ap.add_argument("--max-obs-overhead", type=float, default=0.05,
                    help="cap on the tracing-enabled host overhead as a "
                         "fraction of tick wall (obs_runs, DESIGN.md §15)")
    ap.add_argument("--max-fault-overhead", type=float, default=0.02,
                    help="cap on the armed-but-idle resilience layer's "
                         "extra host time as a fraction of tick wall "
                         "(fault_runs, DESIGN.md §16)")
    ap.add_argument("--root", default=".")
    args = ap.parse_args()
    os.chdir(args.root)
    n = check_serve(min_ratio=args.min_serve_ratio,
                    min_async_ratio=args.min_async_ratio,
                    max_host_frac=args.max_host_frac,
                    max_obs_overhead=args.max_obs_overhead,
                    max_fault_overhead=args.max_fault_overhead)
    n += check_tuning()
    n += check_model()
    print(f"bench guard ok ({n} checks)")


if __name__ == "__main__":
    main()
