"""Benchmark harness entry point — one function per paper table/figure plus
kernel and roofline benches. Prints ``name,us_per_call,derived`` CSV.

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig3,...]
"""

from __future__ import annotations

import argparse
import sys

sys.path.insert(0, "src")


def main() -> None:
    from . import (bench_engine, bench_figs, bench_kernels, bench_roofline,
                   bench_serve, bench_tables)

    benches = {
        "engine": bench_engine.bench_engine,
        "serve": bench_serve.bench_serve,
        "table1": bench_tables.table1_bh_ablation,
        "table2": bench_tables.table2_unic_any_solver,
        "table3": bench_tables.table3_oracle,
        "table4": bench_tables.table4_order_schedules,
        "table5": bench_tables.table5_more_nfe,
        "fig3": bench_figs.fig3_unconditional,
        "fig4": bench_figs.fig4_guided,
        "free_oracle": bench_figs.free_oracle_study,
        "kernels": lambda: (bench_kernels.kernel_unipc_update(),
                            bench_kernels.kernel_unipc_update_latents(),
                            bench_kernels.kernel_flash_attention(),
                            bench_kernels.kernel_correctness_timing()),
        "roofline": bench_roofline.roofline_table,
    }
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(benches))
    args = ap.parse_args()
    selected = (args.only.split(",") if args.only else list(benches))
    print("name,us_per_call,derived")
    for name in selected:
        benches[name]()


if __name__ == "__main__":
    main()
