"""Benchmark harness entry point — one function per paper table/figure plus
kernel, roofline, serving, and tuning benches. Prints ``name,us_per_call,
derived`` CSV while running, then aggregates every ``BENCH_*.json`` artifact
at the repo root into one summary table.

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig3,...]
    PYTHONPATH=src python -m benchmarks.run --summarize   # aggregate only

A bench that is supposed to write a ``BENCH_*.json`` artifact but didn't —
or an artifact that no longer parses — aborts the run with a nonzero exit
instead of being silently skipped: the JSON artifacts are the tracked perf
trajectory, so a hole in them is a failure, not a gap.
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, "src")

# benches that persist a JSON artifact at the repo root; checked after a run
BENCH_ARTIFACTS = {
    "serve": "BENCH_serve.json",
    "tuning": "BENCH_tuning.json",
    "model": "BENCH_model.json",
}

# extra sections an artifact must carry beyond 'runs' — a bench that stopped
# writing one of these silently dropped part of the tracked trajectory
REQUIRED_SECTIONS = {
    "BENCH_serve.json": ("async_runs", "obs_runs", "fault_runs"),
    "BENCH_model.json": ("quant_runs",),
}


def _load_bench_file(path: str) -> dict:
    """Parse one BENCH_*.json; a corrupt or unreadable artifact is fatal."""
    try:
        with open(path) as f:
            data = json.load(f)
    except FileNotFoundError:
        raise SystemExit(f"bench artifact {path} is missing — rerun "
                         f"`python -m benchmarks.run --only "
                         f"{_bench_for(path)}` to regenerate it")
    except (json.JSONDecodeError, OSError) as e:
        raise SystemExit(f"bench artifact {path} is corrupt ({e}); delete "
                         f"it and rerun the bench")
    if not isinstance(data, dict) or "runs" not in data:
        raise SystemExit(f"bench artifact {path} has no 'runs' table — "
                         f"not a bench artifact?")
    for section in REQUIRED_SECTIONS.get(os.path.basename(path), ()):
        if not data.get(section):
            raise SystemExit(f"bench artifact {path} has no {section!r} "
                             f"section — the bench stopped writing part of "
                             f"its trajectory; rerun `python -m "
                             f"benchmarks.run --only {_bench_for(path)}`")
    return data


def _bench_for(path: str) -> str:
    base = os.path.basename(path)
    for name, artifact in BENCH_ARTIFACTS.items():
        if artifact == base:
            return name
    return "<unknown>"


def summarize(root: str = ".") -> int:
    """Aggregate every BENCH_*.json under `root` into one summary table.

    Returns the number of artifacts summarized; zero artifacts is fatal
    (the committed repo always carries at least BENCH_serve.json).
    """
    paths = sorted(glob.glob(os.path.join(root, "BENCH_*.json")))
    if not paths:
        raise SystemExit(f"no BENCH_*.json artifacts under {root!r}; run "
                         f"`python -m benchmarks.run` first")
    print(f"\n=== bench summary ({len(paths)} artifacts) ===")
    print(f"{'artifact':<22} {'run':<40} {'headline'}")
    for path in paths:
        data = _load_bench_file(path)
        base = os.path.basename(path)
        for run in data["runs"]:
            print(f"{base:<22} {_run_tag(base, run):<40} "
                  f"{_run_headline(base, run)}")
        for run in data.get("quant_runs", []):
            tag = f"{run.get('arch')}/quant_{run.get('mode')}"
            pb = (run.get("quant_param_bytes", 0)
                  / max(run.get("fp32_param_bytes", 1), 1))
            print(f"{base:<22} {tag:<40} "
                  f"eval={run.get('eval_us', 0)/1e3:.2f}ms "
                  f"hbm={run.get('hbm_bytes', 0):.2e}B "
                  f"x{run.get('speedup_vs_fp32', 0):.2f} vs fp32 "
                  f"params x{pb:.2f}")
    return len(paths)


def _run_tag(base: str, run: dict) -> str:
    if base == "BENCH_serve.json":
        return (f"{run.get('arch')}/cfg{run.get('cfg_scale')}"
                f"/{run.get('mode')}")
    if base == "BENCH_tuning.json":
        return f"{run.get('arch')}/nfe{run.get('nfe')}"
    if base == "BENCH_model.json":
        return f"{run.get('arch')}/{run.get('mode')}"
    return ",".join(f"{k}={run[k]}" for k in list(run)[:3])


def _run_headline(base: str, run: dict) -> str:
    if base == "BENCH_serve.json":
        return (f"rps={run.get('throughput_rps', 0):.2f} "
                f"tput/tick={run.get('throughput_per_tick', 0):.3f} "
                f"p95={run.get('latency_s_p95', 0)*1e3:.0f}ms "
                f"occ={run.get('occupancy', 0):.2f}")
    if base == "BENCH_tuning.json":
        return (f"discrepancy {run.get('baseline_discrepancy', 0):.5f}"
                f"->{run.get('tuned_discrepancy', 0):.5f} "
                f"(-{run.get('rel_improvement', 0)*100:.1f}%) "
                f"search={run.get('search_wall_s', 0):.1f}s")
    if base == "BENCH_model.json":
        if run.get("mode") == "attn_traffic":
            return (f"naive={run.get('naive_bytes', 0):.2e}B "
                    f"flash_model={run.get('flash_model_bytes', 0):.2e}B")
        return (f"eval={run.get('eval_us', 0)/1e3:.2f}ms "
                f"hbm={run.get('hbm_bytes', 0):.2e}B "
                f"x{run.get('speedup_vs_eager', 0):.2f} vs eager"
                if "speedup_vs_eager" in run else
                f"eval={run.get('eval_us', 0)/1e3:.2f}ms")
    keys = [k for k, v in run.items() if isinstance(v, (int, float))][:4]
    return " ".join(f"{k}={run[k]:.4g}" for k in keys)


def main() -> None:
    from . import (bench_engine, bench_figs, bench_kernels, bench_model,
                   bench_roofline, bench_serve, bench_tables, bench_tuning)

    benches = {
        "engine": bench_engine.bench_engine,
        "serve": bench_serve.bench_serve,
        "tuning": bench_tuning.bench_tuning,
        "model": bench_model.bench_model,
        "table1": bench_tables.table1_bh_ablation,
        "table2": bench_tables.table2_unic_any_solver,
        "table3": bench_tables.table3_oracle,
        "table4": bench_tables.table4_order_schedules,
        "table5": bench_tables.table5_more_nfe,
        "fig3": bench_figs.fig3_unconditional,
        "fig4": bench_figs.fig4_guided,
        "free_oracle": bench_figs.free_oracle_study,
        "kernels": lambda: (bench_kernels.kernel_unipc_update(),
                            bench_kernels.kernel_unipc_update_latents(),
                            bench_kernels.kernel_flash_attention(),
                            bench_kernels.kernel_correctness_timing()),
        "roofline": bench_roofline.roofline_table,
    }
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(benches))
    ap.add_argument("--summarize", action="store_true",
                    help="skip running benches; aggregate the existing "
                         "BENCH_*.json artifacts and exit")
    args = ap.parse_args()
    if args.summarize:
        summarize()
        return
    selected = (args.only.split(",") if args.only else list(benches))
    unknown = [s for s in selected if s not in benches]
    if unknown:
        ap.error(f"unknown benches {unknown}; choose from "
                 f"{','.join(benches)}")
    print("name,us_per_call,derived")
    for name in selected:
        benches[name]()
        if name in BENCH_ARTIFACTS:
            _load_bench_file(BENCH_ARTIFACTS[name])  # wrote + parses, or die
    summarize()


if __name__ == "__main__":
    main()
