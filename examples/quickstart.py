"""Quickstart: train a tiny DiT on synthetic latents, then sample it with
UniPC at 8 NFE and compare against DDIM using the paper's convergence-error
metric. Runs on CPU in ~2-3 minutes.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.core import DDIM, Grid, UniPC
from repro.diffusion import VPLinear, wrap_model
from repro.launch.train import train
from repro.models import api


def main():
    print("=== 1. train a reduced DiT for 80 steps (diffusion objective) ===")
    params, hist = train("dit-cifar", reduced=True, objective="diffusion",
                         steps=80, batch=16, seq=32, lr=2e-3, log_every=20)
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

    print("=== 2. sample with DDIM vs UniPC-3 at 8 NFE ===")
    cfg = get_config("dit-cifar").reduced()
    sched = VPLinear()
    net = api.eps_network(cfg)
    extra = {"class_ids": jnp.zeros((4,), jnp.int32)}
    eps = jax.jit(lambda x, t: net(params, x, jnp.asarray(t, jnp.float32),
                                   extra))
    model = wrap_model(sched, eps, "data")
    x_T = jax.random.normal(jax.random.PRNGKey(0),
                            (4, cfg.patch_tokens, cfg.latent_dim))
    ref = np.asarray(DDIM(model, Grid.build(sched, 200),
                          prediction="data").sample(x_T))
    D = np.sqrt(ref.size)
    for name, run in {
        "ddim": lambda: DDIM(model, Grid.build(sched, 8),
                             prediction="data").sample(x_T),
        "unipc-3": lambda: UniPC(model, Grid.build(sched, 8), order=3,
                                 prediction="data").sample_pc(
                                     x_T, use_corrector=True),
    }.items():
        t0 = time.time()
        x0 = np.asarray(run())
        err = np.linalg.norm(x0 - ref) / D
        print(f"{name:10s} NFE=8  conv-err={err:.5f}  wall={time.time()-t0:.1f}s")
    print("UniPC should show a clearly lower convergence error.")


if __name__ == "__main__":
    main()
