"""Solver-plan autotuning demo: search -> saved plan -> sample with it.

Walks the full loop on the reduced dit-cifar backbone:

1. briefly train the eps-net (random init gives a near-linear ODE where
   every plan ties at fp32 noise);
2. search the per-step decision space for an NFE-8 plan, starting from the
   hand-set UniPC-2 baseline, scored by trajectory discrepancy against a
   high-NFE reference;
3. save the winner as JSON and sample with it — exactly what
   `python -m repro.launch.sample --arch dit-cifar --plan plan8.json` does;
4. tune a fast/balanced/quality bank and serve a mixed-tier Poisson trace
   from ONE compiled step program.

    PYTHONPATH=src python examples/tune_solver.py --budget 40

Runs on CPU in a couple of minutes at the default budget.
"""

import argparse
import sys

sys.path.insert(0, "src")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="dit-cifar")
    ap.add_argument("--nfe", type=int, default=8)
    ap.add_argument("--budget", type=int, default=40)
    ap.add_argument("--train-steps", type=int, default=100)
    ap.add_argument("--plan-out", default="plan8.json")
    ap.add_argument("--bank-out", default="bank.json")
    args = ap.parse_args()

    from repro.launch.sample import sample
    from repro.launch.serve import serve_diffusion
    from repro.launch.tune import tune, tune_bank
    from repro.tuning import save_bank

    # -- 1+2: search one NFE budget -------------------------------------
    plan, report = tune(args.arch, nfe=args.nfe, budget=args.budget,
                        train_steps=args.train_steps)
    print(f"tuned nfe={args.nfe}: discrepancy "
          f"{report['baseline']:.5f} (UniPC-2 baseline) -> "
          f"{report['tuned']:.5f} in {report['evals']} evals")

    # -- 3: save + sample with the plan ---------------------------------
    plan.save(args.plan_out)
    print(f"saved {args.plan_out}; sampling with it:")
    sample(args.arch, reduced=True, plan=args.plan_out, batch=2)

    # -- 4: a tuned tier bank, served as one program --------------------
    plans, reports = tune_bank(args.arch,
                               {"fast": 5, "balanced": args.nfe},
                               budget=args.budget // 2,
                               train_steps=args.train_steps)
    save_bank(args.bank_out, plans)
    for rep in reports:
        print(f"tier {rep['tier']}: {rep['baseline']:.5f} -> "
              f"{rep['tuned']:.5f}")
    print(f"saved {args.bank_out}; serving a mixed-tier trace:")
    serve_diffusion(args.arch, reduced=True, batch=4, plan_bank=args.bank_out,
                    arrival_rate=0.5, requests=8)


if __name__ == "__main__":
    main()
