"""Solver-zoo comparison on an analytic DPM: every solver in the repo, with
and without the method-agnostic UniC, plus the engine's scan-compiled path —
a miniature of the paper's Table 2 and Figure 3 that runs in seconds on CPU
with machine-checkable ground truth. The `scan` column is the same solver
compiled to a per-step weight table and run through the production
`lax.scan` + fused-update path (DESIGN.md §8): it should agree with `plain`
to fp32 accuracy.

    PYTHONPATH=src python examples/sample_comparison.py --nfe 8
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.core import (DDIM, DEIS, DPMSolverPP, DPMSolverSinglestep, PNDM,
                        Grid, UniPC)
from repro.core.solver import CorrectorConfig
from repro.diffusion import GaussianDPM, VPLinear
from repro.engine import EngineSpec, SamplerEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--nfe", type=int, default=12)
    args = ap.parse_args()
    sched = VPLinear()
    dpm = GaussianDPM(sched)
    x_T = np.random.default_rng(0).normal(size=(512,))
    eps = lambda x, t: dpm.eps_model(np.asarray(x, np.float64), t)

    def dm(x, t):
        a, s = float(sched.alpha(t)), float(sched.sigma(t))
        return (np.asarray(x, np.float64) - s * eps(x, t)) / a

    def eps_jx(x, t):  # the same analytic model, traceable for the scan path
        t = jnp.asarray(t)
        a = jnp.exp(sched.log_alpha_jax(t))
        sig = jnp.sqrt(1 - a * a)
        return sig * (x - a * dpm.mu) / (a * a * dpm.s ** 2 + sig * sig)

    engine = SamplerEngine(sched, eps=eps_jx)

    # zoo rows: loop constructor, UniC order, engine spec for the scan column
    zoo = {
        "ddim (order 1)": (lambda g: DDIM(eps, g, prediction="noise"), 1,
                           EngineSpec(solver="ddim", order=1, nfe=args.nfe)),
        "dpm-solver++ 2M": (lambda g: DPMSolverPP(dm, g, order=2), 2,
                            EngineSpec(solver="dpmpp", order=2, nfe=args.nfe)),
        "dpm-solver++ 3M": (lambda g: DPMSolverPP(dm, g, order=3), 3,
                            EngineSpec(solver="dpmpp", order=3, nfe=args.nfe)),
        # the engine compiles G = nfe // order grid steps; feed it the same
        # clamped grid the loop rows below use so the columns stay comparable
        "dpm-solver 3S": (lambda g: DPMSolverSinglestep(
            eps, g, sched, order=3, prediction="noise"), 3,
            EngineSpec(solver="dpm", order=3,
                       nfe=3 * max(2, args.nfe // 3))),
        "pndm": (lambda g: PNDM(eps, g), 4,
                 EngineSpec(solver="pndm", nfe=args.nfe)),
        "deis tAB3": (lambda g: DEIS(eps, g, sched, order=3), 3,
                      EngineSpec(solver="deis", order=3, nfe=args.nfe)),
        "unipc-3 (ours)": (None, 3,
                           EngineSpec(solver="unipc", order=3, nfe=args.nfe)),
    }

    def rms(a, ref):
        return float(np.sqrt(np.mean((np.asarray(a) - ref) ** 2)))

    print(f"NFE={args.nfe}; RMS error vs exact ODE solution, lower is better")
    print(f"{'solver':24s} {'plain':>12s} {'+UniC':>12s} {'scan':>12s}")
    for name, (mk, order, spec) in zoo.items():
        g = Grid.build(sched, args.nfe)
        ref = dpm.exact_solution(x_T, g.t[-1])
        if mk is None:
            u = UniPC(dm, g, order=3, prediction="data")
            plain = rms(u.sample_pc(x_T, use_corrector=False), ref)
            u2 = UniPC(dm, Grid.build(sched, args.nfe), order=3,
                       prediction="data")
            cor = rms(u2.sample_pc(x_T, use_corrector=True), ref)
        else:
            steps = args.nfe if "3S" not in name else max(2, args.nfe // 3)
            s = mk(Grid.build(sched, steps))
            plain = rms(s.sample(x_T), ref)
            s2 = mk(Grid.build(sched, steps))
            cor = rms(s2.sample(x_T, corrector=CorrectorConfig(order=order)),
                      ref)
        scan = rms(engine.build(spec)(jnp.asarray(x_T, jnp.float32)), ref)
        print(f"{name:24s} {plain:12.3e} {cor:12.3e} {scan:12.3e}")


if __name__ == "__main__":
    main()
