"""End-to-end driver: train a diffusion language model (the UniPC framework's
training workload) for a few hundred steps, checkpoint it, reload, and sample
token sequences with UniPC (non-autoregressive denoising + rounding).

Reduced scale by default (CPU, ~5 min with --steps 200). On real hardware use
--full --arch olmo-1b for a ~1B-parameter run with the same code path; the
dry-run (repro.launch.dryrun) proves the full configs shard on the 256/512-chip
meshes.

    PYTHONPATH=src python examples/train_diffusion_lm.py --steps 200
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.registry import get_config
from repro.core import make_unipc_schedule, unipc_sample_scan
from repro.diffusion import VPLinear
from repro.launch.train import train
from repro.models import api


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--nfe", type=int, default=10)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="results/diffusion_lm_ckpt")
    args = ap.parse_args()

    print(f"=== training diffusion-LM on {args.arch} "
          f"({'full' if args.full else 'reduced'}) ===")
    params, hist = train(args.arch, reduced=not args.full,
                         objective="diffusion", steps=args.steps,
                         batch=args.batch, seq=args.seq, lr=1e-3,
                         ckpt_dir=args.ckpt_dir, log_every=20)
    print(f"loss: {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")

    print("=== reload checkpoint ===")
    tree, step = ckpt.restore(args.ckpt_dir)
    params = tree["params"]
    print(f"restored step={step}")

    print(f"=== UniPC sampling ({args.nfe} NFE, production scan path) ===")
    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    sched = VPLinear()
    net = api.eps_network(cfg)
    B, S = 4, args.seq

    def data_model(x, t):
        a, sg = sched.alpha_sigma_jax(jnp.asarray(t, jnp.float32))
        return (x - sg * net(params, x, t, {})) / a

    us = make_unipc_schedule(sched, args.nfe, order=3, prediction="data",
                             variant="bh2")
    x_T = jax.random.normal(jax.random.PRNGKey(0), (B, S, cfg.latent_dim))
    x0 = unipc_sample_scan(jax.jit(data_model), x_T, us)
    # rounding: nearest token latent (Diffusion-LM decoding)
    logits = jnp.einsum("bsl,vl->bsv", x0,
                        params["token_latents"].astype(jnp.float32))
    tokens = np.asarray(jnp.argmax(logits, -1))
    print("sampled token grid (first 2 rows, 16 cols):")
    print(tokens[:2, :16])
    uniq = len(np.unique(tokens))
    print(f"distinct tokens: {uniq} / vocab {cfg.vocab_size} — "
          f"finite: {np.isfinite(np.asarray(x0)).all()}")


if __name__ == "__main__":
    main()
