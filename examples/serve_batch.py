"""Batched serving demo across architecture families: prefill a prompt batch,
decode greedily with the per-family cache (KV / SSD-state / hybrid), report
per-token latency.

    PYTHONPATH=src python examples/serve_batch.py --archs olmo-1b mamba2-780m zamba2-7b
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", nargs="+",
                    default=["olmo-1b", "mamba2-780m", "zamba2-7b",
                             "mixtral-8x7b", "whisper-small"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    for arch in args.archs:
        print(f"--- {arch} ---")
        out = serve(arch, reduced=True, batch=args.batch,
                    prompt_len=args.prompt_len, gen=args.gen, temperature=0.8)
        print(f"generated shape {out.shape}; first row: {out[0][:10]}")


if __name__ == "__main__":
    main()
