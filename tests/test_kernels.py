"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.unipc_update import ops as up_ops, ref as up_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("K,shape", [
    (2, (128,)), (3, (4, 100)), (5, (2, 7, 33)), (6, (1, 2048)),
    (4, (3, 128, 130)),
])
def test_unipc_update_sweep(K, shape, dtype):
    rng = jax.random.PRNGKey(K)
    t = jax.random.normal(rng, (K,) + shape, jnp.float32).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(K + 7), (K,), jnp.float32)
    got = up_ops.weighted_combine(t, w, force_pallas=True)
    want = up_ref.weighted_combine(t, w)
    assert got.dtype == want.dtype == dtype
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,D,causal,window", [
    (1, 4, 2, 128, 128, 64, True, None),      # GQA causal
    (2, 4, 4, 256, 256, 32, True, None),      # MHA causal
    (1, 2, 1, 200, 200, 64, True, None),      # padded (non-multiple) seq
    (1, 4, 2, 128, 384, 64, False, None),     # cross-attention shape
    (1, 4, 4, 256, 256, 64, True, 96),        # sliding window
    (1, 8, 1, 128, 128, 128, True, None),     # MQA, wide head
])
def test_flash_attention_sweep(B, Hq, Hkv, Sq, Skv, D, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Skv, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Skv, D), jnp.float32).astype(dtype)
    got = fa_ops.attention(q, k, v, causal=causal, window=window,
                           force_pallas=True)
    want = fa_ref.attention(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_matches_model_sdpa():
    """The kernel agrees with the model-side sdpa (different layout)."""
    from repro.models.layers import sdpa
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    B, Hq, Hkv, S, D = 2, 4, 2, 128, 32
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    want = sdpa(q, k, v, causal=True)
    got = fa_ops.attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                           v.transpose(0, 2, 1, 3), causal=True,
                           force_pallas=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_fused_update_in_scan_sampler(vp):
    """unipc_sample_scan with the fused Pallas update == jnp path."""
    import functools
    from repro.core import make_unipc_schedule, unipc_sample_scan
    from repro.kernels.unipc_update import ops as uops

    def eps(x, t):
        a = jnp.exp(vp.log_alpha_jax(jnp.asarray(t)))
        sig = jnp.sqrt(1 - a * a)
        return sig * (x - a * 0.7) / (a * a * 0.35 ** 2 + sig * sig)

    x_T = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
    us = make_unipc_schedule(vp, 6, order=2, prediction="noise")
    ref_out = unipc_sample_scan(eps, x_T, us, fused_update=False)
    # monkeypatch dispatch: force the Pallas interpret path inside the scan
    orig = uops.weighted_combine
    uops.weighted_combine = functools.partial(orig, force_pallas=True)
    try:
        fused_out = unipc_sample_scan(eps, x_T, us, fused_update=True)
    finally:
        uops.weighted_combine = orig
    np.testing.assert_allclose(np.asarray(fused_out), np.asarray(ref_out),
                               rtol=1e-5, atol=1e-5)
