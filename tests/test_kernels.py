"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.unipc_update import ops as up_ops, ref as up_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("K,shape", [
    (2, (128,)), (3, (4, 100)), (5, (2, 7, 33)), (6, (1, 2048)),
    (4, (3, 128, 130)),
])
def test_unipc_update_sweep(K, shape, dtype):
    rng = jax.random.PRNGKey(K)
    t = jax.random.normal(rng, (K,) + shape, jnp.float32).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(K + 7), (K,), jnp.float32)
    got = up_ops.weighted_combine(t, w, force_pallas=True)
    want = up_ref.weighted_combine(t, w)
    assert got.dtype == want.dtype == dtype
    tol = 1e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,Sq,Skv,D,causal,window", [
    (1, 4, 2, 128, 128, 64, True, None),      # GQA causal
    (2, 4, 4, 256, 256, 32, True, None),      # MHA causal
    (1, 2, 1, 200, 200, 64, True, None),      # padded (non-multiple) seq
    (1, 4, 2, 128, 384, 64, False, None),     # cross-attention shape
    (1, 4, 4, 256, 256, 64, True, 96),        # sliding window
    (1, 8, 1, 128, 128, 128, True, None),     # MQA, wide head
])
def test_flash_attention_sweep(B, Hq, Hkv, Sq, Skv, D, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Hq, Sq, D), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Hkv, Skv, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Hkv, Skv, D), jnp.float32).astype(dtype)
    got = fa_ops.attention(q, k, v, causal=causal, window=window,
                           force_pallas=True)
    want = fa_ref.attention(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_matches_model_sdpa():
    """The kernel agrees with the model-side sdpa (different layout)."""
    from repro.models.layers import sdpa
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    B, Hq, Hkv, S, D = 2, 4, 2, 128, 32
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    want = sdpa(q, k, v, causal=True)
    got = fa_ops.attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                           v.transpose(0, 2, 1, 3), causal=True,
                           force_pallas=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_fused_update_in_scan_sampler(vp):
    """unipc_sample_scan with the fused Pallas update == jnp path."""
    import functools
    from repro.core import make_unipc_schedule, unipc_sample_scan
    from repro.kernels.unipc_update import ops as uops

    def eps(x, t):
        a = jnp.exp(vp.log_alpha_jax(jnp.asarray(t)))
        sig = jnp.sqrt(1 - a * a)
        return sig * (x - a * 0.7) / (a * a * 0.35 ** 2 + sig * sig)

    x_T = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
    us = make_unipc_schedule(vp, 6, order=2, prediction="noise")
    ref_out = unipc_sample_scan(eps, x_T, us, fused_update=False)
    # monkeypatch dispatch: force the Pallas interpret path inside the scan
    orig = uops.weighted_combine
    uops.weighted_combine = functools.partial(orig, force_pallas=True)
    try:
        fused_out = unipc_sample_scan(eps, x_T, us, fused_update=True)
    finally:
        uops.weighted_combine = orig
    np.testing.assert_allclose(np.asarray(fused_out), np.asarray(ref_out),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("K,shape", [
    (5, (2049,)),                 # 1D, one full tile + 1-lane remainder
    (3, (3, 2178,)),              # batched, remainder tile of 130
    (4, (2, 5, 1000)),            # batched, sub-tile rows (remainder only)
    (5, (3, 64, 48)),             # dit-cifar latent batch (N = 3072)
    (5, (2, 256, 32)),            # dit-i256 latent batch (N = 8192)
])
def test_unipc_update_remainder_tiles(K, shape):
    """Arbitrary (non multiple of 16*128) per-sample sizes: the boundary tile
    is padded on load and masked on store, never shifted onto valid lanes."""
    rng = jax.random.PRNGKey(K)
    t = jax.random.normal(rng, (K,) + shape, jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(K + 7), (K,), jnp.float32)
    got = up_ops.weighted_combine(t, w, force_pallas=True)
    want = up_ref.weighted_combine(t, w)
    assert got.shape == shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_unipc_update_bf16_accumulates_fp32():
    """bf16 terms: the kernel must accumulate in fp32 — its output matches the
    fp32-accumulated oracle on the same bf16 inputs to cast precision, far
    tighter than a bf16-accumulated chain would land."""
    K, shape = 6, (2, 4, 1000)
    t = jax.random.normal(jax.random.PRNGKey(0), (K,) + shape,
                          jnp.float32).astype(jnp.bfloat16)
    w = jax.random.normal(jax.random.PRNGKey(1), (K,), jnp.float32)
    got = up_ops.weighted_combine(t, w, force_pallas=True)
    assert got.dtype == jnp.bfloat16
    want_f32 = jnp.tensordot(w, t.astype(jnp.float32), axes=1)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want_f32), rtol=1e-2, atol=1e-2)
    # and bit-parity with the oracle, which uses the same fp32 accumulation
    want = up_ref.weighted_combine(t, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=1e-6, atol=1e-6)


def test_unipc_update_dispatch():
    """select_backend policy + explicit backend pinning."""
    from repro.kernels.unipc_update.kernel import TILE
    assert up_ops.select_backend(1 << 20, "cpu") == "jnp"
    assert up_ops.select_backend(1 << 20, "gpu") == "jnp"
    assert up_ops.select_backend(1 << 20, "tpu") == "pallas"
    assert up_ops.select_backend(TILE - 1, "tpu") == "jnp"  # sub-tile state
    t = jax.random.normal(jax.random.PRNGKey(0), (3, 2, 300))
    w = jax.random.normal(jax.random.PRNGKey(1), (3,))
    want = up_ref.weighted_combine(t, w)
    for backend in ("jnp", "interpret"):
        got = up_ops.weighted_combine(t, w, backend=backend)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)
    with pytest.raises(ValueError):
        up_ops.weighted_combine(t, w, backend="cuda")


@pytest.mark.parametrize("order", [1, 2, 3])
def test_scan_fused_default_matches_jnp_path(vp, order, monkeypatch):
    """Acceptance: unipc_sample_scan(fused_update=True) == the inline jnp
    op-chain to <= 1e-5 at fp32 on a non-tile-aligned latent shape, with the
    kernel (interpret mode) actually on the dispatched path, orders 1-3."""
    import functools
    from repro.core import make_unipc_schedule, unipc_sample_scan
    from repro.kernels.unipc_update import ops as uops

    def data(x, t):
        a = jnp.exp(vp.log_alpha_jax(jnp.asarray(t)))
        sig = jnp.sqrt(1 - a * a)
        eps = sig * (x - a * 0.4) / (a * a * 0.5 ** 2 + sig * sig)
        return (x - sig * eps) / a

    x_T = jax.random.normal(jax.random.PRNGKey(order), (2, 7, 9))
    us = make_unipc_schedule(vp, 7, order=order, prediction="data")
    ref_out = unipc_sample_scan(data, x_T, us, fused_update=False)
    monkeypatch.setattr(uops, "weighted_combine",
                        functools.partial(uops.weighted_combine,
                                          force_pallas=True))
    fused_out = unipc_sample_scan(data, x_T, us, fused_update=True)
    np.testing.assert_allclose(np.asarray(fused_out), np.asarray(ref_out),
                               rtol=1e-5, atol=1e-5)
