"""Pipelined serving loop: depth-N scheduling is bit-identical to depth 1.

The tentpole property (DESIGN.md §13): every pipeline depth drives the SAME
compiled `step_flight` program over the SAME host-predicted admission
schedule, so finished latents, completion order, per-request bookkeeping,
and every tick-denominated metric are bit-identical across depths — the
only thing depth changes is WHEN the trailing readback stream is consumed.
Plus the mechanics that make it work: mid-flight admission (arrivals fold
into the next tick without draining the pipeline), one batched readback per
completing tick, dispatch-stamped completion clocks, and the done-mask
cross-check between device and host bookkeeping.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.diffusion import GaussianDPM
from repro.engine import EngineSpec, SamplerEngine
from repro.serving import Request, SlotScheduler, poisson_requests, run_trace

from test_serving import _cfg_engine, _eps_jx, _tier_specs, _x_T

DEPTHS = (1, 2, 3)


def _metric_key(m):
    """The deterministic (tick-denominated) slice of ServeMetrics — the
    fields that must be EXACTLY equal across pipeline depths."""
    return (m.mode, m.requests, m.completed, m.slots, m.n_rows, m.ticks,
            m.evals, m.makespan_ticks, m.throughput_per_tick,
            m.latency_ticks_p50, m.latency_ticks_p95, m.occupancy,
            m.evals_per_latent, m.per_tier)


def _completion_key(c):
    return (c.rid, c.arrival, c.admit_tick, c.finish_tick, c.finish_clock,
            c.evals, c.tier, c.eval_cost,
            c.ok, c.retries, c.requeues, c.first_tier, c.fail_reason)


def _run_at_depth(make_sched, reqs, depth):
    sched = make_sched(depth)
    m = run_trace(sched, reqs())
    assert sched.in_flight == 0  # run_trace flushed the readback stream
    return sched, m


@pytest.mark.parametrize("solver,order", [("unipc", 3), ("dpmpp", 2)])
def test_depths_bit_identical_on_poisson_trace(gaussian_dpm, solver, order):
    """Latents, completion order, bookkeeping, and metrics at depths 1/2/3
    are EXACTLY equal (np.testing.assert_array_equal, not allclose) on a
    staggered Poisson trace."""
    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    program = eng.build_step(EngineSpec(solver=solver, order=order, nfe=7))

    def make(depth):
        return SlotScheduler(program, 3, (8,), pipeline_depth=depth)

    def reqs():
        return [Request(rid=r.rid, arrival=r.arrival, x_T=_x_T(r.rid))
                for r in poisson_requests(9, rate=0.5, seed=5)]

    base, m0 = _run_at_depth(make, reqs, 1)
    assert m0.completed == 9 and m0.pipeline_depth == 1
    for depth in DEPTHS[1:]:
        sched, m = _run_at_depth(make, reqs, depth)
        assert m.pipeline_depth == depth
        assert _metric_key(m) == _metric_key(m0)
        assert ([_completion_key(c) for c in sched.completions]
                == [_completion_key(c) for c in base.completions])
        for a, b in zip(base.completions, sched.completions):
            np.testing.assert_array_equal(a.latent, b.latent)


def test_depths_bit_identical_with_tiers_and_cfg(vp):
    """The composed case: a plan-bank (tiered) program with per-request
    guidance scales — per-tier metrics and eval_cost included in the
    cross-depth equality."""
    eng = _cfg_engine(vp)
    tiers = {k: EngineSpec(solver="unipc", nfe=s.nfe, order=s.order,
                           cfg_scale=2.0)
             for k, s in _tier_specs().items()}
    program = eng.build_bank(tiers)
    names = ["fast", "balanced", "quality"]
    scales = [1.0, 2.0, 3.5]

    def make(depth):
        return SlotScheduler(program, 3, (8,), pipeline_depth=depth)

    def reqs():
        return [Request(rid=i, arrival=float(a), x_T=_x_T(i),
                        tier=names[i % 3], cfg_scale=scales[i % 3])
                for i, a in enumerate([0, 0, 1, 3, 4, 8, 9])]

    base, m0 = _run_at_depth(make, reqs, 1)
    assert m0.completed == 7
    assert m0.per_tier is not None and set(m0.per_tier) == set(names)
    for depth in DEPTHS[1:]:
        sched, m = _run_at_depth(make, reqs, depth)
        assert _metric_key(m) == _metric_key(m0)  # incl. per_tier dicts
        assert ([_completion_key(c) for c in sched.completions]
                == [_completion_key(c) for c in base.completions])
        for a, b in zip(base.completions, sched.completions):
            np.testing.assert_array_equal(a.latent, b.latent)


def test_mid_flight_admission_does_not_drain_the_pipeline(gaussian_dpm):
    """An arrival while ticks are in flight is admitted on the very next
    tick — not delayed to a pipeline drain boundary — so its latency equals
    the compiled budget exactly."""
    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    program = eng.build_step(EngineSpec(solver="unipc", order=2, nfe=6))
    sched = SlotScheduler(program, 2, (8,), pipeline_depth=3)
    sched.submit(Request(rid=0, x_T=_x_T(0)))
    sched.tick()
    sched.tick()
    assert sched.in_flight == 2  # a full-depth-minus-one pipeline
    # B arrives mid-flight: admission must fold into the NEXT tick's scatter
    sched.submit(Request(rid=1, x_T=_x_T(1)))
    sched.tick()
    assert sched.in_flight == 2  # pipeline stayed full — nothing drained
    assert not sched.queue  # admitted, not still queued
    assert sched.slot_req[1] is not None and sched.slot_req[1].rid == 1
    done = sched.drain()
    got = {c.rid: c for c in done}
    # rid 1 was admitted into the very next dispatched tick (admit_tick is
    # the pre-tick counter: 2 ticks had run when it folded in) and finished
    # exactly n_rows ticks later — zero drain-boundary delay
    assert got[1].admit_tick == 2
    assert got[1].finish_tick == 2 + program.n_rows
    # and the mid-flight admission reproduced the uniform scan bit-for-bit
    ref = np.asarray(eng.build(EngineSpec(solver="unipc", order=2, nfe=6))(
        jnp.asarray(_x_T(1))[None, :]))[0]
    np.testing.assert_allclose(got[1].latent, ref, atol=1e-5, rtol=0)


def test_trailing_readback_defers_emission_by_depth(gaussian_dpm):
    """At depth 2 a completion is emitted one tick AFTER the tick that
    finished it (or at flush), with finish_tick/clock stamped at dispatch."""
    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    program = eng.build_step(EngineSpec(solver="unipc", order=2, nfe=4))
    n = program.n_rows
    sched = SlotScheduler(program, 2, (8,), pipeline_depth=2)
    sched.submit(Request(rid=0, x_T=_x_T(0)))
    emitted = []
    for _ in range(n):
        emitted += sched.tick()
    # the finishing tick's readback is still in flight at depth 2
    assert emitted == [] and sched.in_flight >= 1
    assert sched.active == 0  # host prediction already freed the slot
    done = sched.flush()
    assert [c.rid for c in done] == [0]
    assert done[0].finish_tick == n  # dispatch-stamped, not emission-stamped
    # depth 1 on the same trace emits the identical completion immediately
    ref = SlotScheduler(program, 2, (8,), pipeline_depth=1)
    ref.submit(Request(rid=0, x_T=_x_T(0)))
    ref_done = []
    for _ in range(n):
        ref_done += ref.tick()
    assert [c.finish_tick for c in ref_done] == [n]
    np.testing.assert_array_equal(done[0].latent, ref_done[0].latent)


def test_simultaneous_completions_ride_one_flight(gaussian_dpm):
    """Slots finishing on the same tick share ONE batched readback (the
    satellite fix for the per-slot device_get): a single flight record
    carries all of them, already in slot order."""
    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    program = eng.build_step(EngineSpec(solver="unipc", order=2, nfe=5))
    sched = SlotScheduler(program, 3, (8,), pipeline_depth=2)
    for r in range(3):  # all admitted tick 1 -> all finish the same tick
        sched.submit(Request(rid=r, x_T=_x_T(r)))
    for _ in range(program.n_rows):
        sched.tick()
    [flight] = list(sched._inflight)
    assert flight.slots.tolist() == [0, 1, 2]
    assert flight.lat is not None and flight.lat.shape[0] == 3
    done = sched.flush()
    assert [c.rid for c in done] == [0, 1, 2]
    assert len({c.finish_tick for c in done}) == 1


def test_done_mask_desync_raises(gaussian_dpm):
    """The device done mask is cross-checked against the host prediction at
    consumption: under recovery='raise' (the pre-resilience escape hatch,
    DESIGN.md §16) a step override whose mask disagrees must raise
    immediately, naming the desync — never silently emit wrong latents.
    The default recovery='recover' path is covered in test_resilience.py."""
    from repro.serving import ResilienceConfig

    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    program = eng.build_step(EngineSpec(solver="unipc", order=2, nfe=4))

    def lying_step(state, meta, g=None, extras=None):
        state, meta, done = program.step_flight(state, meta, g, extras)
        return state, meta, jnp.zeros_like(done)  # device says: nobody done

    sched = SlotScheduler(program, 2, (8,), step_override=lying_step,
                          resilience=ResilienceConfig(recovery="raise"))
    sched.submit(Request(rid=0, x_T=_x_T(0)))
    with pytest.raises(RuntimeError, match="done mask"):
        sched.drain()


def test_depth_zero_rejected(gaussian_dpm):
    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    program = eng.build_step(EngineSpec(solver="unipc", order=1, nfe=3))
    with pytest.raises(ValueError, match="pipeline_depth"):
        SlotScheduler(program, 2, (8,), pipeline_depth=0)
    # default stays the synchronous loop — depth is opt-in
    assert SlotScheduler(program, 2, (8,)).pipeline_depth == 1
