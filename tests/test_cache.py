"""Feature-reuse eval caching (DESIGN.md §12): the DiT cache boundary, the
engine's cached model path, joint plan tuning, eval-cost accounting, and
cached-bank serving.

The two acceptance properties (ISSUE 6):

* parity — with every step full (cache_depth all zero, or a plain registry
  table on a cache-wired spec), the cached path reproduces the uncached eval
  BIT-identically at fp32: full evals take the freshly computed deep
  activations directly, never a cache reconstruction;
* accounting — evals-per-latent of a plan with shallow steps is strictly
  below its NFE floor and agrees across `SolverPlan.eval_cost`,
  `core.coeffs.eval_cost_rows`, `StepProgram.span_cost`, and the scheduler's
  per-request `Completion.eval_cost`.

Every output-parity test perturbs the params: the adaLN-zero init makes a
fresh DiT block an exact identity, so an unperturbed deep segment contributes
nothing and shallow == full vacuously.
"""

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.core.coeffs import eval_cost_rows
from repro.diffusion import VPLinear
from repro.engine import EngineSpec, SamplerEngine
from repro.launch.sample import build_engine
from repro.models import api
from repro.models.dit import dit_apply, dit_apply_cached, dit_cache_shape
from repro.serving import Request, SlotScheduler, run_trace
from repro.tuning import SolverPlan


def _noisy(params, rng, scale=0.02):
    """Perturb every float leaf (see module docstring: adaLN-zero identity)."""
    leaves, treedef = jax.tree.flatten(params)
    ks = jax.random.split(rng, len(leaves))
    return jax.tree.unflatten(treedef, [
        a + scale * jax.random.normal(k, a.shape, a.dtype)
        if jnp.issubdtype(a.dtype, jnp.floating) else a
        for a, k in zip(leaves, ks)])


@pytest.fixture(scope="module")
def dit_setup():
    """(cfg, params) with perturbed weights; model-level tests index
    params["backbone"], engine-level ones pass the full tree."""
    cfg = get_config("dit-cifar").reduced()
    params = _noisy(api.init_params(cfg, jax.random.PRNGKey(0)),
                    jax.random.PRNGKey(1))
    return cfg, params


@pytest.fixture(scope="module")
def dit_classless():
    """Class-free DiT params: the baked per-slot class ids become no-ops, so
    a batch-1 uniform reference scan is comparable with any slot count."""
    from repro.models.dit import init_dit

    cfg = get_config("dit-cifar").reduced()
    params = {"backbone": _noisy(init_dit(cfg, jax.random.PRNGKey(0),
                                          num_classes=0),
                                 jax.random.PRNGKey(1))}
    return cfg, params


def _engine(cfg, params, batch=2, cache_block=1, seed=0):
    return build_engine(cfg, params, VPLinear(), batch, seed,
                        cache_block=cache_block)


def _x(cfg, batch=2, seed=2):
    return jax.random.normal(jax.random.PRNGKey(seed),
                             (batch, cfg.patch_tokens, cfg.latent_dim),
                             jnp.float32)


def _cached_plan(nfe=4, order=2, k=1):
    """Full init + first body step, shallow everywhere after."""
    p = SolverPlan.default(nfe, order=order)
    return replace(p, cache_depth=[0] + [k] * (nfe - 1))


# ---------------------------------------------------------------------------
# model level: the cache boundary itself
# ---------------------------------------------------------------------------


def test_full_eval_is_bit_identical_and_fills_cache(dit_setup):
    """reuse=0 through the cached path == dit_apply bitwise (eager and jit),
    and the returned cache is the deep residual delta, not zero."""
    cfg, params = dit_setup
    params = params["backbone"]
    x, t = _x(cfg), jnp.full((2,), 0.4, jnp.float32)
    C0 = jnp.zeros((2,) + dit_cache_shape(cfg), jnp.float32)
    r0 = jnp.zeros((2,))
    # compare eager-to-eager and jit-to-jit: XLA fusion reorders fp32 sums,
    # so cross-mode comparisons are only ULP-close, not bitwise
    cases = [
        (dit_apply(params, cfg, x, t),
         dit_apply_cached(params, cfg, x, t, cache=C0, reuse=r0,
                          cache_block=1)),
        (jax.jit(lambda p, xx, tt: dit_apply(p, cfg, xx, tt))(params, x, t),
         jax.jit(lambda p, xx, tt, C, r: dit_apply_cached(
             p, cfg, xx, tt, cache=C, reuse=r, cache_block=1))(
             params, x, t, C0, r0)),
    ]
    for ref, (out, C1) in cases:
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
        assert float(jnp.abs(C1).max()) > 0.0  # deep blocks did something


def test_shallow_eval_reuses_cache_and_differs_from_full(dit_setup):
    """A shallow eval at a *different* x: output differs from the full eval
    (it is an approximation) but equals shallow-blocks + the stale delta; the
    cache itself passes through unchanged."""
    cfg, params = dit_setup
    params = params["backbone"]
    t = jnp.full((2,), 0.4, jnp.float32)
    C0 = jnp.zeros((2,) + dit_cache_shape(cfg), jnp.float32)
    x1, x2 = _x(cfg, seed=2), _x(cfg, seed=3)
    _, C1 = dit_apply_cached(params, cfg, x1, t, cache=C0,
                             reuse=jnp.zeros((2,)), cache_block=1)
    full2 = dit_apply(params, cfg, x2, t)
    shal2, C2 = dit_apply_cached(params, cfg, x2, t, cache=C1,
                                 reuse=jnp.ones((2,)), cache_block=1)
    assert not np.allclose(np.asarray(shal2), np.asarray(full2))
    np.testing.assert_array_equal(np.asarray(C2), np.asarray(C1))


def test_mixed_batch_reuse_is_per_sample(dit_setup):
    """reuse is a per-sample flag: in one batched call, the full row matches
    the all-full eval bitwise and keeps a refreshed cache; the shallow row
    keeps its stale cache."""
    cfg, params = dit_setup
    params = params["backbone"]
    t = jnp.full((2,), 0.3, jnp.float32)
    x = _x(cfg, seed=4)
    _, C1 = dit_apply_cached(
        params, cfg, _x(cfg, seed=5), t,
        cache=jnp.zeros((2,) + dit_cache_shape(cfg)),
        reuse=jnp.zeros((2,)), cache_block=1)
    ref, Cref = dit_apply_cached(params, cfg, x, t, cache=C1,
                                 reuse=jnp.zeros((2,)), cache_block=1)
    mix, Cmix = dit_apply_cached(params, cfg, x, t, cache=C1,
                                 reuse=jnp.asarray([0.0, 1.0]), cache_block=1)
    np.testing.assert_array_equal(np.asarray(mix[0]), np.asarray(ref[0]))
    np.testing.assert_array_equal(np.asarray(Cmix[0]), np.asarray(Cref[0]))
    np.testing.assert_array_equal(np.asarray(Cmix[1]), np.asarray(C1[1]))


def test_cache_block_bounds_are_validated(dit_setup):
    cfg, params = dit_setup
    params = params["backbone"]
    x = _x(cfg)
    C = jnp.zeros((2,) + dit_cache_shape(cfg))
    for bad in (0, cfg.num_layers, 7):
        with pytest.raises(ValueError, match="cache_block"):
            dit_apply_cached(params, cfg, x, 0.5, cache=C, cache_block=bad)


# ---------------------------------------------------------------------------
# engine level: parity, handshakes, accounting
# ---------------------------------------------------------------------------


def test_cached_engine_all_full_matches_uncached_bitwise(dit_setup):
    """The acceptance parity: a cache-wired engine running a plain registry
    table (cache_block spec, no shallow rows) reproduces the uncached
    engine's build() scan BIT-identically at fp32."""
    cfg, params = dit_setup
    x_T = _x(cfg)
    plain = build_engine(cfg, params, VPLinear(), 2, 0)
    cached = _engine(cfg, params)
    spec = EngineSpec(solver="unipc", nfe=5, order=2)
    ref = np.asarray(plain.build(spec)(x_T))
    got = np.asarray(cached.build(replace(spec, cache_block=1))(x_T))
    np.testing.assert_array_equal(got, ref)


def test_cached_plan_all_full_matches_uncached_bitwise(dit_setup):
    """Same parity through a tuned plan whose cache_depth is all zero (the
    column exists, every row is full)."""
    cfg, params = dit_setup
    x_T = _x(cfg)
    plain = build_engine(cfg, params, VPLinear(), 2, 0)
    cached = _engine(cfg, params)
    plan = SolverPlan.default(4, order=2)
    plan0 = replace(plan, cache_depth=[0] * 4)
    sched = VPLinear()
    spec = EngineSpec(solver="unipc", nfe=4, order=2)
    ref = np.asarray(plain.build(spec, table=plain.compile(
        spec, table=plan.compile(sched)))(x_T))
    cspec = replace(spec, cache_block=1)
    got = np.asarray(cached.build(cspec, table=cached.compile(
        cspec, table=plan0.compile(sched)))(x_T))
    np.testing.assert_array_equal(got, ref)


def test_shallow_plan_diverges_but_stays_finite(dit_setup):
    """A plan with real shallow steps must actually change the trajectory
    (caching is on) while staying finite (it is a sane approximation)."""
    cfg, params = dit_setup
    x_T = _x(cfg)
    cached = _engine(cfg, params)
    sched = VPLinear()
    spec = EngineSpec(solver="unipc", nfe=4, order=2, cache_block=1)
    full = np.asarray(cached.build(spec, table=cached.compile(
        spec, table=_cached_plan(4).compile(sched)))(x_T))
    ref_spec = EngineSpec(solver="unipc", nfe=4, order=2)
    plain = build_engine(cfg, params, VPLinear(), 2, 0)
    ref = np.asarray(plain.build(ref_spec)(x_T))
    assert np.isfinite(full).all()
    assert not np.array_equal(full, ref)


def test_spec_and_engine_handshakes():
    cfg = get_config("dit-cifar").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    # spec-level: guidance is incompatible with the single-batch cache
    with pytest.raises(ValueError, match="unconditional"):
        EngineSpec(solver="unipc", nfe=4, cache_block=1,
                   cfg_scale=2.0).resolve()
    with pytest.raises(ValueError, match=">= 0"):
        EngineSpec(solver="unipc", nfe=4, cache_block=-1).resolve()
    # wiring-level: family, guidance, and boundary bounds
    with pytest.raises(ValueError, match="unconditional"):
        build_engine(cfg, params, VPLinear(), 2, 0, want_cfg=True,
                     cache_block=1)
    with pytest.raises(ValueError, match="1..1"):
        build_engine(cfg, params, VPLinear(), 2, 0,
                     cache_block=cfg.num_layers)
    # engine-level: cached spec on an unwired engine
    plain = build_engine(cfg, params, VPLinear(), 2, 0)
    with pytest.raises(ValueError, match="no .*cached eps-net"):
        plain.build(EngineSpec(solver="unipc", nfe=4, cache_block=1))
    # boundary mismatch between spec and wiring is caught, not served
    cfg4 = replace(cfg, num_layers=4)
    params4 = api.init_params(cfg4, jax.random.PRNGKey(0))
    wired = build_engine(cfg4, params4, VPLinear(), 2, 0, cache_block=2)
    with pytest.raises(ValueError, match="wired for cache boundary 2"):
        wired.build(EngineSpec(solver="unipc", nfe=4, cache_block=1))


def test_cached_plan_on_uncached_spec_is_rejected(dit_setup):
    """A cached plan's table must not silently serve with caching off."""
    cfg, params = dit_setup
    cached = _engine(cfg, params)
    spec = EngineSpec(solver="unipc", nfe=4, order=2)  # cache_block=0
    tab = cached.compile(replace(spec, cache_block=1),
                         table=_cached_plan(4).compile(VPLinear()))
    with pytest.raises(ValueError, match="silently paying full evals"):
        cached.build(spec, table=tab)


def test_plan_cache_depth_validation_and_json_round_trip(tmp_path):
    good = SolverPlan.default(4)
    with pytest.raises(ValueError, match="cache_depth"):
        replace(good, cache_depth=[1, 0])                 # wrong length
    with pytest.raises(ValueError, match=">= 0"):
        replace(good, cache_depth=[0, -1, 0, 0])
    with pytest.raises(ValueError, match="share one k"):
        replace(good, cache_depth=[1, 2, 0, 0])           # mixed boundaries
    plan = replace(good, cache_depth=[0, 1, 1, 0])
    assert plan.cache_block == 1
    assert good.cache_block == 0
    path = str(tmp_path / "p.json")
    plan.save(path)
    loaded = SolverPlan.load(path)
    assert loaded.to_dict() == plan.to_dict()
    assert loaded.cache_depth == [0, 1, 1, 0]
    # the lowered reuse column: init row full, then the 0/1 schedule
    tab = loaded.compile(VPLinear())
    np.testing.assert_array_equal(tab.model_cols["cache_reuse"],
                                  [0.0, 0.0, 1.0, 1.0, 0.0])


def test_eval_cost_accounting_agrees_everywhere(dit_setup):
    """plan.eval_cost == eval_cost_rows sum == program.span_cost, and a
    shallow plan lands strictly below its NFE floor."""
    cfg, params = dit_setup
    plan = _cached_plan(4, k=1)                  # 3 shallow of 5 evals
    n_blocks = cfg.num_layers                    # reduced dit-cifar: 2
    want = 5 - 3 * (1 - 1 / n_blocks)            # 3.5 at k=1, L=2
    assert plan.eval_cost(n_blocks) == pytest.approx(want)
    assert plan.eval_cost(n_blocks) < plan.nfe + 1
    rows = {"t": np.zeros(5),
            "mc_cache_reuse": np.array([0.0, 0.0, 1.0, 1.0, 1.0])}
    cost = eval_cost_rows(rows, cache_block=1, n_blocks=n_blocks)
    assert cost.sum() == pytest.approx(want)
    # uncached rows cost 1.0 each regardless of flags
    np.testing.assert_array_equal(
        eval_cost_rows(rows, cache_block=0, n_blocks=n_blocks), np.ones(5))
    engine = _engine(cfg, params)
    spec = EngineSpec(solver="unipc", nfe=4, order=2, cache_block=1)
    program = engine.build_step(spec, table=engine.compile(
        spec, table=plan.compile(VPLinear())))
    assert program.span_cost(0, program.n_rows) == pytest.approx(want)
    assert program.cache is not None and program.cache.block == 1


# ---------------------------------------------------------------------------
# serving level: cached banks through the scheduler
# ---------------------------------------------------------------------------


def test_staggered_cached_bank_matches_uniform_cached_scans(dit_classless):
    """Cached-bank acceptance: staggered requests served from ONE compiled
    cached program match each tier's own uniform cached build() scan, and
    each completion's eval_cost is its tier's evals-per-latent."""
    cfg, params = dit_classless
    engine = _engine(cfg, params, batch=2)
    sched_vp = VPLinear()
    plans = {"fast": _cached_plan(3, k=1),
             "quality": SolverPlan.default(5, order=2)}   # uncached tier
    common = dict(solver="unipc", cache_block=1)
    tier_specs = {n: EngineSpec(nfe=p.nfe, order=max(p.orders), **common)
                  for n, p in plans.items()}
    tables = {n: p.compile(sched_vp) for n, p in plans.items()}
    program = engine.build_bank(tier_specs, tables)
    sched = SlotScheduler(program, 2, (cfg.patch_tokens, cfg.latent_dim))
    x_T = {r: np.asarray(_x(cfg, batch=1, seed=10 + r)[0]) for r in range(4)}
    names = ["fast", "quality", "fast", "quality"]
    reqs = [Request(rid=r, arrival=float(a), x_T=x_T[r], tier=names[r])
            for r, a in zip(range(4), [0, 0, 2, 5])]
    run_trace(sched, reqs)
    got = {c.rid: c for c in sched.completions}
    assert len(got) == 4
    for r, name in enumerate(names):
        ref = np.asarray(engine.build(
            tier_specs[name], table=engine.compile(
                tier_specs[name], table=tables[name]))(
            jnp.asarray(x_T[r])[None]))[0]
        # untrained data-prediction latents sit at O(600): 1e-3 absolute is
        # fp32 ULP-level agreement between the scan and per-slot step paths
        np.testing.assert_allclose(got[r].latent, ref, atol=1e-3, rtol=0,
                                   err_msg=f"rid={r} tier={name}")
        want = plans[name].eval_cost(cfg.num_layers)
        assert got[r].eval_cost == pytest.approx(want)
    # the cached tier really is below its floor; the plain tier is at it
    assert got[0].eval_cost < got[0].evals
    assert got[1].eval_cost == got[1].evals


def test_slot_reuse_does_not_leak_cache_between_requests(dit_setup):
    """A request admitted into a slot a previous request just vacated must
    see a zeroed cache: same result as being served alone."""
    cfg, params = dit_setup
    engine = _engine(cfg, params, batch=1)
    spec = EngineSpec(solver="unipc", nfe=3, order=2, cache_block=1)
    tab = engine.compile(spec, table=_cached_plan(3).compile(VPLinear()))

    def serve(reqs):
        sched = SlotScheduler(engine.build_step(spec, table=tab), 1,
                              (cfg.patch_tokens, cfg.latent_dim))
        run_trace(sched, reqs)
        return {c.rid: c.latent for c in sched.completions}

    probe = np.asarray(_x(cfg, batch=1, seed=9)[0])
    solo = serve([Request(rid=1, x_T=probe)])
    behind = serve([Request(rid=0, x_T=np.asarray(_x(cfg, 1, 8)[0])),
                    Request(rid=1, x_T=probe, arrival=4.0)])
    np.testing.assert_array_equal(solo[1], behind[1])


def test_bank_rejects_mixed_cache_boundaries(dit_setup):
    cfg, params = dit_setup
    engine = _engine(cfg, params)
    specs = {"a": EngineSpec(solver="unipc", nfe=4, cache_block=1),
             "b": EngineSpec(solver="unipc", nfe=4, cache_block=0)}
    with pytest.raises(ValueError, match="agree on cache_block"):
        engine.build_bank(specs)
