"""SSD (Mamba2) chunked-scan vs naive recurrence, and MoE dispatch checks."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.moe import moe_apply, moe_decode_apply, moe_init
from repro.models.ssm import (init_mamba_state, mamba2_apply, mamba2_decode,
                              mamba2_init, ssd_scan)


def naive_ssd(x, dt, A, B, C, D):
    """Token-by-token linear recurrence oracle (Mamba2 eq. in fp64)."""
    b, l, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = np.repeat(np.asarray(B, np.float64), rep, axis=2)
    Ch = np.repeat(np.asarray(C, np.float64), rep, axis=2)
    xf = np.asarray(x, np.float64)
    dtf = np.asarray(dt, np.float64)
    Af = np.asarray(A, np.float64)
    S = np.zeros((b, h, p, n))
    y = np.zeros_like(xf)
    for i in range(l):
        da = np.exp(dtf[:, i] * Af[None])                 # (b, h)
        S = S * da[..., None, None] + np.einsum(
            "bhp,bhn->bhpn", xf[:, i] * dtf[:, i][..., None], Bh[:, i])
        y[:, i] = np.einsum("bhn,bhpn->bhp", Ch[:, i], S)
    y = y + xf * np.asarray(D, np.float64)[None, None, :, None]
    return y, S


@pytest.mark.parametrize("l,chunk", [(32, 8), (24, 8), (16, 16), (20, 8)])
@pytest.mark.parametrize("g", [1, 2])
def test_ssd_scan_matches_recurrence(l, chunk, g):
    rng = np.random.default_rng(0)
    b, h, p, n = 2, 4, 8, 8
    x = rng.normal(size=(b, l, h, p)).astype(np.float32)
    dt = (0.1 + 0.5 * rng.random((b, l, h))).astype(np.float32)
    A = -np.exp(rng.normal(size=(h,))).astype(np.float32)
    B = rng.normal(size=(b, l, g, n)).astype(np.float32)
    C = rng.normal(size=(b, l, g, n)).astype(np.float32)
    D = rng.normal(size=(h,)).astype(np.float32)
    y, S = ssd_scan(jnp.asarray(x), jnp.asarray(dt), jnp.asarray(A),
                    jnp.asarray(B), jnp.asarray(C), jnp.asarray(D), chunk)
    y_ref, S_ref = naive_ssd(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(S), S_ref, rtol=2e-3, atol=2e-3)


def _ssm_cfg():
    return ModelConfig(arch_id="t", family="ssm", num_layers=1, d_model=64,
                       num_heads=0, head_dim=0, d_ff=0, vocab_size=64,
                       ssm_state=16, ssm_head_dim=16, ssm_expand=2,
                       ssm_chunk=8, dtype="float32", param_dtype="float32")


def test_mamba_block_decode_matches_full():
    """Running the block token-by-token with the recurrent state must match
    the full-sequence chunked pass."""
    cfg = _ssm_cfg()
    rng = jax.random.PRNGKey(0)
    params = mamba2_init(rng, cfg)
    B, S = 2, 24
    u = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    y_full = mamba2_apply(params, u, cfg)
    state = init_mamba_state(cfg, B)
    ys = []
    for i in range(S):
        y_i, state = mamba2_decode(params, state, u[:, i:i + 1], cfg)
        ys.append(y_i)
    y_steps = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_steps), np.asarray(y_full),
                               rtol=5e-3, atol=5e-3)


def test_mamba_prefill_state_matches_decode_state():
    cfg = _ssm_cfg()
    params = mamba2_init(jax.random.PRNGKey(0), cfg)
    B, S = 1, 16
    u = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    _, st_full = mamba2_apply(params, u, cfg, return_state=True)
    state = init_mamba_state(cfg, B)
    for i in range(S):
        _, state = mamba2_decode(params, state, u[:, i:i + 1], cfg)
    np.testing.assert_allclose(np.asarray(st_full["ssm"]),
                               np.asarray(state["ssm"]), rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(st_full["conv"]),
                               np.asarray(state["conv"]), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def _moe_cfg(cap=4.0):
    return ModelConfig(arch_id="t", family="moe", num_layers=1, d_model=32,
                       num_heads=4, d_ff=64, vocab_size=64, num_experts=4,
                       experts_per_token=2, moe_d_ff=64, capacity_factor=cap,
                       dtype="float32", param_dtype="float32")


def test_moe_capacity_dispatch_matches_dense():
    """With ample capacity (no drops) the scatter dispatch must equal the
    dense compute-all-experts路径 (moe_decode_apply)."""
    cfg = _moe_cfg(cap=8.0)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y_scatter, aux = moe_apply(params, x, cfg)
    y_dense = moe_decode_apply(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y_scatter), np.asarray(y_dense),
                               rtol=1e-4, atol=1e-5)
    assert float(aux) > 0


def test_moe_drops_under_tight_capacity():
    """With capacity factor << 1 tokens are dropped (residual passthrough),
    output stays finite and differs from the dense path."""
    cfg = _moe_cfg(cap=0.25)
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, _ = moe_apply(params, x, cfg)
    assert np.all(np.isfinite(np.asarray(y)))


def test_moe_router_grad_flows():
    cfg = _moe_cfg()
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))

    def loss(p):
        y, aux = moe_apply(p, x, cfg)
        return jnp.mean(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(params)
    assert float(jnp.sum(jnp.abs(g["router"]))) > 0
