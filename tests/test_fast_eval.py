"""The fast-eval denoiser path (DESIGN.md §11): flash attention in the model
stack, the fused adaLN kernel, the bf16 serving eval, and donated step
buffers. Acceptance: the new default eval path matches the eager fp32 path
<= 1e-5; bf16 is opt-in with its tolerance asserted here; the donated AOT
step is bit-identical to the undonated one."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.kernels.adaln_modulate import ops as ad_ops, ref as ad_ref
from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.models import api


# ---------------------------------------------------------------------------
# flash attention: non-causal DiT parity + dispatch policy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,Hq,Hkv,S,D", [
    (2, 4, 4, 64, 32),     # dit-cifar tokens (sub-block S)
    (1, 4, 4, 256, 32),    # dit-i256 tokens (two S tiles)
    (2, 4, 2, 200, 32),    # non-block-multiple S, GQA
    (1, 2, 1, 130, 64),    # remainder of 2 over one tile
])
def test_flash_noncausal_matches_sdpa_at_dit_shapes(B, Hq, Hkv, S, D):
    """The kernel (interpret mode) == the model-side seq-major sdpa for the
    non-causal full-token path the DiT blocks run, including token counts
    that are not block multiples."""
    from repro.models.layers import sdpa

    ks = jax.random.split(jax.random.PRNGKey(S), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    want = sdpa(q, k, v, causal=False)
    for backend in ("interpret", "jnp"):
        got = fa_ops.attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=False,
            backend=backend).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5, err_msg=backend)


def test_flash_attention_dispatch_policy():
    """The explicit pallas|interpret|jnp policy of unipc_update/ops.py:
    platform selection, explicit pinning, unknown backends rejected."""
    assert fa_ops.select_backend("tpu") == "pallas"
    assert fa_ops.select_backend("cpu") == "jnp"
    assert fa_ops.select_backend("gpu") == "jnp"
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 2, 64, 16))
    want = fa_ref.attention(q, q, q, causal=True)
    # jnp backend IS the oracle; interpret runs the real kernel
    got_jnp = fa_ops.attention(q, q, q, causal=True, backend="jnp")
    np.testing.assert_array_equal(np.asarray(got_jnp), np.asarray(want))
    got_int = fa_ops.attention(q, q, q, causal=True, backend="interpret")
    np.testing.assert_allclose(np.asarray(got_int), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    with pytest.raises(ValueError, match="backend"):
        fa_ops.attention(q, q, q, backend="cuda")


def test_attention_chunk_remainder(rng):
    """The chunked path is no longer dead for S % chunk != 0: remainder
    query chunks are padded and sliced, same softmax."""
    from repro.models.layers import chunked_sdpa, sdpa

    ks = jax.random.split(rng, 3)
    B, S, H, D = 2, 100, 4, 16  # 100 = 3*32 + 4 remainder
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, H, D))
    v = jax.random.normal(ks[2], (B, S, H, D))
    for causal, window in ((True, None), (False, None), (True, 24)):
        want = sdpa(q, k, v, causal=causal, sliding_window=window)
        got = chunked_sdpa(q, k, v, causal=causal, sliding_window=window,
                           chunk=32)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# fused adaLN kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B,T,D", [
    (2, 64, 128),    # dit-cifar reduced block shape
    (4, 256, 128),   # dit-i256 reduced block shape
    (3, 100, 130),   # remainder T tile + non-128-multiple D (masked LN)
    (1, 7, 48),      # sub-tile everything
])
def test_adaln_modulate_kernel_vs_ref(B, T, D):
    ks = jax.random.split(jax.random.PRNGKey(B * T + D), 4)
    x = jax.random.normal(ks[0], (B, T, D))
    sh = jax.random.normal(ks[1], (B, D))
    sc = jax.random.normal(ks[2], (B, D))
    g = jax.random.normal(ks[3], (B, D))
    want = ad_ref.modulate(x, sh, sc)
    got = ad_ops.modulate(x, sh, sc, backend="interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    want_g = ad_ref.gate_residual(x, g, x)
    got_g = ad_ops.gate_residual(x, g, x, backend="interpret")
    np.testing.assert_allclose(np.asarray(got_g), np.asarray(want_g),
                               rtol=1e-5, atol=1e-5)


def test_adaln_matches_inline_dit_math(rng):
    """The op == the pre-PR inline chain `layernorm({}, h)*(1+sc)+sh`
    bit-for-bit at fp32 (jnp backend) and <=1e-5 through the kernel."""
    from repro.models.layers import layernorm

    ks = jax.random.split(rng, 3)
    x = jax.random.normal(ks[0], (2, 64, 128))
    sh = jax.random.normal(ks[1], (2, 128))
    sc = jax.random.normal(ks[2], (2, 128))
    inline = layernorm({}, x) * (1 + sc[:, None]) + sh[:, None]
    np.testing.assert_array_equal(
        np.asarray(ad_ops.modulate(x, sh, sc, backend="jnp")),
        np.asarray(inline))
    np.testing.assert_allclose(
        np.asarray(ad_ops.modulate(x, sh, sc, backend="interpret")),
        np.asarray(inline), rtol=1e-5, atol=1e-5)


def test_adaln_dispatch_policy():
    assert ad_ops.select_backend("tpu") == "pallas"
    assert ad_ops.select_backend("cpu") == "jnp"
    x = jnp.ones((1, 8, 16))
    with pytest.raises(ValueError, match="backend"):
        ad_ops.modulate(x, jnp.ones((1, 16)), jnp.ones((1, 16)),
                        backend="cuda")
    with pytest.raises(ValueError, match="backend"):
        ad_ops.gate_residual(x, jnp.ones((1, 16)), x, backend="cuda")


# ---------------------------------------------------------------------------
# the DiT fast-eval path end to end
# ---------------------------------------------------------------------------


def _noisy(params, rng, scale=0.05):
    """Perturb every float leaf: the adaLN-zero init makes an untrained DiT
    output exactly zero (zero out_proj, zero gates), which would make any
    output-parity assertion vacuous."""
    leaves, treedef = jax.tree.flatten(params)
    ks = jax.random.split(rng, len(leaves))
    return jax.tree.unflatten(treedef, [
        a + scale * jax.random.normal(k, a.shape, a.dtype)
        if jnp.issubdtype(a.dtype, jnp.floating) else a
        for a, k in zip(leaves, ks)])


def _dit_eval(cfg, params, x, t, ids):
    net = api.eps_network(cfg)
    return np.asarray(jax.jit(
        lambda x, t: net(params, x, t, {"class_ids": ids}))(x, t))


def test_dit_interpret_kernels_match_default(rng):
    """dit_apply with the real kernels (interpret mode) == the default
    (jnp-dispatch) eval <= 1e-5 — the served-path parity acceptance."""
    cfg = get_config("dit-cifar").reduced()
    params = _noisy(api.init_params(cfg, rng), jax.random.PRNGKey(9))
    B = 2
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (B, cfg.patch_tokens, cfg.latent_dim))
    t = jnp.full((B,), 0.4)
    ids = jnp.asarray([3, 7], jnp.int32)
    default = _dit_eval(cfg, params, x, t, ids)
    assert np.abs(default).max() > 0  # the noisy net is non-degenerate
    pinned = dataclasses.replace(cfg, attention_backend="interpret",
                                 adaln_backend="interpret")
    kern = _dit_eval(pinned, params, x, t, ids)
    np.testing.assert_allclose(kern, default, rtol=1e-5, atol=1e-5)


def test_bf16_eval_sample_close_to_fp32(vp):
    """End-to-end engine sample with eval_dtype=bfloat16 vs fp32: the solver
    state stays fp32, so the drift is the network's bf16 rounding carried
    through NFE evals. Documented bound (DESIGN.md §11): <= 1e-2 relative
    L-inf on the sampled latents (measured ~2.5e-3 on this net) — far above
    fp32 path noise, far below sample-visible error."""
    from repro.engine import EngineSpec
    from repro.launch.sample import build_engine

    cfg = get_config("dit-cifar").reduced()
    params = _noisy(api.init_params(cfg, jax.random.PRNGKey(0)),
                    jax.random.PRNGKey(9))
    x_T = jax.random.normal(jax.random.PRNGKey(1),
                            (2, cfg.patch_tokens, cfg.latent_dim))
    outs = {}
    for ed in ("float32", "bfloat16"):
        eng = build_engine(cfg, params, vp, 2, eval_dtype=ed)
        spec = EngineSpec(solver="unipc", order=2, nfe=6, eval_dtype=ed)
        outs[ed] = np.asarray(eng.build(spec)(x_T))
    assert outs["bfloat16"].dtype == np.float32  # state stays fp32
    err = np.abs(outs["bfloat16"] - outs["float32"]).max()
    rel = err / np.abs(outs["float32"]).max()
    assert rel < 1e-2, f"bf16 eval drifted {rel} relative from fp32"
    assert err > 0  # bf16 must actually have run in reduced precision


def test_eval_dtype_validation():
    from repro.engine import EngineSpec
    from repro.launch.sample import build_engine

    with pytest.raises(ValueError, match="eval_dtype"):
        EngineSpec(solver="unipc", eval_dtype="float16").resolve()
    with pytest.raises(ValueError, match="eval_dtype"):
        build_engine(get_config("dit-cifar").reduced(), {}, None, 2,
                     eval_dtype="float16")


def test_engine_and_spec_eval_dtype_must_match(vp):
    """A bf16-wired engine rejects fp32 specs (and vice versa): the net-side
    cast and the engine-side fp32 boundary cannot silently desynchronize."""
    from repro.engine import EngineSpec
    from repro.launch.sample import build_engine

    cfg = get_config("dit-cifar").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    eng16 = build_engine(cfg, params, vp, 2, eval_dtype="bfloat16")
    with pytest.raises(ValueError, match="wired for 'bfloat16'"):
        eng16.build(EngineSpec(solver="unipc", nfe=4))
    eng32 = build_engine(cfg, params, vp, 2)
    with pytest.raises(ValueError, match="wired for 'float32'"):
        eng32.build(EngineSpec(solver="unipc", nfe=4,
                               eval_dtype="bfloat16"))


def test_bank_tiers_must_share_eval_dtype(gaussian_dpm):
    from repro.engine import EngineSpec, SamplerEngine

    def eps(x, t):
        return jnp.zeros_like(x)

    eng = SamplerEngine(gaussian_dpm.schedule, eps=eps)
    with pytest.raises(ValueError, match="eval_dtype"):
        eng.build_bank({
            "a": EngineSpec(solver="unipc", nfe=4, order=2),
            "b": EngineSpec(solver="unipc", nfe=6, order=2,
                            eval_dtype="bfloat16"),
        })


# ---------------------------------------------------------------------------
# donated step buffers
# ---------------------------------------------------------------------------


def _gauss_engine(gaussian_dpm):
    from repro.engine import SamplerEngine

    sched = gaussian_dpm.schedule

    def eps(x, t):
        t = jnp.asarray(t)
        a = jnp.exp(sched.log_alpha_jax(t))
        sig = jnp.sqrt(1 - a * a)
        if t.ndim == 1:
            bshape = (-1,) + (1,) * (x.ndim - 1)
            a, sig = a.reshape(bshape), sig.reshape(bshape)
        return sig * (x - a * gaussian_dpm.mu) / (
            a * a * gaussian_dpm.s ** 2 + sig * sig)

    return SamplerEngine(sched, eps=eps)


def test_donated_step_bit_identical_to_undonated(gaussian_dpm):
    """The AOT-compiled step with donated (x, E) buffers produces bit-identical
    trajectories to the undonated program — donation only recycles memory."""
    from repro.engine import EngineSpec

    eng = _gauss_engine(gaussian_dpm)
    spec = EngineSpec(solver="unipc", order=2, nfe=5)
    slots, shape = 3, (6,)
    prog_d = eng.build_step(spec, donate=True)
    prog_u = eng.build_step(spec, donate=False)
    x0 = jax.random.normal(jax.random.PRNGKey(0), (slots,) + shape)

    def run(prog):
        state = prog.init_state(slots, shape)
        state = (state[0] + x0, state[1])
        # AOT-compile exactly as the scheduler does
        idx0 = jnp.zeros((slots,), jnp.int32)
        compiled = prog.step.lower(state, idx0, None, None).compile()
        outs = []
        for i in range(prog.n_rows):
            idx = jnp.full((slots,), i, jnp.int32)
            state = compiled(state, idx, None, None)
            outs.append(np.asarray(state[0]))
        return outs

    for a, b in zip(run(prog_d), run(prog_u)):
        np.testing.assert_array_equal(a, b)


def test_donated_step_consumes_input_state(gaussian_dpm):
    """Donation is real: after a donated step call, the input buffers are
    gone (deleted on CPU/TPU) — the scheduler's reassign-always contract."""
    from repro.engine import EngineSpec

    eng = _gauss_engine(gaussian_dpm)
    prog = eng.build_step(EngineSpec(solver="unipc", order=2, nfe=4))
    state = prog.init_state(2, (4,))
    idx = jnp.zeros((2,), jnp.int32)
    new_state = prog.step(state, idx, None, None)
    assert new_state[0].shape == state[0].shape
    with pytest.raises(RuntimeError, match="deleted"):
        _ = np.asarray(state[0]) + 1


def test_scheduler_serves_with_donated_program(gaussian_dpm):
    """The scheduler end-to-end on the (default) donated program matches the
    uniform scan — the existing parity property survives donation."""
    from repro.engine import EngineSpec
    from repro.serving import Request, SlotScheduler, run_trace

    eng = _gauss_engine(gaussian_dpm)
    spec = EngineSpec(solver="unipc", order=2, nfe=5)
    prog = eng.build_step(spec)
    sched = SlotScheduler(prog, 2, (6,))
    sched.aot_compile()
    xs = [np.random.default_rng(40 + i).normal(size=(6,)).astype(np.float32)
          for i in range(4)]
    reqs = [Request(rid=i, arrival=float(a), x_T=xs[i])
            for i, a in enumerate([0, 0, 2, 3])]
    run_trace(sched, reqs)
    ref = np.asarray(eng.build(spec)(jnp.asarray(np.stack(xs))))
    got = {c.rid: c.latent for c in sched.completions}
    for i in range(4):
        np.testing.assert_allclose(got[i], ref[i], atol=1e-5, rtol=0)
