"""Resilient serving (DESIGN.md §16): overload control, TTL expiry,
degraded-tier retry on non-finite output, and host/device desync recovery,
all driven by the deterministic fault-injection harness.

The chaos acceptance property: a seeded `FaultPlan` produces the same event
ledger every run, and every request a fault never touched finishes
bit-identical (assert_array_equal) to the clean run — at pipeline depths
1, 2 and 3. Re-admitted requests carry their seed, so even the POISONED
request reproduces the clean latent once its retry lands.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine import EngineSpec, SamplerEngine
from repro.serving import (FaultPlan, MetaFault, NanFault, Rejection,
                           Request, ResilienceConfig, SkewFault,
                           SlotScheduler, fallback_tier, parse_fault_spec,
                           poisson_requests, run_trace, validate_resilience)
from repro.serving.resilience import (FAIL_NONFINITE, REJECT_EXPIRED,
                                      REJECT_QUEUE_FULL)

from test_serving import _eps_jx, _tier_specs, _x_T

DEPTHS = (1, 2, 3)


def _program(gaussian_dpm, nfe=7, order=3):
    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    return eng.build_step(EngineSpec(solver="unipc", order=order, nfe=nfe))


def _reqs(n=9, rate=0.5, seed=5, **kw):
    return [Request(rid=r.rid, arrival=r.arrival, x_T=_x_T(r.rid), **kw)
            for r in poisson_requests(n, rate=rate, seed=seed)]


def _clean_latents(program, slots=3):
    sched = SlotScheduler(program, slots, (8,))
    run_trace(sched, _reqs())
    return {c.rid: c.latent for c in sched.completions}


# ---------------------------------------------------------------------------
# overload control: bounded queue, typed rejections, TTL expiry
# ---------------------------------------------------------------------------


def test_queue_full_rejects_fifo(gaussian_dpm):
    """Past max_queue, submit() returns a typed Rejection and the LATER
    submissions are the ones shed — admission order stays FIFO."""
    program = _program(gaussian_dpm, nfe=4, order=2)
    sched = SlotScheduler(program, 1, (8,),
                          resilience=ResilienceConfig(max_queue=2))
    outcomes = [sched.submit(Request(rid=r, x_T=_x_T(r))) for r in range(6)]
    assert outcomes[:2] == [None, None]  # fit the bound
    assert all(isinstance(o, Rejection) for o in outcomes[2:])
    assert [o.rid for o in outcomes[2:]] == [2, 3, 4, 5]  # shed in order
    assert all(o.reason == REJECT_QUEUE_FULL for o in outcomes[2:])
    done = sched.drain()
    assert [c.rid for c in done] == [0, 1]  # FIFO survivors
    # completions + rejections partition every submission
    assert len(done) + len(sched.rejections) == 6


def test_partition_invariant_in_metrics(gaussian_dpm):
    """run_trace's derived metrics hold submitted == completed + rejected
    under shed + expiry — no request is silently dropped or double-counted."""
    program = _program(gaussian_dpm, nfe=4, order=2)
    sched = SlotScheduler(program, 2, (8,),
                          resilience=ResilienceConfig(max_queue=2,
                                                      default_ttl=3.0))
    m = run_trace(sched, _reqs(n=14, rate=2.0, seed=7))
    assert m.rejected > 0
    assert m.requests == 14
    assert m.requests == m.completed + m.rejected
    assert m.expired <= m.rejected
    assert len(sched.completions) + len(sched.rejections) == 14


def test_ttl_bounds_queue_wait_not_service(gaussian_dpm):
    """TTL is an ADMISSION deadline: a request still queued past it expires,
    but a request admitted in time runs to completion even when service ends
    long after the deadline."""
    program = _program(gaussian_dpm, nfe=7)  # service >> ttl
    sched = SlotScheduler(program, 1, (8,),
                          resilience=ResilienceConfig(default_ttl=3.0))
    run_trace(sched, [Request(rid=0, arrival=0.0, x_T=_x_T(0)),
                      Request(rid=1, arrival=0.0, x_T=_x_T(1))])
    done = {c.rid: c for c in sched.completions}
    # rid 0 admitted tick 1, finished ~n_rows ticks later — way past its
    # deadline, served anyway
    assert list(done) == [0]
    assert done[0].finish_clock - done[0].arrival > 3.0
    [rej] = sched.rejections
    assert (rej.rid, rej.reason) == (1, REJECT_EXPIRED)


def test_request_ttl_overrides_default(gaussian_dpm):
    """Request.ttl beats ResilienceConfig.default_ttl per request."""
    program = _program(gaussian_dpm, nfe=7)
    sched = SlotScheduler(program, 1, (8,),
                          resilience=ResilienceConfig(default_ttl=3.0))
    run_trace(sched, [Request(rid=0, arrival=0.0, x_T=_x_T(0)),
                      Request(rid=1, arrival=0.0, x_T=_x_T(1), ttl=100.0)])
    assert sorted(c.rid for c in sched.completions) == [0, 1]
    assert not sched.rejections


def test_degrade_shed_remaps_tier(gaussian_dpm):
    """shed_policy='degrade' remaps submissions past the watermark to the
    cheap tier instead of rejecting; provenance keeps the asked-for tier."""
    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    program = eng.build_bank(_tier_specs())
    cfg = ResilienceConfig(max_queue=4, shed_policy="degrade",
                           degrade_watermark=1, degrade_tier="fast")
    sched = SlotScheduler(program, 1, (8,), resilience=cfg)
    for r in range(4):
        assert sched.submit(Request(rid=r, x_T=_x_T(r),
                                    tier="quality")) is None
    done = {c.rid: c for c in sched.drain()}
    assert done[0].tier == "quality" and done[0].first_tier is None
    for r in (1, 2, 3):  # past the watermark: served, but on the cheap tier
        assert done[r].tier == "fast"
        assert done[r].first_tier == "quality"
    assert done[1].evals < done[0].evals


# ---------------------------------------------------------------------------
# output validation: NaN detection, degraded-tier retry, exhaustion
# ---------------------------------------------------------------------------


def test_nan_fault_retries_and_reproduces_clean_latents(gaussian_dpm):
    """A poisoned latent is flagged on device, the request re-admitted with
    its seed, and EVERY latent — poisoned-then-retried included — lands
    bit-identical to the clean run, at every pipeline depth."""
    program = _program(gaussian_dpm)
    clean = _clean_latents(program)
    plan = FaultPlan(nans=(NanFault(rid=2, step=3),))
    ledgers = []
    for depth in DEPTHS:
        sched = SlotScheduler(program, 3, (8,), pipeline_depth=depth,
                              resilience=ResilienceConfig(max_retries=2),
                              faults=plan)
        m = run_trace(sched, _reqs())
        assert m.completed == 9 and m.failed == 0
        assert m.retries == 1 and m.faults_injected == 1
        got = {c.rid: c for c in sched.completions}
        assert all(c.ok for c in got.values())
        assert got[2].retries == 1 and got[2].fail_reason is None
        for rid, lat in clean.items():
            np.testing.assert_array_equal(got[rid].latent, lat)
        ledgers.append(list(sched.events))
    # the seeded chaos is deterministic: one ledger, all depths
    assert ledgers[0] == ledgers[1] == ledgers[2]


def test_retry_exhaustion_emits_failed_completion(gaussian_dpm):
    """A sticky fault that survives every retry ends in a Completion with
    ok=False + fail_reason — never a shipped NaN, never a hang."""
    program = _program(gaussian_dpm, nfe=4, order=2)
    plan = FaultPlan(nans=(NanFault(rid=0, step=1, sticky=True),))
    sched = SlotScheduler(program, 2, (8,),
                          resilience=ResilienceConfig(max_retries=1),
                          faults=plan)
    m = run_trace(sched, _reqs(n=4, rate=1.0, seed=3))
    got = {c.rid: c for c in sched.completions}
    assert m.failed == 1 and m.requests == m.completed + m.rejected
    bad = got[0]
    assert not bad.ok and bad.fail_reason == FAIL_NONFINITE
    assert bad.retries == 1  # the budget was spent before giving up
    assert not np.isfinite(bad.latent).all()
    assert all(c.ok and np.isfinite(c.latent).all()
               for rid, c in got.items() if rid != 0)


def test_retry_walks_fallback_chain(gaussian_dpm):
    """Retries walk the configured safer-tier chain (and park at its tail),
    recording the original tier as provenance."""
    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    program = eng.build_bank(_tier_specs())
    plan = FaultPlan(nans=(NanFault(rid=0, step=1, sticky=True),))
    cfg = ResilienceConfig(max_retries=3, fallback=("balanced", "fast"))
    sched = SlotScheduler(program, 2, (8,), resilience=cfg, faults=plan)
    sched.submit(Request(rid=0, x_T=_x_T(0), tier="quality"))
    [c] = sched.drain()
    # quality (not on chain) -> balanced -> fast -> fast (parked)
    assert not c.ok and c.retries == 3
    assert c.tier == "fast" and c.first_tier == "quality"
    retry_hops = [(ev[3], ev[4]) for ev in sched.events if ev[0] == "retry"]
    assert retry_hops == [("quality", "balanced"), ("balanced", "fast"),
                          ("fast", "fast")]


# ---------------------------------------------------------------------------
# desync recovery
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("depth", DEPTHS)
def test_desync_recovery_completes_all_requests(gaussian_dpm, depth):
    """A corrupted device row counter is detected at the next checked
    flight; recovery drains the pipeline, resyncs the host mirrors from
    device meta, requeues the affected requests and keeps serving — every
    request completes, bit-identical to the clean run, and nothing raises
    out of tick()."""
    program = _program(gaussian_dpm)
    clean = _clean_latents(program)
    plan = FaultPlan(metas=(MetaFault(tick=5),))
    sched = SlotScheduler(program, 3, (8,), pipeline_depth=depth,
                          resilience=ResilienceConfig(), faults=plan)
    m = run_trace(sched, _reqs())
    assert m.completed == 9 and m.recoveries >= 1
    got = {c.rid: c for c in sched.completions}
    assert all(c.ok for c in got.values())
    assert any(c.requeues > 0 for c in got.values())
    for rid, lat in clean.items():
        np.testing.assert_array_equal(got[rid].latent, lat)


def test_desync_recovery_ledger_deterministic(gaussian_dpm):
    """Two runs of the same meta-corruption plan produce the same event
    ledger — chaos that can't be reproduced proves nothing."""
    program = _program(gaussian_dpm)
    plan = FaultPlan(metas=(MetaFault(tick=5),))

    def run():
        sched = SlotScheduler(program, 3, (8,), pipeline_depth=2,
                              faults=plan)
        run_trace(sched, _reqs())
        return list(sched.events)

    assert run() == run()


def test_recovery_limit_exhausted_raises(gaussian_dpm):
    """A persistently lying step program must not recover forever: past
    max_recoveries the scheduler raises instead of looping."""
    program = _program(gaussian_dpm, nfe=3, order=1)

    def lying_step(state, meta, g=None, extras=None):
        state, meta, done = program.step_flight(state, meta, g, extras)
        return state, meta, jnp.zeros_like(done)  # device: nobody ever done

    sched = SlotScheduler(program, 2, (8,), step_override=lying_step,
                          resilience=ResilienceConfig(max_recoveries=2))
    sched.submit(Request(rid=0, x_T=_x_T(0)))
    with pytest.raises(RuntimeError, match="recovery limit"):
        sched.drain()


# ---------------------------------------------------------------------------
# harness plumbing: config validation, fallback walk, spec parsing, skew
# ---------------------------------------------------------------------------


def test_validate_resilience_rejects_contradictions(gaussian_dpm):
    single = _program(gaussian_dpm, nfe=3, order=1)
    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    bank = eng.build_bank(_tier_specs())
    with pytest.raises(ValueError, match="shed_policy"):
        validate_resilience(ResilienceConfig(shed_policy="drop"), single)
    with pytest.raises(ValueError, match="recovery"):
        validate_resilience(ResilienceConfig(recovery="ignore"), single)
    with pytest.raises(ValueError, match="max_queue"):
        validate_resilience(ResilienceConfig(max_queue=0), single)
    with pytest.raises(ValueError, match="degrade_tier"):
        validate_resilience(ResilienceConfig(shed_policy="degrade"), bank)
    with pytest.raises(ValueError, match="degrade_watermark"):
        validate_resilience(
            ResilienceConfig(max_queue=2, shed_policy="degrade",
                             degrade_tier="fast", degrade_watermark=5), bank)
    with pytest.raises(ValueError):
        # fallback tiers must resolve against the program's bank
        validate_resilience(ResilienceConfig(fallback=("fast",)), single)
    # degrade watermark defaults to the queue bound
    cfg = validate_resilience(
        ResilienceConfig(max_queue=3, shed_policy="degrade",
                         degrade_tier="fast"), bank)
    assert cfg.degrade_watermark == 3


def test_fallback_tier_walk():
    cfg = ResilienceConfig(fallback=("balanced", "fast"))
    assert fallback_tier(cfg, "quality") == "balanced"  # enter at the head
    assert fallback_tier(cfg, "balanced") == "fast"     # walk
    assert fallback_tier(cfg, "fast") == "fast"         # park at the tail
    assert fallback_tier(ResilienceConfig(), "quality") == "quality"
    assert fallback_tier(ResilienceConfig(), None) is None


def test_parse_fault_spec_roundtrip():
    plan = parse_fault_spec("nan:rid=2,step=1;meta:tick=6;skew:tick=3,delta=9")
    assert plan.nans == (NanFault(rid=2, step=1),)
    assert plan.metas == (MetaFault(tick=6),)
    assert plan.skews == (SkewFault(tick=3, delta=9.0),)
    assert parse_fault_spec(plan.describe()) == plan
    assert not parse_fault_spec("")
    assert not parse_fault_spec("none")
    seeded = parse_fault_spec("seed:7,requests=8,nfe=4,n_meta=1")
    assert seeded == FaultPlan.seeded(7, n_requests=8, nfe=4, n_meta=1)
    assert len(seeded.nans) == 1 and len(seeded.metas) == 1
    with pytest.raises(ValueError, match="bad fault clause"):
        parse_fault_spec("nan:step=1")  # rid is required
    with pytest.raises(ValueError, match="bad fault clause"):
        parse_fault_spec("flood:tick=3")


def test_skew_fault_forces_expiry(gaussian_dpm):
    """A clock-skew fault makes queued requests blow their TTL without a
    real slow consumer — the expiry path under test control."""
    program = _program(gaussian_dpm, nfe=4, order=2)
    plan = FaultPlan(skews=(SkewFault(tick=4, delta=100.0),))
    sched = SlotScheduler(program, 1, (8,),
                          resilience=ResilienceConfig(default_ttl=50.0),
                          faults=plan)
    m = run_trace(sched, _reqs(n=6, rate=1.0, seed=2))
    assert m.faults_injected == 1
    assert m.expired > 0
    assert m.requests == m.completed + m.rejected
    assert any(ev[0] == "fault_skew" for ev in sched.events)


def test_fault_free_resilient_sched_matches_plain(gaussian_dpm):
    """The whole layer at defaults is inert: same trace, same latents, same
    completion bookkeeping as a scheduler built with no resilience config —
    the bit-identity contract that makes the layer safe to always-on."""
    program = _program(gaussian_dpm)
    plain = SlotScheduler(program, 3, (8,))
    armed = SlotScheduler(program, 3, (8,),
                          resilience=ResilienceConfig(max_queue=64,
                                                      max_retries=2))
    m0, m1 = run_trace(plain, _reqs()), run_trace(armed, _reqs())
    det = lambda m: (m.requests, m.completed, m.ticks, m.evals,
                     m.makespan_ticks, m.latency_ticks_p50, m.occupancy,
                     m.rejected, m.expired, m.degraded, m.retries,
                     m.failed, m.recoveries, m.faults_injected)
    assert det(m0) == det(m1)
    assert not armed.events and not armed.rejections
    for a, b in zip(plain.completions, armed.completions):
        assert (a.rid, a.finish_tick, a.ok, a.retries) == \
            (b.rid, b.finish_tick, b.ok, b.retries)
        np.testing.assert_array_equal(a.latent, b.latent)
