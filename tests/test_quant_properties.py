"""Property-based checks for the quant_matmul package (hypothesis).

Complements the fixed-shape tests in test_quant.py: random weight
distributions exercise the absmax/round-to-nearest bound, determinism, and
interpret-vs-jnp kernel parity across arbitrary small odd shapes instead of
a handful of hand-picked ones.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.kernels.quant_matmul import ops as qops  # noqa: E402
from repro.kernels.quant_matmul import ref as qref  # noqa: E402

# small bounded shapes keep each example fast; remainder-tile coverage comes
# from the shapes being arbitrary, not multiples of anything
dims = st.integers(min_value=1, max_value=40)


def _arr(seed, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape,
                                     jnp.float32)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=dims, n=dims,
       bits=st.sampled_from((8, 4)),
       granularity=st.sampled_from(qref.GRANULARITIES),
       scale=st.floats(1e-3, 1e3))
def test_roundtrip_bound_holds_for_random_weights(seed, k, n, bits,
                                                  granularity, scale):
    """|w - deq(q(w))| <= scale/2 for any weight magnitude and granularity;
    per-tensor uses one global step so its bound is the single shared
    scale."""
    w = _arr(seed, (k, n), scale)
    qw, ws = qref.quantize(w, bits=bits, granularity=granularity)
    err = np.abs(np.asarray(qref.dequantize(qw, ws)) - np.asarray(w))
    bound = np.asarray(ws)[None, :] * 0.5
    assert (err <= bound + 1e-6 * scale).all()
    assert np.asarray(qw).dtype == np.int8
    assert np.abs(np.asarray(qw)).max() <= qref._QMAX[bits]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=dims, n=dims,
       granularity=st.sampled_from(qref.GRANULARITIES))
def test_quantize_is_deterministic(seed, k, n, granularity):
    """Same weights in, bit-identical (qw, scale) out — the property the
    calibration cache and plan reproducibility rest on."""
    w = _arr(seed, (k, n))
    qw1, ws1 = qref.quantize(w, granularity=granularity)
    qw2, ws2 = qref.quantize(w, granularity=granularity)
    np.testing.assert_array_equal(np.asarray(qw1), np.asarray(qw2))
    np.testing.assert_array_equal(np.asarray(ws1), np.asarray(ws2))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), m=dims, k=dims, n=dims,
       a8=st.booleans())
def test_interpret_kernel_matches_jnp_at_arbitrary_shapes(seed, m, k, n, a8):
    """The blocked kernel's padding/masking is exact: interpret backend
    agrees with the jnp oracle at every (M, K, N), including shapes far
    below one tile."""
    x = _arr(seed, (m, k))
    w = _arr(seed + 1, (k, n))
    qw, ws = qref.quantize(w)
    sa = float(jnp.max(jnp.abs(x))) / qref.ACT_QMAX + 1e-9 if a8 else None
    ref = qops.quant_matmul(x, qw, ws, sa=sa, backend="jnp")
    ker = qops.quant_matmul(x, qw, ws, sa=sa, backend="interpret")
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
