"""Production scan sampler == reference python loop, across orders/variants/
prediction types; jit-ability; guidance utilities."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import UniPC, Grid, make_unipc_schedule, unipc_sample_scan
from repro.diffusion import (VPLinear, cfg_model, dynamic_threshold,
                             guided_data_model)


def _models(dpm):
    sched = dpm.schedule

    def eps_np(x, t):
        return dpm.eps_model(np.asarray(x, np.float64), t)

    def eps_jx(x, t):
        t = jnp.asarray(t)
        a = jnp.exp(sched.log_alpha_jax(t))
        sig = jnp.sqrt(1 - a * a)
        return sig * (x - a * dpm.mu) / (a * a * dpm.s ** 2 + sig * sig)

    def data_np(x, t):
        a, s = float(sched.alpha(t)), float(sched.sigma(t))
        return (np.asarray(x, np.float64) - s * eps_np(x, t)) / a

    def data_jx(x, t):
        t = jnp.asarray(t)
        a = jnp.exp(sched.log_alpha_jax(t))
        sig = jnp.sqrt(1 - a * a)
        return (x - sig * eps_jx(x, t)) / a

    return {"noise": (eps_np, eps_jx), "data": (data_np, data_jx)}


@pytest.mark.parametrize("order", [1, 2, 3])
@pytest.mark.parametrize("prediction", ["noise", "data"])
@pytest.mark.parametrize("variant", ["bh1", "bh2"])
def test_scan_matches_loop(gaussian_dpm, x_T, order, prediction, variant):
    M = 8
    m_np, m_jx = _models(gaussian_dpm)[prediction]
    g = Grid.build(gaussian_dpm.schedule, M)
    ref = UniPC(m_np, g, order=order, prediction=prediction,
                variant=variant).sample_pc(np.asarray(x_T), use_corrector=True)
    us = make_unipc_schedule(gaussian_dpm.schedule, M, order=order,
                             prediction=prediction, variant=variant)
    out = unipc_sample_scan(m_jx, jnp.asarray(x_T, jnp.float32), us)
    np.testing.assert_allclose(np.asarray(out, np.float64), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_scan_is_jittable(gaussian_dpm):
    _, m_jx = _models(gaussian_dpm)["data"]
    us = make_unipc_schedule(gaussian_dpm.schedule, 6, order=3,
                             prediction="data")
    f = jax.jit(lambda x: unipc_sample_scan(m_jx, x, us))
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8))
    out = f(x)
    assert out.shape == x.shape and np.all(np.isfinite(np.asarray(out)))


def test_cfg_model_algebra():
    e_c = lambda x, t: jnp.ones_like(x)
    e_u = lambda x, t: jnp.zeros_like(x)
    f = cfg_model(e_c, e_u, scale=2.0)
    out = f(jnp.zeros((3,)), 0.5)
    np.testing.assert_allclose(np.asarray(out), 3.0)  # (1+s)*1 - s*0


def test_dynamic_threshold():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 64)) * 5)
    y = dynamic_threshold(x, percentile=0.9)
    assert float(jnp.max(jnp.abs(y))) <= 1.0 + 1e-6
    # already-in-range inputs pass through unchanged
    x2 = jnp.clip(x / 10.0, -0.9, 0.9)
    np.testing.assert_allclose(np.asarray(dynamic_threshold(x2)),
                               np.asarray(x2), rtol=1e-6)


def test_guided_data_model(vp):
    e = lambda x, t: 0.1 * x
    f = guided_data_model(vp, e, e, guidance_scale=1.5, thresholding=True)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(2, 8)))
    out = f(x, 0.5)
    assert out.shape == x.shape
    assert float(jnp.max(jnp.abs(out))) <= 1.0 + 1e-6
