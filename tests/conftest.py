import os
import random
import sys

# Tests run single-device (the dry-run owns the 512-device flag).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
# Determinism: any set-iteration-order dependence in subprocess helpers is a
# bug we want CI to catch the same way every run (ci.yml pins this too; the
# parent interpreter's own hashing is already fixed by the time we run).
os.environ.setdefault("PYTHONHASHSEED", "0")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.diffusion import GaussianDPM, VPLinear  # noqa: E402


@pytest.fixture(autouse=True)
def _seeded_global_rngs():
    """Every test starts from the same global RNG state.

    The suite's own randomness goes through explicit PRNGKeys / Generators,
    but library helpers occasionally fall back to the global np/random state;
    reseeding per-test keeps one test's draws from leaking into the next and
    makes failure repros independent of `-k` selections and execution order.
    """
    np.random.seed(0)
    random.seed(0)
    yield


@pytest.fixture(scope="session")
def vp():
    return VPLinear()


@pytest.fixture(scope="session")
def gaussian_dpm(vp):
    return GaussianDPM(vp)


@pytest.fixture(scope="session")
def x_T():
    return np.array([1.3, -0.2, 0.5, 0.9, -1.1], np.float64)


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)
