import os
import sys

# Tests run single-device (the dry-run owns the 512-device flag).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402

from repro.diffusion import GaussianDPM, VPLinear  # noqa: E402


@pytest.fixture(scope="session")
def vp():
    return VPLinear()


@pytest.fixture(scope="session")
def gaussian_dpm(vp):
    return GaussianDPM(vp)


@pytest.fixture(scope="session")
def x_T():
    return np.array([1.3, -0.2, 0.5, 0.9, -1.1], np.float64)


@pytest.fixture
def rng():
    return jax.random.PRNGKey(0)
