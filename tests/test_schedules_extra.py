"""UniPC across noise-schedule families (the solver must be schedule-agnostic:
everything enters through (alpha, sigma, lambda))."""

import numpy as np
import pytest

from repro.core import Grid, UniPC, UniPCSinglestep
from repro.diffusion import EDMSchedule, GaussianDPM, VPCosine, VPLinear, empirical_order


@pytest.mark.parametrize("sched", [VPCosine(), VPLinear(beta_0=0.05, beta_1=12.0)])
def test_unipc_on_other_vp_schedules(sched):
    dpm = GaussianDPM(sched)
    x_T = np.array([1.1, -0.4, 0.8])
    model = lambda x, t: dpm.eps_model(np.asarray(x, np.float64), t)
    errs = []
    for M in (20, 80):
        g = Grid.build(sched, M)
        s = UniPC(model, g, order=3, prediction="noise",
                  lower_order_final=False)
        x0 = s.sample_pc(x_T, use_corrector=True)
        errs.append(float(np.max(np.abs(x0 - dpm.exact_solution(x_T, g.t[-1])))))
    assert errs[1] < errs[0] / 50, errs  # >= order-3 behaviour


def test_unipc_on_edm_schedule():
    """EDM: alpha=1, sigma=t (VE parametrization) — exercises the lambda maps
    outside the VP family."""
    sched = EDMSchedule(T=10.0, t_eps=0.05)
    dpm = GaussianDPM(sched, mu=0.3, s=0.5)
    x_T = np.array([2.0, -1.5, 0.7])
    model = lambda x, t: dpm.eps_model(np.asarray(x, np.float64), t)
    errs = []
    for M in (20, 80):
        g = Grid.build(sched, M)
        s = UniPC(model, g, order=2, prediction="noise",
                  lower_order_final=False)
        x0 = s.sample_pc(x_T, use_corrector=True)
        errs.append(float(np.max(np.abs(x0 - dpm.exact_solution(x_T, g.t[-1])))))
    assert errs[1] < errs[0] / 8 and errs[1] < 1e-2, errs


def test_singlestep_unipc_order(gaussian_dpm, x_T):
    """Singlestep UniPC-2 measured order ~2 (NFE = 2 per grid step)."""
    model = lambda x, t: gaussian_dpm.eps_model(np.asarray(x, np.float64), t)
    Ms = (10, 20, 40, 80)
    errs = []
    for M in Ms:
        g = Grid.build(gaussian_dpm.schedule, M)
        s = UniPCSinglestep(model, g, gaussian_dpm.schedule, order=2,
                            prediction="noise")
        x0 = s.sample(x_T)
        errs.append(float(np.max(np.abs(
            x0 - gaussian_dpm.exact_solution(x_T, g.t[-1])))) + 1e-300)
    slope = empirical_order(errs, Ms)
    assert slope > 1.6, (slope, errs)


def test_time_spacings():
    """time_uniform / quadratic spacings also converge (coarser than logsnr)."""
    sched = VPLinear()
    dpm = GaussianDPM(sched)
    x_T = np.array([1.0, -0.5])
    model = lambda x, t: dpm.eps_model(np.asarray(x, np.float64), t)
    for spacing in ("time_uniform", "time_quadratic"):
        g = Grid.build(sched, 80, spacing=spacing)
        s = UniPC(model, g, order=2, prediction="noise")
        x0 = s.sample_pc(x_T, use_corrector=True)
        err = float(np.max(np.abs(x0 - dpm.exact_solution(x_T, g.t[-1]))))
        assert err < 0.05, (spacing, err)
