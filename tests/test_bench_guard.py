"""Regression tests: the bench harness fails LOUDLY on broken artifacts
(ISSUE 6 satellite).

`benchmarks/guard.py` and `benchmarks/run.py --summarize` are the committed
perf trajectory's immune system — a missing or corrupt BENCH_*.json must be
a non-zero exit that NAMES the artifact, never a silent skip. These tests
drive both as subprocesses against a scratch copy of the real artifacts so
the checks stay honest against schema drift.

Stdlib-only under the hood (neither tool imports jax), so this module runs
in well under a second despite spawning interpreters.
"""

import json
import os
import shutil
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ARTIFACTS = ("BENCH_serve.json", "BENCH_tuning.json", "BENCH_model.json")


@pytest.fixture
def bench_root(tmp_path):
    """A scratch dir holding copies of the committed bench artifacts."""
    for name in ARTIFACTS:
        shutil.copy(os.path.join(REPO, name), tmp_path / name)
    return tmp_path


def _guard(root):
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "benchmarks", "guard.py"),
         "--root", str(root)],
        capture_output=True, text=True)


def _summarize(root):
    # run.py's own `sys.path.insert(0, "src")` is cwd-relative; running from
    # the scratch root needs both the package and repro on PYTHONPATH
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join([REPO, os.path.join(REPO, "src")]),
               JAX_PLATFORMS="cpu")
    return subprocess.run(
        [sys.executable, "-m", "benchmarks.run", "--summarize"],
        cwd=str(root), env=env, capture_output=True, text=True)


def test_guard_passes_on_committed_artifacts(bench_root):
    r = _guard(bench_root)
    assert r.returncode == 0, r.stderr
    assert "bench guard ok" in r.stdout


@pytest.mark.parametrize("victim", ARTIFACTS)
def test_guard_fails_and_names_missing_artifact(bench_root, victim):
    os.remove(bench_root / victim)
    r = _guard(bench_root)
    assert r.returncode != 0
    assert victim in r.stderr and "missing" in r.stderr


@pytest.mark.parametrize("victim", ARTIFACTS)
def test_guard_fails_and_names_corrupt_artifact(bench_root, victim):
    (bench_root / victim).write_text("{not json", encoding="utf-8")
    r = _guard(bench_root)
    assert r.returncode != 0
    assert victim in r.stderr and "corrupt" in r.stderr


def test_guard_fails_when_async_runs_are_dropped(bench_root):
    """The pipelined-serving trajectory (DESIGN.md §13) is load-bearing:
    stripping async_runs from an otherwise valid BENCH_serve.json must fail
    the guard by name."""
    path = bench_root / "BENCH_serve.json"
    data = json.loads(path.read_text())
    data.pop("async_runs")
    path.write_text(json.dumps(data))
    r = _guard(bench_root)
    assert r.returncode != 0
    assert "async_runs" in r.stderr and "BENCH_serve.json" in r.stderr


def test_guard_fails_when_async_loses_throughput(bench_root):
    """Pipelined serving falling behind the synchronous loop (beyond the
    noise floor) must trip the async acceptance check."""
    path = bench_root / "BENCH_serve.json"
    data = json.loads(path.read_text())
    for run in data["async_runs"]:
        if run["pipeline_depth"] >= 2:
            run["throughput_rps"] *= 0.5
    path.write_text(json.dumps(data))
    r = _guard(bench_root)
    assert r.returncode != 0
    assert "lost throughput" in r.stderr


def test_guard_fails_when_host_overhead_blows_the_cap(bench_root):
    """Host bookkeeping creeping back onto the critical path (e.g. a
    reintroduced per-slot Python loop) must trip the host-fraction cap."""
    path = bench_root / "BENCH_serve.json"
    data = json.loads(path.read_text())
    for run in data["async_runs"]:
        if run["pipeline_depth"] == 1:
            run["host_us_per_tick"] = run["tick_s"] * 1e6  # 100% of the tick
    path.write_text(json.dumps(data))
    r = _guard(bench_root)
    assert r.returncode != 0
    assert "host bookkeeping overhead" in r.stderr


def test_guard_fails_when_phase_split_is_dropped(bench_root):
    """The per-phase host split (DESIGN.md §15) is part of the committed
    serving trajectory: an async run without host_phase_us_per_tick must
    fail the guard by name."""
    path = bench_root / "BENCH_serve.json"
    data = json.loads(path.read_text())
    for run in data["async_runs"]:
        run.pop("host_phase_us_per_tick", None)
    path.write_text(json.dumps(data))
    r = _guard(bench_root)
    assert r.returncode != 0
    assert "host_phase_us_per_tick" in r.stderr


def test_guard_fails_when_phase_split_drifts_from_aggregate(bench_root):
    """admission + bookkeeping must equal host_us_per_tick — both come from
    the same nanosecond counters, so a gap means the split and the aggregate
    are computed by divergent code paths."""
    path = bench_root / "BENCH_serve.json"
    data = json.loads(path.read_text())
    for run in data["async_runs"]:
        run["host_phase_us_per_tick"]["admission"] += 1000.0
    path.write_text(json.dumps(data))
    r = _guard(bench_root)
    assert r.returncode != 0
    assert "phase split drifted" in r.stderr


def test_guard_fails_when_obs_runs_are_dropped(bench_root):
    """The tracing-overhead comparison (DESIGN.md §15) is load-bearing:
    stripping obs_runs from BENCH_serve.json must fail the guard by name."""
    path = bench_root / "BENCH_serve.json"
    data = json.loads(path.read_text())
    data.pop("obs_runs")
    path.write_text(json.dumps(data))
    r = _guard(bench_root)
    assert r.returncode != 0
    assert "obs_runs" in r.stderr and "BENCH_serve.json" in r.stderr


def test_guard_fails_when_tracing_overhead_blows_the_cap(bench_root):
    """Tracing leaving the cheap path (e.g. formatting events at record time
    instead of at export) must trip the obs-overhead cap."""
    path = bench_root / "BENCH_serve.json"
    data = json.loads(path.read_text())
    for run in data["obs_runs"]:
        if run.get("traced"):
            run["obs_overhead_frac"] = 0.5
    path.write_text(json.dumps(data))
    r = _guard(bench_root)
    assert r.returncode != 0
    assert "tracing overhead" in r.stderr


def test_guard_fails_when_fault_runs_are_dropped(bench_root):
    """The resilience pricing (DESIGN.md §16) is load-bearing: stripping
    fault_runs from BENCH_serve.json must fail the guard by name."""
    path = bench_root / "BENCH_serve.json"
    data = json.loads(path.read_text())
    data.pop("fault_runs")
    path.write_text(json.dumps(data))
    r = _guard(bench_root)
    assert r.returncode != 0
    assert "fault_runs" in r.stderr and "BENCH_serve.json" in r.stderr


def test_guard_fails_when_fault_free_overhead_blows_the_cap(bench_root):
    """The armed-but-idle resilience layer creeping onto the hot path (a
    policy check that allocates, a counter registered eagerly) must trip
    the fault-free overhead cap."""
    path = bench_root / "BENCH_serve.json"
    data = json.loads(path.read_text())
    for run in data["fault_runs"]:
        if run.get("resilience") == "armed":
            run["fault_free_overhead_frac"] = 0.5
    path.write_text(json.dumps(data))
    r = _guard(bench_root)
    assert r.returncode != 0
    assert "armed-but-idle" in r.stderr


def test_guard_fails_when_faulted_run_drops_requests(bench_root):
    """Recovery that stops recovering — the faulted run completing fewer
    requests than were submitted — must fail the guard."""
    path = bench_root / "BENCH_serve.json"
    data = json.loads(path.read_text())
    for run in data["fault_runs"]:
        if run.get("resilience") == "faulted":
            run["completed"] = run["requests"] - 1
    path.write_text(json.dumps(data))
    r = _guard(bench_root)
    assert r.returncode != 0
    assert "complete every request" in r.stderr


def test_guard_fails_when_faults_stop_firing(bench_root):
    """A faulted row with no recovery/retry on the ledger means the
    injected faults silently stopped exercising the resilience paths."""
    path = bench_root / "BENCH_serve.json"
    data = json.loads(path.read_text())
    for run in data["fault_runs"]:
        if run.get("resilience") == "faulted":
            run["recoveries"] = 0
    path.write_text(json.dumps(data))
    r = _guard(bench_root)
    assert r.returncode != 0
    assert "injected faults" in r.stderr


def test_guard_fails_when_cached_runs_are_dropped(bench_root):
    """The feature-reuse acceptance trajectory (DESIGN.md §12) is load-
    bearing: stripping cached_runs from an otherwise valid BENCH_tuning.json
    must fail the guard by name."""
    path = bench_root / "BENCH_tuning.json"
    data = json.loads(path.read_text())
    data.pop("cached_runs")
    path.write_text(json.dumps(data))
    r = _guard(bench_root)
    assert r.returncode != 0
    assert "cached_runs" in r.stderr and "BENCH_tuning.json" in r.stderr


def test_guard_fails_when_cache_stops_paying(bench_root):
    """Every cached run pinned at the NFE floor (no eval saved) must trip
    the below-floor acceptance check."""
    path = bench_root / "BENCH_tuning.json"
    data = json.loads(path.read_text())
    for run in data["cached_runs"]:
        run["evals_per_latent"] = run["nfe_evals"]
    path.write_text(json.dumps(data))
    r = _guard(bench_root)
    assert r.returncode != 0
    assert "below its NFE floor" in r.stderr


def test_guard_fails_when_quant_runs_are_dropped(bench_root):
    """The quantized-eval trajectory (DESIGN.md §14) is load-bearing:
    stripping quant_runs from an otherwise valid BENCH_model.json must fail
    the guard by name."""
    path = bench_root / "BENCH_model.json"
    data = json.loads(path.read_text())
    data.pop("quant_runs")
    path.write_text(json.dumps(data))
    r = _guard(bench_root)
    assert r.returncode != 0
    assert "quant_runs" in r.stderr and "BENCH_model.json" in r.stderr


def test_guard_fails_when_w8_tier_disappears(bench_root):
    """Each arch must keep a w8 row — an artifact that only carries some
    other tier predates (or silently dropped) the acceptance criterion."""
    path = bench_root / "BENCH_model.json"
    data = json.loads(path.read_text())
    arch = data["quant_runs"][0]["arch"]
    data["quant_runs"] = [run for run in data["quant_runs"]
                          if run["arch"] != arch
                          or not str(run["mode"]).startswith("w8")]
    path.write_text(json.dumps(data))
    r = _guard(bench_root)
    assert r.returncode != 0
    assert "no w8 tier" in r.stderr and arch in r.stderr


def test_guard_fails_when_quant_stops_shrinking_params(bench_root):
    """Param-bytes is the platform-independent win, so it is enforced even
    on cpu-stamped artifacts: a quant tier whose packed bytes match fp32
    quantized nothing."""
    path = bench_root / "BENCH_model.json"
    data = json.loads(path.read_text())
    for run in data["quant_runs"]:
        run["quant_param_bytes"] = run["fp32_param_bytes"]
    path.write_text(json.dumps(data))
    r = _guard(bench_root)
    assert r.returncode != 0
    assert "shrinks param" in r.stderr


def test_guard_warns_but_passes_without_env_stamp(bench_root):
    """A pre-stamp artifact is treated as cpu-produced: low-precision
    wall-clock rules go informational rather than failing spuriously."""
    path = bench_root / "BENCH_model.json"
    data = json.loads(path.read_text())
    data.pop("env")
    path.write_text(json.dumps(data))
    r = _guard(bench_root)
    assert r.returncode == 0, r.stderr
    assert "no env stamp" in r.stdout


def test_guard_enforces_lowp_wallclock_on_accelerator_stamp(bench_root):
    """The same committed cpu numbers re-stamped as gpu-produced must fail:
    on an accelerator the bf16/quant wall-clock and HBM wins are enforced,
    not informational."""
    path = bench_root / "BENCH_model.json"
    data = json.loads(path.read_text())
    data["env"]["backend"] = "gpu"
    path.write_text(json.dumps(data))
    r = _guard(bench_root)
    assert r.returncode != 0
    assert "bf16" in r.stderr or "quant tier" in r.stderr


def test_summarize_ok_then_fatal_on_empty_root(bench_root, tmp_path):
    r = _summarize(bench_root)
    assert r.returncode == 0, r.stderr
    assert "bench summary" in r.stdout
    empty = tmp_path / "empty"
    empty.mkdir()
    r = _summarize(empty)
    assert r.returncode != 0
    assert "no BENCH_*.json artifacts" in (r.stderr + r.stdout)


def test_summarize_fatal_on_corrupt_artifact(bench_root):
    (bench_root / "BENCH_model.json").write_text("[1,", encoding="utf-8")
    r = _summarize(bench_root)
    assert r.returncode != 0
    assert "BENCH_model.json" in r.stderr and "corrupt" in r.stderr


def test_summarize_fatal_on_schema_drift(bench_root):
    (bench_root / "BENCH_serve.json").write_text(json.dumps({"rows": []}))
    r = _summarize(bench_root)
    assert r.returncode != 0
    assert "BENCH_serve.json" in r.stderr and "'runs'" in r.stderr


def test_summarize_fatal_when_quant_runs_are_dropped(bench_root):
    """run.py's artifact-integrity pass mirrors the guard: BENCH_model.json
    without its quant_runs section is a hole in the tracked trajectory."""
    path = bench_root / "BENCH_model.json"
    data = json.loads(path.read_text())
    data["quant_runs"] = []
    path.write_text(json.dumps(data))
    r = _summarize(bench_root)
    assert r.returncode != 0
    assert "quant_runs" in r.stderr and "BENCH_model.json" in r.stderr
