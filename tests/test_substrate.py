"""Optimizer, checkpointing, sharding helpers, HLO parser, roofline math."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo import analyze, parse_computations
from repro.analysis.roofline import Roofline, active_params
from repro.checkpoint import ckpt
from repro.configs.registry import get_config
from repro.optim import AdamW, warmup_cosine


def test_adamw_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state = opt.update(g, state, params)
    assert float(loss(params)) < 1e-3


def test_warmup_cosine():
    lr = warmup_cosine(1.0, 10, 100)
    assert float(lr(jnp.asarray(0))) == 0.0
    assert abs(float(lr(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(lr(jnp.asarray(100))) <= 0.2
    assert float(lr(jnp.asarray(5))) == 0.5


def test_ckpt_roundtrip():
    tree = {"a": {"b": np.arange(12, dtype=np.float32).reshape(3, 4)},
            "c": [np.ones((2,), np.int32), np.zeros((5,), np.float32)],
            "d": np.float32(3.5)}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, tree, step=7)
        out, step = ckpt.restore(d)
    assert step == 7
    np.testing.assert_array_equal(out["a"]["b"], tree["a"]["b"])
    np.testing.assert_array_equal(out["c"][0], tree["c"][0])
    np.testing.assert_array_equal(out["c"][1], tree["c"][1])
    assert float(out["d"]) == 3.5


def test_ckpt_multi_shard():
    tree = {f"k{i}": np.random.default_rng(i).normal(
        size=(64, 64)).astype(np.float32) for i in range(8)}
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, tree, shard_mb=0)  # force one shard per array
        assert len([f for f in os.listdir(d) if f.endswith(".npz")]) == 8
        out, _ = ckpt.restore(d)
    for k in tree:
        np.testing.assert_array_equal(out[k], tree[k])


def test_shard_noop_without_context():
    from repro.parallel.sharding import shard
    x = jnp.ones((4, 4))
    assert shard(x, "batch", "d_model") is x


def test_hlo_trip_count_scaling():
    """flops of a 12-iteration scan == 12x the single matmul."""
    def step(w, x):
        def body(h, wl):
            return jnp.tanh(h @ wl), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    w = jnp.ones((12, 64, 64))
    x = jnp.ones((8, 64))
    compiled = jax.jit(step).lower(w, x).compile()
    acct = analyze(compiled.as_text(), 1)
    expect = 12 * 2 * 8 * 64 * 64
    assert abs(acct["flops"] - expect) / expect < 0.05, acct["flops"]


def test_hlo_collectives_detected():
    """A psum across 1-device 'mesh' compiles away; check parser on text with
    a synthetic all-reduce line instead."""
    text = """
HloModule m

ENTRY %main.1 (p: f32[128,256]) -> f32[128,256] {
  %p = f32[128,256]{1,0} parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(%p), replica_groups=[2,8]<=[16], to_apply=%add.1
  ROOT %r = f32[128,256]{1,0} copy(%ar)
}
"""
    acct = analyze(text, 16)
    b = 128 * 256 * 4
    assert abs(acct["collectives"]["all-reduce"] - 2 * (7 / 8) * b) < 1.0


def test_roofline_bottleneck():
    r = Roofline(flops=1e15, hbm_bytes=1e12, collective_bytes=1e9, chips=256,
                 model_flops=6e14)
    assert r.compute_s > 0 and r.memory_s > 0 and r.collective_s > 0
    assert r.bottleneck in ("compute", "memory", "collective")
    assert 0 < r.useful_flops_ratio < 1


def test_active_params_moe_less_than_total():
    moe = get_config("mixtral-8x7b")
    act = active_params(moe)
    # top-2 of 8 experts: active far below the ~46B total
    assert 1e10 < act < 2e10


def test_param_sharding_inference():
    from repro.launch.mesh import make_host_mesh
    from repro.launch.specs import abstract_params, param_shardings
    from repro.parallel.sharding import TRAIN_RULES
    cfg = get_config("olmo-1b").reduced()
    mesh = make_host_mesh()
    p_abs = abstract_params(cfg)
    sh = param_shardings(p_abs, mesh, TRAIN_RULES)
    assert jax.tree.structure(sh) == jax.tree.structure(p_abs)
