"""Hypothesis property tests for the step-row machinery under tuned plans
(ISSUE 6 satellite): `augment_step_rows` row-gather identity, `stack_step_rows`
span bookkeeping, and plan JSON round-trip bit-exactness — all under random
NFE / per-step order / tier mixes, with and without a cache schedule.

Skipped (not errored) when hypothesis is absent so the suite collects on
minimal installs; `pip install -e .[test]` pulls it in (pyproject.toml).
"""

import json

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.coeffs import augment_step_rows, stack_step_rows  # noqa: E402
from repro.diffusion import VPLinear  # noqa: E402
from repro.tuning import SolverPlan  # noqa: E402

VP = VPLinear()

# table columns compared for bit-exactness after a round trip
TABLE_COLS = ("base_x", "base_m0", "w_pred", "w_corr_prev", "w_corr_new",
              "use_corrector", "out_scale", "lambdas", "alphas", "sigmas",
              "timesteps")
# augmented-row keys whose body rows must mirror the table columns
ROW_OF_COL = {"base_x": "base_x", "base_m0": "base_m0", "w_pred": "w_pred",
              "w_corr_prev": "w_corr_prev", "w_corr_new": "w_corr_new",
              "use_c": "use_corrector", "out_scale": "out_scale"}


@st.composite
def plans(draw, cached=None):
    """A random valid SolverPlan; `cached` forces the cache axis on/off
    (None draws it) so tier mixes can share one model-column set."""
    nfe = draw(st.integers(2, 10))
    per_step = lambda elems: st.lists(elems, min_size=nfe, max_size=nfe)
    knots = sorted(draw(st.lists(
        st.floats(0.01, 0.99, allow_nan=False), unique=True,
        min_size=nfe - 1, max_size=nfe - 1)))
    if cached is None:
        cached = draw(st.booleans())
    depth = (draw(per_step(st.sampled_from([0, 1]))) if cached else None)
    return SolverPlan(
        nfe=nfe, knots=knots,
        orders=draw(per_step(st.integers(1, 3))),
        corrector=draw(per_step(st.booleans())),
        variants=draw(per_step(st.sampled_from(["bh1", "bh2"]))),
        cache_depth=depth)


@given(plans())
@settings(max_examples=40, deadline=None)
def test_augmented_rows_gather_back_to_the_table(plan):
    """Row 0 is the identity init row; rows 1..M are the table's own columns
    bit-for-bit; model columns keep their native (M+1,) layout."""
    tab = plan.compile(VP)
    rows = augment_step_rows(tab)
    M = plan.nfe
    for key, col in ROW_OF_COL.items():
        np.testing.assert_array_equal(rows[key][1:], getattr(tab, col),
                                      err_msg=key)
    assert rows["base_x"][0] == 1.0 and rows["base_m0"][0] == 0.0
    for key in ("w_pred", "w_corr_prev", "w_corr_new", "use_c", "out_scale"):
        assert not np.any(rows[key][0]), key
    np.testing.assert_array_equal(rows["t"], tab.timesteps)
    assert all(len(rows[k]) == M + 1 for k in rows)
    if plan.cache_depth is not None:
        np.testing.assert_array_equal(rows["mc_cache_reuse"],
                                      tab.model_cols["cache_reuse"])
        assert rows["mc_cache_reuse"][0] == 0.0  # the init eval seeds, fully


@given(st.lists(st.tuples(st.sampled_from(["fast", "mid", "hq", "xl"]),
                          st.booleans()),
                min_size=1, max_size=4, unique_by=lambda nb: nb[0]),
       st.data())
@settings(max_examples=25, deadline=None)
def test_stacked_spans_recover_each_tier_exactly(names_cached, data):
    """Tier spans are contiguous, cover the stack, and slicing a tier's span
    out of the stacked rows reproduces that tier's own augmented rows
    (difference columns zero-padded to the widest tier)."""
    cached = any(c for _, c in names_cached)  # one column set per bank
    tabs = {name: data.draw(plans(cached=cached)).compile(VP)
            for name, _ in names_cached}
    stacked, tiers = stack_step_rows(tabs)
    assert list(tiers) == list(tabs)
    offset = 0
    K = max(t.w_pred.shape[1] for t in tabs.values())
    for name, tab in tabs.items():
        off, n = tiers[name]
        assert off == offset and n == len(tab.timesteps)
        offset += n
        own = augment_step_rows(tab)
        for key in ("w_pred", "w_corr_prev"):
            pad = K - own[key].shape[1]
            if pad:
                own[key] = np.pad(own[key], ((0, 0), (0, pad)))
        for key, v in own.items():
            np.testing.assert_array_equal(stacked[key][off:off + n], v,
                                          err_msg=f"{name}/{key}")
    assert all(len(v) == offset for v in stacked.values())


@given(plans())
@settings(max_examples=40, deadline=None)
def test_plan_json_round_trip_is_bit_exact(plan):
    """to_dict -> json text -> from_dict compiles to the SAME table bit for
    bit (floats survive JSON exactly: python json round-trips doubles)."""
    loaded = SolverPlan.from_dict(json.loads(json.dumps(plan.to_dict())))
    assert loaded.to_dict() == plan.to_dict()
    assert loaded.cache_depth == plan.cache_depth
    assert loaded.cache_block == plan.cache_block
    a, b = plan.compile(VP), loaded.compile(VP)
    for col in TABLE_COLS:
        np.testing.assert_array_equal(getattr(a, col), getattr(b, col),
                                      err_msg=col)
    assert sorted((a.model_cols or {})) == sorted((b.model_cols or {}))
    for k in (a.model_cols or {}):
        np.testing.assert_array_equal(a.model_cols[k], b.model_cols[k],
                                      err_msg=k)
