"""Serving observability (DESIGN.md §15): tracer, metrics registry, probe.

The load-bearing properties:

* the registry IS the metrics substrate — `run_trace`'s ServeMetrics is
  derived from a registry snapshot delta, and must equal the legacy
  arithmetic recomputed from `sched.completions` here;
* the deterministic snapshot slice (`deterministic_only=True`) is
  bit-identical across pipeline depths 1/2/3 on the same arrival trace;
* attaching a Tracer changes NOTHING about the computation — latents are
  exactly equal with tracing on and off — and the exported trace validates
  against the Chrome trace_event schema;
* zero-completion runs report 0.0 percentiles everywhere (including
  per-tier) instead of crashing np.percentile;
* the metrics artifact round-trips: `obsreport --check`'s re-derivation of
  ServeMetrics from the raw snapshot equals the embedded aggregate.
"""

import json

import numpy as np
import pytest

from repro.engine import EngineSpec, SamplerEngine
from repro.obs import (MetricsRegistry, QualityProbe, Tracer, delta,
                      parse_fullname, probe_selected, render_report,
                      snapshot_percentile, span_stats, validate_metrics,
                      validate_trace, write_metrics_artifact)
from repro.obs.metrics import Histogram
from repro.serving import Request, SlotScheduler, run_trace
from repro.serving.server import serve_metrics_from_snapshot

from test_serving import _cfg_engine, _eps_jx, _tier_specs, _x_T

# ---------------------------------------------------------------------------
# metrics registry primitives
# ---------------------------------------------------------------------------


def test_histogram_buckets_and_percentiles():
    h = Histogram(buckets=(1.0, 2.0, 4.0))
    assert h.percentile(95) == 0.0  # empty -> 0.0, never an exception
    for v in (0.5, 1.0, 3.0, 100.0):
        h.observe(v)
    # bisect_left on upper bounds: 1.0 lands IN the le=1 bucket
    assert h.counts == [2, 0, 1, 1]
    assert h.count == 4 and h.sum == pytest.approx(104.5)
    assert h.percentile(50) == float(np.percentile([0.5, 1.0, 3.0, 100.0], 50))


def test_histogram_sample_cap_sets_truncated_flag():
    h = Histogram(buckets=(1.0,), sample_cap=2)
    for v in (0.1, 0.2, 0.3):
        h.observe(v)
    assert h.samples == [0.1, 0.2] and h.samples_truncated
    assert h.count == 3  # bucket state keeps counting past the cap


def test_registry_get_or_create_and_kind_mismatch():
    reg = MetricsRegistry()
    c = reg.counter("x", {"tier": "fast"})
    assert reg.counter("x", {"tier": "fast"}) is c
    assert reg.counter("x", {"tier": "slow"}) is not c
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("x", {"tier": "fast"})


def test_snapshot_delta_and_wall_exclusion():
    reg = MetricsRegistry()
    c = reg.counter("ticks")
    g = reg.gauge("wall_s", wall=True)
    h = reg.histogram("lat", buckets=(1.0, 4.0))
    c.inc(3)
    h.observe(2.0)
    snap0 = reg.snapshot()
    c.inc(2)
    g.set(1.5)
    h.observe(0.5)
    d = delta(snap0, reg.snapshot())
    assert d["ticks"]["value"] == 2
    assert d["lat"]["count"] == 1 and d["lat"]["samples"] == [0.5]
    assert d["wall_s"]["value"] == 1.5  # gauges keep the after-value
    # wall metrics are excluded from the deterministic slice
    assert "wall_s" not in reg.snapshot(deterministic_only=True)
    assert "ticks" in reg.snapshot(deterministic_only=True)


def test_fullname_roundtrip_and_exposition():
    reg = MetricsRegistry()
    reg.counter("done", {"tier": "fast"}).inc(7)
    reg.histogram("lat", buckets=(1.0, 2.0), help="latency").observe(1.5)
    snap = reg.snapshot()
    assert parse_fullname('done{tier="fast"}') == ("done", {"tier": "fast"})
    assert all(parse_fullname(full)[0] in ("done", "lat") for full in snap)
    text = reg.exposition()
    assert 'done{tier="fast"} 7' in text
    assert "# TYPE lat histogram" in text
    assert 'lat_bucket{le="+Inf"} 1' in text and "lat_count 1" in text


# ---------------------------------------------------------------------------
# tracer + schema validation
# ---------------------------------------------------------------------------


def test_tracer_events_and_validation(tmp_path):
    tr = Tracer(capacity=64, meta={"arch": "test"})
    t0 = 1000
    tr.complete("tick", t0, t0 + 5000, args={"tick": 0})
    tr.instant("note", args={"k": 1})
    tr.counter("slots", {"busy": 2})
    tr.async_begin("request", 7, args={"tier": "fast"})
    tr.async_instant("admit", 7)
    tr.async_end("request", 7)
    obj = json.loads(json.dumps(tr.to_json()))
    assert validate_trace(obj) == []
    phs = [e["ph"] for e in obj["traceEvents"]]
    assert phs.count("X") == 1 and "b" in phs and "e" in phs
    assert obj["otherData"]["arch"] == "test"
    p = tmp_path / "t.json"
    tr.export(str(p))
    assert validate_trace(json.loads(p.read_text())) == []


def test_validate_trace_names_violations():
    assert validate_trace([]) != []  # not an object
    bad = {"traceEvents": [{"ph": "X", "name": "t", "ts": 0}],  # no dur
           "otherData": {"schema": "repro.obs.trace/v1",
                         "dropped_events": 0}}
    errs = validate_trace(bad)
    assert any("dur" in e for e in errs)
    unbalanced = {"traceEvents": [{"ph": "b", "name": "request", "ts": 0,
                                   "id": "1", "cat": "request"}],
                  "otherData": {"schema": "repro.obs.trace/v1",
                                "dropped_events": 0}}
    assert any("unbalanced" in e for e in validate_trace(unbalanced))


def test_tracer_ring_drops_oldest_and_counts():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.instant(f"e{i}")
    assert tr.dropped == 6
    names = [e["name"] for e in tr.events()]
    assert names == ["e6", "e7", "e8", "e9"]
    obj = json.loads(json.dumps(tr.to_json()))
    assert obj["otherData"]["dropped_events"] == 6
    # balanced-span validation is skipped once events were dropped
    assert validate_trace(obj) == []


# ---------------------------------------------------------------------------
# scheduler integration: derivation parity, determinism, zero-change tracing
# ---------------------------------------------------------------------------


def _poisson_reqs(n=9, rate=0.5, seed=5):
    from repro.serving import poisson_requests
    return [Request(rid=r.rid, arrival=r.arrival, x_T=_x_T(r.rid))
            for r in poisson_requests(n, rate=rate, seed=seed)]


def _sched(gaussian_dpm, depth=1, **kw):
    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    program = eng.build_step(EngineSpec(solver="unipc", order=3, nfe=7))
    return SlotScheduler(program, 3, (8,), pipeline_depth=depth, **kw)


def test_registry_derived_metrics_match_legacy_arithmetic(gaussian_dpm):
    """ServeMetrics (now derived from the registry snapshot delta) must equal
    the legacy formulas recomputed from the completion records."""
    sched = _sched(gaussian_dpm)
    m = run_trace(sched, _poisson_reqs())
    cs = sched.completions
    lat = [c.finish_clock - c.arrival for c in cs]
    assert m.requests == 9 and m.completed == len(cs) == 9
    assert m.ticks == m.evals == sched.ticks
    assert m.makespan_ticks == max(c.finish_clock for c in cs)
    assert m.throughput_per_tick == len(cs) / max(m.makespan_ticks, 1.0)
    assert m.latency_ticks_p50 == float(np.percentile(lat, 50))
    assert m.latency_ticks_p95 == float(np.percentile(lat, 95))
    assert m.evals_per_latent == sched.ticks * sched.slots / len(cs)
    assert 0.0 < m.occupancy <= 1.0
    assert m.host_phase_us_per_tick is not None
    split = (m.host_phase_us_per_tick["admission"]
             + m.host_phase_us_per_tick["bookkeeping"])
    assert split == pytest.approx(m.host_us_per_tick)


def test_zero_completion_run_reports_zeros():
    """The np.percentile edge case (satellite): an empty snapshot delta —
    a run that admitted and completed nothing — derives all-zero metrics,
    per-tier included, with no exception anywhere."""
    m = serve_metrics_from_snapshot({}, mode="continuous", slots=4, n_rows=8)
    assert m.completed == 0 and m.ticks == 0
    assert m.occupancy == 0.0 and m.latency_ticks_p50 == 0.0
    assert m.latency_ticks_p95 == 0.0 and m.host_us_per_tick == 0.0
    assert m.throughput_per_tick == 0.0
    # a tier that registered but never completed: empty histogram -> 0.0
    d = {'tier_completed{tier="fast"}': {"type": "counter", "wall": False,
                                         "value": 0},
         'tier_latency_ticks{tier="fast"}': {"type": "histogram",
                                             "wall": False,
                                             "buckets": [1.0], "counts": [0, 0],
                                             "sum": 0.0, "count": 0,
                                             "samples": []}}
    m = serve_metrics_from_snapshot(d, mode="continuous", slots=4, n_rows=8)
    assert m.per_tier == {"fast": {"completed": 0, "evals": 0,
                                   "eval_cost": 0.0,
                                   "latency_ticks_p50": 0.0}}


def test_deterministic_snapshot_identical_across_depths(gaussian_dpm):
    """The registry's deterministic slice is bit-identical at pipeline
    depths 1/2/3 on the same arrival trace — wall-clock metrics are the
    only thing depth may change."""
    snaps = {}
    for depth in (1, 2, 3):
        sched = _sched(gaussian_dpm, depth=depth)
        run_trace(sched, _poisson_reqs())
        snaps[depth] = sched.registry.snapshot(deterministic_only=True)
    assert snaps[1] == snaps[2] == snaps[3]
    assert any(parse_fullname(k)[0] == "latency_ticks" for k in snaps[1])


def test_tracer_changes_nothing_and_trace_validates(gaussian_dpm):
    """Attaching a Tracer is observation only: latents, completion records,
    and deterministic metrics are EXACTLY equal to the untraced run, and the
    emitted trace is schema-valid with balanced request spans."""
    plain = _sched(gaussian_dpm)
    m0 = run_trace(plain, _poisson_reqs())
    tr = Tracer()
    traced = _sched(gaussian_dpm, depth=2, tracer=tr)
    m1 = run_trace(traced, _poisson_reqs())
    assert [c.rid for c in plain.completions] \
        == [c.rid for c in traced.completions]
    for a, b in zip(plain.completions, traced.completions):
        np.testing.assert_array_equal(a.latent, b.latent)
    assert (m0.ticks, m0.latency_ticks_p50, m0.occupancy) \
        == (m1.ticks, m1.latency_ticks_p50, m1.occupancy)
    obj = json.loads(json.dumps(tr.to_json()))
    assert validate_trace(obj) == []
    stats = span_stats(obj)
    assert {"tick", "admission", "dispatch"} <= set(stats)
    assert stats["tick"]["count"] == m1.ticks
    begins = sum(1 for e in obj["traceEvents"] if e["ph"] == "b")
    ends = sum(1 for e in obj["traceEvents"] if e["ph"] == "e")
    assert begins == ends == 9


def test_tiered_metrics_ride_the_registry(vp):
    """Per-tier rows come from labelled registry metrics now; the derivation
    must still produce the plan-bank view (each tier's completions, evals,
    eval_cost, latency p50)."""
    eng = _cfg_engine(vp)
    tiers = {k: EngineSpec(solver="unipc", nfe=s.nfe, order=s.order,
                           cfg_scale=2.0)
             for k, s in _tier_specs().items()}
    program = eng.build_bank(tiers)
    sched = SlotScheduler(program, 3, (8,))
    names = ["fast", "balanced", "quality"]
    reqs = [Request(rid=i, arrival=float(i), x_T=_x_T(i), tier=names[i % 3],
                    cfg_scale=2.0)
            for i in range(6)]
    m = run_trace(sched, reqs)
    assert m.completed == 6 and set(m.per_tier) == set(names)
    for t in names:
        cs = [c for c in sched.completions if c.tier == t]
        row = m.per_tier[t]
        assert row["completed"] == len(cs) == 2
        assert row["evals"] == cs[0].evals
        assert row["latency_ticks_p50"] == float(np.percentile(
            [c.finish_clock - c.arrival for c in cs], 50))


# ---------------------------------------------------------------------------
# artifact round-trip (the obsreport --check contract)
# ---------------------------------------------------------------------------


def test_metrics_artifact_roundtrips_exactly(gaussian_dpm, tmp_path):
    """Writing the artifact and re-deriving ServeMetrics from its raw
    snapshot (through JSON) must reproduce the embedded aggregate EXACTLY —
    the acceptance criterion obsreport --check enforces."""
    from repro.launch.obsreport import check_metrics_roundtrip

    sched = _sched(gaussian_dpm, depth=2)
    reg = sched.registry
    snap0 = reg.snapshot()
    rows = []
    m = run_trace(sched, _poisson_reqs(), snapshot_every=3, snapshot_log=rows)
    path = tmp_path / "metrics.json"
    write_metrics_artifact(
        str(path), metrics=delta(snap0, reg.snapshot()),
        serve_metrics=m.row(),
        static={"mode": m.mode, "slots": m.slots, "n_rows": m.n_rows,
                "pipeline_depth": m.pipeline_depth},
        exposition=reg.exposition(), rows=rows)
    obj = json.loads(path.read_text())
    assert validate_metrics(obj) == []
    assert check_metrics_roundtrip(obj) == []
    assert len(obj["rows"]) >= 1
    # periodic rows are the compact sample-free form
    for row in obj["rows"]:
        for full, rec in row["metrics"].items():
            assert "samples" not in rec, full
    report = render_report(metrics=obj)
    assert "where a tick goes" in report and "admission" in report


def test_validate_metrics_names_violations():
    assert validate_metrics([]) != []
    bad = {"schema": "repro.obs.metrics/v1",
           "run": {"metrics": {"h": {"type": "histogram", "buckets": [1.0],
                                     "counts": [1], "count": 2, "sum": 0.5}}},
           "serve_metrics": {}, "exposition": "", "rows": []}
    errs = validate_metrics(bad)
    assert any("length mismatch" in e for e in errs)
    assert any("count != sum" in e for e in errs)
    assert any("serve_metrics" in e
               for e in validate_metrics({"schema": "repro.obs.metrics/v1",
                                          "run": {"metrics": {}}}))


# ---------------------------------------------------------------------------
# quality probe
# ---------------------------------------------------------------------------


def test_probe_selection_is_deterministic_and_proportional():
    sel = [probe_selected(r, 0.25, salt=3) for r in range(4000)]
    assert sel == [probe_selected(r, 0.25, salt=3) for r in range(4000)]
    assert 0.2 < np.mean(sel) < 0.3
    assert not any(probe_selected(r, 0.0) for r in range(100))
    assert all(probe_selected(r, 1.0) for r in range(100))


def test_probe_records_discrepancy_against_reference(gaussian_dpm):
    """End to end on the scheduler: a probe replaying every completion
    against a higher-NFE uniform scan records small-but-nonzero trajectory
    discrepancies per tier, into the registry and the summary."""
    import jax.numpy as jnp

    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    ref = eng.build(EngineSpec(solver="unipc", order=3, nfe=24))

    def reference_fn(x_T, g=None, extras=None):
        return np.asarray(ref(jnp.asarray(x_T)[None, :]))[0]

    program = eng.build_step(EngineSpec(solver="unipc", order=3, nfe=7))
    probe = QualityProbe(reference_fn, fraction=1.0)
    sched = SlotScheduler(program, 3, (8,), probe=probe)
    run_trace(sched, _poisson_reqs(n=5))
    assert len(probe.results) == 5
    for r in probe.results:
        assert 0.0 < r["discrepancy"] < 0.5
    summ = probe.summary()
    assert summ["default"]["count"] == 5
    assert 0.0 < summ["default"]["mean"] <= summ["default"]["max"]
    snap = sched.registry.snapshot()
    assert snap['probe_requests{tier="default"}']["value"] == 5
    assert snap['probe_discrepancy_hist{tier="default"}']["count"] == 5


def test_probe_fraction_and_max_probes_bound_the_replay(gaussian_dpm):
    calls = []

    def reference_fn(x_T, g=None, extras=None):
        calls.append(1)
        return np.asarray(x_T)

    probe = QualityProbe(reference_fn, fraction=1.0, max_probes=2)
    sched = _sched(gaussian_dpm, probe=probe)
    run_trace(sched, _poisson_reqs(n=6))
    assert len(calls) == 2 and len(probe.results) == 2
    # unselected rids never touch the reference runner
    probe0 = QualityProbe(reference_fn, fraction=0.0)
    sched0 = _sched(gaussian_dpm, probe=probe0)
    run_trace(sched0, _poisson_reqs(n=4))
    assert len(calls) == 2 and probe0.results == []
