"""End-to-end behaviour tests: training reduces loss, serving produces tokens,
UniPC sampling of a trained model beats DDIM at equal NFE (the paper's claim,
measured with the paper's own convergence-error metric)."""

import numpy as np
import pytest

from repro.launch.serve import serve, serve_diffusion
from repro.launch.train import train


@pytest.mark.slow
def test_train_loss_decreases():
    _, hist = train("qwen2-0.5b", reduced=True, objective="ar", steps=120,
                    batch=8, seq=64, lr=2e-3, log_every=5)
    first = np.mean([h["loss"] for h in hist[:2]])
    last = np.mean([h["loss"] for h in hist[-3:]])
    assert last < first - 0.05, (first, last)


@pytest.mark.slow
def test_diffusion_train_loss_decreases():
    _, hist = train("olmo-1b", reduced=True, objective="diffusion", steps=80,
                    batch=8, seq=32, lr=2e-3, log_every=5)
    first = np.mean([h["loss"] for h in hist[:3]])
    last = np.mean([h["loss"] for h in hist[-3:]])
    assert last < first - 0.05, (first, last)


def test_serve_emits_tokens():
    out = serve("olmo-1b", reduced=True, batch=2, prompt_len=12, gen=5)
    assert out.shape == (2, 5)
    assert out.dtype in (np.int32, np.int64)


def test_serve_diffusion_emits_latents():
    """The dit serving path: a request batch rides one UniPC scan, both with
    the fused-update dispatch (the default) and with it pinned off."""
    outs = [serve_diffusion("dit-cifar", reduced=True, batch=2, nfe=4,
                            fused_update=f) for f in (True, False)]
    for out in outs:
        assert out.shape[0] == 2 and np.isfinite(out).all()
    np.testing.assert_allclose(outs[0], outs[1], rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_unipc_beats_ddim_on_trained_model(tmp_path):
    """Fig. 4c methodology: l2 distance to a fine-grid reference, UniPC-3 vs
    DDIM at NFE=8 on a (briefly) trained DiT.

    The training budget matters: at 40 steps the eps-net is still near its
    random init, both solvers' errors are dominated by the rough model rather
    than discretization, and they tie (observed ratio ~1.001 — the seed-state
    flake). At 120 steps the model is smooth enough for solver order to show:
    observed unipc/ddim error ratio ~0.73 on this fixed seed, so the 0.9
    assertion bound has a comfortable deterministic margin."""
    import jax
    import jax.numpy as jnp
    from repro.configs.registry import get_config
    from repro.core import DDIM, Grid, UniPC
    from repro.diffusion import VPLinear, wrap_model
    from repro.launch.train import train as _train
    from repro.models import api

    params, _ = _train("dit-cifar", reduced=True, objective="diffusion",
                       steps=120, batch=8, seq=32, lr=1e-3, log_every=50)
    cfg = get_config("dit-cifar").reduced()
    sched = VPLinear()
    net = api.eps_network(cfg)
    extra = {"class_ids": jnp.zeros((2,), jnp.int32)}
    eps = jax.jit(lambda x, t: net(params, x, jnp.asarray(t, jnp.float32),
                                   extra))
    model = wrap_model(sched, eps, "data")
    x_T = jax.random.normal(jax.random.PRNGKey(0),
                            (2, cfg.patch_tokens, cfg.latent_dim))
    ref = np.asarray(DDIM(model, Grid.build(sched, 200),
                          prediction="data").sample(x_T))
    D = np.sqrt(ref.size)
    errs = {}
    g = Grid.build(sched, 8)
    errs["ddim"] = np.linalg.norm(
        np.asarray(DDIM(model, g, prediction="data").sample(x_T)) - ref) / D
    u = UniPC(model, Grid.build(sched, 8), order=3, prediction="data")
    errs["unipc"] = np.linalg.norm(
        np.asarray(u.sample_pc(x_T, use_corrector=True)) - ref) / D
    assert errs["unipc"] < 0.9 * errs["ddim"], errs
