"""Per-architecture smoke tests (reduced configs) + serving consistency.

Every assigned arch: one forward/train step on CPU, asserting output shapes
and no NaNs; plus the strong correctness check that prefill+decode reproduces
the full-sequence forward's next-token logits.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import all_arch_ids, get_config
from repro.models import api

ARCHS = all_arch_ids()


def _batch(cfg, B=2, S=32):
    b = {"tokens": jnp.asarray(np.random.default_rng(0).integers(
            0, cfg.vocab_size, (B, S)), jnp.int32),
         "targets": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "vlm":
        b["image_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(1), (B, cfg.image_tokens, cfg.d_model))
    if cfg.family == "audio":
        b["audio_embeds"] = 0.02 * jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.audio_frames, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch, rng):
    """One AR train step on the reduced config: finite loss, finite grads."""
    cfg = get_config(arch).reduced()
    params = api.init_params(cfg, rng)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(api.train_loss(cfg, "ar"))(
        params, batch, rng)
    assert np.isfinite(float(loss)), arch
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_diffusion_step(arch, rng):
    """The paper-technique objective lowers for every backbone family."""
    cfg = get_config(arch).reduced()
    params = api.init_params(cfg, rng)
    loss = api.train_loss(cfg, "diffusion")(params, _batch(cfg), rng)
    assert np.isfinite(float(loss)), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_shapes(arch, rng):
    cfg = get_config(arch).reduced()
    params = api.init_params(cfg, rng)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits, cache = api.prefill_fn(cfg)(params, batch, max_len=S + 8)
    assert logits.shape == (B, 1, cfg.vocab_size)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    logits2, cache = api.decode_fn(cfg)(params, cache, tok, jnp.int32(S))
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32))), arch


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "olmo-1b", "mixtral-8x7b",
                                  "mamba2-780m", "zamba2-7b", "whisper-small",
                                  "llama-3.2-vision-90b"])
def test_decode_matches_forward(arch, rng):
    """prefill(t[:S]) then decode(t[S]) must equal the full forward's logits
    at position S (same cache semantics as the fused training path)."""
    import dataclasses
    cfg = get_config(arch).reduced()
    if cfg.num_experts:
        # ample capacity: the scatter dispatch (forward/prefill) must then
        # agree exactly with the dense decode path — no token drops
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = api.init_params(cfg, rng)
    B, S = 2, 17
    batch = _batch(cfg, B, S + 1)
    full = dict(batch)
    # full forward logits at position S given tokens[0..S]
    from repro.models import transformer, hybrid, vlm, encdec
    bk = params["backbone"]
    if cfg.family in ("dense", "moe"):
        hidden, _ = transformer.forward(bk, cfg, batch["tokens"])
    elif cfg.family == "ssm":
        hidden, _ = hybrid.mamba_forward(bk, cfg, batch["tokens"])
    elif cfg.family == "hybrid":
        hidden, _ = hybrid.zamba_forward(bk, cfg, batch["tokens"])
    elif cfg.family == "vlm":
        hidden, _ = vlm.vlm_forward(bk, cfg, batch["tokens"],
                                    batch["image_embeds"])
    else:
        hidden, _ = encdec.encdec_forward(bk, cfg, batch["tokens"],
                                          batch["audio_embeds"])
    ref = transformer.logits_from_hidden(bk, cfg, hidden)[:, S]

    pre = {k: (v[:, :S] if k in ("tokens", "targets") else v)
           for k, v in batch.items()}
    _, cache = api.prefill_fn(cfg)(params, pre, max_len=S + 4)
    logits, _ = api.decode_fn(cfg)(params, cache, batch["tokens"][:, S:S + 1],
                                   jnp.int32(S))
    np.testing.assert_allclose(np.asarray(logits[:, 0]), np.asarray(ref),
                               rtol=2e-2, atol=2e-3)


def test_sliding_window_cache(rng):
    """Rolling SWA cache: decode with window W attends only to the last W."""
    import dataclasses
    cfg = dataclasses.replace(get_config("qwen2-0.5b").reduced(),
                              sliding_window=8)
    params = api.init_params(cfg, rng)
    B, S = 1, 12
    batch = _batch(cfg, B, S + 1)
    from repro.models import transformer
    hidden, _ = transformer.forward(params["backbone"], cfg, batch["tokens"])
    ref = transformer.logits_from_hidden(params["backbone"], cfg, hidden)[:, S]
    pre = {"tokens": batch["tokens"][:, :S]}
    _, cache = api.prefill_fn(cfg)(params, pre, max_len=S + 4)
    assert cache["k"].shape[2] == 8  # window-sized, not max_len
    logits, _ = api.decode_fn(cfg)(params, cache, batch["tokens"][:, S:S + 1],
                                   jnp.int32(S))
    np.testing.assert_allclose(np.asarray(logits[:, 0]), np.asarray(ref),
                               rtol=2e-2, atol=2e-3)
