"""Unit tests for the exponential-integrator functions and UniPC coefficients."""

import math

import numpy as np
import pytest

from repro.core import phi as phi_mod
from repro.core.coeffs import (bh_value, build_unipc_schedule,
                               default_order_schedule, unipc_weights)
from repro.core.phi import (g_vec, phi_vec, psi, psi1_closed, psi2_closed,
                            psi3_closed, varphi, varphi1_closed,
                            varphi2_closed, varphi3_closed)


@pytest.mark.parametrize("h", [0.01, 0.1, 0.4, 0.7, 2.0, 5.0])
def test_varphi_closed_forms(h):
    # NB: at small h the *closed forms* cancel catastrophically (that is why
    # the implementation switches to the series) — tolerance scales with 1/h.
    tol = 1e-10 if h >= 0.4 else 1e-6
    np.testing.assert_allclose(varphi(1, h), varphi1_closed(h), rtol=tol)
    np.testing.assert_allclose(varphi(2, h), varphi2_closed(h), rtol=tol)
    np.testing.assert_allclose(varphi(3, h), varphi3_closed(h), rtol=10 * tol)
    np.testing.assert_allclose(psi(1, h), psi1_closed(h), rtol=tol)
    np.testing.assert_allclose(psi(2, h), psi2_closed(h), rtol=tol)
    np.testing.assert_allclose(psi(3, h), psi3_closed(h), rtol=10 * tol)


def test_varphi_recursion_identity():
    # varphi_{n+1}(h) = (varphi_n(h) - 1/n!)/h (Thm 3.1) across the series/
    # recursion switch point
    for h in [1e-4, 0.05, 0.49, 0.51, 1.3]:
        for n in range(0, 5):
            lhs = varphi(n + 1, h)
            rhs = (varphi(n, h) - 1.0 / math.factorial(n)) / h
            np.testing.assert_allclose(lhs, rhs, rtol=1e-7, atol=1e-12)


def test_small_h_stability():
    # the recursion cancels catastrophically; series must stay accurate
    for h in [1e-8, 1e-6, 1e-4]:
        v = varphi(4, h)
        assert abs(v - 1.0 / math.factorial(4)) < 1e-4
        assert np.isfinite(v)


def test_degenerate_a1_is_half():
    # App. F: UniC-1 / UniP-2 admit a_1 = 0.5 for both B1 and B2
    for variant in ("bh1", "bh2"):
        for pred in ("noise", "data"):
            w = unipc_weights(np.array([1.0]), 0.2, variant, pred,
                              degenerate_a1=True)
            B = bh_value(0.2, variant, pred)
            np.testing.assert_allclose(w, [0.5 * B], rtol=1e-12)


def test_exact_solve_b_independent():
    # with exact Vandermonde solves, w = R^{-1} phi and B(h) cancels
    r = np.array([-1.3, -0.6, 1.0])
    for pred in ("noise", "data"):
        w1 = unipc_weights(r, 0.3, "bh1", pred)
        w2 = unipc_weights(r, 0.3, "bh2", pred)
        np.testing.assert_allclose(w1, w2, rtol=1e-9)


def test_vary_matches_exact_solve():
    # UniPC_v's A = C^{-1} satisfies the same moment conditions exactly
    r = np.array([-0.9, -0.4, 1.0])
    for pred in ("noise", "data"):
        wv = unipc_weights(r, 0.25, "vary", pred)
        wb = unipc_weights(r, 0.25, "bh2", pred)
        np.testing.assert_allclose(wv, wb, rtol=1e-8)


def test_moment_conditions():
    # R_p(h) a B(h) = phi_p(h) exactly for the solved systems (Eq. 5)
    h = 0.35
    r = np.array([-1.1, -0.5, 1.0])
    for pred, vec in (("noise", phi_vec), ("data", g_vec)):
        w = unipc_weights(r, h, "bh2", pred)  # w = B a / r
        a_r = w * r  # = B a
        R = np.vander(r * h, N=3, increasing=True).T
        target = vec(3, h)
        np.testing.assert_allclose(R @ a_r, target, rtol=1e-8)


def test_default_order_schedule():
    assert default_order_schedule(6, 3, lower_order_final=False) == [1, 2, 3, 3, 3, 3]
    assert default_order_schedule(6, 3, lower_order_final=True) == [1, 2, 3, 3, 2, 1]


def test_build_schedule_shapes(vp):
    from repro.core import make_unipc_schedule
    s = make_unipc_schedule(vp, 8, order=3, prediction="data", variant="bh2")
    assert s.w_pred.shape == (8, 2)
    assert s.w_corr_prev.shape == (8, 2)
    assert s.w_corr_new.shape == (8,)
    assert s.use_corrector[-1] == 0.0  # no corrector after the last step
    assert np.all(np.isfinite(s.w_pred)) and np.all(np.isfinite(s.w_corr_prev))
