"""Continuous-batching serving: per-slot step function + request scheduler.

The acceptance property: a batch of requests started at *staggered* ticks
through the scheduler produces, per request, latents matching the uniform
`build()` scan for the same (solver, order, nfe, seed, cfg-scale) — across
solvers and with per-request guidance scales. Plus scheduler invariants
(eval count == ticks, occupancy, gang-mode degradation) and the 1-device
mesh/SERVE_RULES bit-identity of both engine paths.
"""

from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.diffusion import GaussianDPM
from repro.engine import EngineSpec, SamplerEngine
from repro.serving import Request, SlotScheduler, poisson_requests, run_trace


def _eps_jx(dpm):
    """Gaussian-DPM eps-net that accepts scalar or per-sample (B,) t."""
    sched = dpm.schedule

    def eps(x, t):
        t = jnp.asarray(t)
        a = jnp.exp(sched.log_alpha_jax(t))
        sig = jnp.sqrt(1 - a * a)
        if t.ndim == 1:
            bshape = (-1,) + (1,) * (x.ndim - 1)
            a, sig = a.reshape(bshape), sig.reshape(bshape)
        return sig * (x - a * dpm.mu) / (a * a * dpm.s ** 2 + sig * sig)

    return eps


def _cfg_engine(vp):
    cond = GaussianDPM(vp, mu=0.7, s=0.35)
    uncond = GaussianDPM(vp, mu=-0.4, s=0.5)
    eps_c, eps_u = _eps_jx(cond), _eps_jx(uncond)

    def eps_stacked(xx, t):
        x1, x2 = jnp.split(xx, 2, axis=0)
        tt = jnp.asarray(t)
        t1, t2 = (jnp.split(tt, 2, axis=0) if tt.ndim == 1 else (tt, tt))
        return jnp.concatenate([eps_c(x1, t1), eps_u(x2, t2)], axis=0)

    return SamplerEngine(vp, eps=eps_c, eps_stacked=eps_stacked,
                         eps_uncond=eps_u)


def _x_T(rid, d=8):
    return np.random.default_rng(100 + rid).normal(size=(d,)).astype(np.float32)


def _staggered_serve(engine, spec, rids, arrivals, slots, cfg_scales=None):
    """Run rids through the scheduler with the given arrival ticks; returns
    {rid: latent}."""
    program = engine.build_step(spec)
    sched = SlotScheduler(program, slots, (8,))
    reqs = [Request(rid=r, arrival=float(a), x_T=_x_T(r),
                    cfg_scale=None if cfg_scales is None else cfg_scales[i])
            for i, (r, a) in enumerate(zip(rids, arrivals))]
    run_trace(sched, reqs)
    return {c.rid: c.latent for c in sched.completions}, sched


# ---------------------------------------------------------------------------
# heterogeneous-batch parity (the acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("solver,order", [
    ("unipc", 3), ("dpmpp", 2), ("deis", 3), ("pndm", 4), ("ddim", 1),
])
def test_staggered_requests_match_uniform_scan(gaussian_dpm, solver, order):
    """Six requests admitted at staggered ticks over three slots == the
    uniform build() scan per request, <=1e-5 fp32."""
    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    spec = EngineSpec(solver=solver, order=order, nfe=8)
    rids = list(range(6))
    got, sched = _staggered_serve(eng, spec, rids,
                                  arrivals=[0, 0, 2, 5, 7, 11], slots=3)
    xs = jnp.asarray(np.stack([_x_T(r) for r in rids]))
    ref = np.asarray(eng.build(spec)(xs))
    assert len(got) == len(rids)
    for i, r in enumerate(rids):
        np.testing.assert_allclose(got[r], ref[i], atol=1e-5, rtol=0)
    # invariant: one batched eval per tick, every request on its full budget
    assert sched.evals == sched.ticks
    assert all(c.evals == sched.program.n_rows for c in sched.completions)


def test_per_request_guidance_scales_match_uniform_scan(vp):
    """Per-slot cfg: one compiled program serves requests at different
    guidance scales; each matches a uniform scan built at that scale."""
    eng = _cfg_engine(vp)
    spec = EngineSpec(solver="unipc", order=3, nfe=8, cfg_scale=2.0)
    scales = [1.0, 2.0, 3.5, 0.0, 2.0]
    rids = list(range(5))
    got, _ = _staggered_serve(eng, spec, rids, arrivals=[0, 0, 1, 4, 6],
                              slots=2, cfg_scales=scales)
    for r, s in zip(rids, scales):
        ref_spec = replace(spec, cfg_scale=s)
        ref = np.asarray(eng.build(ref_spec)(
            jnp.asarray(_x_T(r))[None, :]))[0]
        np.testing.assert_allclose(got[r], ref, atol=1e-5, rtol=0,
                                   err_msg=f"rid={r} cfg_scale={s}")


def test_per_request_cfg_with_schedule_and_thresholding(vp):
    """Scheduled guidance + dynamic thresholding survive the per-slot path:
    the table contributes the schedule *profile*, the slot its scale."""
    eng = _cfg_engine(vp)
    spec = EngineSpec(solver="unipc", order=2, nfe=8, cfg_scale=2.0,
                      cfg_schedule="linear", cfg_scale_end=1.0,
                      thresholding=True)
    got, _ = _staggered_serve(eng, spec, [0, 1], arrivals=[0, 3], slots=2,
                              cfg_scales=[2.0, 2.0])
    ref = np.asarray(eng.build(spec)(
        jnp.asarray(np.stack([_x_T(0), _x_T(1)]))))
    np.testing.assert_allclose(got[0], ref[0], atol=1e-5, rtol=0)
    np.testing.assert_allclose(got[1], ref[1], atol=1e-5, rtol=0)


# ---------------------------------------------------------------------------
# scheduler mechanics
# ---------------------------------------------------------------------------


def test_scheduler_invariants_and_occupancy(gaussian_dpm):
    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    program = eng.build_step(EngineSpec(solver="dpmpp", order=2, nfe=6))
    sched = SlotScheduler(program, slots=3, sample_shape=(8,))
    reqs = poisson_requests(7, rate=0.6, seed=3)
    m = run_trace(sched, reqs)
    assert m.completed == 7 and m.evals == m.ticks
    assert 0.0 < m.occupancy <= 1.0
    assert m.evals_per_latent >= program.n_rows / sched.slots
    # per-request NFE accounting: every completion consumed the full grid
    assert all(c.evals == program.n_rows for c in sched.completions)
    # latency can never undercut the service time
    assert m.latency_ticks_p50 >= program.n_rows


def test_gang_mode_admits_only_into_empty_batch(gaussian_dpm):
    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    program = eng.build_step(EngineSpec(solver="ddim", order=1, nfe=4))
    sched = SlotScheduler(program, slots=2, sample_shape=(8,), gang=True)
    for r in range(3):
        sched.submit(Request(rid=r, x_T=_x_T(r)))
    sched.tick()
    assert sched.active == 2 and len(sched.queue) == 1
    # mid-flight ticks must NOT admit the queued request
    sched.tick()
    assert sched.active == 2 and len(sched.queue) == 1
    sched.drain()
    assert len(sched.completions) == 3


def test_continuous_beats_gang_at_2x_arrival_rate(gaussian_dpm):
    """The serving win: at 2x the slot-capacity arrival rate, continuous
    batching finishes the trace sooner (higher throughput) and wastes fewer
    slot-evals per latent than sequential full-batch serving."""
    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    spec = EngineSpec(solver="unipc", order=3, nfe=8)
    slots = 4

    def run(gang):
        program = eng.build_step(spec)
        sched = SlotScheduler(program, slots, (8,), gang=gang)
        rate = 2.0 * slots / program.n_rows
        return run_trace(sched, poisson_requests(16, rate, seed=7))

    cont, gang = run(False), run(True)
    assert cont.completed == gang.completed == 16
    assert cont.throughput_per_tick > gang.throughput_per_tick
    assert cont.evals_per_latent <= gang.evals_per_latent


def test_cfg_request_on_uncond_program_is_rejected(gaussian_dpm):
    """A request carrying a guidance scale must not be silently served
    unguided by a program compiled without cfg."""
    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    program = eng.build_step(EngineSpec(solver="unipc", order=2, nfe=4))
    sched = SlotScheduler(program, slots=2, sample_shape=(8,))
    with pytest.raises(ValueError, match="without guidance"):
        sched.submit(Request(rid=0, cfg_scale=3.0))
    # an explicit 0.0 is the unguided path and stays accepted
    sched.submit(Request(rid=1, cfg_scale=0.0, x_T=_x_T(1)))
    sched.drain()
    assert len(sched.completions) == 1


def test_latency_uses_trace_clock_across_idle_gaps(gaussian_dpm):
    """Completion latency is measured on the arrival clock: a request after
    a long idle gap (which the trace driver fast-forwards over) still gets
    latency >= its own service time, never a negative value."""
    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    program = eng.build_step(EngineSpec(solver="unipc", order=2, nfe=4))
    sched = SlotScheduler(program, slots=2, sample_shape=(8,))
    reqs = [Request(rid=0, x_T=_x_T(0), arrival=0.0),
            Request(rid=1, x_T=_x_T(1), arrival=50.0)]
    run_trace(sched, reqs)
    lats = {c.rid: c.latency_ticks for c in sched.completions}
    assert lats[0] == program.n_rows
    assert lats[1] == program.n_rows  # admitted immediately after the gap


def test_idle_slots_are_identity_and_poison_free(gaussian_dpm):
    """Ticks with idle slots must not corrupt the active ones, and an idle
    slot's state must stay fixed (the init row is an identity update)."""
    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    program = eng.build_step(EngineSpec(solver="unipc", order=3, nfe=6))
    sched = SlotScheduler(program, slots=3, sample_shape=(8,))
    sched.submit(Request(rid=0, x_T=_x_T(0)))
    before = np.asarray(sched.state[0][1:])
    for _ in range(program.n_rows):
        sched.tick()
    np.testing.assert_array_equal(np.asarray(sched.state[0][1:]), before)
    ref = np.asarray(eng.build(EngineSpec(solver="unipc", order=3, nfe=6))(
        jnp.asarray(_x_T(0))[None, :]))[0]
    np.testing.assert_allclose(sched.completions[0].latent, ref,
                               atol=1e-5, rtol=0)


def test_per_request_class_conditioning_is_slot_independent():
    """A dit request's class conditioning rides the request (scheduler
    extras), not the slot: the same (seed, class_id, cfg_scale) request
    produces the same latent no matter which slot admission lands it in."""
    from repro.configs.registry import get_config
    from repro.diffusion import VPLinear
    from repro.launch.sample import NULL_CLASS_ID, build_engine
    from repro.models import api

    cfg = get_config("dit-cifar").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    engine = build_engine(cfg, params, VPLinear(), 2, 0, want_cfg=True,
                          per_request_cond=True)
    program = engine.build_step(
        EngineSpec(solver="unipc", order=2, nfe=3, cfg_scale=2.0))

    def serve(reqs):
        sched = SlotScheduler(program, 2,
                              (cfg.patch_tokens, cfg.latent_dim),
                              extras_init={"class_ids": NULL_CLASS_ID})
        run_trace(sched, reqs)
        return {c.rid: c.latent for c in sched.completions}

    probe = dict(seed=42, cfg_scale=3.0, extras={"class_ids": 7})
    # alone -> slot 0
    solo = serve([Request(rid=9, **probe)])
    # behind an earlier request -> slot 1
    staggered = serve([Request(rid=0, seed=1, arrival=0.0,
                               extras={"class_ids": 3}),
                       Request(rid=9, arrival=1.0, **probe)])
    np.testing.assert_array_equal(solo[9], staggered[9])


# ---------------------------------------------------------------------------
# plan banks: mixed-tier batches (DESIGN.md §10)
# ---------------------------------------------------------------------------


def _tier_specs():
    return {"fast": EngineSpec(solver="unipc", nfe=5, order=2),
            "balanced": EngineSpec(solver="unipc", nfe=8, order=3),
            "quality": EngineSpec(solver="unipc", nfe=12, order=3)}


def test_mixed_tier_batch_matches_per_tier_uniform_scans(gaussian_dpm):
    """The bank acceptance property: fast/balanced/quality requests served
    out of ONE compiled StepProgram match each tier's own uniform build()
    scan <= 1e-5 fp32."""
    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    tiers = _tier_specs()
    program = eng.build_bank(tiers)
    assert set(program.tiers) == set(tiers)
    sched = SlotScheduler(program, 3, (8,))
    names = ["fast", "balanced", "quality", "quality", "fast", "balanced"]
    reqs = [Request(rid=r, arrival=float(a), x_T=_x_T(r), tier=names[r])
            for r, a in zip(range(6), [0, 0, 1, 3, 6, 9])]
    run_trace(sched, reqs)
    got = {c.rid: c for c in sched.completions}
    assert len(got) == 6
    for r, name in enumerate(names):
        ref = np.asarray(eng.build(tiers[name])(
            jnp.asarray(_x_T(r))[None, :]))[0]
        np.testing.assert_allclose(got[r].latent, ref, atol=1e-5, rtol=0,
                                   err_msg=f"rid={r} tier={name}")
        # per-tier NFE accounting: evals == that tier's own row count
        assert got[r].evals == tiers[name].nfe + 1
        assert got[r].tier == name
    assert sched.evals == sched.ticks


def test_bank_with_per_request_guidance_scales(vp):
    """Tiers and per-request cfg compose: a bank program serves requests at
    different tiers AND different guidance scales, each matching the uniform
    scan built at that (tier, scale)."""
    eng = _cfg_engine(vp)
    tiers = {"fast": EngineSpec(solver="unipc", nfe=4, order=2,
                                cfg_scale=2.0),
             "quality": EngineSpec(solver="unipc", nfe=9, order=3,
                                   cfg_scale=2.0)}
    program = eng.build_bank(tiers)
    sched = SlotScheduler(program, 2, (8,))
    cases = [(0, "fast", 1.0), (1, "quality", 3.0), (2, "fast", 2.0)]
    reqs = [Request(rid=r, arrival=float(i), x_T=_x_T(r), tier=t,
                    cfg_scale=s) for i, (r, t, s) in enumerate(cases)]
    run_trace(sched, reqs)
    got = {c.rid: c.latent for c in sched.completions}
    for r, t, s in cases:
        ref_spec = replace(tiers[t], cfg_scale=s)
        ref = np.asarray(eng.build(ref_spec)(
            jnp.asarray(_x_T(r))[None, :]))[0]
        np.testing.assert_allclose(got[r], ref, atol=1e-5, rtol=0,
                                   err_msg=f"rid={r} tier={t} scale={s}")


def test_bank_from_tuned_plans_round_trips_through_serving(vp, tmp_path):
    """save_bank -> load_bank -> build_bank(tables=plan tables) serves each
    tier exactly as the plan's own uniform scan."""
    from repro.tuning import SolverPlan, load_bank, save_bank

    dpm = GaussianDPM(vp)
    eng = SamplerEngine(vp, eps=_eps_jx(dpm))
    plans = {"fast": SolverPlan.default(4, order=2),
             "quality": SolverPlan.default(8, order=3)}
    path = str(tmp_path / "bank.json")
    save_bank(path, plans)
    loaded = load_bank(path)
    tier_specs = {k: EngineSpec(solver="unipc", nfe=p.nfe,
                                order=max(p.orders))
                  for k, p in loaded.items()}
    tables = {k: p.compile(vp) for k, p in loaded.items()}
    program = eng.build_bank(tier_specs, tables)
    sched = SlotScheduler(program, 2, (8,))
    reqs = [Request(rid=0, x_T=_x_T(0), tier="fast"),
            Request(rid=1, x_T=_x_T(1), tier="quality", arrival=1.0)]
    run_trace(sched, reqs)
    got = {c.rid: c.latent for c in sched.completions}
    for r, k in ((0, "fast"), (1, "quality")):
        ref = np.asarray(eng.build(tier_specs[k],
                                   table=tables[k])(
            jnp.asarray(_x_T(r))[None, :]))[0]
        np.testing.assert_allclose(got[r], ref, atol=1e-5, rtol=0)


def test_tier_tags_are_validated(gaussian_dpm):
    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    bank = eng.build_bank({"fast": EngineSpec(solver="unipc", nfe=4,
                                              order=2)})
    sched = SlotScheduler(bank, 2, (8,))
    with pytest.raises(ValueError, match="unknown tier"):
        sched.submit(Request(rid=0, tier="turbo"))
    with pytest.raises(ValueError, match="tag requests"):
        sched.submit(Request(rid=1))          # untagged on a bank
    single = eng.build_step(EngineSpec(solver="unipc", nfe=4, order=2))
    sched2 = SlotScheduler(single, 2, (8,))
    with pytest.raises(ValueError, match="single plan"):
        sched2.submit(Request(rid=2, tier="fast"))


def test_bank_rejects_mixed_prediction_and_guidance(gaussian_dpm, vp):
    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    with pytest.raises(ValueError, match="prediction"):
        eng.build_bank({"a": EngineSpec(solver="unipc", nfe=4),
                        "b": EngineSpec(solver="ddim", nfe=4,
                                        prediction="noise")})
    eng2 = _cfg_engine(vp)
    with pytest.raises(ValueError, match="guidance scale"):
        eng2.build_bank({"a": EngineSpec(solver="unipc", nfe=4,
                                         cfg_scale=2.0),
                         "b": EngineSpec(solver="unipc", nfe=6,
                                         cfg_scale=3.0)})


def test_per_tier_metrics_reported(gaussian_dpm):
    from repro.serving import poisson_requests

    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    program = eng.build_bank(_tier_specs())
    sched = SlotScheduler(program, 3, (8,))
    reqs = poisson_requests(9, rate=0.5, seed=5,
                            tiers=["fast", "balanced", "quality"])
    m = run_trace(sched, reqs)
    assert m.completed == 9
    assert set(m.per_tier) == {"fast", "balanced", "quality"}
    for name, spec in _tier_specs().items():
        assert m.per_tier[name]["completed"] == 3
        assert m.per_tier[name]["evals"] == spec.nfe + 1
        assert m.per_tier[name]["latency_ticks_p50"] >= spec.nfe + 1


# ---------------------------------------------------------------------------
# scheduler edge cases (ISSUE 6 satellite)
# ---------------------------------------------------------------------------


def test_burst_arrivals_beyond_slots_serve_fifo(gaussian_dpm):
    """3x-slots requests all arriving at tick 0: admission drains the queue
    strictly FIFO (rid order), nothing is dropped, and the queue backlog
    shrinks only as slots free."""
    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    program = eng.build_step(EngineSpec(solver="unipc", order=2, nfe=4))
    sched = SlotScheduler(program, slots=2, sample_shape=(8,))
    for r in range(6):
        sched.submit(Request(rid=r, x_T=_x_T(r)))
    sched.tick()
    assert sched.active == 2 and len(sched.queue) == 4
    # mid-flight ticks keep the backlog: no slot frees before n_rows ticks
    for _ in range(program.n_rows - 1):
        sched.tick()
    assert len(sched.completions) == 2 and len(sched.queue) == 4
    sched.tick()                      # freed slots refill on the NEXT tick
    assert sched.active == 2 and len(sched.queue) == 2
    sched.drain()
    assert [c.rid for c in sched.completions] == list(range(6))
    finishes = [c.finish_tick for c in sched.completions]
    assert finishes == sorted(finishes)


def test_nfe_budget_one_request_completes(gaussian_dpm):
    """The minimum budget: nfe=1 compiles to 2 rows (init + one step) and a
    request consumes exactly those two evals."""
    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    spec = EngineSpec(solver="unipc", order=1, nfe=1)
    program = eng.build_step(spec)
    assert program.n_rows == 2
    sched = SlotScheduler(program, slots=2, sample_shape=(8,))
    m = run_trace(sched, [Request(rid=0, x_T=_x_T(0))])
    assert m.completed == 1 and m.ticks == 2
    c = sched.completions[0]
    assert c.evals == 2 and c.latency_ticks == 2
    ref = np.asarray(eng.build(spec)(jnp.asarray(_x_T(0))[None, :]))[0]
    np.testing.assert_allclose(c.latent, ref, atol=1e-5, rtol=0)


def test_empty_trace_and_single_tier_metrics(gaussian_dpm):
    """Zero-completion metrics must not divide by zero, and a bank trace
    that exercises only one tier reports per_tier for that tier alone."""
    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    program = eng.build_bank(_tier_specs())
    sched = SlotScheduler(program, slots=2, sample_shape=(8,))
    m0 = run_trace(sched, [])
    assert m0.completed == 0 and m0.ticks == 0 and m0.evals == 0
    assert m0.occupancy == 0.0 and m0.throughput_rps == 0.0
    assert m0.per_tier is None
    m1 = run_trace(sched, [Request(rid=0, x_T=_x_T(0), tier="fast"),
                           Request(rid=1, x_T=_x_T(1), tier="fast")])
    assert m1.completed == 2
    assert set(m1.per_tier) == {"fast"}
    assert m1.per_tier["fast"]["completed"] == 2


def test_trace_clock_resets_on_scheduler_reuse(gaussian_dpm):
    """A second trace on the same scheduler restarts the arrival clock at 0:
    its metrics cover only the new run (counter snapshots) and its
    completions' latencies are not inflated by the first run's clock."""
    eng = SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))
    program = eng.build_step(EngineSpec(solver="unipc", order=2, nfe=4))
    sched = SlotScheduler(program, slots=2, sample_shape=(8,))
    m1 = run_trace(sched, [Request(rid=0, x_T=_x_T(0), arrival=3.0)])
    assert sched.clock is None        # the driver always restores tick time
    m2 = run_trace(sched, [Request(rid=1, x_T=_x_T(1), arrival=0.0)])
    assert m1.completed == m2.completed == 1
    assert m2.ticks == program.n_rows == m2.evals
    lat = {c.rid: c.latency_ticks for c in sched.completions}
    assert lat[0] == program.n_rows   # measured from ITS arrival, not tick 0
    assert lat[1] == program.n_rows   # run-2 clock restarted at 0
    assert len(sched.completions) == 2


# ---------------------------------------------------------------------------
# 1-device mesh under SERVE_RULES: bit-identical to no mesh context
# ---------------------------------------------------------------------------


def _dit_setup(batch=2, nfe=4):
    from repro.configs.registry import get_config
    from repro.diffusion import VPLinear
    from repro.launch.sample import build_engine
    from repro.models import api

    cfg = get_config("dit-cifar").reduced()
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    engine = build_engine(cfg, params, VPLinear(), batch, 0)
    spec = EngineSpec(solver="unipc", order=3, nfe=nfe)
    x_T = jax.random.normal(jax.random.PRNGKey(1),
                            (batch, cfg.patch_tokens, cfg.latent_dim),
                            jnp.float32)
    return engine, spec, x_T


def test_scan_path_bit_identical_under_serve_rules_mesh():
    from repro.launch.mesh import make_host_mesh
    from repro.parallel.sharding import SERVE_RULES, sharding_rules

    engine, spec, x_T = _dit_setup()
    plain = np.asarray(engine.build(spec)(x_T))
    with sharding_rules(make_host_mesh(), SERVE_RULES):
        meshed = np.asarray(engine.build(spec)(x_T))
    np.testing.assert_array_equal(plain, meshed)


def test_step_path_bit_identical_under_serve_rules_mesh():
    from repro.launch.mesh import make_host_mesh
    from repro.parallel.sharding import SERVE_RULES, sharding_rules

    engine, spec, x_T = _dit_setup()

    def serve(mesh_ctx):
        program = engine.build_step(spec)
        sched = SlotScheduler(program, 2,
                              sample_shape=x_T.shape[1:])
        reqs = [Request(rid=i, arrival=float(2 * i),
                        x_T=np.asarray(x_T[i])) for i in range(2)]
        if mesh_ctx:
            with sharding_rules(make_host_mesh(), SERVE_RULES):
                run_trace(sched, reqs)
        else:
            run_trace(sched, reqs)
        return {c.rid: c.latent for c in sched.completions}

    plain, meshed = serve(False), serve(True)
    for r in plain:
        np.testing.assert_array_equal(plain[r], meshed[r])
    # and the staggered step path agrees with the uniform scan on the dit net
    ref = np.asarray(engine.build(spec)(x_T))
    for i in range(2):
        np.testing.assert_allclose(plain[i], ref[i], atol=1e-5, rtol=0)
