"""Hypothesis property-based tests on system invariants.

Skipped (not errored) when hypothesis is absent so the suite collects on
minimal installs; `pip install -e .[test]` pulls it in (pyproject.toml).
"""

import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.coeffs import unipc_weights
from repro.core.phi import g_vec, phi_vec, psi, varphi
from repro.core.solver import Grid, semilinear_base, unified_step
from repro.diffusion import VPCosine, VPLinear, timestep_grid

schedules = st.sampled_from([VPLinear(), VPCosine(),
                             VPLinear(beta_0=0.05, beta_1=10.0)])


@given(schedules, st.floats(1e-3, 1.0))
@settings(max_examples=50, deadline=None)
def test_schedule_invariants(sched, t):
    t = min(max(t, sched.t_eps), sched.T)
    a = float(sched.alpha(t))
    s = float(sched.sigma(t))
    assert 0 < a <= 1 and 0 < s < 1
    assert abs(a * a + s * s - 1.0) < 1e-9  # variance preserving
    # t_of_lam inverts lam
    lam = float(sched.lam(t))
    t2 = float(sched.t_of_lam(lam))
    assert abs(t2 - t) < 1e-6 * max(1.0, abs(t)) + 1e-7


@given(schedules, st.integers(4, 40))
@settings(max_examples=30, deadline=None)
def test_grid_monotone(sched, M):
    t, lam, alpha, sigma = timestep_grid(sched, M)
    assert np.all(np.diff(t) < 0)         # time decreasing T -> eps
    assert np.all(np.diff(lam) > 0)       # half log-SNR increasing
    assert np.all(np.diff(alpha) > 0)     # signal grows as t -> 0
    assert np.all(np.diff(sigma) < 0)


@given(st.floats(1e-6, 4.0), st.integers(1, 6))
@settings(max_examples=80, deadline=None)
def test_phi_psi_positive_and_bounded(h, p):
    v = float(varphi(p, h))
    w = float(psi(p, h))
    assert v > 0 and w > 0
    assert w <= 1.0 / math.factorial(p - 1) + 1e-9  # psi_k(h) <= psi_k(0)


@given(st.lists(st.floats(-3.0, -0.05).map(lambda v: round(v, 2)),
                min_size=0, max_size=3, unique=True),
       st.floats(0.02, 1.5), st.sampled_from(["noise", "data"]),
       st.sampled_from(["bh1", "bh2", "vary"]))
@settings(max_examples=120, deadline=None)
def test_weights_finite(r_prev, h, prediction, variant):
    # r values rounded to a 0.01 grid: near-coincident points make the
    # Vandermonde system ill-conditioned (physically: duplicate timesteps)
    if len(set(r_prev)) != len(r_prev):
        return
    r = np.array(sorted(r_prev) + [1.0])
    w = unipc_weights(r, h, variant, prediction)
    assert np.all(np.isfinite(w))
    # first-moment condition: sum w_m * r_m = b_1 (exactly solved systems)
    if len(r) > 1:
        vec = phi_vec if prediction == "noise" else g_vec
        b1 = float(vec(len(r), h)[0]) / h  # row 1 scaled: sum B a = phi_1/h...
        np.testing.assert_allclose(np.sum(w * r), b1 * h, rtol=1e-6, atol=1e-9)


@given(st.floats(-2.0, 2.0), st.floats(-1.0, 1.0))
@settings(max_examples=50, deadline=None)
def test_unified_step_affine_in_state(c1, c2):
    """The unified update is affine in (x, model outputs): scaling both input
    points scales the output (homogeneity) — a direct consequence of Eq. 3."""
    vp = VPLinear()
    t, lam, alpha, sigma = timestep_grid(vp, 4)
    x = np.array([1.0, -2.0])
    m0 = np.array([0.3, 0.1])
    pt = (float(lam[0]), np.array([0.2, -0.4]))
    kw = dict(lam_s=lam[1], lam_t=lam[2], alpha_s=alpha[1], alpha_t=alpha[2],
              sigma_s=sigma[1], sigma_t=sigma[2], prediction="noise")
    base = unified_step(x, m0, [pt], **kw)
    scaled = unified_step(c1 * x, c1 * m0, [(pt[0], c1 * pt[1])], **kw)
    np.testing.assert_allclose(scaled, c1 * base, rtol=1e-9, atol=1e-9)
    # additivity
    y = np.array([0.5, 0.25])
    m0b = np.array([-0.1, 0.2])
    ptb = (pt[0], np.array([0.05, 0.15]))
    two = unified_step(x + y, m0 + m0b, [(pt[0], pt[1] + ptb[1])], **kw)
    one_b = unified_step(y, m0b, [ptb], **kw)
    np.testing.assert_allclose(two, base + one_b, rtol=1e-9, atol=1e-9)


@given(st.integers(2, 64), st.integers(2, 1024), st.integers(0, 2 ** 31 - 1))
@settings(max_examples=20, deadline=None)
def test_token_stream_deterministic_seekable(batch, vocab, idx):
    from repro.data.synthetic import TokenStream
    s1 = TokenStream(vocab, 16, batch % 8 + 1, seed=3)
    s2 = TokenStream(vocab, 16, batch % 8 + 1, seed=3)
    b1 = s1.block(idx % 1000)
    b2 = s2.block(idx % 1000)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < vocab
