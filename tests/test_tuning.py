"""Solver-plan autotuner: plans, lowering, objective, search, plan banks.

The two acceptance properties (ISSUE 4 / DESIGN.md §10):

* plan round-trip — search -> JSON -> load -> compiled table BIT-identical;
* a tuned plan strictly beats the hand-set UniPC-2 baseline on the
  reference-trajectory discrepancy metric (analytic DPMs here; the dit-cifar
  gate runs as the CI tuning smoke and the slow system test).
"""

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.coeffs import build_unipc_schedule
from repro.diffusion import GaussianDPM
from repro.engine import EngineSpec, SamplerEngine
from repro.tuning import (SearchConfig, SolverPlan, load_bank,
                          make_objective, save_bank, tune_plan)

TABLE_COLS = ("base_x", "base_m0", "w_pred", "w_corr_prev", "w_corr_new",
              "use_corrector", "out_scale", "lambdas", "alphas", "sigmas",
              "timesteps")


def _eps_jx(dpm):
    sched = dpm.schedule

    def eps(x, t):
        t = jnp.asarray(t)
        a = jnp.exp(sched.log_alpha_jax(t))
        sig = jnp.sqrt(1 - a * a)
        if t.ndim == 1:
            bshape = (-1,) + (1,) * (x.ndim - 1)
            a, sig = a.reshape(bshape), sig.reshape(bshape)
        return sig * (x - a * dpm.mu) / (a * a * dpm.s ** 2 + sig * sig)

    return eps


def _engine(gaussian_dpm):
    return SamplerEngine(gaussian_dpm.schedule, eps=_eps_jx(gaussian_dpm))


def _objective(gaussian_dpm, nfe=6, order=2, batch=4, ref_nfe=48):
    eng = _engine(gaussian_dpm)
    spec = EngineSpec(solver="unipc", nfe=nfe, order=order)
    x_T = np.random.default_rng(0).normal(size=(batch, 8)).astype(np.float32)
    return eng, spec, make_objective(eng, spec, x_T, ref_nfe=ref_nfe)


# ---------------------------------------------------------------------------
# plans + lowering
# ---------------------------------------------------------------------------


def test_default_plan_matches_hand_set_table(vp):
    """The search starts AT the paper's baseline: the default plan's table
    equals the registry-compiled unipc table (values; the plan pads its
    difference columns to the fixed MAX_ORDER width)."""
    spec = EngineSpec(solver="unipc", nfe=8, order=2).resolve()
    eng = SamplerEngine(vp, eps=lambda x, t: x)
    ref = eng.compile(spec)
    tab = SolverPlan.from_spec(spec).compile(vp)
    for col in TABLE_COLS:
        a, b = getattr(ref, col), getattr(tab, col)
        if a.ndim == 2:  # weight columns: plan pads to MAX_ORDER-1
            b = b[:, : a.shape[1]]
            assert not np.any(tab.w_pred[:, a.shape[1]:])
        np.testing.assert_allclose(a, b, rtol=1e-12, atol=0, err_msg=col)
    assert ref.sign == tab.sign and ref.prediction == tab.prediction


def test_per_step_schedules_change_the_table(vp):
    """variant_schedule / corrector_schedule actually steer row construction."""
    from repro.diffusion.schedules import timestep_grid

    t, lam, alpha, sigma = timestep_grid(vp, 6, "logsnr")
    base = dict(lambdas=lam, alphas=alpha, sigmas=sigma, timesteps=t,
                order=2, prediction="data")
    t_bh2 = build_unipc_schedule(**base, variant="bh2")
    t_mix = build_unipc_schedule(**base, variant="bh2",
                                 variant_schedule=["bh1"] * 3 + ["bh2"] * 3)
    assert not np.allclose(t_bh2.w_pred[1:3], t_mix.w_pred[1:3])
    np.testing.assert_array_equal(t_bh2.w_pred[3:], t_mix.w_pred[3:])
    t_corr = build_unipc_schedule(**base, corrector_schedule=[1, 0, 1, 0, 1, 0])
    np.testing.assert_array_equal(t_corr.use_corrector,
                                  [1, 0, 1, 0, 1, 0])


def test_plan_validation_rejects_malformed():
    good = SolverPlan.default(4)
    with pytest.raises(ValueError, match="knots"):
        SolverPlan(nfe=4, knots=[0.5], orders=good.orders,
                   corrector=good.corrector, variants=good.variants)
    with pytest.raises(ValueError, match="increasing"):
        SolverPlan(nfe=4, knots=[0.6, 0.5, 0.7], orders=good.orders,
                   corrector=good.corrector, variants=good.variants)
    with pytest.raises(ValueError, match="orders"):
        SolverPlan(nfe=4, knots=good.knots, orders=[1, 2, 9, 1],
                   corrector=good.corrector, variants=good.variants)
    with pytest.raises(ValueError, match="variants"):
        SolverPlan(nfe=4, knots=good.knots, orders=good.orders,
                   corrector=good.corrector, variants=["bh3"] * 4)


# ---------------------------------------------------------------------------
# search + round trip (the acceptance criteria)
# ---------------------------------------------------------------------------


def test_search_json_load_compile_bit_identical(gaussian_dpm, tmp_path, vp):
    """search -> save -> load -> compile must be BIT-identical to compiling
    the in-memory winner (floats survive JSON exactly)."""
    _, _, obj = _objective(gaussian_dpm)
    init = SolverPlan.default(6, order=2)
    res = tune_plan(obj, vp, init, SearchConfig(budget=30, beam=2, rounds=1))
    path = str(tmp_path / "plan.json")
    res.plan.save(path)
    loaded = SolverPlan.load(path)
    assert loaded.to_dict() == res.plan.to_dict()
    t1, t2 = res.plan.compile(vp), loaded.compile(vp)
    for col in TABLE_COLS:
        np.testing.assert_array_equal(getattr(t1, col), getattr(t2, col),
                                      err_msg=col)


def test_tuned_plan_strictly_beats_unipc2_baseline(gaussian_dpm, vp):
    """The tuner's reason to exist: at a tight budget the searched plan's
    discrepancy is strictly below the hand-set UniPC-2 table's."""
    _, spec, obj = _objective(gaussian_dpm, nfe=6, order=2)
    init = SolverPlan.from_spec(spec)
    res = tune_plan(obj, vp, init, SearchConfig(budget=40, beam=2, rounds=2))
    assert res.baseline == pytest.approx(obj(init, vp))
    assert res.score < res.baseline
    assert res.plan.meta["objective"] == res.score
    assert res.evals <= 40 + 1


def test_search_never_regresses_and_respects_budget(vp):
    """Even when nearly nothing improves (a Gaussian at high NFE is already
    at reference accuracy), the winner is never worse than the init and the
    eval budget is honored."""
    eng = SamplerEngine(vp, eps=_eps_jx(GaussianDPM(vp)))
    spec = EngineSpec(solver="unipc", nfe=16, order=3)
    x_T = np.random.default_rng(1).normal(size=(2, 8)).astype(np.float32)
    obj = make_objective(eng, spec, x_T, ref_nfe=48)
    res = tune_plan(obj, vp, SolverPlan.from_spec(spec),
                    SearchConfig(budget=10, beam=1, rounds=1))
    assert res.score <= res.baseline
    assert res.evals <= 10


def test_objective_rejects_mismatched_prediction(gaussian_dpm, vp):
    _, _, obj = _objective(gaussian_dpm)
    noise_plan = SolverPlan.default(6, prediction="noise")
    with pytest.raises(ValueError, match="prediction"):
        obj(noise_plan, vp)


def test_objective_uses_one_runner_across_candidates(gaussian_dpm, vp):
    """Candidate scoring must not recompile: ONE jitted runner takes the row
    table as a traced argument, so same-NFE candidates share a compiled
    executable (jit's cache keys on row shapes only)."""
    _, _, obj = _objective(gaussian_dpm)
    obj(SolverPlan.default(6, order=2), vp)
    runner = obj._runner
    obj(SolverPlan.default(6, order=3), vp)
    obj(SolverPlan.default(6, order=1), vp)
    obj(SolverPlan.default(7, order=2), vp)   # new NFE: new shapes, same fn
    assert obj._runner is runner
    if hasattr(runner, "_cache_size"):
        # 4 candidates, 2 distinct row shapes (nfe 6 and 7) -> 2 compiles
        assert runner._cache_size() == 2


def test_compile_with_external_table_does_not_mutate_it(vp):
    """One plan table compiled under two specs: the second compile must not
    rewrite the first program's model columns (apply_model_cols aliasing)."""
    from dataclasses import replace

    eng = SamplerEngine(vp, eps=lambda x, t, **kw: x,
                        eps_stacked=lambda xx, t, **kw: xx)
    base = SolverPlan.default(4).compile(vp)
    spec_a = EngineSpec(solver="unipc", nfe=4, cfg_scale=2.0)
    spec_b = replace(spec_a, cfg_scale=3.0)
    tab_a = eng.compile(spec_a, table=base)
    tab_b = eng.compile(spec_b, table=base)
    assert base.model_cols in (None, {})
    assert float(tab_a.model_cols["g"][0]) == 2.0
    assert float(tab_b.model_cols["g"][0]) == 3.0


def test_search_memo_never_rescans_identical_tables(gaussian_dpm, vp):
    """Re-proposed candidates (same lowered table) are memo hits: the
    objective runs at most once per distinct table, so reported evals ==
    unique candidates scored."""
    _, spec, obj = _objective(gaussian_dpm, nfe=5)
    res = tune_plan(obj, vp, SolverPlan.from_spec(spec),
                    SearchConfig(budget=60, beam=2, rounds=3))
    assert obj.evals == res.evals       # no duplicate objective calls
    assert res.evals <= 60


# ---------------------------------------------------------------------------
# banks
# ---------------------------------------------------------------------------


def test_bank_save_load_round_trip(tmp_path):
    plans = {"fast": SolverPlan.default(4, order=2).with_meta(tier="fast"),
             "quality": SolverPlan.default(8, order=3)}
    path = str(tmp_path / "bank.json")
    save_bank(path, plans)
    loaded = load_bank(path)
    assert list(loaded) == ["fast", "quality"]
    for k in plans:
        assert loaded[k].to_dict() == plans[k].to_dict()
    plans["fast"].save(path)      # overwrite with a bare (non-bank) plan
    with pytest.raises(ValueError, match="plan bank"):
        load_bank(path)


def test_plan_json_is_versioned_and_typed(tmp_path):
    p = SolverPlan.default(4)
    path = str(tmp_path / "p.json")
    p.save(path)
    with open(path) as f:
        d = json.load(f)
    assert d["kind"] == "solver-plan" and d["version"] == 1
    with pytest.raises(ValueError, match="not a solver plan"):
        SolverPlan.from_dict({"kind": "something-else"})
