"""Quantized denoiser path (DESIGN.md §14): kernel parity, calibration,
structural routing, the serving handshake, and the tuner's parity gate.

The tiers under test are the shipped QUANT_MODES: w8a16 (per-channel int8
weights, float activations), w8a8 (static calibrated int8 activations),
fp8a16 (e4m3 weights), and w4a16 — the deliberately harsh per-tensor int4
tier whose only job is to prove the parity gate rejects an over-quantized
spec.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.diffusion import VPLinear
from repro.engine import EngineSpec
from repro.kernels.quant_matmul import ops as qops
from repro.kernels.quant_matmul import ref as qref
from repro.models import api
from repro.models.quant import (QUANT_MODES, calibrate_act_stats,
                                quant_param_bytes, quant_spec,
                                quantize_params)

# ---------------------------------------------------------------------------
# kernel package
# ---------------------------------------------------------------------------

# deliberately not tile multiples: remainder tiles on every axis
ODD_SHAPES = ((5, 37, 130), (1, 7, 3))


@pytest.mark.parametrize("granularity", qref.GRANULARITIES)
@pytest.mark.parametrize("M,K,N", ODD_SHAPES)
def test_kernel_interpret_matches_jnp_oracle(M, K, N, granularity):
    """The blocked Pallas kernel (interpreted) must agree with the fp32
    oracle at non-tile-multiple shapes — zero-padding is exact under fp32
    accumulation, so only summation order may differ."""
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (M, K), jnp.float32)
    w = jax.random.normal(kw, (K, N), jnp.float32)
    qw, ws = qref.quantize(w, granularity=granularity)
    ref = qops.quant_matmul(x, qw, ws, backend="jnp")
    ker = qops.quant_matmul(x, qw, ws, backend="interpret")
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_kernel_interpret_matches_jnp_oracle_a8():
    """Same agreement on the W8A8 path: activations quantized with a static
    scale, sa folded into the weight scale on both backends."""
    M, K, N = 5, 37, 130
    kx, kw = jax.random.split(jax.random.PRNGKey(1))
    x = jax.random.normal(kx, (M, K), jnp.float32)
    w = jax.random.normal(kw, (K, N), jnp.float32)
    qw, ws = qref.quantize(w)
    sa = float(jnp.max(jnp.abs(x))) / qref.ACT_QMAX
    ref = qops.quant_matmul(x, qw, ws, sa=sa, backend="jnp")
    ker = qops.quant_matmul(x, qw, ws, sa=sa, backend="interpret")
    np.testing.assert_allclose(np.asarray(ker), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bits", (8, 4))
@pytest.mark.parametrize("granularity", qref.GRANULARITIES)
def test_roundtrip_error_bounded_by_half_step(bits, granularity):
    """Symmetric absmax round-to-nearest: |w - deq(q(w))| <= scale/2
    elementwise, with scale broadcast per output channel."""
    w = jax.random.normal(jax.random.PRNGKey(2), (23, 17), jnp.float32)
    qw, ws = qref.quantize(w, bits=bits, granularity=granularity)
    deq = qref.dequantize(qw, ws)
    bound = np.asarray(ws)[None, :] * 0.5 + 1e-7
    assert (np.abs(np.asarray(deq) - np.asarray(w)) <= bound).all()
    if granularity == "tensor":
        assert np.unique(np.asarray(ws)).size == 1


@pytest.mark.skipif(not hasattr(jnp, "float8_e4m3fn"),
                    reason="no fp8 dtype in this jax build")
def test_fp8_quantize_roundtrip_bounded():
    w = jax.random.normal(jax.random.PRNGKey(3), (31, 9), jnp.float32)
    qw, ws = qref.quantize(w, fmt="fp8")
    assert qw.dtype == jnp.float8_e4m3fn
    deq = np.asarray(qref.dequantize(qw, ws))
    # e4m3 carries a ~2^-3 relative mantissa step after per-channel scaling
    err = np.abs(deq - np.asarray(w))
    tol = np.maximum(np.abs(np.asarray(w)) * 0.0725,
                     np.asarray(ws)[None, :] * 0.5)
    assert (err <= tol + 1e-7).all()


def test_quantize_act_static_scale_range():
    x = 3.0 * jax.random.normal(jax.random.PRNGKey(4), (11, 5), jnp.float32)
    sa = float(jnp.max(jnp.abs(x))) / qref.ACT_QMAX
    q = np.asarray(qref.quantize_act(x, sa))
    assert q.dtype == np.int8
    assert np.abs(q).max() <= qref.ACT_QMAX
    np.testing.assert_allclose(q * sa, np.asarray(x), atol=sa * 0.5 + 1e-7)


def test_quantize_rejects_bad_args():
    w = jnp.ones((4, 4))
    with pytest.raises(ValueError, match="granularity"):
        qref.quantize(w, granularity="row")
    with pytest.raises(ValueError, match="bits"):
        qref.quantize(w, bits=3)


# ---------------------------------------------------------------------------
# model-level: calibration + param-tree quantization
# ---------------------------------------------------------------------------


def _tiny_dit(seed=0, perturb=0.0, **overrides):
    cfg = get_config("dit-cifar").reduced(num_layers=2, **overrides)
    params = api.init_params(cfg, jax.random.PRNGKey(seed))
    if perturb:
        leaves, td = jax.tree.flatten(params)
        ks = jax.random.split(jax.random.PRNGKey(9), len(leaves))
        params = jax.tree.unflatten(td, [
            a + perturb * jax.random.normal(k, a.shape, a.dtype)
            if jnp.issubdtype(a.dtype, jnp.floating) else a
            for a, k in zip(leaves, ks)])
    return cfg, params


def test_calibration_bit_deterministic():
    cfg, params = _tiny_dit(perturb=0.05)
    s1 = calibrate_act_stats(cfg, params, nfe=2, batch=1, seed=0)
    s2 = calibrate_act_stats(cfg, params, nfe=2, batch=1, seed=0)
    assert sorted(s1) == sorted(s2)
    for k in s1:
        np.testing.assert_array_equal(s1[k], s2[k])
        assert (np.asarray(s1[k]) > 0).all()


def test_quantize_params_structural_routing():
    """Records land exactly at the configured families; everything else is
    untouched; a8 without calibration stats is an error."""
    cfg, params = _tiny_dit()
    spec = quant_spec("w8a16")
    qp = quantize_params(cfg, params, spec)
    blocks = qp["backbone"]["blocks"]
    for name in ("wq", "wk", "wv", "wo"):
        rec = blocks["attn"][name]
        assert set(rec) == {"qw", "ws"} and rec["qw"].dtype == jnp.int8
    for name in ("w1", "w2", "ada"):
        assert set(blocks[name]) == {"qw", "ws"}
    assert set(qp["backbone"]["final_ada"]) == {"qw", "ws"}
    # stacked block leaves keep per-block leading axis (scannable)
    assert blocks["w1"]["qw"].shape[0] == cfg.num_layers
    # non-selected leaves: same arrays, no records
    np.testing.assert_array_equal(np.asarray(qp["backbone"]["out_proj"]),
                                  np.asarray(params["backbone"]["out_proj"]))
    with pytest.raises(ValueError, match="act_bits=8"):
        quantize_params(cfg, params, quant_spec("w8a8"))


def test_quant_param_bytes_shrink():
    cfg, params = _tiny_dit()
    qp = quantize_params(cfg, params, quant_spec("w8a16"))
    n = quant_param_bytes(qp)
    assert 0 < n["quant"] < 0.3 * n["fp32"]


@pytest.mark.parametrize("mode", sorted(QUANT_MODES))
def test_quantized_eval_tracks_fp32(mode):
    """Every shipped tier's eval stays within its documented band of the
    fp32 eval on a perturbed tiny DiT; the band ordering (w8 < fp8 < w4) is
    what makes w4a16 the gate-tripping tier."""
    if mode == "fp8a16" and not hasattr(jnp, "float8_e4m3fn"):
        pytest.skip("no fp8 dtype in this jax build")
    tol = {"w8a16": 2e-2, "w8a8": 5e-2, "fp8a16": 5e-2, "w4a16": 3e-1}[mode]
    cfg, params = _tiny_dit(perturb=0.05)
    net = api.eps_network(cfg)
    x = jax.random.normal(jax.random.PRNGKey(5),
                          (2, cfg.patch_tokens, cfg.latent_dim), jnp.float32)
    t = jnp.full((2,), 0.4, jnp.float32)
    batch = {"class_ids": jnp.zeros((2,), jnp.int32)}
    ref = np.asarray(net(params, x, t, batch))
    assert np.abs(ref).max() > 0
    qcfg, qparams, info = api.calibrate_and_quantize(cfg, params, mode,
                                                     nfe=2, calib_batch=1)
    assert info["spec"] is QUANT_MODES[mode]
    q = np.asarray(api.eps_network(qcfg)(qparams, x, t, batch))
    rel = np.linalg.norm(q - ref) / np.linalg.norm(ref)
    assert rel < tol, f"{mode}: rel err {rel:.3e} >= {tol}"


def test_cached_eval_bitwise_matches_plain_under_quant():
    """Feature reuse composes with quantization structurally: the
    cache-wired eval with reuse=0 is BITWISE the plain quantized eval, and
    a shallow (reuse=1) eval runs the quantized records and stays finite."""
    cfg, params = _tiny_dit(perturb=0.05)
    qcfg, qparams, _ = api.calibrate_and_quantize(cfg, params, "w8a16")
    x = jax.random.normal(jax.random.PRNGKey(6),
                          (2, cfg.patch_tokens, cfg.latent_dim), jnp.float32)
    t = jnp.full((2,), 0.6, jnp.float32)
    batch = {"class_ids": jnp.zeros((2,), jnp.int32)}
    plain = np.asarray(api.eps_network(qcfg)(qparams, x, t, batch))
    cached_net = api.eps_network_cached(qcfg, cache_block=1)
    cache0 = jnp.zeros((2, qcfg.patch_tokens, qcfg.d_model), x.dtype)
    full, cache = cached_net(qparams, x, t, batch, cache0,
                             jnp.zeros((2,), jnp.bool_))
    np.testing.assert_array_equal(np.asarray(full), plain)
    shallow, _ = cached_net(qparams, x, t, batch, cache,
                            jnp.ones((2,), jnp.bool_))
    assert np.isfinite(np.asarray(shallow)).all()


# ---------------------------------------------------------------------------
# serving boundary: spec validation + engine handshake
# ---------------------------------------------------------------------------


def test_spec_rejects_unknown_quant_tier():
    with pytest.raises(ValueError, match="quant mode"):
        EngineSpec(solver="unipc", quant="w2a2").resolve()
    EngineSpec(solver="unipc", quant="w8a16").resolve()  # known tier is fine


def test_engine_rejects_mismatched_quant_wiring():
    """`model_fn` must reject a spec whose quant tier differs from what the
    engine's eps-net was wired for — the contract mirrors eval_dtype."""
    from repro.launch.sample import build_engine

    cfg, params = _tiny_dit()
    engine = build_engine(cfg, params, VPLinear(), 2, 0)
    spec = EngineSpec(solver="unipc", nfe=4, quant="w8a16")
    with pytest.raises(ValueError, match="quant"):
        engine.build(spec)


def test_bank_rejects_mixed_quant_tiers():
    from repro.launch.sample import build_engine

    cfg, params = _tiny_dit()
    engine = build_engine(cfg, params, VPLinear(), 2, 0, quant="w8a16")
    specs = {"a": EngineSpec(solver="unipc", nfe=4, quant="w8a16"),
             "b": EngineSpec(solver="unipc", nfe=5, quant="none")}
    with pytest.raises(ValueError, match="agree on quant"):
        engine.build_bank(specs)


def test_quantized_engine_runs_and_tracks_fp32():
    """End-to-end: the same probe latents through the fp32 engine and a
    w8a16 engine land close; the quantized run is the real scan path."""
    from repro.launch.sample import build_engine, latent_shape

    cfg, params = _tiny_dit(perturb=0.05)
    x_T = jax.random.normal(jax.random.PRNGKey(7), latent_shape(cfg, 2),
                            jnp.float32)
    fp = build_engine(cfg, params, VPLinear(), 2, 0)
    qe = build_engine(cfg, params, VPLinear(), 2, 0, quant="w8a16")
    ref = np.asarray(fp.build(EngineSpec(solver="unipc", nfe=4))(x_T))
    out = np.asarray(qe.build(
        EngineSpec(solver="unipc", nfe=4, quant="w8a16"))(x_T))
    assert np.isfinite(out).all()
    rel = np.linalg.norm(out - ref) / max(np.linalg.norm(ref), 1e-12)
    assert rel < 5e-2


# ---------------------------------------------------------------------------
# tuner parity gate
# ---------------------------------------------------------------------------


def test_quant_parity_gate_unit():
    from repro.tuning import QuantParityError, quant_parity_gate

    assert quant_parity_gate(0.11, 0.10, slack=1.5,
                             quant="w8a16") == pytest.approx(1.1)
    with pytest.raises(QuantParityError, match="over-quantized"):
        quant_parity_gate(0.20, 0.10, slack=1.5, quant="w4a16")


def test_tuner_emits_w8_and_rejects_overquantized_w4():
    """The acceptance pair on one shared setup: a w8a16 tier passes the
    default parity budget and the emitted plan records its tier; the
    per-tensor int4 tier trips the gate and no plan is emitted."""
    from repro.launch.sample import build_engine, latent_shape
    from repro.launch.tune import tune
    from repro.tuning import QuantParityError

    cfg, params = _tiny_dit(perturb=0.2)
    x_T = jax.random.normal(jax.random.PRNGKey(0), latent_shape(cfg, 2),
                            jnp.float32)
    fp = build_engine(cfg, params, VPLinear(), 2, 0)
    kw = dict(nfe=12, budget=4, rounds=1, ref_nfe=24, batch=2, x_T=x_T,
              fp32_engine=fp)
    w8 = build_engine(cfg, params, VPLinear(), 2, 0, quant="w8a16")
    plan, report = tune("dit-cifar", engine=w8, quant="w8a16", **kw)
    assert plan.meta["quant"] == "w8a16"
    assert report["quant_ratio"] <= 1.5
    assert report["fp32_baseline"] > 0
    w4 = build_engine(cfg, params, VPLinear(), 2, 0, quant="w4a16")
    with pytest.raises(QuantParityError, match="w4a16"):
        tune("dit-cifar", engine=w4, quant="w4a16", **kw)


def test_tune_with_engine_requires_fp32_anchor():
    from repro.launch.sample import build_engine, latent_shape
    from repro.launch.tune import tune

    cfg, params = _tiny_dit()
    engine = build_engine(cfg, params, VPLinear(), 2, 0, quant="w8a16")
    x_T = jax.random.normal(jax.random.PRNGKey(0), latent_shape(cfg, 2),
                            jnp.float32)
    with pytest.raises(ValueError, match="fp32_engine"):
        tune("dit-cifar", engine=engine, x_T=x_T, quant="w8a16")
