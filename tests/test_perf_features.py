"""Tests for the §Perf optimization paths: they must be *exact* rewrites."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.configs.registry import get_config
from repro.launch.mesh import make_host_mesh
from repro.models.layers import chunked_sdpa, sdpa
from repro.models.moe import moe_apply, moe_apply_shard_map, moe_init
from repro.parallel.sharding import (SEQ_PARALLEL_TRAIN_RULES, TRAIN_RULES,
                                     sharding_rules)


@pytest.mark.parametrize("chunk", [16, 32, 64])
@pytest.mark.parametrize("window", [None, 24])
def test_chunked_sdpa_exact(chunk, window):
    """H3: blockwise attention is the same softmax, blockwise."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, Hq, Hkv, D = 2, 128, 4, 2, 16
    q = jax.random.normal(ks[0], (B, S, Hq, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    ref = sdpa(q, k, v, causal=True, sliding_window=window)
    got = chunked_sdpa(q, k, v, causal=True, sliding_window=window,
                       chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_attention_chunk_config_end_to_end(rng):
    """Same logits with and without cfg.attention_chunk."""
    from repro.models import api
    base = get_config("olmo-1b").reduced()
    opt = dataclasses.replace(base, attention_chunk=16)
    params = api.init_params(base, rng)
    batch = {"tokens": jnp.arange(64, dtype=jnp.int32).reshape(1, 64) % 100,
             "targets": jnp.zeros((1, 64), jnp.int32)}
    l1 = api.train_loss(base, "ar")(params, batch, rng)
    l2 = api.train_loss(opt, "ar")(params, batch, rng)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def _moe_cfg():
    return ModelConfig(arch_id="t", family="moe", num_layers=1, d_model=32,
                       num_heads=4, d_ff=64, vocab_size=64, num_experts=4,
                       experts_per_token=2, moe_d_ff=64, capacity_factor=8.0,
                       dtype="float32", param_dtype="float32")


def test_moe_group_dispatch_matches_global():
    """H1 iter-1: group-local dispatch == global dispatch when nothing drops."""
    cfg = _moe_cfg()
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))
    y_g, _ = moe_apply(params, x, cfg)
    y_l, _ = moe_apply(params, x, dataclasses.replace(cfg,
                                                      moe_dispatch_groups=4))
    np.testing.assert_allclose(np.asarray(y_l), np.asarray(y_g),
                               rtol=1e-4, atol=1e-5)


def test_moe_shard_map_matches_global():
    """H1 iter-2: the shard_map MoE block is numerically identical on a 1x1
    mesh (and structurally local on real meshes)."""
    cfg = _moe_cfg()
    params = moe_init(jax.random.PRNGKey(0), cfg)
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y_g, aux_g = moe_apply(params, x, cfg)
    mesh = make_host_mesh()
    y_s, aux_s = moe_apply_shard_map(params, x, cfg, mesh)
    np.testing.assert_allclose(np.asarray(y_s), np.asarray(y_g),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(aux_s), float(aux_g), rtol=1e-4)


def test_seq_parallel_rules_lower_on_host_mesh(rng):
    """H2 rules produce valid shardings (axis dedupe) and identical loss."""
    from repro.models import api
    cfg = get_config("olmo-1b").reduced()
    params = api.init_params(cfg, rng)
    batch = {"tokens": jnp.zeros((2, 32), jnp.int32),
             "targets": jnp.zeros((2, 32), jnp.int32)}
    mesh = make_host_mesh()
    ref = api.train_loss(cfg, "ar")(params, batch, rng)
    with sharding_rules(mesh, SEQ_PARALLEL_TRAIN_RULES):
        got = api.train_loss(cfg, "ar")(params, batch, rng)
    np.testing.assert_allclose(float(got), float(ref), rtol=1e-5)


def test_free_oracle_smoke(gaussian_dpm, x_T):
    """Beyond-paper free-oracle corrector stays finite and close to plain."""
    from repro.core import DPMSolverPP, Grid
    from repro.core.solver import CorrectorConfig

    sched = gaussian_dpm.schedule

    def dm(x, t):
        a, s = float(sched.alpha(t)), float(sched.sigma(t))
        e = gaussian_dpm.eps_model(np.asarray(x, np.float64), t)
        return (np.asarray(x, np.float64) - s * e) / a

    g = Grid.build(sched, 10)
    s = DPMSolverPP(dm, g, order=3)
    x0 = s.sample(x_T, corrector=CorrectorConfig(order=3, free_oracle=0.5))
    assert np.all(np.isfinite(np.asarray(x0)))
    assert s.model.nfe == 10  # still free


def test_build_workload_lowers_on_host_mesh():
    """Dry-run plumbing (specs, shardings, jit) on the 1x1 host mesh with a
    reduced config — catches sharding-spec regressions without 512 devices."""
    from repro.configs.base import InputShape
    from repro.launch.dryrun import build_workload
    import repro.launch.dryrun as dr
    import repro.configs.registry as reg

    cfg = get_config("qwen2-0.5b").reduced()
    mesh = make_host_mesh()
    shape = InputShape("tiny_train", 32, 2, "train")
    with mesh, sharding_rules(mesh, TRAIN_RULES):
        fn, args, in_sh, out_sh = build_workload(cfg, shape, mesh, TRAIN_RULES)
        compiled = jax.jit(fn, in_shardings=in_sh,
                           out_shardings=out_sh).lower(*args).compile()
    assert compiled.cost_analysis() is not None


def test_decode_workload_lowers_on_host_mesh():
    from repro.configs.base import InputShape
    from repro.launch.dryrun import build_workload
    from repro.parallel.sharding import SERVE_RULES

    cfg = get_config("mamba2-780m").reduced()
    mesh = make_host_mesh()
    shape = InputShape("tiny_decode", 64, 2, "decode")
    with mesh, sharding_rules(mesh, SERVE_RULES):
        fn, args, in_sh, out_sh = build_workload(cfg, shape, mesh, SERVE_RULES)
        compiled = jax.jit(fn, in_shardings=in_sh).lower(*args).compile()
    assert compiled is not None
