"""Empirical order-of-accuracy tests on the analytic Gaussian DPM — the
paper's central claims (Thm 3.1, Cor 3.2, Prop A.1, Prop D.5/D.6)."""

import numpy as np
import pytest

from repro.core import DDIM, DPMSolverPP, Grid, UniPC
from repro.core.solver import CorrectorConfig
from repro.diffusion import empirical_order

MS = [20, 40, 80, 160]


def _model(dpm, prediction):
    if prediction == "noise":
        return lambda x, t: dpm.eps_model(np.asarray(x, np.float64), t)

    def data_model(x, t):
        sched = dpm.schedule
        a, s = float(sched.alpha(t)), float(sched.sigma(t))
        return (np.asarray(x, np.float64)
                - s * dpm.eps_model(np.asarray(x, np.float64), t)) / a

    return data_model


def _unipc_errors(dpm, x_T, order, prediction, variant, use_corrector):
    errs = []
    for M in MS:
        g = Grid.build(dpm.schedule, M)
        s = UniPC(_model(dpm, prediction), g, order=order,
                  prediction=prediction, variant=variant,
                  lower_order_final=False)
        x0 = s.sample_pc(x_T, use_corrector=use_corrector)
        ref = dpm.exact_solution(x_T, g.t[-1])
        errs.append(float(np.max(np.abs(x0 - ref))) + 1e-300)
    return errs


@pytest.mark.parametrize("order,expect", [(1, 1.0), (2, 2.0), (3, 3.0)])
@pytest.mark.parametrize("prediction", ["noise", "data"])
def test_unip_order(gaussian_dpm, x_T, order, expect, prediction):
    """Cor 3.2: UniP-p has order p."""
    errs = _unipc_errors(gaussian_dpm, x_T, order, prediction, "bh2", False)
    slope = empirical_order(errs, MS)
    assert slope > expect - 0.35, (slope, errs)


@pytest.mark.parametrize("order,expect", [(1, 2.0), (2, 3.0)])
@pytest.mark.parametrize("variant", ["bh1", "bh2", "vary"])
def test_unipc_order(gaussian_dpm, x_T, order, expect, variant):
    """Thm 3.1: UniPC-p (predictor + corrector) has order p+1."""
    errs = _unipc_errors(gaussian_dpm, x_T, order, "noise", variant, True)
    slope = empirical_order(errs, MS)
    assert slope > expect - 0.35, (slope, errs)


def test_unic_raises_ddim_order(gaussian_dpm, x_T):
    """Table 2 mechanism: UniC-1 after DDIM raises the measured order by ~1."""
    slopes = {}
    for corr in (None, CorrectorConfig(order=1, variant="bh2")):
        errs = []
        for M in MS:
            g = Grid.build(gaussian_dpm.schedule, M)
            s = DDIM(_model(gaussian_dpm, "noise"), g, prediction="noise")
            x0 = s.sample(x_T, corrector=corr)
            ref = gaussian_dpm.exact_solution(x_T, g.t[-1])
            errs.append(float(np.max(np.abs(x0 - ref))) + 1e-300)
        slopes[corr is None] = empirical_order(errs, MS)
    assert slopes[False] > slopes[True] + 0.6, slopes


def test_unic_improves_dpmpp(gaussian_dpm, x_T):
    """UniC after DPM-Solver++(2M) reduces error at a fixed budget."""
    errors = {}
    for corr in (None, CorrectorConfig(order=2, variant="bh2")):
        g = Grid.build(gaussian_dpm.schedule, 40)
        s = DPMSolverPP(_model(gaussian_dpm, "data"), g, order=2)
        x0 = s.sample(x_T, corrector=corr)
        ref = gaussian_dpm.exact_solution(x_T, g.t[-1])
        errors[corr is None] = float(np.max(np.abs(x0 - ref)))
    assert errors[False] < errors[True], errors


def test_oracle_not_worse(gaussian_dpm, x_T):
    """Table 3: UniC-oracle (re-eval at the corrected point) >= plain UniC."""
    res = {}
    for oracle in (False, True):
        g = Grid.build(gaussian_dpm.schedule, 20)
        s = UniPC(_model(gaussian_dpm, "data"), g, order=2, prediction="data")
        x0 = s.sample(x_T, corrector=CorrectorConfig(order=2, variant="bh2",
                                                     oracle=oracle))
        ref = gaussian_dpm.exact_solution(x_T, g.t[-1])
        res[oracle] = float(np.max(np.abs(x0 - ref)))
    assert res[True] <= res[False] * 1.5, res
