"""Cross-validation of the analytical accounting used in the roofline:
active_params vs the real parameter tree, and HLO flop accounting vs the
2ND rule on a real lowered forward."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import analyze
from repro.analysis.roofline import active_params
from repro.configs.registry import all_arch_ids, get_config
from repro.launch.specs import abstract_params


def _tree_params(cfg):
    tree = abstract_params(cfg)
    return sum(np.prod(l.shape) for l in jax.tree.leaves(tree))


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "olmo-1b", "qwen2.5-3b",
                                  "deepseek-67b", "mamba2-780m",
                                  "whisper-small"])
def test_active_params_close_to_tree(arch):
    """For non-MoE archs the analytical count must match the real tree within
    ~10% (the tree adds the diffusion head + norms; the formula ignores them)."""
    cfg = get_config(arch)
    analytic = active_params(cfg)
    real = _tree_params(cfg)
    assert abs(real - analytic) / real < 0.10, (arch, analytic, real)


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "granite-moe-3b-a800m"])
def test_active_params_below_total_for_moe(arch):
    cfg = get_config(arch)
    assert active_params(cfg) < 0.6 * _tree_params(cfg)


def test_hlo_flops_match_2nd_rule():
    """Lower a small dense LM forward and check HLO dot flops ~= 2*N*D
    (+ attention quadratic term) — validates the trip-count scaling that the
    whole roofline depends on."""
    from repro.models import transformer

    cfg = get_config("olmo-1b").reduced(num_layers=4, d_model=128, d_ff=512,
                                        vocab_size=1024, num_heads=4,
                                        num_kv_heads=4)
    params = jax.eval_shape(
        lambda r: transformer.init_lm(cfg, r), jax.random.PRNGKey(0))
    B, S = 4, 256

    def fwd(p, tokens):
        h, _ = transformer.forward(p, cfg, tokens)
        return transformer.logits_from_hidden(p, cfg, h)

    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    compiled = jax.jit(fwd).lower(params, tok).compile()
    acct = analyze(compiled.as_text(), 1)
    # matmul params (per layer: qkvo + gelu-mlp 2*d*f; embed output matmul)
    d, f, L, V = cfg.d_model, cfg.d_ff, cfg.num_layers, cfg.vocab_size
    n_mat = L * (4 * d * d + 2 * d * f) + V * d
    expect = 2 * n_mat * B * S + L * 2 * 2 * B * cfg.num_heads * S * S * (
        d // cfg.num_heads)
    assert abs(acct["flops"] - expect) / expect < 0.05, (acct["flops"], expect)
