"""The solver-agnostic engine: every zoo solver scan-compiled == its
python-loop GridSolver reference; UniC bolt-on composition; fused CFG
(one batched eval per step) == sequential guided_data_model + loop UniPC."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import Grid, UniPC
from repro.diffusion import (GaussianDPM, VPLinear, guidance_schedule,
                             guided_data_model)
from repro.engine import SOLVERS, EngineSpec, SamplerEngine, compile_table


def _eps_np(dpm):
    return lambda x, t: dpm.eps_model(np.asarray(x, np.float64), t)


def _eps_jx(dpm):
    sched = dpm.schedule

    def eps(x, t):
        t = jnp.asarray(t)
        a = jnp.exp(sched.log_alpha_jax(t))
        sig = jnp.sqrt(1 - a * a)
        return sig * (x - a * dpm.mu) / (a * a * dpm.s ** 2 + sig * sig)

    return eps


def _engines(dpm):
    """(scan engine on the jnp model, loop engine on the float64 np model)."""
    return (SamplerEngine(dpm.schedule, eps=_eps_jx(dpm)),
            SamplerEngine(dpm.schedule, eps=_eps_np(dpm)))


# ---------------------------------------------------------------------------
# scan-compiled zoo == python-loop references
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("solver,order", [
    ("ddim", 1), ("dpmpp", 1), ("dpmpp", 2), ("dpmpp", 3),
    ("pndm", 4), ("deis", 2), ("deis", 3), ("unipc", 2), ("unipc", 3),
])
@pytest.mark.parametrize("nfe", [5, 10, 20])
def test_scan_compiled_matches_loop(gaussian_dpm, x_T, solver, order, nfe):
    spec = EngineSpec(solver=solver, order=order, nfe=nfe)
    eng, eng_np = _engines(gaussian_dpm)
    out = eng.build(spec, jit=False)(jnp.asarray(x_T, jnp.float32))
    ref = eng_np.build_loop(spec)(x_T)
    np.testing.assert_allclose(np.asarray(out, np.float64),
                               np.asarray(ref, np.float64), atol=1e-5, rtol=0)


@pytest.mark.parametrize("order", [2, 3])
@pytest.mark.parametrize("nfe", [10, 20])
def test_singlestep_dpm_scan_matches_loop(gaussian_dpm, x_T, order, nfe):
    """DPM-Solver 2S/3S on the expanded grid. At very few grid steps the
    re-based rows carry expm1(h)-sized coefficients whose fp32 cancellation
    dominates (the compile itself is exact — see the fp64 test below), so
    the fp32 bound is checked at NFE >= 10."""
    spec = EngineSpec(solver="dpm", order=order, nfe=nfe)
    eng, eng_np = _engines(gaussian_dpm)
    out = eng.build(spec, jit=False)(jnp.asarray(x_T, jnp.float32))
    ref = eng_np.build_loop(spec)(x_T)
    np.testing.assert_allclose(np.asarray(out, np.float64),
                               np.asarray(ref, np.float64), atol=1e-5, rtol=0)


@pytest.mark.parametrize("order", [2, 3])
def test_singlestep_dpm_compile_exact_fp64(gaussian_dpm, x_T, order):
    """The expanded-grid re-basing is exact linear algebra: at float64 the
    scan reproduces the python loop to near machine precision even at the
    worst-conditioned grid (one or two giant-h steps)."""
    from repro.core.unipc import unipc_sample_scan

    jax.config.update("jax_enable_x64", True)
    try:
        spec = EngineSpec(solver="dpm", order=order, nfe=5)
        eng, eng_np = _engines(gaussian_dpm)
        tab = eng.compile(spec)
        out = unipc_sample_scan(eng.model_fn(spec, tab),
                                jnp.asarray(x_T, jnp.float64), tab,
                                fused_update=False, dtype=jnp.float64)
        ref = eng_np.build_loop(spec)(x_T)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-9, rtol=0)
    finally:
        jax.config.update("jax_enable_x64", False)


@pytest.mark.parametrize("solver,order", [
    ("ddim", 1), ("dpmpp", 2), ("dpmpp", 3), ("pndm", 4), ("deis", 3),
])
def test_unic_bolt_on_scan_matches_loop(gaussian_dpm, x_T, solver, order):
    """Table 2 on the scan path: the method-agnostic UniC composes with any
    compiled solver — same rows the python loop's CorrectorConfig applies —
    and improves the solution at the same grid."""
    spec = EngineSpec(solver=solver, order=order, nfe=16, use_corrector=True)
    eng, eng_np = _engines(gaussian_dpm)
    out = eng.build(spec, jit=False)(jnp.asarray(x_T, jnp.float32))
    ref = eng_np.build_loop(spec)(x_T)
    np.testing.assert_allclose(np.asarray(out, np.float64),
                               np.asarray(ref, np.float64), atol=1e-5, rtol=0)
    plain = eng.build(EngineSpec(solver=solver, order=order, nfe=16),
                      jit=False)(jnp.asarray(x_T, jnp.float32))
    g = Grid.build(gaussian_dpm.schedule, 16)
    exact = gaussian_dpm.exact_solution(x_T, g.t[-1])

    def err(x0):
        return float(np.max(np.abs(np.asarray(x0, np.float64) - exact)))

    assert err(out) < err(plain), (solver, err(out), err(plain))


def test_wide_k_tables_through_kernel_dispatch(gaussian_dpm):
    """PLMS-4 + UniC-4 produces the widest combine in the zoo (6 terms at
    the corrector); the fused dispatch (and the interpret-mode Pallas
    kernel) must agree with the pinned jnp tensordot reference."""
    from repro.core.unipc import unipc_sample_scan
    from repro.kernels.unipc_update import ops as fused_ops

    eng, _ = _engines(gaussian_dpm)
    spec = EngineSpec(solver="pndm", nfe=12, use_corrector=True)
    tab = eng.compile(spec)
    assert tab.w_pred.shape[1] == 3  # K=3 -> corrector combine has 6 terms
    model = eng.model_fn(spec, tab)
    x = jnp.asarray(np.random.default_rng(3).normal(size=(2, 8)), jnp.float32)
    fused = unipc_sample_scan(model, x, tab, fused_update=True)
    ref = unipc_sample_scan(model, x, tab, fused_update=False)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               atol=1e-5, rtol=0)
    # the Pallas kernel itself (interpret mode off-TPU) at K=6
    terms = jnp.asarray(np.random.default_rng(4).normal(size=(6, 2, 200)),
                        jnp.float32)
    w = jnp.asarray(np.random.default_rng(5).normal(size=(6,)), jnp.float32)
    out = fused_ops.weighted_combine(terms, w, force_pallas=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.tensordot(w, terms, axes=1)),
                               atol=1e-5, rtol=0)


def test_engine_scan_is_jittable(gaussian_dpm):
    eng, _ = _engines(gaussian_dpm)
    run = eng.build(EngineSpec(solver="dpmpp", order=2, nfe=8))
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8))
    out = run(x)
    assert out.shape == x.shape and np.all(np.isfinite(np.asarray(out)))


# ---------------------------------------------------------------------------
# fused CFG
# ---------------------------------------------------------------------------


def _cfg_setup(vp):
    cond = GaussianDPM(vp, mu=0.7, s=0.35)
    uncond = GaussianDPM(vp, mu=-0.4, s=0.5)
    eps_c, eps_u = _eps_jx(cond), _eps_jx(uncond)

    def eps_stacked(xx, t):
        x1, x2 = jnp.split(xx, 2, axis=0)
        return jnp.concatenate([eps_c(x1, t), eps_u(x2, t)], axis=0)

    return eps_c, eps_u, eps_stacked


@pytest.mark.parametrize("thresholding", [False, True])
def test_fused_cfg_matches_guided_loop(vp, thresholding):
    """Fused-CFG-in-scan (one stacked batched eval per step) == sequential
    guided_data_model (two evals per step) + python-loop UniPC."""
    eps_c, eps_u, eps_stacked = _cfg_setup(vp)
    x_T = jnp.asarray(
        np.random.default_rng(0).normal(size=(4, 8)), jnp.float32)
    eng = SamplerEngine(vp, eps=eps_c, eps_stacked=eps_stacked,
                        eps_uncond=eps_u)
    spec = EngineSpec(solver="unipc", order=3, nfe=10, cfg_scale=2.0,
                      thresholding=thresholding)
    out = eng.build(spec)(x_T)
    gm = guided_data_model(vp, eps_c, eps_u, guidance_scale=2.0,
                           thresholding=thresholding)
    ref = UniPC(gm, Grid.build(vp, 10), order=3,
                prediction="data").sample_pc(x_T, use_corrector=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=1e-5, rtol=0)


def test_cfg_one_batched_eval_per_step(vp):
    """The acceptance property: with cfg_scale != 0, the scan performs
    exactly one network call per eval point, each on the stacked 2B batch —
    never cfg_model's two sequential B-sized calls."""
    eps_c, eps_u, _ = _cfg_setup(vp)
    calls = []
    B, nfe = 3, 6

    def eps_stacked(xx, t):
        rows = xx.shape[0]  # static under trace
        jax.debug.callback(lambda _: calls.append(rows), t)
        x1, x2 = jnp.split(xx, 2, axis=0)
        return jnp.concatenate([eps_c(x1, t), eps_u(x2, t)], axis=0)

    eng = SamplerEngine(vp, eps=eps_c, eps_stacked=eps_stacked)
    run = eng.build(EngineSpec(solver="unipc", order=3, nfe=nfe,
                               cfg_scale=2.0))
    x_T = jnp.asarray(np.random.default_rng(1).normal(size=(B, 8)),
                      jnp.float32)
    jax.block_until_ready(run(x_T))
    assert len(calls) == nfe + 1, calls
    assert all(c == 2 * B for c in calls), calls


def test_serve_diffusion_cfg_one_batched_eval_per_step(monkeypatch):
    """`serve_diffusion --cfg-scale 2.0` end to end: the dit eps-net is
    entered once per scheduler tick, always on the stacked 2B batch — and
    the AOT compile (`lower().compile()`, the serve-timing fix) performs no
    eval at all, so the count is exactly the nfe+1 serving ticks."""
    from repro.launch.serve import serve_diffusion
    from repro.models import api

    calls = []
    real_factory = api.eps_network

    def counting_factory(cfg):
        net = real_factory(cfg)

        def wrapped(p, x_t, t, batch):
            jax.debug.callback(lambda _: calls.append(x_t.shape[0]), t)
            return net(p, x_t, t, batch)

        return wrapped

    monkeypatch.setattr(api, "eps_network", counting_factory)
    batch, nfe = 2, 4
    out = serve_diffusion("dit-cifar", reduced=True, batch=batch, nfe=nfe,
                          cfg_scale=2.0)
    assert out.shape[0] == batch and np.isfinite(out).all()
    # batch requests all arrive at tick 0 -> one drain of nfe+1 ticks, each
    # ONE batched eval on the 2B stacked batch; AOT compile adds none
    assert len(calls) == nfe + 1, calls
    assert all(c == 2 * batch for c in calls), calls


def test_cfg_schedule_columns(vp):
    """Guidance-scale schedules ride the table as per-eval columns."""
    g = guidance_schedule(2.0, 5, "constant")
    np.testing.assert_allclose(g, 2.0)
    g = guidance_schedule(2.0, 5, "linear", scale_end=0.0)
    np.testing.assert_allclose(g, [2.0, 1.5, 1.0, 0.5, 0.0])
    g = guidance_schedule(2.0, 5, "cosine", scale_end=0.0)
    assert g[0] == 2.0 and abs(g[-1]) < 1e-12 and np.all(np.diff(g) < 0)
    eps_c, eps_u, eps_stacked = _cfg_setup(vp)
    eng = SamplerEngine(vp, eps=eps_c, eps_stacked=eps_stacked)
    tab = eng.compile(EngineSpec(solver="dpmpp", order=2, nfe=6,
                                 cfg_scale=2.0, cfg_schedule="linear",
                                 cfg_scale_end=0.5, thresholding=True))
    assert set(tab.model_cols) == {"g", "tq"}
    assert len(tab.model_cols["g"]) == len(tab.timesteps) == 7
    assert tab.model_cols["g"][0] == 2.0 and tab.model_cols["g"][-1] == 0.5
    np.testing.assert_allclose(tab.model_cols["tq"], 0.995)
    # the scheduled-cfg scan runs and stays finite
    x_T = jnp.asarray(np.random.default_rng(2).normal(size=(2, 8)),
                      jnp.float32)
    out = eng.build(EngineSpec(solver="dpmpp", order=2, nfe=6, cfg_scale=2.0,
                               cfg_schedule="cosine", cfg_scale_end=0.0))(x_T)
    assert np.all(np.isfinite(np.asarray(out)))


# ---------------------------------------------------------------------------
# registry / spec validation
# ---------------------------------------------------------------------------


def test_registry_covers_the_zoo():
    assert {"unipc", "ddim", "dpmpp", "pndm", "deis", "dpm"} <= set(SOLVERS)


def test_spec_validation(vp):
    with pytest.raises(KeyError):
        EngineSpec(solver="euler").resolve()
    with pytest.raises(ValueError):  # dpmpp is data-prediction only
        EngineSpec(solver="dpmpp", prediction="noise").resolve()
    with pytest.raises(ValueError):  # UniC is grid-anchored
        EngineSpec(solver="dpm", use_corrector=True).resolve()
    with pytest.raises(ValueError):  # thresholding needs data prediction
        eng = SamplerEngine(vp, eps=lambda x, t: x)
        eng.compile(EngineSpec(solver="deis", thresholding=True))
    # resolve fills solver defaults
    spec = EngineSpec(solver="unipc").resolve()
    assert spec.prediction == "data" and spec.use_corrector
    spec = EngineSpec(solver="pndm").resolve()
    assert spec.prediction == "noise" and not spec.use_corrector


def test_unipc_table_unchanged_through_engine(vp):
    """The engine's unipc compile is exactly core's build_unipc_schedule."""
    from repro.core import make_unipc_schedule

    tab = compile_table(EngineSpec(solver="unipc", order=3, nfe=8), vp)
    ref = make_unipc_schedule(vp, 8, order=3, prediction="data")
    for f in ("base_x", "base_m0", "w_pred", "w_corr_prev", "w_corr_new",
              "use_corrector", "out_scale", "timesteps"):
        np.testing.assert_array_equal(getattr(tab, f), getattr(ref, f))
