"""Solver equivalences and baseline behaviour (paper §3.3, Tables 2/6)."""

import numpy as np
import pytest

from repro.core import (DDIM, DEIS, DPMSolverPP, DPMSolverSinglestep, PNDM,
                        Grid, UniPC, UniPCSinglestep)
from repro.core.solver import CorrectorConfig


def _noise_model(dpm):
    return lambda x, t: dpm.eps_model(np.asarray(x, np.float64), t)


def _data_model(dpm):
    def f(x, t):
        sched = dpm.schedule
        a, s = float(sched.alpha(t)), float(sched.sigma(t))
        return (np.asarray(x, np.float64) - s * _noise_model(dpm)(x, t)) / a
    return f


def _err(x0, dpm, x_T, g):
    return float(np.max(np.abs(x0 - dpm.exact_solution(x_T, g.t[-1]))))


def test_ddim_equals_unip1(gaussian_dpm, x_T):
    """§3.3: when p=1, UniP reduces to DDIM — exact equality."""
    g = Grid.build(gaussian_dpm.schedule, 12)
    d = DDIM(_noise_model(gaussian_dpm), g, prediction="noise").sample(x_T)
    u = UniPC(_noise_model(gaussian_dpm), g, order=1,
              prediction="noise").sample_pc(x_T, use_corrector=False)
    np.testing.assert_allclose(np.asarray(d), np.asarray(u), rtol=1e-12)


def test_dpm_solver2_equals_unip2_bh2(gaussian_dpm, x_T):
    """§3.3: DPM-Solver-2 lies in the UniPC framework as UniP-2 with
    B(h) = e^h - 1 (singlestep, r1 = 0.5)."""
    g = Grid.build(gaussian_dpm.schedule, 10)
    ref = DPMSolverSinglestep(_noise_model(gaussian_dpm), g,
                              gaussian_dpm.schedule, order=2,
                              prediction="noise").sample(x_T)
    uni = UniPCSinglestep(_noise_model(gaussian_dpm), g,
                          gaussian_dpm.schedule, order=2,
                          prediction="noise", variant="bh2").sample(x_T)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(uni), rtol=1e-7)


@pytest.mark.parametrize("solver_key", ["ddim", "dpmpp2", "dpmpp3", "dpm3s",
                                        "pndm", "deis"])
def test_baselines_converge(gaussian_dpm, x_T, solver_key):
    errs = []
    for M in (20, 80):
        g = Grid.build(gaussian_dpm.schedule, M)
        if solver_key == "ddim":
            s = DDIM(_noise_model(gaussian_dpm), g, prediction="noise")
        elif solver_key == "dpmpp2":
            s = DPMSolverPP(_data_model(gaussian_dpm), g, order=2)
        elif solver_key == "dpmpp3":
            s = DPMSolverPP(_data_model(gaussian_dpm), g, order=3)
        elif solver_key == "dpm3s":
            s = DPMSolverSinglestep(_noise_model(gaussian_dpm), g,
                                    gaussian_dpm.schedule, order=3,
                                    prediction="noise")
        elif solver_key == "pndm":
            s = PNDM(_noise_model(gaussian_dpm), g)
        else:
            s = DEIS(_noise_model(gaussian_dpm), g, gaussian_dpm.schedule,
                     order=3)
        errs.append(_err(s.sample(x_T), gaussian_dpm, x_T, g))
    assert errs[1] < errs[0], (solver_key, errs)
    assert errs[1] < 0.05, (solver_key, errs)


@pytest.mark.parametrize("solver_key,order,pred", [
    ("ddim", 1, "noise"), ("dpmpp2", 2, "data"), ("dpmpp3", 3, "data"),
    ("dpm3s", 3, "noise"), ("pndm", 3, "noise"), ("deis", 3, "noise"),
])
def test_unic_improves_every_solver(gaussian_dpm, x_T, solver_key, order, pred):
    """Table 2: UniC is method-agnostic — it improves each off-the-shelf
    solver at the same grid."""
    res = {}
    for use_c in (False, True):
        g = Grid.build(gaussian_dpm.schedule, 16)
        if solver_key == "ddim":
            s = DDIM(_noise_model(gaussian_dpm), g, prediction="noise")
        elif solver_key == "dpmpp2":
            s = DPMSolverPP(_data_model(gaussian_dpm), g, order=2)
        elif solver_key == "dpmpp3":
            s = DPMSolverPP(_data_model(gaussian_dpm), g, order=3)
        elif solver_key == "dpm3s":
            s = DPMSolverSinglestep(_noise_model(gaussian_dpm), g,
                                    gaussian_dpm.schedule, order=3,
                                    prediction="noise")
        elif solver_key == "pndm":
            s = PNDM(_noise_model(gaussian_dpm), g)
        else:
            s = DEIS(_noise_model(gaussian_dpm), g, gaussian_dpm.schedule,
                     order=3)
        corr = CorrectorConfig(order=order, variant="bh2") if use_c else None
        res[use_c] = _err(s.sample(x_T, corrector=corr), gaussian_dpm, x_T, g)
    assert res[True] < res[False], (solver_key, res)


def test_singlestep_unipc_converges(gaussian_dpm, x_T):
    errs = []
    for M in (10, 40):
        g = Grid.build(gaussian_dpm.schedule, M)
        s = UniPCSinglestep(_noise_model(gaussian_dpm), g,
                            gaussian_dpm.schedule, order=3,
                            prediction="noise")
        errs.append(_err(s.sample(x_T), gaussian_dpm, x_T, g))
    assert errs[1] < errs[0] and errs[1] < 0.01, errs


def test_custom_order_schedule(gaussian_dpm, x_T):
    """Table 4 mechanism: arbitrary order schedules run and stay finite;
    an all-max schedule is not automatically better."""
    g = Grid.build(gaussian_dpm.schedule, 7)
    for sched in ([1, 2, 3, 3, 3, 2, 1], [1, 2, 2, 3, 3, 3, 4],
                  [1, 2, 3, 4, 5, 6, 7]):
        s = UniPC(_noise_model(gaussian_dpm), g, order=max(sched),
                  prediction="noise", order_schedule=sched)
        x0 = s.sample_pc(x_T, use_corrector=True)
        assert np.all(np.isfinite(np.asarray(x0))), sched


def test_nfe_accounting(gaussian_dpm, x_T):
    """Corrector must not add NFE (the current-step eval is re-used)."""
    for use_c in (False, True):
        g = Grid.build(gaussian_dpm.schedule, 9)
        s = UniPC(_noise_model(gaussian_dpm), g, order=3, prediction="noise")
        s.sample_pc(x_T, use_corrector=use_c)
        assert s.model.nfe == 9, (use_c, s.model.nfe)
    # oracle costs extra evals (Table 3's NFE caveat)
    g = Grid.build(gaussian_dpm.schedule, 9)
    s = UniPC(_noise_model(gaussian_dpm), g, order=3, prediction="noise")
    s.sample(x_T, corrector=CorrectorConfig(order=3, oracle=True))
    assert s.model.nfe > 9
